"""L1 correctness: every Pallas kernel against its pure-jnp oracle,
hypothesis-swept over shapes and dtypes (the CORE correctness signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ber_inject, conv_pe, ref, systolic_mm

settings.register_profile("kernels", deadline=None, max_examples=12)
settings.load_profile("kernels")


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------- systolic_mm

@given(
    m=st.integers(1, 48),
    k=st.integers(1, 64),
    n=st.integers(1, 48),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_matmul_matches_ref(m, k, n, dtype):
    x = rand(m * 7 + 1, (m, k), dtype)
    w = rand(n * 13 + 2, (k, n), dtype)
    got = systolic_mm.matmul(x, w)
    want = ref.matmul_ref(x, w)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-4)


@pytest.mark.parametrize("bm,bn", [(1, 1), (8, 8), (128, 128), (7, 3)])
def test_matmul_block_shapes(bm, bn):
    x = rand(1, (24, 32), jnp.float32)
    w = rand(2, (32, 12), jnp.float32)
    np.testing.assert_allclose(
        systolic_mm.matmul(x, w, bm=bm, bn=bn), ref.matmul_ref(x, w),
        rtol=1e-4, atol=1e-5,  # reduction order differs per block shape
    )


def test_matmul_rejects_bad_inner_dim():
    with pytest.raises(AssertionError):
        systolic_mm.matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))


def test_matmul_vmem_estimate_positive():
    assert systolic_mm.vmem_bytes(256, 512, 256) > 0
    # Full-K stripes: VMEM grows linearly in K.
    assert systolic_mm.vmem_bytes(256, 1024, 256) > systolic_mm.vmem_bytes(256, 512, 256)


# -------------------------------------------------------------------- conv_pe

@given(
    n=st.integers(1, 4),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    hw=st.sampled_from([4, 6, 8, 16]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_conv_matches_ref(n, cin, cout, hw, dtype):
    x = rand(n * 31 + 3, (n, cin, hw, hw), dtype)
    w = rand(cout * 17 + 4, (cout, cin, 3, 3), dtype)
    b = rand(5, (cout,), dtype)
    got = conv_pe.conv3x3_same(x, w, b)
    want = ref.conv3x3_same_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=1e-3)


def test_conv_identity_kernel():
    # A delta kernel must reproduce the input channel.
    x = rand(6, (1, 1, 8, 8), jnp.float32)
    w = jnp.zeros((1, 1, 3, 3), jnp.float32).at[0, 0, 1, 1].set(1.0)
    b = jnp.zeros((1,), jnp.float32)
    np.testing.assert_allclose(conv_pe.conv3x3_same(x, w, b), x, rtol=1e-6)


def test_conv_bias_broadcast():
    x = jnp.zeros((2, 3, 4, 4), jnp.float32)
    w = jnp.zeros((5, 3, 3, 3), jnp.float32)
    b = jnp.arange(5, dtype=jnp.float32)
    out = conv_pe.conv3x3_same(x, w, b)
    for c in range(5):
        np.testing.assert_allclose(out[:, c], jnp.full((2, 4, 4), float(c)))


def test_conv_vmem_estimate():
    assert conv_pe.vmem_bytes(8, 32, 16, 16) > 0


# ----------------------------------------------------------------- ber_inject

@given(n=st.integers(1, 256), seed=st.integers(0, 2**31 - 1))
def test_bitflip_matches_ref(n, seed):
    x = rand(seed % 1000, (n,), jnp.float32)
    mask = jnp.asarray(
        np.random.RandomState(seed % 2**31).randint(0, 2**32, size=n, dtype=np.uint64)
    ).astype(jnp.uint32)
    got = ber_inject.bitflip(x, mask)
    want = ref.bitflip_ref(x, mask)
    np.testing.assert_array_equal(
        np.asarray(got).view(np.uint32), np.asarray(want).view(np.uint32)
    )


def test_bitflip_zero_mask_identity():
    x = rand(1, (64,), jnp.float32)
    out = ber_inject.bitflip(x, jnp.zeros(64, jnp.uint32))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_bitflip_involution():
    x = rand(2, (64,), jnp.float32)
    mask = jnp.full((64,), (1 << 22) | (1 << 3), jnp.uint32)
    twice = ber_inject.bitflip(ber_inject.bitflip(x, mask), mask)
    np.testing.assert_array_equal(np.asarray(twice), np.asarray(x))


def test_bitflip_sign_bit():
    x = jnp.array([1.0, -2.5], jnp.float32)
    out = ber_inject.bitflip(x, jnp.full((2,), 1 << 31, jnp.uint32))
    np.testing.assert_allclose(np.asarray(out), [-1.0, 2.5])


# ------------------------------------------------------------------- maxpool

@given(n=st.integers(1, 3), c=st.integers(1, 4), hw=st.sampled_from([2, 4, 8]))
def test_maxpool_ref_matches_lax(n, c, hw):
    x = rand(9, (n, c, hw, hw), jnp.float32)
    want = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )
    np.testing.assert_allclose(ref.maxpool2_ref(x), want)
