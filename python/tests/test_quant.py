"""int8 quantized path: Pallas kernel vs reference, quantization error
bounds, and end-to-end quantized-linear accuracy."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import quant

settings.register_profile("quant", deadline=None, max_examples=10)
settings.load_profile("quant")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@given(m=st.integers(1, 32), k=st.integers(1, 48), n=st.integers(1, 32))
def test_matmul_i8_matches_ref(m, k, n):
    xq, _ = quant.quantize(rand(m + 100, (m, k)))
    wq, _ = quant.quantize(rand(n + 200, (k, n)))
    got = quant.matmul_i8(xq, wq)
    want = quant.matmul_i8_ref(xq, wq)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32


def test_quantize_roundtrip_error_bound():
    x = rand(1, (64, 64), scale=3.0)
    q, s = quant.quantize(x)
    back = quant.dequantize(q, s)
    # Symmetric int8: max error is half a step.
    max_err = float(jnp.max(jnp.abs(back - x)))
    assert max_err <= float(s) * 0.5 + 1e-6


def test_quantize_preserves_zero_and_sign():
    x = jnp.array([[0.0, -1.0, 1.0, -0.5]], jnp.float32)
    q, s = quant.quantize(x)
    qa = np.asarray(q)
    assert qa[0, 0] == 0
    assert qa[0, 1] < 0 < qa[0, 2]
    assert s > 0


def test_quantize_saturates_at_127():
    x = jnp.array([[1000.0, -1000.0, 0.1]], jnp.float32)
    q, _ = quant.quantize(x)
    qa = np.asarray(q)
    assert qa[0, 0] == 127 and qa[0, 1] == -127


def test_linear_quantized_close_to_f32():
    x = rand(3, (8, 64))
    w = rand(4, (64, 16)) * 0.1
    got = quant.linear_quantized(x, w)
    want = jnp.matmul(x, w)
    # int8 linear: ~1% relative error at these scales.
    err = float(jnp.max(jnp.abs(got - want)) / (jnp.max(jnp.abs(want)) + 1e-8))
    assert err < 0.05, err
