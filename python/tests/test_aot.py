"""AOT path: lowering produces loadable HLO text with the right signature."""

import jax.numpy as jnp

from compile import aot, model


def test_lower_forward_emits_hlo_text():
    hlo = aot.lower_forward(batch=1)
    assert "ENTRY" in hlo and "HloModule" in hlo
    # One parameter per weight tensor + the image input, inside the ENTRY
    # computation body (HLO text puts the body between "ENTRY ... {" and "}").
    entry_body = hlo.split("ENTRY", 1)[1]
    entry_body = entry_body.split("\n}", 1)[0]
    n_params = entry_body.count("parameter(")
    assert n_params == len(model.PARAM_SPECS) + 1, entry_body


def test_lowered_output_shape_in_text():
    hlo = aot.lower_forward(batch=1)
    # Tuple-wrapped (1, 10) logits.
    assert "(f32[1,10]" in hlo.replace(" ", "") or "f32[1,10]" in hlo


def test_param_specs_order_matches_model():
    names = [n for n, _ in model.PARAM_SPECS]
    assert names == [
        "conv1_w", "conv1_b", "conv2_w", "conv2_b",
        "fc1_w", "fc1_b", "fc2_w", "fc2_b",
    ]
    sizes = [int(jnp.zeros(s).size) for _, s in model.PARAM_SPECS]
    assert sum(sizes) == 8 * 1 * 9 + 8 + 32 * 8 * 9 + 32 + 512 * 128 + 128 + 128 * 10 + 10
