"""L2 correctness: the Pallas-built TinyCNN against its jnp reference,
shape contracts, and the PARAM_SPECS single-source-of-truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(42))


def test_param_specs_consistent(params):
    assert len(params) == len(model.PARAM_SPECS)
    for p, (name, shape) in zip(params, model.PARAM_SPECS):
        assert p.shape == shape, name
        assert p.dtype == jnp.float32


def test_forward_shapes(params):
    for batch in (1, 3, 16):
        x = jnp.zeros((batch, *model.IMAGE_SHAPE), jnp.float32)
        out = model.forward_ref(params, x)
        assert out.shape == (batch, model.NUM_CLASSES)


def test_pallas_equals_ref(params):
    # The core L2 signal: both forward paths are the same function.
    x, _ = data.make_dataset(jax.random.PRNGKey(7), 4)
    got = model.forward_pallas(params, x)
    want = model.forward_ref(params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_aot_entrypoint_tuple(params):
    x, _ = data.make_dataset(jax.random.PRNGKey(8), 2)
    out = model.forward_pallas_tuple(*params, x)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (2, model.NUM_CLASSES)


def test_param_count_is_tinycnn_class():
    n = sum(int(np.prod(s)) for _, s in model.PARAM_SPECS)
    assert 60_000 < n < 90_000, n


def test_bias_only_changes_logits(params):
    x, _ = data.make_dataset(jax.random.PRNGKey(9), 2)
    base = model.forward_ref(params, x)
    bumped = list(params)
    bumped[-1] = bumped[-1] + 1.0  # fc2 bias
    out = model.forward_ref(bumped, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base) + 1.0, rtol=1e-5)
