"""Make the build-time `compile` package importable when pytest runs from
the repository root (the Makefile runs it from python/)."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
