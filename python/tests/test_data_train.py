"""Dataset determinism/learnability and the build-time training loop."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data, model, train


def test_dataset_deterministic():
    x1, y1 = data.make_dataset(jax.random.PRNGKey(3), 32)
    x2, y2 = data.make_dataset(jax.random.PRNGKey(3), 32)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    x3, _ = data.make_dataset(jax.random.PRNGKey(4), 32)
    assert not np.array_equal(np.asarray(x1), np.asarray(x3))


def test_dataset_shapes_and_labels():
    x, y = data.make_dataset(jax.random.PRNGKey(0), 100)
    assert x.shape == (100, *model.IMAGE_SHAPE)
    assert y.shape == (100,)
    assert int(y.min()) >= 0 and int(y.max()) < model.NUM_CLASSES
    # All ten classes appear in a reasonable sample.
    assert len(np.unique(np.asarray(y))) == 10


def test_class_patterns_distinct():
    pats = [np.asarray(data._class_pattern(k)) for k in range(10)]
    for i in range(10):
        for j in range(i + 1, 10):
            assert not np.allclose(pats[i], pats[j]), (i, j)


def test_loss_decreases_quickly():
    # A short burst of Adam steps must cut the loss markedly — the dataset
    # is learnable and the gradient path is sound.
    key = jax.random.PRNGKey(1)
    x, y = data.make_dataset(key, 512)
    params = model.init_params(jax.random.PRNGKey(2))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    first = float(train.cross_entropy(params, x, y))
    for t in range(1, 41):
        params, m, v, loss = train.adam_step(params, m, v, t, x, y)
    last = float(train.cross_entropy(params, x, y))
    assert last < 0.7 * first, (first, last)


def test_accuracy_helper_bounds():
    x, y = data.make_dataset(jax.random.PRNGKey(5), 64)
    params = model.init_params(jax.random.PRNGKey(6))
    acc = train.accuracy(params, x, y)
    assert 0.0 <= acc <= 1.0
