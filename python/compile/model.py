"""L2: the TinyCNN model assembled from the L1 Pallas kernels.

TinyCNN is the end-to-end accuracy workload of the Fig. 21 reproduction
(DESIGN.md §3 documents the ImageNet→synthetic substitution): a small conv
net — conv(1→8) → pool → conv(8→32) → pool → fc(512→128) → fc(128→10) —
over 16x16 single-channel images, 10 classes, ~70k parameters.

Two forward paths with identical semantics (pytest asserts so):

* `forward_pallas` — built on `kernels.conv_pe` / `kernels.systolic_mm`;
  this is what `aot.py` lowers to the HLO artifact the Rust runtime serves.
* `forward_ref`    — pure-jnp (kernels/ref.py); used by `train.py` where
  interpret-mode Pallas would be orders of magnitude too slow.

Params flow as a flat list of arrays so the lowered HLO takes each tensor
as a separate parameter — the Rust side rebuilds them from the flat weight
file via the manifest offsets and can fault-inject any of them.
"""

import jax
import jax.numpy as jnp

from .kernels import conv_pe, ref, systolic_mm

IMAGE_SHAPE = (1, 16, 16)
NUM_CLASSES = 10

# (name, shape) in call order — single source of truth for model.py,
# train.py, aot.py and the Rust manifest.
PARAM_SPECS = [
    ("conv1_w", (8, 1, 3, 3)),
    ("conv1_b", (8,)),
    ("conv2_w", (32, 8, 3, 3)),
    ("conv2_b", (32,)),
    ("fc1_w", (512, 128)),
    ("fc1_b", (128,)),
    ("fc2_w", (128, 10)),
    ("fc2_b", (10,)),
]


def init_params(key):
    """He-init parameters as a list in PARAM_SPECS order."""
    params = []
    for name, shape in PARAM_SPECS:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[1:] if len(shape) == 4 else shape[:1]:
                fan_in *= d
            scale = jnp.sqrt(2.0 / fan_in)
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def _head(h, fc1_w, fc1_b, fc2_w, fc2_b, mm):
    n = h.shape[0]
    h = h.reshape(n, -1)
    h = jax.nn.relu(mm(h, fc1_w) + fc1_b)
    return mm(h, fc2_w) + fc2_b


def forward_pallas(params, x):
    """Logits via the Pallas kernels. x: (N, 1, 16, 16) -> (N, 10)."""
    c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b = params
    h = jax.nn.relu(conv_pe.conv3x3_same(x, c1w, c1b))
    h = ref.maxpool2_ref(h)  # pooling stays jnp (paper: pool is not the PE)
    h = jax.nn.relu(conv_pe.conv3x3_same(h, c2w, c2b))
    h = ref.maxpool2_ref(h)
    return _head(h, f1w, f1b, f2w, f2b, systolic_mm.matmul)


def forward_ref(params, x):
    """Same model on the pure-jnp reference ops (fast path for training)."""
    c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b = params
    h = jax.nn.relu(ref.conv3x3_same_ref(x, c1w, c1b))
    h = ref.maxpool2_ref(h)
    h = jax.nn.relu(ref.conv3x3_same_ref(h, c2w, c2b))
    h = ref.maxpool2_ref(h)
    return _head(h, f1w, f1b, f2w, f2b, ref.matmul_ref)


def forward_pallas_tuple(*args):
    """AOT entrypoint: (w..., x) -> (logits,). Tuple return for the HLO
    bridge (return_tuple=True), see /opt/xla-example/gen_hlo.py."""
    params, x = list(args[:-1]), args[-1]
    return (forward_pallas(params, x),)
