"""Build-time training of TinyCNN on the synthetic dataset.

A few hundred Adam steps on the pure-jnp forward (kernels/ref.py — the
Pallas path is numerically identical but interpret-mode slow). The loss
curve is logged to artifacts/train_log.json and summarized in
EXPERIMENTS.md. Deterministic: fixed seeds end to end.
"""

import json

import jax
import jax.numpy as jnp

from . import data, model

TRAIN_N = 4096
TEST_N = 512
BATCH = 64
STEPS = 400
LR = 1e-3
SEED = 0


def cross_entropy(params, x, y):
    logits = model.forward_ref(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(x.shape[0]), y])


@jax.jit
def adam_step(params, m, v, t, x, y):
    loss, grads = jax.value_and_grad(cross_entropy)(params, x, y)
    b1, b2, eps = 0.9, 0.999, 1e-8
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        mhat = mi / (1 - b1**t)
        vhat = vi / (1 - b2**t)
        new_params.append(p - LR * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_params, new_m, new_v, loss


def accuracy(params, x, y, batch=256):
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = model.forward_ref(params, x[i : i + batch])
        correct += int((jnp.argmax(logits, -1) == y[i : i + batch]).sum())
    return correct / x.shape[0]


def train(verbose=True):
    """Returns (params, test_images, test_labels, log_dict)."""
    key = jax.random.PRNGKey(SEED)
    k_init, k_train, k_test = jax.random.split(key, 3)
    train_x, train_y = data.make_dataset(k_train, TRAIN_N)
    test_x, test_y = data.make_dataset(k_test, TEST_N)

    params = model.init_params(k_init)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]

    losses = []
    perm_key = jax.random.PRNGKey(SEED + 1)
    for step in range(1, STEPS + 1):
        perm_key, sub = jax.random.split(perm_key)
        idx = jax.random.randint(sub, (BATCH,), 0, TRAIN_N)
        params, m, v, loss = adam_step(params, m, v, step, train_x[idx], train_y[idx])
        if step % 20 == 0 or step == 1:
            losses.append((step, float(loss)))
            if verbose:
                print(f"step {step:4d}  loss {float(loss):.4f}")

    train_acc = accuracy(params, train_x, train_y)
    test_acc = accuracy(params, test_x, test_y)
    if verbose:
        print(f"train acc {train_acc:.4f}  test acc {test_acc:.4f}")
    log = {
        "steps": STEPS,
        "batch": BATCH,
        "lr": LR,
        "loss_curve": losses,
        "train_acc": train_acc,
        "test_acc": test_acc,
    }
    return params, test_x, test_y, log


if __name__ == "__main__":
    _, _, _, log = train()
    print(json.dumps(log["loss_curve"]))
