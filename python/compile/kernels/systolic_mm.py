"""L1 Pallas kernel: the systolic-array matrix multiply (paper Fig. 3b).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's systolic
mode loads an (H_A × W_SA) tile of weights and streams activations through
it. On TPU the analogous structure is an MXU-targeted tile matmul: the
BlockSpec pins a (bk-wide) weight stripe in VMEM per (i, j) grid step — the
"stationary" operand — while activation tiles stream past. f32 accumulation
mirrors the PE's FP32 adders behind the BFloat16 multipliers.

Runs under interpret=True: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, w_ref, o_ref):
    # One (bm, bn) output tile: full-K stripes of x and w are resident
    # (the weight stripe is the 'stationary' operand of the systolic array).
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim, target):
    """Largest divisor of `dim` that is <= target (keeps the grid exact)."""
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x, w, bm=128, bn=128):
    """x: (M, K) @ w: (K, N) -> (M, N) f32, tiled Pallas matmul."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def vmem_bytes(m, k, n, bm=128, bn=128, itemsize=4):
    """Estimated VMEM working set per grid step (perf model, DESIGN.md §Perf):
    x stripe (bm, K) + w stripe (K, bn) + out tile (bm, bn)."""
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    return itemsize * (bm * k + k * bn + bm * bn)
