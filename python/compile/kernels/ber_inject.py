"""L1 Pallas kernel: bit-flip fault injection (the STT-MRAM BER model).

Flips selected raw bits of an f32 buffer by XOR-ing a uint32 mask lane-wise
— the same fault mechanism the Rust coordinator applies to the bf16 weight
image, expressed as a kernel so the fault model can also be studied at the
L1/L2 level (kernel-ablation benches). Bitcast-XOR-bitcast is exactly what
an in-buffer retention/read-disturb upset does to a stored word.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flip_kernel(x_ref, m_ref, o_ref):
    bits = jax.lax.bitcast_convert_type(x_ref[...], jnp.uint32)
    o_ref[...] = jax.lax.bitcast_convert_type(bits ^ m_ref[...], jnp.float32)


@jax.jit
def bitflip(x, mask):
    """x: f32 (n,), mask: uint32 (n,) -> f32 (n,) with bits XOR'd."""
    assert x.ndim == 1 and x.shape == mask.shape
    n = x.shape[0]
    return pl.pallas_call(
        _flip_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), mask.astype(jnp.uint32))
