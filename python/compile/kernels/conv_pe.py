"""L1 Pallas kernel: the reconfigurable core's convolution mode (Fig. 3c).

The paper's conv PE performs P_s-wide dot products between stationary kernel
rows and streaming ifmap rows (row-stationary), accumulating partial sums
per input channel. The TPU rethink: one grid step owns one image's full conv
(batch is the grid dimension — the HBM→VMEM schedule the paper expressed
with PE-block scheduling); inside the kernel the 3x3 window is unrolled into
nine shifted (Cout × Cin) dot products — each an einsum over the channel
axis, the same "dot-product block + partial-sum accumulation" structure as
the PE array, with f32 accumulators standing in for the FP32 adders.

interpret=True for CPU-PJRT executability (see systolic_mm.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, b_ref, o_ref):
    # x: (1, Cin, H+2, W+2) padded slice for this image
    # w: (Cout, Cin, 3, 3), b: (Cout,), o: (1, Cout, H, W)
    x = x_ref[...][0].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    h = o_ref.shape[2]
    wd = o_ref.shape[3]
    acc = jnp.zeros(o_ref.shape[1:], jnp.float32)
    # Unrolled 3x3: nine P_s-wide dot-product passes with psum accumulation.
    for i in range(3):
        for j in range(3):
            patch = x[:, i : i + h, j : j + wd]  # (Cin, H, W)
            acc = acc + jnp.einsum(
                "oc,chw->ohw", w[:, :, i, j], patch,
                preferred_element_type=jnp.float32,
            )
    o_ref[...] = (acc + b[:, None, None])[None]


@jax.jit
def conv3x3_same(x, w, b):
    """3x3 'same' conv, NCHW/OIHW, stride 1, f32 accumulation.

    x: (N, Cin, H, W), w: (Cout, Cin, 3, 3), b: (Cout,).
    """
    n, cin, h, wd = x.shape
    cout = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    return pl.pallas_call(
        _conv_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, cin, h + 2, wd + 2), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((cout, cin, 3, 3), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, cout, h, wd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, cout, h, wd), jnp.float32),
        interpret=True,
    )(xp, w, b)


def vmem_bytes(cin, cout, h, w, itemsize=4):
    """Per-grid-step VMEM estimate: padded ifmap + weights + ofmap."""
    return itemsize * (cin * (h + 2) * (w + 2) + cout * cin * 9 + cout * h * w)
