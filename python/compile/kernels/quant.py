"""L1: the int8 inference path (the paper's inference-only hardware).

The paper's core is BF16 for training but "if only inference is desired,
the hardware can be 8-bit int8 type". This module provides symmetric
per-tensor int8 quantization and a Pallas int8 matmul with i32
accumulation — the systolic mode of the int8 build.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def quantize(x, scale=None):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    x = x.astype(jnp.float32)
    if scale is None:
        amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
        scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def matmul_i8_ref(xq, wq):
    """Reference int8 matmul: i32 accumulation."""
    return jnp.matmul(xq.astype(jnp.int32), wq.astype(jnp.int32))


def _mm_i8_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def _pick_block(dim, target):
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul_i8(xq, wq, bm=128, bn=128):
    """int8 × int8 → int32 tiled Pallas matmul (interpret=True)."""
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    return pl.pallas_call(
        _mm_i8_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(xq, wq)


def linear_quantized(x, w, x_scale=None):
    """f32 linear layer through the int8 path: quantize, i8 matmul,
    dequantize with the product of scales."""
    xq, sx = quantize(x, x_scale)
    wq, sw = quantize(w)
    acc = matmul_i8(xq, wq)
    return acc.astype(jnp.float32) * (sx * sw)
