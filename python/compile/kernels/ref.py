"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has its semantics defined here first; pytest
(`python/tests/`) asserts `assert_allclose(kernel(...), ref(...))` across a
hypothesis-driven sweep of shapes and dtypes. These functions are also what
`train.py` uses on its fast path (interpret-mode Pallas is far too slow to
train with).
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    """Reference for systolic_mm: plain f32-accumulated matmul."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def conv3x3_same_ref(x, w, b):
    """Reference for conv_pe: 3x3 'same' convolution, NCHW / OIHW.

    x: (N, Cin, H, W), w: (Cout, Cin, 3, 3), b: (Cout,)
    returns (N, Cout, H, W), f32 accumulation.
    """
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b.astype(jnp.float32)[None, :, None, None]


def bitflip_ref(x, mask):
    """Reference for ber_inject: xor the raw bits of f32 lanes with mask."""
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    return jax.lax.bitcast_convert_type(bits ^ mask, jnp.float32)


def maxpool2_ref(x):
    """2x2 max pooling, NCHW, H and W even."""
    n, c, h, w = x.shape
    return x.reshape(n, c, h // 2, 2, w // 2, 2).max(axis=(3, 5))
