"""AOT compile path: train TinyCNN, lower the Pallas forward to HLO text,
dump weights/test-set binaries and the manifest the Rust runtime consumes.

HLO *text* is the interchange format (NOT jax's serialized proto): the
image's xla_extension 0.5.1 rejects jax>=0.5 64-bit-id protos; the text
parser reassigns ids. See /opt/xla-example/README.md and gen_hlo.py.
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, train

BATCHES = (1, 16)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forward(batch):
    """Lower forward_pallas_tuple for one batch size to HLO text."""
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in model.PARAM_SPECS]
    x = jax.ShapeDtypeStruct((batch, *model.IMAGE_SHAPE), jnp.float32)
    lowered = jax.jit(model.forward_pallas_tuple).lower(*specs, x)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batches", type=int, nargs="*", default=list(BATCHES))
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    print("== training TinyCNN (build-time, ref path) ==")
    params, test_x, test_y, log = train.train()

    # Flat weights blob + per-param offsets.
    offsets, flat, cursor = [], [], 0
    for (name, shape), p in zip(model.PARAM_SPECS, params):
        arr = np.asarray(p, dtype=np.float32)
        assert arr.shape == shape, (name, arr.shape, shape)
        offsets.append({"name": name, "shape": list(shape), "offset": cursor})
        flat.append(arr.reshape(-1))
        cursor += arr.size
    weights = np.concatenate(flat)
    weights.tofile(out / "tinycnn_weights.bin")

    np.asarray(test_x, np.float32).tofile(out / "test_images.bin")
    np.asarray(test_y, np.float32).tofile(out / "test_labels.bin")

    models = {}
    for batch in args.batches:
        print(f"== lowering forward_pallas (batch {batch}) ==")
        hlo = lower_forward(batch)
        name = f"tinycnn_b{batch}"
        hlo_file = f"{name}.hlo.txt"
        (out / hlo_file).write_text(hlo)
        print(f"   wrote {hlo_file}: {len(hlo)} chars")
        models[name] = {
            "hlo": hlo_file,
            "batch": batch,
            "input_shape": list(model.IMAGE_SHAPE),
            "num_classes": model.NUM_CLASSES,
            "params": offsets,
        }

    manifest = {
        "models": models,
        "weights": "tinycnn_weights.bin",
        "testset": {
            "images": "test_images.bin",
            "labels": "test_labels.bin",
            "n": int(test_x.shape[0]),
            "image_shape": list(model.IMAGE_SHAPE),
        },
        "train_meta": log,
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"== manifest written to {out / 'manifest.json'} ==")


if __name__ == "__main__":
    main()
