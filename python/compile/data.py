"""Synthetic structured 10-class dataset (the ImageNet substitution).

Each class k is a deterministic 16x16 spatial pattern — oriented gratings
(4 orientations x 2 frequencies) plus two radial patterns — overlaid with
Gaussian noise. Classes are separable but not trivially so at the chosen
noise level (a linear model plateaus well below the CNN; the gap is what
makes the Fig. 21 accuracy-vs-BER comparison meaningful).
"""

import jax
import jax.numpy as jnp

from .model import IMAGE_SHAPE

NOISE = 2.2


def _class_pattern(k):
    h, w = IMAGE_SHAPE[1], IMAGE_SHAPE[2]
    yy, xx = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    yy = yy.astype(jnp.float32)
    xx = xx.astype(jnp.float32)
    if k < 8:
        angle = (k % 4) * jnp.pi / 4.0
        freq = 2.0 * jnp.pi / (4.0 if k < 4 else 8.0)
        phase = xx * jnp.cos(angle) + yy * jnp.sin(angle)
        return jnp.sin(freq * phase)
    cy, cx = (h - 1) / 2.0, (w - 1) / 2.0
    r = jnp.sqrt((yy - cy) ** 2 + (xx - cx) ** 2)
    if k == 8:
        return jnp.sin(2.0 * jnp.pi * r / 5.0)
    return jnp.cos(2.0 * jnp.pi * r / 3.0)


def make_dataset(key, n):
    """Returns (images (n, 1, 16, 16) f32, labels (n,) i32)."""
    k_lab, k_noise = jax.random.split(key)
    labels = jax.random.randint(k_lab, (n,), 0, 10)
    patterns = jnp.stack([_class_pattern(k) for k in range(10)])  # (10,16,16)
    clean = patterns[labels][:, None, :, :]
    noise = NOISE * jax.random.normal(k_noise, clean.shape, jnp.float32)
    return clean + noise, labels
