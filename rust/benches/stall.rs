//! Write-bandwidth stall model hot path (EXPERIMENTS.md §Latency-model):
//! the per-candidate stalled-latency walk (the selection grid's inner
//! loop) and the full `--fig stall` comparison sweep on the runner pool.
//!
//! Flags (mixed with harness flags, all optional): `--smoke` reduced
//! budget for CI, `--parallel N` worker count, `--bench-json PATH`
//! machine-readable trajectory output.

use stt_ai::accel::{ArrayConfig, RetentionAnalysis};
use stt_ai::dse::{cache, engine};
use stt_ai::memsys::{GlbBandwidth, GlbKind, Scratchpad};
use stt_ai::models::{self, DType};
use stt_ai::util::bench::{self, Bencher, Ledger};
use stt_ai::util::units::MB;

fn main() {
    let smoke = bench::smoke_from_args();
    let b = if smoke {
        Bencher { sample_target_s: 0.02, samples: 3 }
    } else {
        Bencher::new()
    };
    let mut ledger = Ledger::new();

    let zoo = engine::shared_zoo();
    let m = models::by_name("ResNet50").unwrap();
    let a = ArrayConfig::paper_42x42();
    let traffic = cache::traffic(&m, &a, DType::Bf16, 16, 12 * MB);
    let bw = GlbBandwidth::of(&GlbKind::stt_ai_ultra(), 1.0e-8, 1.0e-5);
    let sp = Scratchpad::paper_bf16();
    let ra = RetentionAnalysis::new(&a, 16);

    // Per-candidate stalled walk over the memoized traffic (what every
    // selection-grid candidate pays on top of the cached walks).
    let label = "stall/stalled_walk_resnet50_b16";
    let r = b.run(label, || ra.inference_latency_stalled(&m, &traffic, &bw, Some(&sp)));
    ledger.add_throughput(label, &r, traffic.layers.len() as f64, "layers");

    // The full `--fig stall` comparison sweep (12 points), warm cache.
    let runner = engine::Runner::from_args();
    let spec = engine::spec_stall(&zoo);
    let label = format!("stall/spec_stall_x{}", runner.workers());
    let points = spec.len() as f64;
    let r = b.run(&label, || runner.run(spec.clone()));
    ledger.add_throughput(&label, &r, points, "points");

    // Shape sanity inside the bench binary: the comparison must surface a
    // real stall somewhere (the 84×84 MRAM corner) and none for SRAM.
    let rows = runner.run(spec);
    let worst = rows.iter().map(|x| x.metric("stall_s")).fold(0.0_f64, f64::max);
    println!("    -> max stall across the comparison grid: {:.3} ms", worst * 1e3);
    assert!(worst > 0.0, "the stall comparison must surface a nonzero stall");

    bench::finish(&ledger);
}
