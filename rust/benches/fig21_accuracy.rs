//! Bench: the Fig. 21 fault-injection accuracy grid on the AOT artifacts
//! (skips politely without `make artifacts`), plus injection/inference
//! throughput.
use std::path::Path;

use stt_ai::config::GlbVariant;
use stt_ai::coordinator::{accuracy, Engine, EngineConfig};
use stt_ai::util::bench::Bencher;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP fig21: run `make artifacts` first");
        return;
    }
    for prune in [0.0, 0.5] {
        let row = accuracy::fig21_row(dir, prune, 16, Some(256)).unwrap();
        println!("== Fig. 21 (prune {prune}) ==");
        for r in [&row.baseline, &row.stt_ai, &row.stt_ai_ultra] {
            println!(
                "  {:<14} top1 {:.4} top5 {:.4} flips {}",
                r.variant, r.top1, r.top5, r.bit_flips
            );
        }
    }
    let engine = Engine::load(dir, EngineConfig::new(GlbVariant::SttAiUltra)).unwrap();
    let model = engine.model_for_batch(16).unwrap();
    let (images, _) = engine.manifest.load_testset().unwrap();
    let chunk = &images[..16 * 256];
    let b = Bencher { sample_target_s: 0.2, samples: 8 };
    b.run("fig21/pjrt_infer_batch16", || engine.infer(&model, chunk).unwrap().len());
    let mut e2 = Engine::load(dir, EngineConfig::new(GlbVariant::SttAiUltra)).unwrap();
    b.run("fig21/rebuild_served_weights", || {
        e2.rebuild_served();
        e2.flips
    });
}
