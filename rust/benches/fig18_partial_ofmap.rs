//! Bench: regenerate Fig. 18 (max partial-ofmap sizes).
use stt_ai::dse::engine::Runner;
use stt_ai::dse::scratchpad::PartialOfmapRow;
use stt_ai::models;
use stt_ai::report;
use stt_ai::util::bench::Bencher;

fn main() {
    report::fig18_with(&mut std::io::stdout().lock(), &Runner::from_args()).unwrap();
    let zoo = models::zoo();
    Bencher::new().run("fig18/partials_19_models", || {
        zoo.iter().map(|m| PartialOfmapRow::analyze(m).bf16_bytes).max().unwrap()
    });
}
