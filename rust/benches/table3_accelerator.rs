//! Bench: Table III — accelerator composition and the headline savings,
//! plus an ablation over GLB capacity (the "larger buffers favor MRAM more"
//! trend behind the paper's future-accelerator claim).
use stt_ai::config::{GlbVariant, SystemConfig};
use stt_ai::memsys::BufferSystem;
use stt_ai::report::{self, AcceleratorSummary, CoreCosts};
use stt_ai::util::bench::Bencher;
use stt_ai::util::units::MB;

fn main() {
    let rows = report::table3_rows();
    println!("== Table III ==");
    let base = rows[0].clone();
    for r in &rows {
        let (a, p) = r.savings_vs(&base);
        println!(
            "  {:<18} {:>7.2} mm² {:>9.2} mW  ({:.1}% area, {:.1}% power saving)",
            r.name,
            r.area_mm2,
            r.total_power_mw(),
            a * 100.0,
            p * 100.0
        );
    }

    println!("== ablation: GLB capacity scaling ==");
    let core = CoreCosts::paper_42x42();
    for mb in [4u64, 8, 12, 24, 48] {
        let sram = AcceleratorSummary::compose(
            "sram",
            core,
            &BufferSystem::new(stt_ai::memsys::GlbKind::baseline(), mb * MB, None),
        );
        let mram = AcceleratorSummary::compose(
            "mram",
            core,
            &BufferSystem::new(stt_ai::memsys::GlbKind::stt_ai(), mb * MB, None),
        );
        let (a, p) = mram.savings_vs(&sram);
        println!("  {mb:>3} MB GLB: {:.1}% area, {:.1}% power saving", a * 100.0, p * 100.0);
    }

    let b = Bencher::new();
    b.run("table3/compose_three_accelerators", || report::table3_rows().len());
    b.run("table3/buffer_system_from_config", || {
        SystemConfig::paper_stt_ai_ultra().buffer_system().area_mm2()
    });
    let _ = GlbVariant::SttAiUltra;
}
