//! Bench: regenerate Fig. 19 (buffer energy SRAM / MRAM / MRAM+scratchpad)
//! plus an ablation over scratchpad capacity (DESIGN.md ablation list).
use stt_ai::accel::{ArrayConfig, ModelTraffic};
use stt_ai::dse::engine::Runner;
use stt_ai::dse::scratchpad::ScratchpadEnergyRow;
use stt_ai::memsys::{BufferSystem, EnergyLedger, GlbKind, Scratchpad};
use stt_ai::models::{self, DType};
use stt_ai::report;
use stt_ai::util::bench::Bencher;
use stt_ai::util::units::{KB, MB};

fn main() {
    report::fig19_with(&mut std::io::stdout().lock(), &Runner::from_args()).unwrap();

    // Ablation: scratchpad capacity 0..104 KB for ResNet-50.
    let a = ArrayConfig::paper_42x42();
    let m = models::by_name("ResNet50").unwrap();
    let traffic = ModelTraffic::analyze(&m, &a, DType::Bf16, 16, 12 * MB);
    println!("== ablation: scratchpad capacity (ResNet-50, batch 16) ==");
    for kb in [0u64, 13, 26, 52, 104] {
        let sys = BufferSystem::new(
            GlbKind::stt_ai(),
            12 * MB,
            (kb > 0).then(|| Scratchpad::new(kb * KB)),
        );
        let mut total = EnergyLedger::default();
        for l in &traffic.layers {
            total.add(&sys.layer_energy(
                l.glb_reads,
                l.glb_writes,
                l.partial_bytes,
                l.partial_rounds,
                l.dram_bytes,
            ));
        }
        println!("  {kb:>4} KB scratchpad: {:.3} mJ", total.total() * 1e3);
    }

    Bencher::new().run("fig19/three_way_comparison", || {
        ScratchpadEnergyRow::analyze(&m, &a, DType::Bf16, 16).mram_scratchpad.total()
    });
}
