//! Bench: regenerate Fig. 15 (Δ scaling panels, both base cases).
use stt_ai::dse::delta::{paper_design_points, DeltaSweep};
use stt_ai::dse::engine::Runner;
use stt_ai::mram::MtjTech;
use stt_ai::report;
use stt_ai::util::bench::Bencher;

fn main() {
    report::fig15_with(&mut std::io::stdout().lock(), &Runner::from_args()).unwrap();
    let deltas = DeltaSweep::default_deltas();
    let b = Bencher::new();
    b.run("fig15/sweep_51_deltas_x2_tech", || {
        DeltaSweep::run(MtjTech::sakhare2020(), 1e-8, &deltas).retention.len()
            + DeltaSweep::run(MtjTech::wei2019(), 1e-8, &deltas).retention.len()
    });
    b.run("fig15/solve_3_design_points", || paper_design_points(MtjTech::sakhare2020()).len());
}
