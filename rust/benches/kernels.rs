//! Scalar-vs-kernel datapoints for the `dse::kernels` columnar hot path
//! (EXPERIMENTS.md §Perf, vectorized-kernel subsection): fused constraint
//! bitmasks, the tiled Pareto dominance scan (serial and pool-fanned), and
//! the masked argmin — each measured against the exact pre-kernel scalar
//! loop (`dse::kernels::scalar` / `Constraint::satisfied_at`) on the
//! 2592-candidate dense selection grid, plus the end-to-end `select()`
//! pass. Every scalar/kernel pair is asserted bit-identical before it is
//! timed, so a reported speedup can never come from computing something
//! else. `--smoke` shrinks sample counts only — the workload (and thus the
//! entry names) stay identical, which makes these entries smoke-stable for
//! the `--baseline` gate. `--bench-json PATH` writes BENCH_kernels.json.
use stt_ai::dse::engine::{Runner, SweepColumns};
use stt_ai::dse::kernels::{self, Bitmask};
use stt_ai::dse::{cache, engine, select, Constraint, Objective, SelectionGrid};
use stt_ai::util::bench::{self, Bencher, Ledger};
use stt_ai::util::pool::ThreadPool;
use stt_ai::util::rng::Rng;

fn main() {
    let smoke = bench::smoke_from_args();
    let b = if smoke {
        Bencher { sample_target_s: 0.005, samples: 3 }
    } else {
        Bencher::new()
    };
    let mut ledger = Ledger::new();

    // The dense stress grid, evaluated once (warm caches) into the columnar
    // view every kernel below scans.
    let zoo = engine::shared_zoo();
    let spec = select::spec_selection_grid(&zoo, SelectionGrid::Dense);
    let n = spec.len();
    println!("-- dense selection grid: {n} candidates");
    let results = spec.run_serial();
    let cols = SweepColumns::from_results(&results);
    let constraints =
        vec![Constraint::MinAccuracy(0.99), Constraint::RetentionCoversOccupancy];
    let objectives = Objective::all();

    // Parity first: the kernels must reproduce the scalar masks bit-for-bit
    // before any timing is trusted.
    let scalar_feasible: Vec<bool> = (0..cols.len())
        .map(|row| constraints.iter().all(|c| c.satisfied_at(&cols, row)))
        .collect();
    assert_eq!(
        select::feasible_mask_columns(&cols, &constraints),
        scalar_feasible,
        "fused feasibility must match the scalar constraint fold"
    );
    let signed: Vec<Vec<f64>> = objectives
        .iter()
        .map(|o| {
            let key = cols
                .key_index(o.metric())
                .expect("the dense grid carries every objective metric");
            let col = cols.column(key);
            let lower = o.lower_is_better();
            (0..cols.len()).map(|r| if lower { col[r] } else { -col[r] }).collect()
        })
        .collect();
    let scalar_frontier = kernels::scalar::nondominated(&signed);
    let auto = Runner::from_args();
    for workers in [1, auto.workers()] {
        assert_eq!(
            select::pareto_mask_columns_with(&cols, &objectives, &ThreadPool::new(workers)),
            scalar_frontier,
            "tiled frontier must match the scalar scan at {workers} workers"
        );
    }

    // Fused constraint predicates vs the per-row satisfied_at fold.
    let label = format!("kernels/feasible_scalar_{n}");
    let r_scalar = b.run(&label, || {
        (0..cols.len())
            .map(|row| constraints.iter().all(|c| c.satisfied_at(&cols, row)))
            .collect::<Vec<bool>>()
    });
    ledger.add_throughput(&label, &r_scalar, n as f64, "candidates");
    let label = format!("kernels/feasible_fused_{n}");
    let r_fused = b.run(&label, || select::feasible_mask_columns(&cols, &constraints));
    ledger.add_throughput(&label, &r_fused, n as f64, "candidates");
    let feasible_speedup = r_scalar.median_ns / r_fused.median_ns;
    println!("    -> fused feasibility speedup: {feasible_speedup:.2}x");

    // Tiled Pareto dominance scan vs the closure-based O(n²) scalar scan,
    // over identical signed columns.
    let label = format!("kernels/pareto_scalar_{n}");
    let r_scalar = b.run(&label, || kernels::scalar::nondominated(&signed));
    ledger.add_throughput(&label, &r_scalar, n as f64, "candidates");
    let serial_pool = ThreadPool::new(1);
    let label = format!("kernels/pareto_tiled_{n}");
    let r_tiled = b.run(&label, || kernels::pareto_nondominated(&signed, &serial_pool));
    ledger.add_throughput(&label, &r_tiled, n as f64, "candidates");
    let pareto_speedup = r_scalar.median_ns / r_tiled.median_ns;
    println!("    -> tiled pareto speedup (serial): {pareto_speedup:.2}x");
    let pool = ThreadPool::new(auto.workers());
    let label = format!("kernels/pareto_tiled_{n}_x{}", pool.workers());
    let r_pool = b.run(&label, || kernels::pareto_nondominated(&signed, &pool));
    ledger.add_throughput(&label, &r_pool, n as f64, "candidates");
    println!(
        "    -> tiled pareto speedup ({} workers): {:.2}x",
        pool.workers(),
        r_scalar.median_ns / r_pool.median_ns
    );

    // Masked argmin under total_cmp order: two-pass integer-key kernel vs
    // the strictly-less scalar scan, on a 1M-lane normal column.
    let argmin_n = 1 << 20;
    let mut column = Vec::new();
    Rng::seed_from_u64(0xC01).fill_normal_into(&mut column, argmin_n);
    let live_bools = vec![true; argmin_n];
    let live = Bitmask::ones(argmin_n);
    for negate in [false, true] {
        assert_eq!(
            kernels::argmin_masked(&column, &live, negate),
            kernels::scalar::argmin_masked(&column, &live_bools, negate),
            "argmin kernel must match the scalar scan (negate={negate})"
        );
    }
    let label = "kernels/argmin_scalar_1m";
    let r_scalar = b.run(label, || kernels::scalar::argmin_masked(&column, &live_bools, false));
    ledger.add_throughput(label, &r_scalar, argmin_n as f64, "lanes");
    let label = "kernels/argmin_kernel_1m";
    let r_kernel = b.run(label, || kernels::argmin_masked(&column, &live, false));
    ledger.add_throughput(label, &r_kernel, argmin_n as f64, "lanes");
    println!("    -> argmin speedup: {:.2}x", r_scalar.median_ns / r_kernel.median_ns);

    // End-to-end columnar selection pass (constraints → frontier → winner)
    // over the dense grid — the user-visible cost `--grid dense` pays.
    let label = format!("kernels/select_dense_{n}");
    let r = b.run(&label, || {
        select::select("selection", &results, Objective::MinArea, &constraints).unwrap()
    });
    ledger.add_throughput(&label, &r, n as f64, "candidates");

    println!("-- dse::cache tiers (whole run)");
    for e in cache::tier_stats() {
        println!("    L{} {:<18} {:>9} hits {:>9} misses", e.tier, e.name, e.hits, e.misses);
    }

    // The acceptance floor for the PR 7 kernels: ≥ 2× over the scalar scans
    // on the dense grid. Asserted in full mode only — smoke's 3-sample
    // medians are too noisy to gate a ratio on.
    if !smoke {
        assert!(
            pareto_speedup >= 2.0,
            "tiled pareto scan is only {pareto_speedup:.2}x over scalar (need >= 2x)"
        );
        assert!(
            feasible_speedup >= 2.0,
            "fused feasibility is only {feasible_speedup:.2}x over scalar (need >= 2x)"
        );
    }

    bench::finish(&ledger);
}
