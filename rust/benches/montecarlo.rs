//! Monte-Carlo engine throughput (EXPERIMENTS.md §Perf): serial baseline vs
//! the pool-parallel chunked map-reduce, steady-state allocation behavior,
//! and a bit-identical determinism cross-check.
//!
//! Flags (mixed with harness flags, all optional):
//! `--smoke` reduced n for CI, `--parallel N` worker count,
//! `--bench-json PATH` machine-readable trajectory output.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use stt_ai::dse::engine::Runner;
use stt_ai::mram::montecarlo::{BLOCK_SAMPLES, DEFAULT_CHUNK_SAMPLES};
use stt_ai::mram::MonteCarlo;
use stt_ai::util::bench::{self, Bencher, Ledger};
use stt_ai::util::pool::ThreadPool;

/// Counting allocator: every heap allocation anywhere in the process bumps
/// one counter, which is how the "zero per-sample allocation" claim is
/// measured rather than asserted.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn main() {
    let smoke = bench::smoke_from_args();
    let n: usize = if smoke { 64 * BLOCK_SAMPLES / 4 } else { 1_000_000 };
    let b = if smoke {
        Bencher { sample_target_s: 0.02, samples: 3 }
    } else {
        Bencher::new()
    };
    let mc = MonteCarlo::paper_glb();
    let mut ledger = Ledger::new();

    // Serial baseline: same streaming engine, one worker.
    let serial = ThreadPool::new(1);
    let label = format!("montecarlo/run_{}k_serial", n / 1000);
    let r1 = b.run(&label, || mc.run_with(0xD1E5, n, &serial, DEFAULT_CHUNK_SAMPLES));
    ledger.add_throughput(&label, &r1, n as f64, "samples");
    println!("    -> {:.2} Msamples/s", n as f64 * 1e3 / r1.median_ns);

    // Pool-parallel: all hardware threads (or `--parallel N`).
    let workers = Runner::from_args().workers();
    let pool = ThreadPool::new(workers);
    let label = format!("montecarlo/run_{}k_parallel_x{}", n / 1000, workers);
    let rn = b.run(&label, || mc.run_with(0xD1E5, n, &pool, DEFAULT_CHUNK_SAMPLES));
    ledger.add_throughput(&label, &rn, n as f64, "samples");
    println!(
        "    -> {:.2} Msamples/s: {:.2}x vs serial with {} workers (acceptance: >=5x at >=4)",
        n as f64 * 1e3 / rn.median_ns,
        r1.median_ns / rn.median_ns,
        workers
    );

    // Determinism cross-check: worker count AND chunk size must not change
    // a single bit of the result.
    let a = mc.run_with(7, n, &serial, DEFAULT_CHUNK_SAMPLES);
    let c = mc.run_with(7, n, &pool, 2 * BLOCK_SAMPLES);
    assert_eq!(a, c, "parallel/chunked MC must be bit-identical to serial");

    // Steady-state allocations (engine already warm from the timed runs):
    // the budget is O(chunks + blocks) per run, ~0 per sample.
    let before = ALLOCS.load(Ordering::Relaxed);
    std::hint::black_box(mc.run_with(0xA110C, n, &pool, DEFAULT_CHUNK_SAMPLES));
    let during = ALLOCS.load(Ordering::Relaxed) - before;
    println!(
        "    -> {} allocations / {} samples = {:.5} per sample (target ~0)",
        during,
        n,
        during as f64 / n as f64
    );

    bench::finish(&ledger);
}
