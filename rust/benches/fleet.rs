//! Fleet-simulator hot paths (EXPERIMENTS.md §Fleet simulation): the full
//! open-loop Poisson run on the virtual clock (events/s at 1e6 requests),
//! the bursty heterogeneous fleet, steady-state allocation behavior, and a
//! byte-identity determinism cross-check.
//!
//! Flags (mixed with harness flags, all optional): `--smoke` reduced
//! budget for CI, `--bench-json PATH` machine-readable trajectory output.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use stt_ai::config::GlbVariant;
use stt_ai::coordinator::{
    ArrivalTrace, EngineSpec, FleetConfig, FleetSim, FleetSimReport, TenantMix,
};
use stt_ai::util::bench::{self, Bencher, Ledger};
use stt_ai::util::clock::Clock;

/// Counting allocator: every heap allocation anywhere in the process bumps
/// one counter, which is how the per-event allocation budget is measured
/// rather than asserted.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn run(trace: &str, specs: Vec<EngineSpec>, requests: usize, parallel: usize) -> FleetSimReport {
    let trace = ArrivalTrace::builtin(trace).expect("builtin trace");
    let cfg = FleetConfig { requests, parallel, ..Default::default() };
    let mut sim = FleetSim::new(trace, specs, cfg).expect("fleet");
    sim.run(&Clock::virtual_at_zero()).expect("fleet run")
}

fn main() {
    let smoke = bench::smoke_from_args();
    let n: usize = if smoke { 20_000 } else { 1_000_000 };
    let b = if smoke {
        Bencher { sample_target_s: 0.02, samples: 3 }
    } else {
        Bencher::new()
    };
    let mut ledger = Ledger::new();

    // The headline run: open-loop Poisson arrivals through three Ultra
    // engines — every sample replays the full event schedule from epoch.
    let label = format!("fleet/poisson_{}k_3xultra", n / 1000);
    let r = b.run(&label, || run("poisson", EngineSpec::paper_fleet(3), n, 1));
    ledger.add_throughput(&label, &r, n as f64, "requests");
    let rep = run("poisson", EngineSpec::paper_fleet(3), n, 1);
    println!(
        "    -> {:.2} Mevents/s ({} events for {} requests)",
        rep.events as f64 * 1e3 / r.median_ns,
        rep.events,
        n
    );

    // The hetero storm: SRAM island + two Ultras under the bursty MMPP.
    let hetero = || {
        vec![
            EngineSpec::paper(GlbVariant::Sram),
            EngineSpec::paper(GlbVariant::SttAiUltra),
            EngineSpec::paper(GlbVariant::SttAiUltra),
        ]
    };
    let hn = if smoke { 10_000 } else { 200_000 };
    let label = format!("fleet/bursty_{}k_hetero", hn / 1000);
    let r = b.run(&label, || run("bursty", hetero(), hn, 1));
    ledger.add_throughput(&label, &r, hn as f64, "requests");

    // Determinism cross-check inside the bench binary: the worker knob
    // must not change a byte of the report.
    let a = run("bursty", hetero(), hn, 1);
    let c = run("bursty", hetero(), hn, 4);
    assert_eq!(a.render(), c.render(), "--parallel leaked into the report");

    // The two-tenant mix on the SRAM+Ultra pair: class-aware scheduling
    // (per-class DRR queues, island routing, per-tenant ledgers) against
    // the single-queue ablation on the same offered load — the event-rate
    // cost of tenancy is the delta between these two datapoints.
    let pair = || {
        vec![EngineSpec::paper(GlbVariant::Sram), EngineSpec::paper(GlbVariant::SttAiUltra)]
    };
    let run_mix = |classless: bool| {
        let trace = ArrivalTrace::builtin("poisson").expect("builtin trace");
        let cfg = FleetConfig {
            requests: hn,
            tenants: TenantMix::builtin("two_tier").expect("builtin mix"),
            classless,
            ..Default::default()
        };
        let mut sim = FleetSim::new(trace, pair(), cfg).expect("fleet");
        sim.run(&Clock::virtual_at_zero()).expect("fleet run")
    };
    let label = format!("fleet/two_tier_{}k_hetero", hn / 1000);
    let r = b.run(&label, || run_mix(false));
    ledger.add_throughput(&label, &r, hn as f64, "requests");
    let label = format!("fleet/two_tier_{}k_single_queue", hn / 1000);
    let r = b.run(&label, || run_mix(true));
    ledger.add_throughput(&label, &r, hn as f64, "requests");
    // The payoff gate, asserted where the full-size runs already exist:
    // tight-class p99 beats the single-queue baseline at <= 105% energy.
    let aware = run_mix(false);
    let baseline = run_mix(true);
    assert!(
        aware.tenants[0].p99_us < baseline.tenants[0].p99_us,
        "tight p99 {}us >= single-queue {}us",
        aware.tenants[0].p99_us,
        baseline.tenants[0].p99_us
    );
    assert!(
        aware.mean_uj <= baseline.mean_uj * 1.05,
        "tenant-aware energy {:.3}uJ/req vs baseline {:.3}uJ/req",
        aware.mean_uj,
        baseline.mean_uj
    );

    // Steady-state allocations: the budget is O(1) per event (queue rows,
    // batch assembly, wake scheduling) — not O(fleet) or O(history).
    let before = ALLOCS.load(Ordering::Relaxed);
    let rep = std::hint::black_box(run("poisson", EngineSpec::paper_fleet(3), n, 1));
    let during = ALLOCS.load(Ordering::Relaxed) - before;
    let per_event = during as f64 / rep.events as f64;
    println!(
        "    -> {} allocations / {} events = {:.2} per event ({:.2} per request)",
        during,
        rep.events,
        per_event,
        during as f64 / n as f64
    );
    if !smoke {
        assert!(per_event < 64.0, "allocation budget blew up: {per_event:.1} per event");
    }

    bench::finish(&ledger);
}
