//! Bench: regenerate Fig. 12 (extra DRAM latency/energy, 12 MB GLB).
use stt_ai::accel::ArrayConfig;
use stt_ai::dse::capacity::DramOverheadRow;
use stt_ai::dse::engine::Runner;
use stt_ai::memsys::DramModel;
use stt_ai::models::{self, DType};
use stt_ai::report;
use stt_ai::util::bench::Bencher;
use stt_ai::util::units::MB;

fn main() {
    report::fig12_with(&mut std::io::stdout().lock(), &Runner::from_args()).unwrap();
    let zoo = models::zoo();
    let a = ArrayConfig::paper_42x42();
    let d = DramModel::ddr4_2933_dual();
    Bencher::new().run("fig12/full_grid_19x4x2", || {
        let mut acc = 0.0f64;
        for m in &zoo {
            for dt in [DType::Int8, DType::Bf16] {
                for batch in [1u64, 2, 4, 8] {
                    acc += DramOverheadRow::analyze(m, &a, &d, dt, batch, 12 * MB).extra_latency;
                }
            }
        }
        acc
    });
}
