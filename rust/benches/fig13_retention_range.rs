//! Bench: regenerate Fig. 13 (GLB retention ranges, 42x42, batch 16).
use stt_ai::dse::engine::Runner;
use stt_ai::dse::retention;
use stt_ai::models;
use stt_ai::report;
use stt_ai::util::bench::Bencher;

fn main() {
    report::fig13_with(&mut std::io::stdout().lock(), &Runner::from_args()).unwrap();
    let zoo = models::zoo();
    Bencher::new().run("fig13/retention_19_models", || {
        retention::fig13(&zoo).iter().map(|r| r.max_t_ret).fold(0.0, f64::max)
    });
}
