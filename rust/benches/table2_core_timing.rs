//! Bench: Table II — the reconfigurable core's two modes, functional model
//! throughput and the analytical per-layer timing.
use stt_ai::accel::{ArrayConfig, CoreMode, PeBlock, RetentionAnalysis};
use stt_ai::models;
use stt_ai::util::bench::Bencher;

fn main() {
    let a = ArrayConfig::paper_42x42();
    println!("== Table II: reconfigurable core (post-layout anchors) ==");
    println!("  systolic mode: {} cycles/step @ {:.1} GHz", a.cyc_per_step_systolic, a.clk_hz / 1e9);
    println!("  conv mode:     {} cycles/step @ {:.1} GHz", a.cyc_per_step_conv, a.clk_hz / 1e9);
    for mode in [CoreMode::Systolic, CoreMode::Convolution] {
        println!("  peak {mode:?}: {:.2} GMAC/s", a.peak_macs_per_s(mode) / 1e9);
    }

    let b = Bencher::new();
    b.run("table2/pe_conv_step", || {
        let mut pe = PeBlock::default();
        pe.conv_step([1.0, 2.0, 3.0], [4.0, 5.0, 6.0], 7.0)
    });
    b.run("table2/pe_systolic_step", || {
        let mut pe = PeBlock::default();
        pe.systolic_step([1.0, 2.0, 3.0], [4.0, 5.0, 6.0], [7.0, 8.0, 9.0])
    });
    let m = models::by_name("ResNet50").unwrap();
    b.run("table2/resnet50_layer_timings", || {
        RetentionAnalysis::new(&a, 16).layer_timings(&m).len()
    });
}
