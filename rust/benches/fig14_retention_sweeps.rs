//! Bench: regenerate Fig. 14 (retention vs array size / batch).
use stt_ai::dse::engine::Runner;
use stt_ai::dse::retention;
use stt_ai::models;
use stt_ai::report;
use stt_ai::util::bench::Bencher;

fn main() {
    report::fig14_with(&mut std::io::stdout().lock(), &Runner::from_args()).unwrap();
    let zoo = models::zoo();
    let b = Bencher::new();
    b.run("fig14a/array_sweep", || retention::fig14a(&zoo, &[14, 28, 42, 56, 84]).len());
    b.run("fig14b/batch_sweep", || retention::fig14b(&zoo, &[1, 2, 4, 8, 16, 32]).len());
}
