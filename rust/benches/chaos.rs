//! Fault-injection harness hot paths (EXPERIMENTS.md §Robustness): the
//! full burst_ber chaos run on the virtual clock, the per-tick effective
//! fault lookup the dispatcher pays, and the 64 KiB canary probe.
//!
//! Flags (mixed with harness flags, all optional): `--smoke` reduced
//! budget for CI, `--bench-json PATH` machine-readable trajectory output.

use stt_ai::ber::{BankSplit, Injector, WordKind};
use stt_ai::config::{BerConfig, GlbVariant, TechBase};
use stt_ai::coordinator::{ChaosConfig, EngineSpec, FaultSchedule, Supervisor, SupervisorPolicy};
use stt_ai::util::bench::{self, Bencher, Ledger};
use stt_ai::util::clock::{Clock, Tick};

fn main() {
    let smoke = bench::smoke_from_args();
    let b = if smoke {
        Bencher { sample_target_s: 0.02, samples: 3 }
    } else {
        Bencher::new()
    };
    let mut ledger = Ledger::new();

    // The golden scenario end-to-end: build the fleet, replay the storm,
    // assemble the report. Each sample is a fresh supervisor so the health
    // machine walks the full Degraded → Down → fallback arc every time.
    let requests = if smoke { 400 } else { 2000 };
    let label = format!("chaos/burst_ber_{requests}req");
    let run = || {
        let schedule = FaultSchedule::builtin("burst_ber").expect("builtin");
        let mut sup = Supervisor::new(
            schedule,
            EngineSpec::paper_fleet(3),
            Some(EngineSpec::paper(GlbVariant::Sram)),
            SupervisorPolicy::default(),
            1,
        )
        .expect("fleet");
        let cfg = ChaosConfig { requests, ..Default::default() };
        sup.run(&cfg, &Clock::virtual_at_zero()).expect("chaos run")
    };
    let r = b.run(&label, || run());
    ledger.add_throughput(&label, &r, requests as f64, "requests");

    // The fault layer's per-dispatch question: what does engine e see at
    // tick t? Folds every active event over the base BER budget.
    let schedule = FaultSchedule::builtin("burst_ber").expect("builtin");
    let base = BerConfig::for_variant(GlbVariant::SttAiUltra);
    let tech = TechBase::from_token("stt").expect("stt tech");
    let label = "faults/effective_lookup";
    let evals = 64 * 3;
    let r = b.run(label, || {
        let mut acc = 0.0_f64;
        for step in 0..64u64 {
            let now = Tick::from_nanos(step * 1_250_000); // 0..80 ms
            for engine in 0..3 {
                let eff = schedule.effective(engine, now, base, tech, 60.0, 30.0);
                acc += eff.msb_ber + eff.lsb_ber;
            }
        }
        acc
    });
    ledger.add_throughput(label, &r, evals as f64, "lookups");

    // One canary probe at the storm's escalated BER: seed-derived
    // injection into a zeroed 64 KiB buffer, split across the bank pair.
    let policy = SupervisorPolicy::default();
    let label = "faults/canary_probe_64k";
    let r = b.run(label, || {
        let mut buf = vec![0u8; policy.canary_probe_bytes.next_multiple_of(2)];
        let mut inj = Injector::new(0xFA17);
        let split = BankSplit {
            kind: WordKind::Bf16,
            msb_ber: base.msb_ber * 1.0e3,
            lsb_ber: base.lsb_ber * 1.0e3,
        };
        split.inject_split(&mut inj, &mut buf)
    });
    ledger.add_throughput(label, &r, policy.canary_probe_bytes as f64, "bytes");

    // Shape sanity inside the bench binary: the storm must degrade
    // gracefully, not collapse — and the fallback reboot must fire.
    let rep = run();
    println!(
        "    -> availability {:.3}%  retries {}  fallbacks {}",
        rep.availability, rep.retries, rep.fallbacks
    );
    assert!(rep.availability >= 99.0, "graceful degradation gate");
    assert!(rep.fallbacks >= 1, "the SRAM fallback reboot must fire");

    bench::finish(&ledger);
}
