//! Bench: regenerate Fig. 11 (required GLB capacity vs batch).
use stt_ai::dse::capacity;
use stt_ai::dse::engine::Runner;
use stt_ai::models::{self, DType};
use stt_ai::report;
use stt_ai::util::bench::Bencher;

fn main() {
    report::fig11_with(&mut std::io::stdout().lock(), &Runner::from_args()).unwrap();
    let zoo = models::zoo();
    let b = Bencher::new();
    b.run("fig11/capacity_sweep_4_batches", || {
        [1u64, 2, 4, 8]
            .iter()
            .map(|&n| capacity::glb_capacity_for_zoo(&zoo, DType::Bf16, n))
            .sum::<u64>()
    });
}
