//! Hot-path microbenches driving the §Perf iteration (EXPERIMENTS.md §Perf):
//! BER injection throughput, bf16 round-trip, retention analysis, JSON
//! parse, batcher ops, Monte-Carlo sampling, and the figure-regeneration
//! end-to-end cost (serial vs the parallel sweep engine; honors
//! `--parallel N`). `--smoke` runs reduced sizes for CI; `--bench-json PATH`
//! writes the machine-readable BENCH_hotpath.json trajectory.
use std::time::Duration;

use stt_ai::accel::{ArrayConfig, RetentionAnalysis};
use stt_ai::ber::{BankSplit, Injector, WordKind};
use stt_ai::coordinator::{Batcher, Request};
use stt_ai::dse::engine::Runner;
use stt_ai::dse::{cache, engine, select, DramOverheadRow, RetentionRow};
use stt_ai::memsys::DramModel;
use stt_ai::models::{self, DType};
use stt_ai::mram::montecarlo::DEFAULT_CHUNK_SAMPLES;
use stt_ai::mram::MonteCarlo;
use stt_ai::report;
use stt_ai::util::bench::{self, Bencher, Ledger};
use stt_ai::util::bf16::{bf16_to_f32, f32_to_bf16};
use stt_ai::util::json::Json;
use stt_ai::util::pool::ThreadPool;
use stt_ai::util::units::MB;

fn main() {
    let smoke = bench::smoke_from_args();
    let b = if smoke {
        Bencher { sample_target_s: 0.005, samples: 3 }
    } else {
        Bencher::new()
    };
    let mut ledger = Ledger::new();

    // BER injector: GLB-sized buffer at GLB-like BERs. Report GB/s.
    let buf_mb: usize = if smoke { 2 } else { 16 };
    let mut buf = vec![0u8; buf_mb << 20];
    for ber in [1e-8, 1e-5, 1e-3] {
        let label = format!("injector/flip_{buf_mb}MB@{ber:.0e}");
        let mut inj = Injector::new(42);
        let r = b.run(&label, || inj.flip(&mut buf, ber).bits_flipped);
        ledger.add_throughput(&label, &r, (buf_mb << 20) as f64, "bytes");
        println!("    -> {:.2} GB/s", ((buf_mb as u64) << 20) as f64 / r.median_ns);
    }
    let split = BankSplit::ultra(WordKind::Bf16);
    let mut inj = Injector::new(7);
    let label = format!("injector/bank_split_{buf_mb}MB_ultra");
    let r = b.run(&label, || split.inject(&mut inj, &mut buf).bits_flipped);
    ledger.add_throughput(&label, &r, (buf_mb << 20) as f64, "bytes");

    // bf16 round trip over a weight-image-sized vector.
    let weights: Vec<f32> = (0..70_000).map(|i| (i as f32) * 1e-4 - 3.5).collect();
    let r = b.run("bf16/roundtrip_70k_weights", || {
        weights.iter().map(|w| bf16_to_f32(f32_to_bf16(*w))).sum::<f32>()
    });
    ledger.add("bf16/roundtrip_70k_weights", &r);

    // Retention analysis of the full zoo (the fig13 inner loop).
    let zoo = models::zoo();
    let a = ArrayConfig::paper_42x42();
    let r = b.run("accel/zoo_retention_analysis", || {
        zoo.iter()
            .map(|m| RetentionAnalysis::new(&a, 16).analyze(m).max_t_ret())
            .fold(0.0, f64::max)
    });
    ledger.add("accel/zoo_retention_analysis", &r);

    // The fig11/fig12/fig14-style overlapping model walks, cold (cache
    // cleared every iteration) vs warm (memoized across sweeps) — the
    // ROADMAP perf item behind `dse::cache`.
    let a42 = ArrayConfig::paper_42x42();
    let dram = DramModel::ddr4_2933_dual();
    let walk = |zoo: &[stt_ai::models::Model]| {
        let mut acc = 0.0f64;
        for m in zoo {
            for batch in [1u64, 2, 4, 8] {
                let r = DramOverheadRow::analyze(m, &a42, &dram, DType::Bf16, batch, 12 * MB);
                acc += r.extra_energy;
                acc += RetentionRow::analyze(m, &a42, batch).max_t_ret;
            }
        }
        acc
    };
    let cold = b.run("dse/model_walks_cold", || {
        cache::clear();
        walk(&zoo)
    });
    let warm = b.run("dse/model_walks_warm", || walk(&zoo));
    ledger.add("dse/model_walks_cold", &cold);
    ledger.add("dse/model_walks_warm", &warm);
    let (hits, misses) = cache::stats();
    println!(
        "    -> traffic/retention cache: {:.1}x faster warm ({hits} hits / {misses} misses)",
        cold.median_ns / warm.median_ns
    );

    // Selection-grid evaluation: the full 108-candidate (variant × Δ × BER
    // × GLB × array) grid behind `stt-ai select`, warm caches — the
    // per-candidate evaluator cost the batched/tiered hot path targets.
    let shared = engine::shared_zoo();
    let sel_spec = select::spec_selection(&shared);
    let sel_label = format!("dse/selection_grid_{}", sel_spec.len());
    let r = b.run(&sel_label, || sel_spec.run_serial());
    ledger.add_throughput(&sel_label, &r, sel_spec.len() as f64, "candidates");
    println!(
        "    -> {:.1} us/candidate warm",
        r.median_ns / sel_spec.len() as f64 / 1e3
    );

    // Monte-Carlo PT sampling, serial vs pool-parallel — the headline
    // datapoints; `benches/montecarlo.rs` carries the deep dive.
    let mc = MonteCarlo::paper_glb();
    let mc_n: usize = if smoke { 50_000 } else { 200_000 };
    let label = format!("mram/montecarlo_{}k_serial", mc_n / 1000);
    let serial_pool = ThreadPool::new(1);
    let r1 = b.run(&label, || mc.run_with(0xD1E5, mc_n, &serial_pool, DEFAULT_CHUNK_SAMPLES));
    ledger.add_throughput(&label, &r1, mc_n as f64, "samples");
    let auto = Runner::from_args();
    let mc_pool = ThreadPool::new(auto.workers());
    let label = format!("mram/montecarlo_{}k_parallel_x{}", mc_n / 1000, mc_pool.workers());
    let rn = b.run(&label, || mc.run_with(0xD1E5, mc_n, &mc_pool, DEFAULT_CHUNK_SAMPLES));
    ledger.add_throughput(&label, &rn, mc_n as f64, "samples");
    println!(
        "    -> montecarlo speedup: {:.2}x with {} workers ({:.2} Msamples/s)",
        r1.median_ns / rn.median_ns,
        mc_pool.workers(),
        mc_n as f64 * 1e3 / rn.median_ns
    );

    // JSON parse of a manifest-sized document.
    let doc = std::fs::read_to_string("artifacts/manifest.json")
        .unwrap_or_else(|_| r#"{"models":{"m":{"batch":16}},"weights":"w","testset":{"n":1}}"#.into());
    let r = b.run("json/parse_manifest", || Json::parse(&doc).unwrap());
    ledger.add("json/parse_manifest", &r);

    // Batcher push/form cycle.
    let r = b.run("batcher/push_form_64", || {
        let mut batcher = Batcher::new(16, Duration::ZERO, 4, 1024);
        let now = stt_ai::util::clock::Tick::ZERO;
        for i in 0..64u64 {
            batcher.push(Request::new(i, vec![0.0; 4], now));
        }
        let mut n = 0;
        while let Some(batch) = batcher.form(16, now) {
            n += batch.real;
        }
        n
    });
    ledger.add("batcher/push_form_64", &r);

    // Figure regeneration end to end (Figs. 10-19): the pre-refactor serial
    // path vs the work-stealing sweep engine — the acceptance wall-clock
    // entry for the `dse::engine` refactor.
    let slow = if smoke {
        Bencher { sample_target_s: 0.05, samples: 2 }
    } else {
        Bencher { sample_target_s: 0.2, samples: 5 }
    };
    let serial = Runner::new(1);
    let r1 = slow.run("figures/regenerate_all_serial", || {
        report::render_all(&mut std::io::sink(), &serial).unwrap()
    });
    ledger.add("figures/regenerate_all_serial", &r1);
    let label = format!("figures/regenerate_all_parallel_x{}", auto.workers());
    let rn = slow.run(&label, || report::render_all(&mut std::io::sink(), &auto).unwrap());
    ledger.add(&label, &rn);
    println!(
        "    -> figure regeneration speedup: {:.2}x with {} workers",
        r1.median_ns / rn.median_ns,
        auto.workers()
    );

    // Tiered-cache breakdown over the whole run: which entry point absorbed
    // the hot-path work (L1 per-candidate derived, L2 shared walks, L3
    // model fingerprints).
    println!("-- dse::cache tiers (whole run)");
    for e in cache::tier_stats() {
        println!("    L{} {:<18} {:>9} hits {:>9} misses", e.tier, e.name, e.hits, e.misses);
    }

    // --bench-json / --save-baseline / --baseline handling (the CI
    // regression gate lives behind `--baseline`).
    bench::finish(&ledger);
}
