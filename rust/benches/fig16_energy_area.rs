//! Bench: regenerate Fig. 16 (SRAM vs MRAM energy/area vs capacity).
use stt_ai::dse::energy_area;
use stt_ai::dse::engine::Runner;
use stt_ai::report;
use stt_ai::util::bench::Bencher;

fn main() {
    report::fig16_with(&mut std::io::stdout().lock(), &Runner::from_args()).unwrap();
    let caps = energy_area::default_capacities_mb();
    Bencher::new().run("fig16/two_delta_sweeps", || {
        energy_area::fig16_glb(&caps).len() + energy_area::fig16_lsb(&caps).len()
    });
}
