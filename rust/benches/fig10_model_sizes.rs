//! Bench: regenerate Fig. 10 (model sizes + conv fmap/weight ranges) and
//! time the zoo analysis.
use stt_ai::dse::capacity::CapacityRow;
use stt_ai::dse::engine::Runner;
use stt_ai::models::{self, DType};
use stt_ai::report;
use stt_ai::util::bench::Bencher;

fn main() {
    report::fig10_with(&mut std::io::stdout().lock(), &Runner::from_args()).unwrap();
    let b = Bencher::new();
    b.run("fig10/zoo_build", || models::zoo().len());
    let zoo = models::zoo();
    b.run("fig10/analyze_19_models", || {
        zoo.iter().map(|m| CapacityRow::analyze(m, DType::Bf16, &[1]).size_bf16).sum::<u64>()
    });
}
