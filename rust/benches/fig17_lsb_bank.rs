//! Bench: regenerate Fig. 17 (Δ scaling at relaxed BER for the LSB bank).
use stt_ai::dse::delta::DeltaSweep;
use stt_ai::dse::engine::Runner;
use stt_ai::mram::MtjTech;
use stt_ai::report;
use stt_ai::util::bench::Bencher;

fn main() {
    report::fig17_with(&mut std::io::stdout().lock(), &Runner::from_args()).unwrap();
    let deltas = DeltaSweep::default_deltas();
    Bencher::new().run("fig17/relaxed_vs_tight", || {
        DeltaSweep::run(MtjTech::wei2019(), 1e-5, &deltas).write_pulse.len()
            + DeltaSweep::run(MtjTech::wei2019(), 1e-8, &deltas).write_pulse.len()
    });
}
