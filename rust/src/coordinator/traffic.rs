//! Open-loop arrival-trace DSL for the fleet simulator.
//!
//! An [`ArrivalTrace`] is a named, seeded description of *when requests
//! arrive*, independent of how fast the fleet serves them — the open-loop
//! half of the discrete-event simulation in [`crate::coordinator::fleet`].
//! Three stochastic generators cover the canonical serving regimes:
//!
//! * **poisson** — memoryless arrivals at a constant rate λ (inverse-CDF
//!   exponential inter-arrival times);
//! * **diurnal** — a nonhomogeneous Poisson process whose rate follows a
//!   raised-cosine day/night curve between `base_rps` and `peak_rps`,
//!   sampled exactly by Lewis–Shedler thinning against the peak rate;
//! * **bursty** — a two-state Markov-modulated Poisson process (calm/burst
//!   phases with exponential dwell times), the trace that separates a
//!   hetero fleet's fast SRAM island from an all-Ultra fleet in the p99.
//!
//! Two degenerate patterns complete the grammar: **closed** (every request
//! queued at t = 0, the old `serve::closed_loop` arrival model) and
//! **uniform** (fixed gap, the supervisor's chaos pacing). A sixth,
//! **replay**, is not stochastic at all: it streams a recorded list of
//! arrival instants (a `fleet --record` log, or any JSON-lines file of
//! timestamps) back through the same [`ArrivalGen`] contract, and is the
//! only finite pattern — its generator returns `None` past the last
//! recorded instant.
//!
//! Multi-tenant runs hold one trace per tenant; [`MuxArrivalGen`] merges
//! the per-tenant generators into a single nondecreasing arrival stream
//! tagged with the originating tenant index, deterministic because ties
//! break to the lowest index and each stream is itself seed-deterministic.
//!
//! Like the fault DSL ([`crate::coordinator::faults`]), traces come from
//! three places sharing one grammar: built-in tokens
//! ([`ArrivalTrace::builtin`]), JSON files ([`ArrivalTrace::parse`] falls
//! back to a path — the committed golden lives at
//! `rust/golden/fleet_diurnal.trace.json`), and the `[traffic]` section of
//! a [`crate::config::SystemConfig`]. All randomness derives from the
//! trace seed through the crate's xoshiro [`Rng`], so a trace replays the
//! exact same arrival instants on every run and at any worker count.

use std::sync::Arc;
use std::time::Duration;

use crate::util::clock::Tick;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// The stochastic (or degenerate) process generating arrival instants.
#[derive(Debug, Clone, PartialEq)]
pub enum TracePattern {
    /// Every request arrives at t = 0 (closed-loop serving: the clients
    /// queue everything up front and wait).
    Closed,
    /// Fixed inter-arrival gap.
    Uniform { gap: Duration },
    /// Homogeneous Poisson arrivals at `rate_rps` requests per second.
    Poisson { rate_rps: f64 },
    /// Nonhomogeneous Poisson with a raised-cosine rate curve:
    /// `λ(t) = base + (peak − base)·(1 − cos(2πt/period))/2`, so the trace
    /// starts at the quiet `base_rps` and crests at `peak_rps` once per
    /// `period`.
    Diurnal { base_rps: f64, peak_rps: f64, period: Duration },
    /// Two-state Markov-modulated Poisson process: exponential dwell times
    /// with the given means, Poisson arrivals at the phase's rate.
    Bursty { calm_rps: f64, burst_rps: f64, calm_dwell: Duration, burst_dwell: Duration },
    /// Recorded arrival instants replayed verbatim: nondecreasing offsets
    /// from the clock epoch, in nanoseconds. The only finite pattern —
    /// the generator ends after the last instant. Shared via `Arc` so
    /// cloning a trace (config roundtrips, per-shard setup) does not copy
    /// the recording.
    Replay { offsets_ns: Arc<Vec<u64>> },
}

impl TracePattern {
    /// Stable serialization token.
    pub fn token(&self) -> &'static str {
        match self {
            TracePattern::Closed => "closed",
            TracePattern::Uniform { .. } => "uniform",
            TracePattern::Poisson { .. } => "poisson",
            TracePattern::Diurnal { .. } => "diurnal",
            TracePattern::Bursty { .. } => "bursty",
            TracePattern::Replay { .. } => "replay",
        }
    }
}

/// A named, seeded arrival trace.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalTrace {
    pub name: String,
    /// Root seed for the arrival generator's xoshiro stream.
    pub seed: u64,
    pub pattern: TracePattern,
}

impl ArrivalTrace {
    /// Built-in traces by token; `None` for unknown names.
    ///
    /// Rates are sized against the paper fleet (≈1 ms service, batch 16 →
    /// ~16 k req/s per STT-AI Ultra engine): `poisson` loads one engine to
    /// ~90 %, `diurnal` crests near a two-engine fleet's capacity, and
    /// `bursty` alternates a comfortable 8 k req/s calm phase with 40 k
    /// req/s storms that overload a two-Ultra fleet but not one fronted by
    /// an SRAM island — the hetero-fleet p99 gate in `tests/fleet.rs`.
    pub fn builtin(name: &str) -> Option<Self> {
        let ms = Duration::from_millis;
        match name {
            "closed" => {
                Some(Self { name: "closed".into(), seed: 0x0C10, pattern: TracePattern::Closed })
            }
            "uniform" => Some(Self {
                name: "uniform".into(),
                seed: 0x41F0,
                pattern: TracePattern::Uniform { gap: Duration::from_micros(70) },
            }),
            "poisson" => Some(Self {
                name: "poisson".into(),
                seed: 0x9015,
                pattern: TracePattern::Poisson { rate_rps: 14_000.0 },
            }),
            "diurnal" => Some(Self {
                name: "diurnal".into(),
                seed: 0xD1A1,
                pattern: TracePattern::Diurnal {
                    base_rps: 8_000.0,
                    peak_rps: 28_000.0,
                    period: ms(100),
                },
            }),
            "bursty" => Some(Self {
                name: "bursty".into(),
                seed: 0xB4B5,
                pattern: TracePattern::Bursty {
                    calm_rps: 8_000.0,
                    burst_rps: 40_000.0,
                    calm_dwell: ms(20),
                    burst_dwell: ms(10),
                },
            }),
            _ => None,
        }
    }

    /// Every built-in trace token (CLI help + roundtrip tests).
    pub fn builtin_names() -> &'static [&'static str] {
        &["closed", "uniform", "poisson", "diurnal", "bursty"]
    }

    /// Resolve a CLI `--trace` spec: a built-in token first, else a path to
    /// a trace JSON file, else a JSON-lines recording (a `fleet --record`
    /// log, or one timestamp object per line) replayed as a `replay` trace.
    pub fn parse(spec: &str) -> crate::Result<Self> {
        if let Some(t) = Self::builtin(spec) {
            return Ok(t);
        }
        let path = std::path::Path::new(spec);
        if path.exists() {
            let text = std::fs::read_to_string(path)?;
            // A whole-file JSON document is a trace description; a record
            // log is JSON *lines*, so whole-file parsing stops at the first
            // newline with a trailing-content error and we fall through.
            return match Json::parse(&text) {
                Ok(j) => Self::from_json(&j),
                Err(_) => Self::replay_from_jsonl(path, &text),
            };
        }
        anyhow::bail!(
            "unknown arrival trace {spec:?} (builtins: {}; or a path to a trace JSON \
             or a JSON-lines arrival recording)",
            Self::builtin_names().join(", ")
        )
    }

    /// Parse a JSON-lines arrival recording into a `replay` trace.
    ///
    /// Accepted rows: `fleet --record` entries (objects with an
    /// `arrival_ns` field) or bare objects `{"arrival_ns": N}`. A header
    /// line carrying `trace` and `seed` (the record log writes one) names
    /// the replayed trace so a record → replay round trip reproduces the
    /// original report byte for byte; without it the trace is named after
    /// the file.
    fn replay_from_jsonl(path: &std::path::Path, text: &str) -> crate::Result<Self> {
        let mut name: Option<String> = None;
        let mut seed = 0u64;
        let mut offsets = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let row = Json::parse(line).map_err(|e| {
                anyhow::anyhow!("{}:{}: not a JSON line: {e}", path.display(), lineno + 1)
            })?;
            if let Some(n) = row.get("arrival_ns").and_then(Json::as_u64) {
                offsets.push(n);
            } else if row.get("trace").is_some() {
                // Record-log header: restore the recorded trace identity.
                name = row.get("trace").and_then(Json::as_str).map(str::to_string);
                seed = row.get("seed").and_then(Json::as_u64).unwrap_or(0);
            } else {
                anyhow::bail!(
                    "{}:{}: replay rows need an arrival_ns field",
                    path.display(),
                    lineno + 1
                );
            }
        }
        if offsets.is_empty() {
            anyhow::bail!("{}: no arrivals to replay", path.display());
        }
        if offsets.windows(2).any(|w| w[0] > w[1]) {
            anyhow::bail!("{}: replay arrivals must be nondecreasing", path.display());
        }
        let name = name.unwrap_or_else(|| {
            path.file_stem().map_or_else(|| "replay".into(), |s| s.to_string_lossy().into_owned())
        });
        Ok(Self { name, seed, pattern: TracePattern::Replay { offsets_ns: Arc::new(offsets) } })
    }

    /// Serialize (durations as integer microseconds — exact on roundtrip;
    /// rates as JSON numbers, which the crate serializer prints losslessly
    /// for the integral req/s values the grammar uses).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("seed", self.seed.into()),
            ("pattern", Json::Str(self.pattern.token().to_string())),
        ];
        match &self.pattern {
            TracePattern::Closed => {}
            TracePattern::Uniform { gap } => {
                fields.push(("gap_us", (gap.as_micros() as u64).into()));
            }
            TracePattern::Poisson { rate_rps } => fields.push(("rate_rps", Json::Num(*rate_rps))),
            TracePattern::Diurnal { base_rps, peak_rps, period } => {
                fields.push(("base_rps", Json::Num(*base_rps)));
                fields.push(("peak_rps", Json::Num(*peak_rps)));
                fields.push(("period_us", (period.as_micros() as u64).into()));
            }
            TracePattern::Bursty { calm_rps, burst_rps, calm_dwell, burst_dwell } => {
                fields.push(("calm_rps", Json::Num(*calm_rps)));
                fields.push(("burst_rps", Json::Num(*burst_rps)));
                fields.push(("calm_dwell_us", (calm_dwell.as_micros() as u64).into()));
                fields.push(("burst_dwell_us", (burst_dwell.as_micros() as u64).into()));
            }
            TracePattern::Replay { offsets_ns } => {
                fields.push((
                    "offsets_ns",
                    Json::Arr(offsets_ns.iter().map(|&n| n.into()).collect()),
                ));
            }
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        use anyhow::Context;
        let name = j.req_str("name").map_err(anyhow::Error::from)?.to_string();
        let seed = j.req_u64("seed").map_err(anyhow::Error::from)?;
        let us = |key: &str| -> crate::Result<Duration> {
            Ok(Duration::from_micros(j.req_u64(key).map_err(anyhow::Error::from)?))
        };
        let rps = |key: &'static str| -> crate::Result<f64> {
            let v = j.req(key).map_err(anyhow::Error::from)?.as_f64().context(key)?;
            if !(v.is_finite() && v > 0.0) {
                anyhow::bail!("trace {name:?}: {key} must be a positive rate, got {v}");
            }
            Ok(v)
        };
        let pattern = match j.req_str("pattern").map_err(anyhow::Error::from)? {
            "closed" => TracePattern::Closed,
            "uniform" => {
                let gap = us("gap_us")?;
                if gap.is_zero() {
                    anyhow::bail!("trace {name:?}: uniform gap_us must be positive");
                }
                TracePattern::Uniform { gap }
            }
            "poisson" => TracePattern::Poisson { rate_rps: rps("rate_rps")? },
            "diurnal" => {
                let (base_rps, peak_rps) = (rps("base_rps")?, rps("peak_rps")?);
                let period = us("period_us")?;
                if peak_rps < base_rps {
                    anyhow::bail!("trace {name:?}: peak_rps {peak_rps} below base_rps {base_rps}");
                }
                if period.is_zero() {
                    anyhow::bail!("trace {name:?}: diurnal period_us must be positive");
                }
                TracePattern::Diurnal { base_rps, peak_rps, period }
            }
            "bursty" => {
                let (calm_dwell, burst_dwell) = (us("calm_dwell_us")?, us("burst_dwell_us")?);
                if calm_dwell.is_zero() || burst_dwell.is_zero() {
                    anyhow::bail!("trace {name:?}: bursty dwell times must be positive");
                }
                TracePattern::Bursty {
                    calm_rps: rps("calm_rps")?,
                    burst_rps: rps("burst_rps")?,
                    calm_dwell,
                    burst_dwell,
                }
            }
            "replay" => {
                let rows = j.req_arr("offsets_ns").map_err(anyhow::Error::from)?;
                let offsets = rows
                    .iter()
                    .map(|v| {
                        v.as_u64().ok_or_else(|| {
                            anyhow::anyhow!("trace {name:?}: offsets_ns entries must be u64 ns")
                        })
                    })
                    .collect::<crate::Result<Vec<u64>>>()?;
                if offsets.is_empty() {
                    anyhow::bail!("trace {name:?}: replay needs at least one arrival");
                }
                if offsets.windows(2).any(|w| w[0] > w[1]) {
                    anyhow::bail!("trace {name:?}: replay offsets must be nondecreasing");
                }
                TracePattern::Replay { offsets_ns: Arc::new(offsets) }
            }
            other => anyhow::bail!("unknown trace pattern {other:?}"),
        };
        Ok(Self { name, seed, pattern })
    }
}

/// Exponential inter-arrival draw for rate λ (per second), in nanoseconds.
/// `next_f64` is 53-bit in [0, 1), so `1 − u ∈ (0, 1]` keeps the log finite
/// and the draw bounded by ~36.7/λ.
#[inline]
fn exp_ns(rng: &mut Rng, rate_rps: f64) -> u64 {
    (-(1.0 - rng.next_f64()).ln() / rate_rps * 1e9) as u64
}

/// Exponential dwell draw with the given mean.
#[inline]
fn exp_dwell_ns(rng: &mut Rng, mean: Duration) -> u64 {
    (-(1.0 - rng.next_f64()).ln() * mean.as_nanos() as f64) as u64
}

/// Streaming generator of arrival instants for one [`ArrivalTrace`]: each
/// [`ArrivalGen::next_offset_opt`] call yields the next arrival as a
/// nondecreasing offset from the clock epoch (`None` once a finite
/// `replay` trace is exhausted; stochastic traces never end). Entirely
/// seed-driven — two generators built from equal traces emit identical
/// instants forever.
#[derive(Debug)]
pub struct ArrivalGen {
    pattern: TracePattern,
    rng: Rng,
    t_ns: u64,
    in_burst: bool,
    state_until_ns: u64,
    /// Cursor into a `replay` trace's recorded offsets.
    idx: usize,
}

impl ArrivalGen {
    pub fn new(trace: &ArrivalTrace) -> Self {
        let mut rng = Rng::seed_from_u64(trace.seed);
        let state_until_ns = match &trace.pattern {
            TracePattern::Bursty { calm_dwell, .. } => exp_dwell_ns(&mut rng, *calm_dwell),
            _ => 0,
        };
        Self {
            pattern: trace.pattern.clone(),
            rng,
            t_ns: 0,
            in_burst: false,
            state_until_ns,
            idx: 0,
        }
    }

    /// Offset from the clock epoch of the next arrival. An exhausted
    /// `replay` trace holds at its last instant; open-ended callers should
    /// prefer [`Self::next_offset_opt`].
    pub fn next_offset(&mut self) -> Duration {
        let held = Duration::from_nanos(self.t_ns);
        self.next_offset_opt().unwrap_or(held)
    }

    /// Offset from the clock epoch of the next arrival, or `None` once a
    /// finite trace has replayed every recorded instant.
    pub fn next_offset_opt(&mut self) -> Option<Duration> {
        match &self.pattern {
            TracePattern::Closed => {}
            TracePattern::Uniform { gap } => self.t_ns += gap.as_nanos() as u64,
            TracePattern::Poisson { rate_rps } => {
                let rate = *rate_rps;
                self.t_ns += exp_ns(&mut self.rng, rate);
            }
            TracePattern::Diurnal { base_rps, peak_rps, period } => {
                let (base_rps, peak_rps, period) = (*base_rps, *peak_rps, *period);
                // Lewis–Shedler thinning against the peak rate: candidate
                // arrivals at λ_max, each kept with probability λ(t)/λ_max.
                // Acceptance never falls below base/peak, so the loop
                // terminates (and in ~peak/base expected candidates).
                loop {
                    self.t_ns += exp_ns(&mut self.rng, peak_rps);
                    let phase = std::f64::consts::TAU * Tick::from_nanos(self.t_ns).as_secs_f64()
                        / period.as_secs_f64();
                    let rate = base_rps + (peak_rps - base_rps) * 0.5 * (1.0 - phase.cos());
                    if self.rng.next_f64() * peak_rps < rate {
                        break;
                    }
                }
            }
            TracePattern::Bursty { calm_rps, burst_rps, calm_dwell, burst_dwell } => {
                let (calm_rps, burst_rps) = (*calm_rps, *burst_rps);
                let (calm_dwell, burst_dwell) = (*calm_dwell, *burst_dwell);
                loop {
                    let rate = if self.in_burst { burst_rps } else { calm_rps };
                    let cand = self.t_ns + exp_ns(&mut self.rng, rate);
                    if cand <= self.state_until_ns {
                        self.t_ns = cand;
                        break;
                    }
                    // Phase boundary crossed: jump to it, toggle the state,
                    // and redraw — exact for an MMPP because the
                    // exponential is memoryless, so the discarded partial
                    // draw carries no information.
                    self.t_ns = self.state_until_ns;
                    self.in_burst = !self.in_burst;
                    let dwell = if self.in_burst { burst_dwell } else { calm_dwell };
                    self.state_until_ns = self.t_ns + exp_dwell_ns(&mut self.rng, dwell);
                }
            }
            TracePattern::Replay { offsets_ns } => {
                let off = *offsets_ns.get(self.idx)?;
                self.idx += 1;
                self.t_ns = off;
            }
        }
        Some(Duration::from_nanos(self.t_ns))
    }
}

/// Merge per-tenant arrival generators into one nondecreasing stream of
/// `(offset, tenant)` pairs.
///
/// Each pull yields the earliest pending arrival across every stream; ties
/// break to the lowest tenant index, so the merged order is a pure function
/// of the traces — seed-deterministic and independent of worker count. A
/// single-stream mux emits exactly its generator's sequence, which is how
/// the default single-tenant fleet stays byte-identical to the pre-tenant
/// serving stack. The mux ends (`None`) only when every stream is finite
/// and exhausted.
#[derive(Debug)]
pub struct MuxArrivalGen {
    gens: Vec<ArrivalGen>,
    /// The next undelivered offset of each stream (`None` = exhausted).
    next: Vec<Option<Duration>>,
}

impl MuxArrivalGen {
    pub fn new(traces: &[ArrivalTrace]) -> Self {
        let mut gens: Vec<ArrivalGen> = traces.iter().map(ArrivalGen::new).collect();
        let next = gens.iter_mut().map(ArrivalGen::next_offset_opt).collect();
        Self { gens, next }
    }

    /// The earliest pending arrival and its tenant index, or `None` when
    /// every stream is exhausted.
    pub fn next_arrival(&mut self) -> Option<(Duration, u32)> {
        let mut best: Option<(Duration, usize)> = None;
        for (i, pending) in self.next.iter().enumerate() {
            if let Some(d) = pending {
                if best.is_none_or(|(bd, _)| *d < bd) {
                    best = Some((*d, i));
                }
            }
        }
        let (off, i) = best?;
        self.next[i] = self.gens[i].next_offset_opt();
        Some((off, i as u32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_roundtrip_through_json() {
        for name in ArrivalTrace::builtin_names() {
            let t = ArrivalTrace::builtin(name).unwrap();
            let text = t.to_json().to_string();
            let back = ArrivalTrace::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, t, "{name} roundtrip");
            assert_eq!(back.to_json().to_string(), text, "{name} byte-stable");
        }
    }

    #[test]
    fn parse_rejects_unknown_traces_with_a_named_error() {
        let err = ArrivalTrace::parse("no_such_trace").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown arrival trace"), "{msg}");
        assert!(msg.contains("bursty"), "lists builtins: {msg}");
    }

    #[test]
    fn from_json_rejects_nonpositive_rates_and_zero_durations() {
        let bad = r#"{"name":"x","seed":1,"pattern":"poisson","rate_rps":0}"#;
        assert!(ArrivalTrace::from_json(&Json::parse(bad).unwrap()).is_err());
        let bad = r#"{"name":"x","seed":1,"pattern":"uniform","gap_us":0}"#;
        assert!(ArrivalTrace::from_json(&Json::parse(bad).unwrap()).is_err());
        let bad = r#"{"name":"x","seed":1,"pattern":"diurnal",
                      "base_rps":9000,"peak_rps":100,"period_us":1000}"#;
        assert!(ArrivalTrace::from_json(&Json::parse(bad).unwrap()).is_err());
        let bad = r#"{"name":"x","seed":1,"pattern":"warp"}"#;
        assert!(ArrivalTrace::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn generators_are_deterministic_and_nondecreasing() {
        for name in ArrivalTrace::builtin_names() {
            let trace = ArrivalTrace::builtin(name).unwrap();
            let mut a = ArrivalGen::new(&trace);
            let mut b = ArrivalGen::new(&trace);
            let mut last = Duration::ZERO;
            for i in 0..2_000 {
                let x = a.next_offset();
                assert_eq!(x, b.next_offset(), "{name} diverged at arrival {i}");
                assert!(x >= last, "{name}: arrivals must be nondecreasing");
                last = x;
            }
        }
    }

    #[test]
    fn closed_trace_queues_everything_at_the_epoch() {
        let mut g = ArrivalGen::new(&ArrivalTrace::builtin("closed").unwrap());
        for _ in 0..10 {
            assert_eq!(g.next_offset(), Duration::ZERO);
        }
    }

    #[test]
    fn uniform_trace_paces_exactly() {
        let mut g = ArrivalGen::new(&ArrivalTrace::builtin("uniform").unwrap());
        assert_eq!(g.next_offset(), Duration::from_micros(70));
        assert_eq!(g.next_offset(), Duration::from_micros(140));
    }

    /// The MMPP actually alternates: over many dwells the burst phase must
    /// contribute a visibly higher local arrival density than calm.
    #[test]
    fn bursty_trace_has_two_distinguishable_phases() {
        let trace = ArrivalTrace::builtin("bursty").unwrap();
        let mut g = ArrivalGen::new(&trace);
        // Bin arrivals into 5 ms windows over ~2 s of trace.
        let mut bins = vec![0u32; 400];
        loop {
            let t = g.next_offset();
            let bin = t.as_nanos() as u64 / 5_000_000;
            if bin as usize >= bins.len() {
                break;
            }
            bins[bin as usize] += 1;
        }
        let (lo, hi) = (*bins.iter().min().unwrap(), *bins.iter().max().unwrap());
        // calm ≈ 40/bin, burst ≈ 200/bin; demand a clear spread.
        assert!(hi > 2 * lo.max(1), "no burst structure: min {lo} max {hi}");
    }

    /// Diurnal rate law: arrivals per period-half around the crest must
    /// clearly exceed those around the trough.
    #[test]
    fn diurnal_trace_follows_the_rate_curve() {
        let trace = ArrivalTrace::builtin("diurnal").unwrap();
        let mut g = ArrivalGen::new(&trace);
        let period_ns = 100_000_000u64;
        let (mut trough, mut crest) = (0u64, 0u64);
        loop {
            let t = g.next_offset().as_nanos() as u64;
            if t >= 20 * period_ns {
                break;
            }
            // Quarter around the trough (phase 0) vs around the crest (π).
            let phase = t % period_ns;
            if phase < period_ns / 8 || phase >= 7 * period_ns / 8 {
                trough += 1;
            } else if (3 * period_ns / 8..5 * period_ns / 8).contains(&phase) {
                crest += 1;
            }
        }
        assert!(crest > 2 * trough, "crest {crest} vs trough {trough}");
    }

    fn replay_trace(offsets: &[u64]) -> ArrivalTrace {
        ArrivalTrace {
            name: "rec".into(),
            seed: 7,
            pattern: TracePattern::Replay { offsets_ns: Arc::new(offsets.to_vec()) },
        }
    }

    #[test]
    fn replay_roundtrips_through_json_and_ends_after_the_recording() {
        let t = replay_trace(&[10, 10, 25, 40]);
        let text = t.to_json().to_string();
        let back = ArrivalTrace::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, t, "replay roundtrip");
        let mut g = ArrivalGen::new(&back);
        let got: Vec<_> = std::iter::from_fn(|| g.next_offset_opt()).collect();
        assert_eq!(
            got,
            vec![
                Duration::from_nanos(10),
                Duration::from_nanos(10),
                Duration::from_nanos(25),
                Duration::from_nanos(40)
            ]
        );
        assert_eq!(g.next_offset_opt(), None, "stays exhausted");
        assert_eq!(g.next_offset(), Duration::from_nanos(40), "open-ended view holds the end");
    }

    #[test]
    fn replay_from_json_rejects_empty_and_decreasing_recordings() {
        let bad = r#"{"name":"x","seed":1,"pattern":"replay","offsets_ns":[]}"#;
        assert!(ArrivalTrace::from_json(&Json::parse(bad).unwrap()).is_err(), "empty");
        let bad = r#"{"name":"x","seed":1,"pattern":"replay","offsets_ns":[5,3]}"#;
        assert!(ArrivalTrace::from_json(&Json::parse(bad).unwrap()).is_err(), "decreasing");
    }

    #[test]
    fn parse_reads_a_jsonl_recording_and_restores_the_header_identity() {
        let path =
            std::env::temp_dir().join(format!("stt_ai_replay_{}.jsonl", std::process::id()));
        let log = "{\"requests\":3,\"seed\":36885,\"trace\":\"poisson\"}\n\
                   {\"arrival_ns\":100,\"completion_ns\":900,\"engine\":0,\"id\":0,\"tenant\":0}\n\
                   {\"arrival_ns\":250,\"completion_ns\":1100,\"engine\":1,\"id\":1,\"tenant\":0}\n\
                   {\"arrival_ns\":300,\"completion_ns\":1300,\"engine\":0,\"id\":2,\"tenant\":0}\n";
        std::fs::write(&path, log).unwrap();
        let t = ArrivalTrace::parse(path.to_str().unwrap()).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(t.name, "poisson", "header names the replayed trace");
        assert_eq!(t.seed, 36885, "header restores the recorded seed");
        match &t.pattern {
            TracePattern::Replay { offsets_ns } => {
                assert_eq!(offsets_ns.as_slice(), &[100, 250, 300]);
            }
            other => panic!("expected replay, got {other:?}"),
        }
    }

    #[test]
    fn mux_merges_streams_in_time_order_with_lowest_index_ties() {
        let a = replay_trace(&[10, 30, 30]);
        let b = replay_trace(&[5, 30, 50]);
        let mut mux = MuxArrivalGen::new(&[a, b]);
        let ns = Duration::from_nanos;
        let got: Vec<_> = std::iter::from_fn(|| mux.next_arrival()).collect();
        assert_eq!(
            got,
            vec![
                (ns(5), 1),
                (ns(10), 0),
                (ns(30), 0), // tie at 30 ns: tenant 0 wins
                (ns(30), 0),
                (ns(30), 1),
                (ns(50), 1)
            ]
        );
        assert_eq!(mux.next_arrival(), None);
    }

    #[test]
    fn single_stream_mux_matches_the_plain_generator() {
        let trace = ArrivalTrace::builtin("bursty").unwrap();
        let mut plain = ArrivalGen::new(&trace);
        let mut mux = MuxArrivalGen::new(std::slice::from_ref(&trace));
        for i in 0..2_000 {
            let (off, tenant) = mux.next_arrival().unwrap();
            assert_eq!(tenant, 0);
            assert_eq!(off, plain.next_offset(), "diverged at arrival {i}");
        }
    }
}
