//! Dynamic batcher: coalesce queued inference requests into batches.
//!
//! The accelerator exposes fixed-batch executables (one per compiled batch
//! size); the batcher drains the request queue up to `max_batch`, waits at
//! most `window` for stragglers, and pads the final partial batch (padding
//! rows are executed and discarded — the fixed-shape cost of AOT).
//!
//! All time is expressed as [`Tick`] from an injectable
//! [`Clock`](crate::util::clock::Clock): under a virtual clock the same
//! arrival schedule forms byte-identical batches on every run, which is what
//! makes the fault-injection harness (`coordinator::supervisor`)
//! deterministic.

use crate::util::clock::Tick;
use std::collections::VecDeque;
use std::time::Duration;

/// One inference request: an image, an opaque id, and its arrival instant.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub enqueued: Tick,
}

impl Request {
    /// Build a request stamped with its arrival instant (read it from the
    /// serving loop's `Clock`).
    pub fn new(id: u64, image: Vec<f32>, now: Tick) -> Self {
        Self { id, image, enqueued: now }
    }
}

/// A formed batch: concatenated images + the real (unpadded) request count.
#[derive(Debug, Clone)]
pub struct Batch {
    pub ids: Vec<u64>,
    pub images: Vec<f32>,
    /// Number of real rows; rows beyond this are padding.
    pub real: usize,
    /// Batch capacity (the executable's compiled batch size).
    pub capacity: usize,
    /// Queueing delay of the oldest request in the batch.
    pub oldest_wait: Duration,
    /// Arrival instant of each real row (parallel to `ids`) — the fleet
    /// simulator turns these into per-request sojourn latencies when the
    /// batch completes.
    pub enqueued: Vec<Tick>,
}

/// The batcher. Synchronous core (easily driven from a tokio task — see
/// examples/serve.rs).
pub struct Batcher {
    queue: VecDeque<Request>,
    pub max_batch: usize,
    pub window: Duration,
    pub image_elems: usize,
    /// Rejected when the queue is full (backpressure).
    pub queue_depth: usize,
    pub rejected: u64,
    /// Rejected because the request's image shape does not match the
    /// compiled executables (a malformed request must never crash the
    /// serving loop — it is the *caller's* payload that is wrong).
    pub malformed: u64,
}

impl Batcher {
    pub fn new(max_batch: usize, window: Duration, image_elems: usize, queue_depth: usize) -> Self {
        Self {
            queue: VecDeque::new(),
            max_batch,
            window,
            image_elems,
            queue_depth,
            rejected: 0,
            malformed: 0,
        }
    }

    /// Enqueue a request; `false` if rejected (malformed image shape, or
    /// backpressure when the queue is full).
    pub fn push(&mut self, r: Request) -> bool {
        if r.image.len() != self.image_elems {
            self.malformed += 1;
            return false;
        }
        if self.queue.len() >= self.queue_depth {
            self.rejected += 1;
            return false;
        }
        self.queue.push_back(r);
        true
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Queueing delay of the oldest pending request (zero when idle) — the
    /// signal [`crate::coordinator::Router::dispatch`] schedules on.
    pub fn oldest_wait(&self, now: Tick) -> Duration {
        self.queue.front().map_or(Duration::ZERO, |r| now.duration_since(r.enqueued))
    }

    /// Should the caller fire a batch now? Either the batch is full, or the
    /// oldest request has waited past the window.
    pub fn ready(&self, now: Tick) -> bool {
        if self.queue.len() >= self.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(r) => now.duration_since(r.enqueued) >= self.window,
            None => false,
        }
    }

    /// Form a batch of exactly `capacity` rows (padding with zero images if
    /// fewer real requests are queued). Returns `None` on an empty queue.
    pub fn form(&mut self, capacity: usize, now: Tick) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let take = self.queue.len().min(capacity);
        let mut ids = Vec::with_capacity(take);
        let mut images = Vec::with_capacity(capacity * self.image_elems);
        let mut enqueued = Vec::with_capacity(take);
        let mut oldest = Duration::ZERO;
        for _ in 0..take {
            // `take <= queue.len()` by construction, but a sick invariant
            // must degrade to a short batch, not a serving-loop panic.
            let Some(r) = self.queue.pop_front() else { break };
            oldest = oldest.max(now.duration_since(r.enqueued));
            ids.push(r.id);
            enqueued.push(r.enqueued);
            images.extend_from_slice(&r.image);
        }
        let real = ids.len();
        images.resize(capacity * self.image_elems, 0.0);
        Some(Batch { ids, images, real, capacity, oldest_wait: oldest, enqueued })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![0.5; 4], Tick::ZERO)
    }

    fn batcher() -> Batcher {
        Batcher::new(4, Duration::from_millis(5), 4, 8)
    }

    #[test]
    fn fires_when_full() {
        let mut b = batcher();
        for i in 0..4 {
            assert!(b.push(req(i)));
        }
        assert!(b.ready(Tick::ZERO));
        let batch = b.form(4, Tick::ZERO).unwrap();
        assert_eq!(batch.real, 4);
        assert_eq!(batch.ids, vec![0, 1, 2, 3]);
        assert_eq!(batch.images.len(), 16);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn window_expiry_fires_partial() {
        let mut b = batcher();
        b.push(req(1));
        assert!(!b.ready(Tick::ZERO), "fresh request, window not expired");
        let later = Tick::ZERO + Duration::from_millis(10);
        assert!(b.ready(later));
        let batch = b.form(4, later).unwrap();
        assert_eq!(batch.real, 1);
        assert_eq!(batch.capacity, 4);
        assert_eq!(batch.oldest_wait, Duration::from_millis(10));
        // Per-row arrival instants cover exactly the real rows.
        assert_eq!(batch.enqueued, vec![Tick::ZERO]);
        // Padding rows are zeros.
        assert!(batch.images[4..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn backpressure_rejects() {
        let mut b = batcher();
        for i in 0..8 {
            assert!(b.push(req(i)));
        }
        assert!(!b.push(req(99)));
        assert_eq!(b.rejected, 1);
    }

    #[test]
    fn empty_queue_forms_nothing() {
        let mut b = batcher();
        assert!(b.form(4, Tick::ZERO).is_none());
        assert!(!b.ready(Tick::ZERO));
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = batcher();
        for i in [5u64, 3, 9] {
            b.push(req(i));
        }
        let batch = b.form(4, Tick::ZERO).unwrap();
        assert_eq!(batch.ids, vec![5, 3, 9]);
    }

    #[test]
    fn malformed_request_is_rejected_not_a_panic() {
        // Regression: a wrong-shaped image used to assert! and crash the
        // whole serving loop; it must be rejected and counted instead.
        let mut b = batcher();
        assert!(!b.push(Request::new(1, vec![0.5; 3], Tick::ZERO)), "short image rejected");
        assert!(!b.push(Request::new(2, vec![0.5; 5], Tick::ZERO)), "long image rejected");
        assert!(!b.push(Request::new(3, Vec::new(), Tick::ZERO)), "empty image rejected");
        assert_eq!(b.malformed, 3);
        assert_eq!(b.rejected, 0, "malformed is its own counter");
        assert_eq!(b.pending(), 0, "nothing malformed reaches the queue");
        // The loop keeps serving well-formed traffic afterwards.
        assert!(b.push(req(4)));
        assert_eq!(b.pending(), 1);
        assert_eq!(b.form(4, Tick::ZERO).unwrap().ids, vec![4]);
    }

    #[test]
    fn malformed_counts_even_under_backpressure() {
        // Shape check runs first: a malformed request never consumes the
        // queue-depth budget, and a full queue still counts it as malformed.
        let mut b = batcher();
        for i in 0..8 {
            assert!(b.push(req(i)));
        }
        assert!(!b.push(Request::new(99, vec![0.0; 2], Tick::ZERO)));
        assert_eq!((b.malformed, b.rejected), (1, 0));
        assert!(!b.push(req(100)));
        assert_eq!((b.malformed, b.rejected), (1, 1));
    }

    #[test]
    fn oldest_wait_tracks_the_queue_head() {
        let mut b = batcher();
        let now = Tick::ZERO;
        assert_eq!(b.oldest_wait(now), Duration::ZERO, "idle queue waits zero");
        b.push(req(1));
        let later = now + Duration::from_millis(10);
        assert_eq!(b.oldest_wait(later), Duration::from_millis(10));
        // Forming the batch drains the head; the wait resets.
        b.form(4, later).unwrap();
        assert_eq!(b.oldest_wait(later + Duration::from_millis(5)), Duration::ZERO);
    }

    #[test]
    fn window_expiry_interacts_with_backpressure() {
        // Fill to the depth limit, get rejected, then let the window expire:
        // the partial batch fires, frees queue space, and pushes succeed
        // again — backpressure is transient, not sticky.
        let mut b = batcher();
        for i in 0..8 {
            assert!(b.push(req(i)));
        }
        assert!(!b.push(req(99)));
        assert_eq!(b.rejected, 1);
        let later = Tick::ZERO + Duration::from_millis(10);
        assert!(b.ready(later), "expired window fires despite backpressure");
        let batch = b.form(4, later).unwrap();
        assert_eq!(batch.real, 4);
        assert_eq!(batch.oldest_wait, Duration::from_millis(10));
        assert_eq!(b.pending(), 4);
        assert!(b.push(req(100)), "space freed after the batch fired");
    }
}
