//! Dynamic batcher: coalesce queued inference requests into batches.
//!
//! The accelerator exposes fixed-batch executables (one per compiled batch
//! size); the batcher drains the request queues up to `max_batch`, waits at
//! most `window` for stragglers, and pads the final partial batch (padding
//! rows are executed and discarded — the fixed-shape cost of AOT).
//!
//! The batcher is class-aware: each tenant class owns its own FIFO queue
//! with its own backpressure budget, and [`Batcher::form`] admits rows by
//! weighted deficit round-robin — a backlogged class of weight *w* earns
//! *w* rows per service round, so no positive-weight class can be starved
//! by a heavier neighbour. A single-class batcher (the
//! [`Batcher::new`] constructor) degenerates to exactly the historical
//! FIFO: one queue, round-robin over one class.
//!
//! All time is expressed as [`Tick`] from an injectable
//! [`Clock`](crate::util::clock::Clock): under a virtual clock the same
//! arrival schedule forms byte-identical batches on every run, which is what
//! makes the fault-injection harness (`coordinator::supervisor`)
//! deterministic.

use crate::util::clock::Tick;
use std::collections::VecDeque;
use std::time::Duration;

/// One inference request: an image, an opaque id, its tenant class, and its
/// arrival instant.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Tenant class index (position in the run's
    /// [`TenantMix`](super::TenantMix)); single-tenant paths use 0.
    pub tenant: u32,
    pub image: Vec<f32>,
    pub enqueued: Tick,
}

impl Request {
    /// Build a default-tenant request stamped with its arrival instant
    /// (read it from the serving loop's `Clock`).
    pub fn new(id: u64, image: Vec<f32>, now: Tick) -> Self {
        Self::for_tenant(id, 0, image, now)
    }

    /// Build a request tagged with its tenant class.
    pub fn for_tenant(id: u64, tenant: u32, image: Vec<f32>, now: Tick) -> Self {
        Self { id, tenant, image, enqueued: now }
    }
}

/// A formed batch: concatenated images + the real (unpadded) request count.
#[derive(Debug, Clone)]
pub struct Batch {
    pub ids: Vec<u64>,
    pub images: Vec<f32>,
    /// Number of real rows; rows beyond this are padding.
    pub real: usize,
    /// Batch capacity (the executable's compiled batch size).
    pub capacity: usize,
    /// Queueing delay of the oldest request in the batch.
    pub oldest_wait: Duration,
    /// Arrival instant of each real row (parallel to `ids`) — the fleet
    /// simulator turns these into per-request sojourn latencies when the
    /// batch completes.
    pub enqueued: Vec<Tick>,
    /// Tenant class of each real row (parallel to `ids`) — the fleet
    /// simulator books each row into its tenant's ledger on completion.
    pub tenants: Vec<u32>,
}

/// One tenant class's FIFO queue plus its deficit-round-robin state.
#[derive(Debug)]
struct ClassQueue {
    queue: VecDeque<Request>,
    /// DRR quantum: rows this class may contribute per service round.
    weight: u64,
    /// Unspent quantum carried across `form` calls (persistent deficit —
    /// a class cut off mid-quantum resumes where it stopped).
    deficit: u64,
    rejected: u64,
    malformed: u64,
}

impl ClassQueue {
    fn new(weight: u64) -> Self {
        Self { queue: VecDeque::new(), weight, deficit: 0, rejected: 0, malformed: 0 }
    }
}

/// The batcher. Synchronous core (easily driven from a tokio task — see
/// examples/serve.rs).
pub struct Batcher {
    classes: Vec<ClassQueue>,
    /// Round-robin cursor: which class the next service round visits.
    rr: usize,
    pub max_batch: usize,
    pub window: Duration,
    pub image_elems: usize,
    /// Per-class queue budget; a class at its budget rejects (backpressure)
    /// without consuming its neighbours' headroom.
    pub queue_depth: usize,
    pub rejected: u64,
    /// Rejected because the request's image shape does not match the
    /// compiled executables (a malformed request must never crash the
    /// serving loop — it is the *caller's* payload that is wrong).
    pub malformed: u64,
}

impl Batcher {
    /// Single-class batcher: the historical FIFO path.
    pub fn new(max_batch: usize, window: Duration, image_elems: usize, queue_depth: usize) -> Self {
        Self::with_weights(max_batch, window, image_elems, queue_depth, &[1])
    }

    /// Class-aware batcher with one queue per weight (tenant order). An
    /// empty slice falls back to a single class of weight 1.
    pub fn with_weights(
        max_batch: usize,
        window: Duration,
        image_elems: usize,
        queue_depth: usize,
        weights: &[u64],
    ) -> Self {
        let classes = if weights.is_empty() {
            vec![ClassQueue::new(1)]
        } else {
            weights.iter().map(|&w| ClassQueue::new(w.max(1))).collect()
        };
        Self {
            classes,
            rr: 0,
            max_batch,
            window,
            image_elems,
            queue_depth,
            rejected: 0,
            malformed: 0,
        }
    }

    /// Number of tenant classes.
    pub fn class_count(&self) -> usize {
        self.classes.len()
    }

    fn class_of(&self, tenant: u32) -> usize {
        (tenant as usize).min(self.classes.len() - 1)
    }

    /// Enqueue a request; `false` if rejected (malformed image shape, or
    /// backpressure when the request's class queue is full).
    pub fn push(&mut self, r: Request) -> bool {
        let c = self.class_of(r.tenant);
        if r.image.len() != self.image_elems {
            self.malformed += 1;
            self.classes[c].malformed += 1;
            return false;
        }
        if self.classes[c].queue.len() >= self.queue_depth {
            self.rejected += 1;
            self.classes[c].rejected += 1;
            return false;
        }
        self.classes[c].queue.push_back(r);
        true
    }

    pub fn pending(&self) -> usize {
        self.classes.iter().map(|c| c.queue.len()).sum()
    }

    /// Pending requests in one tenant class.
    pub fn class_pending(&self, class: usize) -> usize {
        self.classes.get(class).map_or(0, |c| c.queue.len())
    }

    /// Backpressure rejects charged to one tenant class.
    pub fn class_rejected(&self, class: usize) -> u64 {
        self.classes.get(class).map_or(0, |c| c.rejected)
    }

    /// Malformed rejects charged to one tenant class.
    pub fn class_malformed(&self, class: usize) -> u64 {
        self.classes.get(class).map_or(0, |c| c.malformed)
    }

    /// Queueing delay of the oldest pending request across all classes
    /// (zero when idle) — the signal
    /// [`crate::coordinator::Router::dispatch`] schedules on.
    pub fn oldest_wait(&self, now: Tick) -> Duration {
        self.classes
            .iter()
            .filter_map(|c| c.queue.front())
            .map(|r| now.duration_since(r.enqueued))
            .max()
            .unwrap_or(Duration::ZERO)
    }

    /// Should the caller fire a batch now? Either a full batch is pending,
    /// or some class's oldest request has waited past the window (the
    /// window is accounted per class head, so a trickle-rate tenant still
    /// fires on time behind a high-rate neighbour).
    pub fn ready(&self, now: Tick) -> bool {
        self.pending() >= self.max_batch || self.oldest_wait(now) >= self.window
    }

    /// Form a batch of exactly `capacity` rows (padding with zero images if
    /// fewer real requests are queued), admitting rows by weighted deficit
    /// round-robin over the class queues. Returns `None` when every queue
    /// is empty.
    pub fn form(&mut self, capacity: usize, now: Tick) -> Option<Batch> {
        let pending = self.pending();
        if pending == 0 {
            return None;
        }
        let take = pending.min(capacity);
        let n = self.classes.len();
        let mut ids = Vec::with_capacity(take);
        let mut images = Vec::with_capacity(capacity * self.image_elems);
        let mut enqueued = Vec::with_capacity(take);
        let mut tenants = Vec::with_capacity(take);
        let mut oldest = Duration::ZERO;
        let mut taken = 0usize;
        while taken < take {
            let c = self.rr;
            if self.classes[c].queue.is_empty() {
                // An idle class spends nothing and banks nothing.
                self.classes[c].deficit = 0;
                self.rr = (self.rr + 1) % n;
                continue;
            }
            if self.classes[c].deficit == 0 {
                self.classes[c].deficit = self.classes[c].weight;
            }
            while self.classes[c].deficit > 0 && taken < take {
                let Some(r) = self.classes[c].queue.pop_front() else { break };
                self.classes[c].deficit -= 1;
                oldest = oldest.max(now.duration_since(r.enqueued));
                ids.push(r.id);
                tenants.push(r.tenant);
                enqueued.push(r.enqueued);
                images.extend_from_slice(&r.image);
                taken += 1;
            }
            if self.classes[c].queue.is_empty() {
                self.classes[c].deficit = 0;
            }
            if self.classes[c].deficit == 0 {
                self.rr = (self.rr + 1) % n;
            }
        }
        let real = ids.len();
        images.resize(capacity * self.image_elems, 0.0);
        Some(Batch { ids, images, real, capacity, oldest_wait: oldest, enqueued, tenants })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![0.5; 4], Tick::ZERO)
    }

    fn treq(id: u64, tenant: u32) -> Request {
        Request::for_tenant(id, tenant, vec![0.5; 4], Tick::ZERO)
    }

    fn batcher() -> Batcher {
        Batcher::new(4, Duration::from_millis(5), 4, 8)
    }

    #[test]
    fn fires_when_full() {
        let mut b = batcher();
        for i in 0..4 {
            assert!(b.push(req(i)));
        }
        assert!(b.ready(Tick::ZERO));
        let batch = b.form(4, Tick::ZERO).unwrap();
        assert_eq!(batch.real, 4);
        assert_eq!(batch.ids, vec![0, 1, 2, 3]);
        assert_eq!(batch.images.len(), 16);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn window_expiry_fires_partial() {
        let mut b = batcher();
        b.push(req(1));
        assert!(!b.ready(Tick::ZERO), "fresh request, window not expired");
        let later = Tick::ZERO + Duration::from_millis(10);
        assert!(b.ready(later));
        let batch = b.form(4, later).unwrap();
        assert_eq!(batch.real, 1);
        assert_eq!(batch.capacity, 4);
        assert_eq!(batch.oldest_wait, Duration::from_millis(10));
        // Per-row arrival instants cover exactly the real rows.
        assert_eq!(batch.enqueued, vec![Tick::ZERO]);
        assert_eq!(batch.tenants, vec![0]);
        // Padding rows are zeros.
        assert!(batch.images[4..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn backpressure_rejects() {
        let mut b = batcher();
        for i in 0..8 {
            assert!(b.push(req(i)));
        }
        assert!(!b.push(req(99)));
        assert_eq!(b.rejected, 1);
    }

    #[test]
    fn empty_queue_forms_nothing() {
        let mut b = batcher();
        assert!(b.form(4, Tick::ZERO).is_none());
        assert!(!b.ready(Tick::ZERO));
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = batcher();
        for i in [5u64, 3, 9] {
            b.push(req(i));
        }
        let batch = b.form(4, Tick::ZERO).unwrap();
        assert_eq!(batch.ids, vec![5, 3, 9]);
    }

    #[test]
    fn malformed_request_is_rejected_not_a_panic() {
        // Regression: a wrong-shaped image used to assert! and crash the
        // whole serving loop; it must be rejected and counted instead.
        let mut b = batcher();
        assert!(!b.push(Request::new(1, vec![0.5; 3], Tick::ZERO)), "short image rejected");
        assert!(!b.push(Request::new(2, vec![0.5; 5], Tick::ZERO)), "long image rejected");
        assert!(!b.push(Request::new(3, Vec::new(), Tick::ZERO)), "empty image rejected");
        assert_eq!(b.malformed, 3);
        assert_eq!(b.rejected, 0, "malformed is its own counter");
        assert_eq!(b.pending(), 0, "nothing malformed reaches the queue");
        // The loop keeps serving well-formed traffic afterwards.
        assert!(b.push(req(4)));
        assert_eq!(b.pending(), 1);
        assert_eq!(b.form(4, Tick::ZERO).unwrap().ids, vec![4]);
    }

    #[test]
    fn malformed_counts_even_under_backpressure() {
        // Shape check runs first: a malformed request never consumes the
        // queue-depth budget, and a full queue still counts it as malformed.
        let mut b = batcher();
        for i in 0..8 {
            assert!(b.push(req(i)));
        }
        assert!(!b.push(Request::new(99, vec![0.0; 2], Tick::ZERO)));
        assert_eq!((b.malformed, b.rejected), (1, 0));
        assert!(!b.push(req(100)));
        assert_eq!((b.malformed, b.rejected), (1, 1));
    }

    #[test]
    fn oldest_wait_tracks_the_queue_head() {
        let mut b = batcher();
        let now = Tick::ZERO;
        assert_eq!(b.oldest_wait(now), Duration::ZERO, "idle queue waits zero");
        b.push(req(1));
        let later = now + Duration::from_millis(10);
        assert_eq!(b.oldest_wait(later), Duration::from_millis(10));
        // Forming the batch drains the head; the wait resets.
        b.form(4, later).unwrap();
        assert_eq!(b.oldest_wait(later + Duration::from_millis(5)), Duration::ZERO);
    }

    #[test]
    fn window_expiry_interacts_with_backpressure() {
        // Fill to the depth limit, get rejected, then let the window expire:
        // the partial batch fires, frees queue space, and pushes succeed
        // again — backpressure is transient, not sticky.
        let mut b = batcher();
        for i in 0..8 {
            assert!(b.push(req(i)));
        }
        assert!(!b.push(req(99)));
        assert_eq!(b.rejected, 1);
        let later = Tick::ZERO + Duration::from_millis(10);
        assert!(b.ready(later), "expired window fires despite backpressure");
        let batch = b.form(4, later).unwrap();
        assert_eq!(batch.real, 4);
        assert_eq!(batch.oldest_wait, Duration::from_millis(10));
        assert_eq!(b.pending(), 4);
        assert!(b.push(req(100)), "space freed after the batch fired");
    }

    #[test]
    fn drr_interleaves_by_weight() {
        // Two backlogged classes at weights 2:1 — a service round admits
        // two rows of class 0 for every one of class 1.
        let mut b = Batcher::with_weights(6, Duration::ZERO, 4, 64, &[2, 1]);
        for i in 0..6 {
            b.push(treq(i, 0));
        }
        for i in 10..16 {
            b.push(treq(i, 1));
        }
        let batch = b.form(6, Tick::ZERO).unwrap();
        assert_eq!(batch.ids, vec![0, 1, 10, 2, 3, 11]);
        assert_eq!(batch.tenants, vec![0, 0, 1, 0, 0, 1]);
        // The cursor and deficits persist: the next batch picks up where
        // the round stopped instead of restarting at class 0.
        let batch = b.form(6, Tick::ZERO).unwrap();
        assert_eq!(batch.ids, vec![4, 5, 12, 13, 14, 15]);
    }

    #[test]
    fn drr_never_starves_the_light_class() {
        // Weight 7 vs 1 with a deep heavy backlog: every 8-row service
        // round still carries one light-class row.
        let mut b = Batcher::with_weights(8, Duration::ZERO, 4, 1024, &[7, 1]);
        for i in 0..64 {
            b.push(treq(i, 0));
        }
        for i in 100..108 {
            b.push(treq(i, 1));
        }
        for round in 0..8 {
            let batch = b.form(8, Tick::ZERO).unwrap();
            let light = batch.tenants.iter().filter(|&&t| t == 1).count();
            assert_eq!(light, 1, "round {round} carries exactly one light row");
        }
    }

    #[test]
    fn out_of_range_tenant_clamps_to_the_last_class() {
        let mut b = Batcher::with_weights(4, Duration::ZERO, 4, 8, &[1, 1]);
        assert!(b.push(treq(1, 7)));
        assert_eq!(b.class_pending(1), 1, "tenant 7 lands in the last class");
        assert_eq!(b.form(4, Tick::ZERO).unwrap().tenants, vec![7], "tag preserved verbatim");
    }

    #[test]
    fn per_class_backpressure_is_isolated() {
        // Class 0 saturates its budget; class 1 still accepts traffic, and
        // rejects are charged to the class that overflowed.
        let mut b = Batcher::with_weights(4, Duration::ZERO, 4, 4, &[1, 1]);
        for i in 0..4 {
            assert!(b.push(treq(i, 0)));
        }
        assert!(!b.push(treq(99, 0)), "class 0 is full");
        assert!(b.push(treq(100, 1)), "class 1 has its own budget");
        assert_eq!((b.class_rejected(0), b.class_rejected(1)), (1, 0));
        assert_eq!(b.rejected, 1, "aggregate counter still tracks the total");
    }

    #[test]
    fn idle_class_banks_no_deficit() {
        // A class that goes idle mid-round must not hoard quantum and burst
        // ahead when traffic returns: deficit resets on empty.
        let mut b = Batcher::with_weights(4, Duration::ZERO, 4, 64, &[3, 1]);
        b.push(treq(0, 0));
        assert_eq!(b.form(4, Tick::ZERO).unwrap().ids, vec![0]);
        for i in 1..4 {
            b.push(treq(i, 0));
        }
        for i in 10..12 {
            b.push(treq(i, 1));
        }
        // The cursor moved past class 0 when it went idle, so class 1 runs
        // first; class 0 then earns exactly its weight (3) again — the
        // unspent quantum from the short round did not carry over.
        assert_eq!(b.form(4, Tick::ZERO).unwrap().ids, vec![10, 1, 2, 3]);
        assert_eq!(b.form(4, Tick::ZERO).unwrap().ids, vec![11]);
    }

    #[test]
    fn window_fires_for_a_trickle_tenant_behind_a_busy_one() {
        // Class 1's lone request ages past the window even while class 0
        // keeps its own head fresh — readiness tracks the oldest head
        // across classes, not just one queue front.
        let mut b = Batcher::with_weights(16, Duration::from_millis(5), 4, 64, &[1, 1]);
        b.push(Request::for_tenant(1, 1, vec![0.5; 4], Tick::ZERO));
        let later = Tick::ZERO + Duration::from_millis(6);
        b.push(Request::for_tenant(2, 0, vec![0.5; 4], later));
        assert!(b.ready(later), "aged class-1 head fires the window");
        assert_eq!(b.oldest_wait(later), Duration::from_millis(6));
    }
}
