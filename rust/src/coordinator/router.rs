//! Request router: picks the executable variant (batch size) per dispatch.
//!
//! The AOT flow compiles one executable per batch size; at serve time the
//! router looks at queue depth and latency targets and decides whether to
//! fire a small batch now (latency) or wait and fill a big one
//! (throughput) — the same decision a vLLM-style router makes between
//! latency- and throughput-optimal batching.

use anyhow::bail;
use std::time::Duration;

/// One available executable variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Variant {
    pub batch: usize,
}

/// Routing policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct RouterPolicy {
    /// Fire the largest fillable batch once queue ≥ this fraction of it.
    pub fill_threshold: f64,
    /// Max age of the oldest request before firing whatever is available.
    pub max_wait: Duration,
}

impl Default for RouterPolicy {
    fn default() -> Self {
        Self { fill_threshold: 1.0, max_wait: Duration::from_millis(2) }
    }
}

/// The router.
#[derive(Debug, Clone)]
pub struct Router {
    /// Sorted ascending by batch; guaranteed non-empty by [`Router::new`].
    variants: Vec<Variant>,
    pub policy: RouterPolicy,
}

impl Router {
    /// Build a router over the compiled batch sizes (sorted, deduplicated).
    ///
    /// An empty variant list is a configuration error, not a panic: a
    /// serving binary booting from a bad manifest must surface
    /// `router: no compiled batch variants` instead of crashing the fleet.
    pub fn new(mut batches: Vec<usize>, policy: RouterPolicy) -> crate::Result<Self> {
        batches.sort_unstable();
        batches.dedup();
        if batches.is_empty() {
            bail!("router: no compiled batch variants (need at least one batch size)");
        }
        Ok(Self { variants: batches.into_iter().map(|batch| Variant { batch }).collect(), policy })
    }

    pub fn variants(&self) -> &[Variant] {
        &self.variants
    }

    /// Largest compiled batch — the per-shard capacity quantum the fleet
    /// balancer divides outstanding work by when projecting service time.
    pub fn largest(&self) -> Variant {
        // Non-empty by `new()`'s contract.
        *self.variants.last().expect("router variants are non-empty")
    }

    /// Smallest compiled variant covering `queued` requests (the largest
    /// one if the queue exceeds everything) — what the deadline path would
    /// fire. The fleet balancer uses this to estimate the *next* batch's
    /// capacity for a shard without mutating its queue.
    pub fn covering(&self, queued: usize) -> Variant {
        *self
            .variants
            .iter()
            .find(|v| v.batch >= queued)
            .or_else(|| self.variants.last())
            .expect("router variants are non-empty")
    }

    /// Decide what to run given `queued` requests whose oldest has waited
    /// `oldest_wait`. Returns `None` to keep waiting.
    pub fn dispatch(&self, queued: usize, oldest_wait: Duration) -> Option<Variant> {
        if queued == 0 {
            return None;
        }
        // Throughput path: fire only when the LARGEST variant fills to the
        // threshold (firing small variants early would starve big batches).
        // `new()` guarantees a non-empty ladder, so last() always exists.
        let largest = *self.variants.last()?;
        if queued as f64 >= largest.batch as f64 * self.policy.fill_threshold {
            return Some(largest);
        }
        if oldest_wait >= self.policy.max_wait {
            // Deadline: smallest variant that covers the queue (minimize
            // padding), or the largest one if the queue exceeds everything.
            let v = self
                .variants
                .iter()
                .find(|v| v.batch >= queued)
                .or_else(|| self.variants.last())?;
            return Some(*v);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        Router::new(vec![16, 1], RouterPolicy::default()).expect("non-empty variants")
    }

    #[test]
    fn empty_variant_list_is_an_error_not_a_panic() {
        // Regression: Router::new used to assert! on an empty list, taking
        // the whole serving process down on a bad manifest.
        let err = Router::new(Vec::new(), RouterPolicy::default()).unwrap_err();
        assert!(err.to_string().contains("no compiled batch variants"), "named error: {err}");
    }

    #[test]
    fn empty_queue_waits() {
        assert_eq!(router().dispatch(0, Duration::from_secs(1)), None);
    }

    #[test]
    fn full_queue_fires_big_batch() {
        let r = router();
        assert_eq!(r.dispatch(16, Duration::ZERO), Some(Variant { batch: 16 }));
        assert_eq!(r.dispatch(40, Duration::ZERO), Some(Variant { batch: 16 }));
    }

    #[test]
    fn fresh_partial_queue_waits() {
        let r = router();
        // 5 queued, fresh: a single-request variant would thrash; wait.
        assert_eq!(r.dispatch(5, Duration::ZERO), None);
    }

    #[test]
    fn deadline_fires_smallest_covering_variant() {
        let r = Router::new(vec![1, 4, 16], RouterPolicy::default()).expect("variants");
        let late = Duration::from_millis(5);
        assert_eq!(r.dispatch(3, late), Some(Variant { batch: 4 }));
        assert_eq!(r.dispatch(1, late), Some(Variant { batch: 1 }));
        assert_eq!(r.dispatch(9, late), Some(Variant { batch: 16 }));
    }

    #[test]
    fn single_request_fires_batch1_only_on_deadline() {
        // A lone request waits for company; on deadline it takes the
        // batch-1 variant (no padding).
        let r = router();
        assert_eq!(r.dispatch(1, Duration::ZERO), None);
        assert_eq!(r.dispatch(1, Duration::from_millis(5)), Some(Variant { batch: 1 }));
    }

    #[test]
    fn threshold_below_one_fires_earlier() {
        let r = Router::new(vec![16], RouterPolicy { fill_threshold: 0.5, ..Default::default() })
            .expect("variants");
        assert_eq!(r.dispatch(8, Duration::ZERO), Some(Variant { batch: 16 }));
        assert_eq!(r.dispatch(7, Duration::ZERO), None);
    }

    #[test]
    fn covering_and_largest_mirror_the_deadline_ladder() {
        let r = Router::new(vec![2, 8, 32], RouterPolicy::default()).expect("variants");
        assert_eq!(r.largest(), Variant { batch: 32 });
        assert_eq!(r.covering(0), Variant { batch: 2 });
        assert_eq!(r.covering(3), Variant { batch: 8 });
        assert_eq!(r.covering(8), Variant { batch: 8 });
        assert_eq!(r.covering(100), Variant { batch: 32 });
    }

    #[test]
    fn variants_sorted_dedup() {
        let r = Router::new(vec![16, 1, 16, 4], RouterPolicy::default()).expect("variants");
        let b: Vec<usize> = r.variants().iter().map(|v| v.batch).collect();
        assert_eq!(b, vec![1, 4, 16]);
    }

    #[test]
    fn threshold_above_one_never_fires_on_fill_alone() {
        // fill_threshold > 1.0 demands more queued requests than the
        // largest batch holds before the throughput path fires — the queue
        // must overfill so the next batch starts warm.
        let r = Router::new(vec![16], RouterPolicy { fill_threshold: 1.5, ..Default::default() })
            .expect("variants");
        assert_eq!(r.dispatch(16, Duration::ZERO), None, "a full batch is not 1.5x full");
        assert_eq!(r.dispatch(23, Duration::ZERO), None);
        assert_eq!(r.dispatch(24, Duration::ZERO), Some(Variant { batch: 16 }));
        // The deadline path is independent of the threshold: stale traffic
        // still drains even under an overfill policy.
        assert_eq!(r.dispatch(3, Duration::from_millis(5)), Some(Variant { batch: 16 }));
    }

    #[test]
    fn deadline_queue_between_variants_picks_minimal_padding() {
        // Queue sizes that land strictly between compiled variants must
        // take the smallest variant that covers them (minimal padding),
        // across the whole ladder.
        let r = Router::new(vec![2, 8, 32], RouterPolicy::default()).expect("variants");
        let late = Duration::from_millis(5);
        assert_eq!(r.dispatch(1, late), Some(Variant { batch: 2 }));
        assert_eq!(r.dispatch(3, late), Some(Variant { batch: 8 }));
        assert_eq!(r.dispatch(8, late), Some(Variant { batch: 8 }));
        assert_eq!(r.dispatch(9, late), Some(Variant { batch: 32 }));
        // Beyond every variant: the largest fires (the rest re-queue).
        assert_eq!(r.dispatch(33, late), Some(Variant { batch: 32 }));
        // Exactly at the deadline boundary counts as expired.
        assert_eq!(r.dispatch(1, RouterPolicy::default().max_wait), Some(Variant { batch: 2 }));
    }

    #[test]
    fn zero_max_wait_dispatches_any_pending_request() {
        // A zero-deadline policy degenerates to "serve whatever is queued":
        // oldest_wait >= ZERO always holds, so nothing ever starves — and
        // an empty queue still yields None rather than a phantom batch.
        let policy = RouterPolicy { fill_threshold: 1.0, max_wait: Duration::ZERO };
        let r = Router::new(vec![4, 16], policy).expect("variants");
        assert_eq!(r.dispatch(0, Duration::ZERO), None);
        assert_eq!(r.dispatch(1, Duration::ZERO), Some(Variant { batch: 4 }));
        assert_eq!(r.dispatch(16, Duration::ZERO), Some(Variant { batch: 16 }));
    }
}
