//! L3 coordinator: the serving side of the STT-AI accelerator.
//!
//! * [`engine`] — the inference engine: owns the PJRT executables (one per
//!   batch size), the weights, and the STT-MRAM fault model of the selected
//!   GLB variant; applies bank-split BER injection to the weight image the
//!   way the physical buffer would corrupt it, then serves batches.
//! * [`batcher`] — dynamic batcher: coalesces queued requests up to
//!   `max_batch` within a bounded window, padding the tail batch; rejects
//!   (and counts) malformed and backpressured requests instead of crashing.
//! * [`router`] — picks the executable variant per dispatch from queue
//!   depth and head-of-line wait; [`serve::closed_loop`] schedules through
//!   it.
//! * [`metrics`] — latency + queue-wait quantile sketches (fixed footprint,
//!   ≤ 1/64 relative error), throughput counters anchored at the first
//!   served batch.
//! * [`traffic`] — open-loop arrival-trace DSL: seeded Poisson, diurnal
//!   (raised-cosine rate via thinning), bursty (two-state MMPP), uniform,
//!   closed, and replay (recorded JSON-lines timestamps) patterns, from
//!   builtin tokens, JSON files, or the `[traffic]` config section;
//!   [`MuxArrivalGen`] merges per-tenant streams into one deterministic
//!   arrival order.
//! * [`tenant`] — multi-tenant SLO classes: [`TenantSpec`] (tier, weight,
//!   own arrival trace, optional accuracy floor) grouped into a
//!   [`TenantMix`] from builtin tokens, JSON files, or the `[tenants]`
//!   config section. The default single-tenant mix reproduces the
//!   pre-tenant stack byte for byte.
//! * [`fleet`] — discrete-event fleet simulator: one event heap interleaves
//!   open-loop arrivals, per-shard batch completions, window-deadline
//!   wakes, and autoscale rounds over a heterogeneous fleet of
//!   [`EngineSpec`]s; routing is least-outstanding with an SLO-aware
//!   fallback to the fastest projection (the SRAM island), and per-request
//!   latency/energy stream into mergeable sketches at O(1) memory. Under a
//!   non-default [`TenantMix`] the batcher runs weighted deficit
//!   round-robin across per-class queues, routing prefers per-tier islands
//!   under each tenant's own SLO, and the report carries per-tenant
//!   ledgers. [`serve::closed_loop`] is its degenerate
//!   one-shard/closed-arrival configuration ([`fleet::run_closed`]).
//! * [`accuracy`] — Fig. 21-style evaluation loops (Top-1/Top-5, pruning).
//! * [`faults`] — deterministic fault-schedule DSL: seeded, timed BER
//!   escalations, retention storms at the inverted guard-band corner, bank
//!   takedowns, stalls, crashes and latency spikes.
//! * [`supervisor`] — graceful-degradation supervisor over a multi-engine
//!   fleet. Every engine carries a health state driven by canary probes
//!   and dispatch outcomes:
//!
//!   ```text
//!   Healthy --(degraded_after consecutive failures)--> Degraded
//!   Degraded --(down_after consecutive failures)-----> Down
//!   Down --(down for reboot_after)--> fallback reboot --> Degraded probation
//!   Degraded --(recover_after consecutive passes)----> Healthy
//!   ```
//!
//!   The dispatch path prefers Healthy engines, falls back to Degraded
//!   ones, retries with exponential backoff under a per-request deadline,
//!   and — on sustained fault pressure — reboots a Down engine from a
//!   fallback `DesignSelection` (e.g. the latency-optimal SRAM pick, which
//!   is immune to retention faults by construction).
//!
//! All serving time flows through the injectable
//! [`Clock`](crate::util::clock::Clock): wall-backed for live serving,
//! virtual for tests and fault scenarios (bit-reproducible reports at any
//! `--parallel` worker count).
//!
//! The engine boots from a hard-coded paper config
//! ([`EngineConfig::new`]) or from a sweep-selected design point
//! ([`EngineConfig::from_selection`], `stt-ai serve --from-selection`).

pub mod accuracy;
pub mod batcher;
pub mod engine;
pub mod faults;
pub mod fleet;
pub mod metrics;
pub mod router;
pub mod serve;
pub mod supervisor;
pub mod tenant;
pub mod traffic;

pub use accuracy::{AccuracyReport, Fig21Row};
pub use batcher::{Batch, Batcher, Request};
pub use engine::{Engine, EngineConfig};
pub use faults::{EffectiveFaults, FaultEvent, FaultKind, FaultSchedule};
pub use fleet::{
    FleetConfig, FleetEngineReport, FleetPolicy, FleetSim, FleetSimReport, FleetTenantReport,
};
pub use metrics::{Metrics, TenantLedger};
pub use router::{Router, RouterPolicy, Variant};
pub use supervisor::{ChaosConfig, EngineSpec, FleetReport, Health, Supervisor, SupervisorPolicy};
pub use tenant::{SloTier, TenantMix, TenantSpec};
pub use traffic::{ArrivalGen, ArrivalTrace, MuxArrivalGen, TracePattern};
