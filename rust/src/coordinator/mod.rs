//! L3 coordinator: the serving side of the STT-AI accelerator.
//!
//! * [`engine`] — the inference engine: owns the PJRT executables (one per
//!   batch size), the weights, and the STT-MRAM fault model of the selected
//!   GLB variant; applies bank-split BER injection to the weight image the
//!   way the physical buffer would corrupt it, then serves batches.
//! * [`batcher`] — dynamic batcher: coalesces queued requests up to
//!   `max_batch` within a bounded window, padding the tail batch; rejects
//!   (and counts) malformed and backpressured requests instead of crashing.
//! * [`router`] — picks the executable variant per dispatch from queue
//!   depth and head-of-line wait; [`serve::closed_loop`] schedules through
//!   it.
//! * [`metrics`] — latency + queue-wait histograms, throughput counters
//!   anchored at the first served batch.
//! * [`accuracy`] — Fig. 21-style evaluation loops (Top-1/Top-5, pruning).
//!
//! The engine boots from a hard-coded paper config
//! ([`EngineConfig::new`]) or from a sweep-selected design point
//! ([`EngineConfig::from_selection`], `stt-ai serve --from-selection`).

pub mod accuracy;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod serve;

pub use accuracy::{AccuracyReport, Fig21Row};
pub use batcher::{Batch, Batcher, Request};
pub use engine::{Engine, EngineConfig};
pub use metrics::Metrics;
pub use router::{Router, RouterPolicy, Variant};
