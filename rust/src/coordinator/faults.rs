//! Deterministic fault-schedule DSL for the serving stack.
//!
//! A [`FaultSchedule`] is a named, seed-reproducible list of timed
//! [`FaultEvent`]s — retention-loss storms at the hot/slow PT corner (the
//! *inverse* of the Eq. 17 guard band used by `dse::select`), BER
//! escalation episodes, bank takedowns, engine stalls/crashes, and latency
//! spikes — executed by the graceful-degradation supervisor
//! ([`crate::coordinator::supervisor`]) against a virtual clock. Because
//! every event fires at a fixed [`Tick`] and all randomness derives from
//! the schedule seed, the same scenario produces byte-identical
//! availability/accuracy reports on every run and at any worker count.
//!
//! Schedules come from three places, one grammar: built-in scenario tokens
//! ([`FaultSchedule::builtin`], e.g. `burst_ber`), JSON files
//! ([`FaultSchedule::parse`] falls back to a path), and the `[faults]`
//! section of a [`crate::config::SystemConfig`].

use std::time::Duration;

use crate::config::{BerConfig, TechBase};
use crate::util::clock::Tick;
use crate::util::json::Json;

/// What a fault event does to the engines it targets while active.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Multiply both bank BERs by `factor` (temperature / read-disturb
    /// episode), capped at 0.5 per bit.
    BerEscalation { factor: f64 },
    /// Retention-loss storm at the hot/slow PT corner: each bank's BER is
    /// rescaled by the Arrhenius factor between its *built* Δ and the Δ the
    /// guard-band inversion leaves at the worst corner, with `derate`
    /// shrinking the corner Δ further (derate 1.0 = exactly the Eq. 17
    /// corner; see [`storm_ber`]).
    RetentionStorm { derate: f64 },
    /// One bank group goes dark: its BER pegs to 0.5 (every read a coin
    /// flip). `lsb` picks the relaxed bank, otherwise the robust MSB bank.
    BankDown { lsb: bool },
    /// Multiply the engine's service latency by `mult`.
    LatencySpike { mult: f64 },
    /// The engine stops making progress: dispatches time out against the
    /// supervisor's per-request deadline but the process stays up.
    Stall,
    /// The engine process is down: dispatches fail immediately and the
    /// health machine marks it `Down` at once.
    Crash,
}

impl FaultKind {
    /// Stable serialization token.
    pub fn token(&self) -> &'static str {
        match self {
            FaultKind::BerEscalation { .. } => "ber_escalation",
            FaultKind::RetentionStorm { .. } => "retention_storm",
            FaultKind::BankDown { .. } => "bank_down",
            FaultKind::LatencySpike { .. } => "latency_spike",
            FaultKind::Stall => "stall",
            FaultKind::Crash => "crash",
        }
    }
}

/// One timed fault: `kind` applies to `engine` (or the whole fleet) during
/// `[at, until)` on the supervisor's virtual clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Onset, measured from the clock epoch (simulation t = 0).
    pub at: Duration,
    /// End of the window (exclusive), measured from the clock epoch.
    pub until: Duration,
    /// Target engine index; `None` hits every engine in the fleet.
    pub engine: Option<usize>,
    pub kind: FaultKind,
}

impl FaultEvent {
    /// Is this event active for `engine` at instant `now`?
    pub fn active_at(&self, engine: usize, now: Tick) -> bool {
        if self.engine.is_some_and(|e| e != engine) {
            return false;
        }
        let t = now.duration_since(Tick::ZERO);
        t >= self.at && t < self.until
    }
}

/// A named, seeded fault scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSchedule {
    pub name: String,
    /// Root seed: canary probes and any stochastic corruption derive their
    /// sub-streams from it, so the whole run replays exactly.
    pub seed: u64,
    pub events: Vec<FaultEvent>,
}

/// Everything the fault layer says about one engine at one instant: the
/// effective per-bank BERs plus the service-path modifiers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EffectiveFaults {
    pub msb_ber: f64,
    pub lsb_ber: f64,
    pub latency_mult: f64,
    pub stalled: bool,
    pub crashed: bool,
}

impl EffectiveFaults {
    /// The no-fault state over a base BER budget.
    pub fn clean(base: BerConfig) -> Self {
        Self {
            msb_ber: base.msb_ber,
            lsb_ber: base.lsb_ber,
            latency_mult: 1.0,
            stalled: false,
            crashed: false,
        }
    }
}

/// BER of one bank under a retention-loss storm at the hot/slow PT corner.
///
/// Retention failure is Arrhenius in the thermal-stability factor: for a
/// fixed observation window, `BER ∝ exp(-Δ)`. The §V.C flow *builds* the
/// bank at the guard-banded Δ (Eq. 17) precisely so the worst corner still
/// holds the scaled design Δ — so the corner Δ is recovered by inverting
/// the (linear) guard band: `Δ_corner = Δ_built / gb(1.0)`. A storm with
/// `derate > 1` pushes the die *past* the designed-for corner, and the
/// bank's BER scales by `exp(Δ_built − Δ_corner/…)`:
///
/// `ber' = min(0.5, ber · exp(Δ_built − Δ_built / gb(1.0) / derate))`
///
/// A volatile bank (`base_ber == 0`, e.g. SRAM) never flips whatever the
/// storm does — which is exactly why the supervisor's fallback reboot to
/// the SRAM [`crate::dse::select::DesignSelection`] restores service.
pub fn storm_ber(tech: TechBase, delta_built: f64, base_ber: f64, derate: f64) -> f64 {
    if base_ber <= 0.0 {
        return 0.0;
    }
    let gb_per_scaled = tech.technology().guard_band(1.0).delta_guard_banded.max(1.0);
    let delta_corner = delta_built / gb_per_scaled / derate.max(1.0);
    (base_ber * (delta_built - delta_corner).exp()).min(0.5)
}

impl FaultSchedule {
    /// A quiet scenario (no events) — the control run.
    pub fn calm() -> Self {
        Self { name: "calm".into(), seed: 0xCA11, events: Vec::new() }
    }

    /// Built-in scenarios by token; `None` for unknown names.
    ///
    /// `burst_ber` is the golden graceful-degradation scenario (see
    /// EXPERIMENTS.md §Robustness): a long BER-escalation storm on engine 0
    /// drives it through `Degraded → Down → fallback reboot`, a shorter
    /// storm brushes engine 1, and a brief stall on engine 2 forces the
    /// dispatch path to retry and reroute — all while availability stays
    /// ≥ 99 %.
    pub fn builtin(name: &str) -> Option<Self> {
        let ms = Duration::from_millis;
        let ev = |at: u64, until: u64, engine: Option<usize>, kind: FaultKind| FaultEvent {
            at: ms(at),
            until: ms(until),
            engine,
            kind,
        };
        match name {
            "calm" => Some(Self::calm()),
            "burst_ber" => Some(Self {
                name: "burst_ber".into(),
                seed: 0xFA17,
                events: vec![
                    ev(10, 70, Some(0), FaultKind::BerEscalation { factor: 1.0e3 }),
                    ev(30, 50, Some(1), FaultKind::BerEscalation { factor: 1.0e3 }),
                    ev(35, 40, Some(2), FaultKind::Stall),
                ],
            }),
            "retention_storm" => Some(Self {
                name: "retention_storm".into(),
                seed: 0x5702,
                events: vec![
                    // Fleet-wide thermal excursion past the designed-for
                    // corner; volatile fallbacks are immune by construction.
                    ev(10, 60, None, FaultKind::RetentionStorm { derate: 1.5 }),
                ],
            }),
            "bank_takedown" => Some(Self {
                name: "bank_takedown".into(),
                seed: 0xBA2C,
                events: vec![
                    ev(10, 50, Some(0), FaultKind::BankDown { lsb: true }),
                    ev(20, 40, Some(1), FaultKind::BankDown { lsb: false }),
                ],
            }),
            "crash_loop" => Some(Self {
                name: "crash_loop".into(),
                seed: 0xC2A5,
                events: vec![
                    // Windows outlast the dispatch round-robin cycle so the
                    // crash is always observed on the dispatch path (instant
                    // Down), not just by a canary.
                    ev(10, 16, Some(0), FaultKind::Crash),
                    ev(40, 46, Some(0), FaultKind::Crash),
                ],
            }),
            "latency_spike" => Some(Self {
                name: "latency_spike".into(),
                seed: 0x1A7E,
                events: vec![ev(10, 40, Some(1), FaultKind::LatencySpike { mult: 4.0 })],
            }),
            _ => None,
        }
    }

    /// Every built-in scenario token (CLI help + roundtrip tests).
    pub fn builtin_names() -> &'static [&'static str] {
        &["calm", "burst_ber", "retention_storm", "bank_takedown", "crash_loop", "latency_spike"]
    }

    /// Resolve a CLI `--faults`/`--scenario` spec: a built-in token first,
    /// else a path to a schedule JSON file.
    pub fn parse(spec: &str) -> crate::Result<Self> {
        if let Some(s) = Self::builtin(spec) {
            return Ok(s);
        }
        let path = std::path::Path::new(spec);
        if path.exists() {
            let text = std::fs::read_to_string(path)?;
            return Self::from_json(&Json::parse(&text).map_err(anyhow::Error::from)?);
        }
        anyhow::bail!(
            "unknown fault scenario {spec:?} (builtins: {}; or a path to a schedule JSON)",
            Self::builtin_names().join(", ")
        )
    }

    /// The fault layer's view of `engine` at `now`: every active event
    /// folded over the engine's base BER budget. Events compose — two
    /// escalations multiply, a bank takedown wins over anything milder on
    /// that bank (0.5 is the cap).
    pub fn effective(
        &self,
        engine: usize,
        now: Tick,
        base: BerConfig,
        tech: TechBase,
        glb_delta: f64,
        lsb_delta: f64,
    ) -> EffectiveFaults {
        let mut eff = EffectiveFaults::clean(base);
        for e in self.events.iter().filter(|e| e.active_at(engine, now)) {
            match e.kind {
                FaultKind::BerEscalation { factor } => {
                    eff.msb_ber = (eff.msb_ber * factor).min(0.5);
                    eff.lsb_ber = (eff.lsb_ber * factor).min(0.5);
                }
                FaultKind::RetentionStorm { derate } => {
                    eff.msb_ber = storm_ber(tech, glb_delta, eff.msb_ber, derate);
                    eff.lsb_ber = storm_ber(tech, lsb_delta, eff.lsb_ber, derate);
                }
                FaultKind::BankDown { lsb } => {
                    if lsb {
                        eff.lsb_ber = 0.5;
                    } else {
                        eff.msb_ber = 0.5;
                    }
                }
                FaultKind::LatencySpike { mult } => eff.latency_mult *= mult,
                FaultKind::Stall => eff.stalled = true,
                FaultKind::Crash => eff.crashed = true,
            }
        }
        eff
    }

    /// Serialize (durations as integer microseconds — exact on roundtrip).
    pub fn to_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("kind", Json::Str(e.kind.token().to_string())),
                    ("at_us", (e.at.as_micros() as u64).into()),
                    ("for_us", ((e.until - e.at).as_micros() as u64).into()),
                ];
                if let Some(idx) = e.engine {
                    fields.push(("engine", (idx as u64).into()));
                }
                match e.kind {
                    FaultKind::BerEscalation { factor } => {
                        fields.push(("factor", Json::Num(factor)));
                    }
                    FaultKind::RetentionStorm { derate } => {
                        fields.push(("derate", Json::Num(derate)));
                    }
                    FaultKind::BankDown { lsb } => fields.push(("lsb", lsb.into())),
                    FaultKind::LatencySpike { mult } => fields.push(("mult", Json::Num(mult))),
                    FaultKind::Stall | FaultKind::Crash => {}
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("seed", self.seed.into()),
            ("events", Json::Arr(events)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        use anyhow::Context;
        let name = j.req_str("name").map_err(anyhow::Error::from)?.to_string();
        let seed = j.req_u64("seed").map_err(anyhow::Error::from)?;
        let mut events = Vec::new();
        for e in j.req_arr("events").map_err(anyhow::Error::from)? {
            let at = Duration::from_micros(e.req_u64("at_us").map_err(anyhow::Error::from)?);
            let dur = Duration::from_micros(e.req_u64("for_us").map_err(anyhow::Error::from)?);
            let engine = match e.get("engine") {
                Some(v) => Some(v.as_u64().context("engine")? as usize),
                None => None,
            };
            let kind = match e.req_str("kind").map_err(anyhow::Error::from)? {
                "ber_escalation" => FaultKind::BerEscalation {
                    factor: e.req("factor").map_err(anyhow::Error::from)?.as_f64().context("factor")?,
                },
                "retention_storm" => FaultKind::RetentionStorm {
                    derate: e.req("derate").map_err(anyhow::Error::from)?.as_f64().context("derate")?,
                },
                "bank_down" => FaultKind::BankDown {
                    lsb: e.req("lsb").map_err(anyhow::Error::from)?.as_bool().context("lsb")?,
                },
                "latency_spike" => FaultKind::LatencySpike {
                    mult: e.req("mult").map_err(anyhow::Error::from)?.as_f64().context("mult")?,
                },
                "stall" => FaultKind::Stall,
                "crash" => FaultKind::Crash,
                other => anyhow::bail!("unknown fault kind {other:?}"),
            };
            if dur.is_zero() {
                anyhow::bail!("fault event {:?} at {}us has zero duration", kind.token(), at.as_micros());
            }
            events.push(FaultEvent { at, until: at + dur, engine, kind });
        }
        Ok(Self { name, seed, events })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GlbVariant;

    fn ultra() -> BerConfig {
        BerConfig::for_variant(GlbVariant::SttAiUltra)
    }

    #[test]
    fn event_windows_are_half_open_and_targeted() {
        let e = FaultEvent {
            at: Duration::from_millis(10),
            until: Duration::from_millis(20),
            engine: Some(1),
            kind: FaultKind::Stall,
        };
        let t = |ms: u64| Tick::ZERO + Duration::from_millis(ms);
        assert!(!e.active_at(1, t(9)), "before onset");
        assert!(e.active_at(1, t(10)), "inclusive start");
        assert!(e.active_at(1, t(19)));
        assert!(!e.active_at(1, t(20)), "exclusive end");
        assert!(!e.active_at(0, t(15)), "other engine untouched");
        let fleet = FaultEvent { engine: None, ..e };
        assert!(fleet.active_at(0, t(15)) && fleet.active_at(7, t(15)), "fleet-wide event");
    }

    #[test]
    fn escalation_multiplies_and_caps() {
        let s = FaultSchedule {
            name: "x".into(),
            seed: 1,
            events: vec![
                FaultEvent {
                    at: Duration::ZERO,
                    until: Duration::from_millis(1),
                    engine: None,
                    kind: FaultKind::BerEscalation { factor: 1.0e3 },
                },
                FaultEvent {
                    at: Duration::ZERO,
                    until: Duration::from_millis(1),
                    engine: None,
                    kind: FaultKind::BerEscalation { factor: 1.0e3 },
                },
            ],
        };
        let eff = s.effective(0, Tick::ZERO, ultra(), TechBase::Sakhare2020, 27.5, 17.5);
        // Two stacked 1e3 episodes: msb 1e-8 -> 1e-2, lsb 1e-5 -> 0.5 (cap).
        assert!((eff.msb_ber - 1.0e-2).abs() < 1e-12, "msb {}", eff.msb_ber);
        assert_eq!(eff.lsb_ber, 0.5, "lsb capped");
        assert!(!eff.stalled && !eff.crashed);
    }

    #[test]
    fn storm_ber_is_monotone_in_derate_and_caps() {
        let t = TechBase::Sakhare2020;
        let base = 1e-8;
        let b1 = storm_ber(t, 27.5, base, 1.0);
        let b2 = storm_ber(t, 27.5, base, 1.5);
        let b3 = storm_ber(t, 27.5, base, 4.0);
        assert!(b1 > base, "the designed-for corner already costs exp(gb margin): {b1}");
        assert!(b2 > b1 && b3 > b2, "harsher corners flip more: {b1} {b2} {b3}");
        assert!(b3 <= 0.5, "coin-flip cap");
        // derate below 1 clamps to the designed-for corner.
        assert_eq!(storm_ber(t, 27.5, base, 0.5), b1);
    }

    #[test]
    fn storm_leaves_volatile_banks_alone() {
        // SRAM (base BER 0) is immune to retention storms — the basis of
        // the supervisor's fallback reboot.
        assert_eq!(storm_ber(TechBase::Sram, 27.5, 0.0, 4.0), 0.0);
        assert_eq!(storm_ber(TechBase::Sakhare2020, 27.5, 0.0, 4.0), 0.0);
        let calm = FaultSchedule::builtin("retention_storm").unwrap();
        let sram = BerConfig::for_variant(GlbVariant::Sram);
        let eff = calm.effective(
            0,
            Tick::ZERO + Duration::from_millis(20),
            sram,
            TechBase::Sram,
            27.5,
            17.5,
        );
        assert_eq!((eff.msb_ber, eff.lsb_ber), (0.0, 0.0));
    }

    #[test]
    fn bank_down_pegs_one_bank() {
        let s = FaultSchedule::builtin("bank_takedown").unwrap();
        let mid = Tick::ZERO + Duration::from_millis(25);
        let e0 = s.effective(0, mid, ultra(), TechBase::Sakhare2020, 27.5, 17.5);
        assert_eq!(e0.lsb_ber, 0.5, "engine 0 loses the LSB bank");
        assert_eq!(e0.msb_ber, 1e-8, "MSB bank untouched");
        let e1 = s.effective(1, mid, ultra(), TechBase::Sakhare2020, 27.5, 17.5);
        assert_eq!(e1.msb_ber, 0.5, "engine 1 loses the MSB bank");
        assert_eq!(e1.lsb_ber, 1e-5);
    }

    #[test]
    fn builtins_roundtrip_through_json() {
        for name in FaultSchedule::builtin_names() {
            let s = FaultSchedule::builtin(name).unwrap();
            let text = s.to_json().to_string();
            let back = FaultSchedule::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, s, "{name} roundtrip");
            // Serialization itself is byte-stable.
            assert_eq!(back.to_json().to_string(), text, "{name} byte-stable");
        }
    }

    #[test]
    fn parse_rejects_unknown_scenarios_with_a_named_error() {
        let err = FaultSchedule::parse("no_such_scenario").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown fault scenario"), "{msg}");
        assert!(msg.contains("burst_ber"), "lists builtins: {msg}");
    }

    #[test]
    fn burst_ber_timeline_matches_the_golden_story() {
        let s = FaultSchedule::builtin("burst_ber").unwrap();
        assert_eq!(s.seed, 0xFA17);
        let at = |ms: u64| Tick::ZERO + Duration::from_millis(ms);
        let base = ultra();
        let eff = |eng: usize, t: Tick| {
            s.effective(eng, t, base, TechBase::Sakhare2020, 27.5, 17.5)
        };
        // t=5ms: everyone clean.
        for e in 0..3 {
            assert_eq!(eff(e, at(5)), EffectiveFaults::clean(base));
        }
        // t=20ms: engine 0 in the storm, others clean.
        assert!(eff(0, at(20)).msb_ber > base.msb_ber);
        assert_eq!(eff(1, at(20)), EffectiveFaults::clean(base));
        // t=37ms: engine 2 stalled (the retry/reroute driver).
        assert!(eff(2, at(37)).stalled);
        assert!(!eff(2, at(42)).stalled, "stall window closed");
        // t=80ms: storm over everywhere.
        for e in 0..3 {
            assert_eq!(eff(e, at(80)), EffectiveFaults::clean(base));
        }
    }
}
