//! The inference engine: PJRT executables + weight image + fault model.

use std::path::Path;

use crate::ber::{BankSplit, Injector, WordKind};
use crate::config::{BerConfig, GlbVariant};
use crate::runtime::{ArtifactManifest, LoadedModel, Runtime, Weights};

/// Engine construction options.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// GLB variant (bank structure label; the fault model itself lives in
    /// `ber` so a sweep-selected point can carry a custom budget).
    pub variant: GlbVariant,
    /// The BER fault model applied to buffered data.
    pub ber: BerConfig,
    /// Magnitude-pruning rate applied to weights before injection (Fig. 21
    /// evaluates 0.0 and 0.5).
    pub prune_rate: f64,
    /// Injection seed (reproducible fault patterns).
    pub seed: u64,
    /// Also corrupt input activations (ifmaps live in the same GLB banks as
    /// weights; the paper's fault model covers "weight/fmap bits").
    pub inject_activations: bool,
}

impl EngineConfig {
    pub fn new(variant: GlbVariant) -> Self {
        let ber = BerConfig::for_variant(variant);
        Self { variant, ber, prune_rate: 0.0, seed: ber.seed, inject_activations: false }
    }

    /// Boot from a sweep-selected design point (`stt-ai serve
    /// --from-selection`): the variant structure and BER budget both come
    /// from the selection record instead of a hard-coded paper config.
    pub fn from_selection(sel: &crate::dse::select::DesignSelection) -> Self {
        let ber = sel.ber_config();
        Self {
            variant: sel.variant(),
            ber,
            prune_rate: 0.0,
            seed: ber.seed,
            inject_activations: false,
        }
    }

    /// Replace the BER fault model (keeps the variant label).
    pub fn with_ber(mut self, ber: BerConfig) -> Self {
        self.ber = ber;
        self
    }

    pub fn with_activation_faults(mut self) -> Self {
        self.inject_activations = true;
        self
    }

    pub fn with_prune(mut self, rate: f64) -> Self {
        self.prune_rate = rate;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// The engine. Weights are stored twice: `clean` (as trained) and `served`
/// (pruned + BER-injected — the image the STT-MRAM GLB actually holds).
pub struct Engine {
    pub runtime: Runtime,
    pub manifest: ArtifactManifest,
    pub config: EngineConfig,
    clean: Weights,
    served: Weights,
    /// Total bit flips injected into the served weight image.
    pub flips: u64,
    /// Per-call counter for activation-fault seeding.
    act_calls: std::sync::atomic::AtomicU64,
}

impl Engine {
    /// Load artifacts and prepare the served weight image.
    pub fn load(artifacts_dir: &Path, config: EngineConfig) -> crate::Result<Self> {
        let runtime = Runtime::cpu()?;
        let manifest = ArtifactManifest::load(artifacts_dir)?;
        let clean = manifest.load_weights()?;
        let mut engine = Self {
            runtime,
            manifest,
            config,
            served: clean.clone(),
            clean,
            flips: 0,
            act_calls: std::sync::atomic::AtomicU64::new(0),
        };
        engine.rebuild_served();
        Ok(engine)
    }

    /// Rebuild the served weight image: clean → prune → BER injection.
    ///
    /// The fault model mirrors the physical design: weights live in the GLB
    /// as bf16 words split across the MSB/LSB banks, so we corrupt the bf16
    /// image and convert back to the f32 the executable consumes (the
    /// executable itself computes in f32 on CPU; bf16 rounding is part of
    /// the fault model, applied identically to all variants).
    pub fn rebuild_served(&mut self) {
        let mut w = self.clean.data.clone();
        if self.config.prune_rate > 0.0 {
            crate::ber::magnitude_prune_f32(&mut w, self.config.prune_rate);
        }
        // f32 → bf16 image (what the buffer stores).
        let mut image: Vec<u8> = Vec::with_capacity(w.len() * 2);
        for v in &w {
            image.extend_from_slice(&crate::util::bf16::f32_to_bf16(*v).to_le_bytes());
        }
        let ber = self.config.ber;
        let split = BankSplit { kind: WordKind::Bf16, msb_ber: ber.msb_ber, lsb_ber: ber.lsb_ber };
        let mut inj = Injector::new(self.config.seed);
        let stats = split.inject(&mut inj, &mut image);
        self.flips = stats.bits_flipped;
        // bf16 image → f32 served weights.
        let served: Vec<f32> = image
            .chunks_exact(2)
            .map(|c| crate::util::bf16::bf16_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect();
        self.served = Weights { data: served };
    }

    /// The weight image the executables run with.
    pub fn served_weights(&self) -> &Weights {
        &self.served
    }

    /// Load the executable variant for a batch size.
    pub fn model_for_batch(&self, batch: usize) -> crate::Result<LoadedModel> {
        let (_, art) = self.manifest.model_for_batch(batch)?;
        self.runtime.load_model(&self.manifest.dir, art)
    }

    /// Run one batch of images through the served model; returns logits.
    /// With `inject_activations`, the ifmap passes through the same
    /// bf16-image + bank-split fault model as the weights (fresh pattern
    /// per call, seeded from the engine seed + a call counter).
    pub fn infer(&self, model: &LoadedModel, images: &[f32]) -> crate::Result<Vec<f32>> {
        if !self.config.inject_activations {
            return model.infer(&self.served, images);
        }
        let corrupted = self.corrupt_activations(images);
        model.infer(&self.served, &corrupted)
    }

    /// Apply the GLB fault model to an activation buffer.
    pub fn corrupt_activations(&self, images: &[f32]) -> Vec<f32> {
        use crate::util::bf16::{bf16_to_f32, f32_to_bf16};
        let mut image: Vec<u8> = Vec::with_capacity(images.len() * 2);
        for v in images {
            image.extend_from_slice(&f32_to_bf16(*v).to_le_bytes());
        }
        let ber = self.config.ber;
        let split = BankSplit { kind: WordKind::Bf16, msb_ber: ber.msb_ber, lsb_ber: ber.lsb_ber };
        let n = self.act_calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut inj = Injector::new(self.config.seed ^ (0xAC7 << 32) ^ n);
        split.inject(&mut inj, &mut image);
        image
            .chunks_exact(2)
            .map(|c| bf16_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect()
    }

    /// Reseed and rebuild (fresh fault pattern — used by the Fig. 21 bench
    /// to average over injection draws).
    pub fn reseed(&mut self, seed: u64) {
        self.config.seed = seed;
        self.rebuild_served();
    }

    /// Swap the BER fault model in place and rebuild the served image —
    /// how the fault-injection supervisor applies a scheduled BER episode
    /// to a live engine without reloading artifacts.
    pub fn set_ber(&mut self, ber: BerConfig) {
        if self.config.ber.msb_ber == ber.msb_ber
            && self.config.ber.lsb_ber == ber.lsb_ber
            && self.config.ber.seed == ber.seed
        {
            return;
        }
        self.config.ber = ber;
        self.rebuild_served();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_builders() {
        let c = EngineConfig::new(GlbVariant::SttAiUltra)
            .with_prune(0.5)
            .with_seed(99)
            .with_activation_faults();
        assert_eq!(c.prune_rate, 0.5);
        assert_eq!(c.seed, 99);
        assert!(c.inject_activations);
        // `new` carries the paper budget for the variant...
        assert_eq!((c.ber.msb_ber, c.ber.lsb_ber), (1e-8, 1e-5));
        // ...and `with_ber` replaces it without touching the label.
        let custom = BerConfig { msb_ber: 1e-7, lsb_ber: 1e-4, seed: 7 };
        let c = EngineConfig::new(GlbVariant::SttAiUltra).with_ber(custom);
        assert_eq!(c.ber.msb_ber, 1e-7);
        assert_eq!(c.variant, GlbVariant::SttAiUltra);
    }

    #[test]
    fn engine_config_boots_from_a_selection_record() {
        use crate::dse::engine::DesignPoint;
        use crate::dse::select::{DesignSelection, Objective};
        let sel = DesignSelection {
            sweep: "selection".into(),
            objective: Objective::MinArea,
            constraints: vec![],
            latency_model: crate::dse::select::LATENCY_MODEL.into(),
            point: DesignPoint {
                variant: Some(GlbVariant::SttAi),
                ber: Some(1e-6),
                ..Default::default()
            },
            metrics: vec![],
            score: 0.0,
            candidates: 1,
            feasible: 1,
            frontier: 1,
        };
        let c = EngineConfig::from_selection(&sel);
        assert_eq!(c.variant, GlbVariant::SttAi);
        assert_eq!((c.ber.msb_ber, c.ber.lsb_ber), (1e-6, 1e-6));
        // A point that never varied the variant boots the paper's serving
        // default (Ultra) rather than panicking.
        let sparse = DesignSelection { point: DesignPoint::default(), ..sel };
        let c = EngineConfig::from_selection(&sparse);
        assert_eq!(c.variant, GlbVariant::SttAiUltra);
        assert_eq!((c.ber.msb_ber, c.ber.lsb_ber), (1e-8, 1e-5));
    }

    // Engine::load tests require built artifacts; see rust/tests/e2e.rs.
}
