//! Multi-tenant SLO-class DSL for the serving stack.
//!
//! A [`TenantSpec`] names one workload class sharing the fleet: its SLO
//! tier (which routing island it prefers), an optional per-class sojourn
//! target, a weighted-deficit-round-robin admission weight, an optional
//! per-tenant [`ArrivalTrace`], and an optional accuracy floor that keeps
//! the class off relaxed-BER approximate-memory shards. A [`TenantMix`] is
//! the named set of tenants one fleet run serves.
//!
//! Like the fault and traffic DSLs, mixes come from three places sharing
//! one grammar: built-in tokens ([`TenantMix::builtin`] — `default`,
//! `two_tier`, `three_class`), JSON files ([`TenantMix::parse`] falls back
//! to a path; the committed golden lives at
//! `rust/golden/fleet_tenants.mix.json`), and the `[tenants]` section of a
//! [`crate::config::SystemConfig`].
//!
//! The degenerate [`TenantMix::single_default`] — one `standard` tenant of
//! weight 1 inheriting the run's trace and the fleet SLO — is the
//! migration golden: a fleet run under it is byte-identical to the
//! pre-tenant serving stack.

use std::time::Duration;

use crate::util::json::Json;

use super::traffic::ArrivalTrace;

/// The scheduling class of a tenant: which island the class-aware router
/// prefers for it.
///
/// * `tight` — latency-critical: routed to the fastest-service island (the
///   SRAM shards of a hetero fleet), where faster buffers earn their area.
/// * `standard` — no preference: least-outstanding over the whole fleet,
///   exactly the classless router.
/// * `relaxed` — throughput/efficiency: routed to the lowest
///   energy-per-request island (the STT-AI Ultra shards), where the
///   paper's 75.4 % area / 3.5 % power savings accumulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloTier {
    Tight,
    Standard,
    Relaxed,
}

impl SloTier {
    /// Stable serialization token.
    pub fn token(&self) -> &'static str {
        match self {
            SloTier::Tight => "tight",
            SloTier::Standard => "standard",
            SloTier::Relaxed => "relaxed",
        }
    }

    pub fn from_token(s: &str) -> Option<Self> {
        match s {
            "tight" => Some(SloTier::Tight),
            "standard" => Some(SloTier::Standard),
            "relaxed" => Some(SloTier::Relaxed),
            _ => None,
        }
    }
}

/// One workload class sharing the fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    pub tier: SloTier,
    /// Per-class sojourn target; `None` inherits the fleet policy SLO.
    pub slo: Option<Duration>,
    /// Weighted-deficit-round-robin quantum at batch admission: rows this
    /// class may contribute per service round while backlogged (≥ 1, so a
    /// configured tenant can never starve).
    pub weight: u64,
    /// Per-tenant arrival trace; `None` inherits the run's trace.
    pub trace: Option<ArrivalTrace>,
    /// Minimum estimated engine accuracy this class tolerates: shards
    /// whose [`super::EngineSpec::est_accuracy`] falls below it are
    /// excluded from routing (approximate-memory tolerance is
    /// workload-dependent). `None` accepts every shard.
    pub accuracy_floor: Option<f64>,
}

impl TenantSpec {
    /// A standard-tier, weight-1 tenant inheriting the run's trace and the
    /// fleet SLO.
    pub fn standard(name: &str) -> Self {
        Self {
            name: name.to_string(),
            tier: SloTier::Standard,
            slo: None,
            weight: 1,
            trace: None,
            accuracy_floor: None,
        }
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("tier", Json::Str(self.tier.token().to_string())),
            ("weight", self.weight.into()),
        ];
        if let Some(slo) = self.slo {
            fields.push(("slo_us", (slo.as_micros() as u64).into()));
        }
        if let Some(t) = &self.trace {
            fields.push(("trace", t.to_json()));
        }
        if let Some(f) = self.accuracy_floor {
            fields.push(("accuracy_floor", Json::Num(f)));
        }
        Json::obj(fields)
    }

    fn from_json(j: &Json) -> crate::Result<Self> {
        let name = j.req_str("name").map_err(anyhow::Error::from)?.to_string();
        if name.is_empty() {
            anyhow::bail!("tenant names must be non-empty");
        }
        let tier_token = j.req_str("tier").map_err(anyhow::Error::from)?;
        let tier = SloTier::from_token(tier_token).ok_or_else(|| {
            anyhow::anyhow!("tenant {name:?}: unknown tier {tier_token:?} (tight, standard, relaxed)")
        })?;
        let weight = match j.get("weight") {
            Some(w) => w
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("tenant {name:?}: weight must be a u64"))?,
            None => 1,
        };
        if weight == 0 {
            anyhow::bail!("tenant {name:?}: weight must be >= 1 (zero weight starves the class)");
        }
        let slo = match j.get("slo_us") {
            Some(v) => {
                let us = v
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("tenant {name:?}: slo_us must be a u64"))?;
                if us == 0 {
                    anyhow::bail!("tenant {name:?}: slo_us must be positive");
                }
                Some(Duration::from_micros(us))
            }
            None => None,
        };
        let trace = match j.get("trace") {
            Some(t) => Some(ArrivalTrace::from_json(t)?),
            None => None,
        };
        let accuracy_floor = match j.get("accuracy_floor") {
            Some(v) => {
                let f = v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("tenant {name:?}: accuracy_floor not a number"))?;
                if !(f.is_finite() && f > 0.0 && f <= 1.0) {
                    anyhow::bail!("tenant {name:?}: accuracy_floor must be in (0, 1], got {f}");
                }
                Some(f)
            }
            None => None,
        };
        Ok(Self { name, tier, slo, weight, trace, accuracy_floor })
    }
}

/// A named set of tenants sharing one fleet run.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantMix {
    pub name: String,
    pub tenants: Vec<TenantSpec>,
}

impl TenantMix {
    /// The migration golden: one standard tenant, weight 1, inheriting the
    /// run's trace and the fleet SLO. A fleet run under this mix is
    /// byte-identical to the pre-tenant serving stack.
    pub fn single_default() -> Self {
        Self { name: "default".into(), tenants: vec![TenantSpec::standard("default")] }
    }

    /// Is this the degenerate single-tenant mix? Tenant-aware scheduling
    /// and per-tenant report sections only switch on when it is not.
    pub fn is_default(&self) -> bool {
        *self == Self::single_default()
    }

    /// Built-in mixes by token; `None` for unknown names.
    ///
    /// Rates are sized against the paper SRAM+Ultra pair (SRAM ≈ 22.9 k
    /// req/s at 700 µs service, Ultra ≈ 16 k req/s at 1 ms): `two_tier`
    /// offers a 4 k req/s tight 2 ms class next to a bursty relaxed 50 ms
    /// class averaging ≈ 13.3 k req/s — each island alone carries its
    /// class, which is the hetero payoff gate in `tests/tenants.rs` —
    /// and `three_class` adds a standard class plus an accuracy floor that
    /// keeps the tight class off relaxed-BER Ultra shards.
    pub fn builtin(name: &str) -> Option<Self> {
        let ms = Duration::from_millis;
        match name {
            "default" => Some(Self::single_default()),
            "two_tier" => Some(Self {
                name: "two_tier".into(),
                tenants: vec![
                    TenantSpec {
                        name: "tight".into(),
                        tier: SloTier::Tight,
                        slo: Some(ms(2)),
                        weight: 4,
                        trace: Some(ArrivalTrace {
                            name: "two_tier.tight".into(),
                            seed: 0x7167,
                            pattern: super::traffic::TracePattern::Poisson { rate_rps: 4_000.0 },
                        }),
                        accuracy_floor: None,
                    },
                    TenantSpec {
                        name: "relaxed".into(),
                        tier: SloTier::Relaxed,
                        slo: Some(ms(50)),
                        weight: 1,
                        trace: Some(ArrivalTrace {
                            name: "two_tier.relaxed".into(),
                            seed: 0x5E1A,
                            pattern: super::traffic::TracePattern::Bursty {
                                calm_rps: 8_000.0,
                                burst_rps: 24_000.0,
                                calm_dwell: ms(20),
                                burst_dwell: ms(10),
                            },
                        }),
                        accuracy_floor: None,
                    },
                ],
            }),
            "three_class" => Some(Self {
                name: "three_class".into(),
                tenants: vec![
                    TenantSpec {
                        name: "tight".into(),
                        tier: SloTier::Tight,
                        slo: Some(ms(2)),
                        weight: 4,
                        trace: Some(ArrivalTrace {
                            name: "three_class.tight".into(),
                            seed: 0x3C01,
                            pattern: super::traffic::TracePattern::Poisson { rate_rps: 3_000.0 },
                        }),
                        accuracy_floor: Some(0.999),
                    },
                    TenantSpec {
                        name: "standard".into(),
                        tier: SloTier::Standard,
                        slo: None,
                        weight: 2,
                        trace: Some(ArrivalTrace {
                            name: "three_class.standard".into(),
                            seed: 0x3C02,
                            pattern: super::traffic::TracePattern::Poisson { rate_rps: 6_000.0 },
                        }),
                        accuracy_floor: None,
                    },
                    TenantSpec {
                        name: "relaxed".into(),
                        tier: SloTier::Relaxed,
                        slo: Some(ms(50)),
                        weight: 1,
                        trace: Some(ArrivalTrace {
                            name: "three_class.relaxed".into(),
                            seed: 0x3C03,
                            pattern: super::traffic::TracePattern::Poisson { rate_rps: 6_000.0 },
                        }),
                        accuracy_floor: None,
                    },
                ],
            }),
            _ => None,
        }
    }

    /// Every built-in mix token (CLI help + roundtrip tests).
    pub fn builtin_names() -> &'static [&'static str] {
        &["default", "two_tier", "three_class"]
    }

    /// Resolve a CLI `--tenants` spec: a built-in token first, else a path
    /// to a mix JSON file.
    pub fn parse(spec: &str) -> crate::Result<Self> {
        if let Some(m) = Self::builtin(spec) {
            return Ok(m);
        }
        let path = std::path::Path::new(spec);
        if path.exists() {
            let text = std::fs::read_to_string(path)?;
            return Self::from_json(&Json::parse(&text).map_err(anyhow::Error::from)?);
        }
        anyhow::bail!(
            "unknown tenant mix {spec:?} (builtins: {}; or a path to a mix JSON)",
            Self::builtin_names().join(", ")
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("tenants", Json::Arr(self.tenants.iter().map(TenantSpec::to_json).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let name = j.req_str("name").map_err(anyhow::Error::from)?.to_string();
        let rows = j.req_arr("tenants").map_err(anyhow::Error::from)?;
        if rows.is_empty() {
            anyhow::bail!("tenant mix {name:?}: needs at least one tenant");
        }
        let tenants =
            rows.iter().map(TenantSpec::from_json).collect::<crate::Result<Vec<_>>>()?;
        for (i, t) in tenants.iter().enumerate() {
            if tenants[..i].iter().any(|o| o.name == t.name) {
                anyhow::bail!("tenant mix {name:?}: duplicate tenant name {:?}", t.name);
            }
        }
        Ok(Self { name, tenants })
    }

    /// Per-class DRR weights, in tenant order.
    pub fn weights(&self) -> Vec<u64> {
        self.tenants.iter().map(|t| t.weight).collect()
    }

    /// Tenant `i`'s sojourn target, inheriting `fleet_slo` when unset.
    pub fn effective_slo(&self, i: usize, fleet_slo: Duration) -> Duration {
        self.tenants.get(i).and_then(|t| t.slo).unwrap_or(fleet_slo)
    }

    /// The tightest sojourn target across the mix — what the class-aware
    /// autoscaler holds the best shard projection against.
    pub fn tightest_slo(&self, fleet_slo: Duration) -> Duration {
        (0..self.tenants.len())
            .map(|i| self.effective_slo(i, fleet_slo))
            .min()
            .unwrap_or(fleet_slo)
    }
}

impl Default for TenantMix {
    fn default() -> Self {
        Self::single_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_mix_is_the_degenerate_single_tenant() {
        let m = TenantMix::single_default();
        assert!(m.is_default());
        assert_eq!(m.tenants.len(), 1);
        assert_eq!(m.tenants[0].tier, SloTier::Standard);
        assert_eq!(m.tenants[0].weight, 1);
        assert!(m.tenants[0].slo.is_none() && m.tenants[0].trace.is_none());
        assert!(!TenantMix::builtin("two_tier").unwrap().is_default());
    }

    #[test]
    fn builtins_roundtrip_through_json() {
        for name in TenantMix::builtin_names() {
            let m = TenantMix::builtin(name).unwrap();
            let text = m.to_json().to_string();
            let back = TenantMix::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, m, "{name} roundtrip");
            assert_eq!(back.to_json().to_string(), text, "{name} byte-stable");
        }
    }

    #[test]
    fn parse_rejects_unknown_mixes_with_a_named_error() {
        let err = TenantMix::parse("no_such_mix").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown tenant mix"), "{msg}");
        assert!(msg.contains("two_tier"), "lists builtins: {msg}");
    }

    #[test]
    fn from_json_rejects_degenerate_mixes() {
        let bad = r#"{"name":"x","tenants":[]}"#;
        assert!(TenantMix::from_json(&Json::parse(bad).unwrap()).is_err(), "empty mix");
        let bad = r#"{"name":"x","tenants":[{"name":"a","tier":"tight","weight":0}]}"#;
        assert!(TenantMix::from_json(&Json::parse(bad).unwrap()).is_err(), "zero weight");
        let bad = r#"{"name":"x","tenants":[{"name":"a","tier":"warp","weight":1}]}"#;
        assert!(TenantMix::from_json(&Json::parse(bad).unwrap()).is_err(), "unknown tier");
        let bad = r#"{"name":"x","tenants":[
            {"name":"a","tier":"tight","weight":1},
            {"name":"a","tier":"relaxed","weight":1}]}"#;
        assert!(TenantMix::from_json(&Json::parse(bad).unwrap()).is_err(), "duplicate name");
        let bad = r#"{"name":"x","tenants":[{"name":"a","tier":"tight","weight":1,"slo_us":0}]}"#;
        assert!(TenantMix::from_json(&Json::parse(bad).unwrap()).is_err(), "zero slo");
        let bad =
            r#"{"name":"x","tenants":[{"name":"a","tier":"tight","weight":1,"accuracy_floor":1.5}]}"#;
        assert!(TenantMix::from_json(&Json::parse(bad).unwrap()).is_err(), "floor > 1");
    }

    #[test]
    fn missing_weight_defaults_to_one() {
        let j = Json::parse(r#"{"name":"x","tenants":[{"name":"a","tier":"standard"}]}"#).unwrap();
        let m = TenantMix::from_json(&j).unwrap();
        assert_eq!(m.tenants[0].weight, 1);
    }

    #[test]
    fn effective_and_tightest_slos_inherit_the_fleet_target() {
        let fleet = Duration::from_millis(10);
        let m = TenantMix::builtin("three_class").unwrap();
        assert_eq!(m.effective_slo(0, fleet), Duration::from_millis(2));
        assert_eq!(m.effective_slo(1, fleet), fleet, "unset slo inherits the fleet target");
        assert_eq!(m.tightest_slo(fleet), Duration::from_millis(2));
        assert_eq!(TenantMix::single_default().tightest_slo(fleet), fleet);
    }

    #[test]
    fn tier_tokens_roundtrip() {
        for tier in [SloTier::Tight, SloTier::Standard, SloTier::Relaxed] {
            assert_eq!(SloTier::from_token(tier.token()), Some(tier));
        }
        assert_eq!(SloTier::from_token("bogus"), None);
    }
}
