//! Fig. 21-style accuracy evaluation: Top-1/Top-5 of the three GLB variants
//! on the held-out test set, with and without 50% pruning.

use std::path::Path;


use super::engine::{Engine, EngineConfig};
use crate::config::GlbVariant;

/// Accuracy of one (variant, prune) cell.
#[derive(Debug, Clone)]
pub struct AccuracyReport {
    pub variant: String,
    pub prune_rate: f64,
    pub n: usize,
    pub top1: f64,
    pub top5: f64,
    pub bit_flips: u64,
}

/// One row of the Fig. 21 comparison (all variants at one prune rate).
#[derive(Debug, Clone)]
pub struct Fig21Row {
    pub prune_rate: f64,
    pub baseline: AccuracyReport,
    pub stt_ai: AccuracyReport,
    pub stt_ai_ultra: AccuracyReport,
}

impl Fig21Row {
    /// Normalized Top-1 accuracy drop of Ultra vs baseline (paper: <1%).
    pub fn ultra_drop_normalized(&self) -> f64 {
        if self.baseline.top1 <= 0.0 {
            return 0.0;
        }
        (self.baseline.top1 - self.stt_ai_ultra.top1) / self.baseline.top1
    }
}

/// Evaluate one engine over the artifact test set.
pub fn evaluate(engine: &Engine, batch: usize, limit: Option<usize>) -> crate::Result<AccuracyReport> {
    let model = engine.model_for_batch(batch)?;
    let (images, labels) = engine.manifest.load_testset()?;
    let per_image: usize =
        engine.manifest.testset.image_shape.iter().product::<i64>() as usize;
    let n = limit.unwrap_or(engine.manifest.testset.n).min(engine.manifest.testset.n);
    let mut top1 = 0usize;
    let mut top5 = 0usize;
    let mut seen = 0usize;
    let mut i = 0usize;
    while i + batch <= n {
        let chunk = &images[i * per_image..(i + batch) * per_image];
        let logits = engine.infer(&model, chunk)?;
        let preds = model.predictions(&logits);
        let tops = model.top_k(&logits, 5);
        for (j, (&p, t)) in preds.iter().zip(&tops).enumerate() {
            let label = labels[i + j] as usize;
            if p == label {
                top1 += 1;
            }
            if t.contains(&label) {
                top5 += 1;
            }
            seen += 1;
        }
        i += batch;
    }
    Ok(AccuracyReport {
        variant: format!("{:?}", engine.config.variant),
        prune_rate: engine.config.prune_rate,
        n: seen,
        top1: top1 as f64 / seen.max(1) as f64,
        top5: top5 as f64 / seen.max(1) as f64,
        bit_flips: engine.flips,
    })
}

/// Run the full Fig. 21 grid for one prune rate.
pub fn fig21_row(
    artifacts: &Path,
    prune_rate: f64,
    batch: usize,
    limit: Option<usize>,
) -> crate::Result<Fig21Row> {
    let run = |variant: GlbVariant| -> crate::Result<AccuracyReport> {
        let engine = Engine::load(artifacts, EngineConfig::new(variant).with_prune(prune_rate))?;
        evaluate(&engine, batch, limit)
    };
    Ok(Fig21Row {
        prune_rate,
        baseline: run(GlbVariant::Sram)?,
        stt_ai: run(GlbVariant::SttAi)?,
        stt_ai_ultra: run(GlbVariant::SttAiUltra)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ultra_drop_handles_degenerate_baseline() {
        let rep = AccuracyReport {
            variant: "x".into(),
            prune_rate: 0.0,
            n: 0,
            top1: 0.0,
            top5: 0.0,
            bit_flips: 0,
        };
        let row = Fig21Row {
            prune_rate: 0.0,
            baseline: rep.clone(),
            stt_ai: rep.clone(),
            stt_ai_ultra: rep,
        };
        assert_eq!(row.ultra_drop_normalized(), 0.0);
    }
}
