//! Serving metrics: latency histogram, throughput, batch-occupancy.

use std::time::{Duration, Instant};

use crate::util::stats::LatencyHistogram;

/// Aggregated serving metrics.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub latency: LatencyHistogram,
    pub batches: u64,
    pub requests: u64,
    pub padded_rows: u64,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            latency: LatencyHistogram::new(),
            batches: 0,
            requests: 0,
            padded_rows: 0,
            started: Instant::now(),
        }
    }

    /// Record one executed batch.
    pub fn record_batch(&mut self, real: usize, capacity: usize, latency: Duration) {
        self.batches += 1;
        self.requests += real as u64;
        self.padded_rows += (capacity - real) as u64;
        self.latency.record_us(latency.as_micros() as u64);
    }

    /// Requests per second since construction.
    pub fn throughput(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.requests as f64 / secs
        }
    }

    /// Mean batch occupancy (real rows / capacity rows).
    pub fn occupancy(&self) -> f64 {
        let total = self.requests + self.padded_rows;
        if total == 0 {
            0.0
        } else {
            self.requests as f64 / total as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "batches={} requests={} occupancy={:.1}% p50={}us p99={}us max={}us mean={:.0}us",
            self.batches,
            self.requests,
            self.occupancy() * 100.0,
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(99.0),
            self.latency.max_us(),
            self.latency.mean_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::new();
        m.record_batch(4, 4, Duration::from_micros(100));
        m.record_batch(2, 4, Duration::from_micros(300));
        assert_eq!(m.batches, 2);
        assert_eq!(m.requests, 6);
        assert_eq!(m.padded_rows, 2);
        assert!((m.occupancy() - 0.75).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("batches=2"));
    }

    #[test]
    fn throughput_nonzero_after_requests() {
        let mut m = Metrics::new();
        m.record_batch(8, 8, Duration::from_micros(50));
        std::thread::sleep(Duration::from_millis(2));
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.latency.percentile_us(99.0), 0);
    }
}
