//! Serving metrics: latency histogram, throughput, batch-occupancy.

use std::time::{Duration, Instant};

use crate::util::stats::LatencyHistogram;

/// Aggregated serving metrics.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub latency: LatencyHistogram,
    pub batches: u64,
    pub requests: u64,
    pub padded_rows: u64,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            latency: LatencyHistogram::new(),
            batches: 0,
            requests: 0,
            padded_rows: 0,
            started: Instant::now(),
        }
    }

    /// Record one executed batch.
    pub fn record_batch(&mut self, real: usize, capacity: usize, latency: Duration) {
        self.batches += 1;
        self.requests += real as u64;
        self.padded_rows += (capacity - real) as u64;
        self.latency.record_us(latency.as_micros() as u64);
    }

    /// Requests per second since construction.
    pub fn throughput(&self) -> f64 {
        self.throughput_after(self.started.elapsed())
    }

    /// Requests per second over an injected elapsed time — the deterministic
    /// core of [`Metrics::throughput`], also used by tests so they need not
    /// sleep on the wall clock.
    pub fn throughput_after(&self, elapsed: Duration) -> f64 {
        let secs = elapsed.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.requests as f64 / secs
        }
    }

    /// Mean batch occupancy (real rows / capacity rows).
    pub fn occupancy(&self) -> f64 {
        let total = self.requests + self.padded_rows;
        if total == 0 {
            0.0
        } else {
            self.requests as f64 / total as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "batches={} requests={} occupancy={:.1}% p50={}us p99={}us max={}us mean={:.0}us",
            self.batches,
            self.requests,
            self.occupancy() * 100.0,
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(99.0),
            self.latency.max_us(),
            self.latency.mean_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::new();
        m.record_batch(4, 4, Duration::from_micros(100));
        m.record_batch(2, 4, Duration::from_micros(300));
        assert_eq!(m.batches, 2);
        assert_eq!(m.requests, 6);
        assert_eq!(m.padded_rows, 2);
        assert!((m.occupancy() - 0.75).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("batches=2"));
    }

    #[test]
    fn throughput_deterministic_with_injected_elapsed() {
        // No wall-clock sleep: inject the elapsed time instead (the old
        // sleep(2ms)-based assertion was flaky under loaded CI runners).
        let mut m = Metrics::new();
        m.record_batch(8, 8, Duration::from_micros(50));
        assert_eq!(m.throughput_after(Duration::from_secs(2)), 4.0);
        assert_eq!(m.throughput_after(Duration::from_millis(500)), 16.0);
        // Zero elapsed stays defined.
        assert_eq!(m.throughput_after(Duration::ZERO), 0.0);
        // And the wall-clock path is monotone-safe: elapsed > 0 from here.
        assert!(m.throughput() >= 0.0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.latency.percentile_us(99.0), 0);
    }
}
