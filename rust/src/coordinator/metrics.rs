//! Serving metrics: latency histogram, queueing delay, throughput,
//! batch-occupancy.

use std::time::{Duration, Instant};

use crate::util::stats::LatencyHistogram;

/// Aggregated serving metrics.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub latency: LatencyHistogram,
    /// Queueing delay of the oldest request in each executed batch (how
    /// long the batching window actually held traffic back).
    pub queue_wait: LatencyHistogram,
    pub batches: u64,
    pub requests: u64,
    pub padded_rows: u64,
    /// Anchored at the *first executed batch*, not construction — model
    /// load and idle warm-up time must not dilute the throughput figure.
    started: Option<Instant>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            latency: LatencyHistogram::new(),
            queue_wait: LatencyHistogram::new(),
            batches: 0,
            requests: 0,
            padded_rows: 0,
            started: None,
        }
    }

    /// Record one executed batch (no queueing-delay information).
    pub fn record_batch(&mut self, real: usize, capacity: usize, latency: Duration) {
        self.record_batch_waited(real, capacity, latency, Duration::ZERO);
    }

    /// Record one executed batch plus the queueing delay of its oldest
    /// request ([`crate::coordinator::Batch::oldest_wait`]).
    pub fn record_batch_waited(
        &mut self,
        real: usize,
        capacity: usize,
        latency: Duration,
        queue_wait: Duration,
    ) {
        if self.started.is_none() {
            // Anchor at the *start* of the first executed batch (records
            // arrive after inference, so back-date by its latency): the
            // interval includes every batch's service time but none of the
            // model-load/idle time before the first request.
            let now = Instant::now();
            self.started = Some(now.checked_sub(latency).unwrap_or(now));
        }
        self.batches += 1;
        self.requests += real as u64;
        self.padded_rows += (capacity - real) as u64;
        self.latency.record_us(latency.as_micros() as u64);
        self.queue_wait.record_us(queue_wait.as_micros() as u64);
    }

    /// Requests per second since the first recorded batch (0 before any
    /// batch has executed — there is no serving interval to measure yet).
    pub fn throughput(&self) -> f64 {
        match self.started {
            Some(t0) => self.throughput_after(t0.elapsed()),
            None => 0.0,
        }
    }

    /// Requests per second over an injected elapsed time — the deterministic
    /// core of [`Metrics::throughput`], also used by tests so they need not
    /// sleep on the wall clock.
    pub fn throughput_after(&self, elapsed: Duration) -> f64 {
        let secs = elapsed.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.requests as f64 / secs
        }
    }

    /// Mean batch occupancy (real rows / capacity rows).
    pub fn occupancy(&self) -> f64 {
        let total = self.requests + self.padded_rows;
        if total == 0 {
            0.0
        } else {
            self.requests as f64 / total as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "batches={} requests={} occupancy={:.1}% p50={}us p99={}us max={}us mean={:.0}us qwait-p50={}us qwait-max={}us",
            self.batches,
            self.requests,
            self.occupancy() * 100.0,
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(99.0),
            self.latency.max_us(),
            self.latency.mean_us(),
            self.queue_wait.percentile_us(50.0),
            self.queue_wait.max_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::new();
        m.record_batch(4, 4, Duration::from_micros(100));
        m.record_batch_waited(2, 4, Duration::from_micros(300), Duration::from_micros(40));
        assert_eq!(m.batches, 2);
        assert_eq!(m.requests, 6);
        assert_eq!(m.padded_rows, 2);
        assert!((m.occupancy() - 0.75).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("batches=2"));
        assert!(s.contains("qwait-max=40us"), "{s}");
    }

    #[test]
    fn throughput_deterministic_with_injected_elapsed() {
        // No wall-clock sleep: inject the elapsed time instead (the old
        // sleep(2ms)-based assertion was flaky under loaded CI runners).
        let mut m = Metrics::new();
        m.record_batch(8, 8, Duration::from_micros(50));
        assert_eq!(m.throughput_after(Duration::from_secs(2)), 4.0);
        assert_eq!(m.throughput_after(Duration::from_millis(500)), 16.0);
        // Zero elapsed stays defined.
        assert_eq!(m.throughput_after(Duration::ZERO), 0.0);
        // And the wall-clock path is monotone-safe: elapsed > 0 from here.
        assert!(m.throughput() >= 0.0);
    }

    #[test]
    fn throughput_anchors_on_first_batch_not_construction() {
        // Regression: `started` used to be stamped in `new()`, so model
        // loading / idle time before the first request silently deflated
        // throughput. Before any batch there is no interval — and after a
        // batch the interval starts at that batch, so even if construction
        // happened long ago the figure only reflects serving time.
        let m = Metrics::new();
        assert_eq!(m.throughput(), 0.0, "no batches -> no throughput");
        let mut m = Metrics::new();
        std::thread::sleep(Duration::from_millis(50)); // "model load" delay
        m.record_batch(100, 100, Duration::from_millis(10));
        // Anchored at the first batch's start: even with generous scheduler
        // jitter the measured interval stays far below the 50 ms warm-up,
        // so the figure stays above the diluted 100/50ms bound the old
        // construction-time anchor would impose.
        let diluted_bound = 100.0 / Duration::from_millis(50).as_secs_f64();
        assert!(
            m.throughput() > diluted_bound,
            "warm-up time must not count: {} vs {}",
            m.throughput(),
            diluted_bound
        );
        // And the interval includes the first batch's own service time, so
        // a single-batch run reports requests/batch-latency, not a
        // requests/(~0 s) explosion.
        let single_batch_bound = 100.0 / Duration::from_millis(10).as_secs_f64();
        assert!(
            m.throughput() <= single_batch_bound * 1.01,
            "first batch's service time must count: {} vs {}",
            m.throughput(),
            single_batch_bound
        );
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.latency.percentile_us(99.0), 0);
        assert_eq!(m.queue_wait.percentile_us(50.0), 0);
        assert_eq!(m.throughput(), 0.0);
    }
}
