//! Serving metrics: latency quantile sketch, queueing delay, throughput,
//! batch-occupancy.
//!
//! Records are stamped with [`Tick`]s from the serving loop's injected
//! [`Clock`](crate::util::clock::Clock), never with `Instant::now()` — under
//! a virtual clock the whole metrics report is bit-reproducible.
//!
//! Percentiles come from [`QuantileSketch`] (log-linear, fixed footprint,
//! relative error ≤ 1/64) rather than the old power-of-two
//! `LatencyHistogram`, whose bucket upper bounds could overshoot the true
//! maximum by almost 2× — at fleet scale (1e6+ requests) the sketch keeps
//! p50/p99/p999 within 1.6 % of an exact sort at O(1) memory, and
//! `quantile(q) ≤ max()` holds unconditionally.

use std::time::Duration;

use crate::util::clock::Tick;
use crate::util::stats::QuantileSketch;

/// Aggregated serving metrics.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub latency: QuantileSketch,
    /// Queueing delay of the oldest request in each executed batch (how
    /// long the batching window actually held traffic back).
    pub queue_wait: QuantileSketch,
    pub batches: u64,
    pub requests: u64,
    pub padded_rows: u64,
    /// Anchored at the *first executed batch*, not construction — model
    /// load and idle warm-up time must not dilute the throughput figure.
    started: Option<Tick>,
    /// Completion instant of the most recent batch; `started..last_end` is
    /// the serving interval throughput is measured over.
    last_end: Tick,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            latency: QuantileSketch::new(),
            queue_wait: QuantileSketch::new(),
            batches: 0,
            requests: 0,
            padded_rows: 0,
            started: None,
            last_end: Tick::ZERO,
        }
    }

    /// Record one executed batch (no queueing-delay information). `now` is
    /// the batch's *completion* instant on the serving clock.
    pub fn record_batch(&mut self, now: Tick, real: usize, capacity: usize, latency: Duration) {
        self.record_batch_waited(now, real, capacity, latency, Duration::ZERO);
    }

    /// Record one executed batch plus the queueing delay of its oldest
    /// request ([`crate::coordinator::Batch::oldest_wait`]). `now` is the
    /// batch's *completion* instant on the serving clock.
    pub fn record_batch_waited(
        &mut self,
        now: Tick,
        real: usize,
        capacity: usize,
        latency: Duration,
        queue_wait: Duration,
    ) {
        if self.started.is_none() {
            // Anchor at the *start* of the first executed batch (records
            // arrive after inference, so back-date by its latency): the
            // interval includes every batch's service time but none of the
            // model-load/idle time before the first request.
            self.started = Some(now.checked_sub(latency).unwrap_or(now));
        }
        self.last_end = self.last_end.max(now);
        self.batches += 1;
        self.requests += real as u64;
        self.padded_rows += (capacity - real) as u64;
        self.latency.record(latency.as_micros() as u64);
        self.queue_wait.record(queue_wait.as_micros() as u64);
    }

    /// Requests per second over the serving interval — from the first
    /// recorded batch's start to the latest batch's completion (0 before
    /// any batch has executed: there is no interval to measure yet).
    pub fn throughput(&self) -> f64 {
        match self.started {
            Some(t0) => self.throughput_after(self.last_end.duration_since(t0)),
            None => 0.0,
        }
    }

    /// Requests per second over an injected elapsed time — the deterministic
    /// core of [`Metrics::throughput`], also used by tests so they need not
    /// sleep on the wall clock.
    pub fn throughput_after(&self, elapsed: Duration) -> f64 {
        let secs = elapsed.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.requests as f64 / secs
        }
    }

    /// Mean batch occupancy (real rows / capacity rows).
    pub fn occupancy(&self) -> f64 {
        let total = self.requests + self.padded_rows;
        if total == 0 {
            0.0
        } else {
            self.requests as f64 / total as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "batches={} requests={} occupancy={:.1}% p50={}us p99={}us max={}us mean={:.0}us qwait-p50={}us qwait-max={}us",
            self.batches,
            self.requests,
            self.occupancy() * 100.0,
            self.latency.quantile(50.0),
            self.latency.quantile(99.0),
            self.latency.max(),
            self.latency.mean(),
            self.queue_wait.quantile(50.0),
            self.queue_wait.max(),
        )
    }
}

/// Per-tenant serving ledger for the fleet simulator: one sojourn-latency
/// and one energy-per-request sketch plus the class's own accounting
/// counters, so a multi-tenant report can state each class's p50/p99/p999,
/// energy and SLO violations independently of its neighbours.
///
/// Like [`Metrics`], every record is tick-stamped from the injected clock
/// and the sketches are mergeable — per-shard ledgers merged in shard
/// order produce byte-identical figures at any worker count.
#[derive(Debug, Clone)]
pub struct TenantLedger {
    /// Per-request sojourn (arrival → batch completion), microseconds.
    pub latency: QuantileSketch,
    /// Per-request energy attributed at completion, picojoules.
    pub energy_pj: QuantileSketch,
    /// Requests this tenant offered (accepted + rejected).
    pub arrived: u64,
    pub served: u64,
    /// Backpressure rejects charged to this tenant's class queue.
    pub rejected: u64,
    /// Completions whose sojourn exceeded the tenant's effective SLO.
    pub slo_violations: u64,
}

impl Default for TenantLedger {
    fn default() -> Self {
        Self::new()
    }
}

impl TenantLedger {
    pub fn new() -> Self {
        Self {
            latency: QuantileSketch::new(),
            energy_pj: QuantileSketch::new(),
            arrived: 0,
            served: 0,
            rejected: 0,
            slo_violations: 0,
        }
    }

    /// Book one completed request: its sojourn, its energy share, and
    /// whether it broke the tenant's SLO.
    pub fn record_completion(&mut self, sojourn: Duration, energy_pj: u64, slo: Duration) {
        self.served += 1;
        self.latency.record(sojourn.as_micros() as u64);
        self.energy_pj.record(energy_pj);
        if sojourn > slo {
            self.slo_violations += 1;
        }
    }

    /// Fold another ledger of the same tenant into this one (merge order
    /// must be deterministic — the fleet merges in shard order).
    pub fn merge(&mut self, other: &TenantLedger) {
        self.latency.merge(&other.latency);
        self.energy_pj.merge(&other.energy_pj);
        self.arrived += other.arrived;
        self.served += other.served;
        self.rejected += other.rejected;
        self.slo_violations += other.slo_violations;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::new();
        let now = Tick::ZERO + Duration::from_micros(100);
        m.record_batch(now, 4, 4, Duration::from_micros(100));
        m.record_batch_waited(
            now + Duration::from_micros(300),
            2,
            4,
            Duration::from_micros(300),
            Duration::from_micros(40),
        );
        assert_eq!(m.batches, 2);
        assert_eq!(m.requests, 6);
        assert_eq!(m.padded_rows, 2);
        assert!((m.occupancy() - 0.75).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("batches=2"));
        assert!(s.contains("qwait-max=40us"), "{s}");
    }

    #[test]
    fn throughput_deterministic_with_injected_elapsed() {
        let mut m = Metrics::new();
        m.record_batch(Tick::ZERO + Duration::from_micros(50), 8, 8, Duration::from_micros(50));
        assert_eq!(m.throughput_after(Duration::from_secs(2)), 4.0);
        assert_eq!(m.throughput_after(Duration::from_millis(500)), 16.0);
        // Zero elapsed stays defined.
        assert_eq!(m.throughput_after(Duration::ZERO), 0.0);
    }

    #[test]
    fn throughput_anchors_on_first_batch_not_construction() {
        // Regression: `started` used to be stamped in `new()`, so model
        // loading / idle time before the first request silently deflated
        // throughput. With tick-stamped records the interval is exact:
        // anchored at the first batch's *start* (completion back-dated by
        // its latency), ending at the latest batch's completion.
        let m = Metrics::new();
        assert_eq!(m.throughput(), 0.0, "no batches -> no throughput");
        let mut m = Metrics::new();
        // "Model load" delay: the first batch completes 60 ms in, after a
        // 10 ms service time. The interval is exactly that 10 ms — the
        // 50 ms warm-up before it does not count.
        let done = Tick::ZERO + Duration::from_millis(60);
        m.record_batch(done, 100, 100, Duration::from_millis(10));
        assert_eq!(m.throughput(), 100.0 / 0.010, "exactly requests / first batch latency");
        // A second batch extends the interval to its completion.
        m.record_batch(done + Duration::from_millis(10), 100, 100, Duration::from_millis(10));
        assert_eq!(m.throughput(), 200.0 / 0.020);
    }

    #[test]
    fn first_batch_latency_exceeding_epoch_saturates() {
        // A first batch whose latency back-dates past the clock epoch
        // anchors at the completion instant instead of wrapping.
        let mut m = Metrics::new();
        m.record_batch(Tick::ZERO + Duration::from_millis(1), 4, 4, Duration::from_millis(5));
        // Anchor = completion (1 ms), last_end = 1 ms -> zero interval.
        assert_eq!(m.throughput(), 0.0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.occupancy(), 0.0);
        assert_eq!(m.latency.quantile(99.0), 0);
        assert_eq!(m.queue_wait.quantile(50.0), 0);
        assert_eq!(m.throughput(), 0.0);
    }

    /// The percentile-reporting fix, pinned end to end: 1e5 batch records
    /// through the Metrics path agree with an exact sort within the
    /// sketch's documented ≤ 1/64 bound, and the independent P² estimator
    /// corroborates both. The old histogram failed this: its power-of-two
    /// bucket bound could exceed the true maximum by almost 2×.
    #[test]
    fn sketch_percentiles_cross_check_exact_sort_at_1e5() {
        use crate::util::rng::Rng;
        use crate::util::stats::P2Quantile;
        let mut rng = Rng::seed_from_u64(0x5E2E);
        let mut m = Metrics::new();
        let mut p2 = P2Quantile::new(0.99);
        let mut lat = Vec::with_capacity(100_000);
        let now = Tick::ZERO + Duration::from_millis(1);
        for _ in 0..100_000u32 {
            // Heavy-tailed service times, 100 µs .. ~10 ms.
            let us = (100.0 / (1.0 - rng.next_f64()).powf(0.5)) as u64;
            m.record_batch(now, 16, 16, Duration::from_micros(us));
            p2.record(us as f64);
            lat.push(us);
        }
        lat.sort_unstable();
        for q in [50.0, 99.0, 99.9] {
            let rank = (((q / 100.0) * lat.len() as f64).ceil() as usize).max(1);
            let exact = lat[rank - 1];
            let approx = m.latency.quantile(q);
            assert!(approx >= exact, "q={q}: {approx} < exact {exact}");
            assert!(approx - exact <= exact / 64 + 1, "q={q}: {approx} vs exact {exact}");
        }
        // Cross-check: sketch and P² bracket the same p99.
        let (sk99, p299) = (m.latency.quantile(99.0) as f64, p2.value());
        assert!((sk99 - p299).abs() / p299 < 0.2, "sketch {sk99} vs P² {p299}");
        // The summary's max can never be undercut by a percentile.
        assert!(m.latency.quantile(99.9) <= m.latency.max());
    }

    #[test]
    fn tenant_ledger_books_completions_and_violations() {
        let mut l = TenantLedger::new();
        let slo = Duration::from_millis(2);
        l.record_completion(Duration::from_millis(1), 240, slo);
        l.record_completion(Duration::from_millis(3), 150, slo);
        l.record_completion(slo, 150, slo);
        assert_eq!(l.served, 3);
        assert_eq!(l.slo_violations, 1, "exactly-at-SLO is not a violation");
        assert_eq!(l.latency.max(), 3_000);
        assert_eq!(l.energy_pj.max(), 240);
    }

    #[test]
    fn tenant_ledger_merge_is_exact_on_counters() {
        let slo = Duration::from_millis(10);
        let mut a = TenantLedger::new();
        a.arrived = 5;
        a.rejected = 1;
        a.record_completion(Duration::from_millis(1), 100, slo);
        let mut b = TenantLedger::new();
        b.arrived = 3;
        b.record_completion(Duration::from_millis(20), 200, slo);
        a.merge(&b);
        assert_eq!((a.arrived, a.served, a.rejected, a.slo_violations), (8, 2, 1, 1));
        assert_eq!(a.latency.max(), 20_000);
    }
}
