//! Graceful-degradation supervisor: a multi-engine fleet under a
//! [`FaultSchedule`], with per-engine health, canary probes, bounded
//! retry/reroute dispatch, and fallback reboots.
//!
//! Each engine slot carries a [`Health`] state machine (see the diagram in
//! [`crate::coordinator`]) driven by two signals:
//!
//! * **canary probes** — every `canary_period` a small buffer passes
//!   through the engine's effective [`BankSplit`] fault model; the probe
//!   fails when the robust MSB bank flips beyond its (near-zero) budget or
//!   the relaxed LSB bank exceeds ~10x its expected clean flip count. The
//!   probes are fanned across a [`ThreadPool`] but each derives its
//!   injection stream from `(schedule seed, engine, round)`, so the verdict
//!   vector — and therefore the whole report — is identical at any
//!   `--parallel` worker count.
//! * **dispatch outcomes** — a crash marks the engine `Down` at once; a
//!   timeout counts one failure. Successful dispatches do *not* count as
//!   health passes: an engine can serve corrupted answers happily, and only
//!   the canaries are allowed to clear it.
//!
//! The dispatch path prefers `Healthy` engines, falls back to `Degraded`
//! ones, retries with exponential backoff under a per-request deadline, and
//! drops the batch only when the attempt budget or the deadline is
//! exhausted. An engine that stays `Down` for `reboot_after` is rebooted —
//! onto the fallback [`EngineSpec`] (e.g. the latency-optimal SRAM pick,
//! immune to retention faults) the first time, in place afterwards.
//!
//! Everything runs on an injected [`Clock`]; under
//! [`Clock::virtual_at_zero`] the run is a discrete-event simulation whose
//! [`FleetReport`] is byte-identical across runs.

use std::collections::HashMap;
use std::time::Duration;

use crate::ber::{BankSplit, FaultExposure, Injector, WordKind};
use crate::config::{BerConfig, GlbVariant, TechBase, TechConfig};
use crate::dse::select::{DesignSelection, CATASTROPHIC_AMPLIFICATION};
use crate::models::{DType, Model};
use crate::util::clock::{Clock, Tick};
use crate::util::json::Json;
use crate::util::pool::ThreadPool;

use super::batcher::{Batch, Batcher, Request};
use super::faults::{EffectiveFaults, FaultSchedule};
use super::metrics::Metrics;
use super::router::{Router, RouterPolicy};
use super::serve;

/// Engine health as the supervisor sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    /// Serving normally; preferred by the dispatch path.
    Healthy,
    /// Failing canaries or dispatches; used only when no Healthy engine is
    /// available, and the probation state after a reboot.
    Degraded,
    /// Not dispatchable. Leaves via canary passes (the fault cleared on its
    /// own) or a fallback reboot after `reboot_after`.
    Down,
}

impl Health {
    /// Stable serialization token.
    pub fn token(&self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Degraded => "degraded",
            Health::Down => "down",
        }
    }
}

/// Everything the supervisor needs to know about one engine build: the
/// fault model its GLB carries and its modeled per-batch service latency.
#[derive(Debug, Clone)]
pub struct EngineSpec {
    pub label: String,
    pub variant: GlbVariant,
    /// Technology base for retention-storm scaling ([`super::faults::storm_ber`]).
    pub tech: TechBase,
    pub ber: BerConfig,
    /// Built Δ_PT_GB of the (mono or MSB) bank.
    pub glb_delta: f64,
    /// Built Δ_PT_GB of the LSB bank.
    pub lsb_delta: f64,
    /// Modeled clean service latency per batch.
    pub service: Duration,
    /// Modeled GLB energy per served request (J) — the fleet simulator's
    /// energy-per-request metric sums these across the engines that
    /// actually served each request.
    pub energy_per_req_j: f64,
    /// Modeled inference accuracy of this build (fraction of clean).
    /// Tenants with an accuracy floor are only routed to shards at or
    /// above it; the paper builds degrade mildly with BER budget (SRAM
    /// clean, STT-AI 0.999, Ultra 0.995 under its relaxed LSB budget).
    pub est_accuracy: f64,
}

impl EngineSpec {
    /// The paper build of `variant`, with per-variant modeled service
    /// latency and per-request GLB energy.
    ///
    /// Service follows the PR 5 write-stall ordering (SRAM carries no
    /// write-bandwidth stalls, so MinLatency selects it; the STT variants
    /// pay the write service-rate penalty): SRAM 700 µs, STT-AI 900 µs,
    /// STT-AI Ultra exactly 1 ms — the Ultra figure is the anchor
    /// [`SupervisorPolicy`]'s default timers are tuned against and must not
    /// drift. Energy follows the Table III power ranking (Ultra < STT-AI <
    /// SRAM): the customized STT-MRAM buffers trade a little latency for
    /// large static-power and area savings.
    pub fn paper(variant: GlbVariant) -> Self {
        let tech = TechConfig::default();
        let (service_us, energy_per_req_j, est_accuracy) = match variant {
            GlbVariant::Sram => (700, 2.4e-4, 1.0),
            GlbVariant::SttAi => (900, 1.8e-4, 0.999),
            GlbVariant::SttAiUltra => (1_000, 1.5e-4, 0.995),
        };
        Self {
            label: variant.label().to_string(),
            variant,
            tech: tech.base,
            ber: BerConfig::for_variant(variant),
            glb_delta: tech.glb_delta(),
            lsb_delta: tech.lsb_delta(),
            service: Duration::from_micros(service_us),
            energy_per_req_j,
            est_accuracy,
        }
    }

    /// A uniform fleet of `n` paper STT-AI Ultra engines (the serving
    /// default), labeled by slot.
    pub fn paper_fleet(n: usize) -> Vec<EngineSpec> {
        (0..n)
            .map(|i| {
                let mut s = Self::paper(GlbVariant::SttAiUltra);
                s.label = format!("{}-{i}", s.label);
                s
            })
            .collect()
    }

    /// Build from a sweep-selected design point: variant, BER budget, built
    /// Δs and (when the sweep recorded one) the modeled latency all come
    /// from the selection record.
    pub fn from_selection(sel: &DesignSelection) -> Self {
        let cfg = sel.system_config();
        let service = sel
            .metric("latency_s")
            .filter(|s| s.is_finite() && *s > 0.0)
            .map(Duration::from_secs_f64)
            .unwrap_or(Duration::from_millis(1));
        let energy_per_req_j = sel
            .energy_per_request_j()
            .unwrap_or_else(|| Self::paper(sel.variant()).energy_per_req_j);
        let est_accuracy = sel
            .metric("est_accuracy")
            .filter(|a| a.is_finite() && *a > 0.0)
            .unwrap_or_else(|| Self::paper(sel.variant()).est_accuracy);
        Self {
            label: cfg.name.clone(),
            variant: sel.variant(),
            tech: cfg.tech.base,
            ber: sel.ber_config(),
            glb_delta: cfg.tech.glb_delta(),
            lsb_delta: cfg.tech.lsb_delta(),
            service,
            energy_per_req_j,
            est_accuracy,
        }
    }
}

/// Supervisor knobs. `Default` is tuned for 1 ms-class engine specs; the
/// constructor floors `attempt_timeout` and `deadline` against the fleet's
/// actual service latencies so slow selections do not time out on every
/// dispatch.
#[derive(Debug, Clone, Copy)]
pub struct SupervisorPolicy {
    /// Dispatch attempts (including the first) before a batch is dropped.
    pub max_attempts: u32,
    /// First retry backoff; doubles per failed attempt up to `backoff_cap`.
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// Per-attempt service timeout: an engine that holds a batch longer is
    /// abandoned (the stall detector).
    pub attempt_timeout: Duration,
    /// Per-request deadline across all attempts and backoffs.
    pub deadline: Duration,
    /// Canary cadence.
    pub canary_period: Duration,
    /// Probe buffer size (rounded up to whole bf16 words).
    pub canary_probe_bytes: usize,
    /// Max MSB-bank flips per probe before the canary fails. The robust
    /// bank expects ~0.003 flips per 64 KiB probe at the paper's 1e-8, so
    /// anything past a stray flip or two is an episode.
    pub canary_msb_flip_budget: u64,
    /// Max LSB-bank flips per probe. 64 KiB at the Ultra 1e-5 budget
    /// expects ~2.6 flips; 26 is 10x that (never trips clean, always trips
    /// a 1e3 escalation).
    pub canary_lsb_flip_budget: u64,
    /// Consecutive failures before Healthy -> Degraded.
    pub degraded_after: u32,
    /// Consecutive failures before Degraded -> Down.
    pub down_after: u32,
    /// Consecutive canary passes to climb one health level.
    pub recover_after: u32,
    /// Time spent Down before the supervisor reboots the engine.
    pub reboot_after: Duration,
    /// Reboot duration (the slot is not dispatchable or probeable).
    pub reboot_time: Duration,
}

impl Default for SupervisorPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 4,
            backoff_base: Duration::from_micros(250),
            backoff_cap: Duration::from_millis(2),
            attempt_timeout: Duration::from_millis(2),
            deadline: Duration::from_millis(8),
            canary_period: Duration::from_millis(5),
            canary_probe_bytes: 64 << 10,
            canary_msb_flip_budget: 3,
            canary_lsb_flip_budget: 26,
            degraded_after: 2,
            down_after: 4,
            recover_after: 2,
            reboot_after: Duration::from_millis(15),
            reboot_time: Duration::from_millis(2),
        }
    }
}

/// One engine slot: spec + health machine + lifetime counters.
#[derive(Debug, Clone)]
pub struct EngineSlot {
    pub id: usize,
    pub spec: EngineSpec,
    pub health: Health,
    consecutive_failures: u32,
    consecutive_passes: u32,
    /// Requests served (real rows, not padding).
    pub served: u64,
    pub batches: u64,
    /// Dispatch attempts that failed here (crash or timeout).
    pub failed_dispatches: u64,
    pub canaries: u64,
    pub canary_failures: u64,
    pub reboots: u64,
    /// True once the slot runs the fallback spec.
    pub on_fallback: bool,
    down_since: Option<Tick>,
    /// Not dispatchable or probeable before this instant (mid-reboot).
    ready_at: Tick,
    /// Health transition log: (ns since epoch, new state).
    pub transitions: Vec<(u64, Health)>,
}

impl EngineSlot {
    fn new(id: usize, spec: EngineSpec) -> Self {
        Self {
            id,
            spec,
            health: Health::Healthy,
            consecutive_failures: 0,
            consecutive_passes: 0,
            served: 0,
            batches: 0,
            failed_dispatches: 0,
            canaries: 0,
            canary_failures: 0,
            reboots: 0,
            on_fallback: false,
            down_since: None,
            ready_at: Tick::ZERO,
            transitions: Vec::new(),
        }
    }

    fn set_health(&mut self, h: Health, now: Tick) {
        if self.health != h {
            self.health = h;
            self.transitions.push((now.as_nanos(), h));
        }
    }

    /// One failure signal of the given weight (1 for a canary failure or a
    /// dispatch timeout; `down_after` for a crash, which must floor the
    /// engine immediately).
    fn note_failure(&mut self, now: Tick, weight: u32, policy: &SupervisorPolicy) {
        self.consecutive_passes = 0;
        self.consecutive_failures = self.consecutive_failures.saturating_add(weight);
        if self.health == Health::Healthy && self.consecutive_failures >= policy.degraded_after {
            self.set_health(Health::Degraded, now);
        }
        if self.health == Health::Degraded && self.consecutive_failures >= policy.down_after {
            self.set_health(Health::Down, now);
            self.down_since = Some(now);
        }
    }

    /// One canary pass; `recover_after` consecutive passes climb one level
    /// (Down -> Degraded -> Healthy), so a fault that clears on its own
    /// needs two full probation windows to fully rehabilitate the engine.
    fn note_pass(&mut self, now: Tick, policy: &SupervisorPolicy) {
        self.consecutive_failures = 0;
        self.consecutive_passes = self.consecutive_passes.saturating_add(1);
        if self.consecutive_passes >= policy.recover_after {
            match self.health {
                Health::Down => {
                    self.set_health(Health::Degraded, now);
                    self.down_since = None;
                    self.consecutive_passes = 0;
                }
                Health::Degraded => {
                    self.set_health(Health::Healthy, now);
                    self.consecutive_passes = 0;
                }
                Health::Healthy => {}
            }
        }
    }
}

/// Chaos-run shape: offered load and batching knobs.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Total requests offered.
    pub requests: usize,
    /// Max batch (and largest compiled variant of the ladder).
    pub batch: usize,
    /// Open-loop arrival spacing (request i arrives at `i * arrival_gap`).
    pub arrival_gap: Duration,
    /// Synthetic image elements per request (the sim backend never runs a
    /// real executable, so this only sizes the queue traffic).
    pub image_elems: usize,
    pub queue_depth: usize,
    /// Batching window (also the router's deadline).
    pub window: Duration,
    /// Canary fan-out workers. Any value produces the identical report.
    pub parallel: usize,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            requests: 2000,
            batch: 16,
            arrival_gap: Duration::from_micros(70),
            image_elems: 4,
            queue_depth: 4096,
            window: Duration::from_micros(500),
            parallel: 1,
        }
    }
}

/// Per-engine rows of the [`FleetReport`].
#[derive(Debug, Clone)]
pub struct EngineReport {
    pub id: usize,
    pub label: String,
    pub health: Health,
    pub served: u64,
    pub batches: u64,
    pub failed_dispatches: u64,
    pub canaries: u64,
    pub canary_failures: u64,
    pub reboots: u64,
    pub on_fallback: bool,
    pub transitions: Vec<(u64, Health)>,
}

/// The availability/accuracy report of one chaos run. Under a virtual
/// clock both [`FleetReport::render`] and [`FleetReport::to_json`] are
/// byte-identical across runs and worker counts.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub scenario: String,
    pub seed: u64,
    pub engines: Vec<EngineReport>,
    pub offered: u64,
    pub served: u64,
    pub dropped: u64,
    pub rejected: u64,
    pub malformed: u64,
    /// Failed dispatch attempts (timeouts, crashes, all-engines-busy waits).
    pub retries: u64,
    /// Batches that succeeded only after at least one failed attempt.
    pub reroutes: u64,
    /// Reboots that swapped a slot onto the fallback spec.
    pub fallbacks: u64,
    pub reboots: u64,
    pub canaries: u64,
    pub canary_failures: u64,
    /// served / offered, percent.
    pub availability: f64,
    /// Traffic-weighted Fig. 21-style estimated accuracy under faults.
    pub est_accuracy: f64,
    /// The same estimate for the primary spec with no faults active.
    pub clean_accuracy: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    pub qwait_p50_us: u64,
    pub qwait_max_us: u64,
    pub sim_elapsed: Duration,
    pub throughput_rps: f64,
}

impl FleetReport {
    /// Deterministic human-readable report.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "chaos report: scenario={} seed={}", self.scenario, self.seed);
        let _ = writeln!(
            s,
            "  offered={} served={} dropped={} rejected={} malformed={}",
            self.offered, self.served, self.dropped, self.rejected, self.malformed
        );
        let _ = writeln!(
            s,
            "  availability={:.3}% retries={} reroutes={} fallbacks={} reboots={}",
            self.availability, self.retries, self.reroutes, self.fallbacks, self.reboots
        );
        let _ = writeln!(s, "  canaries={} failed={}", self.canaries, self.canary_failures);
        let _ = writeln!(
            s,
            "  est_accuracy={:.6} clean_accuracy={:.6}",
            self.est_accuracy, self.clean_accuracy
        );
        let _ = writeln!(
            s,
            "  latency: p50={}us p99={}us max={}us | qwait: p50={}us max={}us",
            self.p50_us, self.p99_us, self.max_us, self.qwait_p50_us, self.qwait_max_us
        );
        let _ = writeln!(
            s,
            "  sim_elapsed={:.3}ms throughput={:.1} req/s",
            self.sim_elapsed.as_secs_f64() * 1e3,
            self.throughput_rps
        );
        for e in &self.engines {
            let _ = write!(
                s,
                "  engine {} [{}]: health={} served={} batches={} failed={} canaries={}/{} reboots={}{}",
                e.id,
                e.label,
                e.health.token(),
                e.served,
                e.batches,
                e.failed_dispatches,
                e.canary_failures,
                e.canaries,
                e.reboots,
                if e.on_fallback { " (fallback)" } else { "" }
            );
            if e.transitions.is_empty() {
                let _ = writeln!(s);
            } else {
                let _ = write!(s, " |");
                for (ns, h) in &e.transitions {
                    let _ = write!(s, " {:.1}ms->{}", *ns as f64 / 1e6, h.token());
                }
                let _ = writeln!(s);
            }
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let engines = self
            .engines
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("id", (e.id as u64).into()),
                    ("label", Json::Str(e.label.clone())),
                    ("health", Json::Str(e.health.token().to_string())),
                    ("served", e.served.into()),
                    ("batches", e.batches.into()),
                    ("failed_dispatches", e.failed_dispatches.into()),
                    ("canaries", e.canaries.into()),
                    ("canary_failures", e.canary_failures.into()),
                    ("reboots", e.reboots.into()),
                    ("on_fallback", e.on_fallback.into()),
                    (
                        "transitions",
                        Json::Arr(
                            e.transitions
                                .iter()
                                .map(|(ns, h)| {
                                    Json::obj(vec![
                                        ("at_us", (ns / 1_000).into()),
                                        ("health", Json::Str(h.token().to_string())),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("seed", self.seed.into()),
            ("offered", self.offered.into()),
            ("served", self.served.into()),
            ("dropped", self.dropped.into()),
            ("rejected", self.rejected.into()),
            ("malformed", self.malformed.into()),
            ("retries", self.retries.into()),
            ("reroutes", self.reroutes.into()),
            ("fallbacks", self.fallbacks.into()),
            ("reboots", self.reboots.into()),
            ("canaries", self.canaries.into()),
            ("canary_failures", self.canary_failures.into()),
            ("availability_pct", Json::Str(format!("{:.3}", self.availability))),
            ("est_accuracy", Json::Str(format!("{:.6}", self.est_accuracy))),
            ("clean_accuracy", Json::Str(format!("{:.6}", self.clean_accuracy))),
            ("p50_us", self.p50_us.into()),
            ("p99_us", self.p99_us.into()),
            ("max_us", self.max_us.into()),
            ("qwait_p50_us", self.qwait_p50_us.into()),
            ("qwait_max_us", self.qwait_max_us.into()),
            ("sim_elapsed_us", (self.sim_elapsed.as_micros() as u64).into()),
            ("throughput_rps", Json::Str(format!("{:.1}", self.throughput_rps))),
            ("engines", Json::Arr(engines)),
        ])
    }
}

/// One deterministic canary probe: inject the engine's effective fault
/// model into a zeroed buffer and compare per-bank flip counts against the
/// budgets. The injection stream derives from (schedule seed, engine,
/// round) only — never from thread identity.
fn canary_passes(
    seed: u64,
    engine: u64,
    round: u64,
    eff: &EffectiveFaults,
    policy: &SupervisorPolicy,
) -> bool {
    if eff.crashed || eff.stalled {
        return false;
    }
    let mut buf = vec![0u8; policy.canary_probe_bytes.next_multiple_of(2)];
    let mut inj = Injector::new(
        seed ^ engine.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ round.wrapping_mul(0xD1B5_4A32_D192_ED03),
    );
    let split = BankSplit { kind: WordKind::Bf16, msb_ber: eff.msb_ber, lsb_ber: eff.lsb_ber };
    let (msb, lsb) = split.inject_split(&mut inj, &mut buf);
    msb.bits_flipped <= policy.canary_msb_flip_budget
        && lsb.bits_flipped <= policy.canary_lsb_flip_budget
}

/// The model the Fig. 21-style accuracy estimate is computed over.
const EXPOSURE_MODEL: &str = "ResNet50";

/// The graceful-degradation supervisor (see module docs).
pub struct Supervisor {
    schedule: FaultSchedule,
    policy: SupervisorPolicy,
    slots: Vec<EngineSlot>,
    fallback: Option<EngineSpec>,
    pool: ThreadPool,
    model: Model,
    /// Round-robin cursor of the dispatch path.
    rr: usize,
    retries: u64,
    reroutes: u64,
    dropped: u64,
    fallbacks: u64,
    /// Accuracy estimate accumulated per served request.
    acc_weighted: f64,
    acc_weight: f64,
    /// `(msb_ber, lsb_ber) -> estimated accuracy` memo (the exposure
    /// analysis walks every model layer; the schedule only ever produces a
    /// handful of distinct BER pairs).
    exposure_memo: HashMap<(u64, u64), f64>,
}

impl Supervisor {
    /// Build a supervisor over `specs` (slot order = engine index in the
    /// schedule). `fallback` is the reboot target spec; `parallel` sizes
    /// the canary fan-out pool (any value, same report).
    pub fn new(
        schedule: FaultSchedule,
        specs: Vec<EngineSpec>,
        fallback: Option<EngineSpec>,
        mut policy: SupervisorPolicy,
        parallel: usize,
    ) -> crate::Result<Self> {
        if specs.is_empty() {
            anyhow::bail!("supervisor: fleet needs at least one engine spec");
        }
        // Floor the timers against the fleet's modeled service latencies so
        // a slow selection does not time out on every clean dispatch.
        let slowest = specs.iter().map(|s| s.service).max().unwrap_or(Duration::ZERO);
        policy.attempt_timeout = policy.attempt_timeout.max(2 * slowest);
        policy.deadline = policy.deadline.max(4 * policy.attempt_timeout);
        let model = crate::models::by_name(EXPOSURE_MODEL)
            .ok_or_else(|| anyhow::anyhow!("model {EXPOSURE_MODEL} missing from the zoo"))?;
        Ok(Self {
            schedule,
            policy,
            slots: specs.into_iter().enumerate().map(|(i, s)| EngineSlot::new(i, s)).collect(),
            fallback,
            pool: ThreadPool::new(parallel.max(1)),
            model,
            rr: 0,
            retries: 0,
            reroutes: 0,
            dropped: 0,
            fallbacks: 0,
            acc_weighted: 0.0,
            acc_weight: 0.0,
            exposure_memo: HashMap::new(),
        })
    }

    pub fn policy(&self) -> &SupervisorPolicy {
        &self.policy
    }

    pub fn slots(&self) -> &[EngineSlot] {
        &self.slots
    }

    /// Fig. 21-style estimated accuracy at an effective BER pair, memoized.
    fn est_accuracy(&mut self, msb_ber: f64, lsb_ber: f64) -> f64 {
        let key = (msb_ber.to_bits(), lsb_ber.to_bits());
        if let Some(&v) = self.exposure_memo.get(&key) {
            return v;
        }
        let split = BankSplit { kind: WordKind::Bf16, msb_ber, lsb_ber };
        let e = FaultExposure::analyze(&self.model, DType::Bf16, &split);
        let est_drop = (e.catastrophic_fraction * CATASTROPHIC_AMPLIFICATION
            + e.mean_rel_perturbation)
            .min(1.0);
        let acc = 1.0 - est_drop;
        self.exposure_memo.insert(key, acc);
        acc
    }

    /// Pick the next dispatch target: round-robin over Healthy engines
    /// first, then Degraded ones; Down, mid-reboot, and already-`tried`
    /// slots are skipped. Deterministic by construction.
    fn pick_engine(&mut self, tried: &[usize], now: Tick) -> Option<usize> {
        let n = self.slots.len();
        for want in [Health::Healthy, Health::Degraded] {
            for k in 0..n {
                let idx = (self.rr + k) % n;
                let s = &self.slots[idx];
                if s.health == want && s.ready_at <= now && !tried.contains(&idx) {
                    self.rr = (idx + 1) % n;
                    return Some(idx);
                }
            }
        }
        None
    }

    /// Serve one batch: bounded retry with exponential backoff under the
    /// per-request deadline. Serialized model — the supervisor advances the
    /// clock by the service latency of whichever engine finally takes the
    /// batch; the fleet buys redundancy, not parallel throughput.
    fn dispatch_batch(&mut self, b: &Batch, clock: &Clock, metrics: &mut Metrics) {
        let start = clock.now();
        let deadline = start + self.policy.deadline;
        let mut backoff = self.policy.backoff_base;
        let mut attempts: u32 = 0;
        let mut tried: Vec<usize> = Vec::new();
        loop {
            attempts += 1;
            let now = clock.now();
            match self.pick_engine(&tried, now) {
                None => {
                    // Whole fleet down, mid-reboot, or already tried: back
                    // off and widen the candidate set again.
                    tried.clear();
                }
                Some(idx) => {
                    let spec = self.slots[idx].spec.clone();
                    let eff = self.schedule.effective(
                        idx,
                        now,
                        spec.ber,
                        spec.tech,
                        spec.glb_delta,
                        spec.lsb_delta,
                    );
                    if eff.crashed {
                        // Hard failure, detected immediately; the health
                        // machine floors the engine at once.
                        let policy = self.policy;
                        let slot = &mut self.slots[idx];
                        slot.failed_dispatches += 1;
                        slot.note_failure(now, policy.down_after, &policy);
                        tried.push(idx);
                    } else {
                        let service = spec.service.mul_f64(eff.latency_mult.max(0.0));
                        if eff.stalled || service > self.policy.attempt_timeout {
                            // The engine holds the batch until the attempt
                            // timer expires; one failure, try elsewhere.
                            let t = clock.advance(self.policy.attempt_timeout);
                            let policy = self.policy;
                            let slot = &mut self.slots[idx];
                            slot.failed_dispatches += 1;
                            slot.note_failure(t, 1, &policy);
                            tried.push(idx);
                        } else {
                            let done = clock.advance(service);
                            let slot = &mut self.slots[idx];
                            slot.served += b.real as u64;
                            slot.batches += 1;
                            if attempts > 1 {
                                self.reroutes += 1;
                            }
                            let acc = self.est_accuracy(eff.msb_ber, eff.lsb_ber);
                            self.acc_weighted += acc * b.real as f64;
                            self.acc_weight += b.real as f64;
                            metrics.record_batch_waited(
                                done,
                                b.real,
                                b.capacity,
                                done.duration_since(start),
                                b.oldest_wait,
                            );
                            return;
                        }
                    }
                }
            }
            // Failed attempt: retry within the budget or drop the batch.
            self.retries += 1;
            if attempts >= self.policy.max_attempts || clock.now() + backoff >= deadline {
                self.dropped += b.real as u64;
                return;
            }
            clock.advance(backoff);
            backoff = (backoff * 2).min(self.policy.backoff_cap);
        }
    }

    /// One canary round at the scheduled instant `at` (round index `seq`).
    /// Probes fan across the pool; verdicts apply in slot order, then any
    /// engine Down past `reboot_after` is rebooted.
    fn canary_round(&mut self, at: Tick, seq: u64) {
        let policy = self.policy;
        let seed = self.schedule.seed;
        let effs: Vec<Option<EffectiveFaults>> = self
            .slots
            .iter()
            .enumerate()
            .map(|(i, s)| {
                if s.ready_at > at {
                    return None; // mid-reboot: nothing to probe
                }
                Some(self.schedule.effective(
                    i,
                    at,
                    s.spec.ber,
                    s.spec.tech,
                    s.spec.glb_delta,
                    s.spec.lsb_delta,
                ))
            })
            .collect();
        let verdicts: Vec<Option<bool>> = self
            .pool
            .map_range(effs.len(), |i| {
                effs[i].map(|eff| canary_passes(seed, i as u64, seq, &eff, &policy))
            });
        for (i, v) in verdicts.into_iter().enumerate() {
            let Some(pass) = v else { continue };
            let slot = &mut self.slots[i];
            slot.canaries += 1;
            if pass {
                slot.note_pass(at, &policy);
            } else {
                slot.canary_failures += 1;
                slot.note_failure(at, 1, &policy);
            }
        }
        let due: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.health == Health::Down
                    && s.down_since.is_some_and(|t| at.duration_since(t) >= policy.reboot_after)
            })
            .map(|(i, _)| i)
            .collect();
        for i in due {
            self.reboot(i, at);
        }
    }

    /// Reboot a slot: onto the fallback spec the first time (recorded as a
    /// fallback), in place afterwards. The slot re-enters as Degraded
    /// probation and becomes dispatchable after `reboot_time`.
    fn reboot(&mut self, i: usize, at: Tick) {
        let swap = self.fallback.clone().filter(|_| !self.slots[i].on_fallback);
        let swapped = swap.is_some();
        let ready = at + self.policy.reboot_time;
        let slot = &mut self.slots[i];
        if let Some(spec) = swap {
            slot.spec = spec;
            slot.on_fallback = true;
        }
        slot.reboots += 1;
        slot.down_since = None;
        slot.consecutive_failures = 0;
        slot.consecutive_passes = 0;
        slot.ready_at = ready;
        slot.set_health(Health::Degraded, at);
        if swapped {
            self.fallbacks += 1;
        }
    }

    /// Run one chaos scenario to completion and report. Deterministic under
    /// a virtual clock: discrete events are arrivals (`i * arrival_gap`),
    /// canary rounds (`k * canary_period`) and batcher deadlines; the clock
    /// advances to the earliest pending one, never spins.
    pub fn run(&mut self, cfg: &ChaosConfig, clock: &Clock) -> crate::Result<FleetReport> {
        let epoch = clock.now();
        let mut batcher = Batcher::new(cfg.batch, cfg.window, cfg.image_elems, cfg.queue_depth);
        let mut ladder = Vec::new();
        let mut bsz = 1;
        while bsz < cfg.batch {
            ladder.push(bsz);
            bsz *= 2;
        }
        ladder.push(cfg.batch);
        let router =
            Router::new(ladder, RouterPolicy { fill_threshold: 1.0, max_wait: cfg.window })?;
        let mut metrics = Metrics::new();
        let image = vec![0.5f32; cfg.image_elems];
        let clean_ber = self.slots[0].spec.ber;
        let clean_accuracy = self.est_accuracy(clean_ber.msb_ber, clean_ber.lsb_ber);

        let mut admitted: usize = 0;
        let mut canary_seq: u64 = 0;
        let arrival = |i: usize| epoch + cfg.arrival_gap * (i as u32);
        loop {
            let now = clock.now();
            while admitted < cfg.requests && arrival(admitted) <= now {
                batcher.push(Request::new(admitted as u64, image.clone(), arrival(admitted)));
                admitted += 1;
            }
            while epoch + self.policy.canary_period * (canary_seq as u32 + 1) <= now {
                canary_seq += 1;
                let at = epoch + self.policy.canary_period * (canary_seq as u32);
                self.canary_round(at, canary_seq);
            }
            if let Some(capacity) = serve::next_dispatch(&batcher, &router, now) {
                if let Some(b) = batcher.form(capacity, now) {
                    self.dispatch_batch(&b, clock, &mut metrics);
                    continue;
                }
            }
            if admitted >= cfg.requests && batcher.pending() == 0 {
                break;
            }
            let mut target = epoch + self.policy.canary_period * (canary_seq as u32 + 1);
            if admitted < cfg.requests {
                target = target.min(arrival(admitted));
            }
            if batcher.pending() > 0 {
                let deadline = batcher.window.max(router.policy.max_wait);
                let wait = deadline
                    .saturating_sub(batcher.oldest_wait(now))
                    .max(Duration::from_nanos(1));
                target = target.min(now + wait);
            }
            clock.advance_to(target.max(now + Duration::from_nanos(1)));
        }

        let end = clock.now();
        let offered = cfg.requests as u64;
        let served = metrics.requests;
        let engines = self
            .slots
            .iter()
            .map(|s| EngineReport {
                id: s.id,
                label: s.spec.label.clone(),
                health: s.health,
                served: s.served,
                batches: s.batches,
                failed_dispatches: s.failed_dispatches,
                canaries: s.canaries,
                canary_failures: s.canary_failures,
                reboots: s.reboots,
                on_fallback: s.on_fallback,
                transitions: s.transitions.clone(),
            })
            .collect::<Vec<_>>();
        Ok(FleetReport {
            scenario: self.schedule.name.clone(),
            seed: self.schedule.seed,
            offered,
            served,
            dropped: self.dropped,
            rejected: batcher.rejected,
            malformed: batcher.malformed,
            retries: self.retries,
            reroutes: self.reroutes,
            fallbacks: self.fallbacks,
            reboots: engines.iter().map(|e| e.reboots).sum(),
            canaries: engines.iter().map(|e| e.canaries).sum(),
            canary_failures: engines.iter().map(|e| e.canary_failures).sum(),
            availability: if offered == 0 {
                100.0
            } else {
                served as f64 / offered as f64 * 100.0
            },
            est_accuracy: if self.acc_weight > 0.0 {
                self.acc_weighted / self.acc_weight
            } else {
                clean_accuracy
            },
            clean_accuracy,
            p50_us: metrics.latency.quantile(50.0),
            p99_us: metrics.latency.quantile(99.0),
            max_us: metrics.latency.max(),
            qwait_p50_us: metrics.queue_wait.quantile(50.0),
            qwait_max_us: metrics.queue_wait.max(),
            sim_elapsed: end.duration_since(epoch),
            throughput_rps: metrics.throughput(),
            engines,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_scenario(name: &str, requests: usize, parallel: usize) -> FleetReport {
        let schedule = FaultSchedule::builtin(name).unwrap();
        let mut sup = Supervisor::new(
            schedule,
            EngineSpec::paper_fleet(3),
            Some(EngineSpec::paper(GlbVariant::Sram)),
            SupervisorPolicy::default(),
            parallel,
        )
        .unwrap();
        let cfg = ChaosConfig { requests, parallel, ..Default::default() };
        sup.run(&cfg, &Clock::virtual_at_zero()).unwrap()
    }

    fn accounting_closes(r: &FleetReport) {
        assert_eq!(
            r.served + r.dropped + r.rejected + r.malformed,
            r.offered,
            "every offered request must be served, dropped, rejected or malformed"
        );
        assert_eq!(r.served, r.engines.iter().map(|e| e.served).sum::<u64>());
    }

    #[test]
    fn policy_floors_adapt_to_slow_specs() {
        let mut spec = EngineSpec::paper(GlbVariant::SttAiUltra);
        spec.service = Duration::from_millis(20);
        let sup = Supervisor::new(
            FaultSchedule::calm(),
            vec![spec],
            None,
            SupervisorPolicy::default(),
            1,
        )
        .unwrap();
        // 2x the slowest service, and a deadline wide enough for retries.
        assert_eq!(sup.policy().attempt_timeout, Duration::from_millis(40));
        assert_eq!(sup.policy().deadline, Duration::from_millis(160));
        // Fast specs keep the defaults.
        let sup = Supervisor::new(
            FaultSchedule::calm(),
            EngineSpec::paper_fleet(1),
            None,
            SupervisorPolicy::default(),
            1,
        )
        .unwrap();
        assert_eq!(sup.policy().attempt_timeout, Duration::from_millis(2));
        assert_eq!(sup.policy().deadline, Duration::from_millis(8));
    }

    #[test]
    fn empty_fleet_is_an_error_not_a_panic() {
        let err = Supervisor::new(
            FaultSchedule::calm(),
            Vec::new(),
            None,
            SupervisorPolicy::default(),
            1,
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one engine"), "{err}");
    }

    #[test]
    fn health_machine_walks_degraded_down_and_back() {
        let policy = SupervisorPolicy::default();
        let mut s = EngineSlot::new(0, EngineSpec::paper(GlbVariant::SttAiUltra));
        let t = |ms: u64| Tick::ZERO + Duration::from_millis(ms);
        s.note_failure(t(1), 1, &policy);
        assert_eq!(s.health, Health::Healthy, "one failure is not an episode");
        s.note_failure(t(2), 1, &policy);
        assert_eq!(s.health, Health::Degraded);
        s.note_failure(t(3), 1, &policy);
        s.note_failure(t(4), 1, &policy);
        assert_eq!(s.health, Health::Down);
        // Recovery climbs one level per `recover_after` passes.
        s.note_pass(t(5), &policy);
        assert_eq!(s.health, Health::Down);
        s.note_pass(t(6), &policy);
        assert_eq!(s.health, Health::Degraded);
        s.note_pass(t(7), &policy);
        s.note_pass(t(8), &policy);
        assert_eq!(s.health, Health::Healthy);
        // The full walk is logged in order.
        let states: Vec<Health> = s.transitions.iter().map(|(_, h)| *h).collect();
        assert_eq!(
            states,
            vec![Health::Degraded, Health::Down, Health::Degraded, Health::Healthy]
        );
        // A pass resets the failure streak: no flapping from stale counts.
        s.note_failure(t(9), 1, &policy);
        s.note_pass(t(10), &policy);
        s.note_failure(t(11), 1, &policy);
        assert_eq!(s.health, Health::Healthy);
    }

    #[test]
    fn crash_failure_floors_the_engine_immediately() {
        let policy = SupervisorPolicy::default();
        let mut s = EngineSlot::new(0, EngineSpec::paper(GlbVariant::SttAiUltra));
        s.note_failure(Tick::ZERO, policy.down_after, &policy);
        assert_eq!(s.health, Health::Down, "crash weight skips Degraded dwell");
        assert!(s.down_since.is_some());
    }

    #[test]
    fn pick_engine_prefers_healthy_and_skips_down_tried_and_rebooting() {
        let mut sup = Supervisor::new(
            FaultSchedule::calm(),
            EngineSpec::paper_fleet(4),
            None,
            SupervisorPolicy::default(),
            1,
        )
        .unwrap();
        let now = Tick::ZERO + Duration::from_millis(1);
        // Round-robin over the healthy fleet.
        assert_eq!(sup.pick_engine(&[], now), Some(0));
        assert_eq!(sup.pick_engine(&[], now), Some(1));
        // Degrade 2, floor 3, put 0 mid-reboot: only 1 is Healthy+ready.
        sup.slots[2].set_health(Health::Degraded, now);
        sup.slots[3].set_health(Health::Down, now);
        sup.slots[0].ready_at = now + Duration::from_millis(1);
        assert_eq!(sup.pick_engine(&[], now), Some(1));
        // With 1 already tried, the Degraded engine is the fallback pick;
        // Down and rebooting slots never serve.
        assert_eq!(sup.pick_engine(&[1], now), Some(2));
        assert_eq!(sup.pick_engine(&[1, 2], now), None);
        // After the reboot window, slot 0 is dispatchable again.
        assert_eq!(sup.pick_engine(&[1, 2], now + Duration::from_millis(2)), Some(0));
    }

    #[test]
    fn calm_scenario_serves_everything_cleanly() {
        let r = run_scenario("calm", 400, 1);
        accounting_closes(&r);
        assert_eq!(r.served, 400);
        assert_eq!(r.availability, 100.0);
        assert_eq!((r.dropped, r.retries, r.reroutes, r.fallbacks, r.reboots), (0, 0, 0, 0, 0));
        assert!(
            (r.est_accuracy - r.clean_accuracy).abs() < 1e-12,
            "no faults, no accuracy gap: {} vs {}",
            r.est_accuracy,
            r.clean_accuracy
        );
        assert!(r.canaries > 0, "canaries probe even a calm fleet");
        assert_eq!(r.canary_failures, 0);
        for e in &r.engines {
            assert_eq!(e.health, Health::Healthy);
            assert!(e.transitions.is_empty(), "engine {} never left Healthy", e.id);
        }
        assert!(r.throughput_rps > 0.0);
    }

    #[test]
    fn burst_ber_degrades_gracefully_and_reboots_to_fallback() {
        let r = run_scenario("burst_ber", 2000, 1);
        accounting_closes(&r);
        // The golden story: availability holds through the storm...
        assert!(r.availability >= 99.0, "availability {:.3}% < 99%", r.availability);
        assert!(r.dropped <= 20, "dropped {}", r.dropped);
        // ...the stall forces retries and reroutes...
        assert!(r.retries > 0, "the engine-2 stall must force retries");
        assert!(r.reroutes > 0, "stalled dispatches must reroute");
        // ...and sustained canary failures walk engine 0 Degraded -> Down
        // and reboot it onto the SRAM fallback.
        assert!(r.fallbacks >= 1, "engine 0 must reboot onto the fallback");
        let e0 = &r.engines[0];
        assert!(e0.on_fallback);
        let states: Vec<Health> = e0.transitions.iter().map(|(_, h)| *h).collect();
        assert!(states.contains(&Health::Degraded) && states.contains(&Health::Down), "{states:?}");
        assert!(r.canary_failures > 0);
        // Storm traffic costs estimated accuracy.
        assert!(r.est_accuracy <= r.clean_accuracy);
    }

    #[test]
    fn crash_loop_floors_engine_zero_without_losing_the_fleet() {
        let r = run_scenario("crash_loop", 1200, 1);
        accounting_closes(&r);
        assert!(r.availability >= 99.0, "availability {:.3}%", r.availability);
        let e0 = &r.engines[0];
        let states: Vec<Health> = e0.transitions.iter().map(|(_, h)| *h).collect();
        assert!(states.contains(&Health::Down), "crashes must floor engine 0: {states:?}");
    }

    #[test]
    fn reports_are_byte_identical_across_runs_and_worker_counts() {
        let a = run_scenario("burst_ber", 800, 1);
        let b = run_scenario("burst_ber", 800, 1);
        let c = run_scenario("burst_ber", 800, 4);
        assert_eq!(a.render(), b.render(), "same scenario, same report");
        assert_eq!(a.render(), c.render(), "worker count must not leak into the report");
        assert_eq!(a.to_json().to_string(), c.to_json().to_string());
    }
}
