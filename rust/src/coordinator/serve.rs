//! Closed-loop serving driver: feeds synthetic requests drawn from the
//! artifact test set through the batcher + router + engine and reports
//! metrics. (The async open-loop variant lives in examples/serve.rs.)
//!
//! Batch formation is driven by the same two signals a production
//! coordinator schedules on: [`Batcher::ready`] (batch full, or the window
//! expired on the oldest request) gates the loop, and
//! [`Router::dispatch`] picks the executable variant from the queue depth
//! and the head-of-line wait. Queueing delay flows into
//! [`Metrics::queue_wait`] via [`crate::coordinator::Batch::oldest_wait`].

use std::time::{Duration, Instant};

use super::batcher::{Batcher, Request};
use super::engine::Engine;
use super::metrics::Metrics;
use super::router::{Router, RouterPolicy};

/// One scheduling decision: the batch capacity to fire now, or `None` to
/// keep waiting. Pure function of (batcher state, router policy, clock) —
/// the unit-testable core of [`closed_loop`].
pub fn next_dispatch(batcher: &Batcher, router: &Router, now: Instant) -> Option<usize> {
    if !batcher.ready(now) {
        return None;
    }
    router.dispatch(batcher.pending(), batcher.oldest_wait(now)).map(|v| v.batch)
}

/// Run `n_requests` through the engine at the given batch size; returns a
/// human-readable metrics summary.
pub fn closed_loop(engine: &Engine, n_requests: usize, batch: usize) -> crate::Result<String> {
    let model = engine.model_for_batch(batch)?;
    let (images, _) = engine.manifest.load_testset()?;
    let per_image: usize = engine.manifest.testset.image_shape.iter().product::<i64>() as usize;
    let n_test = engine.manifest.testset.n;

    let window = Duration::from_micros(200);
    let mut batcher = Batcher::new(batch, window, per_image, n_requests + 1);
    // One compiled variant in the closed loop; the deadline path of the
    // policy shares the batcher's window so the tail fires when it expires.
    let router = Router::new(vec![batch], RouterPolicy { fill_threshold: 1.0, max_wait: window });
    let mut metrics = Metrics::new();

    for i in 0..n_requests {
        let src = i % n_test;
        let img = images[src * per_image..(src + 1) * per_image].to_vec();
        batcher.push(Request::new(i as u64, img));
    }
    while batcher.pending() > 0 {
        let now = Instant::now();
        let Some(capacity) = next_dispatch(&batcher, &router, now) else {
            // Partial tail inside the window: spin until it expires (the
            // closed loop has no new arrivals to wait for).
            std::hint::spin_loop();
            continue;
        };
        if let Some(b) = batcher.form(capacity, now) {
            let t0 = Instant::now();
            let logits = engine.infer(&model, &b.images)?;
            debug_assert_eq!(logits.len(), capacity * model.art.num_classes);
            metrics.record_batch_waited(b.real, b.capacity, t0.elapsed(), b.oldest_wait);
        }
    }
    Ok(format!(
        "served {n_requests} requests (batch {batch}): {} | throughput {:.1} req/s",
        metrics.summary(),
        metrics.throughput()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![0.25; 4])
    }

    fn harness(window: Duration) -> (Batcher, Router) {
        let batcher = Batcher::new(4, window, 4, 8);
        let router = Router::new(vec![1, 4], RouterPolicy { fill_threshold: 1.0, max_wait: window });
        (batcher, router)
    }

    #[test]
    fn full_queue_dispatches_immediately() {
        let (mut b, r) = harness(Duration::from_millis(5));
        for i in 0..4 {
            b.push(req(i));
        }
        assert_eq!(next_dispatch(&b, &r, Instant::now()), Some(4));
    }

    #[test]
    fn partial_queue_waits_for_the_window_then_fires() {
        let (mut b, r) = harness(Duration::from_millis(5));
        b.push(req(1));
        let now = Instant::now();
        assert_eq!(next_dispatch(&b, &r, now), None, "fresh partial batch waits");
        let later = now + Duration::from_millis(10);
        // Window expired: the deadline path picks the smallest covering
        // variant (batch 1 — no padding), not the big one.
        assert_eq!(next_dispatch(&b, &r, later), Some(1));
        let batch = b.form(1, later).unwrap();
        assert_eq!(batch.real, 1);
        assert!(batch.oldest_wait >= Duration::from_millis(9), "queueing delay recorded");
    }

    #[test]
    fn zero_window_serving_drains_without_waiting() {
        // Regression for the zero-window configuration: every pending
        // request is immediately past its (zero) deadline, so the loop
        // drains batch by batch without ever sleeping — and without panics.
        let (mut b, r) = harness(Duration::ZERO);
        for i in 0..6 {
            b.push(req(i));
        }
        let mut drained = 0;
        while b.pending() > 0 {
            let now = Instant::now();
            let cap = next_dispatch(&b, &r, now).expect("zero window always dispatches");
            let batch = b.form(cap, now).unwrap();
            drained += batch.real;
        }
        assert_eq!(drained, 6);
    }

    #[test]
    fn idle_queue_never_dispatches() {
        let (b, r) = harness(Duration::ZERO);
        assert_eq!(next_dispatch(&b, &r, Instant::now()), None);
    }

    #[test]
    fn backpressure_rejects_while_window_holds_then_recovers() {
        // Queue at depth, window still open: pushes bounce, the dispatcher
        // holds (queue below fill), and once the window expires the batch
        // fires and frees space — the ready/dispatch path and backpressure
        // compose without deadlock.
        let r = Router::new(
            vec![1, 4],
            RouterPolicy { fill_threshold: 1.0, max_wait: Duration::from_millis(5) },
        );
        // max_batch 16 keeps `ready()` gated on the window, not on fill.
        let mut batcher = Batcher::new(16, Duration::from_millis(5), 4, 8);
        for i in 0..8 {
            assert!(batcher.push(req(i)));
        }
        assert!(!batcher.push(req(99)));
        let now = Instant::now();
        assert_eq!(next_dispatch(&batcher, &r, now), None, "below fill, window open");
        let later = now + Duration::from_millis(10);
        let cap = next_dispatch(&batcher, &r, later).expect("deadline fires");
        assert_eq!(cap, 4, "largest variant covers the 8-deep queue");
        batcher.form(cap, later).unwrap();
        assert!(batcher.push(req(100)), "space freed");
    }
}
