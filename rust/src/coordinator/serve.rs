//! Closed-loop serving driver: feeds synthetic requests drawn from the
//! artifact test set through the batcher + router + engine and reports
//! metrics. (The async open-loop variant lives in examples/serve.rs.)
//!
//! Batch formation is driven by the same two signals a production
//! coordinator schedules on: [`Batcher::ready`] (batch full, or the window
//! expired on the oldest request) gates the loop, and
//! [`Router::dispatch`] picks the executable variant from the queue depth
//! and the head-of-line wait. Queueing delay flows into
//! [`Metrics::queue_wait`] via [`crate::coordinator::Batch::oldest_wait`].
//!
//! All time comes from an injected [`Clock`]: `closed_loop` runs on a wall
//! clock, while tests and the fault-injection harness
//! (`coordinator::supervisor`) drive the same [`drain`] core with a virtual
//! clock for bit-reproducible schedules.

use std::time::Duration;

use super::batcher::{Batch, Batcher, Request};
use super::engine::Engine;
use super::metrics::Metrics;
use super::router::{Router, RouterPolicy};
use crate::util::clock::{Clock, Tick};

/// One scheduling decision: the batch capacity to fire now, or `None` to
/// keep waiting. Pure function of (batcher state, router policy, clock) —
/// the unit-testable core of [`closed_loop`].
pub fn next_dispatch(batcher: &Batcher, router: &Router, now: Tick) -> Option<usize> {
    if !batcher.ready(now) {
        return None;
    }
    router.dispatch(batcher.pending(), batcher.oldest_wait(now)).map(|v| v.batch)
}

/// Drain every pending request through `infer`, recording each executed
/// batch into `metrics`.
///
/// When no batch is ready the clock advances *boundedly* to the next
/// scheduling deadline (`max(window, max_wait)` past the oldest arrival) —
/// never an unbounded spin. `infer` returns the batch's service latency;
/// on a virtual clock the drain advances past it (the engine call itself is
/// instantaneous in wall time), on a wall clock the call already consumed
/// real time and `now()` is simply re-read.
///
/// The scheduling core lives in [`fleet::run_closed`]: the closed loop is
/// the degenerate one-shard/closed-arrival configuration of the fleet
/// simulator's event schedule, so delegating keeps the two paths
/// byte-identical by construction.
pub fn drain(
    batcher: &mut Batcher,
    router: &Router,
    metrics: &mut Metrics,
    clock: &Clock,
    infer: impl FnMut(&Batch) -> crate::Result<Duration>,
) -> crate::Result<()> {
    super::fleet::run_closed(batcher, router, metrics, clock, infer)
}

/// The one-line serving report shared by [`closed_loop`] and the CLI.
pub fn summary_line(n_requests: usize, batch: usize, metrics: &Metrics) -> String {
    format!(
        "served {n_requests} requests (batch {batch}): {} | throughput {:.1} req/s",
        metrics.summary(),
        metrics.throughput()
    )
}

/// Run `n_requests` through the engine at the given batch size on a wall
/// clock; returns a human-readable metrics summary.
pub fn closed_loop(engine: &Engine, n_requests: usize, batch: usize) -> crate::Result<String> {
    closed_loop_with(engine, n_requests, batch, &Clock::wall())
}

/// [`closed_loop`] with an injected clock (virtual clocks make the schedule
/// deterministic; inference latency is still measured by the engine).
pub fn closed_loop_with(
    engine: &Engine,
    n_requests: usize,
    batch: usize,
    clock: &Clock,
) -> crate::Result<String> {
    if n_requests == 0 {
        // Nothing offered: report a well-formed empty summary instead of
        // relying on the drain loop never being entered.
        return Ok(summary_line(0, batch, &Metrics::new()));
    }
    let model = engine.model_for_batch(batch)?;
    let (images, _) = engine.manifest.load_testset()?;
    let per_image: usize = engine.manifest.testset.image_shape.iter().product::<i64>() as usize;
    let n_test = engine.manifest.testset.n;

    let window = Duration::from_micros(200);
    let mut batcher = Batcher::new(batch, window, per_image, n_requests + 1);
    // One compiled variant in the closed loop; the deadline path of the
    // policy shares the batcher's window so the tail fires when it expires.
    let router = Router::new(vec![batch], RouterPolicy { fill_threshold: 1.0, max_wait: window })?;
    let mut metrics = Metrics::new();

    let t0 = clock.now();
    for i in 0..n_requests {
        let src = i % n_test;
        let img = images[src * per_image..(src + 1) * per_image].to_vec();
        batcher.push(Request::new(i as u64, img, t0));
    }
    let num_classes = model.art.num_classes;
    drain(&mut batcher, &router, &mut metrics, clock, |b| {
        let t0 = clock.now();
        let logits = engine.infer(&model, &b.images)?;
        debug_assert_eq!(logits.len(), b.capacity * num_classes);
        Ok(clock.now().duration_since(t0))
    })?;
    Ok(summary_line(n_requests, batch, &metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![0.25; 4], Tick::ZERO)
    }

    fn harness(window: Duration) -> (Batcher, Router) {
        let batcher = Batcher::new(4, window, 4, 8);
        let router = Router::new(vec![1, 4], RouterPolicy { fill_threshold: 1.0, max_wait: window })
            .expect("variants");
        (batcher, router)
    }

    #[test]
    fn full_queue_dispatches_immediately() {
        let (mut b, r) = harness(Duration::from_millis(5));
        for i in 0..4 {
            b.push(req(i));
        }
        assert_eq!(next_dispatch(&b, &r, Tick::ZERO), Some(4));
    }

    #[test]
    fn partial_queue_waits_for_the_window_then_fires() {
        let (mut b, r) = harness(Duration::from_millis(5));
        b.push(req(1));
        assert_eq!(next_dispatch(&b, &r, Tick::ZERO), None, "fresh partial batch waits");
        let later = Tick::ZERO + Duration::from_millis(10);
        // Window expired: the deadline path picks the smallest covering
        // variant (batch 1 — no padding), not the big one.
        assert_eq!(next_dispatch(&b, &r, later), Some(1));
        let batch = b.form(1, later).unwrap();
        assert_eq!(batch.real, 1);
        assert_eq!(batch.oldest_wait, Duration::from_millis(10), "queueing delay recorded");
    }

    #[test]
    fn zero_window_serving_drains_without_waiting() {
        // Regression for the zero-window configuration: every pending
        // request is immediately past its (zero) deadline, so the loop
        // drains batch by batch without ever sleeping — and without panics.
        let (mut b, r) = harness(Duration::ZERO);
        for i in 0..6 {
            b.push(req(i));
        }
        let mut drained = 0;
        while b.pending() > 0 {
            let now = Tick::ZERO;
            let cap = next_dispatch(&b, &r, now).expect("zero window always dispatches");
            let batch = b.form(cap, now).unwrap();
            drained += batch.real;
        }
        assert_eq!(drained, 6);
    }

    #[test]
    fn idle_queue_never_dispatches() {
        let (b, r) = harness(Duration::ZERO);
        assert_eq!(next_dispatch(&b, &r, Tick::ZERO), None);
    }

    #[test]
    fn backpressure_rejects_while_window_holds_then_recovers() {
        // Queue at depth, window still open: pushes bounce, the dispatcher
        // holds (queue below fill), and once the window expires the batch
        // fires and frees space — the ready/dispatch path and backpressure
        // compose without deadlock.
        let r = Router::new(
            vec![1, 4],
            RouterPolicy { fill_threshold: 1.0, max_wait: Duration::from_millis(5) },
        )
        .expect("variants");
        // max_batch 16 keeps `ready()` gated on the window, not on fill.
        let mut batcher = Batcher::new(16, Duration::from_millis(5), 4, 8);
        for i in 0..8 {
            assert!(batcher.push(req(i)));
        }
        assert!(!batcher.push(req(99)));
        assert_eq!(next_dispatch(&batcher, &r, Tick::ZERO), None, "below fill, window open");
        let later = Tick::ZERO + Duration::from_millis(10);
        let cap = next_dispatch(&batcher, &r, later).expect("deadline fires");
        assert_eq!(cap, 4, "largest variant covers the 8-deep queue");
        batcher.form(cap, later).unwrap();
        assert!(batcher.push(req(100)), "space freed");
    }

    #[test]
    fn drain_advances_boundedly_through_a_partial_tail() {
        // Regression for the unbounded spin_loop tail wait: a partial batch
        // below the fill threshold must drain by *advancing the clock to
        // the window deadline*, not by spinning. On a virtual clock the
        // number of advances is exact and small.
        let window = Duration::from_millis(5);
        let mut batcher = Batcher::new(4, window, 4, 8);
        let router =
            Router::new(vec![1, 4], RouterPolicy { fill_threshold: 1.0, max_wait: window })
                .expect("variants");
        let clock = Clock::virtual_at_zero();
        batcher.push(Request::new(7, vec![0.25; 4], clock.now()));
        let mut metrics = Metrics::new();
        let mut calls = 0;
        drain(&mut batcher, &router, &mut metrics, &clock, |b| {
            calls += 1;
            assert_eq!(b.real, 1);
            Ok(Duration::from_micros(100))
        })
        .unwrap();
        assert_eq!(calls, 1, "single tail batch fires exactly once");
        assert_eq!(metrics.batches, 1);
        assert_eq!(metrics.requests, 1);
        // Clock advanced to the window deadline, then past the service
        // latency — no further (bounded, not a spin).
        assert_eq!(clock.now(), Tick::ZERO + window + Duration::from_micros(100));
        assert_eq!(metrics.queue_wait.max(), 5_000, "tail waited exactly the window");
    }

    #[test]
    fn drain_full_batches_then_tail() {
        // 6 requests, batch 4: one full batch fires at t=0, the 2-deep tail
        // waits out the window, then fires on the deadline path.
        let window = Duration::from_millis(2);
        let mut batcher = Batcher::new(4, window, 4, 16);
        let router =
            Router::new(vec![1, 4], RouterPolicy { fill_threshold: 1.0, max_wait: window })
                .expect("variants");
        let clock = Clock::virtual_at_zero();
        for i in 0..6 {
            batcher.push(Request::new(i, vec![0.25; 4], clock.now()));
        }
        let mut metrics = Metrics::new();
        drain(&mut batcher, &router, &mut metrics, &clock, |_| Ok(Duration::from_micros(50)))
            .unwrap();
        assert_eq!(metrics.batches, 2);
        assert_eq!(metrics.requests, 6);
        assert_eq!(metrics.padded_rows, 2, "tail padded 2->4");
        assert!(metrics.throughput() > 0.0);
    }

    #[test]
    fn zero_requests_reports_a_well_formed_empty_summary() {
        // Regression: closed_loop(n_requests = 0) must return a complete
        // summary line, not depend on loop non-entry. summary_line is the
        // exact formatting core closed_loop uses for that early return.
        let s = summary_line(0, 16, &Metrics::new());
        assert!(s.starts_with("served 0 requests (batch 16):"), "{s}");
        assert!(s.contains("batches=0"), "{s}");
        assert!(s.contains("requests=0"), "{s}");
        assert!(s.contains("throughput 0.0 req/s"), "{s}");
    }
}
