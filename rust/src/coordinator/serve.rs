//! Closed-loop serving driver: feeds synthetic requests drawn from the
//! artifact test set through the batcher + engine and reports metrics.
//! (The async open-loop variant lives in examples/serve.rs on tokio.)

use std::time::{Duration, Instant};

use super::batcher::{Batcher, Request};
use super::engine::Engine;
use super::metrics::Metrics;

/// Run `n_requests` through the engine at the given batch size; returns a
/// human-readable metrics summary.
pub fn closed_loop(engine: &Engine, n_requests: usize, batch: usize) -> crate::Result<String> {
    let model = engine.model_for_batch(batch)?;
    let (images, _) = engine.manifest.load_testset()?;
    let per_image: usize = engine.manifest.testset.image_shape.iter().product::<i64>() as usize;
    let n_test = engine.manifest.testset.n;

    let mut batcher = Batcher::new(batch, Duration::from_micros(200), per_image, n_requests + 1);
    let mut metrics = Metrics::new();

    for i in 0..n_requests {
        let src = i % n_test;
        let img = images[src * per_image..(src + 1) * per_image].to_vec();
        batcher.push(Request::new(i as u64, img));
    }
    while batcher.pending() > 0 {
        let now = Instant::now();
        if let Some(b) = batcher.form(batch, now) {
            let t0 = Instant::now();
            let logits = engine.infer(&model, &b.images)?;
            debug_assert_eq!(logits.len(), batch * model.art.num_classes);
            metrics.record_batch(b.real, b.capacity, t0.elapsed());
        }
    }
    Ok(format!(
        "served {n_requests} requests (batch {batch}): {} | throughput {:.1} req/s",
        metrics.summary(),
        metrics.throughput()
    ))
}
