//! Discrete-event fleet simulator: open-loop arrivals, heterogeneous
//! engines, SLO-aware routing, and streaming tail metrics at O(1) memory.
//!
//! Where [`super::serve`] drives one engine closed-loop (every request
//! queued at t = 0) and [`super::supervisor`] serializes a fault scenario
//! through one dispatch path, the fleet simulator is the general form: a
//! single event heap on a virtual [`Clock`] interleaves
//!
//! * **arrivals** from an open-loop [`ArrivalTrace`] generator (Poisson,
//!   diurnal, bursty, uniform, or the degenerate closed pattern),
//! * **completions** of in-flight batches, one per shard at a time (each
//!   engine is a serial device with its modeled [`EngineSpec::service`]
//!   latency),
//! * **wakes** for shards holding a partial batch whose window deadline is
//!   the next interesting instant, and
//! * **autoscale** rounds that activate or retire engines on queue-depth
//!   hysteresis with a per-engine warm-up.
//!
//! Routing is least-outstanding with an SLO-aware fallback: when even the
//! emptiest shard's projected completion (warm-up residue plus
//! `ceil((outstanding+1)/batch)` service quanta) exceeds the SLO, the
//! request instead goes to the shard with the *smallest projection* — in a
//! heterogeneous fleet that is the fast SRAM island, which is exactly the
//! paper's case for keeping one latency-optimal build next to the
//! energy-optimal STT-AI Ultra pool.
//!
//! Under a non-default [`TenantMix`] the whole stack becomes class-aware:
//! per-tenant arrival generators are merged into one seed-deterministic
//! [`MuxArrivalGen`] stream, every request carries its tenant tag through the shard
//! batchers' weighted deficit-round-robin queues, routing steers each
//! class to its tier island (tight → fastest service, relaxed → lowest
//! energy per request, both subject to an optional accuracy floor), the
//! autoscaler holds the best active projection against the *tightest*
//! class SLO, and the report gains per-tenant [`TenantLedger`] sections
//! with the same byte-identical-at-any-worker-count guarantee. The
//! degenerate single-default mix takes none of these branches and
//! reproduces the pre-tenant reports byte for byte.
//!
//! Per-request sojourn latencies and per-request energy stream into
//! fixed-footprint [`QuantileSketch`]es (relative error ≤ 1/64), merged in
//! shard order into the fleet report — memory stays O(1) from 1e6 to 1e8
//! requests and the merged report is byte-identical across reruns and
//! `--parallel` settings (the simulation itself is single-threaded; the
//! flag is accepted for CLI symmetry with `serve`/`chaos` and must not
//! change a byte).
//!
//! A [`FaultSchedule`] can ride along as a fleet policy: a crashed or
//! stalled engine refuses dispatch (the batch stays queued and the shard
//! retries a window later), and a latency-spike fault stretches service
//! time — composing the chaos DSL with open-loop traffic.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Duration;

use crate::util::clock::{Clock, Tick};
use crate::util::json::Json;
use crate::util::stats::QuantileSketch;

use super::batcher::{Batch, Batcher, Request};
use super::faults::FaultSchedule;
use super::metrics::{Metrics, TenantLedger};
use super::router::{Router, RouterPolicy};
use super::serve;
use super::supervisor::EngineSpec;
use super::tenant::{SloTier, TenantMix};
use super::traffic::{ArrivalTrace, MuxArrivalGen};

/// Fleet-level scheduling knobs (routing SLO + autoscaler hysteresis).
#[derive(Debug, Clone, Copy)]
pub struct FleetPolicy {
    /// Per-request sojourn target: routing falls back to the fastest
    /// projection when the least-loaded shard would miss it, and every
    /// completed request is checked against it for the violation count.
    pub slo: Duration,
    /// Autoscaler cadence.
    pub scale_period: Duration,
    /// Delay between activating an engine and its first dispatch.
    pub warmup: Duration,
    /// Scale up when total queued requests exceed this many per active
    /// engine.
    pub up_per_engine: usize,
    /// Scale down when total queued requests fall below this many per
    /// active engine (hysteresis band: `down < up`).
    pub down_per_engine: usize,
    /// Never scale below this many active engines.
    pub min_engines: usize,
}

impl Default for FleetPolicy {
    fn default() -> Self {
        Self {
            slo: Duration::from_millis(10),
            scale_period: Duration::from_millis(5),
            warmup: Duration::from_millis(2),
            up_per_engine: 32,
            down_per_engine: 4,
            min_engines: 1,
        }
    }
}

/// Fleet-run shape: offered load, batching knobs, and optional policies.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Total requests offered by the arrival trace.
    pub requests: usize,
    /// Max batch (largest compiled variant of every shard's ladder).
    pub batch: usize,
    /// Synthetic image elements per request.
    pub image_elems: usize,
    /// Per-shard queue depth (backpressure bound).
    pub queue_depth: usize,
    /// Batching window (also each shard router's deadline).
    pub window: Duration,
    /// Start with `policy.min_engines` active and let the autoscaler manage
    /// the rest; `false` keeps every engine active from t = 0.
    pub autoscale: bool,
    /// Accepted for CLI symmetry with `serve`/`chaos`. The simulation is
    /// single-threaded; any value produces the identical report.
    pub parallel: usize,
    pub policy: FleetPolicy,
    /// Optional chaos composition: crashed/stalled engines refuse
    /// dispatch, latency faults stretch service time.
    pub faults: Option<FaultSchedule>,
    /// The tenant mix sharing this fleet. The default single-tenant mix
    /// takes every legacy code path and reproduces pre-tenant reports
    /// byte for byte.
    pub tenants: TenantMix,
    /// Force the legacy single-queue scheduler (one FIFO class, global-SLO
    /// routing and autoscaling) while keeping per-tenant arrival streams,
    /// tags and ledgers — the ablation baseline the hetero payoff gate
    /// compares against.
    pub classless: bool,
    /// Keep a per-request arrival/completion/tenant log for
    /// [`FleetSim::render_record`] (the `fleet --record` JSON-lines dump).
    pub record: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            requests: 20_000,
            batch: 16,
            image_elems: 4,
            queue_depth: 4096,
            window: Duration::from_micros(500),
            autoscale: false,
            parallel: 1,
            policy: FleetPolicy::default(),
            faults: None,
            tenants: TenantMix::default(),
            classless: false,
            record: false,
        }
    }
}

/// One real row of a batch in service: identity, tenant class, and the
/// arrival instant its sojourn is measured from.
#[derive(Debug, Clone, Copy)]
struct InflightRow {
    id: u64,
    tenant: u32,
    enqueued: Tick,
}

/// One batch in service on a shard (the payload of its completion event).
#[derive(Debug, Clone)]
struct Inflight {
    real: usize,
    capacity: usize,
    /// The real rows — sojourn latency is completion minus arrival, per
    /// request, booked into the row's tenant ledger.
    rows: Vec<InflightRow>,
}

/// One line of the `--record` log: a request's full fleet transit.
#[derive(Debug, Clone, Copy)]
struct RecordRow {
    id: u64,
    tenant: u32,
    engine: usize,
    arrival_ns: u64,
    completion_ns: u64,
}

#[derive(Debug)]
enum EventKind {
    /// The next trace arrival (exactly one in the heap at a time), tagged
    /// with the tenant whose stream produced it.
    Arrival { tenant: u32 },
    /// A shard finishes its in-service batch.
    Complete { shard: usize, job: Inflight },
    /// Re-scan a shard holding queued work (window deadline, warm-up end,
    /// or fault-retry instant).
    Wake { shard: usize },
    /// One autoscaler round.
    Autoscale,
}

/// Heap entry. Ordered by `(at, seq)` only — `seq` is the global insertion
/// counter, so simultaneous events pop in creation order and the schedule
/// is fully deterministic.
#[derive(Debug)]
struct Event {
    at: Tick,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        (self.at, self.seq) == (other.at, other.seq)
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// One engine shard: spec + queue + per-shard streaming metrics.
struct Shard {
    spec: EngineSpec,
    batcher: Batcher,
    router: Router,
    /// Per-request sojourn latency (µs).
    latency: QuantileSketch,
    /// Per-request GLB energy (pJ — integer-exact for the paper's
    /// 1e-4 J-class figures, and mergeable like any sketch).
    energy_pj: QuantileSketch,
    served: u64,
    batches: u64,
    padded: u64,
    slo_violations: u64,
    /// Dispatches refused because the fault schedule had the engine
    /// crashed or stalled at that instant.
    fault_blocked: u64,
    /// Queued + in-service requests (the routing signal).
    outstanding: usize,
    peak_outstanding: usize,
    /// Completion instant of the batch in service (a shard is a serial
    /// device: one batch at a time).
    busy_until: Option<Tick>,
    /// Inactive shards receive no traffic until the autoscaler wakes them.
    active: bool,
    /// First dispatchable instant after (re)activation.
    warm_at: Tick,
    /// Times the autoscaler activated this shard.
    warm_boots: u64,
    /// Pending Wake event instant (at most one per shard in the heap).
    wake_at: Option<Tick>,
}

/// The discrete-event fleet simulator. Build with [`FleetSim::new`], run
/// once with [`FleetSim::run`].
pub struct FleetSim {
    trace: ArrivalTrace,
    cfg: FleetConfig,
    shards: Vec<Shard>,
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
    arrived: usize,
    events: u64,
    scale_ups: u64,
    scale_downs: u64,
    image: Vec<f32>,
    /// Class-aware scheduling on? (non-default mix and not forced
    /// classless — the legacy routing/admission paths run otherwise).
    tenant_aware: bool,
    /// Per-tenant accounting on? (any non-default mix, even classless, so
    /// the single-queue baseline reports the same ledgers).
    book_tenants: bool,
    /// Effective per-tenant SLOs (tenant order; unset SLOs inherit the
    /// fleet policy target).
    slos: Vec<Duration>,
    /// The tightest per-tenant SLO — the class-aware autoscaler's target.
    tightest_slo: Duration,
    ledgers: Vec<TenantLedger>,
    /// The merged arrival stream ended (a finite replay ran dry) before
    /// `cfg.requests` arrivals.
    exhausted: bool,
    record_log: Vec<RecordRow>,
}

impl FleetSim {
    /// Build a simulator over `specs` (shard order = engine index, also the
    /// deterministic sketch-merge order of the report).
    pub fn new(
        trace: ArrivalTrace,
        specs: Vec<EngineSpec>,
        cfg: FleetConfig,
    ) -> crate::Result<Self> {
        if specs.is_empty() {
            anyhow::bail!("fleet: need at least one engine spec");
        }
        let mut ladder = Vec::new();
        let mut bsz = 1;
        while bsz < cfg.batch {
            ladder.push(bsz);
            bsz *= 2;
        }
        ladder.push(cfg.batch);
        let min_active = cfg.policy.min_engines.max(1);
        let tenant_aware = !cfg.classless && !cfg.tenants.is_default();
        let book_tenants = !cfg.tenants.is_default();
        let slos: Vec<Duration> = (0..cfg.tenants.tenants.len())
            .map(|i| cfg.tenants.effective_slo(i, cfg.policy.slo))
            .collect();
        let tightest_slo = cfg.tenants.tightest_slo(cfg.policy.slo);
        let ledgers = vec![TenantLedger::new(); cfg.tenants.tenants.len()];
        // Class-aware admission only when the scheduler is tenant-aware;
        // the classless baseline keeps the historical single FIFO.
        let weights = if tenant_aware { cfg.tenants.weights() } else { vec![1] };
        let shards = specs
            .into_iter()
            .enumerate()
            .map(|(i, spec)| {
                let router = Router::new(
                    ladder.clone(),
                    RouterPolicy { fill_threshold: 1.0, max_wait: cfg.window },
                )?;
                Ok(Shard {
                    spec,
                    batcher: Batcher::with_weights(
                        cfg.batch,
                        cfg.window,
                        cfg.image_elems,
                        cfg.queue_depth,
                        &weights,
                    ),
                    router,
                    latency: QuantileSketch::new(),
                    energy_pj: QuantileSketch::new(),
                    served: 0,
                    batches: 0,
                    padded: 0,
                    slo_violations: 0,
                    fault_blocked: 0,
                    outstanding: 0,
                    peak_outstanding: 0,
                    busy_until: None,
                    active: !cfg.autoscale || i < min_active,
                    warm_at: Tick::ZERO,
                    warm_boots: 0,
                    wake_at: None,
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        let image = vec![0.5f32; cfg.image_elems];
        Ok(Self {
            trace,
            cfg,
            shards,
            heap: BinaryHeap::new(),
            seq: 0,
            arrived: 0,
            events: 0,
            scale_ups: 0,
            scale_downs: 0,
            image,
            tenant_aware,
            book_tenants,
            slos,
            tightest_slo,
            ledgers,
            exhausted: false,
            record_log: Vec::new(),
        })
    }

    fn push_event(&mut self, at: Tick, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Reverse(Event { at, seq: self.seq, kind }));
    }

    /// Schedule a re-scan of shard `i` at `at`, unless a Wake for it is
    /// already in the heap (at most one per shard; a too-early wake is a
    /// harmless extra scan, a too-late one only delays the dispatch it
    /// would have found — either way the schedule stays deterministic).
    fn schedule_wake(&mut self, i: usize, at: Tick) {
        if self.shards[i].wake_at.is_some() {
            return;
        }
        self.shards[i].wake_at = Some(at);
        self.push_event(at, EventKind::Wake { shard: i });
    }

    /// Projected completion of one more request routed to shard `i` now:
    /// warm-up residue plus whole service quanta for the batches ahead of
    /// it. Conservative (ignores partially-elapsed service) but monotone in
    /// queue depth, which is all the balancer needs.
    fn projected(&self, i: usize, now: Tick) -> Duration {
        let s = &self.shards[i];
        let batch = s.router.largest().batch.max(1);
        let ahead = (s.outstanding + 1).div_ceil(batch) as u32;
        s.warm_at.duration_since(now) + s.spec.service * ahead
    }

    /// Route one arrival: least-outstanding active shard (ties to the
    /// lowest index); when even that shard's projection misses the SLO,
    /// fall back to the globally fastest projection — the fast island of a
    /// heterogeneous fleet.
    fn route(&self, now: Tick) -> usize {
        let mut least = usize::MAX;
        let mut least_out = usize::MAX;
        for (i, s) in self.shards.iter().enumerate() {
            if s.active && s.outstanding < least_out {
                least = i;
                least_out = s.outstanding;
            }
        }
        debug_assert!(least != usize::MAX, "min_engines >= 1 keeps one shard active");
        if self.projected(least, now) <= self.cfg.policy.slo {
            return least;
        }
        let mut fast = least;
        let mut fast_proj = self.projected(least, now);
        for (i, s) in self.shards.iter().enumerate() {
            if !s.active || i == least {
                continue;
            }
            let p = self.projected(i, now);
            if p < fast_proj {
                fast = i;
                fast_proj = p;
            }
        }
        fast
    }

    /// Class-aware routing: within the tenant's eligible set (active
    /// shards over its accuracy floor), prefer the tier island — tight
    /// classes the fastest-service shards, relaxed classes the lowest
    /// energy per request, standard classes everything — then
    /// least-outstanding with lowest-index ties. When even that pick's
    /// projection misses the *tenant's* SLO, the island preference yields:
    /// fall back to the fastest projection among all eligible shards.
    fn route_tenant(&self, tenant: u32, now: Tick) -> usize {
        let spec = &self.cfg.tenants.tenants[tenant as usize];
        let floor = spec.accuracy_floor;
        let passes = |s: &Shard| floor.is_none_or(|f| s.spec.est_accuracy >= f);
        // If no active shard clears the floor, serving beats starving:
        // the floor filter falls away and every active shard is eligible.
        let any_pass = self.shards.iter().any(|s| s.active && passes(s));
        let eligible = |s: &Shard| s.active && (!any_pass || passes(s));
        let mut min_service = Duration::MAX;
        let mut min_energy = f64::INFINITY;
        for s in self.shards.iter().filter(|s| eligible(s)) {
            min_service = min_service.min(s.spec.service);
            min_energy = min_energy.min(s.spec.energy_per_req_j);
        }
        let in_island = |s: &Shard| match spec.tier {
            SloTier::Tight => s.spec.service == min_service,
            SloTier::Relaxed => s.spec.energy_per_req_j == min_energy,
            SloTier::Standard => true,
        };
        let mut least = usize::MAX;
        let mut least_out = usize::MAX;
        for (i, s) in self.shards.iter().enumerate() {
            if eligible(s) && in_island(s) && s.outstanding < least_out {
                least = i;
                least_out = s.outstanding;
            }
        }
        debug_assert!(least != usize::MAX, "min_engines >= 1 keeps one shard active");
        if self.projected(least, now) <= self.slos[tenant as usize] {
            return least;
        }
        let mut fast = least;
        let mut fast_proj = self.projected(least, now);
        for (i, s) in self.shards.iter().enumerate() {
            if i == least || !eligible(s) {
                continue;
            }
            let p = self.projected(i, now);
            if p < fast_proj {
                fast = i;
                fast_proj = p;
            }
        }
        fast
    }

    /// One autoscaler round: queue-depth hysteresis. Scale-up activates the
    /// lowest-index inactive shard (warm after `warmup`); scale-down
    /// retires the highest-index active shard that is fully idle. A
    /// tenant-aware fleet also scales up — and declines to scale down —
    /// whenever even the best active projection would miss the tightest
    /// class SLO: queue depth alone reacts too late for a 2 ms class on a
    /// 1 ms-service fleet.
    fn autoscale_round(&mut self, now: Tick) {
        let p = self.cfg.policy;
        let active = self.shards.iter().filter(|s| s.active).count();
        let queued: usize = self.shards.iter().map(|s| s.batcher.pending()).sum();
        let slo_pressure = self.tenant_aware && {
            let best = self
                .shards
                .iter()
                .enumerate()
                .filter(|(_, s)| s.active)
                .map(|(i, _)| self.projected(i, now))
                .min()
                .unwrap_or(Duration::MAX);
            best > self.tightest_slo
        };
        if queued > p.up_per_engine * active || slo_pressure {
            if let Some(i) = self.shards.iter().position(|s| !s.active) {
                let s = &mut self.shards[i];
                s.active = true;
                s.warm_at = now + p.warmup;
                s.warm_boots += 1;
                self.scale_ups += 1;
            }
        } else if active > p.min_engines.max(1) && queued < p.down_per_engine * active {
            let idle = self
                .shards
                .iter()
                .rposition(|s| s.active && s.batcher.pending() == 0 && s.busy_until.is_none());
            if let Some(i) = idle {
                self.shards[i].active = false;
                self.scale_downs += 1;
            }
        }
    }

    /// All offered traffic admitted (or the arrival stream ran dry — a
    /// finite replay) and fully drained?
    fn finished(&self) -> bool {
        (self.arrived >= self.cfg.requests || self.exhausted)
            && self
                .shards
                .iter()
                .all(|s| s.batcher.pending() == 0 && s.busy_until.is_none())
    }

    /// Scan every shard for dispatchable work; schedule completions for
    /// what fires and wakes for what must wait.
    fn pump(&mut self, now: Tick) {
        for i in 0..self.shards.len() {
            let s = &self.shards[i];
            if !s.active || s.busy_until.is_some() || s.batcher.pending() == 0 {
                continue;
            }
            if s.warm_at > now {
                self.schedule_wake(i, self.shards[i].warm_at);
                continue;
            }
            let Some(capacity) = serve::next_dispatch(&s.batcher, &s.router, now) else {
                // Partial batch inside the window: the next interesting
                // instant is its deadline (mirrors `serve::drain`'s
                // bounded tail wait).
                let deadline = s.batcher.window.max(s.router.policy.max_wait);
                let wait = deadline
                    .saturating_sub(s.batcher.oldest_wait(now))
                    .max(Duration::from_nanos(1));
                self.schedule_wake(i, now + wait);
                continue;
            };
            let eff = self.cfg.faults.as_ref().map(|f| {
                f.effective(i, now, s.spec.ber, s.spec.tech, s.spec.glb_delta, s.spec.lsb_delta)
            });
            if eff.as_ref().is_some_and(|e| e.crashed || e.stalled) {
                // The engine holds its queue and retries a window later.
                self.shards[i].fault_blocked += 1;
                let at = now + self.cfg.window.max(Duration::from_nanos(1));
                self.schedule_wake(i, at);
                continue;
            }
            let mult = eff.map_or(1.0, |e| e.latency_mult.max(0.0));
            let s = &mut self.shards[i];
            let Some(b) = s.batcher.form(capacity, now) else { continue };
            let service = s.spec.service.mul_f64(mult).max(Duration::from_nanos(1));
            let done = now + service;
            s.busy_until = Some(done);
            let Batch { real, capacity, ids, tenants, enqueued, .. } = b;
            let rows = ids
                .iter()
                .zip(&tenants)
                .zip(&enqueued)
                .map(|((&id, &tenant), &enqueued)| InflightRow { id, tenant, enqueued })
                .collect();
            let job = Inflight { real, capacity, rows };
            self.push_event(done, EventKind::Complete { shard: i, job });
        }
    }

    /// Run the simulation to completion on `clock` (virtual for
    /// reproducibility; the CLI always injects [`Clock::virtual_at_zero`]).
    pub fn run(&mut self, clock: &Clock) -> crate::Result<FleetSimReport> {
        let epoch = clock.now();
        // One merged, seed-deterministic stream over the per-tenant traces
        // (tenants without a trace of their own inherit the run's). The
        // default mix has exactly one stream — the run trace — so the mux
        // degenerates to the plain generator and the schedule is unchanged.
        let traces: Vec<ArrivalTrace> = self
            .cfg
            .tenants
            .tenants
            .iter()
            .map(|t| t.trace.clone().unwrap_or_else(|| self.trace.clone()))
            .collect();
        let mut gen = MuxArrivalGen::new(&traces);
        if self.cfg.requests > 0 {
            match gen.next_arrival() {
                Some((off, tenant)) => self.push_event(epoch + off, EventKind::Arrival { tenant }),
                None => self.exhausted = true,
            }
        }
        if self.cfg.autoscale {
            self.push_event(epoch + self.cfg.policy.scale_period, EventKind::Autoscale);
        }
        while let Some(Reverse(ev)) = self.heap.pop() {
            clock.advance_to(ev.at);
            let now = clock.now();
            self.events += 1;
            match ev.kind {
                EventKind::Arrival { tenant } => {
                    let idx = if self.tenant_aware {
                        self.route_tenant(tenant, now)
                    } else {
                        self.route(now)
                    };
                    let id = self.arrived as u64;
                    let image = self.image.clone();
                    if self.book_tenants {
                        self.ledgers[tenant as usize].arrived += 1;
                    }
                    let s = &mut self.shards[idx];
                    if s.batcher.push(Request::for_tenant(id, tenant, image, now)) {
                        s.outstanding += 1;
                        s.peak_outstanding = s.peak_outstanding.max(s.outstanding);
                    } else if self.book_tenants {
                        self.ledgers[tenant as usize].rejected += 1;
                    }
                    self.arrived += 1;
                    if self.arrived < self.cfg.requests {
                        match gen.next_arrival() {
                            Some((off, tenant)) => {
                                self.push_event(epoch + off, EventKind::Arrival { tenant });
                            }
                            None => self.exhausted = true,
                        }
                    }
                }
                EventKind::Complete { shard, job } => {
                    let fleet_slo = self.cfg.policy.slo;
                    let s = &mut self.shards[shard];
                    s.busy_until = None;
                    s.batches += 1;
                    s.padded += (job.capacity - job.real) as u64;
                    s.served += job.real as u64;
                    s.outstanding = s.outstanding.saturating_sub(job.real);
                    let pj = (s.spec.energy_per_req_j * 1e12) as u64;
                    for row in &job.rows {
                        let sojourn = now.duration_since(row.enqueued);
                        s.latency.record(sojourn.as_micros() as u64);
                        s.energy_pj.record(pj);
                        // Shard violations score against the tenant's SLO
                        // under class-aware scheduling, the fleet SLO on
                        // the legacy paths (including the classless
                        // baseline, whose scheduler knows only that one).
                        let slo = if self.tenant_aware {
                            self.slos[row.tenant as usize]
                        } else {
                            fleet_slo
                        };
                        if sojourn > slo {
                            s.slo_violations += 1;
                        }
                        // The per-tenant ledger always scores the tenant's
                        // own SLO so baseline and class-aware runs stay
                        // comparable per class.
                        if self.book_tenants {
                            self.ledgers[row.tenant as usize].record_completion(
                                sojourn,
                                pj,
                                self.slos[row.tenant as usize],
                            );
                        }
                        if self.cfg.record {
                            self.record_log.push(RecordRow {
                                id: row.id,
                                tenant: row.tenant,
                                engine: shard,
                                arrival_ns: row.enqueued.duration_since(epoch).as_nanos() as u64,
                                completion_ns: now.duration_since(epoch).as_nanos() as u64,
                            });
                        }
                    }
                }
                EventKind::Wake { shard } => {
                    self.shards[shard].wake_at = None;
                }
                EventKind::Autoscale => {
                    self.autoscale_round(now);
                    if !self.finished() {
                        let at = now + self.cfg.policy.scale_period;
                        self.push_event(at, EventKind::Autoscale);
                    }
                }
            }
            self.pump(now);
            if self.finished() {
                // Stale wakes may remain in the heap; the work is done.
                break;
            }
        }
        Ok(self.report(clock.now().duration_since(epoch)))
    }

    fn report(&self, sim_elapsed: Duration) -> FleetSimReport {
        // Deterministic merge: shard order, never completion order.
        let mut latency = QuantileSketch::new();
        let mut energy_pj = QuantileSketch::new();
        for s in &self.shards {
            latency.merge(&s.latency);
            energy_pj.merge(&s.energy_pj);
        }
        let engines = self
            .shards
            .iter()
            .enumerate()
            .map(|(id, s)| FleetEngineReport {
                id,
                label: s.spec.label.clone(),
                served: s.served,
                batches: s.batches,
                padded: s.padded,
                peak_outstanding: s.peak_outstanding as u64,
                slo_violations: s.slo_violations,
                fault_blocked: s.fault_blocked,
                warm_boots: s.warm_boots,
                active: s.active,
                p99_us: s.latency.quantile(99.0),
            })
            .collect::<Vec<_>>();
        // Actual arrivals, not `cfg.requests`: equal on every infinite
        // trace, but a finite replay can run dry first.
        let offered = self.arrived as u64;
        let served: u64 = engines.iter().map(|e| e.served).sum();
        let rejected: u64 = self.shards.iter().map(|s| s.batcher.rejected).sum();
        let malformed: u64 = self.shards.iter().map(|s| s.batcher.malformed).sum();
        let tenants = if self.book_tenants {
            self.cfg
                .tenants
                .tenants
                .iter()
                .zip(&self.ledgers)
                .enumerate()
                .map(|(i, (t, l))| FleetTenantReport {
                    name: t.name.clone(),
                    tier: t.tier.token(),
                    slo: self.slos[i],
                    weight: t.weight,
                    arrived: l.arrived,
                    served: l.served,
                    rejected: l.rejected,
                    slo_violations: l.slo_violations,
                    p50_us: l.latency.quantile(50.0),
                    p99_us: l.latency.quantile(99.0),
                    p999_us: l.latency.quantile(99.9),
                    max_us: l.latency.max(),
                    mean_us: l.latency.mean(),
                    mean_uj: l.energy_pj.mean() / 1e6,
                })
                .collect()
        } else {
            Vec::new()
        };
        let secs = sim_elapsed.as_secs_f64();
        FleetSimReport {
            trace: self.trace.name.clone(),
            seed: self.trace.seed,
            scenario: self.cfg.faults.as_ref().map(|f| f.name.clone()),
            offered,
            served,
            rejected,
            malformed,
            events: self.events,
            slo: self.cfg.policy.slo,
            slo_violations: engines.iter().map(|e| e.slo_violations).sum(),
            fault_blocked: engines.iter().map(|e| e.fault_blocked).sum(),
            scale_ups: self.scale_ups,
            scale_downs: self.scale_downs,
            active_end: self.shards.iter().filter(|s| s.active).count() as u64,
            p50_us: latency.quantile(50.0),
            p99_us: latency.quantile(99.0),
            p999_us: latency.quantile(99.9),
            max_us: latency.max(),
            mean_us: latency.mean(),
            mean_uj: energy_pj.mean() / 1e6,
            p99_uj: energy_pj.quantile(99.0) as f64 / 1e6,
            total_j: served as f64 * energy_pj.mean() * 1e-12,
            sim_elapsed,
            throughput_rps: if secs > 0.0 { served as f64 / secs } else { 0.0 },
            tenants,
            engines,
        }
    }

    /// The `--record` log as JSON lines: a header naming the run (so a
    /// replay restores the trace identity and the round trip reproduces
    /// the report byte for byte), then one line per served request in id —
    /// i.e. arrival — order. Empty body unless the run had
    /// [`FleetConfig::record`] set.
    pub fn render_record(&self) -> String {
        use std::fmt::Write as _;
        let mut rows = self.record_log.clone();
        rows.sort_unstable_by_key(|r| r.id);
        let mut out = String::new();
        let header = Json::obj(vec![
            ("trace", Json::Str(self.trace.name.clone())),
            ("seed", self.trace.seed.into()),
            ("requests", (self.arrived as u64).into()),
        ]);
        let _ = writeln!(out, "{header}");
        for r in rows {
            let line = Json::obj(vec![
                ("id", r.id.into()),
                ("tenant", (r.tenant as u64).into()),
                ("engine", (r.engine as u64).into()),
                ("arrival_ns", r.arrival_ns.into()),
                ("completion_ns", r.completion_ns.into()),
            ]);
            let _ = writeln!(out, "{line}");
        }
        out
    }
}

/// Per-tenant rows of the [`FleetSimReport`] (present when the run's mix
/// is not the single default tenant).
#[derive(Debug, Clone)]
pub struct FleetTenantReport {
    pub name: String,
    /// The tenant's [`SloTier`] token.
    pub tier: &'static str,
    /// Effective SLO the ledger scored against.
    pub slo: Duration,
    pub weight: u64,
    pub arrived: u64,
    pub served: u64,
    pub rejected: u64,
    pub slo_violations: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub max_us: u64,
    pub mean_us: f64,
    /// Mean GLB energy per served request (µJ).
    pub mean_uj: f64,
}

/// Per-engine rows of the [`FleetSimReport`].
#[derive(Debug, Clone)]
pub struct FleetEngineReport {
    pub id: usize,
    pub label: String,
    pub served: u64,
    pub batches: u64,
    pub padded: u64,
    pub peak_outstanding: u64,
    pub slo_violations: u64,
    pub fault_blocked: u64,
    pub warm_boots: u64,
    pub active: bool,
    pub p99_us: u64,
}

/// The fleet-simulation report. Under a virtual clock both
/// [`FleetSimReport::render`] and [`FleetSimReport::to_json`] are
/// byte-identical across reruns and `--parallel` settings.
#[derive(Debug, Clone)]
pub struct FleetSimReport {
    pub trace: String,
    pub seed: u64,
    /// Name of the composed fault scenario, when one rode along.
    pub scenario: Option<String>,
    pub offered: u64,
    pub served: u64,
    pub rejected: u64,
    pub malformed: u64,
    /// Heap events processed (the simulator's work unit; benches report
    /// events/sec).
    pub events: u64,
    pub slo: Duration,
    pub slo_violations: u64,
    pub fault_blocked: u64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub active_end: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub max_us: u64,
    pub mean_us: f64,
    /// Mean / p99 GLB energy per served request (µJ).
    pub mean_uj: f64,
    pub p99_uj: f64,
    /// Total modeled GLB energy over the run (J).
    pub total_j: f64,
    pub sim_elapsed: Duration,
    pub throughput_rps: f64,
    /// Per-tenant ledgers; empty for the default single-tenant mix (whose
    /// reports stay byte-identical to the pre-tenant stack).
    pub tenants: Vec<FleetTenantReport>,
    pub engines: Vec<FleetEngineReport>,
}

impl FleetSimReport {
    /// served / offered, percent.
    pub fn availability(&self) -> f64 {
        if self.offered == 0 {
            100.0
        } else {
            self.served as f64 / self.offered as f64 * 100.0
        }
    }

    /// Deterministic human-readable report.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = write!(s, "fleet report: trace={} seed={}", self.trace, self.seed);
        match &self.scenario {
            Some(sc) => {
                let _ = writeln!(s, " faults={sc}");
            }
            None => {
                let _ = writeln!(s);
            }
        }
        let _ = writeln!(
            s,
            "  offered={} served={} rejected={} malformed={} availability={:.3}%",
            self.offered,
            self.served,
            self.rejected,
            self.malformed,
            self.availability()
        );
        let _ = writeln!(
            s,
            "  latency: p50={}us p99={}us p999={}us max={}us mean={:.0}us",
            self.p50_us, self.p99_us, self.p999_us, self.max_us, self.mean_us
        );
        let _ = writeln!(
            s,
            "  slo={}ms violations={} ({:.3}%) fault_blocked={}",
            self.slo.as_millis(),
            self.slo_violations,
            if self.served == 0 {
                0.0
            } else {
                self.slo_violations as f64 / self.served as f64 * 100.0
            },
            self.fault_blocked
        );
        let _ = writeln!(
            s,
            "  autoscale: ups={} downs={} active_end={}",
            self.scale_ups, self.scale_downs, self.active_end
        );
        let _ = writeln!(
            s,
            "  energy: mean={:.3}uJ/req p99={:.3}uJ/req total={:.6}J",
            self.mean_uj, self.p99_uj, self.total_j
        );
        let _ = writeln!(
            s,
            "  sim_elapsed={:.3}ms events={} throughput={:.1} req/s",
            self.sim_elapsed.as_secs_f64() * 1e3,
            self.events,
            self.throughput_rps
        );
        for t in &self.tenants {
            let _ = writeln!(
                s,
                "  tenant {} [{}] w={}: arrived={} served={} rejected={} slo={}ms viol={} \
                 ({:.3}%) p50={}us p99={}us p999={}us max={}us mean={:.0}us energy={:.3}uJ/req",
                t.name,
                t.tier,
                t.weight,
                t.arrived,
                t.served,
                t.rejected,
                t.slo.as_millis(),
                t.slo_violations,
                if t.served == 0 {
                    0.0
                } else {
                    t.slo_violations as f64 / t.served as f64 * 100.0
                },
                t.p50_us,
                t.p99_us,
                t.p999_us,
                t.max_us,
                t.mean_us,
                t.mean_uj,
            );
        }
        for e in &self.engines {
            let _ = writeln!(
                s,
                "  engine {} [{}]: served={} batches={} padded={} peak_q={} slo_viol={} \
                 blocked={} warm_boots={} p99={}us{}",
                e.id,
                e.label,
                e.served,
                e.batches,
                e.padded,
                e.peak_outstanding,
                e.slo_violations,
                e.fault_blocked,
                e.warm_boots,
                e.p99_us,
                if e.active { "" } else { " (retired)" }
            );
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let engines = self
            .engines
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("id", (e.id as u64).into()),
                    ("label", Json::Str(e.label.clone())),
                    ("served", e.served.into()),
                    ("batches", e.batches.into()),
                    ("padded", e.padded.into()),
                    ("peak_outstanding", e.peak_outstanding.into()),
                    ("slo_violations", e.slo_violations.into()),
                    ("fault_blocked", e.fault_blocked.into()),
                    ("warm_boots", e.warm_boots.into()),
                    ("active", e.active.into()),
                    ("p99_us", e.p99_us.into()),
                ])
            })
            .collect();
        let mut fields = vec![
            ("trace", Json::Str(self.trace.clone())),
            ("seed", self.seed.into()),
            ("offered", self.offered.into()),
            ("served", self.served.into()),
            ("rejected", self.rejected.into()),
            ("malformed", self.malformed.into()),
            ("events", self.events.into()),
            ("availability_pct", Json::Str(format!("{:.3}", self.availability()))),
            ("slo_ms", (self.slo.as_millis() as u64).into()),
            ("slo_violations", self.slo_violations.into()),
            ("fault_blocked", self.fault_blocked.into()),
            ("scale_ups", self.scale_ups.into()),
            ("scale_downs", self.scale_downs.into()),
            ("active_end", self.active_end.into()),
            ("p50_us", self.p50_us.into()),
            ("p99_us", self.p99_us.into()),
            ("p999_us", self.p999_us.into()),
            ("max_us", self.max_us.into()),
            ("mean_us", Json::Str(format!("{:.1}", self.mean_us))),
            ("energy_mean_uj", Json::Str(format!("{:.3}", self.mean_uj))),
            ("energy_p99_uj", Json::Str(format!("{:.3}", self.p99_uj))),
            ("energy_total_j", Json::Str(format!("{:.6}", self.total_j))),
            ("sim_elapsed_us", (self.sim_elapsed.as_micros() as u64).into()),
            ("throughput_rps", Json::Str(format!("{:.1}", self.throughput_rps))),
            ("engines", Json::Arr(engines)),
        ];
        if let Some(sc) = &self.scenario {
            fields.push(("scenario", Json::Str(sc.clone())));
        }
        if !self.tenants.is_empty() {
            let tenants = self
                .tenants
                .iter()
                .map(|t| {
                    Json::obj(vec![
                        ("name", Json::Str(t.name.clone())),
                        ("tier", Json::Str(t.tier.to_string())),
                        ("slo_ms", (t.slo.as_millis() as u64).into()),
                        ("weight", t.weight.into()),
                        ("arrived", t.arrived.into()),
                        ("served", t.served.into()),
                        ("rejected", t.rejected.into()),
                        ("slo_violations", t.slo_violations.into()),
                        ("p50_us", t.p50_us.into()),
                        ("p99_us", t.p99_us.into()),
                        ("p999_us", t.p999_us.into()),
                        ("max_us", t.max_us.into()),
                        ("mean_us", Json::Str(format!("{:.1}", t.mean_us))),
                        ("energy_mean_uj", Json::Str(format!("{:.3}", t.mean_uj))),
                    ])
                })
                .collect();
            fields.push(("tenants", Json::Arr(tenants)));
        }
        Json::obj(fields)
    }
}

/// The closed-loop drain re-threaded through the simulator's scheduling
/// core: a one-shard fleet under the degenerate *closed* arrival pattern
/// reduces exactly to this loop — every request is already queued, so the
/// event schedule alternates `next_dispatch` instants with bounded tail
/// waits and there is nothing left for the heap to order. [`serve::drain`]
/// delegates here, which keeps the closed-loop goldens byte-identical by
/// construction.
pub fn run_closed(
    batcher: &mut Batcher,
    router: &Router,
    metrics: &mut Metrics,
    clock: &Clock,
    mut infer: impl FnMut(&Batch) -> crate::Result<Duration>,
) -> crate::Result<()> {
    while batcher.pending() > 0 {
        let now = clock.now();
        let Some(capacity) = serve::next_dispatch(batcher, router, now) else {
            // Partial tail inside the window: advance to the instant both
            // the batcher window and the router deadline have expired for
            // the oldest request. Guaranteed > 0 (else a batch would have
            // fired), with a 1 ns floor so progress is unconditional.
            let deadline = batcher.window.max(router.policy.max_wait);
            let wait = deadline
                .saturating_sub(batcher.oldest_wait(now))
                .max(Duration::from_nanos(1));
            clock.advance(wait);
            continue;
        };
        if let Some(b) = batcher.form(capacity, now) {
            let latency = infer(&b)?;
            let done = if clock.is_virtual() { clock.advance(latency) } else { clock.now() };
            metrics.record_batch_waited(done, b.real, b.capacity, latency, b.oldest_wait);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GlbVariant;

    fn sim(trace: &str, specs: Vec<EngineSpec>, cfg: FleetConfig) -> FleetSim {
        FleetSim::new(ArrivalTrace::builtin(trace).unwrap(), specs, cfg).expect("sim")
    }

    fn accounting_closes(r: &FleetSimReport) {
        assert_eq!(
            r.served + r.rejected + r.malformed,
            r.offered,
            "every offered request is served, rejected, or malformed"
        );
        assert_eq!(r.served, r.engines.iter().map(|e| e.served).sum::<u64>());
    }

    #[test]
    fn empty_fleet_is_an_error_not_a_panic() {
        let err = FleetSim::new(
            ArrivalTrace::builtin("closed").unwrap(),
            Vec::new(),
            FleetConfig::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one engine"), "{err}");
    }

    #[test]
    fn routing_prefers_least_outstanding_with_lowest_index_ties() {
        let mut s = sim("poisson", EngineSpec::paper_fleet(3), FleetConfig::default());
        assert_eq!(s.route(Tick::ZERO), 0, "all empty: lowest index");
        s.shards[0].outstanding = 5;
        s.shards[1].outstanding = 2;
        s.shards[2].outstanding = 2;
        assert_eq!(s.route(Tick::ZERO), 1, "least outstanding, tie to lower index");
        s.shards[1].outstanding = 9;
        assert_eq!(s.route(Tick::ZERO), 2);
    }

    #[test]
    fn slo_pressure_falls_back_to_the_fast_island() {
        // Shard 0 = fast SRAM island, shard 1 = Ultra. Load both beyond the
        // SLO so even the least-outstanding pick projects a miss; the
        // balancer must route to the *fastest projection* (SRAM at 700 µs
        // per batch) even though Ultra has fewer outstanding.
        let specs =
            vec![EngineSpec::paper(GlbVariant::Sram), EngineSpec::paper(GlbVariant::SttAiUltra)];
        let mut s = sim("poisson", specs, FleetConfig::default());
        s.cfg.policy.slo = Duration::from_millis(2);
        s.shards[0].outstanding = 64; // SRAM: 5 batches ahead ~ 3.5 ms
        s.shards[1].outstanding = 48; // Ultra: 4 batches ahead ~ 4 ms
        assert_eq!(s.route(Tick::ZERO), 0, "fast island wins under SLO pressure");
        // With slack SLO the plain least-outstanding pick stands.
        s.cfg.policy.slo = Duration::from_millis(10);
        assert_eq!(s.route(Tick::ZERO), 1);
    }

    #[test]
    fn projected_accounts_for_warmup_residue() {
        let mut s = sim("poisson", EngineSpec::paper_fleet(2), FleetConfig::default());
        let now = Tick::ZERO + Duration::from_millis(1);
        s.shards[1].warm_at = now + Duration::from_millis(3);
        let cold = s.projected(1, now);
        let warm = s.projected(0, now);
        assert_eq!(cold, warm + Duration::from_millis(3));
    }

    #[test]
    fn autoscaler_hysteresis_scales_up_then_down() {
        let mut cfg = FleetConfig { autoscale: true, ..Default::default() };
        cfg.policy.min_engines = 1;
        let mut s = sim("bursty", EngineSpec::paper_fleet(3), cfg);
        assert!(s.shards[0].active && !s.shards[1].active && !s.shards[2].active);
        // Flood shard 0's queue past up_per_engine * 1.
        let now = Tick::ZERO + Duration::from_millis(1);
        for i in 0..40 {
            s.shards[0].batcher.push(Request::new(i, vec![0.5; 4], now));
        }
        s.autoscale_round(now);
        assert!(s.shards[1].active, "scale-up activates the lowest inactive shard");
        assert_eq!(s.scale_ups, 1);
        assert_eq!(s.shards[1].warm_at, now + s.cfg.policy.warmup);
        // In the hysteresis band (4 <= queued/engine <= 32): no action.
        s.autoscale_round(now);
        assert_eq!((s.scale_ups, s.scale_downs), (1, 0), "band holds steady");
        // Drain the queue below down_per_engine * 2: the idle top shard
        // retires, and min_engines floors the fleet.
        while s.shards[0].batcher.pending() > 0 {
            s.shards[0].batcher.form(16, now);
        }
        s.autoscale_round(now);
        assert!(!s.shards[1].active, "idle top shard retires first");
        assert_eq!(s.scale_downs, 1);
        s.autoscale_round(now);
        assert!(s.shards[0].active, "min_engines keeps the last shard");
        assert_eq!(s.scale_downs, 1);
    }

    #[test]
    fn uniform_trace_serves_everything_and_accounts_close() {
        let cfg = FleetConfig { requests: 2_000, ..Default::default() };
        let mut s = sim("uniform", EngineSpec::paper_fleet(2), cfg);
        let r = s.run(&Clock::virtual_at_zero()).unwrap();
        accounting_closes(&r);
        assert_eq!(r.served, 2_000);
        assert_eq!(r.availability(), 100.0);
        assert!(r.events as usize >= 2_000, "at least one event per arrival");
        assert!(r.p50_us > 0 && r.p99_us >= r.p50_us && r.max_us >= r.p99_us);
        assert!(r.throughput_rps > 0.0);
    }

    #[test]
    fn closed_trace_is_the_degenerate_single_burst() {
        // Every request lands at the epoch; a 1-engine fleet drains them in
        // three full batches plus a tail that the power-of-two ladder
        // covers with the batch-2 variant — the closed-loop shape.
        let cfg = FleetConfig { requests: 50, ..Default::default() };
        let mut s = sim("closed", EngineSpec::paper_fleet(1), cfg);
        let r = s.run(&Clock::virtual_at_zero()).unwrap();
        accounting_closes(&r);
        assert_eq!(r.served, 50);
        assert_eq!(r.engines[0].batches, 4, "3 full batches + the covered tail");
        assert_eq!(r.engines[0].padded, 0, "the ladder covers the 2-deep tail exactly");
    }

    #[test]
    fn energy_per_request_follows_the_spec() {
        let cfg = FleetConfig { requests: 32, ..Default::default() };
        let mut s = sim("closed", EngineSpec::paper_fleet(1), cfg);
        let r = s.run(&Clock::virtual_at_zero()).unwrap();
        // Ultra: 1.5e-4 J/req = 150 µJ; the sketch is exact-ish (≤ 1/64)
        // and the mean of a constant stream is that constant's bucket.
        assert!((r.mean_uj - 150.0).abs() / 150.0 < 0.02, "mean {} uJ", r.mean_uj);
        assert!((r.total_j - 32.0 * 1.5e-4).abs() / (32.0 * 1.5e-4) < 0.02);
    }

    #[test]
    fn reruns_are_byte_identical_and_parallel_is_cosmetic() {
        let run = |parallel: usize| {
            let cfg = FleetConfig { requests: 3_000, parallel, ..Default::default() };
            let mut s = sim("bursty", EngineSpec::paper_fleet(2), cfg);
            let r = s.run(&Clock::virtual_at_zero()).unwrap();
            (r.render(), r.to_json().to_string())
        };
        assert_eq!(run(1), run(1), "rerun identical");
        assert_eq!(run(1), run(4), "worker count cosmetic");
    }

    #[test]
    fn faulted_engine_blocks_dispatch_but_traffic_drains() {
        // The builtin crash_loop scenario crashes engine 0 twice (10–16 ms
        // and 40–46 ms); the shard holds its queue and retries a window
        // later, so nothing is lost — the refusals show in the counter.
        let faults = FaultSchedule::builtin("crash_loop").unwrap();
        let cfg = FleetConfig { requests: 5_000, faults: Some(faults), ..FleetConfig::default() };
        let mut s = sim("uniform", EngineSpec::paper_fleet(3), cfg);
        let r = s.run(&Clock::virtual_at_zero()).unwrap();
        accounting_closes(&r);
        assert_eq!(r.scenario.as_deref(), Some("crash_loop"));
        assert_eq!(r.served, 5_000, "no traffic lost to the crash");
        assert!(r.fault_blocked > 0, "the crashed engine refused dispatches");
        assert_eq!(r.fault_blocked, r.engines[0].fault_blocked, "only engine 0 crashes");
    }

    #[test]
    fn report_renders_all_sections() {
        let cfg = FleetConfig { requests: 200, ..Default::default() };
        let mut s = sim("poisson", EngineSpec::paper_fleet(2), cfg);
        let r = s.run(&Clock::virtual_at_zero()).unwrap();
        let text = r.render();
        for needle in
            ["fleet report: trace=poisson", "latency:", "slo=", "autoscale:", "energy:", "engine 0"]
        {
            assert!(text.contains(needle), "missing {needle:?} in {text}");
        }
        let j = r.to_json().to_string();
        assert!(j.contains("\"trace\":\"poisson\""), "{j}");
        assert!(j.contains("\"events\":"), "{j}");
        assert!(!j.contains("\"tenants\""), "default mix emits no tenant section: {j}");
        assert!(r.tenants.is_empty());
    }

    fn hetero() -> Vec<EngineSpec> {
        vec![EngineSpec::paper(GlbVariant::Sram), EngineSpec::paper(GlbVariant::SttAiUltra)]
    }

    fn mix_cfg(mix: &str) -> FleetConfig {
        FleetConfig {
            tenants: crate::coordinator::TenantMix::builtin(mix).unwrap(),
            ..Default::default()
        }
    }

    #[test]
    fn tenant_routing_prefers_tier_islands() {
        // two_tier on SRAM+Ultra: the tight class heads for the
        // fastest-service shard, the relaxed class for the most
        // energy-efficient one — each island empty of the other's traffic.
        let s = sim("poisson", hetero(), mix_cfg("two_tier"));
        assert!(s.tenant_aware);
        assert_eq!(s.route_tenant(0, Tick::ZERO), 0, "tight -> SRAM island");
        assert_eq!(s.route_tenant(1, Tick::ZERO), 1, "relaxed -> Ultra island");
    }

    #[test]
    fn tenant_slo_pressure_spills_off_the_island() {
        // Load the Ultra island until the relaxed class's projection
        // misses its 50 ms SLO (1 ms service × ceil(817/16) = 52 ms): the
        // island preference must yield to the fastest projection.
        let mut s = sim("poisson", hetero(), mix_cfg("two_tier"));
        s.shards[1].outstanding = 816;
        assert_eq!(s.route_tenant(1, Tick::ZERO), 0, "relaxed spills to SRAM");
        s.shards[1].outstanding = 100;
        assert_eq!(s.route_tenant(1, Tick::ZERO), 1, "within SLO the island holds");
    }

    #[test]
    fn accuracy_floor_filters_shards_until_none_remain() {
        // three_class's tight tenant has floor 0.999: SRAM (1.0) passes,
        // Ultra (0.995) does not — even when Ultra is emptier.
        let mut s = sim("poisson", hetero(), mix_cfg("three_class"));
        s.shards[0].outstanding = 8;
        assert_eq!(s.route_tenant(0, Tick::ZERO), 0, "floor keeps tight off Ultra");
        // On an all-Ultra fleet nothing clears the floor: serving beats
        // starving, so the filter falls away.
        let s = sim("poisson", EngineSpec::paper_fleet(2), mix_cfg("three_class"));
        assert_eq!(s.route_tenant(0, Tick::ZERO), 0, "fallback to every active shard");
    }

    #[test]
    fn classless_mode_keeps_legacy_scheduling_but_books_ledgers() {
        let cfg = FleetConfig { classless: true, ..mix_cfg("two_tier") };
        let mut s = sim("poisson", hetero(), cfg);
        assert!(!s.tenant_aware && s.book_tenants);
        let r = s.run(&Clock::virtual_at_zero()).unwrap();
        accounting_closes(&r);
        assert_eq!(r.tenants.len(), 2, "baseline still reports per-tenant ledgers");
        assert_eq!(
            r.tenants.iter().map(|t| t.arrived).sum::<u64>(),
            r.offered,
            "every arrival is booked to exactly one tenant"
        );
    }

    #[test]
    fn tenant_autoscaler_reacts_to_the_tightest_class_projection() {
        // All-Ultra fleet, two_tier mix: outstanding 32 projects 3 ms on a
        // 1 ms-service shard — past the 2 ms tight SLO but nowhere near
        // the queue-depth trigger. The class-aware autoscaler must scale
        // up anyway, and must not retire capacity while pressure holds.
        let mut cfg = FleetConfig { autoscale: true, ..mix_cfg("two_tier") };
        cfg.policy.min_engines = 1;
        let mut s = sim("poisson", EngineSpec::paper_fleet(3), cfg);
        s.shards[0].outstanding = 32;
        s.autoscale_round(Tick::ZERO);
        assert_eq!(s.scale_ups, 1, "tightest-SLO pressure scales up without deep queues");
        assert!(s.shards[1].active);
        // Pressure gone (projections back under 2 ms): the idle extra
        // shard retires through the ordinary hysteresis path.
        s.shards[0].outstanding = 0;
        s.shards[1].warm_at = Tick::ZERO;
        s.autoscale_round(Tick::ZERO);
        assert_eq!(s.scale_downs, 1, "no pressure, no queue: idle shard retires");
        assert!(!s.shards[1].active);
    }

    #[test]
    fn two_tier_run_reports_per_tenant_ledgers_that_close() {
        let cfg = FleetConfig { requests: 4_000, ..mix_cfg("two_tier") };
        let mut s = sim("poisson", hetero(), cfg);
        let r = s.run(&Clock::virtual_at_zero()).unwrap();
        accounting_closes(&r);
        assert_eq!(r.tenants.len(), 2);
        for t in &r.tenants {
            assert_eq!(t.arrived, t.served + t.rejected, "{}: tenant accounting closes", t.name);
            assert!(t.served > 0, "{}: class saw traffic", t.name);
            assert!(t.p99_us >= t.p50_us && t.max_us >= t.p999_us, "{}", t.name);
        }
        assert_eq!(r.tenants.iter().map(|t| t.served).sum::<u64>(), r.served);
        let text = r.render();
        assert!(text.contains("tenant tight [tight]"), "{text}");
        assert!(text.contains("tenant relaxed [relaxed]"), "{text}");
        let j = r.to_json().to_string();
        assert!(j.contains("\"tenants\":["), "{j}");
    }

    #[test]
    fn record_log_round_trips_through_the_replay_trace() {
        // Record a small single-tenant run, replay the log, and demand the
        // byte-identical report — arrivals, routing, batching, energy and
        // all (the record/replay contract).
        let cfg =
            FleetConfig { requests: 500, record: true, ..Default::default() };
        let mut s = sim("poisson", EngineSpec::paper_fleet(2), cfg.clone());
        let r1 = s.run(&Clock::virtual_at_zero()).unwrap();
        let log = s.render_record();
        assert_eq!(log.lines().count(), 501, "header + one line per request");
        let path = std::env::temp_dir()
            .join(format!("stt_ai_fleet_record_{}.jsonl", std::process::id()));
        std::fs::write(&path, &log).unwrap();
        let replay = ArrivalTrace::parse(path.to_str().unwrap()).unwrap();
        std::fs::remove_file(&path).ok();
        let mut s2 = FleetSim::new(replay, EngineSpec::paper_fleet(2), cfg).unwrap();
        let r2 = s2.run(&Clock::virtual_at_zero()).unwrap();
        assert_eq!(r2.to_json().to_string(), r1.to_json().to_string());
        assert_eq!(r2.render(), r1.render());
    }
}
