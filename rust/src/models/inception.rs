//! Inception-style networks: Inception-v3, Xception, NASNet-Large.
//!
//! NASNet-Large is encoded as a documented approximation: the NASNet-A
//! (6 @ 4032) cell is a fixed DAG of separable convolutions; we encode each
//! normal/reduction cell as its separable-conv inventory (2 input-adjust 1×1,
//! three 5×5-separable and three 3×3-separable pairs at the cell filter
//! count), which preserves the per-layer tensor shapes and total size class
//! that the §V.A analysis consumes.

use super::{Model, ModelBuilder};

// ---------------------------------------------------------------- Inception-v3

/// InceptionA (35×35): 1×1 / 5×5 / double-3×3 / pool-proj branches.
fn inception_a(b: ModelBuilder, name: &str, in_ch: u64, pool_feat: u64) -> ModelBuilder {
    let (_, h, w) = b.shape();
    b.branch_conv(&format!("{name}_b1"), in_ch, 64, 1, 1, 0)
        .branch_conv(&format!("{name}_b5r"), in_ch, 48, 1, 1, 0)
        .branch_conv(&format!("{name}_b5"), 48, 64, 5, 1, 2)
        .branch_conv(&format!("{name}_b3r"), in_ch, 64, 1, 1, 0)
        .branch_conv(&format!("{name}_b3a"), 64, 96, 3, 1, 1)
        .branch_conv(&format!("{name}_b3b"), 96, 96, 3, 1, 1)
        .branch_conv(&format!("{name}_pool"), in_ch, pool_feat, 1, 1, 0)
        .set_shape(224 + pool_feat, h, w)
}

/// InceptionC (17×17): 1×1 / 1×7-7×1 / double-7 factorized / pool branches.
fn inception_c(b: ModelBuilder, name: &str, in_ch: u64, c7: u64) -> ModelBuilder {
    let (_, h, w) = b.shape();
    b.branch_conv(&format!("{name}_b1"), in_ch, 192, 1, 1, 0)
        .branch_conv(&format!("{name}_b7r"), in_ch, c7, 1, 1, 0)
        .branch_conv_rect(&format!("{name}_b7a"), c7, c7, 1, 7)
        .branch_conv_rect(&format!("{name}_b7b"), c7, 192, 7, 1)
        .branch_conv(&format!("{name}_b7dr"), in_ch, c7, 1, 1, 0)
        .branch_conv_rect(&format!("{name}_b7d1"), c7, c7, 7, 1)
        .branch_conv_rect(&format!("{name}_b7d2"), c7, c7, 1, 7)
        .branch_conv_rect(&format!("{name}_b7d3"), c7, c7, 7, 1)
        .branch_conv_rect(&format!("{name}_b7d4"), c7, 192, 1, 7)
        .branch_conv(&format!("{name}_pool"), in_ch, 192, 1, 1, 0)
        .set_shape(768, h, w)
}

/// InceptionE (8×8): 1×1 / split-3×3 / double split-3×3 / pool branches.
fn inception_e(b: ModelBuilder, name: &str, in_ch: u64) -> ModelBuilder {
    let (_, h, w) = b.shape();
    b.branch_conv(&format!("{name}_b1"), in_ch, 320, 1, 1, 0)
        .branch_conv(&format!("{name}_b3r"), in_ch, 384, 1, 1, 0)
        .branch_conv_rect(&format!("{name}_b3a"), 384, 384, 1, 3)
        .branch_conv_rect(&format!("{name}_b3b"), 384, 384, 3, 1)
        .branch_conv(&format!("{name}_bdr"), in_ch, 448, 1, 1, 0)
        .branch_conv(&format!("{name}_bd3"), 448, 384, 3, 1, 1)
        .branch_conv_rect(&format!("{name}_bda"), 384, 384, 1, 3)
        .branch_conv_rect(&format!("{name}_bdb"), 384, 384, 3, 1)
        .branch_conv(&format!("{name}_pool"), in_ch, 192, 1, 1, 0)
        .set_shape(2048, h, w)
}

/// Inception-v3 (299×299) — 23.8 M params (aux head excluded).
pub fn inception_v3() -> Model {
    let mut b = ModelBuilder::new("InceptionV3", 3, 299, 299)
        .reference_params(23_834_568)
        .conv("conv1", 32, 3, 2, 0) // 149
        .conv("conv2", 32, 3, 1, 0) // 147
        .conv("conv3", 64, 3, 1, 1) // 147
        .maxpool("pool1", 3, 2) // 73
        .conv("conv4", 80, 1, 1, 0)
        .conv("conv5", 192, 3, 1, 0) // 71
        .maxpool("pool2", 3, 2); // 35
    b = inception_a(b, "m5b", 192, 32); // 256
    b = inception_a(b, "m5c", 256, 64); // 288
    b = inception_a(b, "m5d", 288, 64); // 288
    // Mixed6a reduction 35 → 17.
    let (_, h, w) = b.shape();
    let (oh, ow) = ((h - 3) / 2 + 1, (w - 3) / 2 + 1);
    b = b
        .branch_conv("m6a_b3", 288, 384, 3, 2, 0)
        .branch_conv("m6a_bdr", 288, 64, 1, 1, 0)
        .branch_conv("m6a_bd1", 64, 96, 3, 1, 1)
        .branch_conv("m6a_bd2", 96, 96, 3, 2, 0)
        .set_shape(768, oh, ow); // 17×17
    b = inception_c(b, "m6b", 768, 128);
    b = inception_c(b, "m6c", 768, 160);
    b = inception_c(b, "m6d", 768, 160);
    b = inception_c(b, "m6e", 768, 192);
    // Mixed7a reduction 17 → 8.
    let (_, h, w) = b.shape();
    let (oh, ow) = ((h - 3) / 2 + 1, (w - 3) / 2 + 1);
    b = b
        .branch_conv("m7a_b3r", 768, 192, 1, 1, 0)
        .branch_conv("m7a_b3", 192, 320, 3, 2, 0)
        .branch_conv("m7a_b7r", 768, 192, 1, 1, 0)
        .branch_conv_rect("m7a_b7a", 192, 192, 1, 7)
        .branch_conv_rect("m7a_b7b", 192, 192, 7, 1)
        .branch_conv("m7a_b7c", 192, 192, 3, 2, 0)
        .set_shape(1280, oh, ow); // 8×8
    b = inception_e(b, "m7b", 1280);
    b = inception_e(b, "m7c", 2048);
    b.global_pool("gap").fc("fc", 1000).build()
}

// ------------------------------------------------------------------- Xception

/// Separable conv pair (dw 3×3 + pw 1×1 to `out_ch`) on the running fmap.
fn sep(b: ModelBuilder, name: &str, out_ch: u64) -> ModelBuilder {
    b.dwconv(&format!("{name}_dw"), 3, 1, 1).conv(&format!("{name}_pw"), out_ch, 1, 1, 0)
}

/// Xception entry/exit block: `n` separable convs then a stride-2 pool, with
/// a 1×1 stride-2 projection skip.
fn xception_block(mut b: ModelBuilder, name: &str, out_ch: u64, n: u32) -> ModelBuilder {
    let (in_ch, _, _) = b.shape();
    b = b.branch_conv(&format!("{name}_skip"), in_ch, out_ch, 1, 2, 0);
    for i in 0..n {
        b = sep(b, &format!("{name}_sep{}", i + 1), out_ch);
    }
    b.maxpool(&format!("{name}_pool"), 2, 2)
}

/// Xception (299×299) — 22.9 M params.
pub fn xception() -> Model {
    let mut b = ModelBuilder::new("Xception", 3, 299, 299)
        .reference_params(22_855_952)
        .conv("conv1", 32, 3, 2, 0) // 149
        .conv("conv2", 64, 3, 1, 0); // 147
    b = xception_block(b, "entry1", 128, 2); // 73
    b = xception_block(b, "entry2", 256, 2); // 36
    b = xception_block(b, "entry3", 728, 2); // 18
    for i in 0..8 {
        let name = format!("mid{}", i + 1);
        b = sep(b, &format!("{name}_sep1"), 728);
        b = sep(b, &format!("{name}_sep2"), 728);
        b = sep(b, &format!("{name}_sep3"), 728);
    }
    // Exit block: 728 → 1024 with skip, then 1536/2048 separables.
    let (in_ch, _, _) = b.shape();
    b = b.branch_conv("exit_skip", in_ch, 1024, 1, 2, 0);
    b = sep(b, "exit_sep1", 728);
    b = sep(b, "exit_sep2", 1024);
    b = b.maxpool("exit_pool", 2, 2); // 9
    b = sep(b, "exit_sep3", 1536);
    b = sep(b, "exit_sep4", 2048);
    b.global_pool("gap").fc("fc", 1000).build()
}

// --------------------------------------------------------------- NASNet-Large

/// Approximated NASNet-A cell: two 1×1 input adjusts (prev + cur) to `f`
/// filters, three 5×5-separable and three 3×3-separable pairs at `f`.
fn nasnet_cell(b: ModelBuilder, name: &str, in_ch: u64, f: u64, out_mult: u64) -> ModelBuilder {
    let (_, h, w) = b.shape();
    let mut b = b
        .branch_conv(&format!("{name}_adj1"), in_ch, f, 1, 1, 0)
        .branch_conv(&format!("{name}_adj2"), in_ch, f, 1, 1, 0);
    for i in 0..3 {
        // 5×5 separable = dw 5×5 + pw 1×1 at f channels.
        b = b
            .raw_conv(super::ConvLayer {
                name: format!("{name}_sep5_{i}_dw"),
                in_ch: f,
                out_ch: f,
                kh: 5,
                kw: 5,
                stride: 1,
                pad: 2,
                groups: f,
                in_h: h,
                in_w: w,
            })
            .branch_conv(&format!("{name}_sep5_{i}_pw"), f, f, 1, 1, 0);
        b = b
            .raw_conv(super::ConvLayer {
                name: format!("{name}_sep3_{i}_dw"),
                in_ch: f,
                out_ch: f,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
                groups: f,
                in_h: h,
                in_w: w,
            })
            .branch_conv(&format!("{name}_sep3_{i}_pw"), f, f, 1, 1, 0);
    }
    b.set_shape(out_mult * f, h, w)
}

/// NASNet-Large (6 @ 4032), 331×331 — ≈85 M params (approximate cell
/// inventory; see module docs).
pub fn nasnet_large() -> Model {
    let mut b = ModelBuilder::new("NasnetLarge", 3, 331, 331)
        .conv("stem_conv", 96, 3, 2, 0) // 165
        .maxpool("stem_pool1", 2, 2) // 82
        .maxpool("stem_pool2", 2, 2); // 41 (stem reduction cells, geometry only)
    let stages: [(u64, u32); 3] = [(168, 6), (336, 6), (672, 6)];
    let mut in_ch = 96;
    for (si, (f, n)) in stages.iter().enumerate() {
        if si > 0 {
            // Reduction cell halves the fmap and doubles filters.
            let (_, h, w) = b.shape();
            b = nasnet_cell(b, &format!("red{si}"), in_ch, *f, 6);
            b = b.set_shape(6 * f, h, w).maxpool(&format!("red{si}_pool"), 2, 2);
            in_ch = 6 * f;
        }
        for i in 0..*n {
            b = nasnet_cell(b, &format!("st{}c{}", si + 1, i + 1), in_ch, *f, 6);
            in_ch = 6 * f;
        }
    }
    b.global_pool("gap").fc("fc", 1000).build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::DType;

    #[test]
    fn inception_v3_classifier_width() {
        let m = inception_v3();
        let fc: Vec<_> = m.fc_layers().collect();
        assert_eq!(fc[0].n_in, 2048);
    }

    #[test]
    fn inception_v3_param_class() {
        let p = inception_v3().param_count();
        assert!((p as f64 - 23.8e6).abs() / 23.8e6 < 0.10, "{p}");
    }

    #[test]
    fn xception_param_class() {
        let p = xception().param_count();
        assert!((p as f64 - 22.9e6).abs() / 22.9e6 < 0.10, "{p}");
    }

    #[test]
    fn xception_mid_flow_is_728() {
        let m = xception();
        let mid = m.conv_layers().find(|c| c.name == "mid4_sep2_pw").unwrap();
        assert_eq!(mid.out_ch, 728);
    }

    #[test]
    fn nasnet_is_large_class() {
        let m = nasnet_large();
        let p = m.param_count();
        // ~85M class (approximate inventory; published 88.9M).
        assert!(p > 60_000_000 && p < 110_000_000, "{p}");
        let fc: Vec<_> = m.fc_layers().collect();
        assert_eq!(fc[0].n_in, 4032);
        // NASNet has the huge activation maps the paper's Fig. 11 calls out:
        // it needs well over 12 MB at batch 8.
        let ws = m.max_conv_working_set(DType::Bf16, 8);
        assert!(ws > 20 * 1024 * 1024, "ws={ws}");
    }
}
