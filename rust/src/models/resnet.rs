//! ResNet family (He et al.): 18/34 with basic blocks, 50/101/152 with
//! bottleneck blocks. Downsample (projection) 1×1 convs included.

use super::{Model, ModelBuilder};

/// Basic block: 3×3 → 3×3 (+ 1×1 projection when the shape changes).
fn basic_block(mut b: ModelBuilder, name: &str, out_ch: u64, stride: u64) -> ModelBuilder {
    let (in_ch, h, w) = b.shape();
    if stride != 1 || in_ch != out_ch {
        b = b.branch_conv(&format!("{name}_proj"), in_ch, out_ch, 1, stride, 0);
    }
    b.conv(&format!("{name}_conv1"), out_ch, 3, stride, 1)
        .conv(&format!("{name}_conv2"), out_ch, 3, 1, 1)
        .set_shape(out_ch, (h + 2 - 3) / stride + 1, (w + 2 - 3) / stride + 1)
}

/// Bottleneck block: 1×1 (mid) → 3×3 (mid) → 1×1 (4·mid).
fn bottleneck(mut b: ModelBuilder, name: &str, mid_ch: u64, stride: u64) -> ModelBuilder {
    let out_ch = 4 * mid_ch;
    let (in_ch, h, w) = b.shape();
    if stride != 1 || in_ch != out_ch {
        b = b.branch_conv(&format!("{name}_proj"), in_ch, out_ch, 1, stride, 0);
    }
    b.conv(&format!("{name}_conv1"), mid_ch, 1, 1, 0)
        .conv(&format!("{name}_conv2"), mid_ch, 3, stride, 1)
        .conv(&format!("{name}_conv3"), out_ch, 1, 1, 0)
        .set_shape(out_ch, (h + 2 - 3) / stride + 1, (w + 2 - 3) / stride + 1)
}

fn stem(name: &str) -> ModelBuilder {
    ModelBuilder::new(name, 3, 224, 224)
        .conv("conv1", 64, 7, 2, 3) // 224 → 112
        .maxpool("pool1", 2, 2) // → 56
}

fn resnet_basic(name: &str, reps: [u32; 4], params: u64) -> Model {
    let mut b = stem(name).reference_params(params);
    for (stage, (&n, ch)) in reps.iter().zip([64u64, 128, 256, 512]).enumerate() {
        for i in 0..n {
            let stride = if stage > 0 && i == 0 { 2 } else { 1 };
            b = basic_block(b, &format!("s{}b{}", stage + 2, i + 1), ch, stride);
        }
    }
    b.global_pool("gap").fc("fc", 1000).build()
}

fn resnet_bottleneck(name: &str, reps: [u32; 4], params: u64) -> Model {
    let mut b = stem(name).reference_params(params);
    for (stage, (&n, ch)) in reps.iter().zip([64u64, 128, 256, 512]).enumerate() {
        for i in 0..n {
            let stride = if stage > 0 && i == 0 { 2 } else { 1 };
            b = bottleneck(b, &format!("s{}b{}", stage + 2, i + 1), ch, stride);
        }
    }
    b.global_pool("gap").fc("fc", 1000).build()
}

pub fn resnet18() -> Model {
    resnet_basic("ResNet18", [2, 2, 2, 2], 11_689_512)
}
pub fn resnet34() -> Model {
    resnet_basic("ResNet34", [3, 4, 6, 3], 21_797_672)
}
pub fn resnet50() -> Model {
    resnet_bottleneck("ResNet50", [3, 4, 6, 3], 25_557_032)
}
pub fn resnet101() -> Model {
    resnet_bottleneck("ResNet101", [3, 4, 23, 3], 44_549_160)
}
pub fn resnet152() -> Model {
    resnet_bottleneck("ResNet152", [3, 8, 36, 3], 60_192_808)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_layer_count() {
        // 1 stem + (3+4+6+3)·3 bottleneck convs + 4 projections = 53 convs.
        let m = resnet50();
        assert_eq!(m.conv_layers().count(), 53);
        assert_eq!(m.fc_layers().count(), 1);
    }

    #[test]
    fn resnet18_final_geometry() {
        let m = resnet18();
        let fc: Vec<_> = m.fc_layers().collect();
        assert_eq!(fc[0].n_in, 512, "gap output must be 512-d");
        let m = resnet50();
        let fc: Vec<_> = m.fc_layers().collect();
        assert_eq!(fc[0].n_in, 2048);
    }

    #[test]
    fn family_size_ordering() {
        let p18 = resnet18().param_count();
        let p34 = resnet34().param_count();
        let p50 = resnet50().param_count();
        let p101 = resnet101().param_count();
        let p152 = resnet152().param_count();
        assert!(p18 < p34 && p34 < p50 && p50 < p101 && p101 < p152);
    }

    #[test]
    fn stage_spatial_sizes() {
        // Stages run at 56/28/14/7 like the reference implementation.
        let m = resnet50();
        let convs: Vec<_> = m.conv_layers().collect();
        let last = convs.last().unwrap();
        assert_eq!(last.in_h, 7, "final stage must be 7x7");
    }
}
