//! Mobile-class networks: MobileNet-v1/v2, ShuffleNet-v2 (1.0×).

use super::{ConvLayer, Model, ModelBuilder};

/// Depthwise-separable pair: dw 3×3 (stride s) + pw 1×1 to `out_ch`.
fn dw_sep(b: ModelBuilder, name: &str, out_ch: u64, stride: u64) -> ModelBuilder {
    b.dwconv(&format!("{name}_dw"), 3, stride, 1).conv(&format!("{name}_pw"), out_ch, 1, 1, 0)
}

/// MobileNet-v1 (1.0×, 224) — 4.23 M params.
pub fn mobilenet_v1() -> Model {
    let mut b = ModelBuilder::new("MobileNetV1", 3, 224, 224)
        .reference_params(4_231_976)
        .conv("conv1", 32, 3, 2, 1); // 112
    let cfg: [(u64, u64); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (i, (c, s)) in cfg.iter().enumerate() {
        b = dw_sep(b, &format!("ds{}", i + 1), *c, *s);
    }
    b.global_pool("gap").fc("fc", 1000).build()
}

/// Inverted-residual block: 1×1 expand (t×) → dw 3×3 → 1×1 project.
fn inverted_residual(mut b: ModelBuilder, name: &str, out_ch: u64, stride: u64, t: u64) -> ModelBuilder {
    let (in_ch, _, _) = b.shape();
    let hidden = in_ch * t;
    if t != 1 {
        b = b.conv(&format!("{name}_expand"), hidden, 1, 1, 0);
    }
    b.dwconv(&format!("{name}_dw"), 3, stride, 1).conv(&format!("{name}_project"), out_ch, 1, 1, 0)
}

/// MobileNet-v2 (1.0×, 224) — 3.50 M params.
pub fn mobilenet_v2() -> Model {
    let mut b = ModelBuilder::new("MobileNetV2", 3, 224, 224)
        .reference_params(3_504_872)
        .conv("conv1", 32, 3, 2, 1); // 112
    // (expansion t, out channels c, repeats n, first stride s)
    let cfg: [(u64, u64, u32, u64); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    for (stage, (t, c, n, s)) in cfg.iter().enumerate() {
        for i in 0..*n {
            let stride = if i == 0 { *s } else { 1 };
            b = inverted_residual(b, &format!("ir{}_{}", stage + 1, i + 1), *c, stride, *t);
        }
    }
    b.conv("conv_last", 1280, 1, 1, 0).global_pool("gap").fc("fc", 1000).build()
}

fn dw(name: &str, ch: u64, stride: u64, h: u64, w: u64) -> ConvLayer {
    ConvLayer {
        name: name.to_string(),
        in_ch: ch,
        out_ch: ch,
        kh: 3,
        kw: 3,
        stride,
        pad: 1,
        groups: ch,
        in_h: h,
        in_w: w,
    }
}

/// ShuffleNet-v2 unit. Stride-1 units: half the channels pass through, the
/// other half sees 1×1 → dw 3×3 → 1×1. Stride-2 (downsample) units process
/// both branches (the shortcut gets dw 3×3 s2 + 1×1 as well).
fn shuffle_unit(b: ModelBuilder, name: &str, out_ch: u64, stride: u64) -> ModelBuilder {
    let (in_ch, h, w) = b.shape();
    let half = out_ch / 2;
    let oh = (h + 2 - 3) / stride + 1;
    let ow = (w + 2 - 3) / stride + 1;
    let main_in = if stride == 1 { half } else { in_ch };
    let mut b = b
        .branch_conv(&format!("{name}_pw1"), main_in, half, 1, 1, 0)
        .raw_conv(dw(&format!("{name}_dw"), half, stride, h, w))
        .branch_conv(&format!("{name}_pw2"), half, half, 1, 1, 0);
    if stride == 2 {
        b = b
            .raw_conv(dw(&format!("{name}_scdw"), in_ch, stride, h, w))
            .branch_conv(&format!("{name}_scpw"), in_ch, half, 1, 1, 0);
    }
    b.set_shape(out_ch, oh, ow)
}

/// ShuffleNet-v2 1.0× — ≈2.3 M params.
pub fn shufflenet_v2() -> Model {
    let mut b = ModelBuilder::new("ShuffleNetV2", 3, 224, 224)
        .conv("conv1", 24, 3, 2, 1) // 112
        .maxpool("pool1", 2, 2); // 56
    let stages: [(u64, u32); 3] = [(116, 4), (232, 8), (464, 4)];
    for (si, (c, n)) in stages.iter().enumerate() {
        for i in 0..*n {
            let stride = if i == 0 { 2 } else { 1 };
            b = shuffle_unit(b, &format!("st{}u{}", si + 2, i + 1), *c, stride);
        }
    }
    b.conv("conv5", 1024, 1, 1, 0).global_pool("gap").fc("fc", 1000).build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mobilenet_v1_param_count_class() {
        let p = mobilenet_v1().param_count();
        assert!(p > 3_800_000 && p < 4_600_000, "{p}");
    }

    #[test]
    fn mobilenet_v2_param_count_class() {
        let p = mobilenet_v2().param_count();
        assert!(p > 3_100_000 && p < 3_900_000, "{p}");
    }

    #[test]
    fn shufflenet_is_smallest_class() {
        let p = shufflenet_v2().param_count();
        assert!(p > 1_200_000 && p < 3_200_000, "{p}");
    }

    #[test]
    fn depthwise_layers_present() {
        let m = mobilenet_v2();
        let dw = m.conv_layers().filter(|c| c.groups > 1).count();
        assert!(dw >= 17, "one dw per inverted residual, got {dw}");
    }

    #[test]
    fn mobilenet_v1_final_fc() {
        let fc: Vec<_> = mobilenet_v1().fc_layers().map(|f| (f.n_in, f.m_out)).collect();
        assert_eq!(fc, vec![(1024, 1000)]);
    }

    #[test]
    fn shufflenet_stage_geometry() {
        // conv5 input must be 464 ch at 7×7.
        let m = shufflenet_v2();
        let conv5 = m.conv_layers().find(|c| c.name == "conv5").unwrap();
        assert_eq!((conv5.in_ch, conv5.in_h, conv5.in_w), (464, 7, 7));
    }
}
