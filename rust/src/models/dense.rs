//! Densely-connected and Darknet families: DenseNet-121/169, Darknet-53.

use super::{Model, ModelBuilder};

/// One DenseNet layer: BN-1×1 (bottleneck to 4k) → BN-3×3 (growth k).
/// The input channel count grows by k per layer inside a block.
fn dense_block(mut b: ModelBuilder, name: &str, layers: u32, growth: u64) -> ModelBuilder {
    let (mut ch, h, w) = b.shape();
    for i in 0..layers {
        b = b
            .branch_conv(&format!("{name}_l{}_1x1", i + 1), ch, 4 * growth, 1, 1, 0)
            .branch_conv(&format!("{name}_l{}_3x3", i + 1), 4 * growth, growth, 3, 1, 1);
        ch += growth;
    }
    b.set_shape(ch, h, w)
}

/// Transition: 1×1 halving channels + 2×2 average pool.
fn transition(b: ModelBuilder, name: &str) -> ModelBuilder {
    let (ch, _, _) = b.shape();
    b.conv(&format!("{name}_conv"), ch / 2, 1, 1, 0).maxpool(&format!("{name}_pool"), 2, 2)
}

fn densenet(name: &str, blocks: [u32; 4], params: u64) -> Model {
    let growth = 32;
    let mut b = ModelBuilder::new(name, 3, 224, 224)
        .reference_params(params)
        .conv("conv1", 64, 7, 2, 3) // 112
        .maxpool("pool1", 2, 2); // 56
    for (i, &n) in blocks.iter().enumerate() {
        b = dense_block(b, &format!("db{}", i + 1), n, growth);
        if i < 3 {
            b = transition(b, &format!("tr{}", i + 1));
        }
    }
    b.global_pool("gap").fc("fc", 1000).build()
}

/// DenseNet-121 — 7.98 M params.
pub fn densenet121() -> Model {
    densenet("DenseNet121", [6, 12, 24, 16], 7_978_856)
}

/// DenseNet-169 — 14.15 M params.
pub fn densenet169() -> Model {
    densenet("DenseNet169", [6, 12, 32, 32], 14_149_480)
}

/// Darknet residual: 1×1 (ch/2) → 3×3 (ch).
fn dark_res(b: ModelBuilder, name: &str, ch: u64) -> ModelBuilder {
    b.conv(&format!("{name}_1x1"), ch / 2, 1, 1, 0).conv(&format!("{name}_3x3"), ch, 3, 1, 1)
}

/// Darknet-53 (the YOLOv3 backbone) — 41.6 M params.
pub fn darknet53() -> Model {
    let mut b = ModelBuilder::new("Darknet53", 3, 256, 256)
        .reference_params(41_620_488)
        .conv("conv1", 32, 3, 1, 1); // 256
    let stages: [(u64, u32); 5] = [(64, 1), (128, 2), (256, 8), (512, 8), (1024, 4)];
    for (si, (ch, reps)) in stages.iter().enumerate() {
        b = b.conv(&format!("down{}", si + 1), *ch, 3, 2, 1);
        for r in 0..*reps {
            b = dark_res(b, &format!("s{}r{}", si + 1, r + 1), *ch);
        }
    }
    b.global_pool("gap").fc("fc", 1000).build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn densenet121_channel_growth() {
        let m = densenet121();
        // Final block: 512 + 16·32 = 1024 channels into the classifier.
        let fc: Vec<_> = m.fc_layers().collect();
        assert_eq!(fc[0].n_in, 1024);
    }

    #[test]
    fn densenet169_final_channels() {
        let m = densenet169();
        let fc: Vec<_> = m.fc_layers().collect();
        // 640 + 32·32 / ... = 1664 channels (published penultimate width).
        assert_eq!(fc[0].n_in, 1664);
    }

    #[test]
    fn darknet53_conv_count() {
        // 52 convs + fc = "53" layers.
        let m = darknet53();
        assert_eq!(m.conv_layers().count(), 52);
    }

    #[test]
    fn darknet53_param_count_class() {
        let p = darknet53().param_count();
        assert!((p as f64 - 41_620_488.0).abs() / 41_620_488.0 < 0.05, "{p}");
    }

    #[test]
    fn densenet_ordering() {
        assert!(densenet121().param_count() < densenet169().param_count());
    }
}
