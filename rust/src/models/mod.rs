//! DNN model zoo: the 19 architectures of the paper's design-space
//! exploration (§V.A, Figs. 10–14, 18), encoded as per-layer shape tables.
//!
//! Only tensor *shapes* matter for the memory/timing analysis, so each model
//! is a sequence of [`Layer`]s built with [`ModelBuilder`], which tracks the
//! running feature-map geometry exactly like the standard reference
//! implementations do (conv arithmetic of Eq. 1). Branch-structured networks
//! (Inception, DenseNet, NASNet) are encoded branch-by-branch: every conv
//! that exists in the graph appears once with its true shapes, which is what
//! the per-layer size/occupancy analysis consumes.
//!
//! Parameter counts are validated against the published numbers in tests.

pub mod classic;
pub mod dense;
pub mod inception;
pub mod mobile;
pub mod resnet;


/// Numeric datatype of weights/activations (Fig. 10's two axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    Int8,
    Bf16,
}

impl DType {
    pub fn bytes(&self) -> u64 {
        match self {
            DType::Int8 => 1,
            DType::Bf16 => 2,
        }
    }
}

/// One layer of a model, with fully-resolved geometry.
#[derive(Debug, Clone)]
pub enum Layer {
    Conv(ConvLayer),
    Fc(FcLayer),
    /// Max/avg pooling — no weights, changes fmap geometry; retention
    /// accounting charges it T_pool_relu.
    Pool(PoolLayer),
}

/// Convolution layer geometry (Eq. 1 parameters).
#[derive(Debug, Clone)]
pub struct ConvLayer {
    pub name: String,
    pub in_ch: u64,
    pub out_ch: u64,
    pub kh: u64,
    pub kw: u64,
    pub stride: u64,
    pub pad: u64,
    /// Grouped conv (depthwise when groups == in_ch); 1 for dense conv.
    pub groups: u64,
    pub in_h: u64,
    pub in_w: u64,
}

impl ConvLayer {
    /// N_ofmap_rw = (I_h − k_h + 2P)/S + 1 (Eq. 1).
    pub fn ofmap_h(&self) -> u64 {
        (self.in_h + 2 * self.pad - self.kh) / self.stride + 1
    }
    pub fn ofmap_w(&self) -> u64 {
        (self.in_w + 2 * self.pad - self.kw) / self.stride + 1
    }
    pub fn ifmap_elems(&self) -> u64 {
        self.in_ch * self.in_h * self.in_w
    }
    pub fn ofmap_elems(&self) -> u64 {
        self.out_ch * self.ofmap_h() * self.ofmap_w()
    }
    pub fn weight_elems(&self) -> u64 {
        self.out_ch * (self.in_ch / self.groups) * self.kh * self.kw
    }
    /// One partial ofmap: the 2-D plane accumulated per (output-channel,
    /// input-channel-step) — what the scratchpad holds (§IV.D, Fig. 18).
    pub fn partial_ofmap_elems(&self) -> u64 {
        self.ofmap_h() * self.ofmap_w()
    }
    /// MACs for the full layer (one image).
    pub fn macs(&self) -> u64 {
        self.ofmap_elems() * (self.in_ch / self.groups) * self.kh * self.kw
    }
}

/// Fully-connected layer: n_fc inputs → m_fc outputs.
#[derive(Debug, Clone)]
pub struct FcLayer {
    pub name: String,
    pub n_in: u64,
    pub m_out: u64,
}

impl FcLayer {
    pub fn weight_elems(&self) -> u64 {
        self.n_in * self.m_out
    }
}

/// Pooling layer.
#[derive(Debug, Clone)]
pub struct PoolLayer {
    pub name: String,
    pub k: u64,
    pub stride: u64,
    pub ch: u64,
    pub in_h: u64,
    pub in_w: u64,
    /// Global pooling collapses H×W → 1×1 regardless of k.
    pub global: bool,
}

impl PoolLayer {
    pub fn out_h(&self) -> u64 {
        if self.global {
            1
        } else {
            (self.in_h - self.k) / self.stride + 1
        }
    }
    pub fn out_w(&self) -> u64 {
        if self.global {
            1
        } else {
            (self.in_w - self.k) / self.stride + 1
        }
    }
}

/// A complete model.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub input: (u64, u64, u64), // (ch, h, w)
    pub layers: Vec<Layer>,
    /// Published parameter count (for validation), if known.
    pub reference_params: Option<u64>,
}

impl Model {
    /// Cheap structural fingerprint (FNV-1a over every layer's geometry),
    /// used by the `dse::cache` keys so two models that happen to share a
    /// name but differ in shape never alias in the analysis caches.
    pub fn fingerprint(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(PRIME);
        };
        mix(self.input.0);
        mix(self.input.1);
        mix(self.input.2);
        for l in &self.layers {
            match l {
                Layer::Conv(c) => {
                    mix(1);
                    mix(c.in_ch);
                    mix(c.out_ch);
                    mix(c.kh);
                    mix(c.kw);
                    mix(c.stride);
                    mix(c.pad);
                    mix(c.groups);
                    mix(c.in_h);
                    mix(c.in_w);
                }
                Layer::Fc(f) => {
                    mix(2);
                    mix(f.n_in);
                    mix(f.m_out);
                }
                Layer::Pool(p) => {
                    mix(3);
                    mix(p.k);
                    mix(p.stride);
                    mix(p.ch);
                    mix(p.in_h);
                    mix(p.in_w);
                    mix(p.global as u64);
                }
            }
        }
        h
    }

    /// Total weight elements (conv + fc).
    pub fn param_count(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Conv(c) => c.weight_elems() + c.out_ch, // + bias/BN-γβ class
                Layer::Fc(f) => f.weight_elems() + f.m_out,
                Layer::Pool(_) => 0,
            })
            .sum()
    }

    /// Model size in bytes at the given datatype (Fig. 10a).
    pub fn size_bytes(&self, dt: DType) -> u64 {
        self.param_count() * dt.bytes()
    }

    /// All conv layers.
    pub fn conv_layers(&self) -> impl Iterator<Item = &ConvLayer> {
        self.layers.iter().filter_map(|l| match l {
            Layer::Conv(c) => Some(c),
            _ => None,
        })
    }

    /// All FC layers.
    pub fn fc_layers(&self) -> impl Iterator<Item = &FcLayer> {
        self.layers.iter().filter_map(|l| match l {
            Layer::Fc(f) => Some(f),
            _ => None,
        })
    }

    /// (min, max) activation-map elements over conv layers — Fig. 10(b).
    pub fn conv_fmap_range(&self) -> (u64, u64) {
        let mut lo = u64::MAX;
        let mut hi = 0;
        for c in self.conv_layers() {
            let m = c.ifmap_elems().max(c.ofmap_elems());
            lo = lo.min(m);
            hi = hi.max(m);
        }
        if hi == 0 {
            (0, 0)
        } else {
            (lo, hi)
        }
    }

    /// (min, max) weight elements over conv layers — Fig. 10(c).
    pub fn conv_weight_range(&self) -> (u64, u64) {
        let mut lo = u64::MAX;
        let mut hi = 0;
        for c in self.conv_layers() {
            lo = lo.min(c.weight_elems());
            hi = hi.max(c.weight_elems());
        }
        if hi == 0 {
            (0, 0)
        } else {
            (lo, hi)
        }
    }

    /// Max single-layer working set (ifmap + weights + ofmap) in bytes at
    /// batch `n` — the per-layer GLB requirement (Fig. 11).
    pub fn max_conv_working_set(&self, dt: DType, batch: u64) -> u64 {
        self.conv_layers()
            .map(|c| (batch * (c.ifmap_elems() + c.ofmap_elems()) + c.weight_elems()) * dt.bytes())
            .max()
            .unwrap_or(0)
    }

    /// Max partial-ofmap bytes over conv layers (Fig. 18).
    pub fn max_partial_ofmap(&self, dt: DType) -> u64 {
        self.conv_layers().map(|c| c.partial_ofmap_elems() * dt.bytes()).max().unwrap_or(0)
    }

    /// Total MACs for one inference (one image).
    pub fn total_macs(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Conv(c) => c.macs(),
                Layer::Fc(f) => f.weight_elems(),
                Layer::Pool(_) => 0,
            })
            .sum()
    }
}

/// Builder that tracks the running feature-map geometry.
pub struct ModelBuilder {
    name: String,
    input: (u64, u64, u64),
    ch: u64,
    h: u64,
    w: u64,
    layers: Vec<Layer>,
    reference_params: Option<u64>,
}

impl ModelBuilder {
    pub fn new(name: &str, ch: u64, h: u64, w: u64) -> Self {
        Self {
            name: name.to_string(),
            input: (ch, h, w),
            ch,
            h,
            w,
            layers: Vec::new(),
            reference_params: None,
        }
    }

    pub fn reference_params(mut self, p: u64) -> Self {
        self.reference_params = Some(p);
        self
    }

    /// Current (ch, h, w).
    pub fn shape(&self) -> (u64, u64, u64) {
        (self.ch, self.h, self.w)
    }

    /// Dense conv consuming the running fmap.
    pub fn conv(mut self, name: &str, out_ch: u64, k: u64, stride: u64, pad: u64) -> Self {
        self.push_conv(name, out_ch, k, k, stride, pad, 1);
        self
    }

    /// Non-square conv (Inception's 1×7 / 7×1 factorizations).
    pub fn conv_rect(
        mut self,
        name: &str,
        out_ch: u64,
        kh: u64,
        kw: u64,
        stride: u64,
        pad_h: u64,
        pad_w: u64,
    ) -> Self {
        let c = ConvLayer {
            name: name.to_string(),
            in_ch: self.ch,
            out_ch,
            kh,
            kw,
            stride,
            pad: pad_h.max(pad_w), // symmetric-enough for size analysis
            groups: 1,
            in_h: self.h,
            in_w: self.w,
        };
        self.h = (self.h + 2 * pad_h - kh) / stride + 1;
        self.w = (self.w + 2 * pad_w - kw) / stride + 1;
        self.ch = out_ch;
        self.layers.push(Layer::Conv(c));
        self
    }

    /// Depthwise conv (groups = channels).
    pub fn dwconv(mut self, name: &str, k: u64, stride: u64, pad: u64) -> Self {
        let ch = self.ch;
        self.push_conv(name, ch, k, k, stride, pad, ch);
        self
    }

    /// Grouped conv.
    pub fn gconv(mut self, name: &str, out_ch: u64, k: u64, stride: u64, pad: u64, groups: u64) -> Self {
        self.push_conv(name, out_ch, k, k, stride, pad, groups);
        self
    }

    /// A conv on a *branch*: uses the running geometry for shapes but does
    /// NOT advance the running fmap (used for parallel branches; caller sets
    /// the merged output with [`Self::set_shape`]).
    pub fn branch_conv(mut self, name: &str, in_ch: u64, out_ch: u64, k: u64, stride: u64, pad: u64) -> Self {
        let c = ConvLayer {
            name: name.to_string(),
            in_ch,
            out_ch,
            kh: k,
            kw: k,
            stride,
            pad,
            groups: 1,
            in_h: self.h,
            in_w: self.w,
        };
        self.layers.push(Layer::Conv(c));
        self
    }

    /// Push a fully-specified conv without advancing the running geometry
    /// (branch-side layers whose input is not the running fmap).
    pub fn raw_conv(mut self, c: ConvLayer) -> Self {
        self.layers.push(Layer::Conv(c));
        self
    }

    /// Rectangular conv on a *branch* (explicit input channels, running
    /// spatial geometry, no shape advance) — Inception's 1×7/7×1 factors.
    #[allow(clippy::too_many_arguments)]
    pub fn branch_conv_rect(
        self,
        name: &str,
        in_ch: u64,
        out_ch: u64,
        kh: u64,
        kw: u64,
    ) -> Self {
        let (h, w) = (self.h, self.w);
        self.raw_conv(ConvLayer {
            name: name.to_string(),
            in_ch,
            out_ch,
            kh,
            kw,
            stride: 1,
            pad: kh.max(kw) / 2, // "same" padding on the long axis
            groups: 1,
            in_h: h,
            in_w: w,
        })
    }

    /// Force the running geometry (after a merge/concat of branches).
    pub fn set_shape(mut self, ch: u64, h: u64, w: u64) -> Self {
        self.ch = ch;
        self.h = h;
        self.w = w;
        self
    }

    fn push_conv(&mut self, name: &str, out_ch: u64, kh: u64, kw: u64, stride: u64, pad: u64, groups: u64) {
        let c = ConvLayer {
            name: name.to_string(),
            in_ch: self.ch,
            out_ch,
            kh,
            kw,
            stride,
            pad,
            groups,
            in_h: self.h,
            in_w: self.w,
        };
        self.h = (self.h + 2 * pad - kh) / stride + 1;
        self.w = (self.w + 2 * pad - kw) / stride + 1;
        self.ch = out_ch;
        self.layers.push(Layer::Conv(c));
    }

    pub fn maxpool(mut self, name: &str, k: u64, stride: u64) -> Self {
        let p = PoolLayer {
            name: name.to_string(),
            k,
            stride,
            ch: self.ch,
            in_h: self.h,
            in_w: self.w,
            global: false,
        };
        self.h = p.out_h();
        self.w = p.out_w();
        self.layers.push(Layer::Pool(p));
        self
    }

    pub fn global_pool(mut self, name: &str) -> Self {
        let p = PoolLayer {
            name: name.to_string(),
            k: self.h,
            stride: 1,
            ch: self.ch,
            in_h: self.h,
            in_w: self.w,
            global: true,
        };
        self.h = 1;
        self.w = 1;
        self.layers.push(Layer::Pool(p));
        self
    }

    pub fn fc(mut self, name: &str, m_out: u64) -> Self {
        let n_in = self.ch * self.h * self.w;
        self.layers.push(Layer::Fc(FcLayer { name: name.to_string(), n_in, m_out }));
        self.ch = m_out;
        self.h = 1;
        self.w = 1;
        self
    }

    pub fn build(self) -> Model {
        Model {
            name: self.name,
            input: self.input,
            layers: self.layers,
            reference_params: self.reference_params,
        }
    }
}

/// The full 19-model zoo of the paper's §V.A analysis.
pub fn zoo() -> Vec<Model> {
    vec![
        classic::alexnet(),
        classic::vgg16(),
        classic::vgg19(),
        classic::googlenet(),
        classic::squeezenet(),
        resnet::resnet18(),
        resnet::resnet34(),
        resnet::resnet50(),
        resnet::resnet101(),
        resnet::resnet152(),
        mobile::mobilenet_v1(),
        mobile::mobilenet_v2(),
        mobile::shufflenet_v2(),
        dense::densenet121(),
        dense::densenet169(),
        dense::darknet53(),
        inception::inception_v3(),
        inception::xception(),
        inception::nasnet_large(),
    ]
}

/// Look a model up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<Model> {
    let want = name.to_lowercase().replace(['-', '_'], "");
    zoo().into_iter().find(|m| m.name.to_lowercase().replace(['-', '_'], "") == want)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_19_models() {
        let z = zoo();
        assert_eq!(z.len(), 19);
        // Unique names.
        let mut names: Vec<&str> = z.iter().map(|m| m.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 19);
    }

    #[test]
    fn every_model_has_conv_and_plausible_size() {
        for m in zoo() {
            assert!(m.conv_layers().count() > 0, "{} has no conv layers", m.name);
            let mb = m.size_bytes(DType::Bf16) as f64 / (1024.0 * 1024.0);
            assert!(mb > 1.0 && mb < 400.0, "{}: {mb} MB bf16", m.name);
        }
    }

    #[test]
    fn param_counts_match_published() {
        // Within 10% of the published parameter counts (our tables omit some
        // BN statistics and odd biases; that is within the paper's own
        // granularity for Fig. 10).
        for m in zoo() {
            if let Some(want) = m.reference_params {
                let got = m.param_count();
                let err = (got as f64 - want as f64).abs() / want as f64;
                assert!(err < 0.10, "{}: got {got}, published {want} ({:.1}% off)", m.name, err * 100.0);
            }
        }
    }

    #[test]
    fn fig10a_aggregate_sizes() {
        // Paper: ~280 MB (bf16) / ~140 MB (int8) stores *the largest models*
        // class; total zoo ≈ several hundred MB; the largest single model
        // (NASNet/VGG-class) is 100–300 MB bf16.
        let z = zoo();
        let max_bf16 =
            z.iter().map(|m| m.size_bytes(DType::Bf16)).max().unwrap() as f64 / (1 << 20) as f64;
        assert!(max_bf16 > 200.0 && max_bf16 < 320.0, "max bf16 model = {max_bf16} MB");
        for m in &z {
            assert_eq!(m.size_bytes(DType::Bf16), 2 * m.size_bytes(DType::Int8));
        }
    }

    #[test]
    fn conv_arithmetic() {
        let c = ConvLayer {
            name: "t".into(),
            in_ch: 3,
            out_ch: 64,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            groups: 1,
            in_h: 224,
            in_w: 224,
        };
        assert_eq!(c.ofmap_h(), 224);
        assert_eq!(c.weight_elems(), 64 * 3 * 9);
        assert_eq!(c.partial_ofmap_elems(), 224 * 224);
        // Fig. 4's example: 3×3 kernel, stride 1 over 5×5 → 3×3 ofmap.
        let f4 = ConvLayer {
            name: "fig4".into(),
            in_ch: 1,
            out_ch: 1,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 0,
            groups: 1,
            in_h: 5,
            in_w: 5,
        };
        assert_eq!((f4.ofmap_h(), f4.ofmap_w()), (3, 3));
    }

    #[test]
    fn builder_tracks_geometry() {
        let m = ModelBuilder::new("t", 3, 224, 224)
            .conv("c1", 64, 7, 2, 3)
            .maxpool("p1", 2, 2)
            .conv("c2", 128, 3, 1, 1)
            .global_pool("gap")
            .fc("fc", 10)
            .build();
        // 224 →(7,2,3) 112 →(pool2) 56 →(3,1,1) 56 →(gap) 1
        let convs: Vec<&ConvLayer> = m.conv_layers().collect();
        assert_eq!(convs[1].in_h, 56);
        let fc: Vec<&FcLayer> = m.fc_layers().collect();
        assert_eq!(fc[0].n_in, 128);
        assert_eq!(fc[0].m_out, 10);
    }

    #[test]
    fn depthwise_weights() {
        let m = ModelBuilder::new("t", 32, 112, 112).dwconv("dw", 3, 1, 1).build();
        let c: Vec<&ConvLayer> = m.conv_layers().collect();
        assert_eq!(c[0].weight_elems(), 32 * 9);
        assert_eq!(c[0].macs(), 32 * 112 * 112 * 9);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("resnet-50").is_some());
        assert!(by_name("ResNet50").is_some());
        assert!(by_name("nope").is_none());
    }
}
