//! Classic CNNs: AlexNet, VGG-16/19, GoogLeNet, SqueezeNet 1.0.

use super::{Model, ModelBuilder};

/// AlexNet (torchvision variant, 224×224 input) — 61.1 M params.
pub fn alexnet() -> Model {
    ModelBuilder::new("AlexNet", 3, 224, 224)
        .reference_params(61_100_840)
        .conv("conv1", 64, 11, 4, 2)
        .maxpool("pool1", 3, 2)
        .conv("conv2", 192, 5, 1, 2)
        .maxpool("pool2", 3, 2)
        .conv("conv3", 384, 3, 1, 1)
        .conv("conv4", 256, 3, 1, 1)
        .conv("conv5", 256, 3, 1, 1)
        .maxpool("pool5", 3, 2)
        .fc("fc6", 4096)
        .fc("fc7", 4096)
        .fc("fc8", 1000)
        .build()
}

fn vgg_block(mut b: ModelBuilder, stage: &str, out_ch: u64, convs: u32) -> ModelBuilder {
    for i in 0..convs {
        b = b.conv(&format!("{stage}_conv{}", i + 1), out_ch, 3, 1, 1);
    }
    b.maxpool(&format!("{stage}_pool"), 2, 2)
}

/// VGG-16 — 138.36 M params.
pub fn vgg16() -> Model {
    let mut b = ModelBuilder::new("VGG16", 3, 224, 224).reference_params(138_357_544);
    b = vgg_block(b, "s1", 64, 2);
    b = vgg_block(b, "s2", 128, 2);
    b = vgg_block(b, "s3", 256, 3);
    b = vgg_block(b, "s4", 512, 3);
    b = vgg_block(b, "s5", 512, 3);
    b.fc("fc6", 4096).fc("fc7", 4096).fc("fc8", 1000).build()
}

/// VGG-19 — 143.67 M params.
pub fn vgg19() -> Model {
    let mut b = ModelBuilder::new("VGG19", 3, 224, 224).reference_params(143_667_240);
    b = vgg_block(b, "s1", 64, 2);
    b = vgg_block(b, "s2", 128, 2);
    b = vgg_block(b, "s3", 256, 4);
    b = vgg_block(b, "s4", 512, 4);
    b = vgg_block(b, "s5", 512, 4);
    b.fc("fc6", 4096).fc("fc7", 4096).fc("fc8", 1000).build()
}

/// One GoogLeNet Inception module: 1×1 / 1×1→3×3 / 1×1→5×5 / pool→1×1
/// branches. `in_ch` is the module input; branch convs are recorded with
/// their true shapes and the running fmap is set to the concat output.
#[allow(clippy::too_many_arguments)]
fn inception_v1(
    b: ModelBuilder,
    name: &str,
    in_ch: u64,
    c1: u64,
    c3r: u64,
    c3: u64,
    c5r: u64,
    c5: u64,
    cp: u64,
) -> ModelBuilder {
    let (_, h, w) = b.shape();
    b.branch_conv(&format!("{name}_1x1"), in_ch, c1, 1, 1, 0)
        .branch_conv(&format!("{name}_3x3r"), in_ch, c3r, 1, 1, 0)
        .branch_conv(&format!("{name}_3x3"), c3r, c3, 3, 1, 1)
        .branch_conv(&format!("{name}_5x5r"), in_ch, c5r, 1, 1, 0)
        .branch_conv(&format!("{name}_5x5"), c5r, c5, 5, 1, 2)
        .branch_conv(&format!("{name}_poolproj"), in_ch, cp, 1, 1, 0)
        .set_shape(c1 + c3 + c5 + cp, h, w)
}

/// GoogLeNet / Inception-v1 (main trunk, aux heads excluded).
pub fn googlenet() -> Model {
    let mut b = ModelBuilder::new("GoogLeNet", 3, 224, 224)
        .conv("conv1", 64, 7, 2, 3)
        .maxpool("pool1", 2, 2)
        .conv("conv2r", 64, 1, 1, 0)
        .conv("conv2", 192, 3, 1, 1)
        .maxpool("pool2", 2, 2); // 28×28
    b = inception_v1(b, "3a", 192, 64, 96, 128, 16, 32, 32);
    b = inception_v1(b, "3b", 256, 128, 128, 192, 32, 96, 64);
    b = b.maxpool("pool3", 2, 2); // 14×14
    b = inception_v1(b, "4a", 480, 192, 96, 208, 16, 48, 64);
    b = inception_v1(b, "4b", 512, 160, 112, 224, 24, 64, 64);
    b = inception_v1(b, "4c", 512, 128, 128, 256, 24, 64, 64);
    b = inception_v1(b, "4d", 512, 112, 144, 288, 32, 64, 64);
    b = inception_v1(b, "4e", 528, 256, 160, 320, 32, 128, 128);
    b = b.maxpool("pool4", 2, 2); // 7×7
    b = inception_v1(b, "5a", 832, 256, 160, 320, 32, 128, 128);
    b = inception_v1(b, "5b", 832, 384, 192, 384, 48, 128, 128);
    b.global_pool("gap").fc("fc", 1000).build()
}

/// One SqueezeNet fire module: squeeze 1×1 → expand 1×1 ‖ 3×3.
fn fire(b: ModelBuilder, name: &str, in_ch: u64, s: u64, e: u64) -> ModelBuilder {
    let (_, h, w) = b.shape();
    b.branch_conv(&format!("{name}_squeeze"), in_ch, s, 1, 1, 0)
        .branch_conv(&format!("{name}_exp1"), s, e, 1, 1, 0)
        .branch_conv(&format!("{name}_exp3"), s, e, 3, 1, 1)
        .set_shape(2 * e, h, w)
}

/// SqueezeNet 1.0 — 1.25 M params.
pub fn squeezenet() -> Model {
    let mut b = ModelBuilder::new("SqueezeNet", 3, 224, 224)
        .reference_params(1_248_424)
        .conv("conv1", 96, 7, 2, 0)
        .maxpool("pool1", 3, 2); // 54×54
    b = fire(b, "fire2", 96, 16, 64);
    b = fire(b, "fire3", 128, 16, 64);
    b = fire(b, "fire4", 128, 32, 128);
    b = b.maxpool("pool4", 3, 2); // 26×26
    b = fire(b, "fire5", 256, 32, 128);
    b = fire(b, "fire6", 256, 48, 192);
    b = fire(b, "fire7", 384, 48, 192);
    b = fire(b, "fire8", 384, 64, 256);
    b = b.maxpool("pool8", 3, 2); // 12×12
    b = fire(b, "fire9", 512, 64, 256);
    b.conv("conv10", 1000, 1, 1, 0).global_pool("gap").build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::DType;

    #[test]
    fn alexnet_fc6_geometry() {
        let m = alexnet();
        let fc: Vec<_> = m.fc_layers().collect();
        assert_eq!(fc[0].n_in, 9216, "pool5 must be 6x6x256");
    }

    #[test]
    fn vgg16_has_13_convs_3_fcs() {
        let m = vgg16();
        assert_eq!(m.conv_layers().count(), 13);
        assert_eq!(m.fc_layers().count(), 3);
        // VGG19 adds 3 convs.
        assert_eq!(vgg19().conv_layers().count(), 16);
    }

    #[test]
    fn vgg16_size_is_fig10_class() {
        // Paper Fig. 10a: VGG-class models are the big ones, >250 MB bf16.
        let mb = vgg16().size_bytes(DType::Bf16) as f64 / (1 << 20) as f64;
        assert!(mb > 250.0 && mb < 290.0, "{mb}");
    }

    #[test]
    fn googlenet_channel_bookkeeping() {
        let m = googlenet();
        // 5b output: 384+384+128+128 = 1024 into the classifier.
        let fc: Vec<_> = m.fc_layers().collect();
        assert_eq!(fc[0].n_in, 1024);
        // GoogLeNet is a small model (≈6 M params).
        let p = m.param_count();
        assert!(p > 4_500_000 && p < 8_000_000, "{p}");
    }

    #[test]
    fn squeezenet_tiny() {
        let m = squeezenet();
        let p = m.param_count();
        assert!(p < 1_500_000, "{p}");
        // No FC layers at all — conv10 is the classifier.
        assert_eq!(m.fc_layers().count(), 0);
    }
}
