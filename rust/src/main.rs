//! `stt-ai` — the STT-AI accelerator co-design framework CLI.
//!
//! Subcommands mirror the paper's workflow:
//!
//! * `figures [--fig N]` — regenerate the §V figures (10–19) as text tables,
//!   in parallel (`--parallel N`), with optional `--sweep` axis overrides.
//! * `sweep`             — free-form cross-product design-space exploration.
//! * `table3`            — accelerator composition + headline savings.
//! * `design`            — solve a customized STT-MRAM design point.
//! * `accuracy`          — Fig. 21 fault-injection evaluation on artifacts.
//! * `serve`             — closed-loop batched inference with metrics.
//! * `chaos`             — deterministic fault-injection run: a named
//!   scenario replayed against a simulated engine fleet under the
//!   graceful-degradation supervisor (or, with `--trace`, composed with
//!   open-loop arrivals inside the fleet simulator).
//! * `fleet`             — discrete-event fleet simulation: open-loop
//!   arrival traces, heterogeneous engines from selection records,
//!   SLO-aware routing and optional autoscaling.
//! * `init-config`       — write the three paper SystemConfigs as JSON.

use std::io::Write;
use std::path::{Path, PathBuf};

use stt_ai::config::{GlbVariant, SystemConfig, TechBase};
use stt_ai::coordinator::{self, Engine, EngineConfig};
use stt_ai::dse::delta::paper_design_points;
use stt_ai::dse::engine as dse_engine;
use stt_ai::dse::engine::Runner;
use stt_ai::dse::select::{self, Constraint, DesignSelection, Objective};
use stt_ai::mram::{DesignTargets, MtjTech, ScalingSolver};
use stt_ai::report;
use stt_ai::util::cli::Args;
use stt_ai::util::pool::available_parallelism;
use stt_ai::util::units::fmt_time;

const USAGE: &str = "\
stt-ai — AI accelerator + customized STT-MRAM co-design framework

USAGE: stt-ai <COMMAND> [FLAGS]

COMMANDS:
  figures      [--fig 10..19|tech|stall] [--csv-dir DIR] [--parallel N]
               [--sweep axis=v1|v2,...] [--tech stt|sot|sram]
               [--from-selection FILE]
               regenerate paper figures (+ cross-technology table and the
               write-bandwidth stall comparison)
  sweep        --axes axis=v1|v2,... [--parallel N] [--csv FILE] [--json FILE]
               [--tech stt|sot|sram]
               free cross-product DSE (axes: model, dtype, batch, glb_mb,
               macs, variant, tech, ber, delta, write_intensity, mc_samples)
  select       [--objective area|energy|latency|throughput]
               [--min-accuracy 0.99] [--max-area-mm2 X] [--max-power-mw X]
               [--no-retention-check] [--grid default|dense]
               [--config build.json]
               [--sweep axis=v1|v2,...] [--parallel N]
               [--out selection.json] [--csv selection.csv]
               objective/constraint design-point selection over the
               variant x delta x ber x glb_mb x macs candidate grid
               (Pareto frontier; latency scored with the write-bandwidth
               stall model; --grid dense widens every axis to the
               2592-candidate stress grid; a --config [deployment]
               section may also carry glb_mb/macs/grid knobs)
  table3                               Table III composition + savings
  design       [--retention 3.0|3y] [--ber 1e-8] [--tech sakhare2020|wei2019]
  accuracy     [--artifacts DIR] [--prune 0.0] [--batch 16] [--limit N]
  serve        [--artifacts DIR] [--variant sram|stt_ai|stt_ai_ultra]
               [--from-selection FILE] [--requests 256] [--batch 16]
               [--faults SCENARIO] [--parallel N]
               (--faults switches to chaos mode: the scenario replays
               against a simulated 3-engine fleet, no artifacts needed)
  chaos        [--scenario burst_ber|FILE] [--config build.json]
               [--requests 2000] [--batch 16] [--engines 3] [--seed N]
               [--variant V] [--from-selection FILE] [--selections FILES]
               [--fallback sram|stt_ai|stt_ai_ultra|none] [--trace TRACE]
               [--parallel N] [--report FILE]
               deterministic fault-injection run: replay a seeded scenario
               against a simulated engine fleet under the
               graceful-degradation supervisor; the report is byte-identical
               across runs and --parallel values (builtins: calm, burst_ber,
               retention_storm, bank_takedown, crash_loop, latency_spike);
               --trace composes the scenario with open-loop arrivals inside
               the fleet simulator instead (where --tenants also applies)
  fleet        [--trace closed|uniform|poisson|diurnal|bursty|FILE]
               [--tenants default|two_tier|three_class|FILE] [--single-queue]
               [--config build.json] [--engines 3]
               [--selections a.json,b.json,...] [--variant V]
               [--from-selection FILE] [--requests 20000] [--batch 16]
               [--slo-ms 10] [--autoscale] [--faults SCENARIO] [--seed N]
               [--record FILE] [--parallel N] [--report FILE]
               discrete-event fleet simulation: open-loop arrivals from a
               seeded trace (or the [traffic] config section), heterogeneous
               engines booted from selection records, SLO-aware
               least-outstanding routing with a fast-island fallback, and
               optional queue-depth autoscaling; reports are byte-identical
               across runs and --parallel values. --tenants (or the config
               [tenants] section) shares the fleet between SLO classes:
               per-class weighted deficit-round-robin batching, per-tier
               island routing against each tenant's own SLO, and per-tenant
               report ledgers (--single-queue keeps the legacy scheduler as
               an ablation baseline); --trace FILE also accepts a JSON-lines
               arrival recording, and --record FILE dumps one for replay
  montecarlo   [--samples 20000] [--seed N] [--parallel N]
               [--sweep axis=v1|v2,...] [--tech stt|wei2019]
               streaming PT Monte Carlo through the sweep engine
  exposure                             zoo-wide analytical fault exposure
  init-config  [--dir configs]         write paper SystemConfigs as JSON
";

fn parse_variant(s: &str) -> anyhow::Result<GlbVariant> {
    GlbVariant::from_token(s).ok_or_else(|| anyhow::anyhow!("unknown variant {s:?}"))
}

fn run_figure(n: u32, out: &mut impl Write, r: &Runner) -> std::io::Result<()> {
    match n {
        10 => report::fig10_with(out, r).map(|_| ()),
        11 => report::fig11_with(out, r).map(|_| ()),
        12 => report::fig12_with(out, r).map(|_| ()),
        13 => report::fig13_with(out, r).map(|_| ()),
        14 => report::fig14_with(out, r).map(|_| ()),
        15 => report::fig15_with(out, r).map(|_| ()),
        16 => report::fig16_with(out, r).map(|_| ()),
        17 => report::fig17_with(out, r).map(|_| ()),
        18 => report::fig18_with(out, r).map(|_| ()),
        19 => report::fig19_with(out, r).map(|_| ()),
        _ => writeln!(out, "no renderer for figure {n} (fig 21 → `stt-ai accuracy`)"),
    }
}

/// Parse a `--tech` token against the technology registry.
fn parse_tech(s: &str) -> anyhow::Result<TechBase> {
    TechBase::from_token(s)
        .ok_or_else(|| anyhow::anyhow!("unknown tech {s:?} (stt, sot, sram, wei2019)"))
}

/// Resolve the primary engine spec shared by `serve --faults`, `chaos`,
/// and `fleet`: an explicit selection record, an explicit variant, the
/// config's GLB variant, or the paper STT-AI Ultra default — in that order.
fn primary_spec(
    args: &Args,
    config: Option<&SystemConfig>,
) -> anyhow::Result<coordinator::EngineSpec> {
    match args.get("from-selection") {
        Some(path) => {
            if args.get("variant").is_some() {
                anyhow::bail!("--variant conflicts with --from-selection");
            }
            Ok(coordinator::EngineSpec::from_selection(&DesignSelection::load(Path::new(path))?))
        }
        None => {
            let variant = match (args.get("variant"), config) {
                (Some(v), _) => parse_variant(v)?,
                (None, Some(c)) => c.glb,
                (None, None) => GlbVariant::SttAiUltra,
            };
            Ok(coordinator::EngineSpec::paper(variant))
        }
    }
}

/// Build the fleet's engine specs, shared by `serve --faults`, `chaos`,
/// and `fleet`. `--selections a.json,b.json,...` boots each engine from
/// its own selection record (a heterogeneous fleet); otherwise the primary
/// spec is cloned. `engines` is the explicit `--engines` count when given:
/// a heterogeneous fleet defaults to one engine per record, a homogeneous
/// one to 3 slots, and naming fewer records than engines is a clean error.
fn fleet_specs(
    args: &Args,
    config: Option<&SystemConfig>,
    engines: Option<usize>,
) -> anyhow::Result<Vec<coordinator::EngineSpec>> {
    let mut specs = match args.get("selections") {
        Some(list) => {
            let paths: Vec<&str> = list.split(',').filter(|s| !s.is_empty()).collect();
            let mut specs = Vec::with_capacity(paths.len());
            for p in &paths {
                let sel = DesignSelection::load(Path::new(p))?;
                specs.push(coordinator::EngineSpec::from_selection(&sel));
            }
            let want = engines.unwrap_or(specs.len());
            if specs.len() < want {
                anyhow::bail!(
                    "--selections names {} record(s) but --engines asks for {want}; \
                     give one selection per engine or drop --engines",
                    specs.len()
                );
            }
            specs.truncate(want.max(1));
            specs
        }
        None => {
            let primary = primary_spec(args, config)?;
            vec![primary; engines.unwrap_or(3).max(1)]
        }
    };
    for (i, s) in specs.iter_mut().enumerate() {
        s.label = format!("{}-{i}", s.label);
    }
    Ok(specs)
}

/// Run one chaos scenario against `specs` under the graceful-degradation
/// supervisor on a virtual clock.
fn run_chaos(
    schedule: coordinator::FaultSchedule,
    specs: Vec<coordinator::EngineSpec>,
    fallback: Option<coordinator::EngineSpec>,
    requests: usize,
    batch: usize,
    parallel: usize,
) -> anyhow::Result<coordinator::FleetReport> {
    let mut sup = coordinator::Supervisor::new(
        schedule,
        specs,
        fallback,
        coordinator::SupervisorPolicy::default(),
        parallel,
    )?;
    let cfg = coordinator::ChaosConfig { requests, batch, parallel, ..Default::default() };
    sup.run(&cfg, &stt_ai::util::clock::Clock::virtual_at_zero())
}

/// Run one fleet simulation on a virtual clock (byte-identical reports
/// across runs and `--parallel` values). The second return is the
/// `--record` JSON-lines log when the config asked for one.
fn run_fleet(
    trace: coordinator::ArrivalTrace,
    specs: Vec<coordinator::EngineSpec>,
    cfg: coordinator::FleetConfig,
) -> anyhow::Result<(coordinator::FleetSimReport, Option<String>)> {
    let record = cfg.record;
    let mut sim = coordinator::FleetSim::new(trace, specs, cfg)?;
    let rep = sim.run(&stt_ai::util::clock::Clock::virtual_at_zero())?;
    let log = record.then(|| sim.render_record());
    Ok((rep, log))
}

/// Resolve the tenant mix for a fleet-simulator command: explicit
/// `--tenants` (builtin token or JSON path), then the `[tenants]` section
/// of `--config`, then the single default tenant (the legacy stack).
fn resolve_tenants(
    spec: Option<&str>,
    config: Option<&SystemConfig>,
) -> anyhow::Result<coordinator::TenantMix> {
    match spec {
        Some(s) => coordinator::TenantMix::parse(s),
        None => Ok(config.and_then(|c| c.tenants.clone()).unwrap_or_default()),
    }
}

/// Write a report JSON (newline-terminated) when `--report FILE` was given.
fn write_report(
    out: &mut impl Write,
    path: Option<PathBuf>,
    json: stt_ai::util::json::Json,
) -> anyhow::Result<()> {
    if let Some(path) = path {
        let mut text = json.to_string();
        text.push('\n');
        std::fs::write(&path, text)?;
        writeln!(out, "-- wrote {path:?}")?;
    }
    Ok(())
}

/// Build the sweep runner from the shared `--parallel` / `--sweep` / `--tech`
/// / `--from-selection` flags (`--tech T` is shorthand for overriding the
/// tech axis to one value; a selection record pins every axis its winning
/// point names, applied last so it wins over the shorthands).
fn runner_from(args: &Args) -> anyhow::Result<Runner> {
    let parallel = args.get_usize("parallel", available_parallelism())?;
    let mut overrides = match args.get("sweep") {
        Some(spec) => dse_engine::parse_axes(spec)?,
        None => Vec::new(),
    };
    if let Some(t) = args.get("tech") {
        overrides.push(dse_engine::Axis::Tech(vec![parse_tech(t)?]));
    }
    if let Some(path) = args.get("from-selection") {
        let sel = DesignSelection::load(Path::new(path))?;
        overrides.extend(select::selection_overrides(&sel.point));
    }
    Ok(Runner::new(parallel).with_overrides(overrides))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut out = std::io::stdout().lock();
    match args.cmd.as_str() {
        "figures" => {
            let runner = runner_from(&args)?;
            if let Some(dir) = args.get("csv-dir") {
                let files = report::export::export_all_with(Path::new(dir), &runner)?;
                writeln!(out, "wrote {} files to {dir}: {files:?}", files.len())?;
                args.finish()?;
                return Ok(());
            }
            match args.get("fig") {
                Some("tech") => {
                    report::figures::techcmp_with(&mut out, &runner)?;
                }
                Some("stall") => {
                    report::figures::stall_with(&mut out, &runner)?;
                }
                Some(n) => run_figure(n.parse()?, &mut out, &runner)?,
                None => report::render_all(&mut out, &runner)?,
            }
            args.finish()?;
        }
        "sweep" => {
            // No `--sweep` overrides here: the axes ARE the sweep, so a
            // stray `--sweep` flag is rejected by `finish()` below.
            let runner = Runner::new(args.get_usize("parallel", available_parallelism())?);
            let mut axes = match args.get("axes") {
                Some(spec) => dse_engine::parse_axes(spec)?,
                None => Vec::new(),
            };
            // `--tech T` pins the technology axis (e.g. `sweep --tech sot`)
            // unless the axis list already varies it.
            if let Some(t) = args.get("tech") {
                if axes.iter().any(|a| a.name() == "tech") {
                    anyhow::bail!("--tech conflicts with a tech= axis in --axes");
                }
                axes.push(dse_engine::Axis::Tech(vec![parse_tech(t)?]));
            }
            let csv = args.get("csv").map(PathBuf::from);
            let json = args.get("json").map(PathBuf::from);
            args.finish()?;
            let zoo = dse_engine::shared_zoo();
            let spec = dse_engine::custom_spec(&zoo, axes);
            writeln!(
                out,
                "== custom sweep: {} points x {} axes ({} workers) ==",
                spec.len(),
                spec.axes.len(),
                runner.workers()
            )?;
            let results = spec.run(runner.pool());
            if let Some(first) = results.first() {
                writeln!(out, "{}", first.csv_header().replace(',', "\t"))?;
            }
            for r in &results {
                writeln!(out, "{}", r.csv_row().replace(',', "\t"))?;
            }
            if let Some(path) = csv {
                report::export::write_results_csv(&path, &results)?;
                writeln!(out, "-- wrote {}", path.display())?;
            }
            if let Some(path) = json {
                report::export::export_json(&path, &results)?;
                writeln!(out, "-- wrote {}", path.display())?;
            }
        }
        "select" => {
            // Objective + constraints (and the optional glb_mb/macs/grid
            // knobs) come from a `[deployment]` config section (`--config
            // build.json`) or from individual flags.
            let (objective, constraints, axis_overrides, grid) = match args.get("config") {
                Some(path) => {
                    for f in [
                        "objective",
                        "min-accuracy",
                        "max-area-mm2",
                        "max-power-mw",
                        "no-retention-check",
                        "grid",
                    ] {
                        if args.get(f).is_some() {
                            anyhow::bail!(
                                "--{f} conflicts with --config (the [deployment] section owns it)"
                            );
                        }
                    }
                    let dep = SystemConfig::load(Path::new(path))?.deployment;
                    let over = dep.grid_overrides();
                    (dep.objective, dep.constraints(), over, dep.grid)
                }
                None => {
                    let objective_token = args.get_or("objective", "area").to_string();
                    let objective = Objective::from_token(&objective_token).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown objective {objective_token:?} (area, energy, latency, throughput)"
                        )
                    })?;
                    let grid_token = args.get_or("grid", "default").to_string();
                    let grid = select::SelectionGrid::from_token(&grid_token).ok_or_else(|| {
                        anyhow::anyhow!("unknown selection grid {grid_token:?} (default, dense)")
                    })?;
                    let mut constraints = Vec::new();
                    if let Some(floor) =
                        args.get("min-accuracy").map(|v| v.parse::<f64>()).transpose()?
                    {
                        constraints.push(Constraint::MinAccuracy(floor));
                    }
                    if !args.get_flag("no-retention-check") {
                        constraints.push(Constraint::RetentionCoversOccupancy);
                    }
                    if let Some(cap) =
                        args.get("max-area-mm2").map(|v| v.parse::<f64>()).transpose()?
                    {
                        constraints.push(Constraint::MaxAreaMm2(cap));
                    }
                    if let Some(cap) =
                        args.get("max-power-mw").map(|v| v.parse::<f64>()).transpose()?
                    {
                        constraints.push(Constraint::MaxPowerMw(cap));
                    }
                    (objective, constraints, Vec::new(), grid)
                }
            };
            // Config-section grid knobs sit below explicit `--sweep` flags.
            let runner = runner_from(&args)?.with_prepended_overrides(axis_overrides);
            let out_json = args.get("out").map(PathBuf::from);
            let csv = args.get("csv").map(PathBuf::from);
            args.finish()?;

            let zoo = dse_engine::shared_zoo();
            let spec = runner.resolve(select::spec_selection_grid(&zoo, grid));
            let results = spec.run(runner.pool());
            let feasible = select::feasible_mask(&results, &constraints);
            let sel = select::select("selection", &results, objective, &constraints)?;

            writeln!(
                out,
                "== design-point selection: {} candidates, objective {} ({} workers) ==",
                results.len(),
                objective.token(),
                runner.workers()
            )?;
            if let Some(first) = results.first() {
                writeln!(out, "{}\tfeasible", first.csv_header().replace(',', "\t"))?;
            }
            for (r, ok) in results.iter().zip(&feasible) {
                writeln!(
                    out,
                    "{}\t{}",
                    r.csv_row().replace(',', "\t"),
                    if *ok { "yes" } else { "no" }
                )?;
            }
            writeln!(
                out,
                "-- constraints: {:?} | feasible {}/{} | Pareto frontier {}",
                sel.constraints, sel.feasible, sel.candidates, sel.frontier
            )?;
            let mut picked = vec![format!("variant={}", sel.variant().label())];
            picked.extend(sel.point.columns().into_iter().map(|(k, v)| format!("{k}={v}")));
            writeln!(
                out,
                "-- winner: {} | {} = {:.6e}",
                picked.join(" "),
                objective.metric(),
                sel.score
            )?;
            if let Some(saving) = sel.metric("area_saving_vs_sram") {
                writeln!(
                    out,
                    "-- area saving vs SRAM baseline: {:.1}% (paper: 75.4% for STT-AI Ultra)",
                    saving * 100.0
                )?;
            }
            if let Some(path) = out_json {
                sel.save(&path)?;
                writeln!(out, "-- wrote {}", path.display())?;
            }
            if let Some(path) = csv {
                report::export::write_selection_csv(&path, std::slice::from_ref(&sel))?;
                writeln!(out, "-- wrote {}", path.display())?;
            }
        }
        "table3" => {
            args.finish()?;
            let rows = report::table3_rows();
            writeln!(out, "== Table III: accelerator design details at 14 nm ==")?;
            writeln!(out, "{:<18} {:>10} {:>12} {:>12}", "accelerator", "area mm2", "dyn mW", "leak mW")?;
            for r in &rows {
                writeln!(out, "{:<18} {:>10.2} {:>12.2} {:>12.3}", r.name, r.area_mm2, r.dynamic_mw, r.leakage_mw)?;
            }
            let base = rows[0].clone();
            for r in &rows[1..] {
                let (a, p) = r.savings_vs(&base);
                writeln!(out, "-- {}: {:.1}% area, {:.1}% power saving vs baseline", r.name, a * 100.0, p * 100.0)?;
            }
        }
        "design" => {
            let retention = args.get_or("retention", "3.0").to_string();
            let ber = args.get_f64("ber", 1e-8)?;
            let tech = match args.get_or("tech", "sakhare2020") {
                "wei2019" => MtjTech::wei2019(),
                _ => MtjTech::sakhare2020(),
            };
            args.finish()?;
            let seconds = if let Some(y) = retention.strip_suffix('y') {
                y.parse::<f64>()? * 365.25 * 24.0 * 3600.0
            } else {
                retention.parse::<f64>()?
            };
            let solver = ScalingSolver::new(tech);
            let t = DesignTargets {
                retention_time: seconds,
                retention_ber: ber,
                read_disturb_ber: ber,
                write_ber: ber,
            };
            let d = solver.solve(&t);
            writeln!(out, "customized STT-MRAM design point ({}):", tech.name)?;
            writeln!(out, "  retention target {} @ BER {ber:.0e}", fmt_time(seconds))?;
            writeln!(out, "  Δ_scaled        = {:.2}", d.delta_scaled)?;
            writeln!(out, "  Δ_PT_GuardBand  = {:.2}   (Eq. 17, 4σ + T_hot)", d.delta_guard_banded)?;
            writeln!(out, "  Δ_PT_MAX        = {:.2}   (Eq. 18, cold/fast corner)", d.delta_pt_max)?;
            writeln!(out, "  write pulse     = {}", fmt_time(d.write_pulse))?;
            writeln!(out, "  read pulse      = {}", fmt_time(d.read_pulse))?;
            writeln!(out, "  achieved ret.   = {}", fmt_time(d.achieved_retention))?;
            writeln!(out, "  rel write energy= {:.3}x vs Δ=60 base", d.rel_write_energy)?;
            writeln!(out, "  rel cell area   = {:.3}x vs Δ=60 base", d.rel_cell_area)?;
            writeln!(out, "\nreference design points:")?;
            for p in paper_design_points(tech) {
                writeln!(
                    out,
                    "  {:<24} Δ={:>5.1} Δ_GB={:>5.1} ret={}",
                    p.label,
                    p.delta_scaled,
                    p.delta_guard_banded,
                    fmt_time(p.achieved_retention)
                )?;
            }
        }
        "accuracy" => {
            let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
            let prune = args.get_f64("prune", 0.0)?;
            let batch = args.get_usize("batch", 16)?;
            let limit = args.get("limit").map(|v| v.parse()).transpose()?;
            args.finish()?;
            let row = coordinator::accuracy::fig21_row(&artifacts, prune, batch, limit)?;
            writeln!(out, "== Fig. 21: Top-1/Top-5 accuracy (prune rate {prune}) ==")?;
            for r in [&row.baseline, &row.stt_ai, &row.stt_ai_ultra] {
                writeln!(
                    out,
                    "  {:<14} top1 {:.4}  top5 {:.4}  flips {}  (n={})",
                    r.variant, r.top1, r.top5, r.bit_flips, r.n
                )?;
            }
            writeln!(out, "-- Ultra normalized Top-1 drop: {:.3}% (paper: <1%)", row.ultra_drop_normalized() * 100.0)?;
        }
        "serve" => {
            let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
            let requests = args.get_usize("requests", 256)?;
            let batch = args.get_usize("batch", 16)?;
            if let Some(spec) = args.get("faults").map(str::to_string) {
                // Chaos mode: replay the scenario against a simulated
                // 3-engine fleet of this build under the supervisor. No
                // artifacts are needed — the supervisor models service
                // latency per spec and injects faults into canary probes.
                let schedule = coordinator::FaultSchedule::parse(&spec)?;
                let specs = fleet_specs(&args, None, Some(3))?;
                let parallel = args.get_usize("parallel", 1)?;
                args.finish()?;
                let _ = artifacts; // unused in chaos mode
                let fallback = Some(coordinator::EngineSpec::paper(GlbVariant::Sram));
                let rep = run_chaos(schedule, specs, fallback, requests, batch, parallel)?;
                write!(out, "{}", rep.render())?;
                return Ok(());
            }
            // The engine boots either from an explicit variant or from a
            // sweep-selected design point — never from both.
            let config = match args.get("from-selection") {
                Some(path) => {
                    if args.get("variant").is_some() {
                        anyhow::bail!("--variant conflicts with --from-selection");
                    }
                    let sel = DesignSelection::load(Path::new(path))?;
                    writeln!(
                        out,
                        "booting from selection {:?}: objective {} -> {} ({} = {:.6e})",
                        sel.sweep,
                        sel.objective.token(),
                        sel.variant().label(),
                        sel.objective.metric(),
                        sel.score
                    )?;
                    EngineConfig::from_selection(&sel)
                }
                None => EngineConfig::new(parse_variant(args.get_or("variant", "stt_ai_ultra"))?),
            };
            args.finish()?;
            let engine = Engine::load(&artifacts, config)?;
            let summary = coordinator::serve::closed_loop(&engine, requests, batch)?;
            writeln!(out, "{summary}")?;
        }
        "chaos" => {
            let requests = args.get_usize("requests", 2000)?;
            let batch = args.get_usize("batch", 16)?;
            let engines_flag = args.get("engines").map(|v| v.parse::<usize>()).transpose()?;
            let parallel = args.get_usize("parallel", 1)?;
            // Scenario resolution order: explicit --scenario (builtin name
            // or JSON path), then the [faults] section of --config, then
            // the burst_ber builtin.
            let config = args
                .get("config")
                .map(|p| SystemConfig::load(Path::new(p)))
                .transpose()?;
            let mut schedule = match args.get("scenario") {
                Some(spec) => coordinator::FaultSchedule::parse(spec)?,
                None => match config.as_ref().and_then(|c| c.faults.clone()) {
                    Some(sched) => sched,
                    None => coordinator::FaultSchedule::builtin("burst_ber")
                        .expect("burst_ber is a builtin"),
                },
            };
            if let Some(seed) = args.get("seed") {
                schedule.seed = seed
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --seed {seed:?}: {e}"))?;
            }
            let specs = fleet_specs(&args, config.as_ref(), engines_flag)?;
            let report_path = args.get("report").map(PathBuf::from);
            if let Some(tspec) = args.get("trace").map(str::to_string) {
                // Open-loop composition: replay the fault scenario inside
                // the fleet simulator under an arrival trace instead of the
                // supervisor's fixed-gap pacing. The simulator has no
                // fallback-reboot path, so --fallback is supervisor-only.
                if args.get("fallback").is_some() {
                    anyhow::bail!("--fallback needs the supervisor path; drop it or --trace");
                }
                let tenants = resolve_tenants(args.get("tenants"), config.as_ref())?;
                args.finish()?;
                let trace = coordinator::ArrivalTrace::parse(&tspec)?;
                let cfg = coordinator::FleetConfig {
                    requests,
                    batch,
                    parallel,
                    faults: Some(schedule),
                    tenants,
                    ..Default::default()
                };
                let (rep, _) = run_fleet(trace, specs, cfg)?;
                write!(out, "{}", rep.render())?;
                return write_report(&mut out, report_path, rep.to_json());
            }
            let fallback = match args.get_or("fallback", "sram") {
                "none" => None,
                v => Some(coordinator::EngineSpec::paper(parse_variant(v)?)),
            };
            args.finish()?;
            let rep = run_chaos(schedule, specs, fallback, requests, batch, parallel)?;
            write!(out, "{}", rep.render())?;
            write_report(&mut out, report_path, rep.to_json())?;
        }
        "fleet" => {
            let requests = args.get_usize("requests", 20_000)?;
            let batch = args.get_usize("batch", 16)?;
            let parallel = args.get_usize("parallel", 1)?;
            let autoscale = args.get_flag("autoscale");
            let engines_flag = args.get("engines").map(|v| v.parse::<usize>()).transpose()?;
            let config = args
                .get("config")
                .map(|p| SystemConfig::load(Path::new(p)))
                .transpose()?;
            // Trace resolution order: explicit --trace (builtin token or
            // JSON path), then the [traffic] section of --config, then the
            // poisson builtin.
            let mut trace = match args.get("trace") {
                Some(spec) => coordinator::ArrivalTrace::parse(spec)?,
                None => match config.as_ref().and_then(|c| c.traffic.clone()) {
                    Some(t) => t,
                    None => coordinator::ArrivalTrace::builtin("poisson")
                        .expect("poisson is a builtin"),
                },
            };
            if let Some(seed) = args.get("seed") {
                trace.seed = seed
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad --seed {seed:?}: {e}"))?;
            }
            let faults = args
                .get("faults")
                .map(coordinator::FaultSchedule::parse)
                .transpose()?;
            // Tenant resolution mirrors the trace: explicit --tenants
            // (builtin token or JSON path), then the [tenants] section of
            // --config, then the single default tenant (the legacy stack).
            let tenants = resolve_tenants(args.get("tenants"), config.as_ref())?;
            let classless = args.get_flag("single-queue");
            let record_path = args.get("record").map(PathBuf::from);
            let specs = fleet_specs(&args, config.as_ref(), engines_flag)?;
            let mut cfg = coordinator::FleetConfig {
                requests,
                batch,
                parallel,
                autoscale,
                faults,
                tenants,
                classless,
                record: record_path.is_some(),
                ..Default::default()
            };
            if let Some(ms) = args.get("slo-ms").map(|v| v.parse::<u64>()).transpose()? {
                cfg.policy.slo = std::time::Duration::from_millis(ms);
            }
            let report_path = args.get("report").map(PathBuf::from);
            args.finish()?;
            let (rep, record) = run_fleet(trace, specs, cfg)?;
            write!(out, "{}", rep.render())?;
            if let (Some(path), Some(log)) = (record_path, record) {
                std::fs::write(&path, log)?;
                writeln!(out, "-- recorded {path:?}")?;
            }
            write_report(&mut out, report_path, rep.to_json())?;
        }
        "montecarlo" => {
            // Through the sweep engine: default grid is the two STT base
            // cases at the GLB Δ; `--sweep mc_samples=...|...,delta=...`
            // and `--tech wei2019` reshape it like any other sweep, and
            // `--parallel N` feeds both point- and chunk-level parallelism
            // (bit-identical results either way).
            let n = args.get_u64("samples", 20_000)?;
            let seed = args.get_u64("seed", 0xD1E5)?;
            let runner = runner_from(&args)?;
            args.finish()?;
            report::figures::montecarlo_with(&mut out, &runner, seed, n)?;
        }
        "exposure" => {
            args.finish()?;
            use stt_ai::ber::{zoo_exposure, BankSplit, WordKind};
            let zoo = stt_ai::models::zoo();
            writeln!(out, "== zoo fault exposure (bf16, STT-AI Ultra banks) ==")?;
            writeln!(out, "{:<14} {:>10} {:>14} {:>16} {:>14}", "model", "E[flips]", "P(corrupt)", "P(catastrophic)", "E[|dw/w|]")?;
            for e in zoo_exposure(&zoo, stt_ai::models::DType::Bf16, &BankSplit::ultra(WordKind::Bf16)) {
                writeln!(
                    out,
                    "{:<14} {:>10.1} {:>14.2e} {:>16.2e} {:>14.2e}",
                    e.model, e.expected_flips, e.corrupted_weight_fraction, e.catastrophic_fraction, e.mean_rel_perturbation
                )?;
            }
        }
        "init-config" => {
            let dir = PathBuf::from(args.get_or("dir", "configs"));
            args.finish()?;
            std::fs::create_dir_all(&dir)?;
            for cfg in [
                SystemConfig::paper_baseline(),
                SystemConfig::paper_stt_ai(),
                SystemConfig::paper_stt_ai_ultra(),
            ] {
                let path = dir.join(format!("{}.json", cfg.name));
                cfg.save(&path)?;
                writeln!(out, "wrote {path:?}")?;
            }
        }
        "" | "help" => {
            write!(out, "{USAGE}")?;
        }
        other => {
            anyhow::bail!("unknown command {other:?}\n\n{USAGE}");
        }
    }
    Ok(())
}
