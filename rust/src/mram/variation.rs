//! Process and temperature (PT) variation handling: Eq. 17–18, Fig. 7–8.
//!
//! Δ varies with process (MTJ diameter, H_K — chip-to-chip dominated, σ =
//! 2.1% of mean from the silicon data of [6]) and with runtime temperature
//! (Δ ∝ 1/T, Eq. 12). The design recipe:
//!
//! * build the MTJ with Δ_PT_GuardBanded such that even a −4σ die at T_hot
//!   still shows at least Δ_scaled (Eq. 17) — protecting retention;
//! * size the write path for Δ_PT_MAX, the +4σ die at T_cold (Eq. 18) —
//!   protecting WER; the adjustable write driver (Fig. 9) supplies that
//!   current only when the PTM says it is needed.


/// PT variation model parameters.
#[derive(Debug, Clone, Copy)]
pub struct PtVariation {
    /// Fractional process σ of Δ (chip-to-chip), e.g. 0.021 from [6].
    pub sigma_frac: f64,
    /// Number of σ to guard (paper: 4σ → 99.993% of samples).
    pub n_sigma: f64,
    /// Nominal temperature (K).
    pub t_nom: f64,
    /// Hot corner (K). Paper: 120 °C = 393 K.
    pub t_hot: f64,
    /// Cold corner (K). Paper: −20 °C = 253 K.
    pub t_cold: f64,
}

impl PtVariation {
    /// The paper's §V.C settings.
    pub fn paper() -> Self {
        Self { sigma_frac: 0.021, n_sigma: 4.0, t_nom: 300.0, t_hot: 393.0, t_cold: 253.0 }
    }

    /// No-variation model (for ablation benches).
    pub fn none() -> Self {
        Self { sigma_frac: 0.0, n_sigma: 0.0, t_nom: 300.0, t_hot: 300.0, t_cold: 300.0 }
    }

    /// Eq. 17 solved for Δ_PT_GuardBanded:
    /// Δ_scaled ≤ (Δ_GB − nσ)·(T_nom/T_hot), σ = sigma_frac·Δ_GB
    /// ⇒ Δ_GB = Δ_scaled·(T_hot/T_nom) / (1 − n·sigma_frac).
    pub fn guard_band(&self, delta_scaled: f64) -> GuardBand {
        let denom = 1.0 - self.n_sigma * self.sigma_frac;
        assert!(denom > 0.0, "guard-band fraction too large");
        let delta_gb = delta_scaled * (self.t_hot / self.t_nom) / denom;
        GuardBand {
            delta_scaled,
            delta_guard_banded: delta_gb,
            delta_pt_max: self.delta_pt_max(delta_gb),
        }
    }

    /// Eq. 18: Δ_PT_MAX = (Δ_GB + nσ)·(T_nom/T_cold).
    pub fn delta_pt_max(&self, delta_guard_banded: f64) -> f64 {
        (delta_guard_banded * (1.0 + self.n_sigma * self.sigma_frac)) * (self.t_nom / self.t_cold)
    }

    /// Δ of a die at process offset `n_sigma_proc`·σ and temperature `t` (K),
    /// for Monte-Carlo-style corner sampling (Fig. 8).
    pub fn delta_at(&self, delta_guard_banded: f64, n_sigma_proc: f64, t: f64) -> f64 {
        delta_guard_banded * (1.0 + n_sigma_proc * self.sigma_frac) * (self.t_nom / t)
    }

    /// Fraction of dies covered by the ±nσ guard (two-sided normal), via an
    /// erf-free Abramowitz–Stegun approximation — good to ~1e-7 which is
    /// plenty for reporting "99.993%".
    pub fn coverage(&self) -> f64 {
        let x = self.n_sigma / std::f64::consts::SQRT_2;
        // A&S 7.1.26 erf approximation.
        let t = 1.0 / (1.0 + 0.327_591_1 * x);
        let poly = t
            * (0.254_829_592 + t * (-0.284_496_736 + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
        let erf = 1.0 - poly * (-x * x).exp();
        erf
    }
}

/// Output of the Eq. 17–18 guard-banding.
#[derive(Debug, Clone, Copy)]
pub struct GuardBand {
    pub delta_scaled: f64,
    pub delta_guard_banded: f64,
    pub delta_pt_max: f64,
}

/// A named PT corner for corner-sweep benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PtCorner {
    /// Nominal process, nominal temperature.
    Typical,
    /// −nσ process at T_hot — minimum Δ: retention/read-disturb worst case.
    HotSlow,
    /// +nσ process at T_cold — maximum Δ: write worst case.
    ColdFast,
}

impl PtCorner {
    pub const ALL: [PtCorner; 3] = [PtCorner::Typical, PtCorner::HotSlow, PtCorner::ColdFast];

    /// Effective Δ of a guard-banded design at this corner.
    pub fn delta(&self, v: &PtVariation, delta_guard_banded: f64) -> f64 {
        match self {
            PtCorner::Typical => v.delta_at(delta_guard_banded, 0.0, v.t_nom),
            PtCorner::HotSlow => v.delta_at(delta_guard_banded, -v.n_sigma, v.t_hot),
            PtCorner::ColdFast => v.delta_at(delta_guard_banded, v.n_sigma, v.t_cold),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_band_reproduces_paper_values() {
        let v = PtVariation::paper();
        // Δ=19.5 → Δ_PT_GB ≈ 27.5 (paper §V.C).
        let gb = v.guard_band(19.5);
        assert!((gb.delta_guard_banded - 27.5).abs() < 1.5, "{}", gb.delta_guard_banded);
        // Δ=39 → Δ_PT_GB ≈ 55.
        let gb = v.guard_band(39.0);
        assert!((gb.delta_guard_banded - 55.0).abs() < 2.0, "{}", gb.delta_guard_banded);
        // Δ=12.5 → Δ_PT_GB ≈ 17.5.
        let gb = v.guard_band(12.5);
        assert!((gb.delta_guard_banded - 17.5).abs() < 1.0, "{}", gb.delta_guard_banded);
    }

    #[test]
    fn hot_slow_corner_recovers_delta_scaled() {
        // By construction the −4σ die at T_hot shows exactly Δ_scaled.
        let v = PtVariation::paper();
        let gb = v.guard_band(19.5);
        let worst = PtCorner::HotSlow.delta(&v, gb.delta_guard_banded);
        assert!((worst - 19.5).abs() < 1e-9, "worst={worst}");
    }

    #[test]
    fn cold_fast_corner_is_pt_max() {
        let v = PtVariation::paper();
        let gb = v.guard_band(19.5);
        let max = PtCorner::ColdFast.delta(&v, gb.delta_guard_banded);
        assert!((max - gb.delta_pt_max).abs() < 1e-9);
        assert!(max > gb.delta_guard_banded);
    }

    #[test]
    fn no_variation_is_identity() {
        let v = PtVariation::none();
        let gb = v.guard_band(19.5);
        assert!((gb.delta_guard_banded - 19.5).abs() < 1e-12);
        assert!((gb.delta_pt_max - 19.5).abs() < 1e-12);
    }

    #[test]
    fn coverage_of_4_sigma() {
        let v = PtVariation::paper();
        let c = v.coverage();
        assert!((c - 0.99993).abs() < 1e-4, "coverage={c}");
    }

    #[test]
    fn corners_ordered() {
        let v = PtVariation::paper();
        let gb = v.guard_band(30.0);
        let d_hot = PtCorner::HotSlow.delta(&v, gb.delta_guard_banded);
        let d_typ = PtCorner::Typical.delta(&v, gb.delta_guard_banded);
        let d_cold = PtCorner::ColdFast.delta(&v, gb.delta_guard_banded);
        assert!(d_hot < d_typ && d_typ < d_cold);
    }
}
