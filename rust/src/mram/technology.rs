//! The pluggable memory-technology layer: every device stack the framework
//! can build a buffer out of, behind one [`MemTechnology`] trait.
//!
//! # Trait contract
//!
//! A [`MemTechnology`] bundles the five things the layers above need to know
//! about a bit cell, and nothing else:
//!
//! 1. **Retention / Δ model** — [`MemTechnology::retention_time`] and its
//!    inverse [`MemTechnology::delta_for_retention`]. Non-volatile
//!    technologies follow the Arrhenius law of Eq. 14 with their own τ;
//!    volatile technologies report infinite retention and Δ = 0 (callers
//!    that serialize metrics should clamp with [`finite_or_max`]).
//! 2. **Read/write dynamics** — [`MemTechnology::write_pulse`] /
//!    [`MemTechnology::read_pulse`] map a WER / read-disturb budget and a Δ
//!    to pulse widths (Eq. 15–16 for STT; incubation-free switching for SOT;
//!    capacity-independent latency class for SRAM).
//! 3. **Critical-current / write-driver model** —
//!    [`MemTechnology::critical_current`], the I_c(Δ) anchor the adjustable
//!    write driver (Fig. 9) and the energy scalings hang off.
//! 4. **Area / energy per bit** — the Destiny-like array calibration:
//!    [`MemTechnology::cell_area_f2`], [`MemTechnology::periphery_mult`],
//!    [`MemTechnology::leakage_mw`], [`MemTechnology::read_energy_j`],
//!    [`MemTechnology::write_energy_j`], [`MemTechnology::ctrl_dynamic_mw`].
//!    `cap_ratio` is capacity / 12 MB (the calibration anchor), `cap_mb` is
//!    capacity in MiB. Implementations must keep these formulas *pure* —
//!    [`crate::memsys::MemoryArray`] is a thin shell over them.
//! 5. **Variation guard-banding** — [`MemTechnology::guard_band`] applies
//!    the Eq. 17–18 process/temperature recipe (or a no-op for volatile
//!    cells).
//!
//! The [`SttMram`] implementation routes every method to the exact same
//! free functions (`reliability::*`) and constants the pre-refactor
//! hard-coded paths used, so the paper figures stay byte-identical — the
//! parity tests in `tests/figures.rs` enforce this. [`SotMram`] and
//! [`Sram`] open the scenario space the ROADMAP names (SOT-MRAM
//! co-optimization, arXiv:2303.12310 class, and the SRAM baseline as a
//! first-class registry citizen).
//!
//! Technologies are enumerated by the Copy-able [`TechnologyId`] so that
//! value types (`MemoryArray`, bank specs, sweep points) stay `Copy`;
//! [`TechnologyId::technology`] resolves the id to the `'static` trait
//! object, and [`registry`] / [`by_token`] expose the full set to the DSE
//! engine's `tech` axis and the CLI's `--tech stt|sot|sram`.

use std::sync::OnceLock;

use super::mtj::MtjTech;
use super::reliability::{read_pulse_at_rd, retention_time_at_ber, write_pulse_at_wer};
use super::variation::{GuardBand, PtVariation};

/// Reference Δ at which the MRAM-class energy/area constants are anchored
/// (the paper's GLB design point, Δ_PT_GB = 27.5).
pub const DELTA_REF: f64 = 27.5;

/// Practical lower bound on any operating pulse (s): driver slew, sense-amp
/// setup and wordline RC at 14 nm keep real accesses at the ~1 ns class even
/// when the reliability solve permits a shorter pulse. Every service-rate
/// and programming-time path floors with this constant so tiny-budget
/// solves can never report sub-physical access times.
pub const PRACTICAL_PULSE_FLOOR: f64 = 1.0e-9;

/// Upper bound on the *operating* read pulse (s) used for service-rate
/// modeling: the disturb budget only bounds the read pulse from above, and
/// a relaxed budget "permits" arbitrarily slow reads — a real design still
/// senses at the base-silicon latency class (4 ns, [6]/[13]).
pub const READ_SERVICE_CAP: f64 = 4.0e-9;

/// Clamp a possibly-infinite technology metric (SRAM retention) to the
/// largest finite f64 so CSV/JSON records stay well-formed.
pub fn finite_or_max(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        f64::MAX
    }
}

/// Copy-able identifier of a registered memory technology.
///
/// The two STT entries share one array-level model (the 1T-1MTJ calibration
/// of Table III) but carry different silicon base cases for the Δ-scaling
/// dynamics ([6] vs [13]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum TechnologyId {
    /// STT-MRAM, Sakhare et al. TED 2020 [6] base case (paper default).
    #[default]
    SttSakhare2020,
    /// STT-MRAM, Wei et al. ISSCC 2019 [13] base case.
    SttWei2019,
    /// SOT-MRAM (three-terminal, decoupled read/write path).
    Sot,
    /// 6T SRAM (volatile baseline).
    Sram,
}

impl TechnologyId {
    /// Resolve to the singleton technology model.
    pub fn technology(self) -> &'static dyn MemTechnology {
        match self {
            TechnologyId::SttSakhare2020 => {
                static T: OnceLock<SttMram> = OnceLock::new();
                T.get_or_init(SttMram::sakhare2020)
            }
            TechnologyId::SttWei2019 => {
                static T: OnceLock<SttMram> = OnceLock::new();
                T.get_or_init(SttMram::wei2019)
            }
            TechnologyId::Sot => &SotMram,
            TechnologyId::Sram => &Sram,
        }
    }

    /// Whether this id names an STT-MRAM base case.
    pub fn is_stt(self) -> bool {
        matches!(self, TechnologyId::SttSakhare2020 | TechnologyId::SttWei2019)
    }
}

/// The pluggable memory-technology abstraction. See the module docs for the
/// full contract of each method group.
pub trait MemTechnology: std::fmt::Debug + Send + Sync {
    /// The id this model answers to.
    fn id(&self) -> TechnologyId;
    /// Human-readable base-case name (stable: used in sweep records).
    fn name(&self) -> &'static str;
    /// Canonical CLI token (`stt` / `sot` / `sram`).
    fn token(&self) -> &'static str;
    /// Whether the cell retains data without power.
    fn is_nonvolatile(&self) -> bool;

    // -- retention / Δ model -------------------------------------------------
    /// Retention time (s) at a per-bit failure budget `ber` for stability
    /// factor `delta`. Volatile cells return `f64::INFINITY`.
    fn retention_time(&self, delta: f64, ber: f64) -> f64;
    /// Minimum Δ whose retention at `ber` covers `retention_s` (0 for
    /// volatile cells: no Δ knob exists).
    fn delta_for_retention(&self, retention_s: f64, ber: f64) -> f64;
    /// Process/temperature guard-banding of a scaled Δ (Eq. 17–18).
    fn guard_band(&self, delta_scaled: f64) -> GuardBand;

    // -- read/write dynamics -------------------------------------------------
    /// Write pulse width (s) meeting the WER budget at `delta`.
    fn write_pulse(&self, wer: f64, delta: f64) -> f64;
    /// Read pulse width (s) meeting the read-disturb budget at `delta`.
    fn read_pulse(&self, rd_ber: f64, delta: f64) -> f64;
    /// Critical switching current I_c(Δ) (A); 0 for volatile cells.
    fn critical_current(&self, delta: f64) -> f64;

    // -- service rates (write-bandwidth stall model) -------------------------
    /// Operating write pulse (s) for service-rate modeling: the reliability
    /// solve floored at the practical driver limit
    /// ([`PRACTICAL_PULSE_FLOOR`]).
    fn write_service_pulse(&self, wer: f64, delta: f64) -> f64 {
        self.write_pulse(wer, delta).max(PRACTICAL_PULSE_FLOOR)
    }
    /// Operating read pulse (s) for service-rate modeling: the disturb-
    /// limited pulse clamped between the practical floor and the
    /// sense-amp-class cap ([`READ_SERVICE_CAP`]) — a relaxed disturb budget
    /// permits slow reads but never forces them.
    fn read_service_pulse(&self, rd_ber: f64, delta: f64) -> f64 {
        self.read_pulse(rd_ber, delta).clamp(PRACTICAL_PULSE_FLOOR, READ_SERVICE_CAP)
    }

    // -- array calibration (Destiny-like, anchored at 12 MB / Δ_REF) --------
    /// Bit-cell area in F² at guard-banded Δ `delta_gb`.
    fn cell_area_f2(&self, delta_gb: f64) -> f64;
    /// Periphery/overhead multiplier on cell area.
    fn periphery_mult(&self) -> f64;
    /// Macro leakage (mW) for `cap_mb` MiB at `delta_gb`.
    fn leakage_mw(&self, delta_gb: f64, cap_mb: f64) -> f64;
    /// Per-access read energy (J) for a 64-bit word; `cap_ratio` = cap/12 MB.
    fn read_energy_j(&self, delta_gb: f64, cap_ratio: f64) -> f64;
    /// Per-access write energy (J) for a 64-bit word.
    fn write_energy_j(&self, delta_gb: f64, cap_ratio: f64) -> f64;
    /// Controller/clock-tree dynamic power (mW) at the reference rate.
    fn ctrl_dynamic_mw(&self, cap_ratio: f64) -> f64;

    // -- default design points ----------------------------------------------
    /// Δ_PT_GB of the robust GLB-class bank (0 for volatile cells).
    fn default_glb_delta(&self) -> f64;
    /// Δ_PT_GB of the relaxed LSB-class bank (0 for volatile cells).
    fn default_lsb_delta(&self) -> f64;
}

/// Every registered technology, in a stable order (the `tech` axis grid).
pub fn registry() -> [&'static dyn MemTechnology; 4] {
    [
        TechnologyId::SttSakhare2020.technology(),
        TechnologyId::SttWei2019.technology(),
        TechnologyId::Sot.technology(),
        TechnologyId::Sram.technology(),
    ]
}

/// Parse a CLI token into a registered technology. Accepts the family
/// tokens (`stt`, `sot`, `sram`) and the explicit base-case names.
pub fn by_token(s: &str) -> Option<&'static dyn MemTechnology> {
    let t = s.to_lowercase().replace('-', "_");
    let id = match t.as_str() {
        "stt" | "stt_mram" | "sakhare2020" => TechnologyId::SttSakhare2020,
        "wei2019" => TechnologyId::SttWei2019,
        "sot" | "sot_mram" | "sot2023" => TechnologyId::Sot,
        "sram" => TechnologyId::Sram,
        _ => return None,
    };
    Some(id.technology())
}

// ---------------------------------------------------------------------------
// STT-MRAM
// ---------------------------------------------------------------------------

/// STT-MRAM behind the trait: Δ dynamics from one [`MtjTech`] silicon base
/// case, array calibration from the Table III anchors. Byte-for-byte
/// identical to the pre-refactor hard-coded paths.
#[derive(Debug, Clone, Copy)]
pub struct SttMram {
    id: TechnologyId,
    base: MtjTech,
    variation: PtVariation,
}

impl SttMram {
    pub fn sakhare2020() -> Self {
        Self {
            id: TechnologyId::SttSakhare2020,
            base: MtjTech::sakhare2020(),
            variation: PtVariation::paper(),
        }
    }

    pub fn wei2019() -> Self {
        Self {
            id: TechnologyId::SttWei2019,
            base: MtjTech::wei2019(),
            variation: PtVariation::paper(),
        }
    }

    /// The underlying silicon base case (for the STT-specific Δ solver).
    pub fn base(&self) -> MtjTech {
        self.base
    }
}

impl MemTechnology for SttMram {
    fn id(&self) -> TechnologyId {
        self.id
    }
    fn name(&self) -> &'static str {
        self.base.name
    }
    fn token(&self) -> &'static str {
        // The family token resolves to the default base case, so the
        // non-default Wei2019 entry must round-trip by its explicit name.
        match self.id {
            TechnologyId::SttWei2019 => "wei2019",
            _ => "stt",
        }
    }
    fn is_nonvolatile(&self) -> bool {
        true
    }

    fn retention_time(&self, delta: f64, ber: f64) -> f64 {
        retention_time_at_ber(self.base.tau_ret, delta, ber)
    }

    fn delta_for_retention(&self, retention_s: f64, ber: f64) -> f64 {
        let lhs = -(-ber).ln_1p();
        (retention_s / (self.base.tau_ret * lhs)).ln()
    }

    fn guard_band(&self, delta_scaled: f64) -> GuardBand {
        self.variation.guard_band(delta_scaled)
    }

    fn write_pulse(&self, wer: f64, delta: f64) -> f64 {
        write_pulse_at_wer(wer, self.base.tau_w, delta, self.base.overdrive_base)
    }

    fn read_pulse(&self, rd_ber: f64, delta: f64) -> f64 {
        read_pulse_at_rd(rd_ber, self.base.tau_rd, delta, self.base.read_ratio)
    }

    fn critical_current(&self, delta: f64) -> f64 {
        self.base.params_at_delta(delta).critical_current()
    }

    fn cell_area_f2(&self, delta_gb: f64) -> f64 {
        6.0 * (delta_gb / DELTA_REF).powf(0.4)
    }

    fn periphery_mult(&self) -> f64 {
        8.53
    }

    fn leakage_mw(&self, delta_gb: f64, cap_mb: f64) -> f64 {
        0.006_67 * cap_mb * (delta_gb / DELTA_REF).powf(1.5)
    }

    fn read_energy_j(&self, delta_gb: f64, cap_ratio: f64) -> f64 {
        let d = delta_gb / DELTA_REF;
        (20.0 + 10.0 * d * cap_ratio.powf(0.5)) * 1e-12
    }

    fn write_energy_j(&self, delta_gb: f64, cap_ratio: f64) -> f64 {
        let d = delta_gb / DELTA_REF;
        (28.0 + 22.0 * d * d * cap_ratio.powf(0.5)) * 1e-12
    }

    fn ctrl_dynamic_mw(&self, cap_ratio: f64) -> f64 {
        9.2 * cap_ratio.powf(0.5)
    }

    fn default_glb_delta(&self) -> f64 {
        27.5
    }
    fn default_lsb_delta(&self) -> f64 {
        17.5
    }
}

// ---------------------------------------------------------------------------
// SOT-MRAM
// ---------------------------------------------------------------------------

/// SOT-MRAM: three-terminal cell writing through a heavy-metal track.
///
/// Modeling assumptions (provisional calibration for the ROADMAP's
/// arXiv:2303.12310 co-optimization scenario; revisit against silicon):
///
/// * retention is the same Arrhenius Eq. 14 law (τ = 1 s calibration class);
/// * switching is incubation-free, so the write pulse is sub-ns and only
///   weakly (logarithmically) dependent on the WER budget;
/// * the read path is decoupled from the write path, so read pulses are
///   sense-limited, not disturb-limited;
/// * the two-transistor cell is ~2× the 1T-1MTJ footprint, with the same
///   Δ^0.4 access-device shrink;
/// * write energy is near read-class (short pulse beats the higher track
///   current) and only ~linear in Δ — which is what makes SOT attractive
///   for write-intensive (training-style) scratchpad traffic.
#[derive(Debug, Clone, Copy)]
pub struct SotMram;

/// SOT incubation-free switching time scale (s).
const SOT_T_W0: f64 = 0.35e-9;
/// SOT sense-limited read pulse (s).
const SOT_T_READ: f64 = 1.2e-9;

impl MemTechnology for SotMram {
    fn id(&self) -> TechnologyId {
        TechnologyId::Sot
    }
    fn name(&self) -> &'static str {
        "sot2023"
    }
    fn token(&self) -> &'static str {
        "sot"
    }
    fn is_nonvolatile(&self) -> bool {
        true
    }

    fn retention_time(&self, delta: f64, ber: f64) -> f64 {
        retention_time_at_ber(1.0, delta, ber)
    }

    fn delta_for_retention(&self, retention_s: f64, ber: f64) -> f64 {
        let lhs = -(-ber).ln_1p();
        (retention_s / lhs).ln()
    }

    fn guard_band(&self, delta_scaled: f64) -> GuardBand {
        PtVariation::paper().guard_band(delta_scaled)
    }

    fn write_pulse(&self, wer: f64, delta: f64) -> f64 {
        // Incubation-free: t_w ≈ t0·(1 + ln(1/WER)/(2Δ)) — sub-ns across the
        // whole Δ/WER design space, vs the STT ln(Δ)/overdrive law.
        SOT_T_W0 * (1.0 + (-wer.ln()) / (2.0 * delta.max(1.0)))
    }

    fn read_pulse(&self, _rd_ber: f64, _delta: f64) -> f64 {
        // Read current does not flow through the write path: disturb-free,
        // sense-amp-limited.
        SOT_T_READ
    }

    fn critical_current(&self, delta: f64) -> f64 {
        // Track current ∝ Δ with a higher prefactor than STT (η_SOT < η_STT
        // per written bit, compensated by the short pulse).
        super::mtj::critical_current(delta, 300.0, 0.01, 0.35, 2.4e5, 1.2e5)
    }

    fn cell_area_f2(&self, delta_gb: f64) -> f64 {
        12.0 * (delta_gb / DELTA_REF).powf(0.4)
    }

    fn periphery_mult(&self) -> f64 {
        8.53
    }

    fn leakage_mw(&self, delta_gb: f64, cap_mb: f64) -> f64 {
        0.008 * cap_mb * (delta_gb / DELTA_REF).powf(1.5)
    }

    fn read_energy_j(&self, delta_gb: f64, cap_ratio: f64) -> f64 {
        let d = delta_gb / DELTA_REF;
        (16.0 + 6.0 * d * cap_ratio.powf(0.5)) * 1e-12
    }

    fn write_energy_j(&self, delta_gb: f64, cap_ratio: f64) -> f64 {
        // Short incubation-free pulse ⇒ near-read-class energy, linear in Δ
        // (vs quadratic for STT).
        let d = delta_gb / DELTA_REF;
        (22.0 + 7.0 * d * cap_ratio.powf(0.5)) * 1e-12
    }

    fn ctrl_dynamic_mw(&self, cap_ratio: f64) -> f64 {
        9.2 * cap_ratio.powf(0.5)
    }

    fn default_glb_delta(&self) -> f64 {
        27.5
    }
    fn default_lsb_delta(&self) -> f64 {
        17.5
    }
}

// ---------------------------------------------------------------------------
// SRAM
// ---------------------------------------------------------------------------

/// 6T SRAM as a first-class registry citizen: volatile, no Δ knob, with the
/// Table III baseline calibration.
#[derive(Debug, Clone, Copy)]
pub struct Sram;

impl MemTechnology for Sram {
    fn id(&self) -> TechnologyId {
        TechnologyId::Sram
    }
    fn name(&self) -> &'static str {
        "sram"
    }
    fn token(&self) -> &'static str {
        "sram"
    }
    fn is_nonvolatile(&self) -> bool {
        false
    }

    fn retention_time(&self, _delta: f64, _ber: f64) -> f64 {
        f64::INFINITY
    }

    fn delta_for_retention(&self, _retention_s: f64, _ber: f64) -> f64 {
        0.0
    }

    fn guard_band(&self, delta_scaled: f64) -> GuardBand {
        GuardBand { delta_scaled, delta_guard_banded: delta_scaled, delta_pt_max: delta_scaled }
    }

    fn write_pulse(&self, _wer: f64, _delta: f64) -> f64 {
        1.0e-9
    }

    fn read_pulse(&self, _rd_ber: f64, _delta: f64) -> f64 {
        1.0e-9
    }

    fn critical_current(&self, _delta: f64) -> f64 {
        0.0
    }

    fn cell_area_f2(&self, _delta_gb: f64) -> f64 {
        100.0
    }

    fn periphery_mult(&self) -> f64 {
        8.21
    }

    fn leakage_mw(&self, _delta_gb: f64, cap_mb: f64) -> f64 {
        0.0175 * cap_mb
    }

    fn read_energy_j(&self, _delta_gb: f64, cap_ratio: f64) -> f64 {
        (5.0 + 112.0 * cap_ratio.powf(0.9)) * 1e-12
    }

    fn write_energy_j(&self, _delta_gb: f64, cap_ratio: f64) -> f64 {
        (5.0 + 112.0 * cap_ratio.powf(0.9)) * 1e-12
    }

    fn ctrl_dynamic_mw(&self, cap_ratio: f64) -> f64 {
        25.6 * cap_ratio.powf(0.5)
    }

    fn default_glb_delta(&self) -> f64 {
        0.0
    }
    fn default_lsb_delta(&self) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mram::{DesignTargets, ScalingSolver};

    #[test]
    fn registry_is_complete_and_tokens_resolve() {
        let names: Vec<&str> = registry().iter().map(|t| t.name()).collect();
        assert_eq!(names, vec!["sakhare2020", "wei2019", "sot2023", "sram"]);
        for t in registry() {
            assert_eq!(by_token(t.token()).unwrap().id(), t.id(), "{}", t.name());
            assert_eq!(t.id().technology().name(), t.name());
        }
        assert_eq!(by_token("stt").unwrap().name(), "sakhare2020");
        assert_eq!(by_token("wei2019").unwrap().id(), TechnologyId::SttWei2019);
        assert_eq!(by_token("SOT-MRAM").unwrap().id(), TechnologyId::Sot);
        assert!(by_token("dram").is_none());
    }

    #[test]
    fn stt_trait_matches_legacy_solver_exactly() {
        // The trait path must be the *same arithmetic* as ScalingSolver —
        // bit-identical, not just close (figure parity depends on it).
        let t = TechnologyId::SttSakhare2020.technology();
        let s = ScalingSolver::new(MtjTech::sakhare2020());
        for delta in [12.5, 19.5, 27.5, 39.0, 60.0] {
            for ber in [1e-9, 1e-8, 1e-5] {
                assert_eq!(t.retention_time(delta, ber), s.retention_vs_delta(ber, &[delta])[0].1);
                assert_eq!(t.read_pulse(ber, delta), s.read_pulse_vs_delta(ber, &[delta])[0].1);
                assert_eq!(t.write_pulse(ber, delta), s.write_pulse_vs_delta(ber, &[delta])[0].1);
            }
        }
        assert_eq!(
            t.delta_for_retention(3.0, 1e-8),
            s.delta_for_retention(&DesignTargets::global_buffer())
        );
        let gb = t.guard_band(19.5);
        assert_eq!(gb.delta_guard_banded, s.variation.guard_band(19.5).delta_guard_banded);
    }

    #[test]
    fn sot_is_write_cheap_and_stt_is_dense() {
        let sot = TechnologyId::Sot.technology();
        let stt = TechnologyId::SttSakhare2020.technology();
        // SOT writes are sub-ns and cheaper than STT at the GLB point.
        assert!(sot.write_pulse(1e-8, 27.5) < 1.0e-9);
        assert!(sot.write_pulse(1e-8, 27.5) < stt.write_pulse(1e-8, 27.5));
        assert!(sot.write_energy_j(27.5, 1.0) < stt.write_energy_j(27.5, 1.0));
        // STT keeps the density edge (1T vs 2T cell).
        assert!(stt.cell_area_f2(27.5) < sot.cell_area_f2(27.5));
        // Both retain by the same Arrhenius class.
        let r_sot = sot.retention_time(19.5, 1e-8);
        assert!(r_sot > 2.0 && r_sot < 4.0, "{r_sot}");
    }

    #[test]
    fn sram_reports_volatile_semantics() {
        let s = TechnologyId::Sram.technology();
        assert!(!s.is_nonvolatile());
        assert_eq!(s.retention_time(0.0, 1e-8), f64::INFINITY);
        assert_eq!(finite_or_max(s.retention_time(0.0, 1e-8)), f64::MAX);
        assert_eq!(s.delta_for_retention(3.0, 1e-8), 0.0);
        assert_eq!(s.critical_current(27.5), 0.0);
        assert_eq!(s.cell_area_f2(0.0), 100.0);
    }

    #[test]
    fn service_pulses_are_floored_and_capped() {
        for t in registry() {
            for (delta, ber) in [(12.5, 1e-5), (17.5, 1e-8), (27.5, 1e-8), (55.0, 1e-9)] {
                let w = t.write_service_pulse(ber, delta);
                let r = t.read_service_pulse(ber, delta);
                assert!(w >= PRACTICAL_PULSE_FLOOR, "{}: write {w}", t.name());
                assert!(
                    (PRACTICAL_PULSE_FLOOR..=READ_SERVICE_CAP).contains(&r),
                    "{}: read {r}",
                    t.name()
                );
            }
        }
        let stt = TechnologyId::SttSakhare2020.technology();
        // Above the floor the write service pulse is the reliability solve.
        assert_eq!(stt.write_service_pulse(1e-8, 27.5), stt.write_pulse(1e-8, 27.5));
        // The relaxed-budget read pulse (µs-class disturb bound at Δ 27.5)
        // is capped at the sense-amp class, not taken literally.
        assert!(stt.read_pulse(1e-5, 27.5) > 1.0e-6);
        assert_eq!(stt.read_service_pulse(1e-5, 27.5), READ_SERVICE_CAP);
        // The tight-budget low-Δ read pulse (ps-class) is floored.
        assert!(stt.read_pulse(1e-8, 12.5) < PRACTICAL_PULSE_FLOOR);
        assert_eq!(stt.read_service_pulse(1e-8, 12.5), PRACTICAL_PULSE_FLOOR);
    }

    #[test]
    fn write_pulse_orderings_hold_across_registry() {
        // Tighter WER never shortens the pulse, for every technology.
        for t in registry() {
            let relaxed = t.write_pulse(1e-5, 27.5);
            let tight = t.write_pulse(1e-9, 27.5);
            assert!(tight >= relaxed, "{}: {tight} < {relaxed}", t.name());
        }
    }
}
