//! STT-MRAM reliability models: retention failure (Eq. 14), read disturb
//! (Eq. 15), write error rate (Eq. 16).

/// Retention failure probability over `t_ret` seconds (Eq. 14):
/// P_RF = 1 − exp(−t_ret / (τ · exp(Δ))).
///
/// Computed via `-expm1` for accuracy at the tiny probabilities (1e-9 .. 1e-5)
/// this design space lives in.
pub fn retention_failure_prob(t_ret: f64, tau: f64, delta: f64) -> f64 {
    debug_assert!(t_ret >= 0.0 && tau > 0.0);
    -(-t_ret / (tau * delta.exp())).exp_m1()
}

/// Eq. 14 with the sweep-invariant ratio `t_ret / τ` hoisted out of the
/// per-sample loop: P_RF = 1 − exp(−(t_ret/τ)·exp(−Δ)).
///
/// Mathematically identical to [`retention_failure_prob`] (agrees to ~1 ulp;
/// the property tests pin the two together) but costs one division less per
/// call — the Monte-Carlo engine computes `t_over_tau` once per chunk and
/// only Δ varies per sample.
#[inline]
pub fn retention_failure_prob_pre(t_over_tau: f64, delta: f64) -> f64 {
    debug_assert!(t_over_tau >= 0.0);
    -(-t_over_tau * (-delta).exp()).exp_m1()
}

/// Mean thermal lifetime τ·exp(Δ) — the "retention time" knob of Fig. 15 when
/// quoted without a BER qualifier.
pub fn mean_retention_time(tau: f64, delta: f64) -> f64 {
    tau * delta.exp()
}

/// Retention time achievable at a per-bit failure budget `ber`
/// (inverse of Eq. 14): t = τ·exp(Δ)·(−ln(1−ber)).
pub fn retention_time_at_ber(tau: f64, delta: f64, ber: f64) -> f64 {
    debug_assert!((0.0..1.0).contains(&ber));
    tau * delta.exp() * -(-ber).ln_1p()
}

/// Read disturb probability for read pulse `t_r` at read-current ratio
/// `ir_over_ic` (Eq. 15): P_RD = 1 − exp(−t_r / (τ·exp(Δ(1 − I_r/I_c)))).
pub fn read_disturb_prob(t_r: f64, tau: f64, delta: f64, ir_over_ic: f64) -> f64 {
    debug_assert!(t_r >= 0.0 && tau > 0.0);
    debug_assert!((0.0..1.0).contains(&ir_over_ic), "read current must be sub-critical");
    -(-t_r / (tau * (delta * (1.0 - ir_over_ic)).exp())).exp_m1()
}

/// Read pulse width that keeps read-disturb probability at `p_rd`
/// (inverse of Eq. 15).
pub fn read_pulse_at_rd(p_rd: f64, tau: f64, delta: f64, ir_over_ic: f64) -> f64 {
    debug_assert!((0.0..1.0).contains(&p_rd));
    tau * (delta * (1.0 - ir_over_ic)).exp() * -(-p_rd).ln_1p()
}

/// Write error rate for write pulse `t_w` at overdrive `iw_over_ic` > 1
/// (Eq. 16, precessional-switching regime):
///
/// WER = 1 − exp( −π²·Δ·(i−1) / (4·[ i·exp((t_w/τ)(i−1)) − 1 ]) ),  i = I_w/I_c.
///
/// (The paper's Eq. 16 prints `I_w/τ` in the inner exponent; the source
/// literature [21], [22] and the stated `t_pw ∝ ln(Δ)` law both require
/// `t_w/τ`, which is what we implement.)
pub fn write_error_rate(t_w: f64, tau: f64, delta: f64, iw_over_ic: f64) -> f64 {
    debug_assert!(t_w >= 0.0 && tau > 0.0);
    write_error_rate_pre(t_w / tau, delta, iw_over_ic)
}

/// Eq. 16 with the sweep-invariant ratio `t_w / τ` hoisted out of the
/// per-sample loop; [`write_error_rate`] is now a thin wrapper, so the two
/// are bit-identical by construction. The Monte-Carlo engine computes
/// `tw_over_tau` once per chunk — only Δ and the overdrive vary per sample.
#[inline]
pub fn write_error_rate_pre(tw_over_tau: f64, delta: f64, iw_over_ic: f64) -> f64 {
    debug_assert!(tw_over_tau >= 0.0);
    debug_assert!(iw_over_ic > 1.0, "write current must exceed critical current");
    let i = iw_over_ic;
    let denom = 4.0 * (i * (tw_over_tau * (i - 1.0)).exp() - 1.0);
    let expo = -(std::f64::consts::PI.powi(2)) * delta * (i - 1.0) / denom;
    -expo.exp_m1()
}

/// Write pulse width achieving the target `wer` (inverse of Eq. 16).
///
/// Solving WER(t_w) = wer for t_w:
/// t_w = (τ/(i−1)) · ln( (1/i)·( π²Δ(i−1) / (4·(−ln(1−wer))) + 1 ) ).
pub fn write_pulse_at_wer(wer: f64, tau: f64, delta: f64, iw_over_ic: f64) -> f64 {
    debug_assert!((0.0..1.0).contains(&wer) && wer > 0.0);
    debug_assert!(iw_over_ic > 1.0);
    let i = iw_over_ic;
    let lhs = -(-wer).ln_1p(); // −ln(1−wer)
    let inner = (std::f64::consts::PI.powi(2) * delta * (i - 1.0) / (4.0 * lhs) + 1.0) / i;
    if inner <= 1.0 {
        // The WER target is met even at zero pulse width (huge overdrive or
        // tiny Δ): the minimum physical pulse is bounded by τ.
        return 0.0;
    }
    (tau / (i - 1.0)) * inner.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    const TAU: f64 = 1.0;
    const TAU_NS: f64 = 1e-9;

    #[test]
    fn retention_monotone_in_delta_and_time() {
        let p1 = retention_failure_prob(1.0, TAU, 20.0);
        let p2 = retention_failure_prob(1.0, TAU, 30.0);
        assert!(p1 > p2);
        let p3 = retention_failure_prob(2.0, TAU, 20.0);
        assert!(p3 > p1);
    }

    #[test]
    fn retention_inverse_roundtrip() {
        for delta in [12.5, 19.5, 39.0, 60.0] {
            for ber in [1e-9, 1e-8, 1e-5] {
                let t = retention_time_at_ber(TAU, delta, ber);
                let p = retention_failure_prob(t, TAU, delta);
                assert!((p / ber - 1.0).abs() < 1e-6, "delta={delta} ber={ber}");
            }
        }
    }

    #[test]
    fn paper_calibration_points() {
        // Fig. 15(a): Δ=39 → ~3 years at BER 1e-9 (τ = 1 s calibration).
        let t = retention_time_at_ber(TAU, 39.0, 1e-9);
        let years = t / super::super::YEAR_S;
        assert!(years > 2.0 && years < 4.0, "got {years} yr");
        // Fig. 15(b): Δ=19.5 → ~3 s at BER 1e-8.
        let t = retention_time_at_ber(TAU, 19.5, 1e-8);
        assert!(t > 2.0 && t < 4.0, "got {t} s");
        // Fig. 17: Δ=12.5 @ 1e-5 still covers the ≤1.5 s GLB occupancy.
        let t = retention_time_at_ber(TAU, 12.5, 1e-5);
        assert!(t > 1.5, "got {t} s");
    }

    #[test]
    fn hoisted_forms_match_the_originals() {
        for delta in [12.5, 19.5, 27.5, 39.0, 60.0] {
            for t in [0.1, 1.0, 3.0, 100.0] {
                let a = retention_failure_prob(t, TAU, delta);
                let b = retention_failure_prob_pre(t / TAU, delta);
                assert!(
                    (a - b).abs() <= 1e-12 * a.max(1e-300),
                    "delta={delta} t={t}: {a} vs {b}"
                );
            }
            for i in [1.5, 2.0, 3.0] {
                for tw in [5e-9, 10e-9, 25e-9] {
                    let a = write_error_rate(tw, TAU_NS, delta, i);
                    let b = write_error_rate_pre(tw / TAU_NS, delta, i);
                    assert_eq!(a.to_bits(), b.to_bits(), "delta={delta} i={i} tw={tw}");
                }
            }
        }
    }

    #[test]
    fn read_disturb_inverse_roundtrip() {
        let (delta, r) = (27.5, 0.25);
        let t = read_pulse_at_rd(1e-8, TAU_NS, delta, r);
        let p = read_disturb_prob(t, TAU_NS, delta, r);
        assert!((p / 1e-8 - 1.0).abs() < 1e-6);
        // Higher read current → more disturb at same pulse.
        assert!(read_disturb_prob(t, TAU_NS, delta, 0.5) > p);
    }

    #[test]
    fn wer_decreases_with_pulse_and_overdrive() {
        let (delta, i) = (27.5, 2.0);
        let w10 = write_error_rate(10e-9, TAU_NS, delta, i);
        let w20 = write_error_rate(20e-9, TAU_NS, delta, i);
        assert!(w20 < w10);
        let w10hi = write_error_rate(10e-9, TAU_NS, delta, 3.0);
        assert!(w10hi < w10);
    }

    #[test]
    fn wer_inverse_roundtrip() {
        for delta in [17.5, 27.5, 55.0, 60.0] {
            for i in [1.5, 2.0, 3.0] {
                let t = write_pulse_at_wer(1e-9, TAU_NS, delta, i);
                assert!(t > 0.0);
                let w = write_error_rate(t, TAU_NS, delta, i);
                assert!((w / 1e-9 - 1.0).abs() < 1e-6, "delta={delta} i={i}");
            }
        }
    }

    #[test]
    fn write_latency_scales_as_ln_delta() {
        // §IV.B: t_pw ∝ ln(Δ) at constant WER — check the ratio law loosely.
        let t60 = write_pulse_at_wer(1e-9, TAU_NS, 60.0, 2.0);
        let t27 = write_pulse_at_wer(1e-9, TAU_NS, 27.5, 2.0);
        assert!(t27 < t60);
        // The additive ln(Δ) term means the delta of pulse widths ≈ τ·ln(60/27.5)/(i−1).
        let expected = TAU_NS * (60.0f64 / 27.5).ln();
        assert!(((t60 - t27) / expected - 1.0).abs() < 0.2, "t60={t60} t27={t27}");
    }

    #[test]
    fn zero_pulse_when_target_trivially_met() {
        // Tiny Δ + huge overdrive: even t_w = 0 satisfies the WER target.
        let t = write_pulse_at_wer(0.5, TAU_NS, 0.1, 100.0);
        assert_eq!(t, 0.0);
    }
}
