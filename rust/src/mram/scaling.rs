//! The Δ-scaling design solver of §IV.B.
//!
//! Given the application's data-occupancy time (from the accelerator
//! occupancy model, `accel::timing`) and a BER budget (from the AI-accuracy
//! analysis, Ares-style [25]), produce a complete customized STT-MRAM design
//! point: scaled Δ, guard-banded Δ, write pulse, read pulse, and the relative
//! latency/energy vs the 10-year base case. This is the engine behind
//! Fig. 15 and Fig. 17.


use super::mtj::MtjTech;
use super::reliability::{
    read_pulse_at_rd, retention_time_at_ber, write_pulse_at_wer,
};
use super::variation::{GuardBand, PtVariation};
use crate::util::bisect;

/// Reliability + lifetime targets for one memory bank.
#[derive(Debug, Clone, Copy)]
pub struct DesignTargets {
    /// Required data retention time (s) — from the occupancy model for GLB
    /// banks, or e.g. 3 years for the weight-storage NVM.
    pub retention_time: f64,
    /// Per-bit retention-failure budget over `retention_time`.
    pub retention_ber: f64,
    /// Per-read read-disturb budget.
    pub read_disturb_ber: f64,
    /// Per-write write-error budget.
    pub write_ber: f64,
}

impl DesignTargets {
    /// The paper's weight-storage NVM target: 3 years @ 1e-9 (Fig. 15a).
    pub fn weight_nvm() -> Self {
        Self {
            retention_time: 3.0 * super::YEAR_S,
            retention_ber: 1e-9,
            read_disturb_ber: 1e-9,
            write_ber: 1e-9,
        }
    }

    /// The paper's GLB target: 3 s @ 1e-8 (Fig. 15b).
    pub fn global_buffer() -> Self {
        Self { retention_time: 3.0, retention_ber: 1e-8, read_disturb_ber: 1e-8, write_ber: 1e-8 }
    }

    /// The STT-AI Ultra LSB bank: relaxed 1e-5 BER (Fig. 17).
    pub fn lsb_bank() -> Self {
        Self { retention_time: 3.0, retention_ber: 1e-5, read_disturb_ber: 1e-5, write_ber: 1e-5 }
    }
}

/// A fully-solved customized STT-MRAM design point.
#[derive(Debug, Clone, Copy)]
pub struct DeltaDesign {
    /// Scaled thermal stability factor (pre guard-band), Δ_scaled.
    pub delta_scaled: f64,
    /// Guard-banded Δ the MTJ is actually built with (Eq. 17).
    pub delta_guard_banded: f64,
    /// Worst-case Δ at cold + fast corner (Eq. 18).
    pub delta_pt_max: f64,
    /// Write pulse width (s) meeting the WER target at `delta_guard_banded`.
    pub write_pulse: f64,
    /// Read pulse width (s) meeting the RD target at `delta_guard_banded`.
    pub read_pulse: f64,
    /// Write-current overdrive ratio I_w/I_c used.
    pub overdrive: f64,
    /// Achieved retention time at the retention-BER target (s).
    pub achieved_retention: f64,
    /// Relative write energy vs the Δ-base design (∝ I_w²·t_w with I_c ∝ Δ).
    pub rel_write_energy: f64,
    /// Relative read energy vs the Δ-base design (∝ I_r·t_r with I_r ∝ I_c ∝ Δ).
    pub rel_read_energy: f64,
    /// Relative bit-cell area vs the Δ-base design (MTJ volume ∝ Δ; the cell
    /// is access-transistor-limited, so area shrinks sub-linearly).
    pub rel_cell_area: f64,
}

/// Solver tying the reliability equations together.
#[derive(Debug, Clone, Copy)]
pub struct ScalingSolver {
    pub tech: MtjTech,
    pub variation: PtVariation,
}

impl ScalingSolver {
    pub fn new(tech: MtjTech) -> Self {
        Self { tech, variation: PtVariation::paper() }
    }

    pub fn with_variation(tech: MtjTech, variation: PtVariation) -> Self {
        Self { tech, variation }
    }

    /// Minimum Δ whose retention at the BER budget covers `targets.retention_time`.
    ///
    /// Closed form from Eq. 14: Δ = ln( t / (τ · (−ln(1−ber))) ).
    pub fn delta_for_retention(&self, targets: &DesignTargets) -> f64 {
        let lhs = -(-targets.retention_ber).ln_1p();
        (targets.retention_time / (self.tech.tau_ret * lhs)).ln()
    }

    /// Solve the complete design point for the given targets.
    ///
    /// Procedure (§IV.B–C):
    /// 1. Δ_scaled from the retention requirement (Eq. 14 inverse).
    /// 2. Guard-band for 4σ process + hot temperature (Eq. 17) and compute
    ///    the cold/fast worst case (Eq. 18).
    /// 3. Write pulse from Eq. 16 inverse at the *guard-banded* Δ (write must
    ///    succeed on every die), keeping the base overdrive ("keep I_w high"
    ///    trick of [18] to preserve write speed at scaled Δ).
    /// 4. Read pulse from Eq. 15 inverse at Δ_scaled at the *hot* corner
    ///    (disturb is worst where Δ is smallest).
    /// 5. Relative energies/area vs the base case: I_c ∝ Δ (Eq. 13).
    pub fn solve(&self, targets: &DesignTargets) -> DeltaDesign {
        let delta_scaled = self.delta_for_retention(targets);
        let gb: GuardBand = self.variation.guard_band(delta_scaled);

        let overdrive = self.tech.overdrive_base;
        // Write designed at the highest Δ any in-spec die can show (cold+4σ):
        // that is exactly why the write driver is adjustable (Fig. 9).
        let write_pulse =
            write_pulse_at_wer(targets.write_ber, self.tech.tau_w, gb.delta_pt_max, overdrive);
        // Read disturb worst case: minimum Δ (hot, −4σ) = Δ_scaled by Eq. 17.
        let read_pulse =
            read_pulse_at_rd(targets.read_disturb_ber, self.tech.tau_rd, delta_scaled, self.tech.read_ratio);

        let base = self.base_point();
        // I_c ∝ Δ ⇒ write current ∝ Δ at fixed overdrive; E_w ∝ I_w²·t_w.
        let rel_write_energy = (gb.delta_guard_banded / base.0).powi(2) * write_pulse / base.1;
        // Read: E_r ∝ I_r·t_r·V ≈ ∝ Δ·t_r.
        let rel_read_energy = (gb.delta_guard_banded / base.0) * read_pulse / base.2;
        // Cell area: MTJ area ∝ Δ^(2/3) at fixed thickness-class; the 1T cell
        // is transistor-dominated, and the smaller I_c also shrinks the
        // required access-transistor width (W ∝ I_w ∝ Δ). Net: ∝ Δ^0.8 is the
        // fit used against the paper's "smaller Δ ⇒ denser cell" claim.
        let rel_cell_area = (gb.delta_guard_banded / base.0).powf(0.8);

        DeltaDesign {
            delta_scaled,
            delta_guard_banded: gb.delta_guard_banded,
            delta_pt_max: gb.delta_pt_max,
            write_pulse,
            read_pulse,
            overdrive,
            achieved_retention: retention_time_at_ber(
                self.tech.tau_ret,
                delta_scaled,
                targets.retention_ber,
            ),
            rel_write_energy,
            rel_read_energy,
            rel_cell_area,
        }
    }

    /// (Δ_base_guardbanded_equivalent, t_w_base, t_r_base) of the 10-year base case.
    fn base_point(&self) -> (f64, f64, f64) {
        (self.tech.delta_base, self.tech.write_latency_base, self.tech.read_latency_base)
    }

    /// Fig. 15(b)-style sweep: retention time at BER target vs Δ.
    pub fn retention_vs_delta(&self, ber: f64, deltas: &[f64]) -> Vec<(f64, f64)> {
        deltas.iter().map(|&d| (d, retention_time_at_ber(self.tech.tau_ret, d, ber))).collect()
    }

    /// Fig. 15(c,d)-style sweep: read pulse at RD target vs Δ.
    pub fn read_pulse_vs_delta(&self, rd_ber: f64, deltas: &[f64]) -> Vec<(f64, f64)> {
        deltas
            .iter()
            .map(|&d| (d, read_pulse_at_rd(rd_ber, self.tech.tau_rd, d, self.tech.read_ratio)))
            .collect()
    }

    /// Fig. 15(e,f)-style sweep: write pulse at WER target vs Δ.
    pub fn write_pulse_vs_delta(&self, wer: f64, deltas: &[f64]) -> Vec<(f64, f64)> {
        deltas
            .iter()
            .map(|&d| {
                (d, write_pulse_at_wer(wer, self.tech.tau_w, d, self.tech.overdrive_base))
            })
            .collect()
    }

    /// Overdrive required to hit a write pulse budget at given Δ (the "I_w as
    /// another knob" of §IV.B) — solved numerically from Eq. 16.
    pub fn overdrive_for_write_pulse(&self, wer: f64, delta: f64, t_w: f64) -> Option<f64> {
        bisect(1.0 + 1e-6, 50.0, 1e-9, |i| {
            write_pulse_at_wer(wer, self.tech.tau_w, delta, i) - t_w
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solver() -> ScalingSolver {
        ScalingSolver::new(MtjTech::sakhare2020())
    }

    #[test]
    fn paper_design_points() {
        let s = solver();
        // Fig. 15(a): weight NVM, 3 yr @ 1e-9 → Δ ≈ 39.
        let d = s.delta_for_retention(&DesignTargets::weight_nvm());
        assert!((d - 39.0).abs() < 1.0, "delta={d}");
        // Fig. 15(b): GLB, 3 s @ 1e-8 → Δ ≈ 19.5.
        let d = s.delta_for_retention(&DesignTargets::global_buffer());
        assert!((d - 19.5).abs() < 1.0, "delta={d}");
        // Fig. 17: LSB bank @ 1e-5 → Δ ≈ 12.5.
        let d = s.delta_for_retention(&DesignTargets::lsb_bank());
        assert!((d - 12.5).abs() < 1.0, "delta={d}");
    }

    #[test]
    fn guard_band_matches_paper() {
        let s = solver();
        let sol = s.solve(&DesignTargets::global_buffer());
        // Paper: Δ=19.5 guard-bands to Δ_PT_GB = 27.5 (±1.5 tolerance here).
        assert!((sol.delta_guard_banded - 27.5).abs() < 1.5, "gb={}", sol.delta_guard_banded);
        assert!(sol.delta_pt_max > sol.delta_guard_banded);
        let nvm = s.solve(&DesignTargets::weight_nvm());
        // Paper: Δ=39 → Δ_PT_GB = 55.
        assert!((nvm.delta_guard_banded - 55.0).abs() < 2.5, "gb={}", nvm.delta_guard_banded);
    }

    #[test]
    fn scaled_design_is_faster_and_cheaper() {
        let s = solver();
        let glb = s.solve(&DesignTargets::global_buffer());
        let nvm = s.solve(&DesignTargets::weight_nvm());
        assert!(glb.write_pulse < nvm.write_pulse);
        assert!(glb.read_pulse < nvm.read_pulse);
        assert!(glb.rel_write_energy < 1.0, "write energy should shrink vs base");
        assert!(glb.rel_cell_area < 1.0);
        assert!(glb.rel_cell_area < nvm.rel_cell_area);
        // Achieved retention covers the requirement.
        assert!(glb.achieved_retention >= 3.0 * 0.99);
    }

    #[test]
    fn lsb_bank_cheaper_than_msb_bank() {
        let s = solver();
        let msb = s.solve(&DesignTargets::global_buffer());
        let lsb = s.solve(&DesignTargets::lsb_bank());
        assert!(lsb.delta_guard_banded < msb.delta_guard_banded);
        assert!(lsb.rel_write_energy < msb.rel_write_energy);
        assert!(lsb.rel_cell_area < msb.rel_cell_area);
        // Paper: Δ_PT_GB = 17.5 for the LSB bank.
        assert!((lsb.delta_guard_banded - 17.5).abs() < 1.5, "gb={}", lsb.delta_guard_banded);
    }

    #[test]
    fn sweeps_are_monotone() {
        let s = solver();
        let deltas: Vec<f64> = (10..=60).map(|d| d as f64).collect();
        let ret = s.retention_vs_delta(1e-8, &deltas);
        assert!(ret.windows(2).all(|w| w[1].1 > w[0].1));
        let rp = s.read_pulse_vs_delta(1e-8, &deltas);
        assert!(rp.windows(2).all(|w| w[1].1 > w[0].1));
        let wp = s.write_pulse_vs_delta(1e-9, &deltas);
        assert!(wp.windows(2).all(|w| w[1].1 >= w[0].1));
    }

    #[test]
    fn overdrive_knob_recovers_speed() {
        let s = solver();
        // At Δ=27.5, find the overdrive that brings the write pulse to 10ns.
        let i = s.overdrive_for_write_pulse(1e-8, 27.5, 10e-9).unwrap();
        assert!(i > 1.0);
        let t = super::write_pulse_at_wer(1e-8, s.tech.tau_w, 27.5, i);
        assert!((t - 10e-9).abs() / 10e-9 < 1e-3);
        // Retention prob of the solved GLB design actually meets budget.
        let sol = s.solve(&DesignTargets::global_buffer());
        let p = crate::mram::retention_failure_prob(3.0, s.tech.tau_ret, sol.delta_scaled);
        assert!(p <= 1e-8 * 1.01);
    }
}
