//! The dynamically adjustable write driver of Fig. 9 with its
//! Process-and-Temperature Monitor (PTM) control loop.
//!
//! The driver has a base PMOS leg sized for the typical corner plus `n_legs`
//! additional legs that the PTM switches in when the sensed (process,
//! temperature) point implies a higher required write current (Δ rises at
//! cold / +σ, and I_c ∝ Δ, Eq. 13). Designing the *base* driver for the worst
//! case would burn write power on every non-worst-case die — the point of the
//! paper's Fig. 9 circuit is to pay for that current only when needed.


use super::variation::PtVariation;

/// Static configuration of the adjustable write driver.
#[derive(Debug, Clone, Copy)]
pub struct WriteDriverConfig {
    /// Write current (A) of the base leg, sized for the typical corner.
    pub base_current: f64,
    /// Number of additional PMOS legs.
    pub n_legs: u32,
    /// Current added per extra leg (A).
    pub leg_current: f64,
    /// Supply voltage (V), for energy accounting.
    pub vdd: f64,
}

impl WriteDriverConfig {
    /// Size the driver for a guard-banded design: base leg covers the typical
    /// corner, legs together cover Δ_PT_MAX, split evenly.
    pub fn sized_for(
        typical_current: f64,
        worst_case_current: f64,
        n_legs: u32,
        vdd: f64,
    ) -> Self {
        assert!(worst_case_current >= typical_current);
        let extra = worst_case_current - typical_current;
        let leg_current = if n_legs == 0 { 0.0 } else { extra / n_legs as f64 };
        Self { base_current: typical_current, n_legs, leg_current, vdd }
    }

    /// Maximum current with all legs on.
    pub fn max_current(&self) -> f64 {
        self.base_current + self.n_legs as f64 * self.leg_current
    }
}

/// One PTM observation: die process offset (in σ) and junction temperature.
#[derive(Debug, Clone, Copy)]
pub struct PtmSample {
    pub process_sigma: f64,
    pub temperature: f64,
}

/// The runtime write driver: PTM sample in, leg setting + current out.
#[derive(Debug, Clone)]
pub struct WriteDriver {
    pub config: WriteDriverConfig,
    pub variation: PtVariation,
    /// Guard-banded design Δ (nominal, at T_nom).
    pub delta_guard_banded: f64,
    /// Required overdrive I_w/I_c.
    pub overdrive: f64,
    /// I_c at (Δ = delta_guard_banded, T_nom), the current-scale anchor.
    pub ic_nominal: f64,
}

impl WriteDriver {
    pub fn new(
        variation: PtVariation,
        delta_guard_banded: f64,
        overdrive: f64,
        ic_nominal: f64,
        n_legs: u32,
        vdd: f64,
    ) -> Self {
        let typical = overdrive * ic_nominal;
        let worst_delta = variation.delta_pt_max(delta_guard_banded);
        let worst = overdrive * ic_nominal * worst_delta / delta_guard_banded;
        Self {
            config: WriteDriverConfig::sized_for(typical, worst, n_legs, vdd),
            variation,
            delta_guard_banded,
            overdrive,
            ic_nominal,
        }
    }

    /// Required write current at the sensed corner: I_w = overdrive · I_c(Δ_eff),
    /// with Δ_eff from the PT model and I_c ∝ Δ (Eq. 13).
    pub fn required_current(&self, s: &PtmSample) -> f64 {
        let delta_eff =
            self.variation.delta_at(self.delta_guard_banded, s.process_sigma, s.temperature);
        self.required_current_at_delta(delta_eff)
    }

    /// Required current when the caller already holds Δ_eff — the
    /// Monte-Carlo hot path computes Δ_eff once per sample and must not
    /// re-derive it from (σ, T) here.
    #[inline]
    pub fn required_current_at_delta(&self, delta_eff: f64) -> f64 {
        self.overdrive * self.ic_nominal * delta_eff / self.delta_guard_banded
    }

    /// PTM decision: how many extra legs to enable for this sample.
    /// Returns `None` if even all legs cannot supply the required current
    /// (out-of-spec die — a write-failure corner, Fig. 8's tail).
    pub fn legs_for(&self, s: &PtmSample) -> Option<u32> {
        let delta_eff =
            self.variation.delta_at(self.delta_guard_banded, s.process_sigma, s.temperature);
        self.legs_for_delta(delta_eff)
    }

    /// [`WriteDriver::legs_for`] on an already-computed Δ_eff (bit-identical:
    /// `legs_for` routes through this).
    #[inline]
    pub fn legs_for_delta(&self, delta_eff: f64) -> Option<u32> {
        let need = self.required_current_at_delta(delta_eff);
        if need <= self.config.base_current {
            return Some(0);
        }
        if self.config.leg_current <= 0.0 {
            return None;
        }
        let extra = need - self.config.base_current;
        let legs = (extra / self.config.leg_current).ceil() as u32;
        (legs <= self.config.n_legs).then_some(legs)
    }

    /// Supplied current with `legs` extra legs on.
    pub fn supplied_current(&self, legs: u32) -> f64 {
        self.config.base_current + legs.min(self.config.n_legs) as f64 * self.config.leg_current
    }

    /// Write energy per bit for this sample: E = I_w(supplied) · V_dd · t_w.
    pub fn write_energy(&self, s: &PtmSample, t_w: f64) -> Option<f64> {
        let legs = self.legs_for(s)?;
        Some(self.supplied_current(legs) * self.config.vdd * t_w)
    }

    /// Energy saved at the typical corner vs a statically worst-case-sized
    /// driver — the benefit the Fig. 9 circuit exists to harvest.
    pub fn typical_saving_fraction(&self, t_w: f64) -> f64 {
        let typ = PtmSample { process_sigma: 0.0, temperature: self.variation.t_nom };
        let e_dyn = self.write_energy(&typ, t_w).unwrap();
        let e_static = self.config.max_current() * self.config.vdd * t_w;
        1.0 - e_dyn / e_static
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn driver() -> WriteDriver {
        WriteDriver::new(PtVariation::paper(), 27.5, 2.0, 40e-6, 4, 0.9)
    }

    #[test]
    fn typical_corner_uses_no_extra_legs() {
        let d = driver();
        let s = PtmSample { process_sigma: 0.0, temperature: 300.0 };
        assert_eq!(d.legs_for(&s), Some(0));
    }

    #[test]
    fn cold_fast_corner_uses_all_legs() {
        let d = driver();
        let s = PtmSample { process_sigma: 4.0, temperature: 253.0 };
        let legs = d.legs_for(&s).expect("worst case must be coverable by sizing");
        assert_eq!(legs, d.config.n_legs);
        // Supplied covers required.
        assert!(d.supplied_current(legs) >= d.required_current(&s) * 0.999_999);
    }

    #[test]
    fn out_of_spec_die_detected() {
        let d = driver();
        // 6σ at an even colder temperature than the design corner.
        let s = PtmSample { process_sigma: 6.0, temperature: 233.0 };
        assert_eq!(d.legs_for(&s), None);
    }

    #[test]
    fn legs_monotone_in_severity() {
        let d = driver();
        let mut last = 0;
        for (sig, t) in [(0.0, 300.0), (1.0, 280.0), (2.0, 270.0), (3.0, 260.0), (4.0, 253.0)] {
            let legs = d.legs_for(&PtmSample { process_sigma: sig, temperature: t }).unwrap();
            assert!(legs >= last, "legs must not decrease with worsening corner");
            last = legs;
        }
    }

    #[test]
    fn delta_fast_path_matches_sample_path() {
        let d = driver();
        for (sig, t) in [(0.0, 300.0), (2.0, 270.0), (4.0, 253.0), (-4.0, 393.0), (6.0, 233.0)] {
            let s = PtmSample { process_sigma: sig, temperature: t };
            let delta_eff = d.variation.delta_at(d.delta_guard_banded, sig, t);
            assert_eq!(d.legs_for(&s), d.legs_for_delta(delta_eff), "sig={sig} t={t}");
            assert_eq!(
                d.required_current(&s).to_bits(),
                d.required_current_at_delta(delta_eff).to_bits()
            );
        }
    }

    #[test]
    fn dynamic_driver_saves_energy_at_typical() {
        let d = driver();
        let saving = d.typical_saving_fraction(10e-9);
        // Δ_PT_MAX/Δ_GB ≈ 1.28 ⇒ ~22% saving at the typical corner.
        assert!(saving > 0.1 && saving < 0.5, "saving={saving}");
    }

    #[test]
    fn write_energy_scale() {
        let d = driver();
        let s = PtmSample { process_sigma: 0.0, temperature: 300.0 };
        let e = d.write_energy(&s, 10e-9).unwrap();
        // 80uA · 0.9V · 10ns ≈ 0.72 pJ/bit — the right order for STT-MRAM.
        assert!(e > 0.1e-12 && e < 10e-12, "e={e}");
    }
}
