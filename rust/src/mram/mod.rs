//! STT-MRAM / MTJ device physics and Δ-customization (paper §IV).
//!
//! This module implements, from the equations in the paper:
//!
//! * [`mtj`] — the MTJ device model: thermal stability factor Δ (Eq. 12) and
//!   critical switching current I_c (Eq. 13), with named technology presets
//!   for the silicon base cases the paper calibrates against
//!   (Sakhare 2020 [6], Wei 2019 [13]).
//! * [`reliability`] — retention-failure probability (Eq. 14), read-disturb
//!   probability (Eq. 15) and write-error rate (Eq. 16).
//! * [`scaling`] — the design solver of §IV.B: given a target retention time
//!   and BER budget, find the scaled Δ; given Δ and a WER/RD target, find the
//!   write pulse width / read pulse width; the `ln(Δ)` write-latency law.
//! * [`variation`] — process/temperature guard-banding (Eq. 17–18, Fig. 7–8).
//! * [`write_driver`] — the dynamically adjustable write driver of Fig. 9
//!   with its process-and-temperature-monitor (PTM) control loop.
//! * [`montecarlo`] — the streaming, pool-parallel Monte-Carlo engine that
//!   samples the die population (Figs. 7–8): chunked map-reduce over
//!   jump-derived RNG sub-streams with zero-allocation accumulators,
//!   bit-identical for any worker count / chunk size.
//! * [`technology`] — the pluggable memory-technology layer: the
//!   [`MemTechnology`] trait (retention/Δ model, read/write dynamics,
//!   critical-current model, per-bit area/energy calibration, variation
//!   guard-banding — the full contract is documented on the module) with
//!   STT-MRAM, SOT-MRAM and SRAM implementations behind a [`TechnologyId`]
//!   registry. Everything above the device layer — `memsys` arrays, the DSE
//!   `tech` axis, config, reports, the CLI — works over this abstraction.

pub mod montecarlo;
pub mod mtj;
pub mod reliability;
pub mod scaling;
pub mod technology;
pub mod variation;
pub mod write_driver;

pub use montecarlo::{McAccumulator, McResult, MonteCarlo};
pub use mtj::{MtjParams, MtjTech};
pub use reliability::{
    read_disturb_prob, read_pulse_at_rd, retention_failure_prob, retention_failure_prob_pre,
    retention_time_at_ber, write_error_rate, write_error_rate_pre, write_pulse_at_wer,
};
pub use scaling::{DeltaDesign, DesignTargets, ScalingSolver};
pub use technology::{finite_or_max, MemTechnology, SotMram, Sram, SttMram, TechnologyId};
pub use variation::{GuardBand, PtCorner, PtVariation};
pub use write_driver::{PtmSample, WriteDriver, WriteDriverConfig};

/// Boltzmann constant (J/K).
pub const K_B: f64 = 1.380_649e-23;
/// Electron charge (C).
pub const E_CHARGE: f64 = 1.602_176_634e-19;
/// Reduced Planck constant ħ (J·s) — Eq. 13's `h` is ħ in the source
/// literature (Khvalkovskiy 2013).
pub const H_BAR: f64 = 1.054_571_817e-34;

/// Seconds in a Julian year, used for NVM retention targets ("3 years").
pub const YEAR_S: f64 = 365.25 * 24.0 * 3600.0;
