//! Monte-Carlo process/temperature analysis (Figs. 7–8).
//!
//! The closed-form guard-banding of Eq. 17–18 covers ±4σ; this module
//! *samples* the die population — Δ ~ N(Δ_GB, σ²), T ~ U(T_cold, T_hot) —
//! and empirically measures retention-failure / write-failure rates, both
//! with the statically-sized and the PTM-adjustable write driver of Fig. 9.
//! It is the numerical check that the analytical corners are actually the
//! worst cases (and the source of the Fig. 8-style current distributions).

use crate::mram::mtj::MtjTech;
use crate::mram::reliability::{retention_failure_prob, write_error_rate};
use crate::mram::variation::PtVariation;
use crate::mram::write_driver::{PtmSample, WriteDriver};
use crate::util::rng::Rng;
use crate::util::stats;

/// One sampled die at one operating temperature.
#[derive(Debug, Clone, Copy)]
pub struct DieSample {
    /// Effective Δ at the sampled (process, temperature) point.
    pub delta_eff: f64,
    pub process_sigma: f64,
    pub temperature: f64,
}

/// Aggregated Monte-Carlo results.
#[derive(Debug, Clone)]
pub struct McResult {
    pub n: usize,
    /// Fraction of samples whose retention-failure prob exceeds the budget.
    pub retention_violations: f64,
    /// Fraction of samples whose WER (at the design pulse/current) exceeds
    /// the budget with a STATIC typical-sized driver.
    pub write_violations_static: f64,
    /// Same with the PTM-adjustable driver (Fig. 9).
    pub write_violations_adjustable: f64,
    /// Mean write energy per bit (J), static vs adjustable driver.
    pub energy_static: f64,
    pub energy_adjustable: f64,
    /// Distribution summary of Δ_eff.
    pub delta_mean: f64,
    pub delta_std: f64,
    pub delta_min: f64,
    pub delta_max: f64,
}

/// The Monte-Carlo engine.
pub struct MonteCarlo {
    pub tech: MtjTech,
    pub variation: PtVariation,
    pub delta_guard_banded: f64,
    pub overdrive: f64,
    pub write_pulse: f64,
    pub retention_time: f64,
    pub retention_ber: f64,
    pub write_ber: f64,
}

impl MonteCarlo {
    /// Sample `n` (die, temperature) points.
    pub fn sample(&self, rng: &mut Rng, n: usize) -> Vec<DieSample> {
        (0..n)
            .map(|_| {
                let ps = rng.normal();
                let t = rng.range_f64(self.variation.t_cold, self.variation.t_hot);
                DieSample {
                    delta_eff: self.variation.delta_at(self.delta_guard_banded, ps, t),
                    process_sigma: ps,
                    temperature: t,
                }
            })
            .collect()
    }

    /// Run the full analysis.
    pub fn run(&self, seed: u64, n: usize) -> McResult {
        let mut rng = Rng::seed_from_u64(seed);
        let samples = self.sample(&mut rng, n);

        let ic_nominal = self.tech.params_at_delta(self.delta_guard_banded).critical_current();
        let driver = WriteDriver::new(
            self.variation,
            self.delta_guard_banded,
            self.overdrive,
            ic_nominal,
            4,
            0.9,
        );
        // Static driver: typical-corner current, always.
        let i_static = self.overdrive * ic_nominal;

        let mut ret_viol = 0usize;
        let mut wr_static = 0usize;
        let mut wr_adj = 0usize;
        let mut e_static = 0.0;
        let mut e_adj = 0.0;
        let deltas: Vec<f64> = samples.iter().map(|s| s.delta_eff).collect();

        for s in &samples {
            // Retention at the effective Δ.
            let p_rf = retention_failure_prob(self.retention_time, self.tech.tau_ret, s.delta_eff);
            if p_rf > self.retention_ber * 1.000_001 {
                ret_viol += 1;
            }
            // Write with the static driver: I_c grows with Δ_eff, so the
            // *effective* overdrive shrinks on cold/+σ dies.
            let ic_eff = ic_nominal * s.delta_eff / self.delta_guard_banded;
            let od_static = (i_static / ic_eff).max(1.000_001);
            let wer_s = write_error_rate(self.write_pulse, self.tech.tau_w, s.delta_eff, od_static);
            if wer_s > self.write_ber * 1.000_001 {
                wr_static += 1;
            }
            e_static += i_static * 0.9 * self.write_pulse;
            // Adjustable driver: the PTM picks legs to restore the overdrive.
            let ptm = PtmSample { process_sigma: s.process_sigma, temperature: s.temperature };
            match driver.legs_for(&ptm) {
                Some(legs) => {
                    let i_adj = driver.supplied_current(legs);
                    let od_adj = (i_adj / ic_eff).max(1.000_001);
                    let wer_a =
                        write_error_rate(self.write_pulse, self.tech.tau_w, s.delta_eff, od_adj);
                    if wer_a > self.write_ber * 1.000_001 {
                        wr_adj += 1;
                    }
                    e_adj += i_adj * 0.9 * self.write_pulse;
                }
                None => {
                    wr_adj += 1; // out-of-spec die (beyond the sized legs)
                    e_adj += driver.config.max_current() * 0.9 * self.write_pulse;
                }
            }
        }

        let (dmin, dmax) = stats::min_max(&deltas).unwrap_or((0.0, 0.0));
        McResult {
            n,
            retention_violations: ret_viol as f64 / n as f64,
            write_violations_static: wr_static as f64 / n as f64,
            write_violations_adjustable: wr_adj as f64 / n as f64,
            energy_static: e_static / n as f64,
            energy_adjustable: e_adj / n as f64,
            delta_mean: stats::mean(&deltas),
            delta_std: stats::std_dev(&deltas),
            delta_min: dmin,
            delta_max: dmax,
        }
    }

    /// The paper's GLB design point, ready to run.
    pub fn paper_glb() -> Self {
        let tech = MtjTech::sakhare2020();
        let v = PtVariation::paper();
        let solver = crate::mram::scaling::ScalingSolver::with_variation(tech, v);
        let d = solver.solve(&crate::mram::scaling::DesignTargets::global_buffer());
        Self {
            tech,
            variation: v,
            delta_guard_banded: d.delta_guard_banded,
            overdrive: d.overdrive,
            write_pulse: d.write_pulse,
            retention_time: 3.0,
            retention_ber: 1e-8,
            write_ber: 1e-8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn population_is_safe_at_paper_design() {
        let mc = MonteCarlo::paper_glb();
        let r = mc.run(0xD1E5, 20_000);
        // ±4σ guard-band: essentially no retention violations in the bulk
        // (beyond-4σ dies are ~6e-5 of the population).
        assert!(r.retention_violations < 1e-3, "{}", r.retention_violations);
        // The adjustable driver keeps write failures at the same level.
        assert!(r.write_violations_adjustable < 2e-3, "{}", r.write_violations_adjustable);
    }

    #[test]
    fn static_driver_fails_cold_dies() {
        // The point of Fig. 9: a typical-sized static driver violates WER on
        // the high-Δ (cold / +σ) part of the population.
        let mc = MonteCarlo::paper_glb();
        let r = mc.run(0xC01D, 20_000);
        assert!(
            r.write_violations_static > r.write_violations_adjustable,
            "static {} vs adjustable {}",
            r.write_violations_static,
            r.write_violations_adjustable
        );
        assert!(r.write_violations_static > 0.05, "{}", r.write_violations_static);
    }

    #[test]
    fn adjustable_driver_saves_energy_vs_worst_case() {
        // Against a driver statically sized for Δ_PT_MAX, the PTM-adjusted
        // one spends less average energy (it only boosts when needed).
        let mc = MonteCarlo::paper_glb();
        let r = mc.run(0xE4E7, 20_000);
        let ic = mc.tech.params_at_delta(mc.delta_guard_banded).critical_current();
        let worst_i =
            mc.overdrive * ic * mc.variation.delta_pt_max(mc.delta_guard_banded) / mc.delta_guard_banded;
        let e_worst = worst_i * 0.9 * mc.write_pulse;
        assert!(r.energy_adjustable < e_worst, "{} vs {}", r.energy_adjustable, e_worst);
        // And more than the bare typical driver (it does boost sometimes).
        assert!(r.energy_adjustable > r.energy_static);
    }

    #[test]
    fn delta_distribution_matches_model() {
        let mc = MonteCarlo::paper_glb();
        let r = mc.run(0xD157, 50_000);
        // Mean Δ_eff sits between the hot and cold scalings of Δ_GB.
        let lo = mc.delta_guard_banded * 300.0 / mc.variation.t_hot;
        let hi = mc.delta_guard_banded * 300.0 / mc.variation.t_cold;
        assert!(r.delta_mean > lo && r.delta_mean < hi, "{}", r.delta_mean);
        assert!(r.delta_std > 0.0);
        assert!(r.delta_min < r.delta_mean && r.delta_mean < r.delta_max);
    }

    #[test]
    fn deterministic_under_seed() {
        let mc = MonteCarlo::paper_glb();
        let a = mc.run(7, 2_000);
        let b = mc.run(7, 2_000);
        assert_eq!(a.retention_violations, b.retention_violations);
        assert_eq!(a.energy_adjustable, b.energy_adjustable);
    }
}
