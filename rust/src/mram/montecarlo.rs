//! Monte-Carlo process/temperature analysis (Figs. 7–8).
//!
//! The closed-form guard-banding of Eq. 17–18 covers ±4σ; this module
//! *samples* the die population — Δ ~ N(Δ_GB, σ²), T ~ U(T_cold, T_hot) —
//! and empirically measures retention-failure / write-failure rates, both
//! with the statically-sized and the PTM-adjustable write driver of Fig. 9.
//! It is the numerical check that the analytical corners are actually the
//! worst cases (and the source of the Fig. 8-style current distributions).
//!
//! # Streaming engine
//!
//! BER-tail studies need 1e6–1e8 samples per design point, so the engine is
//! a chunked map-reduce over the work-stealing pool rather than a serial
//! `Vec<DieSample>` walk:
//!
//! * the sample index space is carved into fixed [`BLOCK_SAMPLES`]-sized
//!   blocks; block `b` draws from the `b`-th [`crate::util::rng::Rng::jump`]
//!   sub-stream of the seed, so the random numbers a sample sees depend only
//!   on its index — never on worker count or chunk size;
//! * each block folds into a zero-allocation [`McAccumulator`] (Welford
//!   [`Streaming`] moments + violation/energy counters) using batched
//!   `fill_normal`/`fill_f64` draws and the hoisted `*_pre` reliability
//!   forms — no per-sample heap traffic, no `Vec<f64>` materialization;
//! * block accumulators merge **in block-index order** on the caller
//!   thread, so [`MonteCarlo::run_with`] is bit-identical for any worker
//!   count *and* any chunk size — the same determinism contract the
//!   `--parallel` sweep engine gives the figures.

use crate::mram::mtj::MtjTech;
use crate::mram::reliability::{
    retention_failure_prob_pre, write_error_rate_pre, write_pulse_at_wer,
};
use crate::mram::scaling::{DesignTargets, ScalingSolver};
use crate::mram::technology::TechnologyId;
use crate::mram::variation::PtVariation;
use crate::mram::write_driver::WriteDriver;
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;
use crate::util::stats::Streaming;

/// Relative slack on the three violation checks (retention, static-driver
/// WER, adjustable-driver WER): a sample only counts as a violation when its
/// failure probability exceeds the budget by more than this factor, so a
/// design sitting exactly *at* its budget is in spec despite FP noise.
pub const BUDGET_TOL: f64 = 1.000_001;

/// Minimum effective overdrive fed to Eq. 16, which requires I_w/I_c > 1
/// strictly (an underdriven die shows ~100% WER and is counted by the
/// budget check, not by a singularity).
pub const MIN_OVERDRIVE: f64 = 1.000_001;

/// RNG sub-stream / accumulator granularity in samples. Fixed (never a
/// tuning knob): sample `i` always draws from jump-stream `i / BLOCK_SAMPLES`
/// and block accumulators always merge in index order, which is what makes
/// results independent of worker count and chunk size.
pub const BLOCK_SAMPLES: usize = 4096;

/// Default chunk handed to one pool worker — a whole number of blocks, big
/// enough to amortize job dispatch, small enough to load-balance.
pub const DEFAULT_CHUNK_SAMPLES: usize = 16 * BLOCK_SAMPLES;

/// Write-driver supply voltage (V) used for energy accounting.
const DRIVER_VDD: f64 = 0.9;

/// Extra PMOS legs of the Fig. 9 adjustable driver.
const PTM_LEGS: u32 = 4;

/// The shared violation predicate — all three checks route through here.
#[inline]
fn exceeds_budget(p: f64, budget: f64) -> bool {
    p > budget * BUDGET_TOL
}

/// One sampled die at one operating temperature.
#[derive(Debug, Clone, Copy)]
pub struct DieSample {
    /// Effective Δ at the sampled (process, temperature) point.
    pub delta_eff: f64,
    pub process_sigma: f64,
    pub temperature: f64,
}

/// Aggregated Monte-Carlo results.
#[derive(Debug, Clone, PartialEq)]
pub struct McResult {
    pub n: usize,
    /// Fraction of samples whose retention-failure prob exceeds the budget.
    pub retention_violations: f64,
    /// Fraction of samples whose WER (at the design pulse/current) exceeds
    /// the budget with a STATIC typical-sized driver.
    pub write_violations_static: f64,
    /// Same with the PTM-adjustable driver (Fig. 9).
    pub write_violations_adjustable: f64,
    /// Mean write energy per bit (J), static vs adjustable driver.
    pub energy_static: f64,
    pub energy_adjustable: f64,
    /// Distribution summary of Δ_eff.
    pub delta_mean: f64,
    pub delta_std: f64,
    pub delta_min: f64,
    pub delta_max: f64,
}

/// Zero-allocation streaming accumulator for a run of samples. One lives
/// per [`BLOCK_SAMPLES`] block; the fixed partition merged in block-index
/// order yields the same bits for any worker count or chunk size (merge
/// order, not merge associativity, is what the contract rests on).
#[derive(Debug, Clone, Copy, Default)]
pub struct McAccumulator {
    ret_viol: u64,
    wr_static: u64,
    wr_adj: u64,
    e_static: f64,
    e_adj: f64,
    delta: Streaming,
}

impl McAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Samples folded in so far (every sample pushes one Δ_eff, so the
    /// moment accumulator is the single source of truth for the count).
    pub fn count(&self) -> u64 {
        self.delta.count()
    }

    /// Fold another accumulator in (callers must keep a fixed merge order
    /// to preserve bit-exact reproducibility).
    pub fn merge(&mut self, o: &McAccumulator) {
        self.ret_viol += o.ret_viol;
        self.wr_static += o.wr_static;
        self.wr_adj += o.wr_adj;
        self.e_static += o.e_static;
        self.e_adj += o.e_adj;
        self.delta.merge(&o.delta);
    }

    /// Finish into the aggregate result (all-zero for an empty run).
    pub fn result(&self) -> McResult {
        let n = self.count();
        let nf = if n == 0 { 1.0 } else { n as f64 };
        McResult {
            n: n as usize,
            retention_violations: self.ret_viol as f64 / nf,
            write_violations_static: self.wr_static as f64 / nf,
            write_violations_adjustable: self.wr_adj as f64 / nf,
            energy_static: self.e_static / nf,
            energy_adjustable: self.e_adj / nf,
            delta_mean: self.delta.mean(),
            delta_std: self.delta.std_dev(),
            delta_min: self.delta.min(),
            delta_max: self.delta.max(),
        }
    }
}

/// Reusable per-worker scratch for one chunk's blocks: allocated once per
/// pool job, so the steady state does zero per-sample heap work.
struct BlockScratch {
    normals: Vec<f64>,
    uniforms: Vec<f64>,
}

impl BlockScratch {
    fn new() -> Self {
        Self { normals: Vec::with_capacity(BLOCK_SAMPLES), uniforms: vec![0.0; BLOCK_SAMPLES] }
    }
}

/// Per-run invariants hoisted out of the per-sample loop (the `ln`/`exp`
/// terms of Eq. 14/16 that do not depend on the sampled die).
#[derive(Debug, Clone, Copy)]
struct McConsts {
    /// retention_time / τ_ret (Eq. 14's hoisted ratio).
    t_over_tau_ret: f64,
    /// write_pulse / τ_w (Eq. 16's hoisted ratio).
    tw_over_tau: f64,
    /// Retention-failure budget.
    ret_budget: f64,
    /// WER budget.
    wr_budget: f64,
    /// overdrive · Δ_GB: static overdrive at Δ_eff is `od_num / Δ_eff`.
    od_num: f64,
    /// I_c(Δ_GB) / Δ_GB: effective critical current is `ic_per_delta · Δ_eff`.
    ic_per_delta: f64,
    /// Static-driver write energy per bit (constant per sample).
    e_static_bit: f64,
    /// V_dd · t_w: adjustable-driver energy is `I_adj · e_per_amp`.
    e_per_amp: f64,
    /// Energy charged to an out-of-spec die (all legs on).
    e_oos: f64,
}

/// The Monte-Carlo engine.
#[derive(Debug, Clone, Copy)]
pub struct MonteCarlo {
    pub tech: MtjTech,
    pub variation: PtVariation,
    pub delta_guard_banded: f64,
    pub overdrive: f64,
    pub write_pulse: f64,
    pub retention_time: f64,
    pub retention_ber: f64,
    pub write_ber: f64,
}

impl MonteCarlo {
    /// Sample `n` (die, temperature) points — the Fig. 8-style raw
    /// distribution view (the aggregate path never materializes this).
    pub fn sample(&self, rng: &mut Rng, n: usize) -> Vec<DieSample> {
        (0..n)
            .map(|_| {
                let ps = rng.normal();
                let t = rng.range_f64(self.variation.t_cold, self.variation.t_hot);
                DieSample {
                    delta_eff: self.variation.delta_at(self.delta_guard_banded, ps, t),
                    process_sigma: ps,
                    temperature: t,
                }
            })
            .collect()
    }

    /// I_c at the guard-banded design Δ (the current-scale anchor).
    fn ic_nominal(&self) -> f64 {
        self.tech.params_at_delta(self.delta_guard_banded).critical_current()
    }

    /// The PTM-adjustable write driver for this design point (Fig. 9 sizing).
    pub fn driver(&self) -> WriteDriver {
        WriteDriver::new(
            self.variation,
            self.delta_guard_banded,
            self.overdrive,
            self.ic_nominal(),
            PTM_LEGS,
            DRIVER_VDD,
        )
    }

    fn consts(&self, driver: &WriteDriver) -> McConsts {
        let ic_nominal = self.ic_nominal();
        let i_static = self.overdrive * ic_nominal;
        let e_per_amp = DRIVER_VDD * self.write_pulse;
        McConsts {
            t_over_tau_ret: self.retention_time / self.tech.tau_ret,
            tw_over_tau: self.write_pulse / self.tech.tau_w,
            ret_budget: self.retention_ber,
            wr_budget: self.write_ber,
            od_num: self.overdrive * self.delta_guard_banded,
            ic_per_delta: ic_nominal / self.delta_guard_banded,
            e_static_bit: i_static * e_per_amp,
            e_per_amp,
            e_oos: driver.config.max_current() * e_per_amp,
        }
    }

    /// Fold `m` samples from `rng` into `acc`, drawing through the batched
    /// fill APIs into caller-provided scratch (no per-sample allocation).
    fn accumulate_block(
        &self,
        rng: &mut Rng,
        m: usize,
        c: &McConsts,
        driver: &WriteDriver,
        scratch: &mut BlockScratch,
        acc: &mut McAccumulator,
    ) {
        // Normals go through the chunked shared fill path (bit-identical to
        // one monolithic fill_normal; capacity reused, zero steady-state
        // allocation).
        scratch.normals.clear();
        rng.fill_normal_into(&mut scratch.normals, m);
        let normals = &scratch.normals[..];
        let uniforms = &mut scratch.uniforms[..m];
        rng.fill_f64(uniforms);
        let t_span = self.variation.t_hot - self.variation.t_cold;
        for (&ps, &u) in normals.iter().zip(uniforms.iter()) {
            let t = self.variation.t_cold + t_span * u;
            let delta_eff = self.variation.delta_at(self.delta_guard_banded, ps, t);
            // Retention at the effective Δ (hoisted Eq. 14).
            let p_rf = retention_failure_prob_pre(c.t_over_tau_ret, delta_eff);
            if exceeds_budget(p_rf, c.ret_budget) {
                acc.ret_viol += 1;
            }
            // Write with the static driver: I_c grows with Δ_eff, so the
            // *effective* overdrive shrinks on cold/+σ dies.
            let od_static = (c.od_num / delta_eff).max(MIN_OVERDRIVE);
            let wer_s = write_error_rate_pre(c.tw_over_tau, delta_eff, od_static);
            if exceeds_budget(wer_s, c.wr_budget) {
                acc.wr_static += 1;
            }
            // Adjustable driver: the PTM picks legs to restore the overdrive.
            match driver.legs_for_delta(delta_eff) {
                Some(legs) => {
                    let i_adj = driver.supplied_current(legs);
                    let od_adj = (i_adj / (c.ic_per_delta * delta_eff)).max(MIN_OVERDRIVE);
                    let wer_a = write_error_rate_pre(c.tw_over_tau, delta_eff, od_adj);
                    if exceeds_budget(wer_a, c.wr_budget) {
                        acc.wr_adj += 1;
                    }
                    acc.e_adj += i_adj * c.e_per_amp;
                }
                None => {
                    acc.wr_adj += 1; // out-of-spec die (beyond the sized legs)
                    acc.e_adj += c.e_oos;
                }
            }
            acc.delta.push(delta_eff);
        }
        // The static driver always pushes the same current: hoist the sum.
        acc.e_static += c.e_static_bit * m as f64;
    }

    /// Run the full analysis on `pool`, `chunk_samples` samples per job
    /// (rounded up to whole [`BLOCK_SAMPLES`] blocks). Bit-identical for
    /// any worker count and any chunk size.
    pub fn run_with(
        &self,
        seed: u64,
        n: usize,
        pool: &ThreadPool,
        chunk_samples: usize,
    ) -> McResult {
        let driver = self.driver();
        let consts = self.consts(&driver);

        // One independent RNG sub-stream per block, derived by successive
        // jumps from the seed (serial, but each jump is a few hundred ops).
        let n_blocks = n.div_ceil(BLOCK_SAMPLES);
        let mut master = Rng::seed_from_u64(seed);
        let mut streams = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            streams.push(master.clone());
            master.jump();
        }

        let blocks_per_chunk = chunk_samples.div_ceil(BLOCK_SAMPLES).max(1);
        let chunks: Vec<(usize, &[Rng])> = streams
            .chunks(blocks_per_chunk)
            .enumerate()
            .map(|(ci, s)| (ci * blocks_per_chunk, s))
            .collect();

        // Map: each chunk folds its blocks into per-block accumulators
        // (scratch buffers are reused across the chunk's blocks). Reduce:
        // merge in block-index order on the caller thread — deterministic
        // for any worker count / chunk split.
        let total = pool.map_reduce(
            &chunks,
            |_, &(first_block, chunk_streams)| {
                let mut scratch = BlockScratch::new();
                chunk_streams
                    .iter()
                    .enumerate()
                    .map(|(j, stream)| {
                        let lo = (first_block + j) * BLOCK_SAMPLES;
                        let m = BLOCK_SAMPLES.min(n - lo);
                        let mut rng = stream.clone();
                        let mut acc = McAccumulator::new();
                        self.accumulate_block(
                            &mut rng,
                            m,
                            &consts,
                            &driver,
                            &mut scratch,
                            &mut acc,
                        );
                        acc
                    })
                    .collect::<Vec<McAccumulator>>()
            },
            McAccumulator::new(),
            |mut acc, blocks| {
                for b in &blocks {
                    acc.merge(b);
                }
                acc
            },
        );
        total.result()
    }

    /// Run the full analysis on all hardware threads (bit-identical to
    /// [`MonteCarlo::run_serial`] by the streaming-engine contract).
    pub fn run(&self, seed: u64, n: usize) -> McResult {
        self.run_with(seed, n, &ThreadPool::auto(), DEFAULT_CHUNK_SAMPLES)
    }

    /// Single-threaded reference run (the bench baseline).
    pub fn run_serial(&self, seed: u64, n: usize) -> McResult {
        self.run_with(seed, n, &ThreadPool::new(1), DEFAULT_CHUNK_SAMPLES)
    }

    /// Build the engine for a registered technology at the given reliability
    /// targets (Δ-scaling solve + guard-band + driver sizing). `None` for
    /// technologies without an MTJ process/temperature model (SOT uses a
    /// different switching mechanism; SRAM has no Δ at all).
    pub fn for_technology(id: TechnologyId, targets: &DesignTargets) -> Option<Self> {
        let tech = match id {
            TechnologyId::SttSakhare2020 => MtjTech::sakhare2020(),
            TechnologyId::SttWei2019 => MtjTech::wei2019(),
            TechnologyId::Sot | TechnologyId::Sram => return None,
        };
        let variation = PtVariation::paper();
        let d = ScalingSolver::with_variation(tech, variation).solve(targets);
        Some(Self {
            tech,
            variation,
            delta_guard_banded: d.delta_guard_banded,
            overdrive: d.overdrive,
            write_pulse: d.write_pulse,
            retention_time: targets.retention_time,
            retention_ber: targets.retention_ber,
            write_ber: targets.write_ber,
        })
    }

    /// The same engine re-anchored at an explicit guard-banded Δ (the sweep
    /// engine's Δ axis); the write pulse is re-solved at the new cold/fast
    /// worst case, mirroring the §IV.B design procedure.
    pub fn at_delta_gb(&self, delta_gb: f64) -> Self {
        let write_pulse = write_pulse_at_wer(
            self.write_ber,
            self.tech.tau_w,
            self.variation.delta_pt_max(delta_gb),
            self.overdrive,
        );
        Self { delta_guard_banded: delta_gb, write_pulse, ..*self }
    }

    /// The paper's GLB design point, ready to run.
    pub fn paper_glb() -> Self {
        Self::for_technology(TechnologyId::SttSakhare2020, &DesignTargets::global_buffer())
            .expect("the STT base case has a PT Monte-Carlo model")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn population_is_safe_at_paper_design() {
        let mc = MonteCarlo::paper_glb();
        let r = mc.run(0xD1E5, 20_000);
        // ±4σ guard-band: essentially no retention violations in the bulk
        // (beyond-4σ dies are ~6e-5 of the population).
        assert!(r.retention_violations < 1e-3, "{}", r.retention_violations);
        // The adjustable driver keeps write failures at the same level.
        assert!(r.write_violations_adjustable < 2e-3, "{}", r.write_violations_adjustable);
    }

    #[test]
    fn static_driver_fails_cold_dies() {
        // The point of Fig. 9: a typical-sized static driver violates WER on
        // the high-Δ (cold / +σ) part of the population.
        let mc = MonteCarlo::paper_glb();
        let r = mc.run(0xC01D, 20_000);
        assert!(
            r.write_violations_static > r.write_violations_adjustable,
            "static {} vs adjustable {}",
            r.write_violations_static,
            r.write_violations_adjustable
        );
        assert!(r.write_violations_static > 0.05, "{}", r.write_violations_static);
    }

    #[test]
    fn adjustable_driver_saves_energy_vs_worst_case() {
        // Against a driver statically sized for Δ_PT_MAX, the PTM-adjusted
        // one spends less average energy (it only boosts when needed).
        let mc = MonteCarlo::paper_glb();
        let r = mc.run(0xE4E7, 20_000);
        let ic = mc.tech.params_at_delta(mc.delta_guard_banded).critical_current();
        let worst_i =
            mc.overdrive * ic * mc.variation.delta_pt_max(mc.delta_guard_banded) / mc.delta_guard_banded;
        let e_worst = worst_i * 0.9 * mc.write_pulse;
        assert!(r.energy_adjustable < e_worst, "{} vs {}", r.energy_adjustable, e_worst);
        // And more than the bare typical driver (it does boost sometimes).
        assert!(r.energy_adjustable > r.energy_static);
    }

    #[test]
    fn delta_distribution_matches_model() {
        let mc = MonteCarlo::paper_glb();
        let r = mc.run(0xD157, 50_000);
        // Mean Δ_eff sits between the hot and cold scalings of Δ_GB.
        let lo = mc.delta_guard_banded * 300.0 / mc.variation.t_hot;
        let hi = mc.delta_guard_banded * 300.0 / mc.variation.t_cold;
        assert!(r.delta_mean > lo && r.delta_mean < hi, "{}", r.delta_mean);
        assert!(r.delta_std > 0.0);
        assert!(r.delta_min < r.delta_mean && r.delta_mean < r.delta_max);
    }

    #[test]
    fn deterministic_under_seed() {
        let mc = MonteCarlo::paper_glb();
        let a = mc.run(7, 2_000);
        let b = mc.run(7, 2_000);
        assert_eq!(a, b);
        assert_ne!(a, mc.run(8, 2_000));
    }

    #[test]
    fn budget_tolerance_boundary() {
        // Exactly at budget: in spec. Beyond the BUDGET_TOL slack: violation.
        // Inside the slack: still in spec — the check guards the p == budget
        // boundary against FP noise, nothing more.
        for budget in [1e-8, 1e-5, 0.5] {
            assert!(!exceeds_budget(budget, budget), "p == budget must be in spec");
            assert!(!exceeds_budget(budget * 1.000_000_9, budget), "inside the slack");
            assert!(exceeds_budget(budget * 1.000_001_1, budget), "beyond the slack");
            assert!(!exceeds_budget(0.0, budget));
        }
    }

    #[test]
    fn accumulator_merge_matches_single_fold() {
        // One 3-block chunk folded serially == the same blocks evaluated as
        // three single-block chunks (exactness of the merge, not closeness).
        let mc = MonteCarlo::paper_glb();
        let whole = mc.run_with(42, 3 * BLOCK_SAMPLES, &ThreadPool::new(1), 3 * BLOCK_SAMPLES);
        let split = mc.run_with(42, 3 * BLOCK_SAMPLES, &ThreadPool::new(1), BLOCK_SAMPLES);
        assert_eq!(whole, split);
    }

    #[test]
    fn empty_run_is_all_zero() {
        let mc = MonteCarlo::paper_glb();
        let r = mc.run(1, 0);
        assert_eq!(r.n, 0);
        assert_eq!(r.retention_violations, 0.0);
        assert_eq!(r.energy_adjustable, 0.0);
        assert_eq!((r.delta_min, r.delta_max), (0.0, 0.0));
    }

    #[test]
    fn delta_axis_reanchors_the_design() {
        let mc = MonteCarlo::paper_glb();
        let relaxed = mc.at_delta_gb(17.5);
        assert_eq!(relaxed.delta_guard_banded, 17.5);
        // Smaller Δ switches faster at the same WER target (t_pw ∝ ln Δ).
        assert!(relaxed.write_pulse < mc.write_pulse);
        let r = relaxed.run(3, 10_000);
        assert!(r.delta_mean < mc.run(3, 10_000).delta_mean);
    }

    #[test]
    fn for_technology_covers_stt_only() {
        let t = DesignTargets::global_buffer();
        assert!(MonteCarlo::for_technology(TechnologyId::SttSakhare2020, &t).is_some());
        assert!(MonteCarlo::for_technology(TechnologyId::SttWei2019, &t).is_some());
        assert!(MonteCarlo::for_technology(TechnologyId::Sot, &t).is_none());
        assert!(MonteCarlo::for_technology(TechnologyId::Sram, &t).is_none());
        // paper_glb is the Sakhare GLB solve.
        let a = MonteCarlo::paper_glb();
        let b = MonteCarlo::for_technology(TechnologyId::SttSakhare2020, &t).unwrap();
        assert_eq!(a.delta_guard_banded, b.delta_guard_banded);
        assert_eq!(a.write_pulse, b.write_pulse);
    }
}
