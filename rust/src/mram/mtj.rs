//! MTJ device model: thermal stability factor Δ (Eq. 12) and critical
//! switching current I_c (Eq. 13).


use super::{E_CHARGE, H_BAR, K_B};

/// Physical parameters of one MTJ design point.
///
/// Δ is *derived* from these via Eq. 12; customization (§IV.B) scales the
/// free-layer volume, which scales Δ linearly at fixed H_K, M_S, T.
#[derive(Debug, Clone, Copy)]
pub struct MtjParams {
    /// Anisotropy field H_K (A/m).
    pub h_k: f64,
    /// Saturation magnetization M_S (A/m).
    pub m_s: f64,
    /// Free-layer volume V (m^3).
    pub volume: f64,
    /// Temperature T (K).
    pub temperature: f64,
    /// LLGE damping constant α.
    pub alpha: f64,
    /// STT efficiency parameter η.
    pub eta: f64,
    /// Effective demagnetization field 4πM_eff (A/m).
    pub four_pi_m_eff: f64,
}

impl MtjParams {
    /// Thermal stability factor Δ = H_K · M_S · V / (2 k_B T)   (Eq. 12).
    ///
    /// (In SI the anisotropy energy density is μ0·H_K·M_S/2; the μ0 is folded
    /// into `h_k` here, matching how the paper quotes field values.)
    pub fn delta(&self) -> f64 {
        self.h_k * self.m_s * self.volume / (2.0 * K_B * self.temperature)
    }

    /// Critical switching current (Eq. 13):
    /// I_c = (4 e k_B T / ħ) · (α/η) · Δ · (1 + 4πM_eff / (2 H_K)).
    pub fn critical_current(&self) -> f64 {
        critical_current(self.delta(), self.temperature, self.alpha, self.eta, self.four_pi_m_eff, self.h_k)
    }

    /// Return a copy with the free-layer volume scaled so that Δ becomes
    /// `target_delta` (the §IV.B customization knob).
    pub fn with_delta(&self, target_delta: f64) -> Self {
        let cur = self.delta();
        assert!(cur > 0.0 && target_delta > 0.0);
        Self { volume: self.volume * target_delta / cur, ..*self }
    }

    /// Return a copy at a different operating temperature. Δ scales as 1/T
    /// (Eq. 12), which is exactly the (T_nom/T_hot) factor of Eq. 17.
    pub fn at_temperature(&self, t_kelvin: f64) -> Self {
        Self { temperature: t_kelvin, ..*self }
    }
}

/// Eq. 13 as a free function of Δ (used by the solver, where Δ is the
/// independent variable).
pub fn critical_current(delta: f64, temperature: f64, alpha: f64, eta: f64, four_pi_m_eff: f64, h_k: f64) -> f64 {
    (4.0 * E_CHARGE * K_B * temperature / H_BAR) * (alpha / eta) * delta * (1.0 + four_pi_m_eff / (2.0 * h_k))
}

/// Named STT-MRAM technology presets.
///
/// `tau_ret` is the Eq. 14 "technology constant" τ. NOTE (documented in
/// DESIGN.md §3): the physical thermal attempt time is ~1 ns, but both of the
/// paper's calibration points (Δ=39 → ≈3 yr @ BER 1e-9; Δ=19.5 → ≈3 s @
/// 1e-8) are consistent with τ ≈ 1 s, so the presets default to the
/// paper-calibrated value. Write dynamics (`tau_w`) and read disturb
/// (`tau_rd`) use the physical ~1 ns characteristic time.
#[derive(Debug, Clone, Copy)]
pub struct MtjTech {
    /// Human-readable name of the base-case silicon.
    pub name: &'static str,
    /// Baseline (10-year-retention-class) thermal stability factor.
    pub delta_base: f64,
    /// Eq. 14 technology constant τ (s) — paper-calibrated, see above.
    pub tau_ret: f64,
    /// Eq. 16 characteristic switching time (s).
    pub tau_w: f64,
    /// Eq. 15 attempt time for read disturb (s).
    pub tau_rd: f64,
    /// Baseline read latency of the silicon base case (s).
    pub read_latency_base: f64,
    /// Baseline write pulse of the silicon base case (s).
    pub write_latency_base: f64,
    /// Baseline write-current overdrive ratio I_w / I_c.
    pub overdrive_base: f64,
    /// Read-current ratio I_r / I_c.
    pub read_ratio: f64,
    /// Nominal device params at Δ = delta_base.
    pub params: MtjParams,
}

impl MtjTech {
    /// Sakhare et al., TED 2020 [6]: 14nm-class LLC STT-MRAM,
    /// J_SW = 5.5 MA/cm², RA = 5.2 Ω·μm². Base case for Fig. 15(c),(e).
    pub fn sakhare2020() -> Self {
        Self {
            name: "sakhare2020",
            delta_base: 60.0,
            tau_ret: 1.0,
            tau_w: 1.0e-9,
            tau_rd: 1.0e-9,
            read_latency_base: 4.0e-9,
            write_latency_base: 25.0e-9,
            overdrive_base: 2.0,
            read_ratio: 0.25,
            params: nominal_params_for_delta(60.0),
        }
    }

    /// Wei et al., ISSCC 2019 [13]: 7Mb STT-MRAM in 22FFL, 4ns read @0.9V.
    /// Base case for Fig. 15(d),(f) and Fig. 17.
    pub fn wei2019() -> Self {
        Self {
            name: "wei2019",
            delta_base: 60.0,
            tau_ret: 1.0,
            tau_w: 1.2e-9,
            tau_rd: 1.2e-9,
            read_latency_base: 4.0e-9,
            write_latency_base: 20.0e-9,
            overdrive_base: 2.2,
            read_ratio: 0.2,
            params: nominal_params_for_delta(60.0),
        }
    }

    /// MTJ params rescaled so Δ = `delta` at nominal temperature.
    pub fn params_at_delta(&self, delta: f64) -> MtjParams {
        self.params.with_delta(delta)
    }
}

/// Construct physically-plausible nominal MTJ parameters that yield the given
/// Δ at 300 K: CoFeB free layer, ~50 nm diameter, ~1.3 nm thickness class.
fn nominal_params_for_delta(delta: f64) -> MtjParams {
    // Start from representative constants (Khvalkovskiy 2013 / Diao 2007):
    let h_k = 1.2e5; // A/m-equivalent effective anisotropy (μ0 folded in, T≈0.15)
    let m_s = 1.1e6; // A/m
    let t = 300.0;
    // Solve Eq. 12 for volume.
    let volume = delta * 2.0 * K_B * t / (h_k * m_s);
    MtjParams {
        h_k,
        m_s,
        volume,
        temperature: t,
        alpha: 0.01,
        eta: 0.6,
        four_pi_m_eff: 2.0 * h_k, // makes the Eq. 13 bracket = 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_roundtrip_via_volume() {
        let p = nominal_params_for_delta(60.0);
        assert!((p.delta() - 60.0).abs() < 1e-9);
        let p2 = p.with_delta(19.5);
        assert!((p2.delta() - 19.5).abs() < 1e-9);
        // Volume scales linearly with Δ.
        assert!((p2.volume / p.volume - 19.5 / 60.0).abs() < 1e-12);
    }

    #[test]
    fn delta_scales_inverse_with_temperature() {
        let p = nominal_params_for_delta(60.0);
        let hot = p.at_temperature(393.0);
        assert!((hot.delta() - 60.0 * 300.0 / 393.0).abs() < 1e-9);
        let cold = p.at_temperature(253.0);
        assert!(cold.delta() > p.delta());
    }

    #[test]
    fn critical_current_linear_in_delta() {
        let p = nominal_params_for_delta(60.0);
        let ic60 = p.critical_current();
        let ic30 = p.with_delta(30.0).critical_current();
        assert!((ic60 / ic30 - 2.0).abs() < 1e-9);
        // Magnitude sanity: tens of microamps for these parameters.
        assert!(ic60 > 1e-6 && ic60 < 1e-3, "ic60={ic60}");
    }

    #[test]
    fn presets_have_sane_base() {
        for t in [MtjTech::sakhare2020(), MtjTech::wei2019()] {
            assert!((t.params.delta() - t.delta_base).abs() < 1e-6);
            assert!(t.overdrive_base > 1.0);
            assert!(t.read_ratio < 1.0);
        }
    }
}
