//! Write-bandwidth service model of the GLB: the stall side of the paper's
//! §II.C/§IV.D integration argument.
//!
//! The paper asserts that MRAM write pulses "hide behind compute". This
//! module makes that claim *checkable* instead of assumed:
//!
//! * [`GlbBandwidth`] derives sustained byte service rates for a
//!   [`GlbKind`] from the technology's write/read pulses
//!   ([`crate::mram::technology::MemTechnology::write_service_pulse`] /
//!   [`crate::mram::technology::MemTechnology::read_service_pulse`],
//!   floored/capped at the practical driver and sense-amp limits) and the
//!   banks' service-lane counts ([`BankSpec::lanes`]);
//! * [`layer_stall`] converts one layer's GLB/scratchpad traffic into the
//!   stall time the compute walk cannot hide, routing partial-ofmap rounds
//!   scratchpad-first with GLB overflow — the exact [`TrafficSplit`]
//!   coalescing the energy ledger uses, so the §IV.D scratchpad shows up as
//!   a *bandwidth* win, not just an energy win.
//!
//! The two-bank (STT-AI Ultra) organization: every word splits into an MSB
//! and an LSB half-word stream, and the §IV.D write buffer decouples the
//! banks, so each drains its stream at its own pulse and the service rates
//! add — the relaxed LSB bank (lower Δ, relaxed WER budget ⇒ shorter pulse)
//! buys the split GLB a write-bandwidth edge over the mono design, matching
//! its cheaper-write energy story.
//!
//! `accel::timing::inference_latency_stalled` composes these per-layer
//! stalls with the Eq. 5/8 compute walk; `dse::select` threads the result
//! into the `latency_s`/`throughput_rps` selection metrics.

use super::hierarchy::{BankSpec, GlbKind};
use super::scratchpad::{Scratchpad, TrafficSplit};
use crate::mram::technology::PRACTICAL_PULSE_FLOOR;

/// GLB access word (bytes) — one 64-bit word per lane per pulse.
pub const WORD_BYTES: f64 = 8.0;

/// Sustained service rates of one GLB organization (bytes/s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GlbBandwidth {
    pub write_bytes_per_s: f64,
    pub read_bytes_per_s: f64,
}

impl GlbBandwidth {
    /// Service rates of `kind` under a per-bank reliability budget: the mono
    /// (or MSB) bank runs at `msb_ber`, the split's relaxed bank at
    /// `lsb_ber`. Volatile banks ignore the budget entirely.
    pub fn of(kind: &GlbKind, msb_ber: f64, lsb_ber: f64) -> Self {
        match kind {
            GlbKind::Mono(b) => Self::bank(b, msb_ber, WORD_BYTES),
            GlbKind::Split { msb, lsb } => {
                let m = Self::bank(msb, msb_ber, 0.5 * WORD_BYTES);
                let l = Self::bank(lsb, lsb_ber, 0.5 * WORD_BYTES);
                Self {
                    write_bytes_per_s: m.write_bytes_per_s + l.write_bytes_per_s,
                    read_bytes_per_s: m.read_bytes_per_s + l.read_bytes_per_s,
                }
            }
        }
    }

    /// One bank moving `width_bytes` per lane per pulse. The budget is
    /// clamped away from 0/1 so a volatile-variant `BerConfig` (0.0) can
    /// never reach the nonvolatile pulse solvers.
    fn bank(b: &BankSpec, ber: f64, width_bytes: f64) -> Self {
        let t = b.tech.technology();
        let ber = ber.clamp(1.0e-15, 0.5);
        let per_lane = width_bytes * b.lanes as f64;
        Self {
            write_bytes_per_s: per_lane / t.write_service_pulse(ber, b.delta_guard_banded),
            read_bytes_per_s: per_lane / t.read_service_pulse(ber, b.delta_guard_banded),
        }
    }

    /// The infinite-bandwidth reference: zero service time for any traffic,
    /// so the stalled latency collapses to the pure compute walk (the
    /// zero-stall parity anchor of the test suite).
    pub fn unconstrained() -> Self {
        Self { write_bytes_per_s: f64::INFINITY, read_bytes_per_s: f64::INFINITY }
    }

    /// Time (s) to service a read/write byte load at these rates.
    pub fn service_time(&self, read_bytes: u64, write_bytes: u64) -> f64 {
        read_bytes as f64 / self.read_bytes_per_s + write_bytes as f64 / self.write_bytes_per_s
    }
}

/// Sustained scratchpad service rate (bytes/s): one word per bank per
/// SRAM-class pulse, floored at the practical limit.
pub fn scratchpad_bytes_per_s(sp: &Scratchpad) -> f64 {
    sp.banks as f64 * WORD_BYTES / sp.array.sram_latency_s().max(PRACTICAL_PULSE_FLOOR)
}

/// One layer's buffer load, pre-routed through the scratchpad policy: the
/// branchy part of [`layer_stall`], factored out so per-layer walks can be
/// flattened once (per traffic model) and the per-candidate stall loop stays
/// branch-light over plain arrays — the same split the PR 3 Monte-Carlo
/// engine applied to its RNG hot loop.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServiceLoads {
    /// GLB read bytes, overflow partial-round reads included.
    pub glb_read_bytes: u64,
    /// GLB write bytes, overflow partial-round writes included.
    pub glb_write_bytes: u64,
    /// Scratchpad write+read bytes (0 without a scratchpad).
    pub scratchpad_bytes: u64,
}

/// Route one layer's traffic: partial-ofmap rounds go scratchpad-first (GLB
/// overflow beyond the scratchpad capacity), or entirely to the GLB when no
/// scratchpad is present — mirroring [`super::BufferSystem::layer_energy`].
pub fn route_layer(
    scratchpad: Option<&Scratchpad>,
    glb_reads: u64,
    glb_writes: u64,
    partial_bytes: u64,
    partial_rounds: u64,
) -> ServiceLoads {
    match scratchpad {
        Some(sp) => {
            let split = TrafficSplit::split(partial_bytes, partial_rounds, sp);
            ServiceLoads {
                glb_read_bytes: glb_reads + split.glb_overflow_reads,
                glb_write_bytes: glb_writes + split.glb_overflow_writes,
                scratchpad_bytes: split.scratchpad_writes + split.scratchpad_reads,
            }
        }
        None => ServiceLoads {
            glb_read_bytes: glb_reads + partial_bytes * partial_rounds,
            glb_write_bytes: glb_writes + partial_bytes * partial_rounds,
            scratchpad_bytes: 0,
        },
    }
}

/// Stall time (s) of one pre-routed layer load at the given GLB rates and
/// scratchpad service rate (`f64::INFINITY` without a scratchpad; a zero
/// byte load then contributes exactly `0.0`). Branch-free: the inner loop of
/// [`crate::accel::StallPlan::stalled_latency`].
#[inline]
pub fn stall_from_loads(
    glb: &GlbBandwidth,
    sp_bytes_per_s: f64,
    loads: &ServiceLoads,
    t_compute: f64,
) -> f64 {
    (glb.service_time(loads.glb_read_bytes, loads.glb_write_bytes)
        + loads.scratchpad_bytes as f64 / sp_bytes_per_s
        - t_compute)
        .max(0.0)
}

/// Stall time (s) of one layer: the buffer service the layer's compute time
/// cannot hide. `glb_reads`/`glb_writes` are the layer's ifmap+weight reads
/// and final-ofmap writes; the composition of [`route_layer`] and
/// [`stall_from_loads`].
pub fn layer_stall(
    glb: &GlbBandwidth,
    scratchpad: Option<&Scratchpad>,
    glb_reads: u64,
    glb_writes: u64,
    partial_bytes: u64,
    partial_rounds: u64,
    t_compute: f64,
) -> f64 {
    let loads = route_layer(scratchpad, glb_reads, glb_writes, partial_bytes, partial_rounds);
    let sp_rate = scratchpad.map(scratchpad_bytes_per_s).unwrap_or(f64::INFINITY);
    stall_from_loads(glb, sp_rate, &loads, t_compute)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsys::hierarchy::DEFAULT_BANK_LANES;
    use crate::mram::technology::TechnologyId;
    use crate::util::units::MB;

    #[test]
    fn sram_outserves_stt_and_budgets_move_the_pulse() {
        let sram = GlbBandwidth::of(&GlbKind::baseline(), 0.0, 0.0);
        let stt = GlbBandwidth::of(&GlbKind::stt_ai(), 1.0e-8, 1.0e-5);
        assert!(sram.write_bytes_per_s > stt.write_bytes_per_s);
        assert!(sram.read_bytes_per_s >= stt.read_bytes_per_s);
        // A relaxed WER budget shortens the write pulse → more bandwidth.
        let relaxed = GlbBandwidth::of(&GlbKind::stt_ai(), 1.0e-5, 1.0e-5);
        assert!(relaxed.write_bytes_per_s > stt.write_bytes_per_s);
    }

    #[test]
    fn split_banks_add_their_half_word_streams() {
        let mono = GlbBandwidth::of(&GlbKind::stt_ai(), 1.0e-8, 1.0e-5);
        let split = GlbBandwidth::of(&GlbKind::stt_ai_ultra(), 1.0e-8, 1.0e-5);
        // The relaxed LSB bank drains faster than the robust bank, so the
        // split's aggregate write rate beats the mono design.
        assert!(split.write_bytes_per_s > mono.write_bytes_per_s, "{split:?} vs {mono:?}");
        // And stays below twice the mono rate (the MSB half is unchanged).
        assert!(split.write_bytes_per_s < 2.0 * mono.write_bytes_per_s);
    }

    #[test]
    fn sot_writes_at_the_practical_floor() {
        let sot = GlbBandwidth::of(&GlbKind::mono(TechnologyId::Sot), 1.0e-8, 1.0e-5);
        let lanes = DEFAULT_BANK_LANES as f64;
        // Sub-ns incubation-free switching floors at 1 ns: 8 B × lanes / ns.
        let expect = WORD_BYTES * lanes / PRACTICAL_PULSE_FLOOR;
        assert!((sot.write_bytes_per_s - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn lanes_scale_bandwidth_linearly() {
        let base = BankSpec::new(TechnologyId::SttSakhare2020, 27.5);
        let wide = base.with_lanes(2 * DEFAULT_BANK_LANES);
        let bw1 = GlbBandwidth::of(&GlbKind::Mono(base), 1.0e-8, 1.0e-5);
        let bw2 = GlbBandwidth::of(&GlbKind::Mono(wide), 1.0e-8, 1.0e-5);
        assert_eq!(bw2.write_bytes_per_s, 2.0 * bw1.write_bytes_per_s);
        assert_eq!(bw2.read_bytes_per_s, 2.0 * bw1.read_bytes_per_s);
        // Zero lanes are clamped to one serviceable lane.
        assert_eq!(base.with_lanes(0).lanes, 1);
    }

    #[test]
    fn service_time_is_linear_and_unconstrained_is_free() {
        let bw = GlbBandwidth::of(&GlbKind::stt_ai(), 1.0e-8, 1.0e-5);
        let t1 = bw.service_time(MB, MB);
        let t2 = bw.service_time(2 * MB, 2 * MB);
        assert!((t2 / t1 - 2.0).abs() < 1e-12);
        let free = GlbBandwidth::unconstrained();
        assert_eq!(free.service_time(u64::MAX, u64::MAX), 0.0);
    }

    #[test]
    fn stall_is_the_unhidden_service_only() {
        let bw = GlbBandwidth::of(&GlbKind::stt_ai(), 1.0e-8, 1.0e-5);
        // A layer with generous compute time hides all its traffic.
        assert_eq!(layer_stall(&bw, None, MB, MB, 0, 0, 10.0), 0.0);
        // With zero compute time the full service is exposed.
        let exposed = layer_stall(&bw, None, MB, MB, 0, 0, 0.0);
        assert_eq!(exposed, bw.service_time(MB, MB));
        // Stall is monotone in the write volume.
        assert!(layer_stall(&bw, None, MB, 4 * MB, 0, 0, 0.0) > exposed);
    }

    #[test]
    fn routed_loads_reproduce_layer_stall_exactly() {
        // The flattened fast path (route once, stall per candidate) is the
        // same arithmetic as the one-shot layer_stall — bit-identical, with
        // and without a scratchpad.
        let bw = GlbBandwidth::of(&GlbKind::stt_ai(), 1.0e-8, 1.0e-5);
        let sp = Scratchpad::paper_bf16();
        for scratchpad in [None, Some(&sp)] {
            let loads = route_layer(scratchpad, 3 * MB, MB, 40 * 1024, 64);
            let sp_rate =
                scratchpad.map(scratchpad_bytes_per_s).unwrap_or(f64::INFINITY);
            for t_compute in [0.0, 1e-6, 10.0] {
                assert_eq!(
                    stall_from_loads(&bw, sp_rate, &loads, t_compute),
                    layer_stall(&bw, scratchpad, 3 * MB, MB, 40 * 1024, 64, t_compute),
                );
            }
        }
        // Without a scratchpad the zero scratchpad load costs exactly zero
        // time even at the infinite rate (0/inf = 0).
        let none = route_layer(None, MB, MB, 0, 0);
        assert_eq!(none.scratchpad_bytes, 0);
        assert_eq!(stall_from_loads(&bw, f64::INFINITY, &none, 0.0), bw.service_time(MB, MB));
    }

    #[test]
    fn scratchpad_absorbs_partial_rounds_from_the_glb() {
        let bw = GlbBandwidth::of(&GlbKind::stt_ai(), 1.0e-8, 1.0e-5);
        let sp = Scratchpad::paper_bf16();
        // 40 KB partials × 64 rounds: fit the scratchpad entirely.
        let with_sp = layer_stall(&bw, Some(&sp), 0, 0, 40 * 1024, 64, 0.0);
        let without = layer_stall(&bw, None, 0, 0, 40 * 1024, 64, 0.0);
        assert!(with_sp < without, "{with_sp} vs {without}");
        // The scratchpad-side time matches its service rate exactly.
        let want = (2 * 40 * 1024 * 64) as f64 / scratchpad_bytes_per_s(&sp);
        assert!((with_sp - want).abs() / want < 1e-12);
    }
}
