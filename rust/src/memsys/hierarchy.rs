//! Composition of the full on-chip buffer system: GLB (one bank in any
//! registered memory technology, or the two-bank MSB/LSB split of STT-AI
//! Ultra), optional scratchpad, weight-storage NVM, and the DRAM behind it —
//! with an energy ledger used by Fig. 19 and the Table III accelerator rows.
//!
//! The GLB is described by [`BankSpec`]s — (technology, guard-banded Δ)
//! pairs — instead of hard-coded SRAM/STT variants, so the same composition
//! code serves the three paper design points and any technology the
//! [`crate::mram::technology`] registry knows (e.g. a SOT-MRAM GLB).

use super::array::MemoryArray;
use super::dram::DramModel;
use super::scratchpad::{Scratchpad, TrafficSplit};
use crate::mram::technology::{MemTechnology, TechnologyId};
use crate::util::units::MB;

/// Parallel word-wide service lanes per GLB bank: the macro is banked into
/// this many independently-addressed subarrays, each moving one 64-bit word
/// per read/write pulse. Calibrated so the STT GLB write bandwidth at the
/// paper design point (Δ 27.5, WER 1e-8, ~22 ns pulse → ~2.9 GB/s) hides
/// behind the 42×42-array compute walk at inference traffic, per the §V
/// integration argument (see `memsys::bandwidth`).
pub const DEFAULT_BANK_LANES: u64 = 8;

/// One GLB bank: a technology at a guard-banded Δ design point, with its
/// service-lane count (the write-bandwidth knob).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BankSpec {
    pub tech: TechnologyId,
    pub delta_guard_banded: f64,
    /// Parallel word-wide subarrays ([`DEFAULT_BANK_LANES`] unless resized).
    pub lanes: u64,
}

impl BankSpec {
    pub fn new(tech: TechnologyId, delta_guard_banded: f64) -> Self {
        Self { tech, delta_guard_banded, lanes: DEFAULT_BANK_LANES }
    }

    /// The same bank with a different service-lane count.
    pub fn with_lanes(mut self, lanes: u64) -> Self {
        self.lanes = lanes.max(1);
        self
    }

    /// The volatile baseline bank.
    pub fn sram() -> Self {
        Self::new(TechnologyId::Sram, 0.0)
    }

    /// A robust (GLB-class) bank at the technology's default design point.
    pub fn glb_default(tech: TechnologyId) -> Self {
        Self::new(tech, tech.technology().default_glb_delta())
    }

    /// A relaxed (LSB-class) bank at the technology's default design point.
    pub fn lsb_default(tech: TechnologyId) -> Self {
        Self::new(tech, tech.technology().default_lsb_delta())
    }

    /// Materialize an array of `capacity_bytes` in this bank's technology.
    pub fn array(&self, capacity_bytes: u64) -> MemoryArray {
        MemoryArray::new(self.tech, capacity_bytes, self.delta_guard_banded)
    }
}

/// Global-buffer organization: one full-capacity bank, or the STT-AI-Ultra
/// split where every word is divided into an MSB group (robust bank) and an
/// LSB group (relaxed bank).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GlbKind {
    /// One bank holding the full capacity.
    Mono(BankSpec),
    /// Two half-capacity banks splitting every word MSB/LSB.
    Split { msb: BankSpec, lsb: BankSpec },
}

impl GlbKind {
    /// Paper's three §V.F design points.
    pub fn baseline() -> Self {
        GlbKind::Mono(BankSpec::sram())
    }
    pub fn stt_ai() -> Self {
        GlbKind::Mono(BankSpec::new(TechnologyId::SttSakhare2020, 27.5))
    }
    pub fn stt_ai_ultra() -> Self {
        GlbKind::Split {
            msb: BankSpec::new(TechnologyId::SttSakhare2020, 27.5),
            lsb: BankSpec::new(TechnologyId::SttSakhare2020, 17.5),
        }
    }

    /// A single-bank GLB in any registered technology at its default
    /// GLB-class design point.
    pub fn mono(tech: TechnologyId) -> Self {
        GlbKind::Mono(BankSpec::glb_default(tech))
    }

    /// A two-bank MSB/LSB GLB in any registered technology at its default
    /// design points.
    pub fn split(tech: TechnologyId) -> Self {
        GlbKind::Split { msb: BankSpec::glb_default(tech), lsb: BankSpec::lsb_default(tech) }
    }

    /// The bank specs, MSB-first.
    pub fn banks(&self) -> Vec<BankSpec> {
        match self {
            GlbKind::Mono(b) => vec![*b],
            GlbKind::Split { msb, lsb } => vec![*msb, *lsb],
        }
    }
}

/// The assembled buffer system.
#[derive(Debug, Clone)]
pub struct BufferSystem {
    pub kind: GlbKind,
    pub glb_bytes: u64,
    pub scratchpad: Option<Scratchpad>,
    pub dram: DramModel,
}

/// Energy ledger for one workload segment (e.g., one conv layer or one full
/// inference), all in joules.
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyLedger {
    pub glb_read: f64,
    pub glb_write: f64,
    pub scratchpad: f64,
    pub dram: f64,
}

impl EnergyLedger {
    pub fn total(&self) -> f64 {
        self.glb_read + self.glb_write + self.scratchpad + self.dram
    }

    pub fn add(&mut self, o: &EnergyLedger) {
        self.glb_read += o.glb_read;
        self.glb_write += o.glb_write;
        self.scratchpad += o.scratchpad;
        self.dram += o.dram;
    }
}

impl BufferSystem {
    pub fn new(kind: GlbKind, glb_bytes: u64, scratchpad: Option<Scratchpad>) -> Self {
        Self { kind, glb_bytes, scratchpad, dram: DramModel::ddr4_2933_dual() }
    }

    /// The paper's three accelerator configurations with a 12 MB GLB.
    pub fn baseline_12mb() -> Self {
        Self::new(GlbKind::baseline(), 12 * MB, None)
    }
    pub fn stt_ai_12mb() -> Self {
        Self::new(GlbKind::stt_ai(), 12 * MB, Some(Scratchpad::paper_bf16()))
    }
    pub fn stt_ai_ultra_12mb() -> Self {
        Self::new(GlbKind::stt_ai_ultra(), 12 * MB, Some(Scratchpad::paper_bf16()))
    }

    /// The physical arrays making up the GLB.
    pub fn glb_arrays(&self) -> Vec<MemoryArray> {
        match self.kind {
            GlbKind::Mono(b) => vec![b.array(self.glb_bytes)],
            GlbKind::Split { msb, lsb } => {
                vec![msb.array(self.glb_bytes / 2), lsb.array(self.glb_bytes / 2)]
            }
        }
    }

    /// GLB silicon area (mm²), scratchpad included.
    pub fn area_mm2(&self) -> f64 {
        let glb: f64 = self.glb_arrays().iter().map(|a| a.area_mm2()).sum();
        glb + self.scratchpad.map_or(0.0, |s| s.array.area_mm2())
    }

    /// Total leakage (mW), scratchpad included (with gating).
    pub fn leakage_mw(&self) -> f64 {
        let glb: f64 = self.glb_arrays().iter().map(|a| a.leakage_mw()).sum();
        glb + self.scratchpad.map_or(0.0, |s| s.leakage_mw())
    }

    /// Per-word GLB read energy (J). Two-bank: both banks fire with
    /// half-width words.
    pub fn glb_read_energy_j(&self) -> f64 {
        match self.kind {
            GlbKind::Split { .. } => {
                self.glb_arrays().iter().map(|a| 0.5 * a.read_energy_j()).sum()
            }
            _ => self.glb_arrays()[0].read_energy_j(),
        }
    }

    /// Per-word GLB write energy (J).
    pub fn glb_write_energy_j(&self) -> f64 {
        match self.kind {
            GlbKind::Split { .. } => {
                self.glb_arrays().iter().map(|a| 0.5 * a.write_energy_j()).sum()
            }
            _ => self.glb_arrays()[0].write_energy_j(),
        }
    }

    /// Dynamic power at the reference rate (Table III column), 2:1 read mix.
    pub fn dynamic_power_mw(&self) -> f64 {
        use super::array::REF_ACCESS_RATE;
        let mix = 2.0;
        match self.kind {
            GlbKind::Split { msb, .. } => {
                // The banks split each word (MSB/LSB groups), sharing one
                // controller/address path — the module behaves like a single
                // full-capacity macro whose cell energy is the half-width
                // average of the two banks.
                let cell: f64 = self
                    .glb_arrays()
                    .iter()
                    .map(|a| 0.5 * a.avg_energy_j(mix) * REF_ACCESS_RATE * 1e3)
                    .sum();
                let ctrl = msb
                    .tech
                    .technology()
                    .ctrl_dynamic_mw(self.glb_bytes as f64 / (12.0 * MB as f64));
                ctrl + cell
            }
            _ => self.glb_arrays()[0].dynamic_power_mw(mix),
        }
    }

    /// Energy for a layer's GLB traffic, given byte counts and the
    /// partial-ofmap round structure (Fig. 19's three-way comparison).
    ///
    /// * `glb_reads`/`glb_writes`: ifmap+weight reads and final-ofmap writes.
    /// * `partial_bytes`, `rounds`: partial-ofmap accumulation traffic that
    ///   the scratchpad (if present) absorbs.
    /// * `dram_bytes`: spill traffic to DRAM.
    pub fn layer_energy(
        &self,
        glb_reads: u64,
        glb_writes: u64,
        partial_bytes: u64,
        rounds: u64,
        dram_bytes: u64,
    ) -> EnergyLedger {
        let word_bytes = 8.0; // 64-bit GLB word
        let er = self.glb_read_energy_j() / word_bytes;
        let ew = self.glb_write_energy_j() / word_bytes;

        let mut ledger = EnergyLedger {
            glb_read: glb_reads as f64 * er,
            glb_write: glb_writes as f64 * ew,
            scratchpad: 0.0,
            dram: self.dram.transfer_energy(dram_bytes),
        };

        match &self.scratchpad {
            Some(sp) => {
                let split = TrafficSplit::split(partial_bytes, rounds, sp);
                let esp_r = sp.array.read_energy_j() / word_bytes;
                let esp_w = sp.array.write_energy_j() / word_bytes;
                ledger.scratchpad = split.scratchpad_writes as f64 * esp_w
                    + split.scratchpad_reads as f64 * esp_r;
                ledger.glb_write += split.glb_overflow_writes as f64 * ew;
                ledger.glb_read += split.glb_overflow_reads as f64 * er;
            }
            None => {
                // No scratchpad: every partial round hits the GLB.
                ledger.glb_write += (partial_bytes * rounds) as f64 * ew;
                ledger.glb_read += (partial_bytes * rounds) as f64 * er;
            }
        }
        ledger
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::KB;

    #[test]
    fn table3_buffer_areas() {
        // SRAM 16.2, MRAM+SP ≈ 1.01+0.069, Ultra+SP ≈ 0.93+0.069.
        let b = BufferSystem::baseline_12mb().area_mm2();
        assert!((b - 16.2).abs() / 16.2 < 0.02, "{b}");
        let s = BufferSystem::stt_ai_12mb().area_mm2();
        assert!((s - 1.079).abs() / 1.079 < 0.05, "{s}");
        let u = BufferSystem::stt_ai_ultra_12mb().area_mm2();
        assert!(u < s, "ultra smaller than stt-ai: {u} vs {s}");
    }

    #[test]
    fn scratchpad_cuts_partial_ofmap_energy() {
        let with = BufferSystem::stt_ai_12mb();
        let without = BufferSystem::new(GlbKind::stt_ai(), 12 * MB, None);
        // ResNet-50-class layer: 40 KB partials, 64 accumulation rounds.
        let e_with = with.layer_energy(2_000_000, 400_000, 40 * KB, 64, 0);
        let e_without = without.layer_energy(2_000_000, 400_000, 40 * KB, 64, 0);
        assert!(e_with.total() < e_without.total());
        assert!(e_with.scratchpad > 0.0);
        assert_eq!(e_without.scratchpad, 0.0);
    }

    #[test]
    fn two_bank_read_energy_below_single_bank() {
        let ai = BufferSystem::stt_ai_12mb();
        let ultra = BufferSystem::stt_ai_ultra_12mb();
        assert!(ultra.glb_read_energy_j() < ai.glb_read_energy_j());
        assert!(ultra.glb_write_energy_j() < ai.glb_write_energy_j());
    }

    #[test]
    fn leakage_ordering_matches_table3() {
        let b = BufferSystem::baseline_12mb().leakage_mw();
        let s = BufferSystem::stt_ai_12mb().leakage_mw();
        let u = BufferSystem::stt_ai_ultra_12mb().leakage_mw();
        assert!(s < b && u < s, "b={b} s={s} u={u}");
    }

    #[test]
    fn dram_spill_adds_energy() {
        let sys = BufferSystem::stt_ai_12mb();
        let no_spill = sys.layer_energy(1000, 1000, 0, 0, 0);
        let spill = sys.layer_energy(1000, 1000, 0, 0, 10 * MB);
        assert!(spill.total() > no_spill.total());
        assert!(spill.dram > 0.0);
    }

    #[test]
    fn ledger_add_accumulates() {
        let sys = BufferSystem::stt_ai_12mb();
        let mut total = EnergyLedger::default();
        let l = sys.layer_energy(1000, 1000, 10 * KB, 4, 0);
        total.add(&l);
        total.add(&l);
        assert!((total.total() - 2.0 * l.total()).abs() < 1e-18);
    }

    #[test]
    fn any_registered_technology_composes_a_glb() {
        // The same composition code serves every registry entry.
        for id in
            [TechnologyId::Sram, TechnologyId::SttSakhare2020, TechnologyId::Sot] {
            let sys = BufferSystem::new(GlbKind::mono(id), 12 * MB, None);
            assert!(sys.area_mm2() > 0.0);
            assert!(sys.glb_read_energy_j() > 0.0);
            let e = sys.layer_energy(1000, 1000, 10 * KB, 4, 0);
            assert!(e.total() > 0.0, "{id:?}");
        }
        // A SOT split GLB exists and is write-cheaper than the STT split.
        let sot = BufferSystem::new(GlbKind::split(TechnologyId::Sot), 12 * MB, None);
        let stt = BufferSystem::stt_ai_ultra_12mb();
        assert!(sot.glb_write_energy_j() < stt.glb_write_energy_j());
    }

    #[test]
    fn paper_kinds_map_to_expected_banks() {
        assert_eq!(GlbKind::baseline().banks(), vec![BankSpec::sram()]);
        let ultra = GlbKind::stt_ai_ultra().banks();
        assert_eq!(ultra.len(), 2);
        assert_eq!(ultra[0].delta_guard_banded, 27.5);
        assert_eq!(ultra[1].delta_guard_banded, 17.5);
        assert!(ultra.iter().all(|b| b.tech.is_stt()));
        // Default-design-point constructors agree with the paper literals.
        assert_eq!(GlbKind::mono(TechnologyId::SttSakhare2020), GlbKind::stt_ai());
        assert_eq!(GlbKind::split(TechnologyId::SttSakhare2020), GlbKind::stt_ai_ultra());
    }
}
