//! Memory *system* models (paper §II.C, §IV.D, §V.A/C/E).
//!
//! * [`array`] — the Destiny-like parametric array model: (capacity, tech, Δ)
//!   → area, per-access read/write energy, leakage. Calibrated to the paper's
//!   Table III silicon-anchored rows and the Fig. 16 SRAM/MRAM crossover.
//! * [`dram`] — dual-channel DDR4-2933 model used for the Fig. 12 extra
//!   DRAM-access latency/energy analysis.
//! * [`scratchpad`] — the small SRAM scratchpad that absorbs partial-ofmap
//!   writes (§IV.D) and the write-traffic bypass accounting (Fig. 19).
//! * [`hierarchy`] — composition of GLB banks (any registered technology,
//!   single- or two-bank), scratchpad, weight NVM, and DRAM into one buffer
//!   system with an energy ledger per layer.
//! * [`bandwidth`] — per-bank write/read service rates from the technology
//!   pulses and the stall-time conversion behind
//!   `accel::timing::inference_latency_stalled`.
//!
//! Arrays and banks are parametrized by [`TechnologyId`] — the
//! [`crate::mram::technology::MemTechnology`] registry — instead of matching
//! on hard-coded SRAM/STT variants.

pub mod array;
pub mod bandwidth;
pub mod dram;
pub mod hierarchy;
pub mod nvm;
pub mod scratchpad;

pub use array::{MemoryArray, F_14NM};
pub use bandwidth::{GlbBandwidth, ServiceLoads};
pub use dram::DramModel;
pub use hierarchy::{BankSpec, BufferSystem, EnergyLedger, GlbKind, DEFAULT_BANK_LANES};
pub use nvm::WeightNvm;
pub use scratchpad::{Scratchpad, TrafficSplit};

pub use crate::mram::technology::TechnologyId;
