//! Destiny-like parametric on-chip memory array model.
//!
//! Maps (capacity, technology, Δ-design) → silicon area, per-access energy,
//! and leakage power at the 14 nm node. The functional forms are the standard
//! memory-compiler scalings (cell area · capacity + periphery; bitline energy
//! growing with array size; leakage ∝ area for SRAM and periphery-only for
//! MRAM); the constants live with each technology behind the
//! [`MemTechnology`] trait ([`crate::mram::technology`]) and are calibrated
//! so that:
//!
//! * 12 MB SRAM   → 16.2 mm², ~49 mW dyn @ reference rate, 0.21 mW leak
//! * 52 KB SRAM   → 0.069 mm² (the scratchpad row)         (Table III)
//! * 12 MB MRAM (Δ_GB=27.5) → ~1.01 mm², ~0.08 mW leak
//! * 6+6 MB MRAM (27.5/17.5) → ~0.93 mm²
//! * MRAM write energy ≈ 1.7 × read energy at scaled Δ (§V.E)
//! * SRAM/MRAM energy crossover ≈ 4 MB (Fig. 16)
//!
//! The paper used a Destiny modified with the silicon observation of [6];
//! we calibrate directly against the numbers the paper publishes. This
//! module is a thin, technology-agnostic shell: it owns only the geometry
//! bookkeeping (bits, capacity ratios, word width) and delegates every
//! per-cell number to the technology.

use crate::mram::technology::{MemTechnology, TechnologyId};
use crate::util::units::MB;

/// 14 nm feature size (m).
pub const F_14NM: f64 = 14.0e-9;

/// Reference access word width (bits) for the per-access energies.
pub const WORD_BITS: u64 = 64;

/// Reference GLB access rate (word accesses / s) used to convert per-access
/// energy into the Table III dynamic-power column.
pub const REF_ACCESS_RATE: f64 = 2.0e8;

/// One physical array instance: a capacity built in one registered memory
/// technology at one guard-banded Δ design point.
#[derive(Debug, Clone, Copy)]
pub struct MemoryArray {
    pub tech: TechnologyId,
    /// Guard-banded Δ the cells are built with (0 for volatile cells, which
    /// have no Δ knob).
    pub delta_guard_banded: f64,
    pub capacity_bytes: u64,
}

/// Reference capacity for the capacity-scaling terms.
const CAP_REF: f64 = 12.0 * MB as f64;

impl MemoryArray {
    /// An array in any registered technology.
    pub fn new(tech: TechnologyId, capacity_bytes: u64, delta_guard_banded: f64) -> Self {
        Self { tech, delta_guard_banded, capacity_bytes }
    }

    pub fn sram(capacity_bytes: u64) -> Self {
        Self::new(TechnologyId::Sram, capacity_bytes, 0.0)
    }

    pub fn stt_mram(capacity_bytes: u64, delta_guard_banded: f64) -> Self {
        Self::new(TechnologyId::SttSakhare2020, capacity_bytes, delta_guard_banded)
    }

    pub fn sot_mram(capacity_bytes: u64, delta_guard_banded: f64) -> Self {
        Self::new(TechnologyId::Sot, capacity_bytes, delta_guard_banded)
    }

    /// The technology model behind this array.
    pub fn technology(&self) -> &'static dyn MemTechnology {
        self.tech.technology()
    }

    fn bits(&self) -> f64 {
        self.capacity_bytes as f64 * 8.0
    }

    /// Bit-cell area in F² (per-technology calibration; see the trait docs).
    pub fn cell_area_f2(&self) -> f64 {
        self.technology().cell_area_f2(self.delta_guard_banded)
    }

    /// Macro silicon area (mm²) including periphery.
    pub fn area_mm2(&self) -> f64 {
        let cell_m2 = self.cell_area_f2() * F_14NM * F_14NM;
        let periphery = self.technology().periphery_mult();
        self.bits() * cell_m2 * periphery * 1e6 // m² → mm²
    }

    /// Leakage power (mW).
    pub fn leakage_mw(&self) -> f64 {
        let cap_mb = self.capacity_bytes as f64 / MB as f64;
        self.technology().leakage_mw(self.delta_guard_banded, cap_mb)
    }

    /// Per-access read energy (J) for a 64-bit word.
    pub fn read_energy_j(&self) -> f64 {
        let c = self.capacity_bytes as f64 / CAP_REF;
        self.technology().read_energy_j(self.delta_guard_banded, c)
    }

    /// Per-access write energy (J) for a 64-bit word.
    pub fn write_energy_j(&self) -> f64 {
        let c = self.capacity_bytes as f64 / CAP_REF;
        self.technology().write_energy_j(self.delta_guard_banded, c)
    }

    /// Average per-access energy for a read:write mix (reads per write).
    pub fn avg_energy_j(&self, reads_per_write: f64) -> f64 {
        (reads_per_write * self.read_energy_j() + self.write_energy_j()) / (reads_per_write + 1.0)
    }

    /// Dynamic power (mW) at the Table III reference access rate, including
    /// the controller component (larger for the big SRAM periphery).
    pub fn dynamic_power_mw(&self, reads_per_write: f64) -> f64 {
        let ctrl = self.technology().ctrl_dynamic_mw(self.capacity_bytes as f64 / CAP_REF);
        ctrl + self.avg_energy_j(reads_per_write) * REF_ACCESS_RATE * 1e3
    }

    /// Area ratio of an SRAM of the same capacity to this array (>1 ⇒ this
    /// array is denser). The Fig. 16(b)(d) metric.
    pub fn density_advantage(&self) -> f64 {
        MemoryArray::sram(self.capacity_bytes).area_mm2() / self.area_mm2()
    }

    /// Read/write latency (s): SRAM fixed ~1 ns class at 14 nm; MRAM from the
    /// Δ-designed pulse widths plus periphery, supplied by the caller via the
    /// `mram::scaling` solver. This helper only covers SRAM; MRAM timing
    /// lives in the design point.
    pub fn sram_latency_s(&self) -> f64 {
        debug_assert!(self.tech == TechnologyId::Sram);
        let c = self.capacity_bytes as f64 / CAP_REF;
        1.0e-9 * (0.4 + 0.6 * c.powf(0.4))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::KB;

    #[test]
    fn table3_sram_area() {
        let a = MemoryArray::sram(12 * MB).area_mm2();
        assert!((a - 16.2).abs() / 16.2 < 0.02, "a={a}");
        let sp = MemoryArray::sram(52 * KB).area_mm2();
        assert!((sp - 0.069).abs() / 0.069 < 0.03, "sp={sp}");
    }

    #[test]
    fn table3_mram_area() {
        let a = MemoryArray::stt_mram(12 * MB, 27.5).area_mm2();
        assert!((a - 1.01).abs() / 1.01 < 0.03, "a={a}");
        // 6+6 split (STT-AI Ultra).
        let split = MemoryArray::stt_mram(6 * MB, 27.5).area_mm2()
            + MemoryArray::stt_mram(6 * MB, 17.5).area_mm2();
        assert!((split - 0.93).abs() / 0.93 < 0.05, "split={split}");
    }

    #[test]
    fn table3_leakage() {
        assert!((MemoryArray::sram(12 * MB).leakage_mw() - 0.21).abs() < 0.01);
        assert!((MemoryArray::stt_mram(12 * MB, 27.5).leakage_mw() - 0.08).abs() < 0.01);
        let split = MemoryArray::stt_mram(6 * MB, 27.5).leakage_mw()
            + MemoryArray::stt_mram(6 * MB, 17.5).leakage_mw();
        assert!((split - 0.06).abs() < 0.01, "split={split}");
        let sp = MemoryArray::sram(52 * KB).leakage_mw();
        assert!((sp - 8e-4).abs() / 8e-4 < 0.25, "sp={sp}");
    }

    #[test]
    fn mram_write_is_about_1p7x_read() {
        let m = MemoryArray::stt_mram(12 * MB, 27.5);
        let ratio = m.write_energy_j() / m.read_energy_j();
        assert!((ratio - 1.7).abs() < 0.1, "ratio={ratio}");
    }

    #[test]
    fn fig16_crossover_near_4mb() {
        // Below the crossover SRAM wins on energy; above, MRAM wins.
        let mix = 2.0;
        let at = |mb: u64| {
            let s = MemoryArray::sram(mb * MB).avg_energy_j(mix);
            let m = MemoryArray::stt_mram(mb * MB, 27.5).avg_energy_j(mix);
            s / m
        };
        assert!(at(1) < 1.0, "SRAM should win at 1 MB: {}", at(1));
        assert!(at(2) < 1.05, "near-parity at 2 MB: {}", at(2));
        assert!(at(8) > 1.0, "MRAM should win at 8 MB: {}", at(8));
        assert!(at(32) > at(8), "advantage grows with capacity");
    }

    #[test]
    fn fig16_density_advantage_over_10x_at_12mb() {
        let adv = MemoryArray::stt_mram(12 * MB, 27.5).density_advantage();
        assert!(adv > 10.0, "adv={adv}");
        // And grows slightly for the relaxed LSB bank.
        let adv_lsb = MemoryArray::stt_mram(12 * MB, 17.5).density_advantage();
        assert!(adv_lsb > adv);
    }

    #[test]
    fn table3_dynamic_power_shape() {
        let mix = 2.0;
        let s = MemoryArray::sram(12 * MB).dynamic_power_mw(mix);
        let m = MemoryArray::stt_mram(12 * MB, 27.5).dynamic_power_mw(mix);
        // Two-bank module: every access touches both banks with half-width
        // words (MSB groups in one, LSB groups in the other) — half the cell
        // energy per bank, both controllers active.
        let split: f64 = [27.5, 17.5]
            .iter()
            .map(|&d| {
                let bank = MemoryArray::stt_mram(6 * MB, d);
                let full = bank.dynamic_power_mw(mix);
                let ctrl = full - bank.avg_energy_j(mix) * REF_ACCESS_RATE * 1e3;
                ctrl + 0.5 * bank.avg_energy_j(mix) * REF_ACCESS_RATE * 1e3
            })
            .sum();
        // Paper: 48.98 vs 17.61 vs 13.75 mW. Check ordering + rough ratios.
        assert!((s - 48.98).abs() / 48.98 < 0.25, "sram dyn={s}");
        assert!((m - 17.61).abs() / 17.61 < 0.25, "mram dyn={m}");
        assert!(split < s && m < s);
    }

    #[test]
    fn sram_latency_grows_with_capacity() {
        let small = MemoryArray::sram(52 * KB).sram_latency_s();
        let big = MemoryArray::sram(12 * MB).sram_latency_s();
        assert!(small < big);
        assert!(big < 2e-9);
    }

    #[test]
    fn sot_array_trades_density_for_write_energy() {
        let stt = MemoryArray::stt_mram(12 * MB, 27.5);
        let sot = MemoryArray::sot_mram(12 * MB, 27.5);
        assert!(sot.area_mm2() > stt.area_mm2(), "2T SOT cell is bigger");
        assert!(sot.area_mm2() < MemoryArray::sram(12 * MB).area_mm2() / 4.0);
        assert!(sot.write_energy_j() < stt.write_energy_j(), "SOT writes are cheaper");
        // At write-heavy mixes SOT wins the average energy.
        assert!(sot.avg_energy_j(0.5) < stt.avg_energy_j(0.5));
    }
}
