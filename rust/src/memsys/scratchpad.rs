//! The scratchpad-assisted GLB write-bypass of §IV.D (Figs. 18–19).
//!
//! Partial ofmaps — the per-input-channel partial sums produced between
//! accelerator steps — are written/read many times before the final ofmap is
//! complete. Routing that traffic to a small SRAM scratchpad instead of the
//! MRAM GLB removes the energy-dominant MRAM writes from the loop.


use super::array::MemoryArray;

/// A small SRAM scratchpad (two-bank, individually clock/power gated in the
/// paper's implementation — banking affects only leakage gating, modeled as a
/// gating factor here).
#[derive(Debug, Clone, Copy)]
pub struct Scratchpad {
    pub array: MemoryArray,
    pub banks: u32,
    /// Fraction of time the second bank can be power-gated (0..1).
    pub gated_fraction: f64,
}

impl Scratchpad {
    /// The paper's 52 KB two-bank scratchpad (26 KB int8 case halves it).
    pub fn paper_bf16() -> Self {
        Self {
            array: MemoryArray::sram(52 * 1024),
            banks: 2,
            gated_fraction: 0.5,
        }
    }

    pub fn paper_int8() -> Self {
        Self {
            array: MemoryArray::sram(26 * 1024),
            banks: 2,
            gated_fraction: 0.5,
        }
    }

    pub fn new(capacity_bytes: u64) -> Self {
        Self { array: MemoryArray::sram(capacity_bytes), banks: 2, gated_fraction: 0.5 }
    }

    /// Does a partial ofmap of `bytes` fit in one attempt?
    pub fn fits(&self, bytes: u64) -> bool {
        bytes <= self.array.capacity_bytes
    }

    /// Effective leakage with bank gating.
    pub fn leakage_mw(&self) -> f64 {
        let per_bank = self.array.leakage_mw() / self.banks as f64;
        per_bank * (self.banks as f64 - self.gated_fraction)
    }
}

/// Traffic split for one conv layer: how many bytes of partial-ofmap traffic
/// go to the scratchpad vs overflow to the GLB.
#[derive(Debug, Clone, Copy, Default)]
pub struct TrafficSplit {
    /// Partial-ofmap write bytes absorbed by the scratchpad.
    pub scratchpad_writes: u64,
    /// Partial-ofmap read bytes served by the scratchpad.
    pub scratchpad_reads: u64,
    /// Partial-ofmap bytes that overflow to the GLB (partial ofmap larger
    /// than the scratchpad).
    pub glb_overflow_writes: u64,
    pub glb_overflow_reads: u64,
}

impl TrafficSplit {
    /// Split partial-ofmap traffic: `partial_bytes` per accumulation round,
    /// `rounds` write+read rounds (one per input-channel step beyond the
    /// first; the final ofmap write still goes to the GLB and is *not*
    /// counted here).
    pub fn split(partial_bytes: u64, rounds: u64, sp: &Scratchpad) -> Self {
        if rounds == 0 {
            return Self::default();
        }
        let fit = partial_bytes.min(sp.array.capacity_bytes);
        let spill = partial_bytes - fit;
        Self {
            scratchpad_writes: fit * rounds,
            scratchpad_reads: fit * rounds,
            glb_overflow_writes: spill * rounds,
            glb_overflow_reads: spill * rounds,
        }
    }

    pub fn total_partial_bytes(&self) -> u64 {
        self.scratchpad_writes + self.glb_overflow_writes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::KB;

    #[test]
    fn paper_scratchpads() {
        assert_eq!(Scratchpad::paper_bf16().array.capacity_bytes, 52 * KB);
        assert_eq!(Scratchpad::paper_int8().array.capacity_bytes, 26 * KB);
        assert!(Scratchpad::paper_bf16().fits(52 * KB));
        assert!(!Scratchpad::paper_bf16().fits(52 * KB + 1));
    }

    #[test]
    fn gating_halves_second_bank_leakage() {
        let sp = Scratchpad::paper_bf16();
        let ungated = sp.array.leakage_mw();
        assert!(sp.leakage_mw() < ungated);
        assert!((sp.leakage_mw() / ungated - 0.75).abs() < 1e-9);
    }

    #[test]
    fn split_all_fits() {
        let sp = Scratchpad::paper_bf16();
        let s = TrafficSplit::split(40 * KB, 10, &sp);
        assert_eq!(s.scratchpad_writes, 400 * KB);
        assert_eq!(s.glb_overflow_writes, 0);
    }

    #[test]
    fn split_overflow() {
        let sp = Scratchpad::paper_bf16();
        let s = TrafficSplit::split(60 * KB, 4, &sp);
        assert_eq!(s.scratchpad_writes, 52 * KB * 4);
        assert_eq!(s.glb_overflow_writes, 8 * KB * 4);
        assert_eq!(s.total_partial_bytes(), 60 * KB * 4);
    }

    #[test]
    fn zero_rounds_no_traffic() {
        let sp = Scratchpad::paper_bf16();
        let s = TrafficSplit::split(60 * KB, 0, &sp);
        assert_eq!(s.total_partial_bytes(), 0);
    }
}
