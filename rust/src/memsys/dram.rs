//! Off-chip DRAM channel model: dual-channel DDR4-2933 with a 64-bit bus,
//! as used for the Fig. 12 extra-access latency/energy analysis (§V.A).


/// DDR4-style channel model.
#[derive(Debug, Clone, Copy)]
pub struct DramModel {
    /// Transfers per second per channel (MT/s · 1e6).
    pub transfer_rate: f64,
    /// Bus width per channel (bits).
    pub bus_bits: u32,
    /// Number of channels.
    pub channels: u32,
    /// Sustained-bandwidth efficiency vs peak (row misses, refresh, turnaround).
    pub efficiency: f64,
    /// Access energy (pJ/bit), I/O + array + on-die termination.
    pub energy_pj_per_bit: f64,
    /// Fixed latency per independent burst (s) — tRCD + tCL class.
    pub burst_latency: f64,
}

impl DramModel {
    /// The paper's configuration: dual-channel DDR4-2933, 64-bit bus.
    pub fn ddr4_2933_dual() -> Self {
        Self {
            transfer_rate: 2933.0e6,
            bus_bits: 64,
            channels: 2,
            efficiency: 0.7,
            energy_pj_per_bit: 15.0,
            burst_latency: 45.0e-9,
        }
    }

    /// Peak bandwidth (bytes/s).
    pub fn peak_bw(&self) -> f64 {
        self.transfer_rate * (self.bus_bits as f64 / 8.0) * self.channels as f64
    }

    /// Sustained bandwidth (bytes/s).
    pub fn sustained_bw(&self) -> f64 {
        self.peak_bw() * self.efficiency
    }

    /// Time (s) to move `bytes` as a streaming transfer.
    pub fn transfer_latency(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.burst_latency + bytes as f64 / self.sustained_bw()
    }

    /// Energy (J) to move `bytes`.
    pub fn transfer_energy(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 * self.energy_pj_per_bit * 1e-12
    }

    /// The paper's §II.C framing: DRAM ≈ 100–200× the energy of a local
    /// access. Ratio of DRAM pJ/bit to an on-chip per-bit read energy.
    pub fn energy_ratio_vs(&self, onchip_read_j_per_word: f64, word_bits: u32) -> f64 {
        let onchip_pj_per_bit = onchip_read_j_per_word * 1e12 / word_bits as f64;
        self.energy_pj_per_bit / onchip_pj_per_bit
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::MB;

    #[test]
    fn peak_bandwidth_matches_spec() {
        let d = DramModel::ddr4_2933_dual();
        // 2933 MT/s × 8 B × 2 ch = 46.9 GB/s.
        assert!((d.peak_bw() / 1e9 - 46.9).abs() < 0.1);
        assert!(d.sustained_bw() < d.peak_bw());
    }

    #[test]
    fn latency_linear_in_bytes() {
        let d = DramModel::ddr4_2933_dual();
        let t1 = d.transfer_latency(10 * MB);
        let t2 = d.transfer_latency(20 * MB);
        assert!(t2 > t1);
        assert!((t2 - d.burst_latency) / (t1 - d.burst_latency) > 1.99);
        assert_eq!(d.transfer_latency(0), 0.0);
    }

    #[test]
    fn fig12_scale_sanity() {
        // Paper: a few models spill ~2 ms at int8/batch-8 with a 12 MB GLB;
        // 2 ms at ~33 GB/s sustained ≈ 66 MB of spill — so a tens-of-MB
        // spill must land in the ms range.
        let d = DramModel::ddr4_2933_dual();
        let t = d.transfer_latency(66 * MB);
        assert!(t > 1.5e-3 && t < 3.0e-3, "t={t}");
    }

    #[test]
    fn energy_ratio_is_paper_order() {
        let d = DramModel::ddr4_2933_dual();
        // vs a register-file-class access (~0.1 pJ/bit): 100–200×.
        let ratio = d.energy_ratio_vs(0.8e-12, 64);
        assert!(ratio > 100.0 && ratio < 2000.0, "ratio={ratio}");
    }
}
