//! Weight-storage NVM: the Δ=39 (guard-banded 55) STT-MRAM bank that
//! replaces eFlash for pre-trained weights (§II.C, §IV.B, Fig. 10a/15a).
//!
//! Sizing comes straight from the zoo analysis: ~280 MB holds every model's
//! bf16 weights, ~140 MB at int8. The module also carries the qualitative
//! eFlash comparison the paper makes (eFlash stops scaling past 28 nm [10];
//! eMRAM wins on write voltage/energy, endurance, area, speed).

use crate::memsys::array::MemoryArray;
use crate::models::{DType, Model};
use crate::mram::technology::PRACTICAL_PULSE_FLOOR;
use crate::mram::{DesignTargets, MtjTech, ScalingSolver};

/// A weight-storage NVM design.
#[derive(Debug, Clone)]
pub struct WeightNvm {
    pub capacity_bytes: u64,
    pub array: MemoryArray,
    /// Guard-banded Δ of the bank.
    pub delta_guard_banded: f64,
    /// Retention at the 1e-9 budget (s).
    pub retention_s: f64,
    /// Write pulse for one word (s).
    pub write_pulse: f64,
}

impl WeightNvm {
    /// Size the NVM for a model set at a datatype, with a headroom factor
    /// (the paper keeps room for "models replaced frequently").
    pub fn sized_for(zoo: &[Model], dt: DType, headroom: f64, tech: MtjTech) -> Self {
        let need: u64 = zoo.iter().map(|m| m.size_bytes(dt)).max().unwrap_or(0);
        let capacity = (need as f64 * headroom) as u64;
        let solver = ScalingSolver::new(tech);
        let d = solver.solve(&DesignTargets::weight_nvm());
        Self {
            capacity_bytes: capacity,
            array: MemoryArray::stt_mram(capacity, d.delta_guard_banded),
            delta_guard_banded: d.delta_guard_banded,
            retention_s: d.achieved_retention,
            write_pulse: d.write_pulse,
        }
    }

    /// Capacity to store *all* zoo models simultaneously (the "model store"
    /// variant of Fig. 10a's aggregate).
    pub fn total_zoo_bytes(zoo: &[Model], dt: DType) -> u64 {
        zoo.iter().map(|m| m.size_bytes(dt)).sum()
    }

    /// Time to load one model's weights into the GLB at the NVM read
    /// bandwidth (words/s from the read pulse, `lanes` parallel banks).
    pub fn load_time(&self, model_bytes: u64, read_pulse: f64, lanes: u64) -> f64 {
        let words = model_bytes.div_ceil(8);
        // Pipelined reads: one word per read pulse per lane (sense-limited;
        // the practical floor guards tiny RD-budget pulses).
        words as f64 * read_pulse.max(PRACTICAL_PULSE_FLOOR) / lanes as f64
    }

    /// Full-model write time (one-time programming cost), words × t_w /
    /// lanes — under the same practical pulse floor as [`Self::load_time`],
    /// so a tiny-budget solve can never report a sub-physical program time.
    pub fn program_time(&self, model_bytes: u64, lanes: u64) -> f64 {
        let words = model_bytes.div_ceil(8);
        words as f64 * self.write_pulse.max(PRACTICAL_PULSE_FLOOR) / lanes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::util::units::MB;

    #[test]
    fn paper_capacity_class() {
        // Fig. 10a: ~280 MB bf16 / ~140 MB int8 for the largest model.
        let zoo = models::zoo();
        let nvm16 = WeightNvm::sized_for(&zoo, DType::Bf16, 1.0, MtjTech::sakhare2020());
        assert!(
            nvm16.capacity_bytes > 250 * MB && nvm16.capacity_bytes < 320 * MB,
            "{}",
            nvm16.capacity_bytes
        );
        let nvm8 = WeightNvm::sized_for(&zoo, DType::Int8, 1.0, MtjTech::sakhare2020());
        assert_eq!(nvm8.capacity_bytes * 2, nvm16.capacity_bytes);
    }

    #[test]
    fn retention_is_years() {
        let zoo = models::zoo();
        let nvm = WeightNvm::sized_for(&zoo, DType::Bf16, 1.0, MtjTech::sakhare2020());
        assert!(nvm.retention_s > 2.9 * 365.25 * 24.0 * 3600.0);
        assert!((nvm.delta_guard_banded - 55.0).abs() < 2.5, "{}", nvm.delta_guard_banded);
    }

    #[test]
    fn nvm_denser_than_sram_store() {
        let zoo = models::zoo();
        let nvm = WeightNvm::sized_for(&zoo, DType::Bf16, 1.0, MtjTech::sakhare2020());
        // Even at the conservative Δ=55, MRAM beats an SRAM weight store by
        // a wide margin — the eFlash-replacement argument in area terms.
        assert!(nvm.array.density_advantage() > 8.0, "{}", nvm.array.density_advantage());
    }

    #[test]
    fn load_and_program_times_scale() {
        let zoo = models::zoo();
        let nvm = WeightNvm::sized_for(&zoo, DType::Bf16, 1.0, MtjTech::sakhare2020());
        let t1 = nvm.load_time(100 * MB, 4e-9, 64);
        let t2 = nvm.load_time(200 * MB, 4e-9, 64);
        assert!((t2 / t1 - 2.0).abs() < 1e-6);
        // Programming a 100 MB model across 64 lanes stays sub-minute.
        let tp = nvm.program_time(100 * MB, 64);
        assert!(tp < 60.0, "{tp}");
        // More lanes, faster.
        assert!(nvm.program_time(100 * MB, 128) < tp);
    }

    #[test]
    fn program_time_floors_tiny_write_pulses() {
        let zoo = models::zoo();
        let mut nvm = WeightNvm::sized_for(&zoo, DType::Bf16, 1.0, MtjTech::sakhare2020());
        // Force a sub-physical solved pulse: the floor must hold, exactly
        // like the read path's sense floor.
        nvm.write_pulse = 1.0e-12;
        let words = (100 * MB).div_ceil(8) as f64;
        let t = nvm.program_time(100 * MB, 64);
        assert_eq!(t, words * PRACTICAL_PULSE_FLOOR / 64.0);
        // Symmetric with the read floor.
        assert_eq!(nvm.load_time(100 * MB, 1.0e-12, 64), t);
    }

    #[test]
    fn zoo_total_store() {
        let zoo = models::zoo();
        let total = WeightNvm::total_zoo_bytes(&zoo, DType::Bf16);
        // All 19 models together: ~1.3 GB bf16 (dominated by the VGGs).
        assert!(total > 1000 * MB && total < 1700 * MB, "{total}");
    }
}
