//! Functional/step-accurate simulator of the reconfigurable PE array.
//!
//! The paper evaluates with the *analytical* model (Eq. 2–11); this
//! simulator executes a conv layer the way §III.B describes — PE blocks get
//! kernel rows (row-stationary), ifmaps stream by column, partial sums
//! accumulate per input-channel step — counting actual array steps and
//! producing real numbers through the `PeBlock` functional model (Fig. 3).
//!
//! It serves two purposes:
//! 1. cross-validate `steps_per_out_ch` / Eq. 2 against a discrete schedule;
//! 2. validate the reconfigurable-core dataflow numerically against a
//!    direct convolution (the golden check behind Table II's cycle counts).

use crate::accel::core::{ArrayConfig, PeBlock};
use crate::accel::timing;
use crate::models::ConvLayer;
use crate::util::ceil_div;

/// Result of simulating one conv layer.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Output feature map, [out_ch][oh][ow] flattened.
    pub ofmap: Vec<f32>,
    pub out_ch: usize,
    pub oh: usize,
    pub ow: usize,
    /// Array steps actually used per output channel.
    pub steps_per_out_ch: u64,
    /// Partial-sum write+read rounds actually performed (scratchpad traffic).
    pub partial_rounds: u64,
    /// Total PE-block issue slots consumed.
    pub pe_issues: u64,
}

/// Simulate a conv layer (stride arbitrary, zero padding) on the array.
///
/// `ifmap`: [in_ch][in_h][in_w] flattened; `weights`: [out_ch][in_ch][kh][kw].
pub fn simulate_conv(
    layer: &ConvLayer,
    a: &ArrayConfig,
    ifmap: &[f32],
    weights: &[f32],
) -> SimResult {
    let (cin, h, w) = (layer.in_ch as usize, layer.in_h as usize, layer.in_w as usize);
    let (cout, kh, kw) = (layer.out_ch as usize, layer.kh as usize, layer.kw as usize);
    let (oh, ow) = (layer.ofmap_h() as usize, layer.ofmap_w() as usize);
    let stride = layer.stride as usize;
    let pad = layer.pad as usize;
    assert_eq!(ifmap.len(), cin * h * w, "ifmap shape");
    assert_eq!(weights.len(), cout * cin * kh * kw, "weight shape");
    assert_eq!(layer.groups, 1, "simulator covers dense conv");

    let at = |c: usize, y: isize, x: isize| -> f32 {
        // Zero padding outside the ifmap.
        if y < 0 || x < 0 || y as usize >= h || x as usize >= w {
            0.0
        } else {
            ifmap[c * h * w + y as usize * w + x as usize]
        }
    };

    // PE demand for one input channel (paper §III.B): each ofmap row needs
    // k_h · ceil(k_w / P_s) PE blocks.
    let pe_per_row = layer.kh * ceil_div(layer.kw, a.p_s);
    let pe_per_in_ch = layer.ofmap_h() * pe_per_row;
    let capacity = a.total_pes();
    let ch_per_step = (capacity / pe_per_in_ch).max(1) as usize;

    let mut ofmap = vec![0.0f32; cout * oh * ow];
    let mut steps_per_out_ch = 0u64;
    let mut partial_rounds = 0u64;
    let mut pe_issues = 0u64;

    for oc in 0..cout {
        // Input channels are processed ch_per_step at a time; the partial
        // ofmap is staged to the scratchpad between steps.
        let mut steps_this_oc = 0u64;
        let mut psum = vec![0.0f32; oh * ow]; // the scratchpad-resident partial
        let mut ic0 = 0usize;
        while ic0 < cin {
            let ic1 = (ic0 + ch_per_step).min(cin);
            steps_this_oc += 1;
            if ic0 > 0 {
                partial_rounds += 1; // wrote + read back the partial ofmap
            }
            for ic in ic0..ic1 {
                for oy in 0..oh {
                    for ox in 0..ow {
                        // One ofmap element = k_h rows of P_s-wide dot
                        // products through the Fig. 3c PE chain.
                        let mut acc = psum[oy * ow + ox];
                        for ky in 0..kh {
                            let mut kx = 0usize;
                            while kx < kw {
                                let mut pe = PeBlock::default();
                                let mut ivec = [0.0f32; 3];
                                let mut wvec = [0.0f32; 3];
                                for lane in 0..(a.p_s as usize).min(3) {
                                    if kx + lane < kw {
                                        let y = (oy * stride + ky) as isize - pad as isize;
                                        let x = (ox * stride + kx + lane) as isize - pad as isize;
                                        ivec[lane] = at(ic, y, x);
                                        wvec[lane] = weights
                                            [((oc * cin + ic) * kh + ky) * kw + kx + lane];
                                    }
                                }
                                acc = pe.conv_step(ivec, wvec, acc);
                                pe_issues += 1;
                                kx += a.p_s as usize;
                            }
                        }
                        psum[oy * ow + ox] = acc;
                    }
                }
            }
            ic0 = ic1;
        }
        steps_per_out_ch = steps_per_out_ch.max(steps_this_oc);
        ofmap[oc * oh * ow..(oc + 1) * oh * ow].copy_from_slice(&psum);
    }

    SimResult { ofmap, out_ch: cout, oh, ow, steps_per_out_ch, partial_rounds, pe_issues }
}

/// Direct (golden) convolution for validation.
pub fn conv_golden(layer: &ConvLayer, ifmap: &[f32], weights: &[f32]) -> Vec<f32> {
    let (cin, h, w) = (layer.in_ch as usize, layer.in_h as usize, layer.in_w as usize);
    let (cout, kh, kw) = (layer.out_ch as usize, layer.kh as usize, layer.kw as usize);
    let (oh, ow) = (layer.ofmap_h() as usize, layer.ofmap_w() as usize);
    let stride = layer.stride as usize;
    let pad = layer.pad as isize;
    let mut out = vec![0.0f32; cout * oh * ow];
    for oc in 0..cout {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0.0f32;
                for ic in 0..cin {
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let y = (oy * stride + ky) as isize - pad;
                            let x = (ox * stride + kx) as isize - pad;
                            if y >= 0 && x >= 0 && (y as usize) < h && (x as usize) < w {
                                acc += ifmap[ic * h * w + y as usize * w + x as usize]
                                    * weights[((oc * cin + ic) * kh + ky) * kw + kx];
                            }
                        }
                    }
                }
                out[oc * oh * ow + oy * ow + ox] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn layer(in_ch: u64, out_ch: u64, k: u64, stride: u64, pad: u64, hw: u64) -> ConvLayer {
        ConvLayer {
            name: "sim".into(),
            in_ch,
            out_ch,
            kh: k,
            kw: k,
            stride,
            pad,
            groups: 1,
            in_h: hw,
            in_w: hw,
        }
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.next_f64() as f32) - 0.5).collect()
    }

    #[test]
    fn fig4_example_geometry() {
        // Fig. 4: 3×3 kernel over 5×5 ifmap, stride 1 → 3×3 ofmap, 9 PEs,
        // one step on the paper array.
        let l = layer(1, 1, 3, 1, 0, 5);
        let a = ArrayConfig::paper_42x42();
        let mut rng = Rng::seed_from_u64(1);
        let x = rand_vec(&mut rng, 25);
        let w = rand_vec(&mut rng, 9);
        let r = simulate_conv(&l, &a, &x, &w);
        assert_eq!((r.oh, r.ow), (3, 3));
        assert_eq!(r.steps_per_out_ch, 1);
        assert_eq!(r.partial_rounds, 0);
    }

    #[test]
    fn simulator_matches_golden_conv() {
        let mut rng = Rng::seed_from_u64(2);
        let a = ArrayConfig::paper_42x42();
        for (cin, cout, k, stride, pad, hw) in
            [(3, 4, 3, 1, 1, 8), (2, 2, 5, 1, 2, 9), (4, 3, 3, 2, 1, 11), (1, 6, 1, 1, 0, 7)]
        {
            let l = layer(cin, cout, k, stride, pad, hw);
            let x = rand_vec(&mut rng, (cin * hw * hw) as usize);
            let w = rand_vec(&mut rng, (cout * cin * k * k) as usize);
            let sim = simulate_conv(&l, &a, &x, &w);
            let gold = conv_golden(&l, &x, &w);
            for (i, (s, g)) in sim.ofmap.iter().zip(&gold).enumerate() {
                assert!(
                    (s - g).abs() <= 1e-4 * g.abs().max(1.0),
                    "cin={cin} cout={cout} k={k} s={stride} idx={i}: {s} vs {g}"
                );
            }
        }
    }

    #[test]
    fn simulator_steps_match_eq2() {
        // The discrete schedule and the analytical Eq. 2 agree on steps per
        // output channel across a spread of layer shapes.
        let a = ArrayConfig::paper_42x42();
        let mut rng = Rng::seed_from_u64(3);
        for (cin, cout, k, hw) in [(16, 4, 3, 14), (32, 2, 3, 28), (8, 8, 5, 10), (64, 2, 1, 7)] {
            let l = layer(cin, cout, k, 1, 0, hw);
            let x = rand_vec(&mut rng, (cin * hw * hw) as usize);
            let w = rand_vec(&mut rng, (cout * cin * k * k) as usize);
            let sim = simulate_conv(&l, &a, &x, &w);
            let analytical = timing::steps_per_out_ch(&l, &a);
            assert_eq!(
                sim.steps_per_out_ch, analytical,
                "cin={cin} k={k} hw={hw}: sim {} vs Eq.2 {}",
                sim.steps_per_out_ch, analytical
            );
        }
    }

    #[test]
    fn partial_rounds_match_traffic_model() {
        let a = ArrayConfig::paper_42x42();
        let l = layer(32, 3, 3, 1, 0, 28);
        let mut rng = Rng::seed_from_u64(4);
        let x = rand_vec(&mut rng, 32 * 28 * 28);
        let w = rand_vec(&mut rng, 3 * 32 * 9);
        let sim = simulate_conv(&l, &a, &x, &w);
        // Traffic model: (steps − 1) rounds per output channel (batch 1).
        let expect = (sim.steps_per_out_ch - 1) * l.out_ch;
        assert_eq!(sim.partial_rounds, expect);
    }

    #[test]
    fn pe_issue_count_scales_with_macs() {
        // PE issues = ofmap elems × k_h × ceil(k_w/P_s) per (in,out) pair.
        let a = ArrayConfig::paper_42x42();
        let l = layer(2, 2, 3, 1, 0, 6);
        let x = vec![0.0; 2 * 36];
        let w = vec![0.0; 2 * 2 * 9];
        let sim = simulate_conv(&l, &a, &x, &w);
        let per_pair = (l.ofmap_h() * l.ofmap_w()) * l.kh * ceil_div(l.kw, a.p_s);
        assert_eq!(sim.pe_issues, per_pair * l.in_ch * l.out_ch);
    }
}
