//! GLB / scratchpad / DRAM byte-traffic accounting per layer.
//!
//! Drives the Fig. 12 extra-DRAM-access analysis (spill when a layer's
//! working set exceeds the GLB) and the Fig. 19 scratchpad-energy comparison
//! (partial-ofmap write/read rounds between accelerator steps, §IV.D).


use super::core::ArrayConfig;
use super::timing::steps_per_out_ch;
use crate::memsys::bandwidth::{route_layer, ServiceLoads};
use crate::memsys::Scratchpad;
use crate::models::{ConvLayer, DType, Layer, Model};

/// Byte traffic of one conv layer at a given batch.
#[derive(Debug, Clone)]
pub struct LayerTraffic {
    pub name: String,
    /// ifmap + weight bytes read from GLB (per inference of the batch).
    pub glb_reads: u64,
    /// Final ofmap bytes written to GLB.
    pub glb_writes: u64,
    /// Bytes of one partial ofmap (the scratchpad working set).
    pub partial_bytes: u64,
    /// Number of partial-accumulation rounds (write+read each) between
    /// steps: steps_per_out_ch − 1 per output channel, times batch.
    pub partial_rounds: u64,
    /// Working-set bytes (ifmap + weights + ofmap) — GLB requirement.
    pub working_set: u64,
    /// Bytes spilled to DRAM if the working set exceeds `glb_bytes`
    /// (the overflow streams from/to DRAM once per layer).
    pub dram_bytes: u64,
}

impl LayerTraffic {
    /// This layer's traffic with the write side scaled by a training-style
    /// multiplier (the `write_intensity` sweep axis, arXiv:2308.02024
    /// scenario): final-ofmap writes and partial-accumulation rounds grow
    /// by `wi`; reads and DRAM spill are unchanged. `wi = 1` reproduces the
    /// layer verbatim (bit-identical counts).
    pub fn with_write_intensity(&self, wi: f64) -> LayerTraffic {
        LayerTraffic {
            glb_writes: (self.glb_writes as f64 * wi).round() as u64,
            partial_rounds: (self.partial_rounds as f64 * wi).round() as u64,
            ..self.clone()
        }
    }
}

/// Traffic analysis of a whole model.
#[derive(Debug, Clone)]
pub struct ModelTraffic {
    pub model: String,
    pub layers: Vec<LayerTraffic>,
}

impl ModelTraffic {
    /// Analyze conv-layer traffic (§V.A scope: FC weights stream from
    /// DRAM/NVM directly, so FC layers are excluded from GLB sizing).
    pub fn analyze(m: &Model, a: &ArrayConfig, dt: DType, batch: u64, glb_bytes: u64) -> Self {
        let layers = m
            .layers
            .iter()
            .filter_map(|l| match l {
                Layer::Conv(c) => Some(layer_traffic(c, a, dt, batch, glb_bytes)),
                _ => None,
            })
            .collect();
        Self { model: m.name.clone(), layers }
    }

    pub fn total_dram_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.dram_bytes).sum()
    }

    pub fn total_glb_reads(&self) -> u64 {
        self.layers.iter().map(|l| l.glb_reads).sum()
    }

    pub fn total_glb_writes(&self) -> u64 {
        self.layers.iter().map(|l| l.glb_writes).sum()
    }

    /// Max partial-ofmap bytes over the model (Fig. 18's metric).
    pub fn max_partial_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.partial_bytes).max().unwrap_or(0)
    }

    /// Pre-route every layer through the scratchpad policy in one flat pass
    /// ([`route_layer`]): the per-layer branch on scratchpad presence and
    /// the [`crate::memsys::scratchpad::TrafficSplit`] arithmetic run once
    /// per traffic model instead of once per (candidate × layer), leaving
    /// the stall hot loop ([`crate::accel::StallPlan::stalled_latency`]) a
    /// branch-light walk over plain arrays.
    pub fn routed_loads(&self, scratchpad: Option<&Scratchpad>) -> Vec<ServiceLoads> {
        let route = |l: &LayerTraffic| {
            route_layer(scratchpad, l.glb_reads, l.glb_writes, l.partial_bytes, l.partial_rounds)
        };
        self.layers.iter().map(route).collect()
    }

    /// The whole walk with every layer's write side scaled by `wi`
    /// ([`LayerTraffic::with_write_intensity`]).
    pub fn with_write_intensity(&self, wi: f64) -> ModelTraffic {
        ModelTraffic {
            model: self.model.clone(),
            layers: self.layers.iter().map(|l| l.with_write_intensity(wi)).collect(),
        }
    }
}

fn layer_traffic(c: &ConvLayer, a: &ArrayConfig, dt: DType, batch: u64, glb_bytes: u64) -> LayerTraffic {
    let eb = dt.bytes();
    let glb_reads = (batch * c.ifmap_elems() + c.weight_elems()) * eb;
    let glb_writes = batch * c.ofmap_elems() * eb;
    let partial_bytes = c.partial_ofmap_elems() * eb;
    let steps = steps_per_out_ch(c, a);
    // One write+read round per step beyond the first, for every output
    // channel of every image in the batch.
    let partial_rounds = steps.saturating_sub(1) * c.out_ch * batch;
    let working_set = (batch * (c.ifmap_elems() + c.ofmap_elems()) + c.weight_elems()) * eb;
    let dram_bytes = working_set.saturating_sub(glb_bytes);
    LayerTraffic {
        name: c.name.clone(),
        glb_reads,
        glb_writes,
        partial_bytes,
        partial_rounds,
        working_set,
        dram_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::util::units::{KB, MB};

    fn setup() -> (ArrayConfig, Model) {
        (ArrayConfig::paper_42x42(), models::by_name("ResNet50").unwrap())
    }
    use crate::models::Model;

    #[test]
    fn fig18_partial_ofmaps_fit_52kb_bf16() {
        // Paper Fig. 18: a 52 KB scratchpad fits most models' partial ofmaps
        // (bf16); 26 KB for int8.
        let a = ArrayConfig::paper_42x42();
        let zoo = models::zoo();
        let mut fit = 0;
        for m in &zoo {
            let t = ModelTraffic::analyze(m, &a, DType::Bf16, 1, 12 * MB);
            if t.max_partial_bytes() <= 52 * KB {
                fit += 1;
            }
        }
        assert!(fit * 4 >= zoo.len() * 3, "≥75% of models must fit 52 KB, got {fit}/19");
    }

    #[test]
    fn int8_partials_half_of_bf16() {
        let (a, m) = setup();
        let t16 = ModelTraffic::analyze(&m, &a, DType::Bf16, 1, 12 * MB);
        let t8 = ModelTraffic::analyze(&m, &a, DType::Int8, 1, 12 * MB);
        assert_eq!(t16.max_partial_bytes(), 2 * t8.max_partial_bytes());
    }

    #[test]
    fn fig12_no_spill_for_resnet50_int8_12mb() {
        // Paper: with 12 MB GLB most models spill nothing at int8, batch ≤ 8.
        let (a, m) = setup();
        let t = ModelTraffic::analyze(&m, &a, DType::Int8, 8, 12 * MB);
        assert_eq!(t.total_dram_bytes(), 0, "ResNet50 int8 batch 8 must fit 12 MB");
    }

    #[test]
    fn fig12_spill_appears_for_big_models_bf16() {
        // VGG19 at bf16 batch 8 exceeds 12 MB on its big layers.
        let a = ArrayConfig::paper_42x42();
        let m = models::by_name("VGG19").unwrap();
        let t = ModelTraffic::analyze(&m, &a, DType::Bf16, 8, 12 * MB);
        assert!(t.total_dram_bytes() > 0);
        // And a bigger GLB removes it.
        let t64 = ModelTraffic::analyze(&m, &a, DType::Bf16, 8, 64 * MB);
        assert!(t64.total_dram_bytes() < t.total_dram_bytes());
    }

    #[test]
    fn partial_rounds_zero_when_single_step() {
        let a = ArrayConfig::paper_42x42();
        // Tiny layer: everything fits in one array step → no partial rounds.
        let c = ConvLayer {
            name: "tiny".into(),
            in_ch: 1,
            out_ch: 1,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 0,
            groups: 1,
            in_h: 5,
            in_w: 5,
        };
        let t = layer_traffic(&c, &a, DType::Bf16, 1, 12 * MB);
        assert_eq!(t.partial_rounds, 0);
    }

    #[test]
    fn write_intensity_scales_the_write_side_only() {
        let (a, m) = setup();
        let t = ModelTraffic::analyze(&m, &a, DType::Bf16, 4, 12 * MB);
        let l = &t.layers[1];
        // Unit intensity is the identity, bit for bit.
        let same = l.with_write_intensity(1.0);
        assert_eq!((same.glb_writes, same.partial_rounds), (l.glb_writes, l.partial_rounds));
        // Training-style intensity scales writes/rounds, nothing else.
        let train = l.with_write_intensity(2.5);
        assert_eq!(train.glb_writes, (l.glb_writes as f64 * 2.5).round() as u64);
        assert_eq!(train.partial_rounds, (l.partial_rounds as f64 * 2.5).round() as u64);
        assert_eq!(train.glb_reads, l.glb_reads);
        assert_eq!(train.dram_bytes, l.dram_bytes);
        assert_eq!(train.partial_bytes, l.partial_bytes);
    }

    #[test]
    fn reads_and_writes_scale_with_batch() {
        let (a, m) = setup();
        let t1 = ModelTraffic::analyze(&m, &a, DType::Bf16, 1, 12 * MB);
        let t4 = ModelTraffic::analyze(&m, &a, DType::Bf16, 4, 12 * MB);
        assert!(t4.total_glb_reads() > t1.total_glb_reads());
        assert_eq!(t4.total_glb_writes(), 4 * t1.total_glb_writes());
    }
}
