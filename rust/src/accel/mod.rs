//! The reconfigurable-core AI accelerator model (paper §III).
//!
//! * [`core`] — the PE building block (3 MACs + 4 muxes, Fig. 3) and the
//!   array-level configuration with the post-layout Table II timing.
//! * [`timing`] — the analytical occupancy/retention-time model, Eq. 2–11.
//! * [`traffic`] — GLB/scratchpad/DRAM byte-traffic accounting per layer
//!   (drives Fig. 12 and Fig. 19).

pub mod core;
pub mod simulator;
pub mod systolic;
pub mod timing;
pub mod traffic;

pub use core::{ArrayConfig, CoreMode, PeBlock};
pub use simulator::{conv_golden, simulate_conv, SimResult};
pub use systolic::{eq8_steps, matmul_golden, simulate_fc, SystolicResult};
pub use timing::{LayerTiming, ModelRetention, RetentionAnalysis, StallPlan, StalledLatency};
pub use traffic::{LayerTraffic, ModelTraffic};
