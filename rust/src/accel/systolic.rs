//! Functional simulator of the systolic mode (Fig. 3b / Fig. 5).
//!
//! FC layers run with Mode = 0: the PE blocks decompose into independent
//! MACs forming an H_A × W_SA systolic array. Weights load per step
//! (divide & conquer over the weight matrix, Fig. 5b), inputs stream
//! left→right, partial sums move down into accumulators. This module
//! executes that schedule step by step, validating Eq. 8's step count and
//! the numerical result against a direct matmul.

use crate::accel::core::ArrayConfig;
use crate::util::ceil_div;

/// Result of simulating one FC layer (x: [batch, n_in] · w: [n_in, m_out]).
#[derive(Debug, Clone)]
pub struct SystolicResult {
    /// Output activations, [batch][m_out] flattened.
    pub out: Vec<f32>,
    /// Weight-load steps actually used (Eq. 8's ceil(m/H_A)·ceil(n/W_SA)).
    pub weight_loads: u64,
    /// MAC operations issued.
    pub macs: u64,
}

/// Simulate the FC layer: tile the weight matrix into (W_SA × H_A) blocks
/// (n-dim × m-dim), load each, stream all batch rows through.
pub fn simulate_fc(a: &ArrayConfig, x: &[f32], w: &[f32], batch: usize, n_in: usize, m_out: usize) -> SystolicResult {
    assert_eq!(x.len(), batch * n_in, "x shape");
    assert_eq!(w.len(), n_in * m_out, "w shape");
    let w_sa = a.w_sa() as usize; // n-dim tile (inputs per load)
    let h_a = a.h_a as usize; // m-dim tile (outputs per load)

    let mut out = vec![0.0f32; batch * m_out];
    let mut weight_loads = 0u64;
    let mut macs = 0u64;

    let mut m0 = 0usize;
    while m0 < m_out {
        let m1 = (m0 + h_a).min(m_out);
        let mut n0 = 0usize;
        while n0 < n_in {
            let n1 = (n0 + w_sa).min(n_in);
            weight_loads += 1; // one array-load step (Fig. 5b tile)
            // Stream every batch row through the loaded tile: each MAC
            // (n, m) accumulates x[b][n]·w[n][m] downward.
            for b in 0..batch {
                for m in m0..m1 {
                    let mut acc = out[b * m_out + m];
                    for n in n0..n1 {
                        acc += x[b * n_in + n] * w[n * m_out + m];
                        macs += 1;
                    }
                    out[b * m_out + m] = acc;
                }
            }
            n0 = n1;
        }
        m0 = m1;
    }
    SystolicResult { out, weight_loads, macs }
}

/// Direct matmul for validation.
pub fn matmul_golden(x: &[f32], w: &[f32], batch: usize, n_in: usize, m_out: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; batch * m_out];
    for b in 0..batch {
        for m in 0..m_out {
            let mut acc = 0.0f32;
            for n in 0..n_in {
                acc += x[b * n_in + n] * w[n * m_out + m];
            }
            out[b * m_out + m] = acc;
        }
    }
    out
}

/// Eq. 8's analytical step count for comparison.
pub fn eq8_steps(a: &ArrayConfig, n_in: u64, m_out: u64) -> u64 {
    ceil_div(m_out, a.h_a) * ceil_div(n_in, a.w_sa())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.next_f64() as f32) - 0.5).collect()
    }

    #[test]
    fn matches_golden_matmul() {
        let a = ArrayConfig::paper_42x42();
        let mut rng = Rng::seed_from_u64(1);
        for (batch, n_in, m_out) in [(1, 100, 50), (4, 64, 64), (3, 200, 97), (2, 42, 42)] {
            let x = rand_vec(&mut rng, batch * n_in);
            let w = rand_vec(&mut rng, n_in * m_out);
            let sim = simulate_fc(&a, &x, &w, batch, n_in, m_out);
            let gold = matmul_golden(&x, &w, batch, n_in, m_out);
            for (s, g) in sim.out.iter().zip(&gold) {
                assert!((s - g).abs() <= 1e-4 * g.abs().max(1.0), "{s} vs {g}");
            }
        }
    }

    #[test]
    fn weight_loads_match_eq8() {
        let a = ArrayConfig::paper_42x42();
        let mut rng = Rng::seed_from_u64(2);
        for (n_in, m_out) in [(4096u64, 4096u64), (25088, 4096), (100, 10), (42, 42), (43, 43)] {
            let x = rand_vec(&mut rng, n_in as usize);
            let w = rand_vec(&mut rng, (n_in * m_out) as usize);
            let sim = simulate_fc(&a, &x, &w, 1, n_in as usize, m_out as usize);
            assert_eq!(
                sim.weight_loads,
                eq8_steps(&a, n_in, m_out),
                "n={n_in} m={m_out}"
            );
        }
    }

    #[test]
    fn fig5b_example_four_tiles() {
        // Fig. 5b: a 4×4 matrix on a 2×2 array → four 2×2 sub-matrices.
        let a = ArrayConfig {
            w_a: 2,
            h_a: 2,
            p_s: 1,
            ..ArrayConfig::paper_42x42()
        };
        assert_eq!(eq8_steps(&a, 4, 4), 4);
        let mut rng = Rng::seed_from_u64(3);
        let x = rand_vec(&mut rng, 4);
        let w = rand_vec(&mut rng, 16);
        let sim = simulate_fc(&a, &x, &w, 1, 4, 4);
        assert_eq!(sim.weight_loads, 4);
        let gold = matmul_golden(&x, &w, 1, 4, 4);
        for (s, g) in sim.out.iter().zip(&gold) {
            assert!((s - g).abs() < 1e-5);
        }
    }

    #[test]
    fn mac_count_is_exact() {
        let a = ArrayConfig::paper_42x42();
        let x = vec![1.0; 2 * 100];
        let w = vec![1.0; 100 * 30];
        let sim = simulate_fc(&a, &x, &w, 2, 100, 30);
        assert_eq!(sim.macs, 2 * 100 * 30);
        // All-ones: every output is n_in.
        assert!(sim.out.iter().all(|v| (*v - 100.0).abs() < 1e-3));
    }
}
