//! The runtime-reconfigurable core (paper §III.A, Fig. 3) and the array
//! configuration with its post-layout timing (Table II).
//!
//! A PE block is three MACs (BFloat16 multiplier + FP32 adder each) and four
//! multiplexers. `Mode = 0` disconnects the MACs into a systolic-array
//! column; `Mode = 1` chains them into a 3-wide convolution dot-product
//! block producing one partial sum per issue.


/// Operating mode of the reconfigurable core (the Mux control of Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreMode {
    /// Systolic array: MACs independent, outputs collected downward (FC).
    Systolic,
    /// Convolution: 3 MACs fused into one dot-product PE (Conv).
    Convolution,
}

/// One PE block: functional model of Fig. 3 used by tests and the
/// golden-model checks of the coordinator.
#[derive(Debug, Clone, Copy, Default)]
pub struct PeBlock {
    /// Partial-sum register (FP32 accumulate).
    pub psum: f32,
}

impl PeBlock {
    /// Convolution mode (Fig. 3c): three parallel products, tree-added with
    /// the previous partial sum — adder3 (m2+m3), adder1 (m1+psum_in),
    /// adder2 (sum) → PE_OUT.
    pub fn conv_step(&mut self, ifmap: [f32; 3], weight: [f32; 3], psum_in: f32) -> f32 {
        let m1 = ifmap[0] * weight[0];
        let m2 = ifmap[1] * weight[1];
        let m3 = ifmap[2] * weight[2];
        let adder3 = m3 + m2;
        let adder1 = m1 + psum_in;
        let out = adder3 + adder1;
        self.psum = out;
        out
    }

    /// Systolic mode (Fig. 3b): each MAC is independent — one
    /// multiply-accumulate per MAC; partial sums move downward (returned).
    pub fn systolic_step(&mut self, a: [f32; 3], w: [f32; 3], psum_in: [f32; 3]) -> [f32; 3] {
        [a[0] * w[0] + psum_in[0], a[1] * w[1] + psum_in[1], a[2] * w[2] + psum_in[2]]
    }
}

/// Accelerator-array configuration (Table I symbols + Table II timing).
#[derive(Debug, Clone, Copy)]
pub struct ArrayConfig {
    /// Accelerator array width in PE blocks (W_A).
    pub w_a: u64,
    /// Accelerator array height in PE blocks (H_A).
    pub h_a: u64,
    /// PE internal size P_s (MACs per PE block = elements per dot product).
    pub p_s: u64,
    /// Clock frequency (Hz). Table II: 1 GHz post-layout at 14nm.
    pub clk_hz: f64,
    /// Clock cycles per step in convolution mode (Table II: 17).
    pub cyc_per_step_conv: u64,
    /// Clock cycles per step in systolic mode (Table II: 11).
    pub cyc_per_step_systolic: u64,
    /// Time charged for MaxPool + ReLU between layers (s). Short vs T1/T2.
    pub t_pool_relu: f64,
}

impl ArrayConfig {
    /// The paper's evaluated configuration: 42×42 MACs, BF16 hardware,
    /// Table II cycle counts. The 42×42 figure counts *MACs*: with P_s = 3
    /// this is a 14×42 grid of PE blocks.
    pub fn paper_42x42() -> Self {
        Self {
            w_a: 14, // 14 PE blocks × 3 MACs = 42 MAC columns
            h_a: 42,
            p_s: 3,
            clk_hz: 1.0e9,
            cyc_per_step_conv: 17,
            cyc_per_step_systolic: 11,
            t_pool_relu: 10.0e-6,
        }
    }

    /// A square array of `macs`×`macs` MACs at P_s = 3 (Fig. 14a sweep).
    pub fn with_mac_array(macs: u64) -> Self {
        let p = Self::paper_42x42();
        Self { w_a: (macs / p.p_s).max(1), h_a: macs, ..p }
    }

    pub fn t_clk(&self) -> f64 {
        1.0 / self.clk_hz
    }

    /// Total PE blocks in the array (W_A · H_A).
    pub fn total_pes(&self) -> u64 {
        self.w_a * self.h_a
    }

    /// Total MACs (= systolic capacity H_A · W_SA with W_SA = P_s · W_A).
    pub fn total_macs(&self) -> u64 {
        self.total_pes() * self.p_s
    }

    /// Systolic array width in MACs, W_SA = P_s · W_A.
    pub fn w_sa(&self) -> u64 {
        self.p_s * self.w_a
    }

    /// Peak MAC throughput (MACs/s) in the given mode: one dot-product
    /// element per MAC per step.
    pub fn peak_macs_per_s(&self, mode: CoreMode) -> f64 {
        let cyc = match mode {
            CoreMode::Systolic => self.cyc_per_step_systolic,
            CoreMode::Convolution => self.cyc_per_step_conv,
        };
        self.total_macs() as f64 * self.clk_hz / cyc as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_step_computes_3wide_dot_plus_psum() {
        let mut pe = PeBlock::default();
        let out = pe.conv_step([1.0, 2.0, 3.0], [4.0, 5.0, 6.0], 10.0);
        assert_eq!(out, 1.0 * 4.0 + 2.0 * 5.0 + 3.0 * 6.0 + 10.0);
        assert_eq!(pe.psum, out);
    }

    #[test]
    fn systolic_step_macs_are_independent() {
        let mut pe = PeBlock::default();
        let out = pe.systolic_step([1.0, 2.0, 3.0], [4.0, 5.0, 6.0], [1.0, 1.0, 1.0]);
        assert_eq!(out, [5.0, 11.0, 19.0]);
    }

    #[test]
    fn paper_array_has_42x42_macs() {
        let a = ArrayConfig::paper_42x42();
        assert_eq!(a.total_macs(), 42 * 42);
        assert_eq!(a.w_sa(), 42);
        assert!((a.t_clk() - 1e-9).abs() < 1e-18);
    }

    #[test]
    fn mode_throughput_ratio_is_table2() {
        let a = ArrayConfig::paper_42x42();
        let conv = a.peak_macs_per_s(CoreMode::Convolution);
        let sys = a.peak_macs_per_s(CoreMode::Systolic);
        // 17 vs 11 cycles per step.
        assert!((sys / conv - 17.0 / 11.0).abs() < 1e-9);
    }

    #[test]
    fn mac_array_sweep_sizes() {
        for macs in [14u64, 28, 42, 84] {
            let a = ArrayConfig::with_mac_array(macs);
            assert!(a.total_macs() >= macs * macs / 3, "array too small for {macs}");
        }
    }
}
