//! The analytical occupancy/retention-time model of §III.B (Eq. 2–11).
//!
//! Deep-learning execution is layer-wise sequential: layer n's ifmap (= layer
//! n−1's ofmap) must stay in the GLB until layer n finishes reading it, i.e.
//! until ofmap_n is complete. So the required retention between consecutive
//! layers is T_ret = T₁ + T_pool_relu + T₂ (Eq. 7/10/11), with T₁/T₂ the
//! ofmap-generation times of the two layers (Eq. 5/6 for conv, Eq. 8/9 for
//! FC). These retention times — ms to seconds — are what licenses the Δ
//! scaling of §IV.


use super::core::ArrayConfig;
use super::traffic::ModelTraffic;
use crate::memsys::bandwidth::{
    scratchpad_bytes_per_s, stall_from_loads, GlbBandwidth, ServiceLoads,
};
use crate::memsys::Scratchpad;
use crate::models::{ConvLayer, FcLayer, Layer, Model};
use crate::util::ceil_div;

/// Timing of one layer on the array.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub name: String,
    /// Ofmap generation time T (s) — Eq. 5 (conv) or Eq. 8 (FC).
    pub t_gen: f64,
    /// Number of array steps per output channel (Eq. 2), conv only.
    pub steps_per_out_ch: u64,
    pub is_conv: bool,
}

/// Eq. 2: steps per output channel.
/// N = ceil( N_in_ch · k_h · N_ofmp_rw · ceil(k_w / P_s) / (W_A · H_A) ).
pub fn steps_per_out_ch(c: &ConvLayer, a: &ArrayConfig) -> u64 {
    // Grouped/depthwise conv: each output channel only reads in_ch/groups
    // input channels.
    let in_ch_eff = c.in_ch / c.groups;
    let pes_needed = in_ch_eff * c.kh * c.ofmap_h() * ceil_div(c.kw, a.p_s);
    ceil_div(pes_needed, a.total_pes()).max(1)
}

/// Eq. 3: time per step,
/// t = T_clk · N_cyc_per_stp · N_ofmp_cl · N_bat.
pub fn time_per_step(c: &ConvLayer, a: &ArrayConfig, batch: u64) -> f64 {
    a.t_clk() * a.cyc_per_step_conv as f64 * c.ofmap_w() as f64 * batch as f64
}

/// Eq. 5: conv-layer ofmap generation time
/// T₁ = steps_per_out_ch · t_per_step · N_out_chn.
pub fn conv_gen_time(c: &ConvLayer, a: &ArrayConfig, batch: u64) -> f64 {
    steps_per_out_ch(c, a) as f64 * time_per_step(c, a, batch) * c.out_ch as f64
}

/// Eq. 8: FC-layer output generation time
/// T₁ = ceil(m_fc/H_A) · ceil(n_fc/W_SA) · T_clk · N_cyc_per_stp · N_bat.
pub fn fc_gen_time(f: &FcLayer, a: &ArrayConfig, batch: u64) -> f64 {
    ceil_div(f.m_out, a.h_a) as f64
        * ceil_div(f.n_in, a.w_sa()) as f64
        * a.t_clk()
        * a.cyc_per_step_systolic as f64
        * batch as f64
}

/// Generation time for any weighted layer; pools return None.
pub fn layer_gen_time(l: &Layer, a: &ArrayConfig, batch: u64) -> Option<LayerTiming> {
    match l {
        Layer::Conv(c) => Some(LayerTiming {
            name: c.name.clone(),
            t_gen: conv_gen_time(c, a, batch),
            steps_per_out_ch: steps_per_out_ch(c, a),
            is_conv: true,
        }),
        Layer::Fc(f) => Some(LayerTiming {
            name: f.name.clone(),
            t_gen: fc_gen_time(f, a, batch),
            steps_per_out_ch: 0,
            is_conv: false,
        }),
        Layer::Pool(_) => None,
    }
}

/// One consecutive-layer retention requirement.
#[derive(Debug, Clone)]
pub struct RetentionPair {
    pub producer: String,
    pub consumer: String,
    /// Eq. 7 / 10 / 11.
    pub t_ret: f64,
    /// Whether a pool/ReLU stage sits between (charges T_pool_relu).
    pub pooled: bool,
}

/// Retention analysis of a full model on a given array.
#[derive(Debug, Clone)]
pub struct ModelRetention {
    pub model: String,
    pub pairs: Vec<RetentionPair>,
}

impl ModelRetention {
    pub fn max_t_ret(&self) -> f64 {
        self.pairs.iter().map(|p| p.t_ret).fold(0.0, f64::max)
    }
    pub fn min_t_ret(&self) -> f64 {
        self.pairs.iter().map(|p| p.t_ret).fold(f64::INFINITY, f64::min)
    }
}

/// Count the Mode-signal reconfigurations a model forces on the core
/// (Fig. 3's Mux toggle): one per Conv↔FC boundary in execution order.
pub fn mode_switches(m: &Model) -> u64 {
    let mut switches = 0;
    let mut last_conv: Option<bool> = None;
    for l in &m.layers {
        let is_conv = match l {
            Layer::Conv(_) => true,
            Layer::Fc(_) => false,
            Layer::Pool(_) => continue,
        };
        if let Some(prev) = last_conv {
            if prev != is_conv {
                switches += 1;
            }
        }
        last_conv = Some(is_conv);
    }
    switches
}

/// The analysis engine.
pub struct RetentionAnalysis<'a> {
    pub array: &'a ArrayConfig,
    pub batch: u64,
}

impl<'a> RetentionAnalysis<'a> {
    pub fn new(array: &'a ArrayConfig, batch: u64) -> Self {
        Self { array, batch }
    }

    /// Per-layer generation times (weighted layers only, in order).
    pub fn layer_timings(&self, m: &Model) -> Vec<LayerTiming> {
        m.layers.iter().filter_map(|l| layer_gen_time(l, self.array, self.batch)).collect()
    }

    /// All consecutive-layer retention pairs (Eq. 7, 10, 11).
    pub fn analyze(&self, m: &Model) -> ModelRetention {
        let mut pairs = Vec::new();
        let mut prev: Option<(LayerTiming, bool)> = None; // (timing, pool seen since)
        for l in &m.layers {
            match l {
                Layer::Pool(_) => {
                    if let Some((_, pooled)) = prev.as_mut() {
                        *pooled = true;
                    }
                }
                _ => {
                    if let Some(t) = layer_gen_time(l, self.array, self.batch) {
                        if let Some((p, pooled)) = prev.take() {
                            let t_ret = p.t_gen
                                + if pooled { self.array.t_pool_relu } else { 0.0 }
                                + t.t_gen;
                            pairs.push(RetentionPair {
                                producer: p.name.clone(),
                                consumer: t.name.clone(),
                                t_ret,
                                pooled,
                            });
                        }
                        prev = Some((t, false));
                    }
                }
            }
        }
        ModelRetention { model: m.name.clone(), pairs }
    }

    /// End-to-end inference time: Σ layer generation times + pool stages.
    pub fn inference_latency(&self, m: &Model) -> f64 {
        let mut t = 0.0;
        for l in &m.layers {
            match l {
                Layer::Pool(_) => t += self.array.t_pool_relu,
                _ => t += layer_gen_time(l, self.array, self.batch).map_or(0.0, |x| x.t_gen),
            }
        }
        t
    }

    /// Flatten the branchy per-layer walk ONCE into a [`StallPlan`]: the
    /// compute walk total, the scratchpad service rate, and one pre-routed
    /// [`ServiceLoads`] + generation time per conv layer. Evaluating the
    /// plan at a [`GlbBandwidth`] is then a branch-light loop over plain
    /// arrays ([`StallPlan::stalled_latency`]) — the hot shape for candidate
    /// grids that revisit the same (model, array, batch, traffic) under many
    /// GLB organizations. `traffic` must be the walk of the same model on
    /// the same array/batch.
    pub fn stall_plan(
        &self,
        m: &Model,
        traffic: &ModelTraffic,
        scratchpad: Option<&Scratchpad>,
    ) -> StallPlan {
        let conv_loads = traffic.routed_loads(scratchpad);
        let sp_bytes_per_s = scratchpad.map_or(f64::INFINITY, scratchpad_bytes_per_s);
        let mut compute = 0.0;
        let mut conv_t_gen = Vec::with_capacity(conv_loads.len());
        let mut conv = traffic.layers.iter();
        for l in &m.layers {
            match l {
                Layer::Pool(_) => compute += self.array.t_pool_relu,
                _ => {
                    if let Some(t) = layer_gen_time(l, self.array, self.batch) {
                        compute += t.t_gen;
                        if t.is_conv {
                            let lt = conv.next().expect("traffic walk covers every conv layer");
                            debug_assert_eq!(lt.name, t.name, "traffic/timing walks must align");
                            conv_t_gen.push(t.t_gen);
                        }
                    }
                }
            }
        }
        StallPlan { compute_s: compute, sp_bytes_per_s, conv_loads, conv_t_gen }
    }

    /// End-to-end inference time under a finite GLB write/read bandwidth:
    /// the Eq. 5/8 compute walk plus, per conv layer, the buffer service
    /// time the layer's generation time cannot hide
    /// ([`crate::memsys::bandwidth::layer_stall`]). FC layers stream their
    /// weights from the NVM (§V.A scope) and pool stages are compute-only,
    /// so neither stalls on the GLB. With [`GlbBandwidth::unconstrained`]
    /// and no scratchpad this reproduces [`Self::inference_latency`]
    /// exactly (zero-stall parity). `traffic` must be the walk of the same
    /// model on the same array/batch. One-shot composition of
    /// [`Self::stall_plan`] + [`StallPlan::stalled_latency`].
    pub fn inference_latency_stalled(
        &self,
        m: &Model,
        traffic: &ModelTraffic,
        glb: &GlbBandwidth,
        scratchpad: Option<&Scratchpad>,
    ) -> StalledLatency {
        self.stall_plan(m, traffic, scratchpad).stalled_latency(glb)
    }
}

/// The pre-flattened stalled-latency walk of one (model, array, batch,
/// traffic, scratchpad) coordinate: everything the per-candidate loop needs
/// except the GLB service rates. Built once by
/// [`RetentionAnalysis::stall_plan`], evaluated per candidate by
/// [`Self::stalled_latency`] — the selection grid shares one plan across
/// every (variant, Δ, BER) that only changes the GLB bandwidth.
#[derive(Debug, Clone, PartialEq)]
pub struct StallPlan {
    /// Total compute walk (s) — identical arithmetic to
    /// [`RetentionAnalysis::inference_latency`].
    pub compute_s: f64,
    /// Scratchpad service rate (`f64::INFINITY` without a scratchpad).
    pub sp_bytes_per_s: f64,
    /// Pre-routed buffer loads, one per conv layer in walk order.
    pub conv_loads: Vec<ServiceLoads>,
    /// Matching ofmap generation times (s).
    pub conv_t_gen: Vec<f64>,
}

impl StallPlan {
    /// Evaluate the plan at one GLB organization's service rates: the
    /// branch-light inner loop ([`stall_from_loads`] over the flat arrays),
    /// accumulating per-layer stalls in the same order as the one-shot walk
    /// (bit-identical totals).
    pub fn stalled_latency(&self, glb: &GlbBandwidth) -> StalledLatency {
        let mut stall = 0.0;
        for (loads, t_gen) in self.conv_loads.iter().zip(&self.conv_t_gen) {
            stall += stall_from_loads(glb, self.sp_bytes_per_s, loads, *t_gen);
        }
        StalledLatency { compute_s: self.compute_s, stall_s: stall }
    }
}

/// End-to-end latency decomposition under the write-bandwidth stall model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StalledLatency {
    /// Pure compute walk — identical arithmetic to
    /// [`RetentionAnalysis::inference_latency`].
    pub compute_s: f64,
    /// Σ per-layer buffer service the compute walk could not hide.
    pub stall_s: f64,
}

impl StalledLatency {
    /// Total inference latency (compute + stall).
    pub fn total(&self) -> f64 {
        self.compute_s + self.stall_s
    }

    /// Stall share of the total latency (0 when everything hides).
    pub fn stall_fraction(&self) -> f64 {
        if self.total() > 0.0 {
            self.stall_s / self.total()
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{self, DType};

    fn paper_array() -> ArrayConfig {
        ArrayConfig::paper_42x42()
    }

    fn small_conv() -> ConvLayer {
        // Fig. 4's worked example: 3×3 kernel over 5×5 ifmap, stride 1.
        ConvLayer {
            name: "fig4".into(),
            in_ch: 1,
            out_ch: 1,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 0,
            groups: 1,
            in_h: 5,
            in_w: 5,
        }
    }

    #[test]
    fn fig4_needs_9_pe_blocks_one_step() {
        let c = small_conv();
        let a = paper_array();
        // N_ofmp_rw · k_h · ceil(k_w/P_s) = 3·3·1 = 9 PEs → 1 step on 588 PEs.
        assert_eq!(steps_per_out_ch(&c, &a), 1);
    }

    #[test]
    fn eq3_time_per_step() {
        let c = small_conv();
        let a = paper_array();
        // T_clk=1ns, 17 cyc, N_ofmp_cl=3, batch=2 → 102 ns.
        let t = time_per_step(&c, &a, 2);
        assert!((t - 102e-9).abs() < 1e-15);
    }

    #[test]
    fn conv_time_scales_with_out_channels_and_batch() {
        let a = paper_array();
        let mut c = small_conv();
        let t1 = conv_gen_time(&c, &a, 1);
        c.out_ch = 4;
        assert!((conv_gen_time(&c, &a, 1) / t1 - 4.0).abs() < 1e-9);
        assert!((conv_gen_time(&c, &a, 4) / conv_gen_time(&c, &a, 1) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn fc_time_eq8() {
        let a = paper_array();
        let f = FcLayer { name: "fc".into(), n_in: 4096, m_out: 4096 };
        // ceil(4096/42)=98 steps each way; 11 cycles; batch 16.
        let want = 98.0 * 98.0 * 1e-9 * 11.0 * 16.0;
        assert!((fc_gen_time(&f, &a, 16) - want).abs() / want < 1e-12);
    }

    #[test]
    fn fig13_retention_under_1p5s_for_zoo() {
        // Paper: max GLB retention < 1.5 s across all models at 42×42 MACs,
        // batch 16, bf16 timing; most models < 0.5 s.
        let a = paper_array();
        let ra = RetentionAnalysis::new(&a, 16);
        let mut under_half = 0;
        let zoo = models::zoo();
        for m in &zoo {
            let r = ra.analyze(m);
            let max = r.max_t_ret();
            assert!(max < 1.6, "{}: max retention {max} s", m.name);
            if max < 0.5 {
                under_half += 1;
            }
        }
        assert!(under_half * 2 > zoo.len(), "most models should be < 0.5 s, got {under_half}");
    }

    #[test]
    fn fig14a_retention_decreases_with_array_size() {
        let m = models::by_name("ResNet50").unwrap();
        let mut last = f64::INFINITY;
        for macs in [14u64, 28, 42, 84] {
            let a = ArrayConfig::with_mac_array(macs);
            let r = RetentionAnalysis::new(&a, 16).analyze(&m);
            assert!(r.max_t_ret() <= last, "retention must shrink as array grows");
            last = r.max_t_ret();
        }
    }

    #[test]
    fn fig14b_retention_grows_with_batch() {
        let m = models::by_name("ResNet50").unwrap();
        let a = paper_array();
        let mut last = 0.0;
        for batch in [1u64, 4, 16, 64] {
            let r = RetentionAnalysis::new(&a, batch).analyze(&m);
            assert!(r.max_t_ret() >= last);
            last = r.max_t_ret();
        }
    }

    #[test]
    fn pairs_cover_consecutive_weighted_layers() {
        let m = models::by_name("AlexNet").unwrap();
        let a = paper_array();
        let r = RetentionAnalysis::new(&a, 1).analyze(&m);
        // AlexNet: 5 convs + 3 fcs = 8 weighted layers → 7 pairs.
        assert_eq!(r.pairs.len(), 7);
        // conv→conv pairs after pools are flagged.
        assert!(r.pairs.iter().any(|p| p.pooled));
        // FC–FC pairs have no pool (Eq. 10).
        let fc_pair = r.pairs.iter().find(|p| p.producer == "fc6").unwrap();
        assert!(!fc_pair.pooled);
    }

    #[test]
    fn mode_switches_counted() {
        // AlexNet: convs then fcs → exactly one reconfiguration.
        assert_eq!(mode_switches(&models::by_name("AlexNet").unwrap()), 1);
        // SqueezeNet: conv-only → none.
        assert_eq!(mode_switches(&models::by_name("SqueezeNet").unwrap()), 0);
    }

    #[test]
    fn conv_fc_pair_uses_eq11() {
        // AlexNet conv5 → fc6 crosses a pool: T_ret = T1 + T_pool_relu + T2.
        let a = paper_array();
        let ra = RetentionAnalysis::new(&a, 1);
        let m = models::by_name("AlexNet").unwrap();
        let r = ra.analyze(&m);
        let pair = r.pairs.iter().find(|p| p.consumer == "fc6").unwrap();
        assert!(pair.pooled, "pool5 sits between conv5 and fc6");
        let t1 = conv_gen_time(
            m.conv_layers().find(|c| c.name == "conv5").unwrap(), &a, 1);
        let t2 = fc_gen_time(m.fc_layers().next().unwrap(), &a, 1);
        assert!((pair.t_ret - (t1 + a.t_pool_relu + t2)).abs() < 1e-12);
    }

    #[test]
    fn stalled_latency_parity_and_write_sensitivity() {
        use crate::memsys::{GlbBandwidth, GlbKind, Scratchpad};
        use crate::util::units::MB;
        let a = paper_array();
        let m = models::by_name("ResNet50").unwrap();
        let ra = RetentionAnalysis::new(&a, 16);
        let traffic = ModelTraffic::analyze(&m, &a, DType::Bf16, 16, 12 * MB);

        // Zero-stall parity: infinite bandwidth reproduces the compute walk
        // exactly, bit for bit.
        let free = ra.inference_latency_stalled(&m, &traffic, &GlbBandwidth::unconstrained(), None);
        assert_eq!(free.stall_s, 0.0);
        assert_eq!(free.total(), ra.inference_latency(&m));
        assert_eq!(free.stall_fraction(), 0.0);

        // A finite MRAM GLB can only add latency, never remove it.
        let bw = GlbBandwidth::of(&GlbKind::stt_ai(), 1.0e-8, 1.0e-5);
        let sp = Scratchpad::paper_bf16();
        let stalled = ra.inference_latency_stalled(&m, &traffic, &bw, Some(&sp));
        assert_eq!(stalled.compute_s, free.compute_s, "compute walk is bandwidth-invariant");
        assert!(stalled.stall_s >= 0.0 && stalled.total() >= free.total());

        // Halving the write bandwidth never shortens the stall (latency is
        // non-decreasing in the write pulse).
        let slower = GlbBandwidth {
            write_bytes_per_s: bw.write_bytes_per_s / 2.0,
            read_bytes_per_s: bw.read_bytes_per_s,
        };
        let worse = ra.inference_latency_stalled(&m, &traffic, &slower, Some(&sp));
        assert!(worse.stall_s >= stalled.stall_s);
    }

    #[test]
    fn stall_plan_reproduces_the_one_shot_walk_bit_for_bit() {
        use crate::memsys::{GlbBandwidth, GlbKind, Scratchpad};
        use crate::util::units::MB;
        let a = paper_array();
        let m = models::by_name("ResNet50").unwrap();
        let ra = RetentionAnalysis::new(&a, 16);
        let traffic = ModelTraffic::analyze(&m, &a, DType::Bf16, 16, 12 * MB);
        let sp = Scratchpad::paper_bf16();
        let bandwidths = [
            GlbBandwidth::unconstrained(),
            GlbBandwidth::of(&GlbKind::baseline(), 0.0, 0.0),
            GlbBandwidth::of(&GlbKind::stt_ai(), 1.0e-8, 1.0e-5),
            GlbBandwidth::of(&GlbKind::stt_ai_ultra(), 1.0e-8, 1.0e-5),
        ];
        for scratchpad in [None, Some(&sp)] {
            // One flattening, many GLB organizations — the grid's hot shape.
            let plan = ra.stall_plan(&m, &traffic, scratchpad);
            assert_eq!(plan.conv_loads.len(), plan.conv_t_gen.len());
            assert_eq!(plan.compute_s, ra.inference_latency(&m));
            for bw in &bandwidths {
                let fast = plan.stalled_latency(bw);
                let slow = ra.inference_latency_stalled(&m, &traffic, bw, scratchpad);
                assert_eq!(fast, slow, "plan and one-shot walk must agree exactly");
            }
        }
    }

    #[test]
    fn inference_latency_positive_and_ordered() {
        let a = paper_array();
        let ra = RetentionAnalysis::new(&a, 1);
        let small = ra.inference_latency(&models::by_name("SqueezeNet").unwrap());
        let big = ra.inference_latency(&models::by_name("VGG16").unwrap());
        assert!(small > 0.0 && big > small, "small={small} big={big}");
        // Sanity: per-image VGG16 latency on 1764 MACs at 1 GHz should be
        // tens-to-hundreds of ms class given 15.5 GMACs and 17-cycle steps.
        let _ = models::by_name("VGG16").unwrap().size_bytes(DType::Bf16);
        assert!(big > 1e-3 && big < 10.0, "big={big}");
    }
}
