//! # STT-AI: AI accelerator + customized STT-MRAM co-design framework
//!
//! Reproduction of *"Designing Efficient and High-performance AI Accelerators
//! with Customized STT-MRAM"* (Mishty & Sadi, 2021) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The crate is organized bottom-up:
//!
//! * [`mram`] — device physics and the pluggable memory-technology layer:
//!   STT-MRAM / MTJ equations (thermal stability factor Δ, critical current,
//!   retention failure, read disturb, write error rate, process/temperature
//!   guard-banding, the PTM-driven write driver), abstracted behind the
//!   [`mram::technology::MemTechnology`] trait with STT-MRAM, SOT-MRAM and
//!   SRAM implementations in a [`mram::TechnologyId`] registry.
//! * [`memsys`] — memory *system* models: technology-parametrized array
//!   area/energy (Destiny-like, over the `MemTechnology` registry), DDR4
//!   DRAM channel model, the scratchpad-assisted global buffer, and the
//!   full on-chip hierarchy composed from per-technology bank specs.
//! * [`models`] — a zoo of 19 real DNN architectures as per-layer shape
//!   tables (the design-space-exploration workload of the paper's §V.A).
//! * [`accel`] — the reconfigurable-core accelerator: PE/core cycle model
//!   (Table II), row-stationary conv + systolic FC mapping, the analytical
//!   occupancy/retention-time model (Eq. 2–11), and GLB traffic accounting.
//! * [`dse`] — design-space exploration: per-figure analyses (Figs. 10–19)
//!   plus [`dse::engine`], the unified parallel sweep subsystem (declarative
//!   `SweepSpec` cross-products over model × dtype × batch × GLB ×
//!   technology × Δ/BER × write-intensity axes, evaluated on the
//!   [`util::pool`] work-stealing pool into serializable `SweepResult`
//!   records), [`dse::cache`], the cross-sweep memoization of the
//!   per-layer traffic/retention model walks, and [`dse::select`], the
//!   objective/constraint selection layer (Pareto frontier, iso-accuracy
//!   and retention-coverage constraints) that derives each deployment's
//!   design point from the sweep records and hands it to the coordinator.
//! * [`ber`] — bit-error-rate fault injection on bf16/int8 buffers with the
//!   MSB/LSB two-bank split of the STT-AI Ultra design, plus magnitude
//!   pruning (Fig. 21).
//! * [`runtime`] — PJRT client wrapper: load AOT HLO-text artifacts, compile,
//!   execute (Python is never on this path).
//! * [`coordinator`] — the L3 serving loop: request queue, dynamic batcher,
//!   router, inference engine, metrics; boots from either a paper config or
//!   a sweep-selected design point ([`dse::select::DesignSelection`]).
//!   Includes the deterministic fault-injection harness
//!   ([`coordinator::faults`]) and the graceful-degradation supervisor
//!   ([`coordinator::supervisor`]): seeded fault schedules replayed on a
//!   virtual [`util::clock::Clock`] against a multi-engine fleet whose
//!   health states (Healthy → Degraded → Down → fallback reboot) are driven
//!   by canary probes, with byte-identical availability reports at any
//!   worker count.
//! * [`report`] — figure/table renderers over the unified sweep records
//!   (`report::legacy` keeps the frozen pre-refactor serial renderers as the
//!   golden parity reference), plus CSV/JSON export.
//! * [`config`] — typed configuration (accelerator, memory, the `[tech.*]`
//!   technology section) with JSON load/save, used by the CLI and launcher.

pub mod accel;
pub mod ber;
pub mod config;
pub mod coordinator;
pub mod dse;
pub mod memsys;
pub mod models;
pub mod mram;
pub mod report;
pub mod runtime;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
