//! The MSB/LSB two-bank bit-group split of STT-AI Ultra (§IV, bullet 4).
//!
//! "The first half of the weight/fmap bits are considered significant (MSB
//! group) and stored in the Δ_PT_GB = 27.5 bank, and the rest of the LSB
//! groups in the Δ_PT_GB = 17.5 bank." For bf16 (1s + 8e + 7m) the MSB group
//! is the upper byte (sign + exponent), for int8 the upper nibble.

use crate::ber::injector::{BitFlipStats, Injector};

/// Word layout for the bank split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WordKind {
    /// 16-bit bfloat16: upper byte = MSB group, lower byte = LSB group.
    Bf16,
    /// 8-bit integer: upper nibble = MSB group, lower nibble = LSB group.
    Int8,
}

impl WordKind {
    pub fn bytes(&self) -> usize {
        match self {
            WordKind::Bf16 => 2,
            WordKind::Int8 => 1,
        }
    }
}

/// Two-bank fault model: independent BERs for the MSB and LSB bit groups.
#[derive(Debug, Clone, Copy)]
pub struct BankSplit {
    pub kind: WordKind,
    pub msb_ber: f64,
    pub lsb_ber: f64,
}

impl BankSplit {
    /// STT-AI (single robust bank): both groups at `ber`.
    pub fn uniform(kind: WordKind, ber: f64) -> Self {
        Self { kind, msb_ber: ber, lsb_ber: ber }
    }

    /// STT-AI Ultra: MSB 1e-8, LSB 1e-5.
    pub fn ultra(kind: WordKind) -> Self {
        Self { kind, msb_ber: 1e-8, lsb_ber: 1e-5 }
    }

    /// Inject into a little-endian buffer of words of `self.kind`.
    pub fn inject(&self, inj: &mut Injector, buf: &mut [u8]) -> BitFlipStats {
        let (msb, lsb) = self.inject_split(inj, buf);
        msb.merge(lsb)
    }

    /// [`BankSplit::inject`] with per-bank stats: `(msb, lsb)` flip counts.
    /// The supervisor's canary probes key on the split — a single MSB-group
    /// flip is catastrophic while LSB flips are budgeted
    /// ([`crate::coordinator::supervisor`]).
    pub fn inject_split(&self, inj: &mut Injector, buf: &mut [u8]) -> (BitFlipStats, BitFlipStats) {
        match self.kind {
            WordKind::Int8 => {
                let hi = inj.flip_masked(buf, self.msb_ber, 0xF0);
                let lo = inj.flip_masked(buf, self.lsb_ber, 0x0F);
                (hi, lo)
            }
            WordKind::Bf16 => {
                assert_eq!(buf.len() % 2, 0, "bf16 buffer must be even-length");
                // Little-endian: byte 0 of each pair is the mantissa-LSB
                // byte (LSB group), byte 1 is sign+exponent (MSB group).
                // Strided geometric walks flip each sub-stream in place.
                let lo = inj.flip_strided(buf, self.lsb_ber, 0, 2);
                let hi = inj.flip_strided(buf, self.msb_ber, 1, 2);
                (hi, lo)
            }
        }
    }

    /// Expected flips for a buffer of `n_bytes`.
    pub fn expected_flips(&self, n_bytes: usize) -> f64 {
        let half_bits = (n_bytes * 8 / 2) as f64;
        half_bits * (self.msb_ber + self.lsb_ber)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ultra_flips_concentrate_in_lsb_group() {
        // With MSB 1e-8 vs LSB 1e-2-scaled test rates, flips land low.
        let split = BankSplit { kind: WordKind::Bf16, msb_ber: 0.0, lsb_ber: 0.05 };
        let mut buf = vec![0u8; 1 << 16];
        let mut inj = Injector::new(11);
        let s = split.inject(&mut inj, &mut buf);
        assert!(s.bits_flipped > 0);
        // All flips in even (LSB) bytes.
        assert!(buf.iter().skip(1).step_by(2).all(|&b| b == 0));
        assert!(buf.iter().step_by(2).any(|&b| b != 0));
    }

    #[test]
    fn int8_nibble_split() {
        let split = BankSplit { kind: WordKind::Int8, msb_ber: 0.0, lsb_ber: 0.1 };
        let mut buf = vec![0u8; 4096];
        let mut inj = Injector::new(13);
        split.inject(&mut inj, &mut buf);
        assert!(buf.iter().all(|&b| b & 0xF0 == 0));
    }

    #[test]
    fn inject_split_reports_per_bank_and_sums_to_inject() {
        // The split stats attribute every flip to its bank, and merging
        // them reproduces the aggregate `inject` contract (same seed, same
        // buffer -> identical flips).
        let split = BankSplit { kind: WordKind::Bf16, msb_ber: 1e-3, lsb_ber: 1e-2 };
        let mut a = vec![0u8; 1 << 16];
        let mut b = a.clone();
        let total = split.inject(&mut Injector::new(17), &mut a);
        let (msb, lsb) = split.inject_split(&mut Injector::new(17), &mut b);
        assert_eq!(a, b, "same seed, same flips");
        assert_eq!(msb.merge(lsb), total);
        assert_eq!(msb.bits_scanned, (b.len() / 2 * 8) as u64);
        assert_eq!(lsb.bits_scanned, (b.len() / 2 * 8) as u64);
        assert!(lsb.bits_flipped > msb.bits_flipped, "LSB bank is 10x leakier");
        // A one-sided split attributes everything to one bank.
        let lsb_only = BankSplit { kind: WordKind::Int8, msb_ber: 0.0, lsb_ber: 0.1 };
        let mut c = vec![0u8; 4096];
        let (m, l) = lsb_only.inject_split(&mut Injector::new(19), &mut c);
        assert_eq!(m.bits_flipped, 0);
        assert!(l.bits_flipped > 0);
    }

    #[test]
    fn uniform_matches_paper_stt_ai() {
        let s = BankSplit::uniform(WordKind::Bf16, 1e-8);
        assert_eq!(s.msb_ber, s.lsb_ber);
        let u = BankSplit::ultra(WordKind::Bf16);
        assert!(u.lsb_ber > u.msb_ber);
    }

    #[test]
    fn expected_flip_scale_of_fig21() {
        // 12 MB buffer at Ultra settings: LSB half at 1e-5 dominates.
        let u = BankSplit::ultra(WordKind::Bf16);
        let e = u.expected_flips(12 << 20);
        // half bits = 50.3e6; ×(1e-5 + 1e-8) ≈ 503 flips.
        assert!(e > 400.0 && e < 600.0, "{e}");
    }

    #[test]
    fn bf16_value_perturbation_small_for_lsb_flips() {
        // Flipping a mantissa (LSB-group) bit perturbs a bf16 value by at
        // most 2^-1 of its exponent bucket (≤ ~33% relative) and usually far
        // less — while an exponent (MSB-group) flip rescales the value by
        // ~2^±64. That asymmetry is the mechanism behind Fig. 21.
        use crate::util::bf16::{bf16_to_f32, f32_to_bf16};
        let bits = f32_to_bf16(1.5f32);
        for bit in 0..7 {
            let y = bf16_to_f32(bits ^ (1 << bit));
            let rel = ((y - 1.5) / 1.5).abs();
            assert!(rel <= 0.34, "bit {bit}: rel={rel}");
        }
        // While an exponent-bit (MSB group) flip is catastrophic — clearing
        // a high exponent bit rescales 1.5 by 2^-64 (rel err ≈ 1), and
        // setting the top exponent bit produces NaN/Inf. That is why the MSB
        // group gets the robust bank.
        let y = bf16_to_f32(bits ^ (1 << 13));
        assert!(((y - 1.5) / 1.5).abs() > 0.9, "y={y}");
        assert!(bf16_to_f32(bits ^ (1 << 14)).is_nan());
    }
}
