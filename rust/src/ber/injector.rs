//! Fast Bernoulli bit flipping over byte buffers.
//!
//! Naive per-bit sampling is O(bits) regardless of BER; at BER 1e-8 that
//! wastes ~1e8 RNG draws per flip. We instead draw the *gap* between flips
//! from the geometric distribution (inverse-CDF: gap = ⌊ln U / ln(1−p)⌋) and
//! jump straight to the next flipped bit — O(flips), >GB/s on the request
//! path.

use crate::util::rng::Rng;

/// Statistics from one injection pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BitFlipStats {
    pub bits_scanned: u64,
    pub bits_flipped: u64,
}

impl BitFlipStats {
    /// Fold another pass into this one (per-bank stats → buffer totals).
    pub fn merge(self, other: BitFlipStats) -> BitFlipStats {
        BitFlipStats {
            bits_scanned: self.bits_scanned + other.bits_scanned,
            bits_flipped: self.bits_flipped + other.bits_flipped,
        }
    }
}

/// Seeded bit-flip injector.
pub struct Injector {
    rng: Rng,
}

impl Injector {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::seed_from_u64(seed) }
    }

    /// Flip each bit of `buf` independently with probability `ber`.
    pub fn flip(&mut self, buf: &mut [u8], ber: f64) -> BitFlipStats {
        self.flip_masked(buf, ber, 0xFF)
    }

    /// Flip bits with probability `ber`, but only bit positions where
    /// `byte_mask` has a 1 (the mask repeats per byte). Used for the
    /// MSB/LSB bank split: e.g. mask 0x00FF of a bf16 word = the LSB bank.
    pub fn flip_masked(&mut self, buf: &mut [u8], ber: f64, byte_mask: u8) -> BitFlipStats {
        let eligible_per_byte = byte_mask.count_ones() as u64;
        let total_bits = buf.len() as u64 * eligible_per_byte;
        let mut stats = BitFlipStats { bits_scanned: total_bits, bits_flipped: 0 };
        if ber <= 0.0 || total_bits == 0 {
            return stats;
        }
        if ber >= 1.0 {
            for b in buf.iter_mut() {
                *b ^= byte_mask;
            }
            stats.bits_flipped = total_bits;
            return stats;
        }
        // Precompute the eligible bit positions of one byte.
        let positions: Vec<u8> =
            (0..8).filter(|i| byte_mask & (1 << i) != 0).collect();
        let ln1mp = (1.0 - ber).ln();
        // Walk the eligible-bit index space in geometric jumps.
        let mut idx: u64 = self.next_gap(ln1mp);
        while idx < total_bits {
            let byte = (idx / eligible_per_byte) as usize;
            let bit = positions[(idx % eligible_per_byte) as usize];
            buf[byte] ^= 1 << bit;
            stats.bits_flipped += 1;
            idx += 1 + self.next_gap(ln1mp);
        }
        stats
    }

    /// Flip bits with probability `ber` over a strided byte sub-stream:
    /// bytes at `offset, offset+stride, offset+2·stride, ...`, all 8 bits
    /// eligible. Lets the bf16 MSB/LSB bank split run in place on the
    /// interleaved word buffer — no deinterleave copies on the hot path
    /// (§Perf: 11.7x faster than the copy-based split at GLB-class BERs).
    pub fn flip_strided(&mut self, buf: &mut [u8], ber: f64, offset: usize, stride: usize) -> BitFlipStats {
        debug_assert!(stride >= 1);
        let n_bytes = if buf.len() > offset { (buf.len() - offset).div_ceil(stride) } else { 0 };
        let total_bits = n_bytes as u64 * 8;
        let mut stats = BitFlipStats { bits_scanned: total_bits, bits_flipped: 0 };
        if ber <= 0.0 || total_bits == 0 {
            return stats;
        }
        if ber >= 1.0 {
            let mut i = offset;
            while i < buf.len() {
                buf[i] ^= 0xFF;
                i += stride;
            }
            stats.bits_flipped = total_bits;
            return stats;
        }
        let ln1mp = (1.0 - ber).ln();
        let mut idx: u64 = self.next_gap(ln1mp);
        while idx < total_bits {
            let byte = offset + (idx / 8) as usize * stride;
            buf[byte] ^= 1 << (idx % 8);
            stats.bits_flipped += 1;
            idx += 1 + self.next_gap(ln1mp);
        }
        stats
    }

    /// Geometric gap: number of un-flipped bits before the next flip.
    fn next_gap(&mut self, ln1mp: f64) -> u64 {
        // U in (0,1]; gap = floor(ln U / ln(1-p)).
        let u: f64 = 1.0 - self.rng.next_f64();
        let g = u.ln() / ln1mp;
        if g >= u64::MAX as f64 {
            u64::MAX
        } else {
            g as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_ber_is_identity() {
        let mut buf = vec![0xA5u8; 1024];
        let orig = buf.clone();
        let mut inj = Injector::new(1);
        let s = inj.flip(&mut buf, 0.0);
        assert_eq!(buf, orig);
        assert_eq!(s.bits_flipped, 0);
    }

    #[test]
    fn ber_one_flips_everything() {
        let mut buf = vec![0x00u8; 16];
        let mut inj = Injector::new(1);
        let s = inj.flip(&mut buf, 1.0);
        assert!(buf.iter().all(|&b| b == 0xFF));
        assert_eq!(s.bits_flipped, 128);
    }

    #[test]
    fn flip_count_matches_ber_statistically() {
        // 8 Mbit at BER 1e-3 → expect ~8389 flips; allow ±5σ (σ≈√8389≈92).
        let mut buf = vec![0u8; 1 << 20];
        let mut inj = Injector::new(42);
        let s = inj.flip(&mut buf, 1e-3);
        let expect = (buf.len() * 8) as f64 * 1e-3;
        let sigma = expect.sqrt();
        assert!(
            (s.bits_flipped as f64 - expect).abs() < 5.0 * sigma,
            "flips={} expect={expect}",
            s.bits_flipped
        );
        // Every flip actually landed in the buffer.
        let ones: u64 = buf.iter().map(|b| b.count_ones() as u64).sum();
        assert_eq!(ones, s.bits_flipped);
    }

    #[test]
    fn deterministic_under_seed() {
        let mut a = vec![0u8; 4096];
        let mut b = vec![0u8; 4096];
        Injector::new(7).flip(&mut a, 1e-4);
        Injector::new(7).flip(&mut b, 1e-4);
        assert_eq!(a, b);
        let mut c = vec![0u8; 4096];
        Injector::new(8).flip(&mut c, 1e-4);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn mask_restricts_flips_to_selected_bits() {
        let mut buf = vec![0u8; 1 << 16];
        let mut inj = Injector::new(3);
        let s = inj.flip_masked(&mut buf, 1e-2, 0x0F);
        assert!(s.bits_flipped > 0);
        assert!(buf.iter().all(|&b| b & 0xF0 == 0), "flips must stay in the low nibble");
        assert_eq!(s.bits_scanned, buf.len() as u64 * 4);
    }

    #[test]
    fn tiny_ber_on_small_buffer_usually_no_flip() {
        let mut buf = vec![0u8; 1024];
        let mut inj = Injector::new(9);
        let s = inj.flip(&mut buf, 1e-9);
        assert!(s.bits_flipped <= 1);
    }

    #[test]
    fn strided_stays_in_lane() {
        let mut buf = vec![0u8; 1 << 16];
        let mut inj = Injector::new(21);
        let s = inj.flip_strided(&mut buf, 1e-2, 0, 2);
        assert!(s.bits_flipped > 0);
        assert!(buf.iter().skip(1).step_by(2).all(|&b| b == 0), "odd bytes untouched");
        let mut inj = Injector::new(22);
        let s = inj.flip_strided(&mut buf, 1.0, 1, 2);
        assert_eq!(s.bits_flipped, (buf.len() / 2 * 8) as u64);
        assert!(buf.iter().skip(1).step_by(2).all(|&b| b == 0xFF));
    }

    #[test]
    fn strided_matches_contiguous_statistics() {
        // Same BER over the same number of eligible bits → same flip-count
        // distribution; check both land within 5 sigma of the expectation.
        let n = 1 << 20;
        let ber = 1e-3;
        let expect = (n / 2 * 8) as f64 * ber;
        let sigma = expect.sqrt();
        let mut a = vec![0u8; n / 2];
        let fa = Injector::new(5).flip(&mut a, ber).bits_flipped as f64;
        let mut b = vec![0u8; n];
        let fb = Injector::new(6).flip_strided(&mut b, ber, 0, 2).bits_flipped as f64;
        assert!((fa - expect).abs() < 5.0 * sigma, "contiguous {fa} vs {expect}");
        assert!((fb - expect).abs() < 5.0 * sigma, "strided {fb} vs {expect}");
    }

    #[test]
    fn strided_empty_and_short_buffers() {
        let mut inj = Injector::new(9);
        let mut empty: Vec<u8> = vec![];
        assert_eq!(inj.flip_strided(&mut empty, 0.5, 0, 2).bits_scanned, 0);
        let mut one = vec![0u8; 1];
        let s = inj.flip_strided(&mut one, 0.0, 0, 2);
        assert_eq!(s.bits_scanned, 8);
        assert_eq!(s.bits_flipped, 0);
        // Offset beyond the buffer scans nothing.
        let mut two = vec![0u8; 2];
        assert_eq!(inj.flip_strided(&mut two, 0.5, 5, 2).bits_scanned, 0);
    }

    #[test]
    fn double_flip_restores() {
        // Same seed twice XORs the same positions → identity.
        let orig: Vec<u8> = (0..=255).collect();
        let mut buf = orig.clone();
        Injector::new(5).flip(&mut buf, 1e-2);
        Injector::new(5).flip(&mut buf, 1e-2);
        assert_eq!(buf, orig);
    }
}
