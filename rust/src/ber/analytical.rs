//! Analytical BER-impact model for the full 19-model zoo.
//!
//! Fig. 21 measures accuracy on models we can execute; for the rest of the
//! zoo the paper argues from Ares [25]: what matters is the *expected number
//! and severity* of faulty weights. This module computes, per model and GLB
//! variant, the expected bit flips per inference-resident weight image, the
//! expected fraction of corrupted weights, and the expected relative weight
//! perturbation — the quantities that predict "no accuracy change at 1e-8,
//! negligible at 1e-5-on-LSB" across model scales.

use crate::ber::banks::{BankSplit, WordKind};
use crate::models::{DType, Model};

/// Expected per-model fault exposure for one bank-split configuration.
#[derive(Debug, Clone)]
pub struct FaultExposure {
    pub model: String,
    pub weight_bytes: u64,
    /// Expected flipped bits over the weight image per retention window.
    pub expected_flips: f64,
    /// Expected fraction of weights with ≥1 flipped bit.
    pub corrupted_weight_fraction: f64,
    /// Expected fraction of weights with a flipped MSB-group bit (the
    /// catastrophic class: exponent/sign for bf16).
    pub catastrophic_fraction: f64,
    /// Mean |Δw/w| over corrupted weights, mantissa-flip model
    /// (E over uniformly chosen mantissa bit b of 2^(b−7)/2 for bf16).
    pub mean_rel_perturbation: f64,
}

impl FaultExposure {
    pub fn analyze(m: &Model, dt: DType, split: &BankSplit) -> Self {
        let weight_bytes = m.size_bytes(dt);
        let word_bits = (split.kind.bytes() * 8) as f64;
        let words = weight_bytes as f64 / split.kind.bytes() as f64;
        let half = word_bits / 2.0;

        let expected_flips = words * half * (split.msb_ber + split.lsb_ber);
        // P(word corrupted) = 1 − (1−p_m)^(bits/2) (1−p_l)^(bits/2).
        let p_word = 1.0
            - (1.0 - split.msb_ber).powf(half) * (1.0 - split.lsb_ber).powf(half);
        let p_cat = 1.0 - (1.0 - split.msb_ber).powf(half);
        // bf16 LSB group = mantissa bits 0..6 + mantissa msb in byte: flips
        // of mantissa bit b change the value by 2^(b−7) of its exponent
        // bucket; uniform over b=0..7 → mean 2^-7·(2^8−1)/8 ≈ 0.249; halve
        // for expected sign of the perturbation magnitude vs full bucket.
        let mean_rel = match split.kind {
            WordKind::Bf16 => 0.249 * 0.5,
            WordKind::Int8 => {
                // int8 low nibble: mean |Δ| = (1+2+4+8)/4 = 3.75 LSBs of 128.
                3.75 / 128.0
            }
        };
        FaultExposure {
            model: m.name.clone(),
            weight_bytes,
            expected_flips,
            corrupted_weight_fraction: p_word,
            catastrophic_fraction: p_cat,
            mean_rel_perturbation: mean_rel * p_word.min(1.0),
        }
    }

    /// The paper's §V.C worst-case bound style: flips for VGG16 at 1e-9 over
    /// the full weight store ≈ 12 bits.
    pub fn worst_case_flips(weight_bytes: u64, ber: f64) -> f64 {
        weight_bytes as f64 * 8.0 * ber
    }
}

/// Zoo-wide table for one variant.
pub fn zoo_exposure(zoo: &[Model], dt: DType, split: &BankSplit) -> Vec<FaultExposure> {
    zoo.iter().map(|m| FaultExposure::analyze(m, dt, split)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn kind(dt: DType) -> WordKind {
        match dt {
            DType::Bf16 => WordKind::Bf16,
            DType::Int8 => WordKind::Int8,
        }
    }

    #[test]
    fn paper_vgg16_worst_case_bound() {
        // §V.C: "the worst-case bit-flips for VGG16 at [1e-9] is about 12".
        let vgg = models::by_name("VGG16").unwrap();
        let flips = FaultExposure::worst_case_flips(vgg.size_bytes(DType::Bf16), 1e-9 * 3.0);
        // RF+RD+WE ≈ 3 budget classes × 1e-9, bf16 store.
        assert!(flips > 5.0 && flips < 20.0, "{flips}");
    }

    #[test]
    fn stt_ai_exposure_is_negligible() {
        // STT-AI (uniform 1e-8): corrupted-weight fraction < 1e-6 for every
        // model — why Fig. 21 shows exact iso-accuracy.
        let zoo = models::zoo();
        let split = BankSplit::uniform(kind(DType::Bf16), 1e-8);
        for e in zoo_exposure(&zoo, DType::Bf16, &split) {
            assert!(e.corrupted_weight_fraction < 2e-7, "{}: {}", e.model, e.corrupted_weight_fraction);
        }
    }

    #[test]
    fn ultra_catastrophic_class_stays_rare() {
        // Ultra: LSB at 1e-5 corrupts ~8e-5 of weights, but the MSB
        // (catastrophic) class stays at the 1e-8 level — 3 orders rarer.
        let zoo = models::zoo();
        let split = BankSplit::ultra(kind(DType::Bf16));
        for e in zoo_exposure(&zoo, DType::Bf16, &split) {
            assert!(e.corrupted_weight_fraction > 1e-5, "{}", e.model);
            assert!(e.catastrophic_fraction < 1e-6, "{}", e.model);
            assert!(e.catastrophic_fraction < e.corrupted_weight_fraction / 100.0);
        }
    }

    #[test]
    fn perturbation_small_under_ultra() {
        let m = models::by_name("ResNet50").unwrap();
        let e = FaultExposure::analyze(&m, DType::Bf16, &BankSplit::ultra(WordKind::Bf16));
        // Mean relative weight perturbation ≪ 1% — the Ares-style argument
        // for <1% normalized accuracy impact.
        assert!(e.mean_rel_perturbation < 1e-4, "{}", e.mean_rel_perturbation);
    }

    #[test]
    fn expected_flips_scale_with_model_size() {
        let zoo = models::zoo();
        let split = BankSplit::ultra(kind(DType::Bf16));
        let exp = zoo_exposure(&zoo, DType::Bf16, &split);
        let vgg = exp.iter().find(|e| e.model == "VGG16").unwrap();
        let squeeze = exp.iter().find(|e| e.model == "SqueezeNet").unwrap();
        assert!(vgg.expected_flips > 50.0 * squeeze.expected_flips);
    }
}
