//! Magnitude pruning (Fig. 21 evaluates original and 50%-pruned models).

/// Zero the `rate` fraction of smallest-magnitude weights, in place.
/// Returns the number of weights zeroed.
pub fn magnitude_prune_f32(weights: &mut [f32], rate: f64) -> usize {
    assert!((0.0..=1.0).contains(&rate));
    if weights.is_empty() || rate == 0.0 {
        return 0;
    }
    let k = ((weights.len() as f64) * rate).floor() as usize;
    if k == 0 {
        return 0;
    }
    // Threshold = k-th smallest |w| via select_nth on a copy of magnitudes.
    let mut mags: Vec<f32> = weights.iter().map(|w| w.abs()).collect();
    let (_, thresh, _) = mags.select_nth_unstable_by(k - 1, |a, b| a.partial_cmp(b).unwrap());
    let thresh = *thresh;
    let mut zeroed = 0;
    for w in weights.iter_mut() {
        if w.abs() <= thresh && zeroed < k {
            *w = 0.0;
            zeroed += 1;
        }
    }
    zeroed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prunes_half() {
        let mut w: Vec<f32> = (1..=100).map(|i| i as f32 / 100.0).collect();
        let n = magnitude_prune_f32(&mut w, 0.5);
        assert_eq!(n, 50);
        assert_eq!(w.iter().filter(|x| **x == 0.0).count(), 50);
        // The survivors are the large-magnitude half.
        assert!(w.iter().filter(|x| **x != 0.0).all(|x| *x > 0.5));
    }

    #[test]
    fn zero_rate_is_noop() {
        let mut w = vec![0.1f32, -0.5, 0.3];
        assert_eq!(magnitude_prune_f32(&mut w, 0.0), 0);
        assert_eq!(w, vec![0.1, -0.5, 0.3]);
    }

    #[test]
    fn keeps_sign_of_survivors() {
        let mut w = vec![-1.0f32, 0.01, -0.02, 2.0];
        magnitude_prune_f32(&mut w, 0.5);
        assert_eq!(w, vec![-1.0, 0.0, 0.0, 2.0]);
    }

    #[test]
    fn full_rate_zeroes_all() {
        let mut w = vec![1.0f32; 10];
        assert_eq!(magnitude_prune_f32(&mut w, 1.0), 10);
        assert!(w.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn ties_do_not_overprune() {
        let mut w = vec![0.5f32; 8];
        let n = magnitude_prune_f32(&mut w, 0.5);
        assert_eq!(n, 4, "exactly half even with ties");
    }
}
