//! Bit-error-rate fault injection (paper §V.G, Fig. 21).
//!
//! The STT-AI Ultra design stores the MSB half of every word in a robust
//! bank (BER 1e-8) and the LSB half in a relaxed bank (BER 1e-5). This
//! module injects that fault model into weight/activation buffers before the
//! coordinator hands them to PJRT:
//!
//! * [`injector`] — fast geometric-skip Bernoulli bit flipping over byte
//!   buffers (deterministic, seeded).
//! * [`banks`] — the MSB/LSB bit-group split for bf16 and int8 words.
//! * [`prune`] — magnitude pruning (Fig. 21 also evaluates 50%-pruned
//!   models).

pub mod analytical;
pub mod banks;
pub mod injector;
pub mod prune;

pub use analytical::{zoo_exposure, FaultExposure};
pub use banks::{BankSplit, WordKind};
pub use injector::{BitFlipStats, Injector};
pub use prune::magnitude_prune_f32;
