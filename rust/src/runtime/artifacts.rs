//! Artifact manifest: what `python/compile/aot.py` emits and the Rust side
//! consumes. All binary tensors are little-endian f32, row-major.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::util::json::Json;

/// One parameter tensor of the model, in call order.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<i64>,
    /// Element offset into the flat weights file.
    pub offset: u64,
}

impl ParamSpec {
    pub fn elems(&self) -> u64 {
        self.shape.iter().product::<i64>() as u64
    }
}

/// One compiled model variant (one executable per batch size).
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    /// HLO text file, relative to the manifest directory.
    pub hlo: String,
    pub batch: usize,
    /// Input image shape (excluding batch): [ch, h, w].
    pub input_shape: Vec<i64>,
    pub num_classes: usize,
    pub params: Vec<ParamSpec>,
}

/// The held-out evaluation set.
#[derive(Debug, Clone)]
pub struct TestSet {
    pub images: String,
    pub labels: String,
    pub n: usize,
    pub image_shape: Vec<i64>,
}

/// Top-level manifest (artifacts/manifest.json).
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// Model variants keyed by name (e.g. "tinycnn_b1", "tinycnn_b16").
    pub models: std::collections::BTreeMap<String, ModelArtifact>,
    /// Flat f32 weights file shared by all variants.
    pub weights: String,
    pub testset: TestSet,
    /// Training metadata recorded by train.py (final loss etc.).
    pub train_meta: Json,
    pub dir: PathBuf,
}

fn shape_of(j: &Json, key: &str) -> crate::Result<Vec<i64>> {
    Ok(j.req_arr(key)
        .map_err(anyhow::Error::from)?
        .iter()
        .map(|x| x.as_i64().context("shape entry not an int"))
        .collect::<Result<Vec<i64>, _>>()?)
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(anyhow::Error::from)?;

        let mut models = std::collections::BTreeMap::new();
        for (name, m) in j.req("models").map_err(anyhow::Error::from)?.as_obj().context("models not an object")? {
            let mut params = Vec::new();
            for p in m.req_arr("params").map_err(anyhow::Error::from)? {
                params.push(ParamSpec {
                    name: p.req_str("name").map_err(anyhow::Error::from)?.to_string(),
                    shape: shape_of(p, "shape")?,
                    offset: p.req_u64("offset").map_err(anyhow::Error::from)?,
                });
            }
            models.insert(
                name.clone(),
                ModelArtifact {
                    hlo: m.req_str("hlo").map_err(anyhow::Error::from)?.to_string(),
                    batch: m.req_u64("batch").map_err(anyhow::Error::from)? as usize,
                    input_shape: shape_of(m, "input_shape")?,
                    num_classes: m.req_u64("num_classes").map_err(anyhow::Error::from)? as usize,
                    params,
                },
            );
        }
        let ts = j.req("testset").map_err(anyhow::Error::from)?;
        let testset = TestSet {
            images: ts.req_str("images").map_err(anyhow::Error::from)?.to_string(),
            labels: ts.req_str("labels").map_err(anyhow::Error::from)?.to_string(),
            n: ts.req_u64("n").map_err(anyhow::Error::from)? as usize,
            image_shape: shape_of(ts, "image_shape")?,
        };
        Ok(ArtifactManifest {
            models,
            weights: j.req_str("weights").map_err(anyhow::Error::from)?.to_string(),
            testset,
            train_meta: j.get("train_meta").cloned().unwrap_or(Json::Null),
            dir: dir.to_path_buf(),
        })
    }

    pub fn model(&self, name: &str) -> crate::Result<&ModelArtifact> {
        self.models.get(name).with_context(|| {
            format!("model {name:?} not in manifest (have: {:?})", self.models.keys())
        })
    }

    /// Pick the variant compiled for `batch`.
    pub fn model_for_batch(&self, batch: usize) -> crate::Result<(&String, &ModelArtifact)> {
        self.models
            .iter()
            .find(|(_, m)| m.batch == batch)
            .with_context(|| format!("no variant compiled for batch {batch}"))
    }

    pub fn hlo_path(&self, m: &ModelArtifact) -> PathBuf {
        self.dir.join(&m.hlo)
    }

    pub fn load_weights(&self) -> crate::Result<Weights> {
        Weights::load(&self.dir.join(&self.weights))
    }

    pub fn load_testset(&self) -> crate::Result<(Vec<f32>, Vec<i64>)> {
        let imgs = read_f32(&self.dir.join(&self.testset.images))?;
        let labels_f = read_f32(&self.dir.join(&self.testset.labels))?;
        let per_image: i64 = self.testset.image_shape.iter().product();
        if imgs.len() as i64 != per_image * self.testset.n as i64 {
            bail!(
                "test image file size mismatch: {} elems, want {}",
                imgs.len(),
                per_image * self.testset.n as i64
            );
        }
        Ok((imgs, labels_f.iter().map(|&x| x as i64).collect()))
    }
}

/// Flat f32 weights blob.
#[derive(Debug, Clone)]
pub struct Weights {
    pub data: Vec<f32>,
}

impl Weights {
    pub fn load(path: &Path) -> crate::Result<Self> {
        Ok(Self { data: read_f32(path)? })
    }

    /// Slice out one parameter tensor.
    pub fn param(&self, spec: &ParamSpec) -> crate::Result<&[f32]> {
        let start = spec.offset as usize;
        let end = start + spec.elems() as usize;
        if end > self.data.len() {
            bail!("param {} [{start}..{end}) out of range ({})", spec.name, self.data.len());
        }
        Ok(&self.data[start..end])
    }

    /// Mutable slice (the BER injector writes through this).
    pub fn param_mut(&mut self, spec: &ParamSpec) -> crate::Result<&mut [f32]> {
        let start = spec.offset as usize;
        let end = start + spec.elems() as usize;
        if end > self.data.len() {
            bail!("param {} [{start}..{end}) out of range ({})", spec.name, self.data.len());
        }
        Ok(&mut self.data[start..end])
    }
}

fn read_f32(path: &Path) -> crate::Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path:?}: length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_spec_elems() {
        let p = ParamSpec { name: "w".into(), shape: vec![8, 3, 3, 3], offset: 0 };
        assert_eq!(p.elems(), 216);
    }

    #[test]
    fn weights_slicing_and_bounds() {
        let w = Weights { data: (0..10).map(|i| i as f32).collect() };
        let p = ParamSpec { name: "a".into(), shape: vec![2, 2], offset: 2 };
        assert_eq!(w.param(&p).unwrap(), &[2.0, 3.0, 4.0, 5.0]);
        let bad = ParamSpec { name: "b".into(), shape: vec![4], offset: 8 };
        assert!(w.param(&bad).is_err());
    }

    #[test]
    fn manifest_roundtrip() {
        let dir = std::env::temp_dir().join("stt_ai_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
            "models": {
                "m_b1": {
                    "hlo": "m.hlo.txt",
                    "batch": 1,
                    "input_shape": [1, 16, 16],
                    "num_classes": 10,
                    "params": [{"name": "w", "shape": [4], "offset": 0}]
                }
            },
            "weights": "w.bin",
            "testset": {"images": "x.bin", "labels": "y.bin", "n": 2, "image_shape": [1, 16, 16]}
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let m = ArtifactManifest::load(&dir).unwrap();
        assert!(m.model("m_b1").is_ok());
        assert!(m.model("nope").is_err());
        let (_, v) = m.model_for_batch(1).unwrap();
        assert_eq!(v.num_classes, 10);
        assert!(m.model_for_batch(99).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_f32_le() {
        let dir = std::env::temp_dir().join("stt_ai_readf32_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        std::fs::write(&p, 1.5f32.to_le_bytes()).unwrap();
        assert_eq!(read_f32(&p).unwrap(), vec![1.5]);
        std::fs::write(&p, [0u8; 3]).unwrap();
        assert!(read_f32(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
