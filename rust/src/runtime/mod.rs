//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Python (JAX + Pallas) runs once at build time (`make artifacts`); this
//! module is the only thing that touches the compiled artifacts on the
//! request path. Interchange format is HLO *text* — the crate's bundled
//! xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos (64-bit
//! instruction ids), while the text parser reassigns ids cleanly.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactManifest, ModelArtifact, TestSet, Weights};
pub use client::{LoadedModel, Runtime};
