//! PJRT client wrapper: HLO text → compiled executable → typed execution.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. The lowered modules return a 1-tuple
//! (`return_tuple=True` at lowering), unwrapped with `to_tuple1`.

use std::path::Path;

use anyhow::Context;

use super::artifacts::{ModelArtifact, Weights};

/// The PJRT CPU client. One per process; cheap to share by reference.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> crate::Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> crate::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable { exe })
    }

    /// Load a model artifact and bind its parameter layout.
    pub fn load_model(&self, manifest_dir: &Path, art: &ModelArtifact) -> crate::Result<LoadedModel> {
        let exe = self.load_hlo(&manifest_dir.join(&art.hlo))?;
        Ok(LoadedModel { exe, art: art.clone() })
    }
}

/// A compiled executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened f32 output of the
    /// 1-tuple result.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> crate::Result<Vec<f32>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[i64]) -> crate::Result<xla::Literal> {
    let n: i64 = shape.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {shape:?} != {} elems", data.len());
    Ok(xla::Literal::vec1(data).reshape(shape)?)
}

/// A compiled model variant plus its parameter layout: everything needed to
/// run inference with (possibly fault-injected) weights.
pub struct LoadedModel {
    exe: Executable,
    pub art: ModelArtifact,
}

impl LoadedModel {
    /// Run one batch: builds param literals from `weights` (in manifest
    /// order) followed by the batched input image literal.
    ///
    /// Returns logits, shape [batch, num_classes] flattened.
    pub fn infer(&self, weights: &Weights, images: &[f32]) -> crate::Result<Vec<f32>> {
        let mut inputs = Vec::with_capacity(self.art.params.len() + 1);
        for p in &self.art.params {
            inputs.push(literal_f32(weights.param(p)?, &p.shape)?);
        }
        let mut x_shape = vec![self.art.batch as i64];
        x_shape.extend_from_slice(&self.art.input_shape);
        inputs.push(literal_f32(images, &x_shape)?);
        let logits = self.exe.run_f32(&inputs)?;
        anyhow::ensure!(
            logits.len() == self.art.batch * self.art.num_classes,
            "logits len {} != batch {} × classes {}",
            logits.len(),
            self.art.batch,
            self.art.num_classes
        );
        Ok(logits)
    }

    /// Argmax per row of a logits batch.
    pub fn predictions(&self, logits: &[f32]) -> Vec<usize> {
        logits
            .chunks_exact(self.art.num_classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Top-k indices per row (for Top-5-style accuracy).
    pub fn top_k(&self, logits: &[f32], k: usize) -> Vec<Vec<usize>> {
        logits
            .chunks_exact(self.art.num_classes)
            .map(|row| {
                let mut idx: Vec<usize> = (0..row.len()).collect();
                idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
                idx.truncate(k);
                idx
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_check() {
        assert!(literal_f32(&[1.0, 2.0], &[2]).is_ok());
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    // Execution-path tests live in rust/tests/runtime_e2e.rs (they need the
    // PJRT client + built artifacts).
}
