//! Cross-sweep memoization of the per-layer model walks.
//!
//! `fig11`/`fig12`/`fig14` (and every custom sweep) used to re-derive
//! overlapping [`ModelTraffic`] and retention walks for the same
//! (model, array, dtype, batch, GLB) coordinates — once per sweep point,
//! across sweeps, across figures (the ROADMAP perf item). Both walks are
//! pure functions of those coordinates, so this module interns the results
//! process-wide:
//!
//! * keys are (model name + structural fingerprint, array-config bits,
//!   dtype/batch/GLB) — fingerprinting keeps ad-hoc test models from
//!   aliasing zoo models that share a name;
//! * values are `Arc`s, so the work-stealing sweep workers share one
//!   allocation; a racing duplicate computation is harmless (identical
//!   values, first insert wins);
//! * results are bit-identical to uncached evaluation — the figure parity
//!   tests cover the cached paths.
//!
//! `benches/hotpath.rs` carries the cold-vs-warm datapoint for this cache.
//!
//! The same interning serves the Monte-Carlo sweep: [`mc_design`] memoizes
//! the solved per-(technology, targets) [`MonteCarlo`] engine so every
//! `mc_samples`/Δ point shares one Δ-scaling solve and driver sizing.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::accel::{ArrayConfig, ModelRetention, ModelTraffic, RetentionAnalysis};
use crate::models::{DType, Model};
use crate::mram::montecarlo::{McResult, MonteCarlo};
use crate::mram::scaling::DesignTargets;
use crate::mram::technology::TechnologyId;

/// Hashable identity of an [`ArrayConfig`] (f64 fields by bit pattern).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct ArrayKey {
    w_a: u64,
    h_a: u64,
    p_s: u64,
    clk_bits: u64,
    cyc_conv: u64,
    cyc_sys: u64,
    pool_bits: u64,
}

impl ArrayKey {
    fn of(a: &ArrayConfig) -> Self {
        Self {
            w_a: a.w_a,
            h_a: a.h_a,
            p_s: a.p_s,
            clk_bits: a.clk_hz.to_bits(),
            cyc_conv: a.cyc_per_step_conv,
            cyc_sys: a.cyc_per_step_systolic,
            pool_bits: a.t_pool_relu.to_bits(),
        }
    }
}

/// Hashable identity of a [`Model`]: name + structural fingerprint.
#[derive(Clone, PartialEq, Eq, Hash)]
struct ModelKey {
    name: String,
    fingerprint: u64,
}

impl ModelKey {
    fn of(m: &Model) -> Self {
        Self { name: m.name.clone(), fingerprint: m.fingerprint() }
    }
}

type TrafficKey = (ModelKey, ArrayKey, u64, u64, u64); // (dtype bytes, batch, glb)
type RetentionKey = (ModelKey, ArrayKey, u64); // (batch)
type OccupancyKey = (u64, ArrayKey, u64); // (zoo fingerprint fold, array, batch)
type McKey = (TechnologyId, u64, u64, u64, u64); // (targets, f64 fields by bit pattern)
type McRunKey = (McKey, u64, u64, u64); // (delta_gb bits, seed, n)

static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

fn traffic_map() -> &'static Mutex<HashMap<TrafficKey, Arc<ModelTraffic>>> {
    static M: OnceLock<Mutex<HashMap<TrafficKey, Arc<ModelTraffic>>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

fn retention_map() -> &'static Mutex<HashMap<RetentionKey, Arc<ModelRetention>>> {
    static M: OnceLock<Mutex<HashMap<RetentionKey, Arc<ModelRetention>>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

fn occupancy_map() -> &'static Mutex<HashMap<OccupancyKey, f64>> {
    static M: OnceLock<Mutex<HashMap<OccupancyKey, f64>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

fn mc_map() -> &'static Mutex<HashMap<McKey, Arc<MonteCarlo>>> {
    static M: OnceLock<Mutex<HashMap<McKey, Arc<MonteCarlo>>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

// One cell per run key: `OnceLock::get_or_init` makes concurrent workers
// that miss on the same key block on ONE computation instead of each
// duplicating a potentially seconds-long sample walk (unlike the walk
// caches above, a sweep grid often collapses to a single MC key, so the
// simultaneous-miss race would be the common case, not the corner).
type McRunCell = Arc<OnceLock<McResult>>;

fn mc_run_map() -> &'static Mutex<HashMap<McRunKey, McRunCell>> {
    static M: OnceLock<Mutex<HashMap<McRunKey, McRunCell>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

fn mc_key(id: TechnologyId, targets: &DesignTargets) -> McKey {
    (
        id,
        targets.retention_time.to_bits(),
        targets.retention_ber.to_bits(),
        targets.read_disturb_ber.to_bits(),
        targets.write_ber.to_bits(),
    )
}

/// Memoized [`ModelTraffic::analyze`].
pub fn traffic(m: &Model, a: &ArrayConfig, dt: DType, batch: u64, glb_bytes: u64) -> Arc<ModelTraffic> {
    let key: TrafficKey = (ModelKey::of(m), ArrayKey::of(a), dt.bytes(), batch, glb_bytes);
    if let Some(hit) = traffic_map().lock().unwrap().get(&key) {
        HITS.fetch_add(1, Ordering::Relaxed);
        return hit.clone();
    }
    // Compute outside the lock: the walk is the expensive part, and a racing
    // duplicate insert produces an identical value (first insert wins).
    MISSES.fetch_add(1, Ordering::Relaxed);
    let v = Arc::new(ModelTraffic::analyze(m, a, dt, batch, glb_bytes));
    traffic_map().lock().unwrap().entry(key).or_insert(v).clone()
}

/// Memoized retention walk ([`RetentionAnalysis::analyze`]).
pub fn retention(m: &Model, a: &ArrayConfig, batch: u64) -> Arc<ModelRetention> {
    let key: RetentionKey = (ModelKey::of(m), ArrayKey::of(a), batch);
    if let Some(hit) = retention_map().lock().unwrap().get(&key) {
        HITS.fetch_add(1, Ordering::Relaxed);
        return hit.clone();
    }
    MISSES.fetch_add(1, Ordering::Relaxed);
    let v = Arc::new(RetentionAnalysis::new(a, batch).analyze(m));
    retention_map().lock().unwrap().entry(key).or_insert(v).clone()
}

/// Memoized zoo-wide worst data-occupancy time (§V.C): the max over every
/// model's retention walk at (array, batch) — the fold the selection grid
/// re-derives for every candidate sharing an array. Keyed by an
/// order-sensitive fold of the zoo's model fingerprints, so ad-hoc test
/// zoos never alias the shared zoo.
pub fn zoo_occupancy(zoo: &[Model], a: &ArrayConfig, batch: u64) -> f64 {
    let fp = zoo.iter().fold(zoo.len() as u64, |acc, m| acc.rotate_left(7) ^ m.fingerprint());
    let key: OccupancyKey = (fp, ArrayKey::of(a), batch);
    if let Some(hit) = occupancy_map().lock().unwrap().get(&key) {
        HITS.fetch_add(1, Ordering::Relaxed);
        return *hit;
    }
    MISSES.fetch_add(1, Ordering::Relaxed);
    let v = zoo.iter().map(|m| retention(m, a, batch).max_t_ret()).fold(0.0, f64::max);
    *occupancy_map().lock().unwrap().entry(key).or_insert(v)
}

/// Memoized [`MonteCarlo::for_technology`]: the Δ-scaling solve, guard-band
/// and driver sizing are pure functions of (technology, targets), so every
/// Monte-Carlo sweep point that varies only `mc_samples` (or re-anchors Δ
/// via [`MonteCarlo::at_delta_gb`], which is a cheap copy) shares one solved
/// engine. `None` for technologies without a PT Monte-Carlo model. Uses the
/// same racy check-then-insert as the walk caches — the closed-form solve
/// is microseconds, so a simultaneous-miss duplicate is harmless (the
/// seconds-scale *runs* get the stricter once-per-key treatment in
/// [`mc_result`]).
pub fn mc_design(id: TechnologyId, targets: &DesignTargets) -> Option<Arc<MonteCarlo>> {
    let key = mc_key(id, targets);
    if let Some(hit) = mc_map().lock().unwrap().get(&key) {
        HITS.fetch_add(1, Ordering::Relaxed);
        return Some(hit.clone());
    }
    let v = Arc::new(MonteCarlo::for_technology(id, targets)?);
    MISSES.fetch_add(1, Ordering::Relaxed);
    Some(mc_map().lock().unwrap().entry(key).or_insert(v).clone())
}

/// Memoized serial Monte-Carlo run: the aggregate result is a pure function
/// of (technology, targets, Δ_GB, seed, n), so sweep grids that repeat the
/// same MC coordinates across orthogonal axes (model × batch × ...) share
/// one run instead of recomputing a potentially seconds-long sample walk —
/// concurrent first callers block on one computation, they do not race it.
/// `None` for technologies without a PT Monte-Carlo model.
pub fn mc_result(
    id: TechnologyId,
    targets: &DesignTargets,
    delta_gb: f64,
    seed: u64,
    n: u64,
) -> Option<McResult> {
    let mc = mc_design(id, targets)?;
    let key: McRunKey = (mc_key(id, targets), delta_gb.to_bits(), seed, n);
    let cell: McRunCell = {
        let mut map = mc_run_map().lock().unwrap();
        map.entry(key).or_insert_with(|| Arc::new(OnceLock::new())).clone()
    };
    if cell.get().is_some() {
        HITS.fetch_add(1, Ordering::Relaxed);
    } else {
        MISSES.fetch_add(1, Ordering::Relaxed);
    }
    // Outside the map lock: the walk is the expensive part. get_or_init
    // runs it exactly once per key; latecomers block until it is ready.
    Some(cell.get_or_init(|| mc.at_delta_gb(delta_gb).run_serial(seed, n as usize)).clone())
}

/// (hits, misses) since process start (or the last [`clear`]).
pub fn stats() -> (u64, u64) {
    (HITS.load(Ordering::Relaxed), MISSES.load(Ordering::Relaxed))
}

/// Drop every cached walk and reset the counters (bench/test hook).
pub fn clear() {
    traffic_map().lock().unwrap().clear();
    retention_map().lock().unwrap().clear();
    occupancy_map().lock().unwrap().clear();
    mc_map().lock().unwrap().clear();
    mc_run_map().lock().unwrap().clear();
    HITS.store(0, Ordering::Relaxed);
    MISSES.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::util::units::MB;

    #[test]
    fn cached_walks_match_direct_analysis() {
        let a = ArrayConfig::paper_42x42();
        let m = models::by_name("ResNet50").unwrap();
        let cached = traffic(&m, &a, DType::Bf16, 4, 12 * MB);
        let direct = ModelTraffic::analyze(&m, &a, DType::Bf16, 4, 12 * MB);
        assert_eq!(cached.total_dram_bytes(), direct.total_dram_bytes());
        assert_eq!(cached.total_glb_reads(), direct.total_glb_reads());
        assert_eq!(cached.layers.len(), direct.layers.len());

        let r1 = retention(&m, &a, 16);
        let r2 = RetentionAnalysis::new(&a, 16).analyze(&m);
        assert_eq!(r1.max_t_ret(), r2.max_t_ret());
        assert_eq!(r1.min_t_ret(), r2.min_t_ret());
    }

    #[test]
    fn repeat_lookups_hit_and_share_the_allocation() {
        let a = ArrayConfig::paper_42x42();
        let m = models::by_name("VGG16").unwrap();
        let first = traffic(&m, &a, DType::Int8, 2, 12 * MB);
        let (h0, _) = stats();
        let second = traffic(&m, &a, DType::Int8, 2, 12 * MB);
        let (h1, _) = stats();
        assert!(h1 > h0, "second lookup must be a hit");
        assert!(Arc::ptr_eq(&first, &second), "hits share one allocation");
    }

    #[test]
    fn distinct_coordinates_do_not_alias() {
        let a = ArrayConfig::paper_42x42();
        let b = ArrayConfig::with_mac_array(14);
        let m = models::by_name("AlexNet").unwrap();
        let r42 = retention(&m, &a, 16);
        let r14 = retention(&m, &b, 16);
        assert!(r42.max_t_ret() < r14.max_t_ret(), "bigger array, shorter occupancy");
        let t1 = traffic(&m, &a, DType::Bf16, 1, 12 * MB);
        let t8 = traffic(&m, &a, DType::Bf16, 8, 12 * MB);
        assert!(t8.total_glb_reads() > t1.total_glb_reads());
    }

    #[test]
    fn zoo_occupancy_matches_the_direct_fold_and_hits() {
        let zoo = models::zoo();
        let a = ArrayConfig::paper_42x42();
        let direct = zoo
            .iter()
            .map(|m| RetentionAnalysis::new(&a, 16).analyze(m).max_t_ret())
            .fold(0.0, f64::max);
        let cached = zoo_occupancy(&zoo, &a, 16);
        assert_eq!(cached, direct);
        let (h0, _) = stats();
        assert_eq!(zoo_occupancy(&zoo, &a, 16), cached);
        let (h1, _) = stats();
        assert!(h1 > h0, "second fold must be a hit");
        // A different zoo slice does not alias the full fold.
        let sub = &zoo[..3];
        let sub_occ = zoo_occupancy(sub, &a, 16);
        assert!(sub_occ <= cached);
    }

    #[test]
    fn mc_designs_are_shared_per_technology_and_targets() {
        let t = DesignTargets::global_buffer();
        let a = mc_design(TechnologyId::SttSakhare2020, &t).unwrap();
        let (h0, _) = stats();
        let b = mc_design(TechnologyId::SttSakhare2020, &t).unwrap();
        let (h1, _) = stats();
        assert!(h1 > h0, "second lookup must be a hit");
        assert!(Arc::ptr_eq(&a, &b), "hits share one solved engine");
        // Distinct targets / technologies do not alias.
        let c = mc_design(TechnologyId::SttSakhare2020, &DesignTargets::lsb_bank()).unwrap();
        assert_ne!(a.delta_guard_banded, c.delta_guard_banded);
        let d = mc_design(TechnologyId::SttWei2019, &t).unwrap();
        assert_ne!(a.write_pulse, d.write_pulse);
        // Technologies without a PT model stay None (and never panic).
        assert!(mc_design(TechnologyId::Sot, &t).is_none());
        assert!(mc_design(TechnologyId::Sram, &t).is_none());
    }

    #[test]
    fn mc_runs_are_memoized_per_coordinates() {
        let t = DesignTargets::global_buffer();
        let a = mc_result(TechnologyId::SttSakhare2020, &t, 27.5, 0xD1E5, 2_000).unwrap();
        let (h0, _) = stats();
        let b = mc_result(TechnologyId::SttSakhare2020, &t, 27.5, 0xD1E5, 2_000).unwrap();
        let (h1, _) = stats();
        assert!(h1 > h0, "second lookup must be a hit");
        assert_eq!(a, b);
        // The memoized run equals a direct engine run, bit for bit.
        let direct = MonteCarlo::for_technology(TechnologyId::SttSakhare2020, &t)
            .unwrap()
            .at_delta_gb(27.5)
            .run_serial(0xD1E5, 2_000);
        assert_eq!(a, direct);
        // Concurrent first callers on a fresh key agree (the per-key
        // OnceLock serializes initialization; latecomers block and read).
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| {
                        mc_result(TechnologyId::SttSakhare2020, &t, 26.5, 0xFEED, 2_000).unwrap()
                    })
                })
                .collect();
            let results: Vec<McResult> = handles.into_iter().map(|h| h.join().unwrap()).collect();
            for r in &results {
                assert_eq!(*r, results[0]);
            }
        });
        // Coordinates are part of the key.
        let c = mc_result(TechnologyId::SttSakhare2020, &t, 27.5, 0xD1E5, 4_000).unwrap();
        assert_eq!(c.n, 4_000);
        assert!(mc_result(TechnologyId::Sram, &t, 27.5, 1, 100).is_none());
    }

    #[test]
    fn same_name_different_shape_does_not_alias() {
        use crate::models::{ConvLayer, Layer};
        let a = ArrayConfig::paper_42x42();
        let mk = |out_ch: u64| Model {
            name: "twin".into(),
            input: (3, 8, 8),
            layers: vec![Layer::Conv(ConvLayer {
                name: "c1".into(),
                in_ch: 3,
                out_ch,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
                groups: 1,
                in_h: 8,
                in_w: 8,
            })],
            reference_params: None,
        };
        let (m1, m2) = (mk(8), mk(16));
        assert_ne!(m1.fingerprint(), m2.fingerprint());
        let t1 = traffic(&m1, &a, DType::Bf16, 1, 12 * MB);
        let t2 = traffic(&m2, &a, DType::Bf16, 1, 12 * MB);
        assert_ne!(t1.layers[0].glb_writes, t2.layers[0].glb_writes);
    }
}
