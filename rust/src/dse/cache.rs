//! Cross-sweep memoization of the per-layer model walks.
//!
//! `fig11`/`fig12`/`fig14` (and every custom sweep) used to re-derive
//! overlapping [`ModelTraffic`] and retention walks for the same
//! (model, array, dtype, batch, GLB) coordinates — once per sweep point,
//! across sweeps, across figures (the ROADMAP perf item). Both walks are
//! pure functions of those coordinates, so this module interns the results
//! process-wide:
//!
//! * keys are (model name + structural fingerprint, array-config bits,
//!   dtype/batch/GLB) — fingerprinting keeps ad-hoc test models from
//!   aliasing zoo models that share a name;
//! * values are `Arc`s, so the work-stealing sweep workers share one
//!   allocation; a racing duplicate computation is harmless (identical
//!   values, first insert wins);
//! * results are bit-identical to uncached evaluation — the figure parity
//!   tests cover the cached paths.
//!
//! `benches/hotpath.rs` carries the cold-vs-warm datapoint for this cache.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::accel::{ArrayConfig, ModelRetention, ModelTraffic, RetentionAnalysis};
use crate::models::{DType, Model};

/// Hashable identity of an [`ArrayConfig`] (f64 fields by bit pattern).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct ArrayKey {
    w_a: u64,
    h_a: u64,
    p_s: u64,
    clk_bits: u64,
    cyc_conv: u64,
    cyc_sys: u64,
    pool_bits: u64,
}

impl ArrayKey {
    fn of(a: &ArrayConfig) -> Self {
        Self {
            w_a: a.w_a,
            h_a: a.h_a,
            p_s: a.p_s,
            clk_bits: a.clk_hz.to_bits(),
            cyc_conv: a.cyc_per_step_conv,
            cyc_sys: a.cyc_per_step_systolic,
            pool_bits: a.t_pool_relu.to_bits(),
        }
    }
}

/// Hashable identity of a [`Model`]: name + structural fingerprint.
#[derive(Clone, PartialEq, Eq, Hash)]
struct ModelKey {
    name: String,
    fingerprint: u64,
}

impl ModelKey {
    fn of(m: &Model) -> Self {
        Self { name: m.name.clone(), fingerprint: m.fingerprint() }
    }
}

type TrafficKey = (ModelKey, ArrayKey, u64, u64, u64); // (dtype bytes, batch, glb)
type RetentionKey = (ModelKey, ArrayKey, u64); // (batch)

static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

fn traffic_map() -> &'static Mutex<HashMap<TrafficKey, Arc<ModelTraffic>>> {
    static M: OnceLock<Mutex<HashMap<TrafficKey, Arc<ModelTraffic>>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

fn retention_map() -> &'static Mutex<HashMap<RetentionKey, Arc<ModelRetention>>> {
    static M: OnceLock<Mutex<HashMap<RetentionKey, Arc<ModelRetention>>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Memoized [`ModelTraffic::analyze`].
pub fn traffic(m: &Model, a: &ArrayConfig, dt: DType, batch: u64, glb_bytes: u64) -> Arc<ModelTraffic> {
    let key: TrafficKey = (ModelKey::of(m), ArrayKey::of(a), dt.bytes(), batch, glb_bytes);
    if let Some(hit) = traffic_map().lock().unwrap().get(&key) {
        HITS.fetch_add(1, Ordering::Relaxed);
        return hit.clone();
    }
    // Compute outside the lock: the walk is the expensive part, and a racing
    // duplicate insert produces an identical value (first insert wins).
    MISSES.fetch_add(1, Ordering::Relaxed);
    let v = Arc::new(ModelTraffic::analyze(m, a, dt, batch, glb_bytes));
    traffic_map().lock().unwrap().entry(key).or_insert(v).clone()
}

/// Memoized retention walk ([`RetentionAnalysis::analyze`]).
pub fn retention(m: &Model, a: &ArrayConfig, batch: u64) -> Arc<ModelRetention> {
    let key: RetentionKey = (ModelKey::of(m), ArrayKey::of(a), batch);
    if let Some(hit) = retention_map().lock().unwrap().get(&key) {
        HITS.fetch_add(1, Ordering::Relaxed);
        return hit.clone();
    }
    MISSES.fetch_add(1, Ordering::Relaxed);
    let v = Arc::new(RetentionAnalysis::new(a, batch).analyze(m));
    retention_map().lock().unwrap().entry(key).or_insert(v).clone()
}

/// (hits, misses) since process start (or the last [`clear`]).
pub fn stats() -> (u64, u64) {
    (HITS.load(Ordering::Relaxed), MISSES.load(Ordering::Relaxed))
}

/// Drop every cached walk and reset the counters (bench/test hook).
pub fn clear() {
    traffic_map().lock().unwrap().clear();
    retention_map().lock().unwrap().clear();
    HITS.store(0, Ordering::Relaxed);
    MISSES.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::util::units::MB;

    #[test]
    fn cached_walks_match_direct_analysis() {
        let a = ArrayConfig::paper_42x42();
        let m = models::by_name("ResNet50").unwrap();
        let cached = traffic(&m, &a, DType::Bf16, 4, 12 * MB);
        let direct = ModelTraffic::analyze(&m, &a, DType::Bf16, 4, 12 * MB);
        assert_eq!(cached.total_dram_bytes(), direct.total_dram_bytes());
        assert_eq!(cached.total_glb_reads(), direct.total_glb_reads());
        assert_eq!(cached.layers.len(), direct.layers.len());

        let r1 = retention(&m, &a, 16);
        let r2 = RetentionAnalysis::new(&a, 16).analyze(&m);
        assert_eq!(r1.max_t_ret(), r2.max_t_ret());
        assert_eq!(r1.min_t_ret(), r2.min_t_ret());
    }

    #[test]
    fn repeat_lookups_hit_and_share_the_allocation() {
        let a = ArrayConfig::paper_42x42();
        let m = models::by_name("VGG16").unwrap();
        let first = traffic(&m, &a, DType::Int8, 2, 12 * MB);
        let (h0, _) = stats();
        let second = traffic(&m, &a, DType::Int8, 2, 12 * MB);
        let (h1, _) = stats();
        assert!(h1 > h0, "second lookup must be a hit");
        assert!(Arc::ptr_eq(&first, &second), "hits share one allocation");
    }

    #[test]
    fn distinct_coordinates_do_not_alias() {
        let a = ArrayConfig::paper_42x42();
        let b = ArrayConfig::with_mac_array(14);
        let m = models::by_name("AlexNet").unwrap();
        let r42 = retention(&m, &a, 16);
        let r14 = retention(&m, &b, 16);
        assert!(r42.max_t_ret() < r14.max_t_ret(), "bigger array, shorter occupancy");
        let t1 = traffic(&m, &a, DType::Bf16, 1, 12 * MB);
        let t8 = traffic(&m, &a, DType::Bf16, 8, 12 * MB);
        assert!(t8.total_glb_reads() > t1.total_glb_reads());
    }

    #[test]
    fn same_name_different_shape_does_not_alias() {
        use crate::models::{ConvLayer, Layer};
        let a = ArrayConfig::paper_42x42();
        let mk = |out_ch: u64| Model {
            name: "twin".into(),
            input: (3, 8, 8),
            layers: vec![Layer::Conv(ConvLayer {
                name: "c1".into(),
                in_ch: 3,
                out_ch,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
                groups: 1,
                in_h: 8,
                in_w: 8,
            })],
            reference_params: None,
        };
        let (m1, m2) = (mk(8), mk(16));
        assert_ne!(m1.fingerprint(), m2.fingerprint());
        let t1 = traffic(&m1, &a, DType::Bf16, 1, 12 * MB);
        let t2 = traffic(&m2, &a, DType::Bf16, 1, 12 * MB);
        assert_ne!(t1.layers[0].glb_writes, t2.layers[0].glb_writes);
    }
}
