//! Tiered cross-sweep memoization of the per-layer model walks.
//!
//! `fig11`/`fig12`/`fig14` (and every custom sweep) used to re-derive
//! overlapping [`ModelTraffic`] and retention walks for the same
//! (model, array, dtype, batch, GLB) coordinates — once per sweep point,
//! across sweeps, across figures (the ROADMAP perf item). All of these are
//! pure functions of their coordinates, so this module interns the results
//! process-wide, organized in three explicit tiers:
//!
//! * **L1 — per-candidate derived results**: the flattened stall plan
//!   ([`stall_plan`]), the DRAM spill row ([`spill`]) and the analytical
//!   fault exposure ([`exposure`]) the selection evaluator derives per
//!   candidate. The 108+ grid collapses to a handful of distinct
//!   (array, glb, model, scratchpad) groups, so per-group work is computed
//!   once and candidates that differ only in GLB organization/Δ/BER reuse
//!   it — the "batched evaluator" of the hot-path campaign.
//! * **L2 — shared model walks**: [`traffic`], [`retention`],
//!   [`zoo_occupancy`], and the Monte-Carlo design/run memos
//!   ([`mc_design`], [`mc_result`]) that L1 and the figure sweeps compose.
//! * **L3 — model fingerprints**: every key above starts from a structural
//!   [`Model::fingerprint`]; for models that live in the process-wide
//!   [`crate::dse::engine::shared_zoo`] the FNV walk itself is memoized by
//!   buffer index, so hot keys cost an address check instead of a per-layer
//!   hash.
//!
//! Mechanics shared by all tiers:
//!
//! * keys are (model name + structural fingerprint, array-config bits,
//!   dtype/batch/GLB) — fingerprinting keeps ad-hoc test models from
//!   aliasing zoo models that share a name;
//! * values are `Arc`s, so the work-stealing sweep workers share one
//!   allocation; a racing duplicate computation is harmless (identical
//!   values, first insert wins);
//! * results are bit-identical to uncached evaluation — the figure parity
//!   tests cover the cached paths;
//! * every entry point keeps its own hit/miss [`Counter`]; [`stats`] is the
//!   aggregate pair, [`tier_stats`] the per-entry breakdown
//!   `benches/hotpath.rs` prints into the bench artifact.
//!
//! The tier structure is also what makes the 2592-candidate `--grid dense`
//! stress grid affordable: its 24× candidate fan-out multiplies only the
//! cheap per-candidate composition, while the L1/L2 coordinate groups it
//! collapses onto grow by the handful of new (array, glb, scratchpad)
//! shapes — `benches/kernels.rs` prints the per-tier counters after the
//! dense sweep so the collapse stays observable.
//!
//! `benches/hotpath.rs` carries the cold-vs-warm datapoint for this cache.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::accel::{ArrayConfig, ModelRetention, ModelTraffic, RetentionAnalysis, StallPlan};
use crate::ber::{BankSplit, FaultExposure};
use crate::dse::capacity::DramOverheadRow;
use crate::memsys::{DramModel, Scratchpad};
use crate::models::{DType, Model};
use crate::mram::montecarlo::{McResult, MonteCarlo};
use crate::mram::scaling::DesignTargets;
use crate::mram::technology::TechnologyId;

// ---------------------------------------------------------------------------
// Per-entry-point hit/miss counters
// ---------------------------------------------------------------------------

/// One entry point's hit/miss counter.
struct Counter {
    name: &'static str,
    tier: u8,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Counter {
    const fn new(name: &'static str, tier: u8) -> Self {
        Self { name, tier, hits: AtomicU64::new(0), misses: AtomicU64::new(0) }
    }

    fn hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    fn miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    fn reset(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

static STALL_PLAN: Counter = Counter::new("stall_plan", 1);
static SPILL: Counter = Counter::new("spill", 1);
static EXPOSURE: Counter = Counter::new("exposure", 1);
static TRAFFIC: Counter = Counter::new("traffic", 2);
static RETENTION: Counter = Counter::new("retention", 2);
static OCCUPANCY: Counter = Counter::new("zoo_occupancy", 2);
static MC_DESIGN: Counter = Counter::new("mc_design", 2);
static MC_RUN: Counter = Counter::new("mc_run", 2);
static FINGERPRINT: Counter = Counter::new("model_fingerprint", 3);

const COUNTERS: [&Counter; 9] = [
    &STALL_PLAN,
    &SPILL,
    &EXPOSURE,
    &TRAFFIC,
    &RETENTION,
    &OCCUPANCY,
    &MC_DESIGN,
    &MC_RUN,
    &FINGERPRINT,
];

/// Snapshot of one entry point's counters (see [`tier_stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EntryStats {
    /// Entry-point name (`traffic`, `stall_plan`, ...).
    pub name: &'static str,
    /// Cache tier: 1 = per-candidate derived, 2 = shared walks, 3 = model
    /// fingerprints.
    pub tier: u8,
    pub hits: u64,
    pub misses: u64,
}

/// Per-entry-point hit/miss counters since process start (or the last
/// [`clear`]), tier-ordered — the breakdown the bench binaries print.
pub fn tier_stats() -> Vec<EntryStats> {
    COUNTERS
        .iter()
        .map(|c| EntryStats {
            name: c.name,
            tier: c.tier,
            hits: c.hits.load(Ordering::Relaxed),
            misses: c.misses.load(Ordering::Relaxed),
        })
        .collect()
}

/// Aggregate (hits, misses) over every entry point since process start (or
/// the last [`clear`]).
pub fn stats() -> (u64, u64) {
    tier_stats().iter().fold((0, 0), |(h, m), e| (h + e.hits, m + e.misses))
}

// ---------------------------------------------------------------------------
// Keys
// ---------------------------------------------------------------------------

/// Hashable identity of an [`ArrayConfig`] (f64 fields by bit pattern).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct ArrayKey {
    w_a: u64,
    h_a: u64,
    p_s: u64,
    clk_bits: u64,
    cyc_conv: u64,
    cyc_sys: u64,
    pool_bits: u64,
}

impl ArrayKey {
    fn of(a: &ArrayConfig) -> Self {
        Self {
            w_a: a.w_a,
            h_a: a.h_a,
            p_s: a.p_s,
            clk_bits: a.clk_hz.to_bits(),
            cyc_conv: a.cyc_per_step_conv,
            cyc_sys: a.cyc_per_step_systolic,
            pool_bits: a.t_pool_relu.to_bits(),
        }
    }
}

/// Hashable identity of a [`Model`]: name + structural fingerprint (the
/// fingerprint itself goes through the L3 memo).
#[derive(Clone, PartialEq, Eq, Hash)]
struct ModelKey {
    name: String,
    fingerprint: u64,
}

impl ModelKey {
    fn of(m: &Model) -> Self {
        Self { name: m.name.clone(), fingerprint: fingerprint_of(m) }
    }
}

/// Hashable identity of an optional [`Scratchpad`]: presence flag + the
/// fields the routed loads and the service rate depend on.
type ScratchpadKey = (u64, u64, u64, u64, u64);

fn scratchpad_key(sp: Option<&Scratchpad>) -> ScratchpadKey {
    match sp {
        Some(sp) => (
            1,
            sp.array.sram_latency_s().to_bits(),
            sp.array.capacity_bytes,
            sp.banks as u64,
            sp.gated_fraction.to_bits(),
        ),
        None => (0, 0, 0, 0, 0),
    }
}

/// Hashable identity of a [`DramModel`] (FNV fold of the field bits).
fn dram_fingerprint(d: &DramModel) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for bits in [
        d.transfer_rate.to_bits(),
        ((d.bus_bits as u64) << 32) | d.channels as u64,
        d.efficiency.to_bits(),
        d.energy_pj_per_bit.to_bits(),
        d.burst_latency.to_bits(),
    ] {
        h = (h ^ bits).wrapping_mul(0x0100_0000_01b3);
    }
    h
}

type TrafficKey = (ModelKey, ArrayKey, u64, u64, u64); // (dtype bytes, batch, glb)
type RetentionKey = (ModelKey, ArrayKey, u64); // (batch)
type OccupancyKey = (u64, ArrayKey, u64); // (zoo fingerprint fold, array, batch)
type McKey = (TechnologyId, u64, u64, u64, u64); // (targets, f64 fields by bit pattern)
type McRunKey = (McKey, u64, u64, u64); // (delta_gb bits, seed, n)
// (dtype bytes, batch, glb, write-intensity bits, scratchpad)
type StallPlanKey = (ModelKey, ArrayKey, u64, u64, u64, u64, ScratchpadKey);
type SpillKey = (ModelKey, ArrayKey, u64, u64, u64, u64); // (dram fp, dtype bytes, batch, glb)
type ExposureKey = (ModelKey, u64, u64, u64, u64); // (dtype bytes, word bytes, msb/lsb bits)

fn traffic_map() -> &'static Mutex<HashMap<TrafficKey, Arc<ModelTraffic>>> {
    static M: OnceLock<Mutex<HashMap<TrafficKey, Arc<ModelTraffic>>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

fn retention_map() -> &'static Mutex<HashMap<RetentionKey, Arc<ModelRetention>>> {
    static M: OnceLock<Mutex<HashMap<RetentionKey, Arc<ModelRetention>>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

fn occupancy_map() -> &'static Mutex<HashMap<OccupancyKey, f64>> {
    static M: OnceLock<Mutex<HashMap<OccupancyKey, f64>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

fn mc_map() -> &'static Mutex<HashMap<McKey, Arc<MonteCarlo>>> {
    static M: OnceLock<Mutex<HashMap<McKey, Arc<MonteCarlo>>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

// One cell per run key: `OnceLock::get_or_init` makes concurrent workers
// that miss on the same key block on ONE computation instead of each
// duplicating a potentially seconds-long sample walk (unlike the walk
// caches above, a sweep grid often collapses to a single MC key, so the
// simultaneous-miss race would be the common case, not the corner).
type McRunCell = Arc<OnceLock<McResult>>;

fn mc_run_map() -> &'static Mutex<HashMap<McRunKey, McRunCell>> {
    static M: OnceLock<Mutex<HashMap<McRunKey, McRunCell>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

fn stall_plan_map() -> &'static Mutex<HashMap<StallPlanKey, Arc<StallPlan>>> {
    static M: OnceLock<Mutex<HashMap<StallPlanKey, Arc<StallPlan>>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

fn spill_map() -> &'static Mutex<HashMap<SpillKey, Arc<DramOverheadRow>>> {
    static M: OnceLock<Mutex<HashMap<SpillKey, Arc<DramOverheadRow>>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

fn exposure_map() -> &'static Mutex<HashMap<ExposureKey, Arc<FaultExposure>>> {
    static M: OnceLock<Mutex<HashMap<ExposureKey, Arc<FaultExposure>>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(HashMap::new()))
}

fn mc_key(id: TechnologyId, targets: &DesignTargets) -> McKey {
    (
        id,
        targets.retention_time.to_bits(),
        targets.retention_ber.to_bits(),
        targets.read_disturb_ber.to_bits(),
        targets.write_ber.to_bits(),
    )
}

// ---------------------------------------------------------------------------
// L3 — model fingerprints
// ---------------------------------------------------------------------------

/// Memoized [`Model::fingerprint`] for models that live in the process-wide
/// [`crate::dse::engine::shared_zoo`] buffer (identified by address — the
/// zoo `Arc` is held here, so the buffer is stable for the process
/// lifetime). Ad-hoc models (tests, custom zoos) compute the FNV walk
/// directly and count as misses — they can never alias a zoo slot.
fn fingerprint_of(m: &Model) -> u64 {
    struct ZooFps {
        zoo: crate::dse::engine::Zoo,
        cells: Vec<OnceLock<u64>>,
    }
    static FPS: OnceLock<ZooFps> = OnceLock::new();
    let fps = FPS.get_or_init(|| {
        let zoo = crate::dse::engine::shared_zoo();
        let cells = (0..zoo.len()).map(|_| OnceLock::new()).collect();
        ZooFps { zoo, cells }
    });
    let base = fps.zoo.as_ptr() as usize;
    let addr = m as *const Model as usize;
    let size = std::mem::size_of::<Model>();
    if addr >= base && addr < base + fps.zoo.len() * size && (addr - base) % size == 0 {
        let idx = (addr - base) / size;
        if let Some(fp) = fps.cells[idx].get() {
            FINGERPRINT.hit();
            return *fp;
        }
        FINGERPRINT.miss();
        *fps.cells[idx].get_or_init(|| fps.zoo[idx].fingerprint())
    } else {
        FINGERPRINT.miss();
        m.fingerprint()
    }
}

// ---------------------------------------------------------------------------
// L2 — shared model walks
// ---------------------------------------------------------------------------

/// Memoized [`ModelTraffic::analyze`].
pub fn traffic(m: &Model, a: &ArrayConfig, dt: DType, batch: u64, glb_bytes: u64) -> Arc<ModelTraffic> {
    let key: TrafficKey = (ModelKey::of(m), ArrayKey::of(a), dt.bytes(), batch, glb_bytes);
    if let Some(hit) = traffic_map().lock().unwrap().get(&key) {
        TRAFFIC.hit();
        return hit.clone();
    }
    // Compute outside the lock: the walk is the expensive part, and a racing
    // duplicate insert produces an identical value (first insert wins).
    TRAFFIC.miss();
    let v = Arc::new(ModelTraffic::analyze(m, a, dt, batch, glb_bytes));
    traffic_map().lock().unwrap().entry(key).or_insert(v).clone()
}

/// Memoized retention walk ([`RetentionAnalysis::analyze`]).
pub fn retention(m: &Model, a: &ArrayConfig, batch: u64) -> Arc<ModelRetention> {
    let key: RetentionKey = (ModelKey::of(m), ArrayKey::of(a), batch);
    if let Some(hit) = retention_map().lock().unwrap().get(&key) {
        RETENTION.hit();
        return hit.clone();
    }
    RETENTION.miss();
    let v = Arc::new(RetentionAnalysis::new(a, batch).analyze(m));
    retention_map().lock().unwrap().entry(key).or_insert(v).clone()
}

/// Memoized zoo-wide worst data-occupancy time (§V.C): the max over every
/// model's retention walk at (array, batch) — the fold the selection grid
/// re-derives for every candidate sharing an array. Keyed by an
/// order-sensitive fold of the zoo's model fingerprints, so ad-hoc test
/// zoos never alias the shared zoo.
pub fn zoo_occupancy(zoo: &[Model], a: &ArrayConfig, batch: u64) -> f64 {
    let fp = zoo.iter().fold(zoo.len() as u64, |acc, m| acc.rotate_left(7) ^ fingerprint_of(m));
    let key: OccupancyKey = (fp, ArrayKey::of(a), batch);
    if let Some(hit) = occupancy_map().lock().unwrap().get(&key) {
        OCCUPANCY.hit();
        return *hit;
    }
    OCCUPANCY.miss();
    let v = zoo.iter().map(|m| retention(m, a, batch).max_t_ret()).fold(0.0, f64::max);
    *occupancy_map().lock().unwrap().entry(key).or_insert(v)
}

/// Memoized [`MonteCarlo::for_technology`]: the Δ-scaling solve, guard-band
/// and driver sizing are pure functions of (technology, targets), so every
/// Monte-Carlo sweep point that varies only `mc_samples` (or re-anchors Δ
/// via [`MonteCarlo::at_delta_gb`], which is a cheap copy) shares one solved
/// engine. `None` for technologies without a PT Monte-Carlo model. Uses the
/// same racy check-then-insert as the walk caches — the closed-form solve
/// is microseconds, so a simultaneous-miss duplicate is harmless (the
/// seconds-scale *runs* get the stricter once-per-key treatment in
/// [`mc_result`]).
pub fn mc_design(id: TechnologyId, targets: &DesignTargets) -> Option<Arc<MonteCarlo>> {
    let key = mc_key(id, targets);
    if let Some(hit) = mc_map().lock().unwrap().get(&key) {
        MC_DESIGN.hit();
        return Some(hit.clone());
    }
    let v = Arc::new(MonteCarlo::for_technology(id, targets)?);
    MC_DESIGN.miss();
    Some(mc_map().lock().unwrap().entry(key).or_insert(v).clone())
}

/// Memoized serial Monte-Carlo run: the aggregate result is a pure function
/// of (technology, targets, Δ_GB, seed, n), so sweep grids that repeat the
/// same MC coordinates across orthogonal axes (model × batch × ...) share
/// one run instead of recomputing a potentially seconds-long sample walk —
/// concurrent first callers block on one computation, they do not race it.
/// `None` for technologies without a PT Monte-Carlo model.
pub fn mc_result(
    id: TechnologyId,
    targets: &DesignTargets,
    delta_gb: f64,
    seed: u64,
    n: u64,
) -> Option<McResult> {
    let mc = mc_design(id, targets)?;
    let key: McRunKey = (mc_key(id, targets), delta_gb.to_bits(), seed, n);
    let cell: McRunCell = {
        let mut map = mc_run_map().lock().unwrap();
        map.entry(key).or_insert_with(|| Arc::new(OnceLock::new())).clone()
    };
    if cell.get().is_some() {
        MC_RUN.hit();
    } else {
        MC_RUN.miss();
    }
    // Outside the map lock: the walk is the expensive part. get_or_init
    // runs it exactly once per key; latecomers block until it is ready.
    Some(cell.get_or_init(|| mc.at_delta_gb(delta_gb).run_serial(seed, n as usize)).clone())
}

// ---------------------------------------------------------------------------
// L1 — per-candidate derived results
// ---------------------------------------------------------------------------

/// Memoized flattened stall walk ([`RetentionAnalysis::stall_plan`] over the
/// memoized traffic, with the write side scaled by `write_intensity` first
/// when it differs from 1 — at 1 the raw walk is used, which
/// [`crate::accel::LayerTraffic::with_write_intensity`] guarantees is
/// bit-identical). Selection grids share one plan across every candidate
/// that differs only in GLB organization/Δ/BER: evaluating a candidate then
/// costs one branch-light [`StallPlan::stalled_latency`] pass instead of a
/// full per-layer walk.
pub fn stall_plan(
    m: &Model,
    a: &ArrayConfig,
    dt: DType,
    batch: u64,
    glb_bytes: u64,
    write_intensity: f64,
    scratchpad: Option<&Scratchpad>,
) -> Arc<StallPlan> {
    let key: StallPlanKey = (
        ModelKey::of(m),
        ArrayKey::of(a),
        dt.bytes(),
        batch,
        glb_bytes,
        write_intensity.to_bits(),
        scratchpad_key(scratchpad),
    );
    if let Some(hit) = stall_plan_map().lock().unwrap().get(&key) {
        STALL_PLAN.hit();
        return hit.clone();
    }
    STALL_PLAN.miss();
    let walk = traffic(m, a, dt, batch, glb_bytes);
    let ra = RetentionAnalysis::new(a, batch);
    let plan = if write_intensity == 1.0 {
        ra.stall_plan(m, &walk, scratchpad)
    } else {
        ra.stall_plan(m, &walk.with_write_intensity(write_intensity), scratchpad)
    };
    let v = Arc::new(plan);
    stall_plan_map().lock().unwrap().entry(key).or_insert(v).clone()
}

/// Memoized DRAM spill row ([`DramOverheadRow::analyze`]): candidates that
/// share (model, array, dtype, batch, GLB, DRAM) — the whole
/// variant × Δ × BER slice of the selection grid — share one spill
/// analysis.
pub fn spill(
    m: &Model,
    a: &ArrayConfig,
    dram: &DramModel,
    dt: DType,
    batch: u64,
    glb_bytes: u64,
) -> Arc<DramOverheadRow> {
    let key: SpillKey =
        (ModelKey::of(m), ArrayKey::of(a), dram_fingerprint(dram), dt.bytes(), batch, glb_bytes);
    if let Some(hit) = spill_map().lock().unwrap().get(&key) {
        SPILL.hit();
        return hit.clone();
    }
    SPILL.miss();
    let v = Arc::new(DramOverheadRow::analyze(m, a, dram, dt, batch, glb_bytes));
    spill_map().lock().unwrap().entry(key).or_insert(v).clone()
}

/// Memoized analytical fault exposure ([`FaultExposure::analyze`]): the
/// powf-heavy per-layer pass is a pure function of (model, dtype, bank
/// split), and the grid's BER budgets collapse to a handful of distinct
/// splits.
pub fn exposure(m: &Model, dt: DType, split: &BankSplit) -> Arc<FaultExposure> {
    let key: ExposureKey = (
        ModelKey::of(m),
        dt.bytes(),
        split.kind.bytes() as u64,
        split.msb_ber.to_bits(),
        split.lsb_ber.to_bits(),
    );
    if let Some(hit) = exposure_map().lock().unwrap().get(&key) {
        EXPOSURE.hit();
        return hit.clone();
    }
    EXPOSURE.miss();
    let v = Arc::new(FaultExposure::analyze(m, dt, split));
    exposure_map().lock().unwrap().entry(key).or_insert(v).clone()
}

/// Drop every cached walk and reset the counters (bench/test hook). The L3
/// fingerprint memo survives — zoo fingerprints are index-stable for the
/// process lifetime and can never go stale — but its counters reset.
pub fn clear() {
    traffic_map().lock().unwrap().clear();
    retention_map().lock().unwrap().clear();
    occupancy_map().lock().unwrap().clear();
    mc_map().lock().unwrap().clear();
    mc_run_map().lock().unwrap().clear();
    stall_plan_map().lock().unwrap().clear();
    spill_map().lock().unwrap().clear();
    exposure_map().lock().unwrap().clear();
    for c in COUNTERS {
        c.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::util::units::MB;

    #[test]
    fn cached_walks_match_direct_analysis() {
        let a = ArrayConfig::paper_42x42();
        let m = models::by_name("ResNet50").unwrap();
        let cached = traffic(&m, &a, DType::Bf16, 4, 12 * MB);
        let direct = ModelTraffic::analyze(&m, &a, DType::Bf16, 4, 12 * MB);
        assert_eq!(cached.total_dram_bytes(), direct.total_dram_bytes());
        assert_eq!(cached.total_glb_reads(), direct.total_glb_reads());
        assert_eq!(cached.layers.len(), direct.layers.len());

        let r1 = retention(&m, &a, 16);
        let r2 = RetentionAnalysis::new(&a, 16).analyze(&m);
        assert_eq!(r1.max_t_ret(), r2.max_t_ret());
        assert_eq!(r1.min_t_ret(), r2.min_t_ret());
    }

    #[test]
    fn repeat_lookups_hit_and_share_the_allocation() {
        let a = ArrayConfig::paper_42x42();
        let m = models::by_name("VGG16").unwrap();
        let first = traffic(&m, &a, DType::Int8, 2, 12 * MB);
        let (h0, _) = stats();
        let second = traffic(&m, &a, DType::Int8, 2, 12 * MB);
        let (h1, _) = stats();
        assert!(h1 > h0, "second lookup must be a hit");
        assert!(Arc::ptr_eq(&first, &second), "hits share one allocation");
    }

    #[test]
    fn distinct_coordinates_do_not_alias() {
        let a = ArrayConfig::paper_42x42();
        let b = ArrayConfig::with_mac_array(14);
        let m = models::by_name("AlexNet").unwrap();
        let r42 = retention(&m, &a, 16);
        let r14 = retention(&m, &b, 16);
        assert!(r42.max_t_ret() < r14.max_t_ret(), "bigger array, shorter occupancy");
        let t1 = traffic(&m, &a, DType::Bf16, 1, 12 * MB);
        let t8 = traffic(&m, &a, DType::Bf16, 8, 12 * MB);
        assert!(t8.total_glb_reads() > t1.total_glb_reads());
    }

    #[test]
    fn zoo_occupancy_matches_the_direct_fold_and_hits() {
        let zoo = models::zoo();
        let a = ArrayConfig::paper_42x42();
        let direct = zoo
            .iter()
            .map(|m| RetentionAnalysis::new(&a, 16).analyze(m).max_t_ret())
            .fold(0.0, f64::max);
        let cached = zoo_occupancy(&zoo, &a, 16);
        assert_eq!(cached, direct);
        let (h0, _) = stats();
        assert_eq!(zoo_occupancy(&zoo, &a, 16), cached);
        let (h1, _) = stats();
        assert!(h1 > h0, "second fold must be a hit");
        // A different zoo slice does not alias the full fold.
        let sub = &zoo[..3];
        let sub_occ = zoo_occupancy(sub, &a, 16);
        assert!(sub_occ <= cached);
    }

    #[test]
    fn mc_designs_are_shared_per_technology_and_targets() {
        let t = DesignTargets::global_buffer();
        let a = mc_design(TechnologyId::SttSakhare2020, &t).unwrap();
        let (h0, _) = stats();
        let b = mc_design(TechnologyId::SttSakhare2020, &t).unwrap();
        let (h1, _) = stats();
        assert!(h1 > h0, "second lookup must be a hit");
        assert!(Arc::ptr_eq(&a, &b), "hits share one solved engine");
        // Distinct targets / technologies do not alias.
        let c = mc_design(TechnologyId::SttSakhare2020, &DesignTargets::lsb_bank()).unwrap();
        assert_ne!(a.delta_guard_banded, c.delta_guard_banded);
        let d = mc_design(TechnologyId::SttWei2019, &t).unwrap();
        assert_ne!(a.write_pulse, d.write_pulse);
        // Technologies without a PT model stay None (and never panic).
        assert!(mc_design(TechnologyId::Sot, &t).is_none());
        assert!(mc_design(TechnologyId::Sram, &t).is_none());
    }

    #[test]
    fn mc_runs_are_memoized_per_coordinates() {
        let t = DesignTargets::global_buffer();
        let a = mc_result(TechnologyId::SttSakhare2020, &t, 27.5, 0xD1E5, 2_000).unwrap();
        let (h0, _) = stats();
        let b = mc_result(TechnologyId::SttSakhare2020, &t, 27.5, 0xD1E5, 2_000).unwrap();
        let (h1, _) = stats();
        assert!(h1 > h0, "second lookup must be a hit");
        assert_eq!(a, b);
        // The memoized run equals a direct engine run, bit for bit.
        let direct = MonteCarlo::for_technology(TechnologyId::SttSakhare2020, &t)
            .unwrap()
            .at_delta_gb(27.5)
            .run_serial(0xD1E5, 2_000);
        assert_eq!(a, direct);
        // Concurrent first callers on a fresh key agree (the per-key
        // OnceLock serializes initialization; latecomers block and read).
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| {
                        mc_result(TechnologyId::SttSakhare2020, &t, 26.5, 0xFEED, 2_000).unwrap()
                    })
                })
                .collect();
            let results: Vec<McResult> = handles.into_iter().map(|h| h.join().unwrap()).collect();
            for r in &results {
                assert_eq!(*r, results[0]);
            }
        });
        // Coordinates are part of the key.
        let c = mc_result(TechnologyId::SttSakhare2020, &t, 27.5, 0xD1E5, 4_000).unwrap();
        assert_eq!(c.n, 4_000);
        assert!(mc_result(TechnologyId::Sram, &t, 27.5, 1, 100).is_none());
    }

    #[test]
    fn same_name_different_shape_does_not_alias() {
        use crate::models::{ConvLayer, Layer};
        let a = ArrayConfig::paper_42x42();
        let mk = |out_ch: u64| Model {
            name: "twin".into(),
            input: (3, 8, 8),
            layers: vec![Layer::Conv(ConvLayer {
                name: "c1".into(),
                in_ch: 3,
                out_ch,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
                groups: 1,
                in_h: 8,
                in_w: 8,
            })],
            reference_params: None,
        };
        let (m1, m2) = (mk(8), mk(16));
        assert_ne!(m1.fingerprint(), m2.fingerprint());
        let t1 = traffic(&m1, &a, DType::Bf16, 1, 12 * MB);
        let t2 = traffic(&m2, &a, DType::Bf16, 1, 12 * MB);
        assert_ne!(t1.layers[0].glb_writes, t2.layers[0].glb_writes);
    }

    #[test]
    fn zoo_fingerprints_are_memoized_and_exact() {
        // L3: a shared-zoo model's memoized fingerprint equals the direct
        // FNV walk, and repeat lookups hit the per-index cell.
        let zoo = crate::dse::engine::shared_zoo();
        let m = &zoo[0];
        assert_eq!(fingerprint_of(m), m.fingerprint());
        let fp_hits = |stats: &[EntryStats]| {
            stats.iter().find(|e| e.name == "model_fingerprint").unwrap().hits
        };
        let h0 = fp_hits(&tier_stats());
        assert_eq!(fingerprint_of(m), m.fingerprint());
        let h1 = fp_hits(&tier_stats());
        assert!(h1 > h0, "second zoo fingerprint must hit the L3 memo");
        // An ad-hoc clone lives outside the zoo buffer: identical value,
        // computed directly (never aliased by address).
        let clone = m.clone();
        assert_eq!(fingerprint_of(&clone), m.fingerprint());
    }

    #[test]
    fn stall_plans_are_memoized_and_match_the_direct_walk() {
        use crate::memsys::{GlbBandwidth, GlbKind};
        let a = ArrayConfig::with_mac_array(84);
        let zoo = crate::dse::engine::shared_zoo();
        let m = zoo.iter().find(|m| m.name == "ResNet50").unwrap();
        let sp = Scratchpad::paper_bf16();
        let plan = stall_plan(m, &a, DType::Bf16, 16, 12 * MB, 1.0, Some(&sp));
        // Bit-identical to the uncached flatten over the uncached traffic.
        let walk = ModelTraffic::analyze(m, &a, DType::Bf16, 16, 12 * MB);
        let direct = RetentionAnalysis::new(&a, 16).stall_plan(m, &walk, Some(&sp));
        assert_eq!(*plan, direct);
        // And evaluating it reproduces the one-shot stalled walk.
        let bw = GlbBandwidth::of(&GlbKind::stt_ai_ultra(), 1.0e-8, 1.0e-5);
        assert_eq!(
            plan.stalled_latency(&bw),
            RetentionAnalysis::new(&a, 16).inference_latency_stalled(m, &walk, &bw, Some(&sp))
        );
        // Same coordinates hit and share the allocation.
        let again = stall_plan(m, &a, DType::Bf16, 16, 12 * MB, 1.0, Some(&sp));
        assert!(Arc::ptr_eq(&plan, &again));
        // Scratchpad presence and write intensity are part of the key.
        let bare = stall_plan(m, &a, DType::Bf16, 16, 12 * MB, 1.0, None);
        assert!(!Arc::ptr_eq(&plan, &bare));
        let train = stall_plan(m, &a, DType::Bf16, 16, 12 * MB, 2.5, Some(&sp));
        let scaled = RetentionAnalysis::new(&a, 16).stall_plan(
            m,
            &walk.with_write_intensity(2.5),
            Some(&sp),
        );
        assert_eq!(*train, scaled);
    }

    #[test]
    fn spill_and_exposure_are_memoized_bit_for_bit() {
        use crate::ber::WordKind;
        let a = ArrayConfig::paper_42x42();
        let zoo = crate::dse::engine::shared_zoo();
        let m = zoo.iter().find(|m| m.name == "VGG16").unwrap();
        let dram = DramModel::ddr4_2933_dual();
        let row = spill(m, &a, &dram, DType::Bf16, 8, 12 * MB);
        let direct = DramOverheadRow::analyze(m, &a, &dram, DType::Bf16, 8, 12 * MB);
        assert_eq!(row.spill_bytes, direct.spill_bytes);
        assert_eq!(row.extra_latency, direct.extra_latency);
        assert_eq!(row.extra_energy, direct.extra_energy);
        assert!(Arc::ptr_eq(&row, &spill(m, &a, &dram, DType::Bf16, 8, 12 * MB)));

        let split = BankSplit::ultra(WordKind::Bf16);
        let exp = exposure(m, DType::Bf16, &split);
        let direct = FaultExposure::analyze(m, DType::Bf16, &split);
        assert_eq!(exp.expected_flips, direct.expected_flips);
        assert_eq!(exp.catastrophic_fraction, direct.catastrophic_fraction);
        assert_eq!(exp.mean_rel_perturbation, direct.mean_rel_perturbation);
        assert!(Arc::ptr_eq(&exp, &exposure(m, DType::Bf16, &split)));
        // The budget is part of the key.
        let relaxed = exposure(m, DType::Bf16, &BankSplit::uniform(WordKind::Bf16, 1.0e-5));
        assert!(relaxed.catastrophic_fraction > exp.catastrophic_fraction);
    }

    #[test]
    fn tier_stats_breaks_the_aggregate_down_per_entry_point() {
        let a = ArrayConfig::paper_42x42();
        let m = models::by_name("GoogLeNet").unwrap();
        let count = |name: &str| {
            let e = tier_stats().into_iter().find(|e| e.name == name).unwrap();
            (e.hits, e.misses)
        };
        let (_, m0) = count("traffic");
        let _ = traffic(&m, &a, DType::Int8, 3, 12 * MB);
        let (h1, m1) = count("traffic");
        assert!(m1 > m0, "fresh coordinate must miss the traffic entry");
        let _ = traffic(&m, &a, DType::Int8, 3, 12 * MB);
        let (h2, _) = count("traffic");
        assert!(h2 > h1, "repeat must hit the traffic entry");
        // Tiers are labeled, and the aggregate equals the per-entry sum.
        let stats_v = tier_stats();
        assert_eq!(stats_v.len(), 9);
        assert!(stats_v.iter().any(|e| e.tier == 1));
        assert!(stats_v.iter().any(|e| e.tier == 2));
        assert!(stats_v.iter().any(|e| e.tier == 3));
        let (h, mi) = stats();
        let sum = stats_v.iter().fold((0, 0), |(a, b), e| (a + e.hits, b + e.misses));
        assert_eq!((h, mi), sum);
    }
}
