//! Δ-scaling sweeps (Fig. 15 a–f and Fig. 17 a–c).


use crate::mram::{DesignTargets, MtjTech, ScalingSolver};

/// A complete Fig. 15/17 panel set for one technology base case.
#[derive(Debug, Clone)]
pub struct DeltaSweep {
    pub tech: String,
    pub ber: f64,
    /// (Δ, retention time s) — Fig. 15(a)(b) / 17(a).
    pub retention: Vec<(f64, f64)>,
    /// (Δ, read pulse s) — Fig. 15(c)(d) / 17(b).
    pub read_pulse: Vec<(f64, f64)>,
    /// (Δ, write pulse s) — Fig. 15(e)(f) / 17(c).
    pub write_pulse: Vec<(f64, f64)>,
}

impl DeltaSweep {
    pub fn run(tech: MtjTech, ber: f64, deltas: &[f64]) -> Self {
        let s = ScalingSolver::new(tech);
        Self {
            tech: tech.name.to_string(),
            ber,
            retention: s.retention_vs_delta(ber, deltas),
            read_pulse: s.read_pulse_vs_delta(ber, deltas),
            write_pulse: s.write_pulse_vs_delta(ber, deltas),
        }
    }

    /// Standard Δ grid of the figures.
    pub fn default_deltas() -> Vec<f64> {
        (10..=60).map(|d| d as f64).collect()
    }
}

/// The three named design points of §V.C–D, solved end to end.
#[derive(Debug, Clone)]
pub struct DesignPointSummary {
    pub label: String,
    pub delta_scaled: f64,
    pub delta_guard_banded: f64,
    pub write_pulse: f64,
    pub read_pulse: f64,
    pub achieved_retention: f64,
    pub rel_write_energy: f64,
    pub rel_cell_area: f64,
}

/// Solve the weight-NVM, GLB, and LSB-bank design points (Fig. 15a/b, 17).
pub fn paper_design_points(tech: MtjTech) -> Vec<DesignPointSummary> {
    let s = ScalingSolver::new(tech);
    [
        ("weight-NVM (3yr @ 1e-9)", DesignTargets::weight_nvm()),
        ("GLB (3s @ 1e-8)", DesignTargets::global_buffer()),
        ("LSB bank (3s @ 1e-5)", DesignTargets::lsb_bank()),
    ]
    .into_iter()
    .map(|(label, t)| {
        let d = s.solve(&t);
        DesignPointSummary {
            label: label.to_string(),
            delta_scaled: d.delta_scaled,
            delta_guard_banded: d.delta_guard_banded,
            write_pulse: d.write_pulse,
            read_pulse: d.read_pulse,
            achieved_retention: d.achieved_retention,
            rel_write_energy: d.rel_write_energy,
            rel_cell_area: d.rel_cell_area,
        }
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_panels_have_grid_length() {
        let deltas = DeltaSweep::default_deltas();
        let s = DeltaSweep::run(MtjTech::sakhare2020(), 1e-8, &deltas);
        assert_eq!(s.retention.len(), deltas.len());
        assert_eq!(s.read_pulse.len(), deltas.len());
        assert_eq!(s.write_pulse.len(), deltas.len());
    }

    #[test]
    fn both_base_cases_run() {
        // Fig. 15 uses [6] for (c)(e) and [13] for (d)(f).
        let deltas = DeltaSweep::default_deltas();
        let a = DeltaSweep::run(MtjTech::sakhare2020(), 1e-8, &deltas);
        let b = DeltaSweep::run(MtjTech::wei2019(), 1e-8, &deltas);
        assert_ne!(a.tech, b.tech);
        // Same physics, different constants → different but same-shaped curves.
        assert!(a.write_pulse[0].1 > 0.0 && b.write_pulse[0].1 > 0.0);
    }

    #[test]
    fn design_points_match_paper() {
        let pts = paper_design_points(MtjTech::sakhare2020());
        assert_eq!(pts.len(), 3);
        let nvm = &pts[0];
        assert!((nvm.delta_scaled - 39.0).abs() < 1.0);
        let glb = &pts[1];
        assert!((glb.delta_scaled - 19.5).abs() < 1.0);
        let lsb = &pts[2];
        assert!((lsb.delta_scaled - 12.5).abs() < 1.0);
        // Relaxed bank is cheapest.
        assert!(lsb.rel_write_energy < glb.rel_write_energy);
        assert!(glb.rel_write_energy < nvm.rel_write_energy);
    }

    #[test]
    fn fig17_relaxed_ber_shrinks_everything() {
        // At the same Δ, relaxing BER 1e-8 → 1e-5 shortens read/write pulses.
        let deltas = vec![17.5];
        let tight = DeltaSweep::run(MtjTech::wei2019(), 1e-8, &deltas);
        let relaxed = DeltaSweep::run(MtjTech::wei2019(), 1e-5, &deltas);
        assert!(relaxed.write_pulse[0].1 < tight.write_pulse[0].1);
        assert!(relaxed.read_pulse[0].1 > tight.read_pulse[0].1); // longer pulse allowed at same RD budget
        assert!(relaxed.retention[0].1 > tight.retention[0].1); // more time within the looser budget
    }
}
