//! Retention-time DSE (Figs. 13–14): GLB data occupancy across the zoo as a
//! function of array size and batch.


use crate::accel::ArrayConfig;
use crate::models::Model;

/// One row of Fig. 13 (per-model retention range) or a cell of Fig. 14.
#[derive(Debug, Clone)]
pub struct RetentionRow {
    pub model: String,
    pub macs: u64,
    pub batch: u64,
    pub min_t_ret: f64,
    pub max_t_ret: f64,
}

impl RetentionRow {
    pub fn analyze(m: &Model, a: &ArrayConfig, batch: u64) -> Self {
        let r = super::cache::retention(m, a, batch);
        Self {
            model: m.name.clone(),
            macs: a.total_macs(),
            batch,
            min_t_ret: r.min_t_ret(),
            max_t_ret: r.max_t_ret(),
        }
    }
}

/// Fig. 13: per-model retention ranges at the paper's operating point.
pub fn fig13(zoo: &[Model]) -> Vec<RetentionRow> {
    let a = ArrayConfig::paper_42x42();
    zoo.iter().map(|m| RetentionRow::analyze(m, &a, 16)).collect()
}

/// Fig. 14a: max retention over the zoo vs MAC-array size (batch 16).
pub fn fig14a(zoo: &[Model], mac_sizes: &[u64]) -> Vec<(u64, f64)> {
    mac_sizes
        .iter()
        .map(|&macs| {
            let a = ArrayConfig::with_mac_array(macs);
            let worst = zoo
                .iter()
                .map(|m| RetentionRow::analyze(m, &a, 16).max_t_ret)
                .fold(0.0, f64::max);
            (macs, worst)
        })
        .collect()
}

/// Fig. 14b: max retention over the zoo vs batch size (42×42 MACs).
pub fn fig14b(zoo: &[Model], batches: &[u64]) -> Vec<(u64, f64)> {
    let a = ArrayConfig::paper_42x42();
    batches
        .iter()
        .map(|&b| {
            let worst =
                zoo.iter().map(|m| RetentionRow::analyze(m, &a, b).max_t_ret).fold(0.0, f64::max);
            (b, worst)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn fig13_shape() {
        let zoo = models::zoo();
        let rows = fig13(&zoo);
        assert_eq!(rows.len(), 19);
        for r in &rows {
            assert!(r.min_t_ret <= r.max_t_ret, "{}", r.model);
            assert!(r.max_t_ret < 1.6, "{}: {}", r.model, r.max_t_ret);
        }
    }

    #[test]
    fn fig14a_monotone_decreasing() {
        let zoo = models::zoo();
        let series = fig14a(&zoo, &[14, 28, 42, 84]);
        assert!(series.windows(2).all(|w| w[1].1 <= w[0].1), "{series:?}");
    }

    #[test]
    fn fig14b_monotone_increasing() {
        let zoo = models::zoo();
        let series = fig14b(&zoo, &[1, 4, 16, 32]);
        assert!(series.windows(2).all(|w| w[1].1 >= w[0].1), "{series:?}");
    }

    #[test]
    fn glb_design_point_covers_worst_case() {
        // The Δ=19.5 design gives 3 s @ 1e-8 — must exceed the worst zoo
        // occupancy at the paper's operating point (Fig. 13 < 1.5 s).
        let zoo = models::zoo();
        let worst = fig13(&zoo).iter().map(|r| r.max_t_ret).fold(0.0, f64::max);
        assert!(worst < 3.0, "worst occupancy {worst} exceeds the 3 s design");
    }
}
