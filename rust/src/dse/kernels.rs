//! Branch-light columnar kernels for the selection/DSE hot path.
//!
//! [`crate::dse::engine::SweepColumns`] (PR 6) laid the candidate metrics
//! out as contiguous `f64` columns; this module supplies the
//! vectorization-friendly inner loops over them:
//!
//! * [`feasible_bitmask`] — all [`CompiledConstraint`]s fused into one pass
//!   per 64-row column chunk, writing a packed `u64` [`Bitmask`] (one
//!   feasibility bit per row);
//! * [`argmin_masked`] — masked column min/argmin with first-wins
//!   tie-breaking, bit-for-bit faithful to `f64::total_cmp` via the
//!   sign-flip integer key ([`total_cmp_key`]);
//! * [`pareto_nondominated`] — a tiled Pareto dominance scan: fixed
//!   [`TILE`]-row source tiles with bounds-check-free lane loops (exact-size
//!   `&[f64; TILE]` views), fanned out across target tiles on
//!   [`ThreadPool::map_range`] and merged caller-side in tile order, so the
//!   frontier is byte-identical at any worker count.
//!
//! The kernels are *pure layout transforms* of the scalar semantics: the
//! [`scalar`] submodule keeps the pre-kernel reference implementations, and
//! `tests/proptests.rs` pins kernel-vs-scalar bit-identity on random
//! columns with NaNs, holes and ties. `benches/kernels.rs` records the
//! scalar-vs-kernel datapoints in the `BENCH_kernels.json` trajectory.

use crate::dse::engine::SweepColumns;
use crate::util::pool::ThreadPool;

/// Rows per Pareto source tile. 64 lanes of `f64` comparisons fit the
/// widest practical vector units a few times over while keeping the
/// per-tile early-exit granularity fine enough that mostly-dominated
/// batches stay cheap.
pub const TILE: usize = 64;

/// Bits per [`Bitmask`] word (the feasibility chunk width).
pub const LANES: usize = 64;

// ---------------------------------------------------------------------------
// Bitmask
// ---------------------------------------------------------------------------

/// A packed per-row bitmask: bit `i % 64` of word `i / 64` is row `i`
/// (little-endian lanes). Tail bits past `len` are always zero, so word-wise
/// reductions (`count`, `indices`, chunk early-exits) never see ghost rows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitmask {
    words: Vec<u64>,
    len: usize,
}

impl Bitmask {
    /// All-zero mask over `len` rows.
    pub fn zeros(len: usize) -> Self {
        Self { words: vec![0; len.div_ceil(LANES)], len }
    }

    /// All-one mask over `len` rows (tail bits trimmed).
    pub fn ones(len: usize) -> Self {
        let mut m = Self { words: vec![!0u64; len.div_ceil(LANES)], len };
        m.trim_tail();
        m
    }

    fn trim_tail(&mut self) {
        let tail = self.len % LANES;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The packed words (tail bits zero).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / LANES] >> (i % LANES)) & 1 == 1
    }

    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / LANES] |= 1u64 << (i % LANES);
    }

    /// Number of set rows (word-wise popcount).
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Set-row indices in ascending order.
    pub fn indices(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.count());
        for (wi, &word) in self.words.iter().enumerate() {
            let mut w = word;
            while w != 0 {
                out.push(wi * LANES + w.trailing_zeros() as usize);
                w &= w - 1;
            }
        }
        out
    }

    /// Unpack to the `Vec<bool>` form the public mask APIs return.
    pub fn to_bools(&self) -> Vec<bool> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    pub fn from_bools(bools: &[bool]) -> Self {
        let mut m = Self::zeros(bools.len());
        for (i, &b) in bools.iter().enumerate() {
            if b {
                m.set(i);
            }
        }
        m
    }
}

// ---------------------------------------------------------------------------
// Fused constraint predicates
// ---------------------------------------------------------------------------

/// A [`crate::dse::select::Constraint`] resolved against one columnar
/// batch's interned keys — the shape the fused feasibility kernel consumes
/// (no string lookups inside the row loop).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CompiledConstraint {
    /// Column `key` must be present and `>= floor`.
    Ge { key: usize, floor: f64 },
    /// Column `key` must be present and `<= cap`.
    Le { key: usize, cap: f64 },
    /// Both columns present and `lhs >= rhs` (the retention-vs-occupancy
    /// pair rule).
    PairGe { lhs: usize, rhs: usize },
    /// The constrained metric is not interned at all: no row can satisfy it.
    Never,
}

/// Evaluate every compiled constraint in one fused pass per 64-row column
/// chunk. Bit `i` of the result is set iff row `i` satisfies *all*
/// constraints — semantics identical to folding
/// [`crate::dse::select::Constraint::satisfied_at`] per row (absent metrics
/// and `NaN` values are infeasible), just without the per-(row × constraint)
/// key lookups and branches.
pub fn feasible_bitmask(cols: &SweepColumns, compiled: &[CompiledConstraint]) -> Bitmask {
    let n = cols.len();
    if compiled.iter().any(|c| matches!(c, CompiledConstraint::Never)) {
        return Bitmask::zeros(n);
    }
    // Presence lanes per distinct constrained key, packed once up front.
    let mut keys: Vec<usize> = Vec::new();
    for c in compiled {
        match *c {
            CompiledConstraint::Ge { key, .. } | CompiledConstraint::Le { key, .. } => {
                keys.push(key)
            }
            CompiledConstraint::PairGe { lhs, rhs } => keys.extend([lhs, rhs]),
            CompiledConstraint::Never => unreachable!("screened above"),
        }
    }
    keys.sort_unstable();
    keys.dedup();
    let presence: Vec<Vec<u64>> = keys.iter().map(|&k| cols.presence_packed(k)).collect();
    let pres = |key: usize, word: usize| {
        presence[keys.binary_search(&key).expect("key collected above")][word]
    };

    let mut mask = Bitmask::ones(n);
    for (w, chunk_base) in (0..n).step_by(LANES).enumerate() {
        let lanes = (n - chunk_base).min(LANES);
        let mut word = mask.words[w];
        for c in compiled {
            if word == 0 {
                break;
            }
            let cw = match *c {
                CompiledConstraint::Ge { key, floor } => {
                    let col = &cols.column(key)[chunk_base..chunk_base + lanes];
                    cmp_word(col, |v| v >= floor) & pres(key, w)
                }
                CompiledConstraint::Le { key, cap } => {
                    let col = &cols.column(key)[chunk_base..chunk_base + lanes];
                    cmp_word(col, |v| v <= cap) & pres(key, w)
                }
                CompiledConstraint::PairGe { lhs, rhs } => {
                    let l = &cols.column(lhs)[chunk_base..chunk_base + lanes];
                    let r = &cols.column(rhs)[chunk_base..chunk_base + lanes];
                    pair_ge_word(l, r) & pres(lhs, w) & pres(rhs, w)
                }
                CompiledConstraint::Never => unreachable!("screened above"),
            };
            word &= cw;
        }
        mask.words[w] = word;
    }
    mask
}

/// Pack one comparison over up to 64 lanes into a word (false for `NaN`,
/// like the scalar comparison).
#[inline]
fn cmp_word(col: &[f64], pred: impl Fn(f64) -> bool) -> u64 {
    let mut w = 0u64;
    for (bit, &v) in col.iter().enumerate() {
        w |= u64::from(pred(v)) << bit;
    }
    w
}

#[inline]
fn pair_ge_word(lhs: &[f64], rhs: &[f64]) -> u64 {
    let mut w = 0u64;
    for (bit, (&l, &r)) in lhs.iter().zip(rhs).enumerate() {
        w |= u64::from(l >= r) << bit;
    }
    w
}

// ---------------------------------------------------------------------------
// Masked min / argmin
// ---------------------------------------------------------------------------

/// The sign-flip integer key: comparing keys with plain `i64::lt` is
/// exactly `f64::total_cmp` on the original values (`-NaN < -inf < … <
/// +inf < +NaN`). Negating a float flips only its sign bit, which reverses
/// this order exactly — so max-objectives reuse the same kernel with
/// `negate = true`, bit-for-bit faithful to the scalar `-v` compare.
#[inline(always)]
pub fn total_cmp_key(bits: u64) -> i64 {
    let b = bits as i64;
    b ^ (((b >> 63) as u64) >> 1) as i64
}

/// Masked argmin under `total_cmp` order with first-wins tie-breaking
/// (`None` when no row is live). Two branch-light passes: a lane-parallel
/// integer min over the masked keys, then the first live row achieving it —
/// which is exactly the index the scalar strictly-less scan holds
/// ([`scalar::argmin_masked`]). `negate` selects the sign-flipped (max
/// objective) view of the column.
pub fn argmin_masked(col: &[f64], mask: &Bitmask, negate: bool) -> Option<usize> {
    debug_assert_eq!(col.len(), mask.len());
    let sign = if negate { 1u64 << 63 } else { 0 };
    let mut min_key = i64::MAX;
    let mut any = false;
    for (chunk, &mword) in col.chunks(LANES).zip(mask.words()) {
        if mword == 0 {
            continue;
        }
        any = true;
        let mut chunk_min = i64::MAX;
        for (bit, &v) in chunk.iter().enumerate() {
            let key = total_cmp_key(v.to_bits() ^ sign);
            let live = (mword >> bit) & 1 == 1;
            // Dead lanes contribute the sentinel; a live lane whose key
            // equals the sentinel is still found by the second pass, which
            // re-checks liveness explicitly.
            chunk_min = chunk_min.min(if live { key } else { i64::MAX });
        }
        min_key = min_key.min(chunk_min);
    }
    if !any {
        return None;
    }
    for (w, (chunk, &mword)) in col.chunks(LANES).zip(mask.words()).enumerate() {
        if mword == 0 {
            continue;
        }
        for (bit, &v) in chunk.iter().enumerate() {
            let live = (mword >> bit) & 1 == 1;
            if live && total_cmp_key(v.to_bits() ^ sign) == min_key {
                return Some(w * LANES + bit);
            }
        }
    }
    unreachable!("a live row achieving the masked min must exist")
}

// ---------------------------------------------------------------------------
// Tiled Pareto dominance scan
// ---------------------------------------------------------------------------

/// Non-dominated mask over dense signed objective columns (every column
/// oriented so *smaller is better*; max objectives are sign-flipped by the
/// caller). Row `a` dominates row `b` when it is `<=` in every column and
/// `<` in at least one — `NaN` lanes compare false on both, so a `NaN` row
/// neither dominates nor is dominated through that column, exactly like the
/// scalar scan.
///
/// Target rows are split into [`TILE`]-sized jobs fanned out on `pool`
/// (byte-identical for any worker count: each bit is a pure function of the
/// full column set, and [`ThreadPool::map_range`] merges in tile order).
/// Source rows are scanned in exact-size `&[f64; TILE]` tiles — the inner
/// lane loops carry no bounds checks — with a per-tile early exit once a
/// dominator is found.
pub fn pareto_nondominated(signed: &[Vec<f64>], pool: &ThreadPool) -> Vec<bool> {
    let Some(n) = signed.first().map(Vec::len) else {
        return Vec::new();
    };
    debug_assert!(signed.iter().all(|c| c.len() == n), "ragged objective columns");
    let tiles = n.div_ceil(TILE);
    let masks = pool.map_range(tiles, |t| {
        let lo = t * TILE;
        let hi = (lo + TILE).min(n);
        (lo..hi).map(|b| !dominated(signed, n, b)).collect::<Vec<bool>>()
    });
    masks.concat()
}

/// Does any source row dominate target `b`?
#[inline]
fn dominated(signed: &[Vec<f64>], n: usize, b: usize) -> bool {
    let full = n - n % TILE;
    let mut base = 0;
    while base < full {
        if tile_dominates(signed, base, b) {
            return true;
        }
        base += TILE;
    }
    span_dominates(signed, full, n, b)
}

/// Branchless dominance accumulation over one exact source tile.
#[inline]
fn tile_dominates(signed: &[Vec<f64>], base: usize, b: usize) -> bool {
    let mut le = [true; TILE];
    let mut lt = [false; TILE];
    for col in signed {
        let tb = col[b];
        let lane: &[f64; TILE] =
            col[base..base + TILE].try_into().expect("exact tile slice");
        for ((le, lt), &v) in le.iter_mut().zip(lt.iter_mut()).zip(lane) {
            *le &= v <= tb;
            *lt |= v < tb;
        }
    }
    le.iter().zip(&lt).any(|(&le, &lt)| le & lt)
}

/// Dominance over a short (tail) source span.
#[inline]
fn span_dominates(signed: &[Vec<f64>], lo: usize, hi: usize, b: usize) -> bool {
    let mut dom = false;
    for a in lo..hi {
        let mut le = true;
        let mut lt = false;
        for col in signed {
            let (av, tb) = (col[a], col[b]);
            le &= av <= tb;
            lt |= av < tb;
        }
        dom |= le & lt;
    }
    dom
}

// ---------------------------------------------------------------------------
// Scalar reference implementations
// ---------------------------------------------------------------------------

/// The pre-kernel scalar implementations, kept as the bit-identity oracle:
/// `tests/proptests.rs` pins kernel == scalar on random columns with NaNs,
/// holes and ties, and `benches/kernels.rs` reports the scalar-vs-kernel
/// speedup datapoints against these exact loops.
pub mod scalar {
    /// PR 6's closure-based O(n²) frontier scan over signed columns.
    pub fn nondominated(signed: &[Vec<f64>]) -> Vec<bool> {
        let Some(n) = signed.first().map(Vec::len) else {
            return Vec::new();
        };
        let dominates = |a: usize, b: usize| {
            signed.iter().all(|c| c[a] <= c[b]) && signed.iter().any(|c| c[a] < c[b])
        };
        (0..n).map(|b| !(0..n).any(|a| dominates(a, b))).collect()
    }

    /// PR 6's winner scan: strictly-less `total_cmp` update over live rows
    /// (first-wins tie-breaking), on the optionally sign-flipped column.
    pub fn argmin_masked(col: &[f64], live: &[bool], negate: bool) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, &v) in col.iter().enumerate() {
            if !live[i] {
                continue;
            }
            let signed = if negate { -v } else { v };
            let better = match best {
                None => true,
                Some((_, held)) => signed.total_cmp(&held) == std::cmp::Ordering::Less,
            };
            if better {
                best = Some((i, signed));
            }
        }
        best.map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::engine::{DesignPoint, SweepResult};
    use crate::util::rng::Rng;

    #[test]
    fn bitmask_tail_and_roundtrip() {
        for len in [0usize, 1, 2, 63, 64, 65, 127, 128, 130] {
            let ones = Bitmask::ones(len);
            assert_eq!(ones.count(), len, "len={len}");
            assert_eq!(ones.indices(), (0..len).collect::<Vec<_>>());
            assert_eq!(ones.to_bools(), vec![true; len]);
            assert_eq!(Bitmask::from_bools(&ones.to_bools()), ones);
            let zeros = Bitmask::zeros(len);
            assert_eq!(zeros.count(), 0);
            assert!(zeros.indices().is_empty());
            // Tail bits past `len` stay zero even for the all-ones mask.
            if len % LANES != 0 {
                let tail = *ones.words().last().unwrap() >> (len % LANES);
                assert_eq!(tail, 0, "len={len}");
            }
        }
        let mut m = Bitmask::zeros(130);
        for i in [0usize, 63, 64, 65, 129] {
            m.set(i);
        }
        assert_eq!(m.indices(), vec![0, 63, 64, 65, 129]);
        assert_eq!(m.count(), 5);
        assert!(m.get(64) && !m.get(1));
    }

    #[test]
    fn total_cmp_key_orders_like_total_cmp() {
        let vals = [
            f64::NEG_INFINITY,
            -1.5,
            -0.0,
            0.0,
            1e-300,
            2.5,
            f64::INFINITY,
            f64::NAN,
            -f64::NAN,
        ];
        for &a in &vals {
            for &b in &vals {
                assert_eq!(
                    total_cmp_key(a.to_bits()).cmp(&total_cmp_key(b.to_bits())),
                    a.total_cmp(&b),
                    "a={a} b={b}"
                );
            }
        }
    }

    fn mask_of(live: &[bool]) -> Bitmask {
        Bitmask::from_bools(live)
    }

    #[test]
    fn argmin_first_wins_and_handles_nan() {
        let col = [3.0, 1.0, 1.0, f64::NAN, 0.5];
        let all = vec![true; col.len()];
        // Ties break to the first index; NaN sorts above every real value
        // under total_cmp so it never wins against one.
        assert_eq!(argmin_masked(&col, &mask_of(&all), false), Some(4));
        let no_last = [true, true, true, true, false];
        assert_eq!(argmin_masked(&col, &mask_of(&no_last), false), Some(1));
        // Max objective: sign-flip view.
        assert_eq!(argmin_masked(&col, &mask_of(&all), true), Some(0));
        // All-NaN column: the first live row wins (matches the scalar scan).
        let nans = [f64::NAN, f64::NAN, f64::NAN];
        assert_eq!(argmin_masked(&nans, &mask_of(&[true; 3]), false), Some(0));
        assert_eq!(argmin_masked(&nans, &mask_of(&[false, true, true]), false), Some(1));
        // Empty mask → no winner.
        assert_eq!(argmin_masked(&col, &mask_of(&[false; 5]), false), None);
        assert_eq!(argmin_masked(&[], &Bitmask::zeros(0), false), None);
    }

    #[test]
    fn argmin_matches_scalar_reference_on_random_columns() {
        let mut rng = Rng::seed_from_u64(0xA561);
        for case in 0..200 {
            let n = 1 + rng.below(200) as usize;
            // Small discrete support forces ties; sprinkle NaNs and signs.
            let col: Vec<f64> = (0..n)
                .map(|_| match rng.below(8) {
                    0 => f64::NAN,
                    k => (k as f64 - 4.0) * 0.5,
                })
                .collect();
            let live: Vec<bool> = (0..n).map(|_| rng.below(4) != 0).collect();
            for negate in [false, true] {
                assert_eq!(
                    argmin_masked(&col, &mask_of(&live), negate),
                    scalar::argmin_masked(&col, &live, negate),
                    "case={case} negate={negate}"
                );
            }
        }
    }

    #[test]
    fn pareto_matches_scalar_and_is_worker_invariant() {
        let mut rng = Rng::seed_from_u64(0x9A12E);
        for case in 0..60 {
            let n = 1 + rng.below(180) as usize;
            let k = 1 + rng.below(4) as usize;
            let signed: Vec<Vec<f64>> = (0..k)
                .map(|_| {
                    (0..n)
                        .map(|_| match rng.below(10) {
                            0 => f64::NAN,
                            v => v as f64,
                        })
                        .collect()
                })
                .collect();
            let reference = scalar::nondominated(&signed);
            for workers in [1, 2, 8] {
                assert_eq!(
                    pareto_nondominated(&signed, &ThreadPool::new(workers)),
                    reference,
                    "case={case} workers={workers} n={n} k={k}"
                );
            }
        }
    }

    #[test]
    fn pareto_tile_boundaries_and_equal_rows() {
        // Exactly one tile, one-past, and multi-tile sizes; equal rows must
        // both stay on the frontier (le holds, lt does not).
        for n in [1usize, 2, TILE - 1, TILE, TILE + 1, 3 * TILE + 7] {
            let col: Vec<f64> = (0..n).map(|i| (i / 2) as f64).collect();
            let signed = vec![col];
            let nd = pareto_nondominated(&signed, &ThreadPool::new(1));
            // Only the global minima (rows 0 and, for n>1, row 1 — equal
            // values) are non-dominated in a single min column.
            for (i, &keep) in nd.iter().enumerate() {
                assert_eq!(keep, i < 2.min(n), "n={n} i={i}");
            }
        }
        assert_eq!(pareto_nondominated(&[], &ThreadPool::new(4)), Vec::<bool>::new());
    }

    fn batch(rows: Vec<Vec<(&'static str, f64)>>) -> SweepColumns {
        let results: Vec<SweepResult> = rows
            .into_iter()
            .map(|metrics| SweepResult {
                sweep: "t".into(),
                point: DesignPoint::default(),
                metrics,
            })
            .collect();
        SweepColumns::from_results(&results)
    }

    #[test]
    fn feasible_bitmask_fuses_constraints_with_presence() {
        let cols = batch(vec![
            vec![("acc", 0.995), ("ret", 10.0), ("occ", 1.0)],
            vec![("acc", 0.5), ("ret", 10.0), ("occ", 1.0)], // fails floor
            vec![("acc", 0.999), ("ret", 0.5), ("occ", 1.0)], // fails pair
            vec![("acc", 0.999)],                             // hole: no ret/occ
            vec![("acc", f64::NAN), ("ret", 10.0), ("occ", 1.0)], // NaN fails
        ]);
        let acc = cols.key_index("acc").unwrap();
        let ret = cols.key_index("ret").unwrap();
        let occ = cols.key_index("occ").unwrap();
        let compiled = [
            CompiledConstraint::Ge { key: acc, floor: 0.99 },
            CompiledConstraint::PairGe { lhs: ret, rhs: occ },
        ];
        let mask = feasible_bitmask(&cols, &compiled);
        assert_eq!(mask.to_bools(), vec![true, false, false, false, false]);
        assert_eq!(mask.indices(), vec![0]);
        // An unresolvable constraint blanks the whole mask.
        let never = [CompiledConstraint::Never];
        assert_eq!(feasible_bitmask(&cols, &never).count(), 0);
        // No constraints: everything feasible (tail bits still trimmed).
        assert_eq!(feasible_bitmask(&cols, &[]).count(), cols.len());
    }

    #[test]
    fn feasible_bitmask_le_cap_and_chunk_tail() {
        // 70 rows crosses the 64-lane chunk boundary.
        let rows: Vec<Vec<(&'static str, f64)>> =
            (0..70).map(|i| vec![("area", i as f64)]).collect();
        let cols = batch(rows);
        let area = cols.key_index("area").unwrap();
        let mask =
            feasible_bitmask(&cols, &[CompiledConstraint::Le { key: area, cap: 66.0 }]);
        assert_eq!(mask.count(), 67);
        assert!(mask.get(66) && !mask.get(67));
    }
}
