//! Scratchpad DSE: partial-ofmap sizing (Fig. 18) and the three-way buffer
//! energy comparison SRAM / MRAM / MRAM+scratchpad (Fig. 19).


use crate::accel::ArrayConfig;
use crate::memsys::{BufferSystem, EnergyLedger, GlbKind, Scratchpad};
use crate::models::{DType, Model};
use crate::util::units::MB;

/// One row of Fig. 18: max partial-ofmap size for a model.
#[derive(Debug, Clone)]
pub struct PartialOfmapRow {
    pub model: String,
    pub bf16_bytes: u64,
    pub int8_bytes: u64,
}

impl PartialOfmapRow {
    pub fn analyze(m: &Model) -> Self {
        Self {
            model: m.name.clone(),
            bf16_bytes: m.max_partial_ofmap(DType::Bf16),
            int8_bytes: m.max_partial_ofmap(DType::Int8),
        }
    }
}

/// One bar group of Fig. 19: buffer energy of one inference under the three
/// buffer organizations.
#[derive(Debug, Clone)]
pub struct ScratchpadEnergyRow {
    pub model: String,
    pub batch: u64,
    pub sram: EnergyLedger,
    pub mram: EnergyLedger,
    pub mram_scratchpad: EnergyLedger,
}

impl ScratchpadEnergyRow {
    pub fn analyze(m: &Model, a: &ArrayConfig, dt: DType, batch: u64) -> Self {
        let glb = 12 * MB;
        let systems = [
            BufferSystem::new(GlbKind::baseline(), glb, None),
            BufferSystem::new(GlbKind::stt_ai(), glb, None),
            BufferSystem::new(GlbKind::stt_ai(), glb, Some(Scratchpad::paper_bf16())),
        ];
        let traffic = super::cache::traffic(m, a, dt, batch, glb);
        let mut ledgers = systems.iter().map(|sys| {
            let mut total = EnergyLedger::default();
            for l in &traffic.layers {
                total.add(&sys.layer_energy(
                    l.glb_reads,
                    l.glb_writes,
                    l.partial_bytes,
                    l.partial_rounds,
                    l.dram_bytes,
                ));
            }
            total
        });
        Self {
            model: m.name.clone(),
            batch,
            sram: ledgers.next().unwrap(),
            mram: ledgers.next().unwrap(),
            mram_scratchpad: ledgers.next().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::util::units::KB;

    #[test]
    fn fig18_majority_fit_52kb() {
        let zoo = models::zoo();
        let fit = zoo
            .iter()
            .map(PartialOfmapRow::analyze)
            .filter(|r| r.bf16_bytes <= 52 * KB)
            .count();
        assert!(fit * 4 >= zoo.len() * 3, "{fit}/19 fit 52 KB bf16");
        // int8 halves the requirement.
        let r = PartialOfmapRow::analyze(&models::by_name("ResNet50").unwrap());
        assert_eq!(r.bf16_bytes, 2 * r.int8_bytes);
    }

    #[test]
    fn fig19_scratchpad_beats_bare_mram_beats_sram() {
        // Paper Fig. 19 (ResNet-50): SRAM > MRAM > MRAM+scratchpad.
        let a = ArrayConfig::paper_42x42();
        let m = models::by_name("ResNet50").unwrap();
        let r = ScratchpadEnergyRow::analyze(&m, &a, DType::Bf16, 16);
        assert!(
            r.mram_scratchpad.total() < r.mram.total(),
            "scratchpad must cut MRAM buffer energy: {} vs {}",
            r.mram_scratchpad.total(),
            r.mram.total()
        );
        assert!(
            r.mram.total() < r.sram.total(),
            "12 MB MRAM must beat SRAM: {} vs {}",
            r.mram.total(),
            r.sram.total()
        );
    }

    #[test]
    fn fig19_partial_traffic_is_visible() {
        let a = ArrayConfig::paper_42x42();
        let m = models::by_name("ResNet50").unwrap();
        let r = ScratchpadEnergyRow::analyze(&m, &a, DType::Bf16, 16);
        assert!(r.mram_scratchpad.scratchpad > 0.0, "scratchpad must absorb traffic");
        // The saving is material (>3% of buffer energy for ResNet-50).
        let saving = 1.0 - r.mram_scratchpad.total() / r.mram.total();
        assert!(saving > 0.03, "saving={saving}");
    }
}
