//! SRAM vs STT-MRAM energy/area capacity sweep (Fig. 16 a–d).


use crate::memsys::MemoryArray;
use crate::util::units::MB;

/// One capacity point of Fig. 16.
#[derive(Debug, Clone)]
pub struct EnergyAreaRow {
    pub capacity_bytes: u64,
    pub delta_guard_banded: f64,
    /// Average per-access energy (J), 2:1 read:write mix.
    pub sram_energy: f64,
    pub mram_energy: f64,
    /// Macro area (mm²).
    pub sram_area: f64,
    pub mram_area: f64,
}

impl EnergyAreaRow {
    pub fn at(capacity_bytes: u64, delta_guard_banded: f64) -> Self {
        let s = MemoryArray::sram(capacity_bytes);
        let m = MemoryArray::stt_mram(capacity_bytes, delta_guard_banded);
        let mix = 2.0;
        Self {
            capacity_bytes,
            delta_guard_banded,
            sram_energy: s.avg_energy_j(mix),
            mram_energy: m.avg_energy_j(mix),
            sram_area: s.area_mm2(),
            mram_area: m.area_mm2(),
        }
    }

    pub fn energy_ratio(&self) -> f64 {
        self.sram_energy / self.mram_energy
    }

    pub fn area_ratio(&self) -> f64 {
        self.sram_area / self.mram_area
    }
}

/// Fig. 16(a)(b): GLB design point Δ_PT_GB = 27.5 across capacities.
pub fn fig16_glb(capacities_mb: &[u64]) -> Vec<EnergyAreaRow> {
    capacities_mb.iter().map(|&c| EnergyAreaRow::at(c * MB, 27.5)).collect()
}

/// Fig. 16(c)(d): LSB-bank design point Δ_PT_GB = 17.5 across capacities.
pub fn fig16_lsb(capacities_mb: &[u64]) -> Vec<EnergyAreaRow> {
    capacities_mb.iter().map(|&c| EnergyAreaRow::at(c * MB, 17.5)).collect()
}

/// Standard capacity grid of the figure.
pub fn default_capacities_mb() -> Vec<u64> {
    vec![1, 2, 4, 8, 12, 16, 24, 32, 48, 64]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn area_advantage_exceeds_10x_beyond_4mb() {
        for r in fig16_glb(&[4, 8, 12, 32]) {
            assert!(r.area_ratio() > 10.0, "at {} B: {}", r.capacity_bytes, r.area_ratio());
        }
    }

    #[test]
    fn energy_advantage_grows_with_capacity() {
        let rows = fig16_glb(&default_capacities_mb());
        let ratios: Vec<f64> = rows.iter().map(|r| r.energy_ratio()).collect();
        assert!(ratios.windows(2).all(|w| w[1] >= w[0] - 1e-12), "{ratios:?}");
        // Significant advantage beyond 4 MB (paper's headline observation).
        let at12 = rows.iter().find(|r| r.capacity_bytes == 12 * MB).unwrap();
        assert!(at12.energy_ratio() > 1.5, "{}", at12.energy_ratio());
    }

    #[test]
    fn lsb_bank_strictly_better_than_glb_bank() {
        let glb = fig16_glb(&[12]);
        let lsb = fig16_lsb(&[12]);
        assert!(lsb[0].mram_energy < glb[0].mram_energy);
        assert!(lsb[0].mram_area < glb[0].mram_area);
    }

    #[test]
    fn sram_wins_below_crossover() {
        let rows = fig16_glb(&[1]);
        assert!(rows[0].energy_ratio() < 1.0, "SRAM must win at 1 MB");
    }
}
