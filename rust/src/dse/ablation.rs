//! Ablation studies for the design choices DESIGN.md calls out.
//!
//! * guard-band on/off — what Eq. 17–18 actually buys (§IV.C);
//! * refresh-based ultra-low-Δ GLB (the [33]-style alternative the paper
//!   rejects) vs the paper's scaled-but-refresh-free design;
//! * P_s (PE dot-product width) sweep — the Fig. 3 core parameter;
//! * write-overdrive sweep — latency/energy trade of §IV.B.

use crate::accel::ArrayConfig;
use crate::memsys::MemoryArray;
use crate::models::Model;
use crate::mram::{
    retention_failure_prob, write_pulse_at_wer, DesignTargets, MtjTech, PtVariation,
    ScalingSolver,
};
use crate::util::units::MB;

/// Guard-band ablation: failure probability of the −4σ/hot die when the
/// design skips Eq. 17.
#[derive(Debug, Clone)]
pub struct GuardBandAblation {
    /// P_RF at the worst corner with guard-banding (should be ≤ budget).
    pub p_rf_guarded: f64,
    /// P_RF at the worst corner when the MTJ is built at Δ_scaled directly.
    pub p_rf_unguarded: f64,
    pub budget: f64,
}

pub fn guard_band_ablation(tech: MtjTech, targets: &DesignTargets) -> GuardBandAblation {
    let v = PtVariation::paper();
    let solver = ScalingSolver::with_variation(tech, v);
    let d = solver.solve(targets);
    // Worst corner Δ for a *guarded* build: Δ_scaled by construction.
    let p_guarded = retention_failure_prob(targets.retention_time, tech.tau_ret, d.delta_scaled);
    // Unguarded build at Δ_scaled: the −4σ/hot die drops below Δ_scaled.
    let worst_unguarded =
        v.delta_at(d.delta_scaled, -v.n_sigma, v.t_hot);
    let p_unguarded =
        retention_failure_prob(targets.retention_time, tech.tau_ret, worst_unguarded);
    GuardBandAblation {
        p_rf_guarded: p_guarded,
        p_rf_unguarded: p_unguarded,
        budget: targets.retention_ber,
    }
}

/// Refresh ablation: scale Δ below the occupancy requirement and pay
/// DRAM-like refresh (periodic rewrite of the whole GLB) instead.
#[derive(Debug, Clone)]
pub struct RefreshAblation {
    pub delta_guard_banded: f64,
    /// Refresh period to keep the per-bit failure within budget (s).
    pub refresh_period: f64,
    /// Average refresh power for a 12 MB GLB (W).
    pub refresh_power_w: f64,
    /// Leakage saved vs the paper's Δ=27.5 design (W) — the upside.
    pub leakage_saved_w: f64,
    /// Net win? (the paper's position: no for seconds-scale occupancy.)
    pub net_power_w: f64,
}

pub fn refresh_ablation(delta_scaled: f64, ber: f64) -> RefreshAblation {
    let tech = MtjTech::sakhare2020();
    let v = PtVariation::paper();
    let gb = v.guard_band(delta_scaled);
    // Refresh period: retention time at the BER budget for this Δ.
    let period = crate::mram::retention_time_at_ber(tech.tau_ret, delta_scaled, ber);
    let glb = MemoryArray::stt_mram(12 * MB, gb.delta_guard_banded);
    // One refresh = read + write every word.
    let words = (12 * MB) as f64 / 8.0;
    let e_refresh = words * (glb.read_energy_j() + glb.write_energy_j());
    let p_refresh = e_refresh / period;
    // Leakage difference vs the Δ_PT_GB = 27.5 paper design: periphery
    // leakage shrinks slightly with Δ.
    let p27 = MemoryArray::stt_mram(12 * MB, 27.5).leakage_mw() * 1e-3;
    let p_this = glb.leakage_mw() * 1e-3;
    RefreshAblation {
        delta_guard_banded: gb.delta_guard_banded,
        refresh_period: period,
        refresh_power_w: p_refresh,
        leakage_saved_w: p27 - p_this,
        net_power_w: p_refresh - (p27 - p_this),
    }
}

/// P_s sweep: steps per output channel (∝ conv time, Eq. 2/5) for a layer
/// as the PE dot-product width varies at a fixed MAC budget.
pub fn ps_sweep(m: &Model, batch: u64, ps_values: &[u64]) -> Vec<(u64, f64)> {
    ps_values
        .iter()
        .map(|&ps| {
            let base = ArrayConfig::paper_42x42();
            // Fixed MAC budget: W_A·H_A·P_s = 1764.
            let w_a = (42 / ps).max(1);
            let a = ArrayConfig { p_s: ps, w_a, h_a: 42, ..base };
            let worst = super::cache::retention(m, &a, batch).max_t_ret();
            (ps, worst)
        })
        .collect()
}

/// Overdrive sweep: write pulse needed at each I_w/I_c (Fig. 15e/f's knob).
pub fn overdrive_sweep(delta: f64, wer: f64, ratios: &[f64]) -> Vec<(f64, f64, f64)> {
    let tech = MtjTech::sakhare2020();
    ratios
        .iter()
        .map(|&i| {
            let t = write_pulse_at_wer(wer, tech.tau_w, delta, i);
            // Energy ∝ I²·t (relative units, I in I_c multiples).
            (i, t, i * i * t)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn guard_band_is_necessary_and_sufficient() {
        let g = guard_band_ablation(MtjTech::sakhare2020(), &DesignTargets::global_buffer());
        assert!(g.p_rf_guarded <= g.budget * 1.01, "guarded {} > budget", g.p_rf_guarded);
        // Without the guard band the worst-corner die blows the budget by
        // orders of magnitude.
        assert!(
            g.p_rf_unguarded > 100.0 * g.budget,
            "unguarded {} vs budget {}",
            g.p_rf_unguarded,
            g.budget
        );
    }

    #[test]
    fn refresh_does_not_pay_for_seconds_occupancy() {
        // Scale Δ to 10 (retention ~ tens of ms at 1e-8) and refresh: the
        // refresh power dwarfs the periphery-leakage saving — the paper's
        // reason to scale only down to the occupancy time.
        let r = refresh_ablation(10.0, 1e-8);
        assert!(r.refresh_period < 1.0, "{}", r.refresh_period);
        assert!(r.net_power_w > 0.0, "refresh must cost net power: {:?}", r);
    }

    #[test]
    fn refresh_period_grows_with_delta() {
        let a = refresh_ablation(10.0, 1e-8);
        let b = refresh_ablation(14.0, 1e-8);
        assert!(b.refresh_period > a.refresh_period);
        assert!(b.refresh_power_w < a.refresh_power_w);
    }

    #[test]
    fn ps_3_optimal_for_3x3_kernels() {
        // The paper's P_s = 3 matches the dominant 3×3 kernel width:
        // ceil(3/3) = 1 wastes no lanes. At a fixed MAC budget it ties
        // P_s = 1 on VGG16 (pure 3×3) and strictly beats P_s = 2
        // (ceil(3/2) = 2 → a third of the lanes idle).
        let m = models::by_name("VGG16").unwrap();
        let sweep = ps_sweep(&m, 16, &[1, 2, 3]);
        let at = |p: u64| sweep.iter().find(|(q, _)| *q == p).unwrap().1;
        assert!(at(3) <= at(1) * 1.01, "P_s=3 {} vs P_s=1 {}", at(3), at(1));
        assert!(at(3) < at(2), "P_s=3 {} must beat P_s=2 {}", at(3), at(2));
    }

    #[test]
    fn ps_sweep_exposes_1x1_utilization_cost() {
        // Ablation finding: for 1×1-heavy nets (ResNet-50 bottlenecks) the
        // 3-wide dot-product block leaves lanes idle — P_s = 1 at the same
        // MAC budget is faster. This is the known utilization cost of the
        // Fig. 3 reconfigurable block, traded for the mux-free 3×3 path.
        let m = models::by_name("ResNet50").unwrap();
        let sweep = ps_sweep(&m, 16, &[1, 3]);
        let at = |p: u64| sweep.iter().find(|(q, _)| *q == p).unwrap().1;
        assert!(at(1) < at(3), "{} vs {}", at(1), at(3));
    }

    #[test]
    fn overdrive_trades_latency_for_energy() {
        let sweep = overdrive_sweep(27.5, 1e-8, &[1.5, 2.0, 3.0, 4.0]);
        // Pulse shrinks monotonically with overdrive…
        assert!(sweep.windows(2).all(|w| w[1].1 <= w[0].1));
        // …but energy is not monotone decreasing — beyond some point the I²
        // factor wins, which is why I_w is a *knob*, not a free lunch.
        let energies: Vec<f64> = sweep.iter().map(|s| s.2).collect();
        assert!(
            energies.last().unwrap() > energies.first().unwrap()
                || energies.windows(2).any(|w| w[1] > w[0]),
            "{energies:?}"
        );
    }
}
