//! Design-space exploration sweeps (paper §V.A–E, Figs. 10–19).
//!
//! Each submodule produces the data series of one or more paper figures as
//! plain structs; the `report` module renders them and the criterion benches
//! measure their regeneration cost.

pub mod ablation;
pub mod capacity;
pub mod delta;
pub mod energy_area;
pub mod retention;
pub mod scratchpad;

pub use capacity::{CapacityRow, DramOverheadRow};
pub use delta::DeltaSweep;
pub use energy_area::EnergyAreaRow;
pub use retention::RetentionRow;
pub use scratchpad::{PartialOfmapRow, ScratchpadEnergyRow};
