//! Design-space exploration (paper §V.A–E, Figs. 10–19).
//!
//! The per-figure submodules hold the *analysis* (one row / one point of a
//! figure as a plain struct); [`engine`] composes them into declarative
//! [`engine::SweepSpec`] cross-products evaluated in parallel on the
//! work-stealing pool, producing the unified [`engine::SweepResult`] records
//! that `report` renders and exports. [`cache`] memoizes the per-layer
//! traffic/retention model walks those analyses share, across sweeps and
//! figures. [`select`] closes the co-design loop: an objective/constraint
//! layer over the sweep records (Pareto frontier, accuracy/retention/budget
//! constraints) that picks the deployment's design point and hands it to
//! the serving coordinator as a [`select::DesignSelection`]. [`kernels`]
//! supplies the branch-light columnar inner loops (fused feasibility
//! bitmasks, masked argmin, pool-tiled Pareto scan) the selection hot path
//! runs on.

pub mod ablation;
pub mod cache;
pub mod capacity;
pub mod delta;
pub mod energy_area;
pub mod engine;
pub mod kernels;
pub mod retention;
pub mod scratchpad;
pub mod select;

pub use capacity::{CapacityRow, DramOverheadRow};
pub use delta::DeltaSweep;
pub use energy_area::EnergyAreaRow;
pub use engine::{Axis, DesignPoint, Runner, SweepColumns, SweepResult, SweepSpec};
pub use retention::RetentionRow;
pub use scratchpad::{PartialOfmapRow, ScratchpadEnergyRow};
pub use select::{Constraint, DesignSelection, Objective, SelectionGrid};
