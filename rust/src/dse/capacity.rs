//! GLB capacity design-space exploration (Figs. 10–12).


use crate::accel::ArrayConfig;
use crate::memsys::DramModel;
use crate::models::{DType, Model};

/// One row of the Fig. 10/11 model-size and capacity tables.
#[derive(Debug, Clone)]
pub struct CapacityRow {
    pub model: String,
    /// Fig. 10a: full model size (bytes) at int8/bf16.
    pub size_int8: u64,
    pub size_bf16: u64,
    /// Fig. 10b: conv activation-map size range (elements).
    pub fmap_min: u64,
    pub fmap_max: u64,
    /// Fig. 10c: conv weight size range (elements).
    pub weight_min: u64,
    pub weight_max: u64,
    /// Fig. 11: required GLB bytes to avoid DRAM access, per batch size.
    pub glb_required: Vec<(u64, u64)>, // (batch, bytes)
}

impl CapacityRow {
    pub fn analyze(m: &Model, dt: DType, batches: &[u64]) -> Self {
        let (fmap_min, fmap_max) = m.conv_fmap_range();
        let (weight_min, weight_max) = m.conv_weight_range();
        Self {
            model: m.name.clone(),
            size_int8: m.size_bytes(DType::Int8),
            size_bf16: m.size_bytes(DType::Bf16),
            fmap_min,
            fmap_max,
            weight_min,
            weight_max,
            glb_required: batches.iter().map(|&b| (b, m.max_conv_working_set(dt, b))).collect(),
        }
    }
}

/// One row of the Fig. 12 extra-DRAM-overhead analysis.
#[derive(Debug, Clone)]
pub struct DramOverheadRow {
    pub model: String,
    pub dtype_bytes: u64,
    pub batch: u64,
    pub glb_bytes: u64,
    /// Spilled bytes (write-out + read-back).
    pub spill_bytes: u64,
    /// Extra DRAM latency (s), Fig. 12(a)(b).
    pub extra_latency: f64,
    /// Extra DRAM energy (J), Fig. 12(c)(d).
    pub extra_energy: f64,
}

impl DramOverheadRow {
    pub fn analyze(
        m: &Model,
        a: &ArrayConfig,
        dram: &DramModel,
        dt: DType,
        batch: u64,
        glb_bytes: u64,
    ) -> Self {
        let t = super::cache::traffic(m, a, dt, batch, glb_bytes);
        let spill = t.total_dram_bytes();
        // A zero-spill row is uniformly zero by construction, not by
        // accident of the DRAM model's internals: the invariant is pinned
        // here (and by `zero_spill_row_is_uniformly_zero`) so neither the
        // burst latency nor any energy term can ever leak into a row that
        // moved no bytes, whatever the transfer formulas grow into.
        let (extra_latency, extra_energy) = if spill == 0 {
            (0.0, 0.0)
        } else {
            (dram.transfer_latency(spill), dram.transfer_energy(spill))
        };
        Self {
            model: m.name.clone(),
            dtype_bytes: dt.bytes(),
            batch,
            glb_bytes,
            spill_bytes: spill,
            extra_latency,
            extra_energy,
        }
    }
}

/// Fig. 11 aggregate: the GLB capacity that covers *all* models at a batch.
pub fn glb_capacity_for_zoo(zoo: &[Model], dt: DType, batch: u64) -> u64 {
    zoo.iter().map(|m| m.max_conv_working_set(dt, batch)).max().unwrap_or(0)
}

/// Count of zoo models fully served (zero spill) by a GLB size at a batch.
pub fn models_served(zoo: &[Model], dt: DType, batch: u64, glb_bytes: u64) -> usize {
    zoo.iter().filter(|m| m.max_conv_working_set(dt, batch) <= glb_bytes).count()
}

/// Working set of a magnitude-pruned model: sparse weights shrink by the
/// prune rate (index overhead folded into `overhead`, e.g. CSR-ish 1.1),
/// activations are unchanged. The paper's "if pruned models are used, a
/// batch of more images can fit into the GLB".
pub fn pruned_working_set(m: &Model, dt: DType, batch: u64, prune_rate: f64, overhead: f64) -> u64 {
    let keep = (1.0 - prune_rate) * overhead;
    m.conv_layers()
        .map(|c| {
            batch * (c.ifmap_elems() + c.ofmap_elems()) * dt.bytes()
                + ((c.weight_elems() * dt.bytes()) as f64 * keep) as u64
        })
        .max()
        .unwrap_or(0)
}

/// Largest batch a GLB can hold for a model (optionally pruned).
pub fn max_batch_served(m: &Model, dt: DType, glb_bytes: u64, prune_rate: f64) -> u64 {
    let mut batch = 0;
    while batch < 1024 {
        let next = batch + 1;
        let ws = if prune_rate > 0.0 {
            pruned_working_set(m, dt, next, prune_rate, 1.1)
        } else {
            m.max_conv_working_set(dt, next)
        };
        if ws > glb_bytes {
            break;
        }
        batch = next;
    }
    batch
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::util::units::MB;

    #[test]
    fn fig11_12mb_covers_small_batches_int8() {
        // Paper: ≤12 MB suffices for batch ≤ 2 at int8 for a max over the
        // zoo; with 12 MB most models support batch 8.
        let zoo = models::zoo();
        let served_b2 = models_served(&zoo, DType::Int8, 2, 12 * MB);
        assert!(served_b2 >= 17, "batch 2 int8: {served_b2}/19 in 12 MB");
        let served_b8 = models_served(&zoo, DType::Int8, 8, 12 * MB);
        assert!(served_b8 * 2 >= zoo.len(), "most models at batch 8: {served_b8}");
        // bf16 batch 1 fits all (paper: "for BF16, 12MB would suffice for
        // batch size 1 for all models").
        let served_bf16 = models_served(&zoo, DType::Bf16, 1, 12 * MB);
        assert!(served_bf16 >= 17, "bf16 batch 1: {served_bf16}/19");
    }

    #[test]
    fn fig12_latency_bounds() {
        // Paper: int8/batch-8 spill latency ≈ 0 for most models, ~ms for a
        // few; bf16 within ~10 ms.
        let zoo = models::zoo();
        let a = ArrayConfig::paper_42x42();
        let d = DramModel::ddr4_2933_dual();
        let mut worst_int8 = 0.0f64;
        let mut worst_bf16 = 0.0f64;
        for m in &zoo {
            let r = DramOverheadRow::analyze(m, &a, &d, DType::Int8, 8, 12 * MB);
            worst_int8 = worst_int8.max(r.extra_latency);
            let r = DramOverheadRow::analyze(m, &a, &d, DType::Bf16, 8, 12 * MB);
            worst_bf16 = worst_bf16.max(r.extra_latency);
        }
        assert!(worst_int8 < 8e-3, "worst int8 spill latency {worst_int8}");
        assert!(worst_bf16 < 15e-3, "worst bf16 spill latency {worst_bf16}");
        assert!(worst_bf16 > worst_int8);
    }

    #[test]
    fn zero_spill_row_is_uniformly_zero() {
        // ResNet-50 int8 batch 8 fits 12 MB (see fig12_no_spill test): the
        // overhead row must charge nothing at all — latency AND energy.
        let a = ArrayConfig::paper_42x42();
        let d = DramModel::ddr4_2933_dual();
        let m = models::by_name("ResNet50").unwrap();
        let r = DramOverheadRow::analyze(&m, &a, &d, DType::Int8, 8, 12 * MB);
        assert_eq!(r.spill_bytes, 0);
        assert_eq!(r.extra_latency, 0.0);
        assert_eq!(r.extra_energy, 0.0);
        // A spilling row charges both.
        let r = DramOverheadRow::analyze(&m, &a, &d, DType::Bf16, 16, 2 * MB);
        assert!(r.spill_bytes > 0 && r.extra_latency > 0.0 && r.extra_energy > 0.0);
    }

    #[test]
    fn fig12_energy_drops_with_glb_size() {
        let a = ArrayConfig::paper_42x42();
        let d = DramModel::ddr4_2933_dual();
        let m = models::by_name("VGG19").unwrap();
        let mut last = f64::INFINITY;
        for glb_mb in [2u64, 4, 8, 12, 24] {
            let r = DramOverheadRow::analyze(&m, &a, &d, DType::Bf16, 4, glb_mb * MB);
            assert!(r.extra_energy <= last);
            last = r.extra_energy;
        }
    }

    #[test]
    fn pruning_never_hurts_batch_capacity() {
        // Paper §V.A says pruned models fit more images. Our per-layer
        // residency analysis refines that: conv-layer working sets in this
        // zoo are *activation*-bound, so 50% weight pruning never reduces —
        // and at a 12 MB GLB rarely increases — the admissible batch. The
        // weight-bound regime where pruning does buy batches is exercised
        // below.
        let zoo = models::zoo();
        for m in &zoo {
            let dense = max_batch_served(m, DType::Bf16, 12 * MB, 0.0);
            let pruned = max_batch_served(m, DType::Bf16, 12 * MB, 0.5);
            assert!(pruned >= dense, "{}: {pruned} < {dense}", m.name);
        }
    }

    #[test]
    fn pruning_buys_batches_in_weight_bound_regime() {
        // A deep, small-fmap, wide-channel layer (Darknet-53's tail shape)
        // is weight-bound: there pruning admits strictly larger batches.
        use crate::models::{ConvLayer, Layer, Model};
        let tail = Model {
            name: "tail".into(),
            input: (512, 16, 16),
            layers: vec![Layer::Conv(ConvLayer {
                name: "d1024".into(),
                in_ch: 512,
                out_ch: 1024,
                kh: 3,
                kw: 3,
                stride: 2,
                pad: 1,
                groups: 1,
                in_h: 16,
                in_w: 16,
            })],
            reference_params: None,
        };
        // 9.4 MB of weights vs ~0.4 MB of activations per image (bf16).
        let glb = 11 * MB;
        let dense = max_batch_served(&tail, DType::Bf16, glb, 0.0);
        let pruned = max_batch_served(&tail, DType::Bf16, glb, 0.5);
        assert!(pruned > dense, "pruned {pruned} must exceed dense {dense}");
        assert!(pruned >= dense + 4, "weight-bound layer should gain several batches");
    }

    #[test]
    fn pruned_working_set_interpolates() {
        let m = models::by_name("VGG16").unwrap();
        let full = pruned_working_set(&m, DType::Bf16, 1, 0.0, 1.0);
        assert_eq!(full, m.max_conv_working_set(DType::Bf16, 1));
        let half = pruned_working_set(&m, DType::Bf16, 1, 0.5, 1.0);
        assert!(half < full);
        let none = pruned_working_set(&m, DType::Bf16, 1, 1.0, 1.0);
        assert!(none < half);
    }

    #[test]
    fn capacity_row_ranges_ordered() {
        let m = models::by_name("ResNet50").unwrap();
        let r = CapacityRow::analyze(&m, DType::Bf16, &[1, 2, 4, 8]);
        assert!(r.fmap_min <= r.fmap_max);
        assert!(r.weight_min <= r.weight_max);
        assert_eq!(r.size_bf16, 2 * r.size_int8);
        // GLB requirement grows with batch.
        assert!(r.glb_required.windows(2).all(|w| w[1].1 >= w[0].1));
    }
}
