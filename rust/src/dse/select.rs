//! Objective/constraint-driven design-point selection over sweep records.
//!
//! The paper's central flow is *model-driven co-design*: the right STT-MRAM
//! design point (GLB variant, Δ scaling, bank split, BER budget) is derived
//! from the DSE sweeps, not hand-picked. This module closes that loop over
//! the unified [`SweepResult`] records:
//!
//! * [`Objective`] — what a deployment optimizes (minimize accelerator
//!   area / buffer energy / latency, maximize throughput);
//! * [`Constraint`] — what it must not violate (estimated-accuracy floor,
//!   retention ≥ data-occupancy time, area/power budgets);
//! * [`pareto_mask`] — non-dominated-frontier extraction across the
//!   objective metrics;
//! * [`select`] — feasibility filter → Pareto frontier → scored winner,
//!   returned as a [`DesignSelection`] that carries the winning
//!   [`DesignPoint`] plus its provenance (sweep name, objective,
//!   constraint set, metrics, candidate/feasible/frontier counts);
//! * [`spec_selection`] — the candidate grid (GLB variant × Δ × BER budget
//!   × GLB capacity × MAC array on the paper's serving workload), evaluated
//!   like any other sweep on the [`crate::dse::engine::Runner`] pool and
//!   memoized through [`crate::dse::cache`];
//! * the serving bridge — [`DesignSelection::system_config`],
//!   [`DesignSelection::ber_config`] and
//!   [`DesignSelection::glb_kind`] let `coordinator::Engine`/`serve` boot
//!   from a *selected* point (`stt-ai serve --from-selection`), with no
//!   hard-coded `GlbVariant` on the path.
//!
//! Under the paper's own deployment objective — minimum accelerator area at
//! an iso-accuracy floor with retention covering occupancy — the frontier
//! selects the STT-AI Ultra point (Δ 27.5/17.5 split banks at BER
//! 1e-8/1e-5, ≈75.4 % area saving vs the SRAM baseline); `tests/select.rs`
//! pins that golden.

use std::path::Path;

use crate::accel::ArrayConfig;
use crate::ber::{BankSplit, WordKind};
use crate::config::{BerConfig, DTypeConfig, GlbVariant, SystemConfig, TechConfig};
use crate::dse::cache;
use crate::dse::capacity::DramOverheadRow;
use crate::dse::engine::{
    variant_stall_context, Axis, DesignPoint, SweepColumns, SweepResult, SweepSpec, Zoo,
};
use crate::dse::kernels;
use crate::util::pool::ThreadPool;
use crate::memsys::{BufferSystem, DramModel, EnergyLedger, GlbKind};
use crate::models::{DType, Model};
use crate::mram::technology::finite_or_max;
use crate::report::table3::{AcceleratorSummary, CoreCosts};
use crate::util::json::Json;
use crate::util::units::MB;

// ---------------------------------------------------------------------------
// Objective
// ---------------------------------------------------------------------------

/// What a deployment optimizes. Each objective names one metric of the
/// selection records and an orientation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Minimize composed accelerator silicon area (`accel_area_mm2`).
    MinArea,
    /// Minimize buffer energy per inference batch (`buffer_energy_j`).
    MinEnergy,
    /// Minimize end-to-end inference latency (`latency_s`).
    MinLatency,
    /// Maximize served requests per second (`throughput_rps`).
    MaxThroughput,
}

impl Objective {
    /// The record metric this objective scores.
    pub fn metric(&self) -> &'static str {
        match self {
            Objective::MinArea => "accel_area_mm2",
            Objective::MinEnergy => "buffer_energy_j",
            Objective::MinLatency => "latency_s",
            Objective::MaxThroughput => "throughput_rps",
        }
    }

    /// Orientation: `true` when a smaller metric value is better.
    pub fn lower_is_better(&self) -> bool {
        !matches!(self, Objective::MaxThroughput)
    }

    /// Canonical CLI/serialization token (`--objective area`).
    pub fn token(&self) -> &'static str {
        match self {
            Objective::MinArea => "area",
            Objective::MinEnergy => "energy",
            Objective::MinLatency => "latency",
            Objective::MaxThroughput => "throughput",
        }
    }

    /// Parse a CLI token.
    pub fn from_token(s: &str) -> Option<Self> {
        match s.to_lowercase().replace('-', "_").as_str() {
            "area" | "min_area" => Some(Objective::MinArea),
            "energy" | "min_energy" => Some(Objective::MinEnergy),
            "latency" | "min_latency" => Some(Objective::MinLatency),
            "throughput" | "max_throughput" => Some(Objective::MaxThroughput),
            _ => None,
        }
    }

    /// Every objective, in the canonical (frontier) order.
    pub fn all() -> [Objective; 4] {
        [Objective::MinArea, Objective::MinEnergy, Objective::MinLatency, Objective::MaxThroughput]
    }
}

// ---------------------------------------------------------------------------
// Constraint
// ---------------------------------------------------------------------------

/// A feasibility constraint over one selection record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Constraint {
    /// Estimated normalized accuracy (`est_accuracy`) must stay at or above
    /// this floor (the paper's iso-accuracy condition; 0.99 ⇔ "<1 % drop").
    MinAccuracy(f64),
    /// Worst-bank retention at the BER budget must cover the worst data
    /// occupancy time of the workload (`retention_at_ber_s ≥ occupancy_s`,
    /// the §V.C design rule).
    RetentionCoversOccupancy,
    /// Composed accelerator area budget (mm²).
    MaxAreaMm2(f64),
    /// Composed accelerator total-power budget (mW).
    MaxPowerMw(f64),
}

impl Constraint {
    /// Does `r` satisfy this constraint? Records missing the constrained
    /// metric are conservatively infeasible.
    pub fn satisfied(&self, r: &SweepResult) -> bool {
        let ge = |name: &str, floor: f64| r.metric_opt(name).is_some_and(|v| v >= floor);
        let le = |name: &str, cap: f64| r.metric_opt(name).is_some_and(|v| v <= cap);
        match self {
            Constraint::MinAccuracy(floor) => ge("est_accuracy", *floor),
            Constraint::RetentionCoversOccupancy => match
                (r.metric_opt("retention_at_ber_s"), r.metric_opt("occupancy_s"))
            {
                (Some(ret), Some(occ)) => ret >= occ,
                _ => false,
            },
            Constraint::MaxAreaMm2(cap) => le("accel_area_mm2", *cap),
            Constraint::MaxPowerMw(cap) => le("accel_power_mw", *cap),
        }
    }

    /// [`Constraint::satisfied`] against one row of a columnar batch — the
    /// form the selection hot path uses so feasibility never re-scans a
    /// record's metric list per constraint.
    pub fn satisfied_at(&self, cols: &SweepColumns, row: usize) -> bool {
        let ge = |name: &str, floor: f64| cols.value(row, name).is_some_and(|v| v >= floor);
        let le = |name: &str, cap: f64| cols.value(row, name).is_some_and(|v| v <= cap);
        match self {
            Constraint::MinAccuracy(floor) => ge("est_accuracy", *floor),
            Constraint::RetentionCoversOccupancy => {
                match (cols.value(row, "retention_at_ber_s"), cols.value(row, "occupancy_s")) {
                    (Some(ret), Some(occ)) => ret >= occ,
                    _ => false,
                }
            }
            Constraint::MaxAreaMm2(cap) => le("accel_area_mm2", *cap),
            Constraint::MaxPowerMw(cap) => le("accel_power_mw", *cap),
        }
    }

    /// Stable provenance string (stored in the selection record).
    pub fn describe(&self) -> String {
        match self {
            Constraint::MinAccuracy(f) => format!("est_accuracy>={f}"),
            Constraint::RetentionCoversOccupancy => "retention_at_ber_s>=occupancy_s".to_string(),
            Constraint::MaxAreaMm2(c) => format!("accel_area_mm2<={c}"),
            Constraint::MaxPowerMw(c) => format!("accel_power_mw<={c}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Pareto frontier + selection
// ---------------------------------------------------------------------------

/// Per-record feasibility under a constraint set (columnar under the hood;
/// see [`feasible_mask_columns`] when a [`SweepColumns`] view already
/// exists).
pub fn feasible_mask(results: &[SweepResult], constraints: &[Constraint]) -> Vec<bool> {
    feasible_mask_columns(&SweepColumns::from_results(results), constraints)
}

/// [`feasible_mask`] over an existing columnar view. The constraints are
/// compiled once against the batch's interned keys and fused into a single
/// bitmask pass per 64-row column chunk
/// ([`kernels::feasible_bitmask`]) — bit-identical to folding
/// [`Constraint::satisfied_at`] per row.
pub fn feasible_mask_columns(cols: &SweepColumns, constraints: &[Constraint]) -> Vec<bool> {
    feasible_bitmask_columns(cols, constraints).to_bools()
}

/// The packed form of [`feasible_mask_columns`] (the shape [`select`]
/// consumes directly).
fn feasible_bitmask_columns(cols: &SweepColumns, constraints: &[Constraint]) -> kernels::Bitmask {
    let compiled = compile_constraints(cols, constraints);
    kernels::feasible_bitmask(cols, &compiled)
}

/// Resolve each [`Constraint`] against the batch's interned keys into the
/// lookup-free form the fused kernel consumes. A metric the batch never
/// interned compiles to [`kernels::CompiledConstraint::Never`] (no row can
/// satisfy it — same as [`Constraint::satisfied_at`] returning false
/// everywhere).
fn compile_constraints(
    cols: &SweepColumns,
    constraints: &[Constraint],
) -> Vec<kernels::CompiledConstraint> {
    use kernels::CompiledConstraint as K;
    let ge = |name: &str, floor: f64| match cols.key_index(name) {
        Some(key) => K::Ge { key, floor },
        None => K::Never,
    };
    let le = |name: &str, cap: f64| match cols.key_index(name) {
        Some(key) => K::Le { key, cap },
        None => K::Never,
    };
    constraints
        .iter()
        .map(|c| match c {
            Constraint::MinAccuracy(floor) => ge("est_accuracy", *floor),
            Constraint::RetentionCoversOccupancy => {
                match (cols.key_index("retention_at_ber_s"), cols.key_index("occupancy_s")) {
                    (Some(lhs), Some(rhs)) => K::PairGe { lhs, rhs },
                    _ => K::Never,
                }
            }
            Constraint::MaxAreaMm2(cap) => le("accel_area_mm2", *cap),
            Constraint::MaxPowerMw(cap) => le("accel_power_mw", *cap),
        })
        .collect()
}

/// Non-dominated mask over the given objectives. Record `a` dominates `b`
/// when it is at least as good on every objective and strictly better on at
/// least one. An objective participates when *some* record carries its
/// metric; records missing a live objective metric are excluded from the
/// frontier (mask false) rather than comparing as if present, so
/// mixed-layout batches cannot smuggle hole-`NaN`s into the dominance scan.
pub fn pareto_mask(results: &[SweepResult], objectives: &[Objective]) -> Vec<bool> {
    pareto_mask_columns(&SweepColumns::from_results(results), objectives)
}

/// [`pareto_mask`] over an existing columnar view.
pub fn pareto_mask_columns(cols: &SweepColumns, objectives: &[Objective]) -> Vec<bool> {
    pareto_mask_columns_with(cols, objectives, &frontier_pool(cols.len()))
}

/// [`pareto_mask_columns`] on an explicit pool. The frontier is
/// byte-identical at any worker count (the tiled scan fans target tiles out
/// on the pool and merges caller-side in tile order); exposing the pool lets
/// tests and benches pin/vary the width.
pub fn pareto_mask_columns_with(
    cols: &SweepColumns,
    objectives: &[Objective],
    pool: &ThreadPool,
) -> Vec<bool> {
    let rows: Vec<usize> = (0..cols.len()).collect();
    pareto_rows_with(cols, objectives, &rows, pool)
}

/// Candidate batches below this row count run the tiled scan serially — the
/// per-job overhead of fanning tile jobs out would dominate the O(n²/64)
/// tile work itself.
const FRONTIER_PAR_ROWS: usize = 1024;

/// Pool choice for an internal frontier scan over `rows` candidates.
fn frontier_pool(rows: usize) -> ThreadPool {
    if rows >= FRONTIER_PAR_ROWS {
        ThreadPool::auto()
    } else {
        ThreadPool::new(1)
    }
}

/// Non-dominated mask over a row subset of a columnar batch (the mask is
/// indexed like `rows`). An objective is live when its metric is interned
/// *and* carried by at least one subset row; subset rows missing any live
/// metric are excluded (mask false) and take no part in dominance. With no
/// live objective the whole subset is trivially non-dominated.
fn pareto_rows(cols: &SweepColumns, objectives: &[Objective], rows: &[usize]) -> Vec<bool> {
    pareto_rows_with(cols, objectives, rows, &frontier_pool(rows.len()))
}

fn pareto_rows_with(
    cols: &SweepColumns,
    objectives: &[Objective],
    rows: &[usize],
    pool: &ThreadPool,
) -> Vec<bool> {
    let mut live: Vec<(usize, bool)> = Vec::new();
    for o in objectives {
        if let Some(key) = cols.key_index(o.metric()) {
            let seen = live.iter().any(|&(k, _)| k == key);
            if !seen && rows.iter().any(|&r| cols.has(r, key)) {
                live.push((key, o.lower_is_better()));
            }
        }
    }
    if live.is_empty() {
        return vec![true; rows.len()];
    }
    // Gather the complete rows (those carrying every live metric) into
    // dense signed sub-columns: smaller is always better (negating flips
    // the f64 sign bit, which reverses `total_cmp`'s order exactly, so the
    // signed view is faithful to the per-record compare). Incomplete rows
    // stay masked out.
    let mut mask = vec![false; rows.len()];
    let complete: Vec<usize> = (0..rows.len())
        .filter(|&i| live.iter().all(|&(key, _)| cols.has(rows[i], key)))
        .collect();
    if complete.is_empty() {
        return mask;
    }
    let signed: Vec<Vec<f64>> = live
        .iter()
        .map(|&(key, lower)| {
            let col = cols.column(key);
            complete
                .iter()
                .map(|&i| if lower { col[rows[i]] } else { -col[rows[i]] })
                .collect()
        })
        .collect();
    let nondominated = kernels::pareto_nondominated(&signed, pool);
    for (&i, keep) in complete.iter().zip(nondominated) {
        mask[i] = keep;
    }
    mask
}

/// Version tag of the latency model behind `latency_s`/`throughput_rps` in
/// the selection records. Bumped when the scoring physics changes so a
/// pinned golden record carries its own provenance: `write-bw-stall-v1` is
/// the per-layer write-bandwidth stall model
/// ([`crate::memsys::bandwidth`]); records predating the tag were scored by
/// the variant-invariant pure compute walk (`compute-walk-v0`).
pub const LATENCY_MODEL: &str = "write-bw-stall-v1";

/// The latency-model tag assumed for records that predate [`LATENCY_MODEL`]
/// provenance.
pub const LATENCY_MODEL_LEGACY: &str = "compute-walk-v0";

/// The outcome of a [`select`] run: the winning design point plus the full
/// provenance needed to rebuild (and audit) the serving configuration.
#[derive(Debug, Clone)]
pub struct DesignSelection {
    /// Name of the sweep the candidates came from (e.g. `selection`).
    pub sweep: String,
    pub objective: Objective,
    /// Stable description of the applied constraint set.
    pub constraints: Vec<String>,
    /// Version of the latency model that scored the candidates (see
    /// [`LATENCY_MODEL`]).
    pub latency_model: String,
    /// The winning coordinate.
    pub point: DesignPoint,
    /// The winner's full metric record.
    pub metrics: Vec<(String, f64)>,
    /// Objective metric value of the winner.
    pub score: f64,
    /// Candidate / feasible / frontier population sizes.
    pub candidates: usize,
    pub feasible: usize,
    pub frontier: usize,
}

impl DesignSelection {
    /// Metric by name, if the record carries it.
    pub fn metric(&self, name: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    /// The selected GLB variant (defaults to the paper's serving pick when
    /// the sweep did not vary the variant axis).
    pub fn variant(&self) -> GlbVariant {
        self.point.variant.unwrap_or(GlbVariant::SttAiUltra)
    }

    /// Materialize a [`SystemConfig`] at the selected point: variant, GLB
    /// capacity, dtype, MAC array, technology and Δ design point all come
    /// from the record (unset axes keep the paper defaults of the variant's
    /// constructor, scratchpad included).
    pub fn system_config(&self) -> SystemConfig {
        let variant = self.variant();
        let mut cfg = match variant {
            GlbVariant::Sram => SystemConfig::paper_baseline(),
            GlbVariant::SttAi => SystemConfig::paper_stt_ai(),
            GlbVariant::SttAiUltra => SystemConfig::paper_stt_ai_ultra(),
        };
        cfg.name = format!("selected-{}-{}", self.objective.token(), variant.label());
        if let Some(mb) = self.point.glb_mb {
            cfg.glb_bytes = mb * MB;
        }
        if let Some(dt) = self.point.dtype {
            cfg.dtype = match dt {
                DType::Int8 => DTypeConfig::Int8,
                DType::Bf16 => DTypeConfig::Bf16,
            };
        }
        if let Some(side) = self.point.macs {
            cfg.array = ArrayConfig::with_mac_array(side);
        }
        cfg.tech = TechConfig {
            base: self.point.tech.unwrap_or_default(),
            glb_delta_override: self.point.delta,
            lsb_delta_override: self.point.delta.map(lsb_delta_for),
        };
        cfg
    }

    /// The GLB bank structure at the selected point.
    pub fn glb_kind(&self) -> GlbKind {
        let cfg = self.system_config();
        cfg.glb.kind_for(&cfg.tech)
    }

    /// The fault-injection budget at the selected point (variant structure
    /// with the record's BER budget applied).
    pub fn ber_config(&self) -> BerConfig {
        BerConfig::for_selection(self.variant(), self.point.ber)
    }

    /// Modeled GLB energy per served request: the record's per-inference
    /// `buffer_energy_j` (scored for one whole batch) divided by the batch
    /// the sweep evaluated at (paper default 16). `None` when the record
    /// carries no usable energy metric — the fleet simulator then falls
    /// back to the variant's paper constant
    /// ([`crate::coordinator::EngineSpec::paper`]).
    pub fn energy_per_request_j(&self) -> Option<f64> {
        let batch = self.point.batch.unwrap_or(16).max(1) as f64;
        self.metric("buffer_energy_j").filter(|e| e.is_finite() && *e > 0.0).map(|e| e / batch)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sweep", Json::Str(self.sweep.clone())),
            ("objective", Json::Str(self.objective.token().to_string())),
            (
                "constraints",
                Json::Arr(self.constraints.iter().map(|c| Json::Str(c.clone())).collect()),
            ),
            ("latency_model", Json::Str(self.latency_model.clone())),
            ("point", self.point.to_json()),
            (
                "metrics",
                Json::Obj(
                    self.metrics.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
                ),
            ),
            ("score", Json::Num(self.score)),
            ("candidates", (self.candidates as u64).into()),
            ("feasible", (self.feasible as u64).into()),
            ("frontier", (self.frontier as u64).into()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let objective_token = j.req_str("objective").map_err(anyhow::Error::from)?;
        let objective = Objective::from_token(objective_token)
            .ok_or_else(|| anyhow::anyhow!("unknown objective {objective_token:?}"))?;
        let constraints = match j.get("constraints").and_then(Json::as_arr) {
            Some(cs) => cs
                .iter()
                .map(|c| {
                    c.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow::anyhow!("constraints must be strings"))
                })
                .collect::<anyhow::Result<_>>()?,
            None => Vec::new(),
        };
        let metrics = match j.get("metrics").and_then(Json::as_obj) {
            Some(m) => m
                .iter()
                .map(|(k, v)| {
                    v.as_f64()
                        .map(|v| (k.clone(), v))
                        .ok_or_else(|| anyhow::anyhow!("metric {k:?} must be a number"))
                })
                .collect::<anyhow::Result<_>>()?,
            None => Vec::new(),
        };
        Ok(Self {
            sweep: j.req_str("sweep").map_err(anyhow::Error::from)?.to_string(),
            objective,
            constraints,
            // Records written before the stall model carry no tag: they were
            // scored by the pure compute walk.
            latency_model: j
                .get("latency_model")
                .and_then(Json::as_str)
                .unwrap_or(LATENCY_MODEL_LEGACY)
                .to_string(),
            point: DesignPoint::from_json(j.req("point").map_err(anyhow::Error::from)?)?,
            metrics,
            score: j
                .req("score")
                .map_err(anyhow::Error::from)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("score must be a number"))?,
            candidates: j.req_u64("candidates").map_err(anyhow::Error::from)? as usize,
            feasible: j.req_u64("feasible").map_err(anyhow::Error::from)? as usize,
            frontier: j.req_u64("frontier").map_err(anyhow::Error::from)? as usize,
        })
    }

    /// Check the record's point against the current zoo before it drives a
    /// sweep or boots an engine: `--from-selection` files carry arbitrary
    /// model strings, and an unknown one must surface as a clean CLI error
    /// instead of a worker panic deep in the sweep pool.
    pub fn validate(&self) -> anyhow::Result<()> {
        if let Some(name) = &self.point.model {
            resolve_model(&crate::dse::engine::shared_zoo(), name)?;
        }
        Ok(())
    }

    pub fn save(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))?;
        Ok(())
    }

    /// Load and [`Self::validate`] a saved record (`select --out` files;
    /// the `--from-selection` boot path).
    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let sel = Self::from_json(&Json::parse(text.trim()).map_err(anyhow::Error::from)?)?;
        sel.validate()?;
        Ok(sel)
    }

    /// CSV schema: provenance columns + the point's axis columns + metrics.
    pub fn csv_header(&self) -> String {
        let mut cols = vec![
            "sweep".to_string(),
            "objective".to_string(),
            "score".to_string(),
            "latency_model".to_string(),
        ];
        cols.extend(self.point.columns().iter().map(|(k, _)| k.to_string()));
        cols.extend(self.metrics.iter().map(|(k, _)| k.clone()));
        cols.join(",")
    }

    pub fn csv_row(&self) -> String {
        let mut cols = vec![
            self.sweep.clone(),
            self.objective.token().to_string(),
            format!("{:.6e}", self.score),
            self.latency_model.clone(),
        ];
        cols.extend(self.point.columns().into_iter().map(|(_, v)| v));
        cols.extend(self.metrics.iter().map(|(_, v)| format!("{v:.6e}")));
        cols.join(",")
    }
}

/// Feasibility filter → Pareto frontier → scored winner.
///
/// The frontier is taken over every [`Objective`] whose metric the records
/// carry; the winner is the frontier member with the best value of the
/// requested objective (ties broken by record order, so selection is
/// deterministic for a deterministic sweep).
pub fn select(
    sweep: &str,
    results: &[SweepResult],
    objective: Objective,
    constraints: &[Constraint],
) -> anyhow::Result<DesignSelection> {
    if results.is_empty() {
        anyhow::bail!("selection needs at least one candidate record");
    }
    // One columnar view for the whole pass: feasibility, the frontier and
    // the winner scan all walk contiguous metric columns instead of
    // re-scanning every record's `Vec<(&str, f64)>` per probe.
    let cols = SweepColumns::from_results(results);
    // Keys are interned from the records, so a missing index means no
    // record carries the objective metric at all.
    let Some(obj_key) = cols.key_index(objective.metric()) else {
        anyhow::bail!(
            "sweep {sweep:?} carries no {:?} metric for objective {:?}",
            objective.metric(),
            objective.token()
        );
    };
    let feasible = feasible_bitmask_columns(&cols, constraints);
    let rows = feasible.indices();
    let n_feasible = rows.len();
    if n_feasible == 0 {
        let described: Vec<String> = constraints.iter().map(Constraint::describe).collect();
        anyhow::bail!(
            "no feasible design point among {} candidates under {:?}",
            results.len(),
            described
        );
    }
    let frontier = pareto_rows(&cols, &Objective::all(), &rows);
    let n_frontier = frontier.iter().filter(|f| **f).count();
    // Winner scan over the frontier: masked argmin on the gathered
    // objective sub-column under the sign-flipped `total_cmp` key — the
    // kernel's two-pass min + first-match keeps the record path's
    // first-wins tie-breaking bit-for-bit.
    let obj_col = cols.column(obj_key);
    let lower = objective.lower_is_better();
    let sub: Vec<f64> = rows.iter().map(|&row| obj_col[row]).collect();
    let mut live = kernels::Bitmask::zeros(rows.len());
    for (i, &row) in rows.iter().enumerate() {
        if frontier[i] && cols.has(row, obj_key) {
            live.set(i);
        }
    }
    let winner = kernels::argmin_masked(&sub, &live, !lower)
        .map(|i| &results[rows[i]])
        .ok_or_else(|| anyhow::anyhow!("Pareto frontier carries no {:?} metric", objective.metric()))?;
    Ok(DesignSelection {
        sweep: sweep.to_string(),
        objective,
        constraints: constraints.iter().map(Constraint::describe).collect(),
        latency_model: LATENCY_MODEL.to_string(),
        point: winner.point.clone(),
        metrics: winner.metrics.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        score: winner.metric(objective.metric()),
        candidates: results.len(),
        feasible: n_feasible,
        frontier: n_frontier,
    })
}

// ---------------------------------------------------------------------------
// The candidate grid (`stt-ai select`)
// ---------------------------------------------------------------------------

/// LSB-bank Δ implied by a GLB-bank Δ: the paper relaxes the split bank by
/// 10 (27.5 → 17.5), floored at the Δ=12.5 LSB design point.
pub fn lsb_delta_for(glb_delta: f64) -> f64 {
    (glb_delta - 10.0).max(12.5)
}

/// Ares-style amplification of the catastrophic fault class: one flipped
/// exponent/sign bit per ~10⁴ resident weights is modeled as losing the
/// prediction — calibrated so the STT-AI Ultra budget (MSB 1e-8 / LSB 1e-5)
/// lands at the paper's "<1 % normalized drop" while a uniformly relaxed
/// 1e-5 budget collapses, which is exactly Fig. 21's contrast.
pub const CATASTROPHIC_AMPLIFICATION: f64 = 1.0e4;

/// Zoo lookup with a clean error for unknown names: `--from-selection`
/// records and hand-edited configs carry arbitrary model strings, and an
/// unknown one must surface as a CLI error, never a worker panic (the
/// boundary paths go through [`DesignSelection::validate`]).
pub fn resolve_model<'a>(zoo: &'a [Model], name: &str) -> anyhow::Result<&'a Model> {
    zoo.iter()
        .find(|m| m.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {name:?} (not in the zoo)"))
}

/// The default candidate grid: the three GLB organizations × a Δ-scaling
/// grid around the paper's design points × tight/relaxed robust-bank BER
/// budgets × GLB capacity × MAC-array side, on the paper's serving workload
/// (ResNet-50, batch 16). The capacity grid starts at the paper's 12 MB
/// (larger sizes trade area for less DRAM spill) and the array grid pairs
/// the paper's 42×42 anchor with an 84×84 scale-up (faster compute, less
/// write-stall hiding). CLI `--sweep` overrides reshape any axis
/// (`variant=...`, `delta=...`, `ber=...`, `glb_mb=...`, `macs=...`,
/// `model=...`, `batch=...`).
pub fn spec_selection(zoo: &Zoo) -> SweepSpec {
    spec_selection_grid(zoo, SelectionGrid::Default)
}

/// Which candidate grid [`spec_selection_grid`] builds — the `[deployment]`
/// `grid` knob / CLI `--grid`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SelectionGrid {
    /// The 108-candidate grid behind the pinned Table III goldens.
    #[default]
    Default,
    /// The 2592-candidate stress grid (variant × Δ × BER × GLB × MAC-array
    /// densified): the vectorized-kernel workload, and the resolution knob
    /// for grids too expensive on the scalar path.
    Dense,
}

impl SelectionGrid {
    pub fn token(self) -> &'static str {
        match self {
            SelectionGrid::Default => "default",
            SelectionGrid::Dense => "dense",
        }
    }

    pub fn from_token(tok: &str) -> Option<Self> {
        match tok {
            "default" => Some(SelectionGrid::Default),
            "dense" => Some(SelectionGrid::Dense),
            _ => None,
        }
    }

    pub fn all() -> [SelectionGrid; 2] {
        [SelectionGrid::Default, SelectionGrid::Dense]
    }
}

/// [`spec_selection`] at an explicit grid resolution. The dense grid keeps
/// the default grid's axes and workload but widens every device/capacity
/// axis (Δ down to the 12.5 LSB floor and up past the paper's 30 anchor,
/// a mid 1e-6 BER budget, an 8 MB GLB below the paper's 12 MB, a 28×28
/// edge-sized MAC array): 3 × 8 × 3 × 4 × 3 = 2592 candidates. Both grids
/// produce the same record shape, so `select`/export/serve consume either;
/// dense winners are *not* pinned as goldens — the grid exists to stress
/// the columnar kernels and to sharpen frontier resolution.
pub fn spec_selection_grid(zoo: &Zoo, grid: SelectionGrid) -> SweepSpec {
    let z = zoo.clone();
    let subject = resolve_model(zoo, "ResNet50").expect("zoo carries ResNet50").name.clone();
    let (delta, ber, glb_mb, macs) = match grid {
        SelectionGrid::Default => {
            (vec![27.5, 22.5, 17.5], vec![1.0e-8, 1.0e-5], vec![12, 16, 24], vec![42, 84])
        }
        SelectionGrid::Dense => (
            vec![30.0, 27.5, 25.0, 22.5, 20.0, 17.5, 15.0, 12.5],
            vec![1.0e-8, 1.0e-6, 1.0e-5],
            vec![8, 12, 16, 24],
            vec![28, 42, 84],
        ),
    };
    SweepSpec::new(
        "selection",
        vec![
            Axis::Model(vec![subject]),
            Axis::Variant(vec![GlbVariant::Sram, GlbVariant::SttAi, GlbVariant::SttAiUltra]),
            Axis::Delta(delta),
            Axis::Ber(ber),
            Axis::GlbMb(glb_mb),
            Axis::Macs(macs),
        ],
        move |p| selection_eval(&z, p),
    )
}

/// Evaluate one candidate: composed accelerator cost (the Table III
/// arithmetic, core rescaled to the candidate's MAC array), serving-workload
/// buffer energy, end-to-end latency under the write-bandwidth stall model,
/// the Ares-style accuracy estimate, and the retention-vs-occupancy pair
/// the §V.C design rule constrains.
fn selection_eval(zoo: &[Model], p: &DesignPoint) -> Vec<(&'static str, f64)> {
    let m = resolve_model(zoo, p.model.as_deref().unwrap_or("ResNet50"))
        .expect("selection model axes are validated at parse/load time");
    let dt = p.dtype.unwrap_or(DType::Bf16);
    let batch = p.batch.unwrap_or(16);
    let glb = p.glb_mb.unwrap_or(12) * MB;
    let macs_side = p.macs.unwrap_or(42);
    let a = ArrayConfig::with_mac_array(macs_side);
    let variant = p.variant.unwrap_or(GlbVariant::SttAiUltra);
    let tech = p.tech.unwrap_or_default();
    let t = tech.technology();
    let delta = p.delta.unwrap_or_else(|| t.default_glb_delta());
    let ber = p.ber.unwrap_or(1.0e-8);
    let tech_cfg = TechConfig {
        base: tech,
        glb_delta_override: Some(delta),
        lsb_delta_override: Some(lsb_delta_for(delta)),
    };
    // The fault/bandwidth budget of this candidate — the *same*
    // [`BerConfig::for_selection`] budget the serving engine will inject
    // with if this candidate wins, so the iso-accuracy constraint, the
    // write-bandwidth stalls and the served fault model cannot drift apart.
    // Budget, scratchpad policy and service rates come from the one shared
    // assembly the `--fig stall` comparison uses.
    let kind = variant.kind_for(&tech_cfg);
    let (budget, scratch, bw) = variant_stall_context(variant, &kind, Some(ber));

    // Composed accelerator (core + GLB variant + scratchpad), and the SRAM
    // baseline of the same capacity/array for the headline saving.
    let sys = BufferSystem::new(kind, glb, scratch);
    let core = CoreCosts::for_mac_array(macs_side);
    let acc = AcceleratorSummary::compose(variant.label(), core, &sys);
    let sram_glb = BufferSystem::new(GlbKind::baseline(), glb, None);
    let baseline = AcceleratorSummary::compose("baseline", core, &sram_glb);

    // Serving-workload buffer energy per inference batch.
    let traffic = cache::traffic(m, &a, dt, batch, glb);
    let mut buffer = EnergyLedger::default();
    for l in &traffic.layers {
        buffer.add(&sys.layer_energy(
            l.glb_reads,
            l.glb_writes,
            l.partial_bytes,
            l.partial_rounds,
            l.dram_bytes,
        ));
    }

    // End-to-end latency: compute walk + per-layer write-bandwidth stalls
    // + DRAM spill overhead. The paper's integration argument — MRAM write
    // pulses hide behind compute — is *checked* per layer instead of
    // assumed: whatever buffer service the generation time cannot hide
    // stalls the array ([`crate::memsys::bandwidth`]), which is what makes
    // `latency_s`/`throughput_rps` variant-, Δ-, BER- and
    // technology-sensitive across the candidate grid.
    // Both passes are L1-memoized: the whole variant × Δ × BER slice of the
    // grid shares one flattened stall plan and one spill row per
    // (model, array, dtype, batch, GLB) group, so a candidate re-prices the
    // shared plan against its own service rates instead of re-walking every
    // layer. `sys.scratchpad` is the `scratch` this candidate's context
    // composed into the buffer system, so the cached plan routes the same
    // loads the energy ledger above charges.
    let dram = DramModel::ddr4_2933_dual();
    let spill = cache::spill(m, &a, &dram, dt, batch, glb);
    let plan = cache::stall_plan(m, &a, dt, batch, glb, 1.0, sys.scratchpad.as_ref());
    let stalled = plan.stalled_latency(&bw);
    let latency = stalled.total() + spill.extra_latency;

    // Ares-style accuracy estimate from the analytical fault exposure of
    // the variant's bank split at this BER budget.
    let kind = match dt {
        DType::Bf16 => WordKind::Bf16,
        DType::Int8 => WordKind::Int8,
    };
    let nonvolatile = t.is_nonvolatile();
    let split = if nonvolatile {
        BankSplit { kind, msb_ber: budget.msb_ber, lsb_ber: budget.lsb_ber }
    } else {
        // A volatile GLB never flips bits, whatever the variant says.
        BankSplit::uniform(kind, 0.0)
    };
    let exposure = cache::exposure(m, dt, &split);
    let est_drop = (exposure.catastrophic_fraction * CATASTROPHIC_AMPLIFICATION
        + exposure.mean_rel_perturbation)
        .min(1.0);

    // Worst-bank retention at the BER budget vs the workload's worst data
    // occupancy (volatile GLBs hold data indefinitely while powered). The
    // built Δ is derated to the hot/slow PT corner before the check — the
    // inverse of the Eq. 17 guard band, so a candidate only passes if its
    // *worst* die still covers the occupancy (§V.C's design rule; this is
    // what makes the paper's Δ_GB = 27.5 the smallest feasible GLB bank).
    let retention = if variant == GlbVariant::Sram || !nonvolatile {
        f64::MAX
    } else {
        // guard_band is linear in Δ_scaled, so one probe inverts it.
        let gb_per_scaled = t.guard_band(1.0).delta_guard_banded;
        let derate = if gb_per_scaled > 0.0 { 1.0 / gb_per_scaled } else { 1.0 };
        let glb_ret = t.retention_time(delta * derate, budget.msb_ber);
        let ret = match variant {
            GlbVariant::SttAiUltra => {
                glb_ret.min(t.retention_time(lsb_delta_for(delta) * derate, budget.lsb_ber))
            }
            _ => glb_ret,
        };
        finite_or_max(ret)
    };
    // §V.C designs the GLB for the worst data occupancy across the whole
    // served zoo, not just the sweep's traffic model — an accelerator that
    // only covers ResNet-50 would lose data under VGG16. The zoo-wide fold
    // is memoized per (array, batch) across candidates and sweeps.
    let occupancy = cache::zoo_occupancy(zoo, &a, batch);

    vec![
        ("accel_area_mm2", acc.area_mm2),
        ("accel_power_mw", acc.total_power_mw()),
        ("buffer_energy_j", buffer.total()),
        ("latency_s", latency),
        ("compute_latency_s", stalled.compute_s),
        ("stall_s", stalled.stall_s),
        ("glb_write_bw_bytes_per_s", bw.write_bytes_per_s),
        ("throughput_rps", batch as f64 / latency),
        ("est_accuracy", 1.0 - est_drop),
        ("retention_at_ber_s", retention),
        ("occupancy_s", occupancy),
        ("area_saving_vs_sram", 1.0 - acc.area_mm2 / baseline.area_mm2),
    ]
}

/// The paper's deployment objectives (area / energy / latency at the
/// iso-accuracy floor with retention covering occupancy) evaluated over one
/// set of candidate records — the `selection.csv` export rows.
pub fn paper_selections(results: &[SweepResult]) -> anyhow::Result<Vec<DesignSelection>> {
    let constraints = [Constraint::MinAccuracy(0.99), Constraint::RetentionCoversOccupancy];
    [Objective::MinArea, Objective::MinEnergy, Objective::MinLatency]
        .into_iter()
        .map(|o| select("selection", results, o, &constraints))
        .collect()
}

/// Axis overrides that pin a sweep to a selected point (`figures
/// --from-selection`): every axis the selection's point names collapses to
/// that single value; axes a given spec does not vary are ignored by
/// [`crate::dse::engine::Runner::resolve`].
pub fn selection_overrides(p: &DesignPoint) -> Vec<Axis> {
    let mut over = Vec::new();
    if let Some(m) = &p.model {
        over.push(Axis::Model(vec![m.clone()]));
    }
    if let Some(d) = p.dtype {
        over.push(Axis::Dtype(vec![d]));
    }
    if let Some(b) = p.batch {
        over.push(Axis::Batch(vec![b]));
    }
    if let Some(g) = p.glb_mb {
        over.push(Axis::GlbMb(vec![g]));
    }
    if let Some(m) = p.macs {
        over.push(Axis::Macs(vec![m]));
    }
    if let Some(v) = p.variant {
        over.push(Axis::Variant(vec![v]));
    }
    if let Some(t) = p.tech {
        over.push(Axis::Tech(vec![t]));
    }
    if let Some(b) = p.ber {
        over.push(Axis::Ber(vec![b]));
    }
    if let Some(d) = p.delta {
        over.push(Axis::Delta(vec![d]));
    }
    over
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(sweep: &str, area: f64, energy: f64, acc: f64) -> SweepResult {
        SweepResult {
            sweep: sweep.to_string(),
            point: DesignPoint { delta: Some(area), ..Default::default() },
            metrics: vec![
                ("accel_area_mm2", area),
                ("buffer_energy_j", energy),
                ("latency_s", 1.0),
                ("throughput_rps", 16.0),
                ("est_accuracy", acc),
                ("retention_at_ber_s", 10.0),
                ("occupancy_s", 1.0),
            ],
        }
    }

    #[test]
    fn objective_tokens_round_trip() {
        for o in Objective::all() {
            assert_eq!(Objective::from_token(o.token()), Some(o));
        }
        assert_eq!(Objective::from_token("min-area"), Some(Objective::MinArea));
        assert_eq!(Objective::from_token("nope"), None);
    }

    #[test]
    fn pareto_keeps_non_dominated_only() {
        let rs = vec![
            rec("t", 10.0, 1.0, 1.0), // best energy
            rec("t", 5.0, 2.0, 1.0),  // best area
            rec("t", 12.0, 3.0, 1.0), // dominated by both
        ];
        let mask = pareto_mask(&rs, &[Objective::MinArea, Objective::MinEnergy]);
        assert_eq!(mask, vec![true, true, false]);
    }

    #[test]
    fn equal_records_do_not_dominate_each_other() {
        let rs = vec![rec("t", 5.0, 2.0, 1.0), rec("t", 5.0, 2.0, 1.0)];
        assert_eq!(pareto_mask(&rs, &[Objective::MinArea, Objective::MinEnergy]), vec![true, true]);
    }

    #[test]
    fn constraints_gate_selection() {
        let rs = vec![rec("t", 4.0, 2.0, 0.5), rec("t", 8.0, 2.0, 0.995)];
        // Unconstrained: the small-area (low-accuracy) point wins.
        let sel = select("t", &rs, Objective::MinArea, &[]).unwrap();
        assert_eq!(sel.score, 4.0);
        // Accuracy floor: the feasible point wins instead.
        let sel =
            select("t", &rs, Objective::MinArea, &[Constraint::MinAccuracy(0.99)]).unwrap();
        assert_eq!(sel.score, 8.0);
        assert_eq!(sel.feasible, 1);
        assert_eq!(sel.candidates, 2);
        // Infeasible everywhere: a clean error naming the constraint set.
        let err = select("t", &rs, Objective::MinArea, &[Constraint::MinAccuracy(1.01)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("no feasible design point"), "{err}");
        assert!(err.contains("est_accuracy>=1.01"), "{err}");
    }

    #[test]
    fn missing_objective_metric_is_an_error() {
        let rs = vec![SweepResult {
            sweep: "t".into(),
            point: DesignPoint::default(),
            metrics: vec![("other", 1.0)],
        }];
        assert!(select("t", &rs, Objective::MinArea, &[]).is_err());
        assert!(select("t", &[], Objective::MinArea, &[]).is_err());
    }

    #[test]
    fn selection_grid_evaluates_and_papers_point_wins_area() {
        let zoo = crate::dse::engine::shared_zoo();
        let results = spec_selection(&zoo).run_serial();
        assert_eq!(results.len(), 108, "3 variants x 3 deltas x 2 bers x 3 glb x 2 macs");
        let sel = select(
            "selection",
            &results,
            Objective::MinArea,
            &[Constraint::MinAccuracy(0.99), Constraint::RetentionCoversOccupancy],
        )
        .unwrap();
        assert_eq!(sel.variant(), GlbVariant::SttAiUltra, "{sel:?}");
        // The unique feasible area-minimum is the paper's exact design point:
        // Δ 27.5/17.5 split banks at the 1e-8/1e-5 BER budget, 12 MB GLB on
        // the 42×42 array. Lower-Δ candidates are cheaper but fail the
        // retention-vs-occupancy rule at the hot/slow corner; relaxed-BER
        // candidates fail iso-accuracy; bigger GLBs/arrays only add area.
        assert_eq!(sel.point.delta, Some(27.5), "{sel:?}");
        assert_eq!(sel.point.ber, Some(1.0e-8), "{sel:?}");
        assert_eq!(sel.point.glb_mb, Some(12), "{sel:?}");
        assert_eq!(sel.point.macs, Some(42), "{sel:?}");
        assert_eq!(sel.latency_model, LATENCY_MODEL);
        let saving = sel.metric("area_saving_vs_sram").unwrap();
        assert!((saving - 0.754).abs() < 0.03, "area saving {saving}");
        assert!(sel.frontier >= 1 && sel.feasible >= sel.frontier);
    }

    #[test]
    fn latency_is_write_bandwidth_sensitive_across_the_grid() {
        // The acceptance contract of the stall model: `latency_s` must NOT
        // be constant across GLB variants at iso (model, glb, macs) — the
        // old compute-walk score was variant-invariant by construction.
        let zoo = crate::dse::engine::shared_zoo();
        let results = spec_selection(&zoo).run_serial();
        let at = |variant, delta, ber| {
            results
                .iter()
                .find(|r| {
                    r.point.variant == Some(variant)
                        && r.point.delta == Some(delta)
                        && r.point.ber == Some(ber)
                        && r.point.glb_mb == Some(12)
                        && r.point.macs == Some(84)
                })
                .unwrap()
                .metric("latency_s")
        };
        let sram = at(GlbVariant::Sram, 27.5, 1.0e-8);
        let mono = at(GlbVariant::SttAi, 27.5, 1.0e-8);
        let ultra = at(GlbVariant::SttAiUltra, 27.5, 1.0e-8);
        // SRAM writes at the practical floor → least stall; the split GLB's
        // aggregate write bandwidth beats the mono bank at the same Δ.
        assert!(sram < ultra && ultra < mono, "sram={sram} ultra={ultra} mono={mono}");
        // Relaxing the WER budget shortens the write pulse → less stall.
        let relaxed = at(GlbVariant::SttAi, 27.5, 1.0e-5);
        assert!(relaxed < mono, "relaxed={relaxed} mono={mono}");
        // And the stall metric itself is exported for the candidate CSV.
        let rec = results
            .iter()
            .find(|r| {
                r.point.variant == Some(GlbVariant::SttAi)
                    && r.point.ber == Some(1.0e-8)
                    && r.point.delta == Some(27.5)
                    && r.point.macs == Some(84)
                    && r.point.glb_mb == Some(12)
            })
            .unwrap();
        assert!(rec.metric("stall_s") > 0.0);
        assert_eq!(
            rec.metric("latency_s"),
            rec.metric("compute_latency_s")
                + rec.metric("stall_s")
                + DramOverheadRow::analyze(
                    resolve_model(&zoo, "ResNet50").unwrap(),
                    &ArrayConfig::with_mac_array(84),
                    &DramModel::ddr4_2933_dual(),
                    DType::Bf16,
                    16,
                    12 * MB,
                )
                .extra_latency
        );
    }

    #[test]
    fn unknown_model_is_a_clean_error_not_a_panic() {
        let zoo = crate::dse::engine::shared_zoo();
        let err = resolve_model(&zoo, "NotAModel").unwrap_err().to_string();
        assert!(err.contains("unknown model"), "{err}");
        // A selection record naming an unknown model fails validation — the
        // `--from-selection` load path surfaces this instead of letting a
        // sweep worker panic.
        let results = spec_selection(&zoo).run_serial();
        let mut sel = select("selection", &results, Objective::MinArea, &[]).unwrap();
        assert!(sel.validate().is_ok());
        sel.point.model = Some("NotAModel".into());
        let err = sel.validate().unwrap_err().to_string();
        assert!(err.contains("unknown model"), "{err}");
    }

    #[test]
    fn relaxed_uniform_ber_fails_the_accuracy_floor() {
        let zoo = crate::dse::engine::shared_zoo();
        let results = spec_selection(&zoo).run_serial();
        let relaxed_mono = results
            .iter()
            .find(|r| {
                r.point.variant == Some(GlbVariant::SttAi) && r.point.ber == Some(1.0e-5)
            })
            .unwrap();
        assert!(relaxed_mono.metric("est_accuracy") < 0.99, "uniform 1e-5 must fail iso-accuracy");
        assert!(!Constraint::MinAccuracy(0.99).satisfied(relaxed_mono));
        // The paper's Ultra budget stays above the floor.
        let ultra = results
            .iter()
            .find(|r| {
                r.point.variant == Some(GlbVariant::SttAiUltra)
                    && r.point.ber == Some(1.0e-8)
                    && r.point.delta == Some(27.5)
            })
            .unwrap();
        assert!(ultra.metric("est_accuracy") > 0.99);
        assert!(Constraint::RetentionCoversOccupancy.satisfied(ultra));
    }

    #[test]
    fn selection_record_round_trips_and_boots_config() {
        let zoo = crate::dse::engine::shared_zoo();
        let results = spec_selection(&zoo).run_serial();
        let sel = paper_selections(&results).unwrap().remove(0);
        let back = DesignSelection::from_json(&sel.to_json()).unwrap();
        assert_eq!(back.point, sel.point);
        assert_eq!(back.objective, sel.objective);
        assert_eq!(back.score, sel.score);
        assert_eq!(back.constraints, sel.constraints);
        // Latency-model provenance survives the round trip; tag-less legacy
        // records fall back to the compute-walk tag.
        assert_eq!(back.latency_model, LATENCY_MODEL);
        let mut legacy = sel.to_json();
        if let Json::Obj(m) = &mut legacy {
            let _ = m.remove("latency_model");
        }
        assert_eq!(
            DesignSelection::from_json(&legacy).unwrap().latency_model,
            LATENCY_MODEL_LEGACY
        );
        // The serving bridge reproduces the paper's Ultra configuration.
        let cfg = back.system_config();
        assert_eq!(cfg.glb, GlbVariant::SttAiUltra);
        assert_eq!(cfg.tech.glb_delta(), 27.5);
        assert_eq!(cfg.tech.lsb_delta(), 17.5);
        let ber = back.ber_config();
        assert_eq!(ber.msb_ber, 1.0e-8);
        assert_eq!(ber.lsb_ber, 1.0e-5);
        match back.glb_kind() {
            GlbKind::Split { msb, lsb } => {
                assert_eq!(msb.delta_guard_banded, 27.5);
                assert_eq!(lsb.delta_guard_banded, 17.5);
            }
            other => panic!("expected split GLB, got {other:?}"),
        }
        // CSV stays rectangular.
        assert_eq!(sel.csv_header().split(',').count(), sel.csv_row().split(',').count());
    }

    #[test]
    fn selection_overrides_pin_swept_axes() {
        let p = DesignPoint {
            variant: Some(GlbVariant::SttAiUltra),
            delta: Some(27.5),
            ber: Some(1.0e-8),
            glb_mb: Some(12),
            macs: Some(42),
            ..Default::default()
        };
        let over = selection_overrides(&p);
        assert_eq!(over.len(), 5);
        let mut spec = spec_selection(&crate::dse::engine::shared_zoo());
        for o in over {
            spec.override_axis(o);
        }
        assert_eq!(spec.len(), 1, "selection pins the grid to one point");
    }
}
