//! Work-stealing thread pool for the DSE engine (std-only: no rayon).
//!
//! The engine's workloads are finite batches of independent, pure jobs (one
//! per [`crate::dse::DesignPoint`]), so the pool is a *scoped fork-join*
//! pool: every call to [`ThreadPool::map`] distributes the job indices over
//! per-worker deques, spawns scoped workers that drain their own deque from
//! the front and steal from the back of their neighbours' when empty, and
//! joins. Results are re-assembled in input order, so the output is
//! deterministic and byte-identical for any worker count — the property the
//! figure-parity tests assert.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Number of hardware threads, with a safe fallback of 1.
pub fn available_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A fixed-width work-stealing pool. Threads are scoped per `map` call (jobs
/// are coarse — figure sweeps, not nanosecond ops — so spawn cost is noise).
#[derive(Debug, Clone, Copy)]
pub struct ThreadPool {
    workers: usize,
}

impl Default for ThreadPool {
    fn default() -> Self {
        Self::auto()
    }
}

impl ThreadPool {
    /// A pool with exactly `workers` threads (clamped to ≥ 1).
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    /// A pool sized to the machine.
    pub fn auto() -> Self {
        Self::new(available_parallelism())
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Apply `f` to every item, in parallel, returning results in input
    /// order. `f(i, &items[i])` must be pure with respect to ordering — the
    /// pool guarantees each index runs exactly once but not *where* or
    /// *when*. Worker panics are propagated to the caller.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        self.map_range(items.len(), |i| f(i, &items[i]))
    }

    /// [`ThreadPool::map`] over a bare index range `0..len` — the form the
    /// columnar kernels use to fan tile jobs out without materializing an
    /// item slice. Same contract: each index runs exactly once, results come
    /// back in index order (deterministic for any worker count), worker
    /// panics propagate.
    pub fn map_range<R, F>(&self, len: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let n = self.workers.min(len);
        if n <= 1 {
            return (0..len).map(f).collect();
        }

        // Contiguous index chunks per worker; stealing takes from the *back*
        // of a victim's chunk so owner (front) and thief (back) rarely race
        // over the same cache lines of work.
        let queues: Vec<Mutex<VecDeque<usize>>> = (0..n)
            .map(|w| {
                let lo = w * len / n;
                let hi = (w + 1) * len / n;
                Mutex::new((lo..hi).collect())
            })
            .collect();

        let f = &f;
        let queues = &queues;
        let mut tagged: Vec<(usize, R)> = Vec::with_capacity(len);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|w| {
                    s.spawn(move || {
                        let mut out: Vec<(usize, R)> = Vec::new();
                        loop {
                            // Pop from the own queue in its own statement so
                            // the guard is dropped *before* stealing — never
                            // hold two queue locks at once (deadlock-free).
                            let own = queues[w].lock().unwrap().pop_front();
                            let job = match own {
                                Some(i) => Some(i),
                                None => (1..n).find_map(|off| {
                                    queues[(w + off) % n].lock().unwrap().pop_back()
                                }),
                            };
                            match job {
                                Some(i) => out.push((i, f(i))),
                                None => return out,
                            }
                        }
                    })
                })
                .collect();
            for h in handles {
                match h.join() {
                    Ok(part) => tagged.extend(part),
                    Err(panic) => std::panic::resume_unwind(panic),
                }
            }
        });

        debug_assert_eq!(tagged.len(), len);
        tagged.sort_by_key(|(i, _)| *i);
        tagged.into_iter().map(|(_, r)| r).collect()
    }

    /// Parallel map + ordered sequential reduce: `map` runs on the pool,
    /// then `fold` combines the results **in input order on the caller
    /// thread**. Because the reduction order is fixed, the accumulated value
    /// is bit-identical for any worker count even when `fold` is not
    /// floating-point associative — the Monte-Carlo merge contract.
    pub fn map_reduce<T, R, A, F, G>(&self, items: &[T], map: F, init: A, fold: G) -> A
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
        G: FnMut(A, R) -> A,
    {
        self.map(items, map).into_iter().fold(init, fold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_input_order() {
        let items: Vec<u64> = (0..257).collect();
        let out = ThreadPool::new(4).map(&items, |i, x| (i as u64, x * 2));
        assert_eq!(out.len(), 257);
        for (i, (idx, doubled)) in out.iter().enumerate() {
            assert_eq!(*idx, i as u64);
            assert_eq!(*doubled, 2 * i as u64);
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let items: Vec<u64> = (0..100).collect();
        let f = |_: usize, x: &u64| x.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17);
        let serial = ThreadPool::new(1).map(&items, f);
        for workers in [2, 3, 8, 64] {
            assert_eq!(ThreadPool::new(workers).map(&items, f), serial, "workers={workers}");
        }
    }

    #[test]
    fn every_job_runs_exactly_once_under_stealing() {
        // Lopsided work: the first chunk's jobs are slow, so other workers
        // must steal to finish — every index must still run exactly once.
        let items: Vec<usize> = (0..64).collect();
        let runs = AtomicUsize::new(0);
        let out = ThreadPool::new(4).map(&items, |i, _| {
            if i < 16 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            runs.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(runs.load(Ordering::Relaxed), 64);
        assert_eq!(out, items);
    }

    #[test]
    fn map_range_matches_map_and_is_worker_invariant() {
        let items: Vec<usize> = (0..321).collect();
        let via_map = ThreadPool::new(1).map(&items, |i, _| i * i);
        for workers in [1, 2, 4, 8] {
            assert_eq!(
                ThreadPool::new(workers).map_range(items.len(), |i| i * i),
                via_map,
                "workers={workers}"
            );
        }
        assert_eq!(ThreadPool::new(4).map_range(0, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn map_reduce_is_worker_count_invariant() {
        // A non-associative float fold must still come out bit-identical
        // for any worker count (the reduce runs in input order).
        let items: Vec<f64> = (0..997).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let serial = ThreadPool::new(1)
            .map_reduce(&items, |_, x| x * x, 0.0f64, |acc, v| acc + v)
            .to_bits();
        for workers in [2, 4, 8] {
            let par = ThreadPool::new(workers)
                .map_reduce(&items, |_, x| x * x, 0.0f64, |acc, v| acc + v)
                .to_bits();
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let pool = ThreadPool::new(8);
        assert_eq!(pool.map(&[] as &[u8], |_, x| *x), Vec::<u8>::new());
        assert_eq!(pool.map(&[7u8], |_, x| *x), vec![7]);
    }

    #[test]
    fn worker_count_clamped() {
        assert_eq!(ThreadPool::new(0).workers(), 1);
        assert!(ThreadPool::auto().workers() >= 1);
    }

    #[test]
    fn panicking_job_does_not_deadlock_or_corrupt_ordering() {
        // A worker panic must neither wedge the remaining workers (the scope
        // join would hang) nor poison anything that corrupts a later map.
        let pool = ThreadPool::new(4);
        let items: Vec<usize> = (0..64).collect();
        let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map(&items, |i, _| {
                if i == 5 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(attempt.is_err(), "panic must propagate to the caller");
        // The pool is stateless across maps: the very next call must run
        // every job exactly once and return results in input order.
        let runs = AtomicUsize::new(0);
        let out = pool.map(&items, |i, x| {
            assert_eq!(i, *x);
            runs.fetch_add(1, Ordering::Relaxed);
            *x * 3
        });
        assert_eq!(runs.load(Ordering::Relaxed), 64);
        assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "job 13 exploded")]
    fn panics_propagate() {
        let items: Vec<usize> = (0..32).collect();
        ThreadPool::new(4).map(&items, |i, _| {
            if i == 13 {
                panic!("job 13 exploded");
            }
            i
        });
    }
}
