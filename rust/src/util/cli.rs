//! Minimal CLI argument parser (offline build: no clap).
//!
//! Grammar: `stt-ai <subcommand> [--flag value]... [--switch]...`.
//! Flags may appear in any order; unknown flags are surfaced as errors by
//! the caller via [`Args::finish`].

use std::collections::BTreeMap;

/// Scan the raw argv for one `--NAME value` / `--NAME=value` flag,
/// tolerating foreign flags around it. A following `--flag` token is never
/// consumed as the value. For binaries that receive argv mixed with harness
/// flags (benches under `cargo bench -- ...`), where [`Args::finish`]'s
/// strict unknown-flag check cannot be used.
pub fn arg_value(name: &str) -> Option<String> {
    arg_value_in(std::env::args(), name)
}

fn arg_value_in(args: impl IntoIterator<Item = String>, name: &str) -> Option<String> {
    let args: Vec<String> = args.into_iter().collect();
    let eq = format!("--{name}=");
    let bare = format!("--{name}");
    for (i, a) in args.iter().enumerate() {
        if let Some(v) = a.strip_prefix(&eq) {
            return Some(v.to_string());
        }
        if *a == bare {
            return args.get(i + 1).filter(|v| !v.starts_with("--")).cloned();
        }
    }
    None
}

/// Scan the raw argv for a bare `--NAME` switch (same tolerance as
/// [`arg_value`]).
pub fn arg_switch(name: &str) -> bool {
    let bare = format!("--{name}");
    std::env::args().any(|a| a == bare)
}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub cmd: String,
    flags: BTreeMap<String, String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from `std::env::args` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an iterator of tokens.
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut cmd = String::new();
        let mut flags = BTreeMap::new();
        let mut iter = it.into_iter().peekable();
        if let Some(first) = iter.peek() {
            if !first.starts_with("--") {
                cmd = iter.next().unwrap();
            }
        }
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // `--k=v`, or `--k v`, or bare switch `--k`.
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else {
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            flags.insert(name.to_string(), v);
                        }
                        _ => {
                            flags.insert(name.to_string(), "true".to_string());
                        }
                    }
                }
            }
        }
        Self { cmd, flags, consumed: Default::default() }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        let v = self.flags.get(key).map(|s| s.as_str());
        if v.is_some() {
            self.consumed.borrow_mut().push(key.to_string());
        }
        v
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_flag(&self, key: &str) -> bool {
        self.get(key).map(|v| v != "false").unwrap_or(false)
    }

    /// Error on any flag that no `get*` call touched (catches typos).
    pub fn finish(&self) -> anyhow::Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !consumed.contains(k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown flags: {unknown:?}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = args("figures --fig 13 --verbose");
        assert_eq!(a.cmd, "figures");
        assert_eq!(a.get("fig"), Some("13"));
        assert!(a.get_flag("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn equals_form() {
        let a = args("design --retention=3.0 --ber=1e-8");
        assert_eq!(a.get_f64("retention", 0.0).unwrap(), 3.0);
        assert_eq!(a.get_f64("ber", 0.0).unwrap(), 1e-8);
    }

    #[test]
    fn defaults_apply() {
        let a = args("serve");
        assert_eq!(a.get_usize("batch", 16).unwrap(), 16);
        assert_eq!(a.get_or("variant", "stt_ai_ultra"), "stt_ai_ultra");
    }

    #[test]
    fn unknown_flags_detected() {
        let a = args("table3 --oops 1");
        assert!(a.finish().is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = args("--help");
        assert_eq!(a.cmd, "");
        assert!(a.get_flag("help"));
    }

    #[test]
    fn bad_number_errors() {
        let a = args("x --n abc");
        assert!(a.get_usize("n", 1).is_err());
    }

    #[test]
    fn raw_argv_scanner_tolerates_foreign_flags() {
        let argv = |s: &str| s.split_whitespace().map(String::from).collect::<Vec<_>>();
        assert_eq!(
            arg_value_in(argv("bench --bench --bench-json out.json --smoke"), "bench-json"),
            Some("out.json".to_string())
        );
        assert_eq!(
            arg_value_in(argv("bench --bench-json=x.json"), "bench-json"),
            Some("x.json".to_string())
        );
        // A following flag is never consumed as the value.
        assert_eq!(arg_value_in(argv("bench --bench-json --smoke"), "bench-json"), None);
        // Missing entirely.
        assert_eq!(arg_value_in(argv("bench --smoke"), "bench-json"), None);
        // `--parallel 4` style numeric flags share the same scanner.
        let p = arg_value_in(argv("hotpath --bench --parallel 4"), "parallel");
        assert_eq!(p, Some("4".into()));
    }
}
