//! Minimal JSON parser/serializer.
//!
//! The build environment is offline (no serde/serde_json); the artifact
//! manifest and config files are plain JSON, so we implement the small
//! subset we need: objects, arrays, strings (with escapes), numbers, bools,
//! null. Strict enough for round-tripping our own output and the output of
//! Python's `json.dump`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers with path-ful errors.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError(format!("missing field {key:?}")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?.as_str().ok_or_else(|| JsonError(format!("field {key:?} not a string")))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, JsonError> {
        self.req(key)?.as_u64().ok_or_else(|| JsonError(format!("field {key:?} not a u64")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.req(key)?.as_arr().ok_or_else(|| JsonError(format!("field {key:?} not an array")))
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_i64(xs: &[i64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

/// Parse/typing error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json: {}", self.0)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected {:?}", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.pos += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // BMP only (no surrogate pairs) — enough for our files.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e1 ").unwrap(), Json::Num(-125.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(), "x");
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"models":{"m":{"batch":16,"shape":[1,16,16]}},"ok":true,"x":1.5}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 42, "f": 1.5, "neg": -3}"#).unwrap();
        assert_eq!(v.req_u64("n").unwrap(), 42);
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        assert_eq!(v.get("neg").unwrap().as_i64(), Some(-3));
        assert!(v.req("missing").is_err());
        assert!(v.req_str("n").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // Python json.dump default ensure_ascii output parses.
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn builders() {
        let j = Json::obj(vec![("a", 1u64.into()), ("b", Json::arr_i64(&[1, 2]))]);
        assert_eq!(j.to_string(), r#"{"a":1,"b":[1,2]}"#);
    }
}
