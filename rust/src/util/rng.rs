//! Deterministic RNG: xoshiro256++ (Blackman & Vigna), implemented locally
//! because the build environment is offline. Used by the BER injector, the
//! Monte-Carlo engine and the property-style randomized tests.
//!
//! Two API layers:
//!
//! * scalar draws (`next_u64` / `next_f64` / `normal` / ...), and
//! * batched fills (`fill_u64` / `fill_f64` / `fill_normal`) that amortize
//!   call overhead and keep the pairwise Box–Muller transform's second
//!   output — the hot-path form the streaming Monte-Carlo engine consumes.
//!
//! [`Rng::jump`] advances the state by 2^128 steps, carving the sequence
//! into non-overlapping sub-streams: chunked parallel consumers derive one
//! stream per chunk from a single seed, so results are independent of how
//! many workers drain the chunks.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// The xoshiro256 2^128-step jump polynomial (Blackman & Vigna reference).
const JUMP: [u64; 4] =
    [0x180e_c6d3_3cfd_0aba, 0xd5a6_1266_f0c9_392c, 0xa958_2618_e03f_c9aa, 0x39ab_dc45_29b1_661c];

impl Rng {
    /// Seed via SplitMix64 (the reference seeding procedure).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping (fine for non-crypto use).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Advance the state by 2^128 `next_u64` steps (the reference xoshiro
    /// jump). Successive jumps from one seed yield non-overlapping
    /// sub-streams of 2^128 draws each — one per Monte-Carlo block.
    pub fn jump(&mut self) {
        let mut s = [0u64; 4];
        for word in JUMP {
            for bit in 0..64 {
                if word & (1u64 << bit) != 0 {
                    s[0] ^= self.s[0];
                    s[1] ^= self.s[1];
                    s[2] ^= self.s[2];
                    s[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = s;
    }

    /// Fill `out` with raw draws; element `i` equals the `i`-th `next_u64`.
    pub fn fill_u64(&mut self, out: &mut [u64]) {
        for x in out.iter_mut() {
            *x = self.next_u64();
        }
    }

    /// Fill `out` with uniform f64 in [0, 1); element `i` equals the `i`-th
    /// `next_f64`.
    pub fn fill_f64(&mut self, out: &mut [f64]) {
        for x in out.iter_mut() {
            *x = self.next_f64();
        }
    }

    /// Fill `out` with standard normals via *pairwise* Box–Muller: each
    /// uniform pair (u1, u2) yields both the cosine and the sine branch, so
    /// a batch of `n` normals costs `n` uniform draws instead of the `2n`
    /// the scalar [`Rng::normal`] spends (it discards the sine partner).
    /// Even-indexed outputs are bit-identical to what `normal()` would have
    /// produced from the same state; a trailing odd element falls back to
    /// the scalar path.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        let mut i = 0;
        while i + 1 < out.len() {
            let u1 = self.next_f64().max(1e-300);
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            out[i] = r * theta.cos();
            out[i + 1] = r * theta.sin();
            i += 2;
        }
        if i < out.len() {
            out[i] = self.normal();
        }
    }

    /// Append `n` standard normals to `out`, generated through
    /// [`Rng::fill_normal`] in fixed [`FILL_CHUNK`]-sized slices. Because
    /// the chunk size is even, every chunk boundary lands between Box–Muller
    /// pairs and the result is bit-identical to one monolithic
    /// `fill_normal` over `n` lanes — while the working set each pass
    /// touches stays L1/L2-resident for large `n`. Capacity is reused
    /// across calls (`clear()` + `fill_normal_into` is the zero-allocation
    /// steady state the MC accumulator and the columnar kernels share).
    pub fn fill_normal_into(&mut self, out: &mut Vec<f64>, n: usize) {
        let start = out.len();
        out.resize(start + n, 0.0);
        for chunk in out[start..].chunks_mut(FILL_CHUNK) {
            self.fill_normal(chunk);
        }
    }
}

/// Slice width of the chunked batched-fill paths ([`Rng::fill_normal_into`]).
/// Must stay even so chunk boundaries never split a Box–Muller pair — that
/// is what keeps the chunked fill bit-identical to the monolithic one.
pub const FILL_CHUNK: usize = 512;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_normal_into_is_bit_identical_to_monolithic_fill() {
        // Chunk boundaries must never split a Box–Muller pair: the chunked
        // append matches one big fill_normal bit-for-bit, for lengths below,
        // at, and straddling FILL_CHUNK (odd tails included).
        for n in [0usize, 1, 2, 511, 512, 513, 1024, 1025, 3 * FILL_CHUNK + 7] {
            let mut mono = vec![0.0f64; n];
            Rng::seed_from_u64(42).fill_normal(&mut mono);
            let mut chunked = Vec::new();
            Rng::seed_from_u64(42).fill_normal_into(&mut chunked, n);
            assert_eq!(chunked.len(), n);
            let eq = mono.iter().zip(&chunked).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(eq, "n={n}");
        }
        // Appends after the existing tail and reuses capacity.
        let mut out = vec![7.0f64];
        let mut rng = Rng::seed_from_u64(9);
        rng.fill_normal_into(&mut out, 10);
        assert_eq!(out.len(), 11);
        assert_eq!(out[0], 7.0);
        assert_eq!(FILL_CHUNK % 2, 0, "FILL_CHUNK must stay even");
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_uniform_enough() {
        let mut r = Rng::seed_from_u64(4);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 600, "{counts:?}");
        }
    }

    #[test]
    fn jump_is_deterministic_and_disjoint() {
        let mut a = Rng::seed_from_u64(11);
        let mut b = Rng::seed_from_u64(11);
        a.jump();
        b.jump();
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // A jumped stream must not replay the base stream's prefix.
        let mut base = Rng::seed_from_u64(11);
        let mut jumped = Rng::seed_from_u64(11);
        jumped.jump();
        let head: Vec<u64> = (0..64).map(|_| base.next_u64()).collect();
        let jhead: Vec<u64> = (0..64).map(|_| jumped.next_u64()).collect();
        assert_ne!(head, jhead);
        // Successive jumps give pairwise-distinct stream heads.
        let mut r = Rng::seed_from_u64(12);
        let mut heads = Vec::new();
        for _ in 0..16 {
            heads.push(r.clone().next_u64());
            r.jump();
        }
        heads.sort_unstable();
        heads.dedup();
        assert_eq!(heads.len(), 16);
    }

    #[test]
    fn fill_matches_scalar_draws() {
        let mut a = Rng::seed_from_u64(21);
        let mut b = Rng::seed_from_u64(21);
        let mut buf = [0u64; 33];
        a.fill_u64(&mut buf);
        for &x in &buf {
            assert_eq!(x, b.next_u64());
        }
        let mut a = Rng::seed_from_u64(22);
        let mut b = Rng::seed_from_u64(22);
        let mut fbuf = [0.0f64; 17];
        a.fill_f64(&mut fbuf);
        for &x in &fbuf {
            assert_eq!(x.to_bits(), b.next_f64().to_bits());
        }
    }

    #[test]
    fn fill_normal_even_lanes_match_scalar() {
        // The cosine branch of each Box–Muller pair is exactly what the
        // scalar normal() computes from the same two uniforms.
        let mut a = Rng::seed_from_u64(23);
        let mut b = Rng::seed_from_u64(23);
        let mut buf = [0.0f64; 8];
        a.fill_normal(&mut buf);
        assert_eq!(buf[0].to_bits(), b.normal().to_bits());
        // Odd trailing element falls back to the scalar path.
        let mut c = Rng::seed_from_u64(24);
        let mut one = [0.0f64; 1];
        c.fill_normal(&mut one);
        let mut d = Rng::seed_from_u64(24);
        assert_eq!(one[0].to_bits(), d.normal().to_bits());
    }

    #[test]
    fn fill_normal_moments() {
        let mut r = Rng::seed_from_u64(25);
        let mut xs = vec![0.0f64; 50_000];
        r.fill_normal(&mut xs);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(5);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }
}
