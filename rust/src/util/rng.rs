//! Deterministic RNG: xoshiro256++ (Blackman & Vigna), implemented locally
//! because the build environment is offline. Used by the BER injector and
//! the property-style randomized tests.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (the reference seeding procedure).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping (fine for non-crypto use).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_uniform_enough() {
        let mut r = Rng::seed_from_u64(4);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 600, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(5);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }
}
