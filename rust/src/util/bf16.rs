//! bfloat16 conversions (local implementation; offline build has no `half`).
//!
//! bf16 is the top 16 bits of an f32 (1 sign + 8 exponent + 7 mantissa).
//! `f32_to_bf16` uses round-to-nearest-even, matching JAX/XLA semantics so
//! the Rust-side fault model quantizes exactly like the compiled graph.

/// f32 → bf16 bits with round-to-nearest-even.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Preserve NaN, force a set mantissa bit.
        return ((bits >> 16) as u16) | 0x0040;
    }
    if x.is_infinite() {
        return (bits >> 16) as u16;
    }
    let lsb = (bits >> 16) & 1;
    (bits.wrapping_add(0x0000_7FFF + lsb) >> 16) as u16
}

/// bf16 bits → f32.
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round an f32 through bf16 precision.
#[inline]
pub fn round_via_bf16(x: f32) -> f32 {
    bf16_to_f32(f32_to_bf16(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1.5] {
            assert_eq!(round_via_bf16(v), v, "{v}");
        }
    }

    #[test]
    fn rounds_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between bf16(1.0) and the next
        // representable value; ties-to-even keeps 1.0.
        let x = 1.0f32 + 2.0f32.powi(-8);
        assert_eq!(round_via_bf16(x), 1.0);
        // Slightly above the halfway point rounds up.
        let y = 1.0f32 + 2.0f32.powi(-8) + 2.0f32.powi(-16);
        assert_eq!(round_via_bf16(y), 1.0 + 2.0f32.powi(-7));
    }

    #[test]
    fn relative_error_bounded() {
        // bf16 has 8 mantissa bits incl. implicit → rel err ≤ 2^-8.
        let mut x = 0.001f32;
        while x < 1.0e6 {
            let r = round_via_bf16(x);
            let rel = ((r - x) / x).abs();
            assert!(rel <= 0.004, "x={x} r={r} rel={rel}");
            x *= 1.7;
        }
    }

    #[test]
    fn specials() {
        assert_eq!(round_via_bf16(f32::INFINITY), f32::INFINITY);
        assert_eq!(round_via_bf16(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(round_via_bf16(f32::NAN).is_nan());
        assert_eq!(bf16_to_f32(0x3F80), 1.0);
        assert_eq!(f32_to_bf16(1.0), 0x3F80);
    }

    #[test]
    fn sign_and_exponent_layout() {
        // MSB byte = sign+exponent(+mantissa msb), LSB byte = mantissa tail.
        let b = f32_to_bf16(-2.5);
        assert_eq!(b & 0x8000, 0x8000, "sign bit set");
        let [lo, hi] = b.to_le_bytes();
        assert_eq!(hi & 0x80, 0x80);
        let _ = lo;
    }
}
