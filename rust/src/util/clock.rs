//! Simulation-grade time: a monotonic [`Tick`] instant plus an injectable
//! [`Clock`] that is either wall-backed (real serving) or virtual
//! (discrete-event simulation, bit-reproducible at any worker count).
//!
//! The serving stack (`coordinator::{batcher, serve, metrics, supervisor}`)
//! takes `Tick`/`Clock` instead of calling `std::time::Instant::now()`
//! directly, so a fault scenario replayed under `Clock::virtual_at_zero()`
//! produces byte-identical reports across runs and `--parallel` settings.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// A monotonic instant measured in nanoseconds since the clock's epoch.
///
/// `Tick` is to the simulated serving path what `std::time::Instant` is to
/// wall-clock code: an opaque point in time supporting `+ Duration` and
/// `duration_since`. Unlike `Instant` it is a plain integer, so virtual
/// schedules are exactly reproducible.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Tick(u64);

impl Tick {
    /// The clock epoch (t = 0).
    pub const ZERO: Tick = Tick(0);

    /// Construct from nanoseconds since the epoch.
    pub fn from_nanos(ns: u64) -> Tick {
        Tick(ns)
    }

    /// Nanoseconds since the epoch.
    pub fn as_nanos(self) -> u64 {
        self.0
    }

    /// Elapsed time since `earlier`, saturating to zero if `earlier` is
    /// actually later (mirrors `Instant::saturating_duration_since`).
    pub fn duration_since(self, earlier: Tick) -> Duration {
        Duration::from_nanos(self.0.saturating_sub(earlier.0))
    }

    /// `self - d`, or `None` if that would precede the epoch. Used to
    /// back-date throughput anchors without wrapping.
    pub fn checked_sub(self, d: Duration) -> Option<Tick> {
        self.0.checked_sub(d.as_nanos() as u64).map(Tick)
    }

    /// Seconds since the epoch as `f64` — the time axis the diurnal arrival
    /// rate `λ(t)` is evaluated on (`coordinator::traffic`). Lossy above
    /// ~2^53 ns (~104 days of simulated time), which no trace approaches.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 * 1e-9
    }
}

impl std::ops::Add<Duration> for Tick {
    type Output = Tick;
    fn add(self, d: Duration) -> Tick {
        Tick(self.0.saturating_add(d.as_nanos() as u64))
    }
}

/// Injectable time source for the serving stack.
///
/// * [`Clock::wall`] — `now()` reads the real elapsed time since
///   construction; `advance` sleeps. Used by live serving and examples.
/// * [`Clock::virtual_at_zero`] — `now()` reads a counter; `advance`
///   adds to it. Used by the fault-injection harness and tests, where it
///   makes every schedule deterministic.
#[derive(Debug)]
pub enum Clock {
    /// Wall-backed clock: ticks are nanoseconds since `epoch`.
    Wall {
        /// Construction instant; all ticks are measured from here.
        epoch: Instant,
    },
    /// Virtual clock: ticks are whatever the harness says they are.
    Virtual(AtomicU64),
}

impl Clock {
    /// A wall-backed clock whose epoch is now.
    pub fn wall() -> Clock {
        Clock::Wall {
            epoch: Instant::now(),
        }
    }

    /// A virtual clock starting at `Tick::ZERO`.
    pub fn virtual_at_zero() -> Clock {
        Clock::Virtual(AtomicU64::new(0))
    }

    /// Current instant on this clock.
    pub fn now(&self) -> Tick {
        match self {
            Clock::Wall { epoch } => Tick(epoch.elapsed().as_nanos() as u64),
            Clock::Virtual(ns) => Tick(ns.load(Ordering::SeqCst)),
        }
    }

    /// Advance time by `d`: sleeps on a wall clock, increments on a
    /// virtual one. Returns the new `now()`.
    pub fn advance(&self, d: Duration) -> Tick {
        match self {
            Clock::Wall { .. } => {
                std::thread::sleep(d);
                self.now()
            }
            Clock::Virtual(ns) => {
                let add = d.as_nanos() as u64;
                Tick(ns.fetch_add(add, Ordering::SeqCst).saturating_add(add))
            }
        }
    }

    /// Advance to at least `t` (no-op if already past). Returns `now()`.
    pub fn advance_to(&self, t: Tick) -> Tick {
        let now = self.now();
        if t > now {
            self.advance(t.duration_since(now))
        } else {
            now
        }
    }

    /// True for virtual clocks (the simulated serving path).
    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tick_arithmetic_round_trips() {
        let t = Tick::ZERO + Duration::from_micros(5);
        assert_eq!(t.as_nanos(), 5_000);
        assert_eq!(t.duration_since(Tick::ZERO), Duration::from_micros(5));
        // Saturating in the wrong direction.
        assert_eq!(Tick::ZERO.duration_since(t), Duration::ZERO);
        assert_eq!(t.checked_sub(Duration::from_micros(5)), Some(Tick::ZERO));
        assert_eq!(t.checked_sub(Duration::from_micros(6)), None);
        assert_eq!(Tick::from_nanos(1_500_000_000).as_secs_f64(), 1.5);
    }

    #[test]
    fn virtual_clock_advances_exactly() {
        let c = Clock::virtual_at_zero();
        assert!(c.is_virtual());
        assert_eq!(c.now(), Tick::ZERO);
        let t = c.advance(Duration::from_millis(3));
        assert_eq!(t.as_nanos(), 3_000_000);
        assert_eq!(c.now(), t);
        // advance_to backwards is a no-op.
        assert_eq!(c.advance_to(Tick::ZERO), t);
        let t2 = c.advance_to(Tick::from_nanos(5_000_000));
        assert_eq!(t2.as_nanos(), 5_000_000);
    }

    #[test]
    fn wall_clock_monotone() {
        let c = Clock::wall();
        assert!(!c.is_virtual());
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }
}
