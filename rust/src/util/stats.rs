//! Summary statistics used by the DSE sweeps and the coordinator metrics.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for len < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile (`q` in [0, 100]) of an unsorted slice.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = q.clamp(0.0, 100.0) / 100.0;
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Min/max of a slice, `None` when empty.
pub fn min_max(xs: &[f64]) -> Option<(f64, f64)> {
    if xs.is_empty() {
        return None;
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    Some((lo, hi))
}

/// Welford-style streaming summary: mean/std/min/max without materializing
/// the sample vector (the Monte-Carlo engine's zero-allocation accumulator).
///
/// [`Streaming::merge`] combines two partial accumulators with the Chan
/// et al. parallel update. Merging is *not* bit-identical to pushing the
/// same samples sequentially (floating-point update order differs), but it
/// IS deterministic: a **fixed partition merged in a fixed order** always
/// reproduces the same bits, no matter which thread computed which partial.
/// That is the property the pool-parallel Monte Carlo leans on — blocks are
/// always [`crate::mram::montecarlo::BLOCK_SAMPLES`] wide and always merge
/// in block-index order, so worker count and chunk size cannot change the
/// result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Streaming {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Streaming {
    fn default() -> Self {
        Self::new()
    }
}

impl Streaming {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Record one observation.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Exact merge of another accumulator into this one.
    pub fn merge(&mut self, o: &Streaming) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *o;
            return;
        }
        let n = self.n + o.n;
        let d = o.mean - self.mean;
        self.mean += d * o.n as f64 / n as f64;
        self.m2 += o.m2 + d * d * (self.n as f64 * o.n as f64 / n as f64);
        self.n = n;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean; 0.0 when empty (matching [`mean`]).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population standard deviation; 0.0 for n < 2 (matching [`std_dev`]).
    pub fn std_dev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }

    /// Smallest observation; 0.0 when empty (record-friendly, like the old
    /// `min_max(..).unwrap_or((0.0, 0.0))` callers).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest observation; 0.0 when empty.
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Streaming latency histogram for the coordinator (fixed log-spaced buckets).
///
/// Buckets are powers of two in microseconds from 1us to ~17min, which is
/// plenty for PJRT execute latencies; recording is O(1) and lock-free when
/// wrapped per-worker.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 32], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record_us(&mut self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile from the log-spaced buckets (upper bound of the
    /// bucket containing the q-quantile sample).
    pub fn percentile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 100.0) / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return 1u64 << (i + 1); // bucket upper bound in us
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Sub-bucket resolution of [`QuantileSketch`]: 2^6 = 64 linear sub-buckets
/// per power-of-two octave, giving a guaranteed relative error ≤ 1/64.
const SKETCH_SUB_BITS: usize = 6;
const SKETCH_SUB: usize = 1 << SKETCH_SUB_BITS;

/// Fixed-size log-linear quantile sketch over `u64` values (HDR-histogram
/// style), the O(1)-memory replacement for exact-sort percentiles at fleet
/// scale (1e6–1e8 recorded values).
///
/// Layout: values below 64 land in exact unit buckets; a value `v ≥ 64` in
/// octave `o = 63 - v.leading_zeros()` lands in one of 64 linear sub-buckets
/// of width `2^(o-6)`. Quantiles report the **inclusive upper bound** of the
/// bucket holding the target-rank sample, clamped to the exact maximum, so
/// for any recorded quantile `exact ≤ sketch ≤ exact·(1 + 1/64)` — the
/// documented ≤ 1.6 % error bound (see EXPERIMENTS.md §Fleet-simulation).
///
/// The footprint is a fixed [`QuantileSketch::BUCKETS`]-slot table
/// (~30 KiB) regardless of how many values are recorded, and
/// [`QuantileSketch::merge`] is a commutative integer bucket-wise add:
/// merging per-shard sketches in shard-index order is bit-identical at any
/// worker count (cf. [`Streaming::merge`]'s fixed-order contract — the
/// sketch is even stronger, being order-independent outright).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuantileSketch {
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new()
    }
}

impl QuantileSketch {
    /// Fixed table size: 58 octaves × 64 sub-buckets + 64 exact unit slots.
    pub const BUCKETS: usize = (64 - SKETCH_SUB_BITS) * SKETCH_SUB + SKETCH_SUB;

    pub fn new() -> Self {
        Self { buckets: vec![0; Self::BUCKETS], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    #[inline]
    fn index(v: u64) -> usize {
        if v < SKETCH_SUB as u64 {
            v as usize
        } else {
            let oct = 63 - v.leading_zeros() as usize;
            let sub = ((v >> (oct - SKETCH_SUB_BITS)) as usize) & (SKETCH_SUB - 1);
            (oct - SKETCH_SUB_BITS + 1) * SKETCH_SUB + sub
        }
    }

    /// Inclusive upper bound of bucket `idx` (exact for the unit slots).
    #[inline]
    fn upper(idx: usize) -> u64 {
        if idx < SKETCH_SUB {
            idx as u64
        } else {
            let oct = idx / SKETCH_SUB + (SKETCH_SUB_BITS - 1);
            let shift = oct - SKETCH_SUB_BITS;
            let lo = ((SKETCH_SUB + idx % SKETCH_SUB) as u64) << shift;
            lo + (1u64 << shift) - 1
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact mean (the sum is tracked exactly in integers); 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Exact minimum; 0 when empty.
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Exact maximum; 0 when empty.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate `q`-th percentile (`q` in [0, 100]): the upper bound of
    /// the bucket holding the rank-`ceil(q·n/100)` sample, clamped to the
    /// exact max so `quantile(q) ≤ max()` always holds (the old
    /// `LatencyHistogram` could overshoot the max by a whole power of two).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (((q.clamp(0.0, 100.0) / 100.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::upper(i).min(self.max);
            }
        }
        self.max
    }

    /// Commutative bucket-wise merge — pure integer adds, so any merge
    /// order over a fixed partition reproduces identical bits.
    pub fn merge(&mut self, other: &QuantileSketch) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Jain–Chlamtac P² single-quantile estimator: five markers, parabolic
/// adjustment, O(1) memory. Kept as an *independent cross-check* on
/// [`QuantileSketch`] (the sketch has a hard error bound; P² does not, but
/// it is the classic streaming estimator the literature reaches for, so the
/// unit tests pin the two against exact sorts on the same stream).
#[derive(Debug, Clone)]
pub struct P2Quantile {
    p: f64,
    n: u64,
    q: [f64; 5],
    pos: [f64; 5],
    warmup: Vec<f64>,
}

impl P2Quantile {
    /// `p` is the quantile in (0, 1), e.g. 0.99 for p99.
    pub fn new(p: f64) -> Self {
        Self {
            p: p.clamp(0.0, 1.0),
            n: 0,
            q: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            warmup: Vec::with_capacity(5),
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn record(&mut self, x: f64) {
        self.n += 1;
        if self.n <= 5 {
            self.warmup.push(x);
            if self.n == 5 {
                self.warmup.sort_by(|a, b| a.partial_cmp(b).unwrap());
                for (i, &v) in self.warmup.iter().enumerate() {
                    self.q[i] = v;
                }
            }
            return;
        }
        // Locate the cell, stretching the extreme markers when x escapes.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x < self.q[1] {
            0
        } else if x < self.q[2] {
            1
        } else if x < self.q[3] {
            2
        } else if x <= self.q[4] {
            3
        } else {
            self.q[4] = x;
            3
        };
        for pos in self.pos.iter_mut().skip(k + 1) {
            *pos += 1.0;
        }
        let n = self.n as f64;
        let want = [
            1.0,
            1.0 + (n - 1.0) * self.p / 2.0,
            1.0 + (n - 1.0) * self.p,
            1.0 + (n - 1.0) * (1.0 + self.p) / 2.0,
            n,
        ];
        for i in 1..4 {
            let d = want[i] - self.pos[i];
            if (d >= 1.0 && self.pos[i + 1] - self.pos[i] > 1.0)
                || (d <= -1.0 && self.pos[i - 1] - self.pos[i] < -1.0)
            {
                let s = if d >= 1.0 { 1.0 } else { -1.0 };
                let cand = self.parabolic(i, s);
                self.q[i] = if self.q[i - 1] < cand && cand < self.q[i + 1] {
                    cand
                } else {
                    self.linear(i, s)
                };
                self.pos[i] += s;
            }
        }
    }

    fn parabolic(&self, i: usize, s: f64) -> f64 {
        let (q, pos) = (&self.q, &self.pos);
        q[i] + s / (pos[i + 1] - pos[i - 1])
            * ((pos[i] - pos[i - 1] + s) * (q[i + 1] - q[i]) / (pos[i + 1] - pos[i])
                + (pos[i + 1] - pos[i] - s) * (q[i] - q[i - 1]) / (pos[i] - pos[i - 1]))
    }

    fn linear(&self, i: usize, s: f64) -> f64 {
        let j = if s > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + s * (self.q[j] - self.q[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate; exact order statistic while fewer than five samples
    /// have been seen, 0.0 when empty.
    pub fn value(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if self.n < 5 {
            let mut v = self.warmup.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let idx = (self.p * (v.len() - 1) as f64).round() as usize;
            return v[idx.min(v.len() - 1)];
        }
        self.q[2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mean_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&v, 50.0), 2.5);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn min_max_works() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), Some((-1.0, 3.0)));
        assert_eq!(min_max(&[]), None);
    }

    #[test]
    fn streaming_matches_batch_stats() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Streaming::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!((s.min(), s.max()), min_max(&xs).unwrap());
    }

    #[test]
    fn streaming_empty_is_zeroed() {
        let s = Streaming::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std_dev(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        // Single observation: std is 0 (population, n < 2).
        let mut s1 = Streaming::new();
        s1.push(3.5);
        assert_eq!(s1.std_dev(), 0.0);
        assert_eq!((s1.min(), s1.max()), (3.5, 3.5));
    }

    #[test]
    fn streaming_merge_matches_sequential() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64 * 0.13 - 5.0).collect();
        let mut whole = Streaming::new();
        for &x in &xs {
            whole.push(x);
        }
        for split in [1usize, 13, 500, 999] {
            let (a, b) = xs.split_at(split);
            let mut left = Streaming::new();
            let mut right = Streaming::new();
            a.iter().for_each(|&x| left.push(x));
            b.iter().for_each(|&x| right.push(x));
            left.merge(&right);
            assert_eq!(left.count(), whole.count());
            assert!((left.mean() - whole.mean()).abs() < 1e-10, "split={split}");
            assert!((left.std_dev() - whole.std_dev()).abs() < 1e-10, "split={split}");
            assert_eq!(left.min(), whole.min());
            assert_eq!(left.max(), whole.max());
        }
        // Merging into/from empty is the identity.
        let mut e = Streaming::new();
        e.merge(&whole);
        assert_eq!(e, whole);
        let mut w2 = whole;
        w2.merge(&Streaming::new());
        assert_eq!(w2, whole);
    }

    #[test]
    fn streaming_fixed_merge_order_is_reproducible() {
        // Same partition, same order → bit-identical results (the MC
        // determinism contract); this holds regardless of who computed the
        // partials.
        let xs: Vec<f64> = (0..4096).map(|i| (i as f64).sin()).collect();
        let fold = |chunk_size: usize| {
            let mut acc = Streaming::new();
            for c in xs.chunks(chunk_size) {
                let mut part = Streaming::new();
                c.iter().for_each(|&x| part.push(x));
                acc.merge(&part);
            }
            (acc.mean().to_bits(), acc.std_dev().to_bits())
        };
        assert_eq!(fold(256), fold(256));
    }

    /// Rank-`ceil(q·n/100)` order statistic of a sorted copy — the exact
    /// reference the sketch's quantile definition is pinned against.
    fn exact_rank(sorted: &[u64], q: f64) -> u64 {
        let target = (((q / 100.0) * sorted.len() as f64).ceil() as usize).max(1);
        sorted[target - 1]
    }

    /// Heavy-tailed sample stream (Pareto-ish) in microsecond scale.
    fn tail_samples(n: usize, seed: u64) -> Vec<u64> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n).map(|_| (150.0 / (1.0 - rng.next_f64()).powf(0.6)) as u64).collect()
    }

    /// The satellite gate: sketch vs exact sort at 1e5 samples, within the
    /// documented bound `exact ≤ sketch ≤ exact·(1 + 1/64)`.
    #[test]
    fn sketch_matches_exact_sort_within_documented_bound_at_1e5() {
        let xs = tail_samples(100_000, 0xF1EE7);
        let mut sk = QuantileSketch::new();
        for &x in &xs {
            sk.record(x);
        }
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        for q in [10.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            let exact = exact_rank(&sorted, q);
            let approx = sk.quantile(q);
            assert!(approx >= exact, "q={q}: sketch {approx} < exact {exact}");
            assert!(
                approx - exact <= exact / 64 + 1,
                "q={q}: sketch {approx} overshoots exact {exact} past 1/64"
            );
        }
        assert_eq!(sk.max(), *sorted.last().unwrap());
        assert_eq!(sk.min(), sorted[0]);
        assert_eq!(sk.count(), 100_000);
        let exact_mean = sorted.iter().map(|&v| v as f64).sum::<f64>() / 100_000.0;
        assert!((sk.mean() - exact_mean).abs() < 1e-6, "integer sum ⇒ exact mean");
        // p999 never exceeds the true max (the old histogram's overshoot bug).
        assert!(sk.quantile(99.9) <= sk.max());
    }

    #[test]
    fn sketch_is_exact_below_64_and_empty_is_zeroed() {
        let mut sk = QuantileSketch::new();
        assert_eq!((sk.quantile(50.0), sk.max(), sk.min(), sk.count()), (0, 0, 0, 0));
        for v in [3u64, 7, 7, 12, 63] {
            sk.record(v);
        }
        assert_eq!(sk.quantile(0.0), 3);
        assert_eq!(sk.quantile(50.0), 7);
        assert_eq!(sk.quantile(100.0), 63);
    }

    #[test]
    fn sketch_merge_is_order_independent_and_matches_sequential() {
        let xs = tail_samples(10_000, 0xCAFE);
        let mut whole = QuantileSketch::new();
        xs.iter().for_each(|&x| whole.record(x));
        let parts: Vec<QuantileSketch> = xs
            .chunks(977)
            .map(|c| {
                let mut s = QuantileSketch::new();
                c.iter().for_each(|&x| s.record(x));
                s
            })
            .collect();
        let mut fwd = QuantileSketch::new();
        parts.iter().for_each(|p| fwd.merge(p));
        let mut rev = QuantileSketch::new();
        parts.iter().rev().for_each(|p| rev.merge(p));
        assert_eq!(fwd, whole, "shard-order merge must equal the sequential stream");
        assert_eq!(rev, whole, "integer buckets make the merge commutative");
    }

    #[test]
    fn sketch_footprint_is_fixed() {
        // O(1) memory at any request count: the table never grows.
        assert_eq!(QuantileSketch::BUCKETS, 3776);
        let mut sk = QuantileSketch::new();
        for i in 0..100_000u64 {
            sk.record(i * 37 + 1);
        }
        assert_eq!(sk.buckets.len(), QuantileSketch::BUCKETS);
        sk.record(u64::MAX); // extreme octave still lands in the fixed table
        assert_eq!(sk.max(), u64::MAX);
    }

    /// P² cross-check: the independent streaming estimator lands close to
    /// the same exact sorts the sketch is pinned against.
    #[test]
    fn p2_estimator_tracks_exact_sort() {
        let xs = tail_samples(100_000, 0xBEEF);
        let mut p50 = P2Quantile::new(0.5);
        let mut p99 = P2Quantile::new(0.99);
        for &x in &xs {
            p50.record(x as f64);
            p99.record(x as f64);
        }
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        let e50 = exact_rank(&sorted, 50.0) as f64;
        let e99 = exact_rank(&sorted, 99.0) as f64;
        assert!((p50.value() - e50).abs() / e50 < 0.05, "p50 {} vs {e50}", p50.value());
        assert!((p99.value() - e99).abs() / e99 < 0.15, "p99 {} vs {e99}", p99.value());
        // Short streams fall back to exact order statistics.
        let mut short = P2Quantile::new(0.5);
        assert_eq!(short.value(), 0.0);
        for x in [5.0, 1.0, 3.0] {
            short.record(x);
        }
        assert_eq!(short.value(), 3.0);
    }

    #[test]
    fn histogram_percentiles_monotone() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 20, 40, 80, 160, 320, 1000, 5000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 8);
        assert!(h.percentile_us(50.0) <= h.percentile_us(99.0));
        assert!(h.mean_us() > 0.0);
        let mut h2 = LatencyHistogram::new();
        h2.record_us(1);
        h2.merge(&h);
        assert_eq!(h2.count(), 9);
    }
}
