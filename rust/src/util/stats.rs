//! Summary statistics used by the DSE sweeps and the coordinator metrics.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for len < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile (`q` in [0, 100]) of an unsorted slice.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = q.clamp(0.0, 100.0) / 100.0;
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Min/max of a slice, `None` when empty.
pub fn min_max(xs: &[f64]) -> Option<(f64, f64)> {
    if xs.is_empty() {
        return None;
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    Some((lo, hi))
}

/// Welford-style streaming summary: mean/std/min/max without materializing
/// the sample vector (the Monte-Carlo engine's zero-allocation accumulator).
///
/// [`Streaming::merge`] combines two partial accumulators with the Chan
/// et al. parallel update. Merging is *not* bit-identical to pushing the
/// same samples sequentially (floating-point update order differs), but it
/// IS deterministic: a **fixed partition merged in a fixed order** always
/// reproduces the same bits, no matter which thread computed which partial.
/// That is the property the pool-parallel Monte Carlo leans on — blocks are
/// always [`crate::mram::montecarlo::BLOCK_SAMPLES`] wide and always merge
/// in block-index order, so worker count and chunk size cannot change the
/// result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Streaming {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Streaming {
    fn default() -> Self {
        Self::new()
    }
}

impl Streaming {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Record one observation.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Exact merge of another accumulator into this one.
    pub fn merge(&mut self, o: &Streaming) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *o;
            return;
        }
        let n = self.n + o.n;
        let d = o.mean - self.mean;
        self.mean += d * o.n as f64 / n as f64;
        self.m2 += o.m2 + d * d * (self.n as f64 * o.n as f64 / n as f64);
        self.n = n;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean; 0.0 when empty (matching [`mean`]).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population standard deviation; 0.0 for n < 2 (matching [`std_dev`]).
    pub fn std_dev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }

    /// Smallest observation; 0.0 when empty (record-friendly, like the old
    /// `min_max(..).unwrap_or((0.0, 0.0))` callers).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest observation; 0.0 when empty.
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Streaming latency histogram for the coordinator (fixed log-spaced buckets).
///
/// Buckets are powers of two in microseconds from 1us to ~17min, which is
/// plenty for PJRT execute latencies; recording is O(1) and lock-free when
/// wrapped per-worker.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 32], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record_us(&mut self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile from the log-spaced buckets (upper bound of the
    /// bucket containing the q-quantile sample).
    pub fn percentile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 100.0) / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return 1u64 << (i + 1); // bucket upper bound in us
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&v, 50.0), 2.5);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn min_max_works() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), Some((-1.0, 3.0)));
        assert_eq!(min_max(&[]), None);
    }

    #[test]
    fn streaming_matches_batch_stats() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Streaming::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!((s.min(), s.max()), min_max(&xs).unwrap());
    }

    #[test]
    fn streaming_empty_is_zeroed() {
        let s = Streaming::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std_dev(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        // Single observation: std is 0 (population, n < 2).
        let mut s1 = Streaming::new();
        s1.push(3.5);
        assert_eq!(s1.std_dev(), 0.0);
        assert_eq!((s1.min(), s1.max()), (3.5, 3.5));
    }

    #[test]
    fn streaming_merge_matches_sequential() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64 * 0.13 - 5.0).collect();
        let mut whole = Streaming::new();
        for &x in &xs {
            whole.push(x);
        }
        for split in [1usize, 13, 500, 999] {
            let (a, b) = xs.split_at(split);
            let mut left = Streaming::new();
            let mut right = Streaming::new();
            a.iter().for_each(|&x| left.push(x));
            b.iter().for_each(|&x| right.push(x));
            left.merge(&right);
            assert_eq!(left.count(), whole.count());
            assert!((left.mean() - whole.mean()).abs() < 1e-10, "split={split}");
            assert!((left.std_dev() - whole.std_dev()).abs() < 1e-10, "split={split}");
            assert_eq!(left.min(), whole.min());
            assert_eq!(left.max(), whole.max());
        }
        // Merging into/from empty is the identity.
        let mut e = Streaming::new();
        e.merge(&whole);
        assert_eq!(e, whole);
        let mut w2 = whole;
        w2.merge(&Streaming::new());
        assert_eq!(w2, whole);
    }

    #[test]
    fn streaming_fixed_merge_order_is_reproducible() {
        // Same partition, same order → bit-identical results (the MC
        // determinism contract); this holds regardless of who computed the
        // partials.
        let xs: Vec<f64> = (0..4096).map(|i| (i as f64).sin()).collect();
        let fold = |chunk_size: usize| {
            let mut acc = Streaming::new();
            for c in xs.chunks(chunk_size) {
                let mut part = Streaming::new();
                c.iter().for_each(|&x| part.push(x));
                acc.merge(&part);
            }
            (acc.mean().to_bits(), acc.std_dev().to_bits())
        };
        assert_eq!(fold(256), fold(256));
    }

    #[test]
    fn histogram_percentiles_monotone() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 20, 40, 80, 160, 320, 1000, 5000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 8);
        assert!(h.percentile_us(50.0) <= h.percentile_us(99.0));
        assert!(h.mean_us() > 0.0);
        let mut h2 = LatencyHistogram::new();
        h2.record_us(1);
        h2.merge(&h);
        assert_eq!(h2.count(), 9);
    }
}
