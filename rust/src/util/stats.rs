//! Summary statistics used by the DSE sweeps and the coordinator metrics.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for len < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile (`q` in [0, 100]) of an unsorted slice.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = q.clamp(0.0, 100.0) / 100.0;
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Min/max of a slice, `None` when empty.
pub fn min_max(xs: &[f64]) -> Option<(f64, f64)> {
    if xs.is_empty() {
        return None;
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    Some((lo, hi))
}

/// Streaming latency histogram for the coordinator (fixed log-spaced buckets).
///
/// Buckets are powers of two in microseconds from 1us to ~17min, which is
/// plenty for PJRT execute latencies; recording is O(1) and lock-free when
/// wrapped per-worker.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 32], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record_us(&mut self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile from the log-spaced buckets (upper bound of the
    /// bucket containing the q-quantile sample).
    pub fn percentile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 100.0) / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return 1u64 << (i + 1); // bucket upper bound in us
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&v, 50.0), 2.5);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn min_max_works() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), Some((-1.0, 3.0)));
        assert_eq!(min_max(&[]), None);
    }

    #[test]
    fn histogram_percentiles_monotone() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 20, 40, 80, 160, 320, 1000, 5000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 8);
        assert!(h.percentile_us(50.0) <= h.percentile_us(99.0));
        assert!(h.mean_us() > 0.0);
        let mut h2 = LatencyHistogram::new();
        h2.record_us(1);
        h2.merge(&h);
        assert_eq!(h2.count(), 9);
    }
}
