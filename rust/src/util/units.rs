//! Unit helpers: byte sizes, time and energy formatting for reports.

pub const KB: u64 = 1024;
pub const MB: u64 = 1024 * 1024;

/// Format a byte count as a human-readable string ("12.00 MB", "52.0 KB").
pub fn fmt_bytes(b: u64) -> String {
    let bf = b as f64;
    if b >= MB {
        format!("{:.2} MB", bf / MB as f64)
    } else if b >= KB {
        format!("{:.1} KB", bf / KB as f64)
    } else {
        format!("{b} B")
    }
}

/// Format seconds with an SI prefix ("1.50 s", "230 ms", "17 ns", "3.0 yr").
pub fn fmt_time(s: f64) -> String {
    const YEAR: f64 = 365.25 * 24.0 * 3600.0;
    if s >= YEAR {
        format!("{:.2} yr", s / YEAR)
    } else if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.3} ns", s * 1e9)
    }
}

/// Format joules with an SI prefix ("2.1 mJ", "13 pJ").
pub fn fmt_energy(j: f64) -> String {
    if j >= 1.0 {
        format!("{j:.3} J")
    } else if j >= 1e-3 {
        format!("{:.3} mJ", j * 1e3)
    } else if j >= 1e-6 {
        format!("{:.3} uJ", j * 1e6)
    } else if j >= 1e-9 {
        format!("{:.3} nJ", j * 1e9)
    } else {
        format!("{:.3} pJ", j * 1e12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(52 * KB), "52.0 KB");
        assert_eq!(fmt_bytes(12 * MB), "12.00 MB");
    }

    #[test]
    fn times() {
        assert_eq!(fmt_time(1.5), "1.500 s");
        assert_eq!(fmt_time(0.23), "230.000 ms");
        assert_eq!(fmt_time(17e-9), "17.000 ns");
        assert!(fmt_time(3.0 * 365.25 * 24.0 * 3600.0).contains("yr"));
    }

    #[test]
    fn energies() {
        assert_eq!(fmt_energy(2.1e-3), "2.100 mJ");
        assert_eq!(fmt_energy(13e-12), "13.000 pJ");
    }
}
