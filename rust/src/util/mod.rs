//! Small shared utilities: units, statistics, bisection root finding, the
//! offline JSON codec, and the work-stealing thread pool behind `dse::engine`.

pub mod bench;
pub mod bf16;
pub mod cli;
pub mod clock;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod units;

/// Bisection root finder for monotone functions.
///
/// Finds `x` in `[lo, hi]` such that `f(x) ~= 0`, assuming `f(lo)` and
/// `f(hi)` bracket a root. Used by the Δ-scaling solver where the reliability
/// equations (retention failure, WER, read disturb) are monotone in Δ, pulse
/// width, or current ratio but have no closed-form inverse.
///
/// Returns `None` if the root is not bracketed.
pub fn bisect(mut lo: f64, mut hi: f64, tol: f64, f: impl Fn(f64) -> f64) -> Option<f64> {
    let (flo, fhi) = (f(lo), f(hi));
    if flo == 0.0 {
        return Some(lo);
    }
    if fhi == 0.0 {
        return Some(hi);
    }
    if flo.signum() == fhi.signum() {
        return None;
    }
    // 200 iterations halves the bracket well below f64 resolution for any
    // practical [lo, hi]; tol is on the bracket width.
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        let fmid = f(mid);
        if fmid == 0.0 || (hi - lo) < tol {
            return Some(mid);
        }
        if fmid.signum() == flo.signum() {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(0.5 * (lo + hi))
}

/// `ceil(a / b)` for positive integers (the ⌈·⌉ of the paper's Eq. 2, 8).
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bisect_finds_sqrt2() {
        let r = bisect(0.0, 2.0, 1e-12, |x| x * x - 2.0).unwrap();
        assert!((r - std::f64::consts::SQRT_2).abs() < 1e-9);
    }

    #[test]
    fn bisect_rejects_unbracketed() {
        assert!(bisect(3.0, 4.0, 1e-9, |x| x * x - 2.0).is_none());
    }

    #[test]
    fn bisect_exact_endpoints() {
        assert_eq!(bisect(0.0, 1.0, 1e-9, |x| x), Some(0.0));
        assert_eq!(bisect(-1.0, 0.0, 1e-9, |x| x), Some(0.0));
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(0, 3), 0);
    }
}
