//! Tiny benchmark harness (offline build: no criterion).
//!
//! `cargo bench` runs each bench binary with `--bench`; [`Bencher`] times a
//! closure with warmup + multiple measured samples and prints a
//! `name  median ± spread  (n iters)` line. Good enough for the §Perf
//! before/after ledger and the per-figure regeneration-cost benches.
//!
//! [`Ledger`] collects results into the machine-readable `BENCH_*.json`
//! trajectory (name → median/mean/p95/min/max ns + optional throughput):
//! bench binaries honor `--bench-json <path>` (see [`bench_json_from_args`])
//! so CI can archive one JSON artifact per bench run, and `--smoke` (see
//! [`smoke_from_args`]) for the reduced-n every-PR compile-and-run check.
//!
//! The saved-baseline workflow (criterion-style, offline): `--save-baseline
//! <path>` merges this run's entries into a baseline file, and `--baseline
//! <path>` compares the run against one — per-bench relative delta on the
//! *median* (stable under CI noise), a [`BaselineGate`] with a 15% tolerance
//! and an absolute noise floor, and a non-zero exit on regression so CI can
//! gate on it. `--baseline-report <path>` additionally writes the
//! machine-readable delta document. [`finish`] is the shared bench-binary
//! tail wiring all four flags.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::json::Json;

/// One benchmark run's summary statistics (nanoseconds per iteration).
#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
}

/// The harness.
pub struct Bencher {
    /// Target wall time per sample (s).
    pub sample_target_s: f64,
    /// Number of measured samples.
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { sample_target_s: 0.05, samples: 12 }
    }
}

impl Bencher {
    /// Quick harness for cheap closures.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, printing a summary line; returns the stats. The closure's
    /// return value is consumed with `std::hint::black_box` to keep the
    /// optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Calibrate: how many iters fit the per-sample budget?
        let t0 = Instant::now();
        std::hint::black_box(f());
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.sample_target_s / once).ceil() as u64).clamp(1, 1_000_000);

        // Warmup.
        for _ in 0..iters.min(3) {
            std::hint::black_box(f());
        }

        let mut per_iter: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            per_iter.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
        }
        per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = per_iter.len();
        let res = BenchResult {
            median_ns: per_iter[n / 2],
            mean_ns: per_iter.iter().sum::<f64>() / n as f64,
            // Nearest-rank p95: ceil(0.95·n) in 1-based rank terms.
            p95_ns: per_iter[((95 * n).div_ceil(100)).saturating_sub(1).min(n - 1)],
            min_ns: per_iter[0],
            max_ns: *per_iter.last().unwrap(),
            iters_per_sample: iters,
            samples: self.samples,
        };
        println!(
            "bench {:<44} {:>12}/iter  (min {}, max {}, {}x{} iters)",
            name,
            fmt_ns(res.median_ns),
            fmt_ns(res.min_ns),
            fmt_ns(res.max_ns),
            res.samples,
            res.iters_per_sample,
        );
        res
    }
}

/// One [`Ledger`] entry: the [`BenchResult`] summary plus an optional
/// throughput derived from a caller-supplied per-iteration work amount.
#[derive(Debug, Clone)]
struct LedgerEntry {
    median_ns: f64,
    mean_ns: f64,
    p95_ns: f64,
    min_ns: f64,
    max_ns: f64,
    throughput_per_s: Option<f64>,
    throughput_unit: Option<String>,
}

impl LedgerEntry {
    fn of(r: &BenchResult) -> Self {
        Self {
            median_ns: r.median_ns,
            mean_ns: r.mean_ns,
            p95_ns: r.p95_ns,
            min_ns: r.min_ns,
            max_ns: r.max_ns,
            throughput_per_s: None,
            throughput_unit: None,
        }
    }
}

/// Machine-readable bench trajectory: ordered `name → summary` records that
/// serialize to the `BENCH_*.json` schema CI archives per run.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    entries: BTreeMap<String, LedgerEntry>,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Record a plain timing result.
    pub fn add(&mut self, name: &str, r: &BenchResult) {
        self.entries.insert(name.to_string(), LedgerEntry::of(r));
    }

    /// Record a result whose iteration processes `work_per_iter` `unit`s
    /// (samples, bytes, ...): throughput = work / median time.
    pub fn add_throughput(&mut self, name: &str, r: &BenchResult, work_per_iter: f64, unit: &str) {
        let mut e = LedgerEntry::of(r);
        e.throughput_per_s = Some(work_per_iter / (r.median_ns * 1e-9));
        e.throughput_unit = Some(unit.to_string());
        self.entries.insert(name.to_string(), e);
    }

    /// The `BENCH_*.json` document: `{"results": {name: {...}}}`.
    pub fn to_json(&self) -> Json {
        let results: BTreeMap<String, Json> = self
            .entries
            .iter()
            .map(|(name, e)| {
                let mut m = BTreeMap::new();
                m.insert("median_ns".to_string(), Json::Num(e.median_ns));
                m.insert("mean_ns".to_string(), Json::Num(e.mean_ns));
                m.insert("p95_ns".to_string(), Json::Num(e.p95_ns));
                m.insert("min_ns".to_string(), Json::Num(e.min_ns));
                m.insert("max_ns".to_string(), Json::Num(e.max_ns));
                if let Some(t) = e.throughput_per_s {
                    m.insert("throughput_per_s".to_string(), Json::Num(t));
                }
                if let Some(u) = &e.throughput_unit {
                    m.insert("throughput_unit".to_string(), Json::Str(u.clone()));
                }
                (name.clone(), Json::Obj(m))
            })
            .collect();
        Json::Obj(BTreeMap::from([("results".to_string(), Json::Obj(results))]))
    }

    /// Parse a `BENCH_*.json` / baseline document. `median_ns` is required
    /// per entry; the other statistics default to the median so baselines
    /// written by older harness versions stay comparable.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let results = j
            .get("results")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("ledger document needs a \"results\" object"))?;
        let mut entries = BTreeMap::new();
        for (name, e) in results {
            let median_ns = e
                .get("median_ns")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("ledger entry {name:?} needs median_ns"))?;
            let stat = |key: &str| e.get(key).and_then(Json::as_f64).unwrap_or(median_ns);
            entries.insert(
                name.clone(),
                LedgerEntry {
                    median_ns,
                    mean_ns: stat("mean_ns"),
                    p95_ns: stat("p95_ns"),
                    min_ns: stat("min_ns"),
                    max_ns: stat("max_ns"),
                    throughput_per_s: e.get("throughput_per_s").and_then(Json::as_f64),
                    throughput_unit: e
                        .get("throughput_unit")
                        .and_then(Json::as_str)
                        .map(str::to_string),
                },
            );
        }
        Ok(Self { entries })
    }

    /// Load a ledger/baseline file.
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(text.trim()).map_err(anyhow::Error::from)?)
    }

    /// Merge `other`'s entries into this ledger (overwriting same-name
    /// entries) — the `--save-baseline` refresh: smoke and full runs carry
    /// different entry names, so a refresh only replaces what it measured.
    pub fn merge(&mut self, other: &Ledger) {
        for (name, e) in &other.entries {
            self.entries.insert(name.clone(), e.clone());
        }
    }

    /// Compare this run against a saved baseline on the median statistic.
    /// Entries missing from the baseline are [`DeltaStatus::New`] (ungated);
    /// baseline entries this run did not produce are ignored, so a smoke run
    /// can be gated against a full-mode baseline without false failures.
    pub fn compare(&self, baseline: &Ledger, gate: BaselineGate) -> BaselineReport {
        let deltas = self
            .entries
            .iter()
            .map(|(name, e)| {
                let cur = e.median_ns;
                match baseline.entries.get(name) {
                    Some(b) => {
                        let base = b.median_ns;
                        let status = if cur > base * (1.0 + gate.tolerance)
                            && cur - base > gate.noise_floor_ns
                        {
                            DeltaStatus::Regressed
                        } else if cur < base * (1.0 - gate.tolerance)
                            && base - cur > gate.noise_floor_ns
                        {
                            DeltaStatus::Improved
                        } else {
                            DeltaStatus::Ok
                        };
                        BenchDelta {
                            name: name.clone(),
                            baseline_ns: Some(base),
                            current_ns: cur,
                            ratio: Some(cur / base),
                            status,
                        }
                    }
                    None => BenchDelta {
                        name: name.clone(),
                        baseline_ns: None,
                        current_ns: cur,
                        ratio: None,
                        status: DeltaStatus::New,
                    },
                }
            })
            .collect();
        BaselineReport { gate, deltas }
    }

    /// Write the trajectory document to `path`.
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
    }
}

/// The regression gate: a run regresses when its median exceeds the baseline
/// median by more than `tolerance` (relative) *and* by more than
/// `noise_floor_ns` (absolute) — the floor keeps nanosecond-class benches
/// from tripping the gate on scheduler jitter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BaselineGate {
    pub tolerance: f64,
    pub noise_floor_ns: f64,
}

impl Default for BaselineGate {
    fn default() -> Self {
        Self { tolerance: 0.15, noise_floor_ns: 100.0 }
    }
}

/// Per-bench comparison outcome against the baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeltaStatus {
    /// Within the gate's tolerance (or inside the noise floor).
    Ok,
    /// Faster than the baseline beyond tolerance + floor.
    Improved,
    /// Slower than the baseline beyond tolerance + floor — fails the gate.
    Regressed,
    /// Not present in the baseline (new bench, or a machine/mode-dependent
    /// name like `_parallel_x8`) — never gated.
    New,
}

impl DeltaStatus {
    /// Stable serialization token.
    pub fn token(&self) -> &'static str {
        match self {
            DeltaStatus::Ok => "ok",
            DeltaStatus::Improved => "improved",
            DeltaStatus::Regressed => "regressed",
            DeltaStatus::New => "new",
        }
    }
}

/// One bench's baseline delta.
#[derive(Debug, Clone)]
pub struct BenchDelta {
    pub name: String,
    pub baseline_ns: Option<f64>,
    pub current_ns: f64,
    /// `current / baseline` medians (`None` for [`DeltaStatus::New`]).
    pub ratio: Option<f64>,
    pub status: DeltaStatus,
}

/// The `--baseline` comparison document: gate parameters + per-bench deltas.
#[derive(Debug, Clone)]
pub struct BaselineReport {
    pub gate: BaselineGate,
    pub deltas: Vec<BenchDelta>,
}

impl BaselineReport {
    /// Did any bench regress beyond the gate?
    pub fn has_regressions(&self) -> bool {
        self.deltas.iter().any(|d| d.status == DeltaStatus::Regressed)
    }

    pub fn to_json(&self) -> Json {
        let results: BTreeMap<String, Json> = self
            .deltas
            .iter()
            .map(|d| {
                let mut m = BTreeMap::new();
                m.insert("status".to_string(), Json::Str(d.status.token().to_string()));
                m.insert("current_ns".to_string(), Json::Num(d.current_ns));
                if let Some(b) = d.baseline_ns {
                    m.insert("baseline_ns".to_string(), Json::Num(b));
                }
                if let Some(r) = d.ratio {
                    m.insert("ratio".to_string(), Json::Num(r));
                }
                (d.name.clone(), Json::Obj(m))
            })
            .collect();
        Json::obj(vec![
            (
                "gate",
                Json::obj(vec![
                    ("tolerance", Json::Num(self.gate.tolerance)),
                    ("noise_floor_ns", Json::Num(self.gate.noise_floor_ns)),
                ]),
            ),
            ("results", Json::Obj(results)),
        ])
    }

    /// Human-readable comparison table (one line per bench).
    pub fn print(&self) {
        println!(
            "-- baseline comparison (gate: +{:.0}% over median, floor {}):",
            self.gate.tolerance * 100.0,
            fmt_ns(self.gate.noise_floor_ns)
        );
        for d in &self.deltas {
            match (d.baseline_ns, d.ratio) {
                (Some(base), Some(ratio)) => println!(
                    "   {:<9} {:<44} {:>12} -> {:>12}  ({:+.1}%)",
                    d.status.token(),
                    d.name,
                    fmt_ns(base),
                    fmt_ns(d.current_ns),
                    (ratio - 1.0) * 100.0
                ),
                _ => println!(
                    "   {:<9} {:<44} {:>12} -> {:>12}",
                    d.status.token(),
                    d.name,
                    "(none)",
                    fmt_ns(d.current_ns)
                ),
            }
        }
    }
}

/// Scan argv for `--bench-json PATH` / `--bench-json=PATH` (bench binaries
/// receive harness flags mixed in, so unknown flags are tolerated).
pub fn bench_json_from_args() -> Option<PathBuf> {
    crate::util::cli::arg_value("bench-json").map(PathBuf::from)
}

/// Scan argv for `--smoke`: CI's reduced-n mode that proves the perf path
/// compiles and runs on every PR without paying full measurement time.
pub fn smoke_from_args() -> bool {
    crate::util::cli::arg_switch("smoke")
}

/// The comparison gate for this invocation: [`BaselineGate::default`]'s
/// tight 15 % relative tolerance, widened by `--gate-tolerance FRAC` (e.g.
/// `--gate-tolerance 1.5` lets the median drift 150 % before failing).
/// The wide setting is how CI compares a quiet-machine full-suite baseline
/// against noisy shared runners: it stops gating small jitter but still
/// catches step regressions (an accidentally serialized hot path, an O(n²)
/// slip) on the smoke-stable entries.
pub fn gate_from_args() -> BaselineGate {
    let mut gate = BaselineGate::default();
    if let Some(tol) = crate::util::cli::arg_value("gate-tolerance") {
        gate.tolerance = tol
            .parse::<f64>()
            .unwrap_or_else(|e| panic!("--gate-tolerance {tol:?}: {e}"));
        assert!(
            gate.tolerance >= 0.0 && gate.tolerance.is_finite(),
            "--gate-tolerance must be a finite non-negative fraction, got {tol:?}"
        );
    }
    gate
}

/// Shared bench-binary tail: write `--bench-json`, refresh `--save-baseline`
/// (load-merge-write, so runs with different entry sets compose), and gate
/// against `--baseline` at the [`gate_from_args`] tolerance (printing the
/// comparison, optionally writing `--baseline-report`, and exiting non-zero
/// on regression — the CI gate).
pub fn finish(ledger: &Ledger) {
    if let Some(path) = bench_json_from_args() {
        ledger.write_json(&path).expect("write --bench-json");
        println!("-- wrote {}", path.display());
    }
    if let Some(path) = crate::util::cli::arg_value("save-baseline").map(PathBuf::from) {
        let mut base = Ledger::load(&path).unwrap_or_default();
        base.merge(ledger);
        base.write_json(&path).expect("write --save-baseline");
        println!("-- saved baseline {} ({} entries)", path.display(), base.len());
    }
    if let Some(path) = crate::util::cli::arg_value("baseline").map(PathBuf::from) {
        let base = Ledger::load(&path)
            .unwrap_or_else(|e| panic!("--baseline {}: {e}", path.display()));
        let report = ledger.compare(&base, gate_from_args());
        report.print();
        if let Some(out) = crate::util::cli::arg_value("baseline-report").map(PathBuf::from) {
            std::fs::write(&out, format!("{}\n", report.to_json()))
                .expect("write --baseline-report");
            println!("-- wrote {}", out.display());
        }
        if report.has_regressions() {
            println!("-- FAIL: bench regression beyond the baseline gate");
            std::process::exit(1);
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.1} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(median: f64) -> BenchResult {
        BenchResult {
            median_ns: median,
            mean_ns: median,
            p95_ns: median,
            min_ns: median,
            max_ns: median,
            iters_per_sample: 1,
            samples: 1,
        }
    }

    #[test]
    fn runs_and_reports() {
        let b = Bencher { sample_target_s: 0.001, samples: 3 };
        let r = b.run("noop-ish", || std::hint::black_box(1 + 1));
        assert!(r.median_ns >= 0.0);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.max_ns);
        // The order statistics nest: median ≤ p95 ≤ max, and the mean stays
        // inside the sample range.
        assert!(r.median_ns <= r.p95_ns && r.p95_ns <= r.max_ns);
        assert!(r.min_ns <= r.mean_ns && r.mean_ns <= r.max_ns);
    }

    #[test]
    fn scales_iterations_for_cheap_ops() {
        let b = Bencher { sample_target_s: 0.001, samples: 2 };
        let r = b.run("cheap", || 42u64);
        assert!(r.iters_per_sample > 100);
    }

    #[test]
    fn ledger_serializes_the_trajectory_schema() {
        let mut l = Ledger::new();
        assert!(l.is_empty());
        let r = BenchResult {
            median_ns: 1000.0,
            mean_ns: 1030.0,
            p95_ns: 1150.0,
            min_ns: 900.0,
            max_ns: 1200.0,
            iters_per_sample: 10,
            samples: 3,
        };
        l.add("plain", &r);
        l.add_throughput("mc", &r, 4096.0, "samples");
        assert_eq!(l.len(), 2);
        let j = l.to_json();
        let results = j.req("results").unwrap();
        let plain = results.get("plain").unwrap();
        assert_eq!(plain.get("median_ns").unwrap().as_f64(), Some(1000.0));
        assert_eq!(plain.get("mean_ns").unwrap().as_f64(), Some(1030.0));
        assert_eq!(plain.get("p95_ns").unwrap().as_f64(), Some(1150.0));
        assert!(plain.get("throughput_per_s").is_none());
        let mc = results.get("mc").unwrap();
        // 4096 units / 1000 ns = 4.096e9 per second.
        let t = mc.get("throughput_per_s").unwrap().as_f64().unwrap();
        assert!((t - 4.096e9).abs() / 4.096e9 < 1e-12, "{t}");
        assert_eq!(mc.get("throughput_unit").unwrap().as_str(), Some("samples"));
        // Round-trips through the offline JSON codec.
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert!(parsed.req("results").unwrap().get("mc").is_some());
    }

    #[test]
    fn ledger_round_trips_and_tolerates_legacy_schemas() {
        let mut l = Ledger::new();
        let r = BenchResult {
            median_ns: 1000.0,
            mean_ns: 1030.0,
            p95_ns: 1150.0,
            min_ns: 900.0,
            max_ns: 1200.0,
            iters_per_sample: 10,
            samples: 3,
        };
        l.add_throughput("mc", &r, 4096.0, "samples");
        let back = Ledger::from_json(&l.to_json()).unwrap();
        assert_eq!(back.to_json().to_string(), l.to_json().to_string());
        // A pre-p95 baseline (median/min/max only) still loads: the missing
        // statistics default to the median.
        let legacy = Json::parse(r#"{"results":{"old":{"median_ns":500.0}}}"#).unwrap();
        let old = Ledger::from_json(&legacy).unwrap();
        assert_eq!(old.entries["old"].p95_ns, 500.0);
        assert_eq!(old.entries["old"].mean_ns, 500.0);
        // And a document without "results" is a clean error.
        assert!(Ledger::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn merge_overwrites_by_name_and_keeps_the_rest() {
        let mut base = Ledger::new();
        base.add("a", &result(100.0));
        base.add("b", &result(200.0));
        let mut run = Ledger::new();
        run.add("b", &result(250.0));
        run.add("c", &result(300.0));
        base.merge(&run);
        assert_eq!(base.len(), 3);
        assert_eq!(base.entries["a"].median_ns, 100.0);
        assert_eq!(base.entries["b"].median_ns, 250.0);
        assert_eq!(base.entries["c"].median_ns, 300.0);
    }

    #[test]
    fn baseline_gate_classifies_deltas() {
        let gate = BaselineGate { tolerance: 0.15, noise_floor_ns: 100.0 };
        let mut baseline = Ledger::new();
        baseline.add("steady", &result(10_000.0));
        baseline.add("slowed", &result(10_000.0));
        baseline.add("faster", &result(10_000.0));
        baseline.add("jitter", &result(200.0));
        baseline.add("retired", &result(1.0));
        let mut run = Ledger::new();
        run.add("steady", &result(10_500.0)); // +5%: within tolerance
        run.add("slowed", &result(12_500.0)); // +25%: regression
        run.add("faster", &result(6_000.0)); // -40%: improvement
        run.add("jitter", &result(260.0)); // +30% but only +60 ns: noise floor
        run.add("fresh", &result(5_000.0)); // not in the baseline
        let report = run.compare(&baseline, gate);
        let status = |name: &str| {
            report.deltas.iter().find(|d| d.name == name).map(|d| d.status).unwrap()
        };
        assert_eq!(status("steady"), DeltaStatus::Ok);
        assert_eq!(status("slowed"), DeltaStatus::Regressed);
        assert_eq!(status("faster"), DeltaStatus::Improved);
        assert_eq!(status("jitter"), DeltaStatus::Ok, "below the noise floor");
        assert_eq!(status("fresh"), DeltaStatus::New);
        // Baseline-only entries are ignored (full-mode baseline, smoke run).
        assert!(report.deltas.iter().all(|d| d.name != "retired"));
        assert!(report.has_regressions());
        // The report document carries the gate and per-bench ratios.
        let j = report.to_json();
        assert_eq!(j.req("gate").unwrap().get("tolerance").unwrap().as_f64(), Some(0.15));
        let slowed = j.req("results").unwrap().get("slowed").unwrap();
        assert_eq!(slowed.get("status").unwrap().as_str(), Some("regressed"));
        assert!((slowed.get("ratio").unwrap().as_f64().unwrap() - 1.25).abs() < 1e-12);
        let fresh = j.req("results").unwrap().get("fresh").unwrap();
        assert_eq!(fresh.get("status").unwrap().as_str(), Some("new"));
        assert!(fresh.get("ratio").is_none());
    }

    #[test]
    fn a_deliberately_slowed_bench_fails_the_gate() {
        // The acceptance demonstration for the CI gate, in miniature: take a
        // clean baseline, slow one bench >15% past the noise floor, and the
        // report must flag exactly that bench as the failing regression.
        let mut baseline = Ledger::new();
        baseline.add("dse/selection_grid_108", &result(1.0e6));
        baseline.add("stall/stalled_walk_resnet50_b16", &result(5.0e4));
        let mut slowed = Ledger::new();
        slowed.add("dse/selection_grid_108", &result(1.0e6 * 1.5)); // sleep injected
        slowed.add("stall/stalled_walk_resnet50_b16", &result(5.0e4));
        let report = slowed.compare(&baseline, BaselineGate::default());
        assert!(report.has_regressions());
        let regressed: Vec<&str> = report
            .deltas
            .iter()
            .filter(|d| d.status == DeltaStatus::Regressed)
            .map(|d| d.name.as_str())
            .collect();
        assert_eq!(regressed, vec!["dse/selection_grid_108"]);
        // The clean run passes the same gate.
        let clean = baseline.compare(&baseline, BaselineGate::default());
        assert!(!clean.has_regressions());
        assert!(clean.deltas.iter().all(|d| d.status == DeltaStatus::Ok));
    }

    #[test]
    fn ledger_writes_a_parseable_file() {
        let mut l = Ledger::new();
        l.add("x", &result(5.0));
        let path = std::env::temp_dir().join("stt_ai_bench_ledger_test.json");
        l.write_json(&path).unwrap();
        let doc = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&doc).unwrap();
        assert!(parsed.req("results").unwrap().get("x").is_some());
        // Load round-trips the file.
        let back = Ledger::load(&path).unwrap();
        assert_eq!(back.len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
