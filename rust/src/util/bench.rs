//! Tiny benchmark harness (offline build: no criterion).
//!
//! `cargo bench` runs each bench binary with `--bench`; [`Bencher`] times a
//! closure with warmup + multiple measured samples and prints a
//! `name  median ± spread  (n iters)` line. Good enough for the §Perf
//! before/after ledger and the per-figure regeneration-cost benches.

use std::time::Instant;

/// One benchmark run's summary statistics (nanoseconds per iteration).
#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
}

/// The harness.
pub struct Bencher {
    /// Target wall time per sample (s).
    pub sample_target_s: f64,
    /// Number of measured samples.
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { sample_target_s: 0.05, samples: 12 }
    }
}

impl Bencher {
    /// Quick harness for cheap closures.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, printing a summary line; returns the stats. The closure's
    /// return value is consumed with `std::hint::black_box` to keep the
    /// optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Calibrate: how many iters fit the per-sample budget?
        let t0 = Instant::now();
        std::hint::black_box(f());
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.sample_target_s / once).ceil() as u64).clamp(1, 1_000_000);

        // Warmup.
        for _ in 0..iters.min(3) {
            std::hint::black_box(f());
        }

        let mut per_iter: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            per_iter.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
        }
        per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let res = BenchResult {
            median_ns: per_iter[per_iter.len() / 2],
            min_ns: per_iter[0],
            max_ns: *per_iter.last().unwrap(),
            iters_per_sample: iters,
            samples: self.samples,
        };
        println!(
            "bench {:<44} {:>12}/iter  (min {}, max {}, {}x{} iters)",
            name,
            fmt_ns(res.median_ns),
            fmt_ns(res.min_ns),
            fmt_ns(res.max_ns),
            res.samples,
            res.iters_per_sample,
        );
        res
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.1} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bencher { sample_target_s: 0.001, samples: 3 };
        let r = b.run("noop-ish", || std::hint::black_box(1 + 1));
        assert!(r.median_ns >= 0.0);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.max_ns);
    }

    #[test]
    fn scales_iterations_for_cheap_ops() {
        let b = Bencher { sample_target_s: 0.001, samples: 2 };
        let r = b.run("cheap", || 42u64);
        assert!(r.iters_per_sample > 100);
    }
}
