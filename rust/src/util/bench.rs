//! Tiny benchmark harness (offline build: no criterion).
//!
//! `cargo bench` runs each bench binary with `--bench`; [`Bencher`] times a
//! closure with warmup + multiple measured samples and prints a
//! `name  median ± spread  (n iters)` line. Good enough for the §Perf
//! before/after ledger and the per-figure regeneration-cost benches.
//!
//! [`Ledger`] collects results into the machine-readable `BENCH_*.json`
//! trajectory (name → median/min/max ns + optional throughput): bench
//! binaries honor `--bench-json <path>` (see [`bench_json_from_args`]) so
//! CI can archive one JSON artifact per bench run, and `--smoke` (see
//! [`smoke_from_args`]) for the reduced-n every-PR compile-and-run check.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::json::Json;

/// One benchmark run's summary statistics (nanoseconds per iteration).
#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
}

/// The harness.
pub struct Bencher {
    /// Target wall time per sample (s).
    pub sample_target_s: f64,
    /// Number of measured samples.
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { sample_target_s: 0.05, samples: 12 }
    }
}

impl Bencher {
    /// Quick harness for cheap closures.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, printing a summary line; returns the stats. The closure's
    /// return value is consumed with `std::hint::black_box` to keep the
    /// optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Calibrate: how many iters fit the per-sample budget?
        let t0 = Instant::now();
        std::hint::black_box(f());
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.sample_target_s / once).ceil() as u64).clamp(1, 1_000_000);

        // Warmup.
        for _ in 0..iters.min(3) {
            std::hint::black_box(f());
        }

        let mut per_iter: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            per_iter.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
        }
        per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let res = BenchResult {
            median_ns: per_iter[per_iter.len() / 2],
            min_ns: per_iter[0],
            max_ns: *per_iter.last().unwrap(),
            iters_per_sample: iters,
            samples: self.samples,
        };
        println!(
            "bench {:<44} {:>12}/iter  (min {}, max {}, {}x{} iters)",
            name,
            fmt_ns(res.median_ns),
            fmt_ns(res.min_ns),
            fmt_ns(res.max_ns),
            res.samples,
            res.iters_per_sample,
        );
        res
    }
}

/// One [`Ledger`] entry: the [`BenchResult`] summary plus an optional
/// throughput derived from a caller-supplied per-iteration work amount.
#[derive(Debug, Clone)]
struct LedgerEntry {
    median_ns: f64,
    min_ns: f64,
    max_ns: f64,
    throughput_per_s: Option<f64>,
    throughput_unit: Option<String>,
}

/// Machine-readable bench trajectory: ordered `name → summary` records that
/// serialize to the `BENCH_*.json` schema CI archives per run.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    entries: BTreeMap<String, LedgerEntry>,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Record a plain timing result.
    pub fn add(&mut self, name: &str, r: &BenchResult) {
        self.entries.insert(
            name.to_string(),
            LedgerEntry {
                median_ns: r.median_ns,
                min_ns: r.min_ns,
                max_ns: r.max_ns,
                throughput_per_s: None,
                throughput_unit: None,
            },
        );
    }

    /// Record a result whose iteration processes `work_per_iter` `unit`s
    /// (samples, bytes, ...): throughput = work / median time.
    pub fn add_throughput(&mut self, name: &str, r: &BenchResult, work_per_iter: f64, unit: &str) {
        self.entries.insert(
            name.to_string(),
            LedgerEntry {
                median_ns: r.median_ns,
                min_ns: r.min_ns,
                max_ns: r.max_ns,
                throughput_per_s: Some(work_per_iter / (r.median_ns * 1e-9)),
                throughput_unit: Some(unit.to_string()),
            },
        );
    }

    /// The `BENCH_*.json` document: `{"results": {name: {...}}}`.
    pub fn to_json(&self) -> Json {
        let results: BTreeMap<String, Json> = self
            .entries
            .iter()
            .map(|(name, e)| {
                let mut m = BTreeMap::new();
                m.insert("median_ns".to_string(), Json::Num(e.median_ns));
                m.insert("min_ns".to_string(), Json::Num(e.min_ns));
                m.insert("max_ns".to_string(), Json::Num(e.max_ns));
                if let Some(t) = e.throughput_per_s {
                    m.insert("throughput_per_s".to_string(), Json::Num(t));
                }
                if let Some(u) = &e.throughput_unit {
                    m.insert("throughput_unit".to_string(), Json::Str(u.clone()));
                }
                (name.clone(), Json::Obj(m))
            })
            .collect();
        Json::Obj(BTreeMap::from([("results".to_string(), Json::Obj(results))]))
    }

    /// Write the trajectory document to `path`.
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
    }
}

/// Scan argv for `--bench-json PATH` / `--bench-json=PATH` (bench binaries
/// receive harness flags mixed in, so unknown flags are tolerated).
pub fn bench_json_from_args() -> Option<PathBuf> {
    crate::util::cli::arg_value("bench-json").map(PathBuf::from)
}

/// Scan argv for `--smoke`: CI's reduced-n mode that proves the perf path
/// compiles and runs on every PR without paying full measurement time.
pub fn smoke_from_args() -> bool {
    crate::util::cli::arg_switch("smoke")
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.1} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bencher { sample_target_s: 0.001, samples: 3 };
        let r = b.run("noop-ish", || std::hint::black_box(1 + 1));
        assert!(r.median_ns >= 0.0);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.max_ns);
    }

    #[test]
    fn scales_iterations_for_cheap_ops() {
        let b = Bencher { sample_target_s: 0.001, samples: 2 };
        let r = b.run("cheap", || 42u64);
        assert!(r.iters_per_sample > 100);
    }

    #[test]
    fn ledger_serializes_the_trajectory_schema() {
        let mut l = Ledger::new();
        assert!(l.is_empty());
        let r = BenchResult {
            median_ns: 1000.0,
            min_ns: 900.0,
            max_ns: 1200.0,
            iters_per_sample: 10,
            samples: 3,
        };
        l.add("plain", &r);
        l.add_throughput("mc", &r, 4096.0, "samples");
        assert_eq!(l.len(), 2);
        let j = l.to_json();
        let results = j.req("results").unwrap();
        let plain = results.get("plain").unwrap();
        assert_eq!(plain.get("median_ns").unwrap().as_f64(), Some(1000.0));
        assert!(plain.get("throughput_per_s").is_none());
        let mc = results.get("mc").unwrap();
        // 4096 units / 1000 ns = 4.096e9 per second.
        let t = mc.get("throughput_per_s").unwrap().as_f64().unwrap();
        assert!((t - 4.096e9).abs() / 4.096e9 < 1e-12, "{t}");
        assert_eq!(mc.get("throughput_unit").unwrap().as_str(), Some("samples"));
        // Round-trips through the offline JSON codec.
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert!(parsed.req("results").unwrap().get("mc").is_some());
    }

    #[test]
    fn ledger_writes_a_parseable_file() {
        let mut l = Ledger::new();
        let r = BenchResult {
            median_ns: 5.0,
            min_ns: 4.0,
            max_ns: 6.0,
            iters_per_sample: 1,
            samples: 1,
        };
        l.add("x", &r);
        let path = std::env::temp_dir().join("stt_ai_bench_ledger_test.json");
        l.write_json(&path).unwrap();
        let doc = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&doc).unwrap();
        assert!(parsed.req("results").unwrap().get("x").is_some());
        let _ = std::fs::remove_file(&path);
    }
}
