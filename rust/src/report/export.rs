//! CSV/JSON export of the figure data series — plot-ready files for anyone
//! regenerating the paper's graphs (`stt-ai figures --csv-dir out/`).
//!
//! Every figure goes through the unified `dse::engine` records: one CSV per
//! sweep whose schema is the sweep's axis columns plus its metric names,
//! and one `sweeps.json` with every record of every sweep. Custom
//! `stt-ai sweep` runs export through the same two helpers.

use std::io::Write;
use std::path::Path;

use crate::dse::engine::{paper_specs, shared_zoo, spec_stall, spec_techcmp, Runner, SweepResult};
use crate::dse::select::{self, DesignSelection};
use crate::util::json::Json;

/// Stable file names for the paper sweeps (kept close to the figure list).
fn file_name(sweep: &str) -> String {
    match sweep {
        "fig10" => "fig10_model_sizes.csv".into(),
        "fig11" => "fig11_glb_capacity.csv".into(),
        "fig12" => "fig12_dram_overhead.csv".into(),
        "fig13" => "fig13_retention.csv".into(),
        "fig14a" => "fig14a_retention_vs_array.csv".into(),
        "fig14b" => "fig14b_retention_vs_batch.csv".into(),
        "fig15" => "fig15_delta_scaling.csv".into(),
        "fig16" => "fig16_energy_area.csv".into(),
        "fig17" => "fig17_lsb_bank.csv".into(),
        "fig18" => "fig18_partial_ofmaps.csv".into(),
        "fig19" => "fig19_scratchpad_energy.csv".into(),
        "techcmp" => "techcmp_technologies.csv".into(),
        "stall" => "stall_write_bandwidth.csv".into(),
        "selection" => "selection_candidates.csv".into(),
        other => format!("{other}.csv"),
    }
}

/// Write one sweep's records as a CSV (axis columns + metric columns).
pub fn write_results_csv(path: &Path, results: &[SweepResult]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    if let Some(first) = results.first() {
        writeln!(f, "{}", first.csv_header())?;
    }
    for r in results {
        writeln!(f, "{}", r.csv_row())?;
    }
    Ok(())
}

/// Write records as a JSON array of `{sweep, point, metrics}` objects.
pub fn export_json(path: &Path, results: &[SweepResult]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", Json::Arr(results.iter().map(SweepResult::to_json).collect()))
}

/// Write selection records (the chosen design points with provenance) as a
/// CSV — the `selection.csv` of `stt-ai figures --csv-dir` and `stt-ai
/// select --csv`.
pub fn write_selection_csv(path: &Path, selections: &[DesignSelection]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    if let Some(first) = selections.first() {
        writeln!(f, "{}", first.csv_header())?;
    }
    for s in selections {
        writeln!(f, "{}", s.csv_row())?;
    }
    Ok(())
}

/// Export every figure's data series into `dir` (CSV per sweep + one JSON
/// dump + Table III + the design-point selection records). Returns the list
/// of files written.
pub fn export_all(dir: &Path) -> std::io::Result<Vec<String>> {
    export_all_with(dir, &Runner::default())
}

pub fn export_all_with(dir: &Path, runner: &Runner) -> std::io::Result<Vec<String>> {
    std::fs::create_dir_all(dir)?;
    let zoo = shared_zoo();
    let mut written = Vec::new();
    let mut all: Vec<SweepResult> = Vec::new();
    // Paper sweeps plus the cross-technology comparison, the write-
    // bandwidth stall comparison and the selection candidate grid.
    for spec in paper_specs(&zoo)
        .into_iter()
        .chain([spec_techcmp(&zoo), spec_stall(&zoo), select::spec_selection(&zoo)])
    {
        let results = runner.run(spec);
        let name = file_name(&results[0].sweep);
        write_results_csv(&dir.join(&name), &results)?;
        written.push(name);
        all.extend(results);
    }

    // The paper-objective selections over the candidate grid: one chosen
    // design point per objective, with provenance.
    let candidates: Vec<SweepResult> =
        all.iter().filter(|r| r.sweep == "selection").cloned().collect();
    let selections = select::paper_selections(&candidates)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
    let sel_csv = "selection.csv";
    write_selection_csv(&dir.join(sel_csv), &selections)?;
    written.push(sel_csv.to_string());

    // Table III is a fixed three-point composition, not a sweep.
    let t3 = "table3_accelerators.csv";
    let mut f = std::fs::File::create(dir.join(t3))?;
    writeln!(f, "accelerator,area_mm2,dynamic_mw,leakage_mw")?;
    for r in super::table3_rows() {
        writeln!(f, "{},{:.4},{:.3},{:.4}", r.name, r.area_mm2, r.dynamic_mw, r.leakage_mw)?;
    }
    written.push(t3.to_string());

    // One JSON dump: every sweep record plus the selection records (their
    // objects keep the same {sweep, point, metrics} core shape, extended
    // with objective/constraint provenance).
    let js = "sweeps.json";
    let mut records: Vec<Json> = all.iter().map(SweepResult::to_json).collect();
    records.extend(selections.iter().map(DesignSelection::to_json));
    let mut f = std::fs::File::create(dir.join(js))?;
    writeln!(f, "{}", Json::Arr(records))?;
    written.push(js.to_string());
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exports_all_figures() {
        let dir = std::env::temp_dir().join("stt_ai_csv_test");
        let files = export_all_with(&dir, &Runner::new(2)).unwrap();
        // 11 sweep CSVs + techcmp + stall + selection candidates
        // + selection picks + table3 + sweeps.json.
        assert_eq!(files.len(), 17, "{files:?}");
        assert!(files.contains(&"techcmp_technologies.csv".to_string()));
        assert!(files.contains(&"stall_write_bandwidth.csv".to_string()));
        assert!(files.contains(&"selection_candidates.csv".to_string()));
        assert!(files.contains(&"selection.csv".to_string()));
        // The paper pick is in the selection records: area objective, Ultra.
        let sel = std::fs::read_to_string(dir.join("selection.csv")).unwrap();
        let area_row = sel.lines().nth(1).unwrap();
        assert!(area_row.starts_with("selection,area,"), "{area_row}");
        assert!(area_row.contains("stt_ai_ultra"), "{area_row}");
        for f in files.iter().filter(|f| f.ends_with(".csv")) {
            let text = std::fs::read_to_string(dir.join(f)).unwrap();
            let lines: Vec<&str> = text.lines().collect();
            assert!(lines.len() >= 2, "{f} must have header + data");
            let cols = lines[0].split(',').count();
            for l in &lines[1..] {
                assert_eq!(l.split(',').count(), cols, "{f}: ragged row {l}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fig13_csv_parses_back() {
        let dir = std::env::temp_dir().join("stt_ai_csv_test2");
        export_all(&dir).unwrap();
        let text = std::fs::read_to_string(dir.join("fig13_retention.csv")).unwrap();
        assert_eq!(text.lines().next().unwrap(), "model,min_t_ret_s,max_t_ret_s");
        let data_rows = text.lines().skip(1).count();
        assert_eq!(data_rows, 19);
        for l in text.lines().skip(1) {
            let parts: Vec<&str> = l.split(',').collect();
            let min: f64 = parts[1].parse().unwrap();
            let max: f64 = parts[2].parse().unwrap();
            assert!(min <= max);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_dump_round_trips() {
        let dir = std::env::temp_dir().join("stt_ai_json_test");
        export_all_with(&dir, &Runner::new(1)).unwrap();
        let text = std::fs::read_to_string(dir.join("sweeps.json")).unwrap();
        let v = Json::parse(text.trim()).unwrap();
        let arr = v.as_arr().unwrap();
        assert!(arr.len() > 300, "all sweeps dumped: {}", arr.len());
        for rec in arr {
            assert!(rec.req_str("sweep").is_ok());
            assert!(rec.req("point").unwrap().as_obj().is_some());
            assert!(rec.req("metrics").unwrap().as_obj().is_some());
        }
        // The selection records ride along in the same dump, identified by
        // their objective field, and parse back into DesignSelections.
        let selections: Vec<&Json> =
            arr.iter().filter(|r| r.get("objective").is_some()).collect();
        assert_eq!(selections.len(), 3, "area/energy/latency paper objectives");
        for s in selections {
            let sel = DesignSelection::from_json(s).unwrap();
            assert_eq!(sel.sweep, "selection");
            assert!(sel.feasible > 0 && sel.feasible <= sel.candidates);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
